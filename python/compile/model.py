"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernel.

BWKM is a clustering-systems paper, so the "model" is the weighted Lloyd
iteration over a dataset partition's representatives (paper Alg. 1 steps
2/4) plus a chunked full-dataset assignment/error program used for the
final E^D(C) evaluation (paper Eq. 1).

Both programs are written against *padded static shapes* so they can be
AOT-lowered once per (mcap, kcap, dcap) variant by aot.py and executed from
the Rust runtime via PJRT. Padding conventions (verified by tests):

  * representative rows >= m carry weight 0      -> no effect on updates,
  * coordinate dims   >= d are zero everywhere   -> no effect on distances,
  * centroid slots    >= K have cmask 0          -> +BIG distance column,
    never selected, and keep their previous value in the update.

The distance + top-2 hot spot is the Pallas kernel (L1); the centroid
update is a one-hot matmul so the whole step is MXU-friendly and fuses into
a single HLO module with no gather/scatter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import distance_top2


def weighted_lloyd_step(reps, weights, centroids, cmask):
    """One weighted-Lloyd iteration over partition representatives.

    Args:
      reps:      (mcap, dcap) f32 — representatives (centers of mass of the
                 blocks of the dataset partition P).
      weights:   (mcap,) f32 — |P| cardinalities; 0 marks padding rows.
      centroids: (kcap, dcap) f32 — current centroid slots.
      cmask:     (kcap,) f32 — 1 for live centroids, 0 for padding.

    Returns a 5-tuple:
      new_centroids: (kcap, dcap) — weighted centers of mass; empty or
                     masked clusters keep their previous centroid.
      idx:           (mcap,) int32 — nearest-centroid assignment.
      d1_sq, d2_sq:  (mcap,) f32 — squared distances to the two nearest
                     live centroids (the Rust side takes sqrt to evaluate
                     the paper's misassignment function, Eq. 3).
      wss:           () f32 — weighted error E^P(C) = sum_i w_i * d1_sq_i.
    """
    d1, d2, idx = distance_top2(reps, centroids, cmask)
    kc = centroids.shape[0]
    onehot = jax.nn.one_hot(idx, kc, dtype=reps.dtype)  # (m, kc)
    wh = onehot * weights[:, None]
    counts = jnp.sum(wh, axis=0)  # (kc,)
    sums = jnp.dot(wh.T, reps, preferred_element_type=jnp.float32)  # (kc, d)
    live = (counts > 0) & (cmask > 0)
    new_c = jnp.where(
        live[:, None], sums / jnp.maximum(counts, 1e-30)[:, None], centroids
    )
    wss = jnp.sum(weights * d1)
    return new_c, idx, d1, d2, wss


def assign_err(points, weights, centroids, cmask):
    """Chunked assignment + weighted SSE, for full-dataset E^D evaluation.

    Same padding conventions as :func:`weighted_lloyd_step`; ``weights`` is
    1.0 for live points and 0.0 for padding rows of the final chunk.

    Returns (idx, sse) with idx (mcap,) int32 and sse a () f32 scalar.
    """
    d1, _, idx = distance_top2(points, centroids, cmask)
    return idx, jnp.sum(weights * d1)


def example_args(mcap: int, kcap: int, dcap: int):
    """ShapeDtypeStructs used to lower either program for a variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((mcap, dcap), f32),
        jax.ShapeDtypeStruct((mcap,), f32),
        jax.ShapeDtypeStruct((kcap, dcap), f32),
        jax.ShapeDtypeStruct((kcap,), f32),
    )


PROGRAMS = {
    "wlloyd_step": weighted_lloyd_step,
    "assign_err": assign_err,
}
