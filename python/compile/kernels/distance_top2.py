"""L1 Pallas kernel: tiled pairwise squared distances + top-2 reduction.

This is the hot spot of every K-means-family algorithm in the paper: the
assignment step. BWKM additionally needs the distance to the *second*
nearest centroid for every representative, because the misassignment
function (paper Eq. 3) is

    eps_{C,D}(B) = max(0, 2 * l_B - (||P - c2|| - ||P - c1||)),

so the kernel returns (d1, d2, argmin) where d1/d2 are the two smallest
*squared* Euclidean distances (callers take sqrt where the paper's delta
needs the metric distance).

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * the distance matrix is computed as ||x||^2 - 2 x.c^T + ||c||^2 so the
    dominant term is a (TM x d) @ (d x K) matmul -> MXU systolic array;
  * the grid tiles the representative axis in TM=128 rows; each tile's
    operands + the (TM x Kcap) distance tile live in VMEM (~30 KiB for
    TM=128, Kcap=32, d=20 -- far under the 16 MiB budget, leaving room for
    double buffering of the HBM->VMEM stream);
  * centroid padding is handled with a mask column adding +BIG, so one
    compiled executable serves every K <= Kcap;
  * the top-2 reduction is a two-pass masked min on the VPU (no sort).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops. Correctness is
pinned against kernels/ref.py by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size along the representatives axis. 128 matches the MXU/VPU lane
# structure on TPU; in interpret mode it only affects trace time.
TILE_M = 128

# Additive penalty for masked-out centroid columns. Large enough to never be
# selected over a real distance, small enough to stay finite in f32. Kept a
# plain Python float: pallas kernels must not capture traced constants.
BIG = 1e30


def _kernel(x_ref, c_ref, cmask_ref, d1_ref, d2_ref, idx_ref):
    """One grid step: TM representatives against all Kcap centroids."""
    x = x_ref[...]  # (TM, d)
    c = c_ref[...]  # (Kc, d)
    cmask = cmask_ref[...]  # (Kc,)

    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; the cross term is the MXU
    # matmul. Accumulate in f32.
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (TM, 1)
    cc = jnp.sum(c * c, axis=1)[None, :]  # (1, Kc)
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # (TM, Kc)
    dist = xx - 2.0 * xc + cc
    # Numerical floor: the decomposition can go slightly negative.
    dist = jnp.maximum(dist, 0.0)
    # Masked centroids can never win the (first or second) min.
    dist = dist + (1.0 - cmask)[None, :] * BIG

    kc = dist.shape[1]
    d1 = jnp.min(dist, axis=1)
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    # Second pass: knock out the winning column, min again.
    winner = jax.nn.one_hot(idx, kc, dtype=dist.dtype)
    d2 = jnp.min(dist + winner * BIG, axis=1)

    d1_ref[...] = d1
    d2_ref[...] = d2
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("tile_m",))
def distance_top2(x, c, cmask, *, tile_m: int = TILE_M):
    """(d1_sq, d2_sq, argmin) for every row of ``x`` against centroids ``c``.

    Args:
      x: (m, d) f32 representatives. ``m`` need not be a tile multiple; rows
        are zero-padded internally and the padding is sliced away.
      c: (kc, d) f32 centroid slots (padded slots arbitrary).
      cmask: (kc,) f32, 1.0 for live centroids, 0.0 for padding.
      tile_m: representative-axis tile.

    Returns:
      d1: (m,) squared distance to the nearest live centroid.
      d2: (m,) squared distance to the second nearest live centroid
          (~1e30 when only one live centroid exists).
      idx: (m,) int32 index of the nearest live centroid.
    """
    m, d = x.shape
    kc = c.shape[0]
    m_pad = ((m + tile_m - 1) // tile_m) * tile_m
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    grid = (m_pad // tile_m,)
    d1, d2, idx = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),
            pl.BlockSpec((kc, d), lambda i: (0, 0)),
            pl.BlockSpec((kc,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
            pl.BlockSpec((tile_m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
            jax.ShapeDtypeStruct((m_pad,), jnp.int32),
        ],
        interpret=True,
    )(x, c, cmask)
    return d1[:m], d2[:m], idx[:m]
