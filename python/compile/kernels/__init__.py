"""Pallas kernels (L1) and their pure-jnp oracles."""

from .distance_top2 import distance_top2, TILE_M, BIG  # noqa: F401
from . import ref  # noqa: F401
