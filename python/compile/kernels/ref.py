"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

Everything here is written the *obvious* way (full broadcasted distance
tensor, sort-based top-2) so it can serve as the ground truth the tiled
kernel is validated against. Never used in artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

BIG = jnp.float32(1e30)


def distance_top2_ref(x, c, cmask):
    """Reference (d1_sq, d2_sq, argmin): direct differences + sort."""
    # (m, kc) squared distances via explicit differences (numerically the
    # "honest" formula, unlike the kernel's matmul decomposition).
    diff = x[:, None, :] - c[None, :, :]
    dist = jnp.sum(diff * diff, axis=-1)
    dist = dist + (1.0 - cmask)[None, :] * BIG
    order = jnp.sort(dist, axis=1)
    d1 = order[:, 0]
    d2 = order[:, 1] if dist.shape[1] > 1 else jnp.full_like(d1, BIG)
    idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
    return d1, d2, idx


def weighted_lloyd_step_ref(reps, weights, centroids, cmask):
    """Reference one weighted-Lloyd iteration (paper Alg. 1 steps 2/4).

    Returns (new_centroids, idx, d1_sq, d2_sq, wss) with the same
    conventions as model.weighted_lloyd_step: empty or masked clusters keep
    their previous centroid; wss = sum_i w_i * d1_sq_i (the weighted error
    E^P(C) of paper §1.2.2.1).
    """
    d1, d2, idx = distance_top2_ref(reps, centroids, cmask)
    kc = centroids.shape[0]
    onehot = (idx[:, None] == jnp.arange(kc)[None, :]).astype(reps.dtype)
    wh = onehot * weights[:, None]  # (m, kc)
    counts = jnp.sum(wh, axis=0)  # (kc,)
    sums = wh.T @ reps  # (kc, d)
    live = (counts > 0) & (cmask > 0)
    new_c = jnp.where(live[:, None], sums / jnp.maximum(counts, 1e-30)[:, None], centroids)
    wss = jnp.sum(weights * d1)
    return new_c, idx, d1, d2, wss


def assign_err_ref(points, weights, centroids, cmask):
    """Reference chunked assignment + weighted SSE (for E^D evaluation)."""
    d1, _, idx = distance_top2_ref(points, centroids, cmask)
    return idx, jnp.sum(weights * d1)
