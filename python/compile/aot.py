"""AOT lowering: JAX programs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md
and gen_hlo.py there).

Each L2 program is lowered once per padded-shape variant; the Rust runtime
(rust/src/runtime/) reads artifacts/manifest.tsv, picks the smallest
variant that fits a request, pads, executes via PJRT, and unpads.

Usage:  python -m compile.aot --out-dir ../artifacts [--full]
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (mcap, kcap, dcap) variants. Rows must be TILE_M multiples (the kernel
# pads internally anyway, but keeping caps aligned avoids dead rows).
# The default grid covers the repo's tests/examples/benches; --full adds the
# larger tiers used for paper-scale runs.
VARIANTS = [
    (2048, 4, 4),
    (2048, 32, 4),
    (2048, 4, 20),
    (2048, 32, 20),
    (16384, 4, 4),
    (16384, 32, 4),
    (16384, 4, 20),
    (16384, 32, 20),
]

FULL_VARIANTS = VARIANTS + [
    (65536, 32, 20),
    (65536, 32, 32),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(program: str, mcap: int, kcap: int, dcap: int) -> str:
    fn = model.PROGRAMS[program]
    lowered = jax.jit(fn).lower(*model.example_args(mcap, kcap, dcap))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--out",
        default=None,
        help="Makefile compatibility: path of the manifest; its directory "
        "becomes --out-dir.",
    )
    ap.add_argument(
        "--full", action="store_true", help="also emit the paper-scale tiers"
    )
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        # Makefile compatibility: --out names the manifest path.
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    variants = FULL_VARIANTS if args.full else VARIANTS
    rows = []
    for program in model.PROGRAMS:
        for mcap, kcap, dcap in variants:
            name = f"{program}_m{mcap}_k{kcap}_d{dcap}.hlo.txt"
            path = os.path.join(out_dir, name)
            text = lower_variant(program, mcap, kcap, dcap)
            with open(path, "w") as f:
                f.write(text)
            rows.append((program, mcap, kcap, dcap, name))
            print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# program\tmcap\tkcap\tdcap\tfile\n")
        for r in rows:
            f.write("\t".join(str(x) for x in r) + "\n")
    print(f"wrote {manifest} ({len(rows)} variants)")


if __name__ == "__main__":
    main()
