"""AOT path: lowering produces parseable HLO text with the expected
parameter shapes, for every program/variant combination."""

import os
import re
import tempfile

import pytest

from compile import aot, model


@pytest.mark.parametrize("program", sorted(model.PROGRAMS))
def test_lower_smallest_variant(program):
    text = aot.lower_variant(program, 256, 4, 4)
    assert "HloModule" in text
    # Padded shapes show up as parameter types in the entry computation.
    assert "f32[256,4]" in text
    assert "f32[4,4]" in text


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    # Exercise the CLI end to end with one tiny variant grid by calling
    # main() through a monkeypatched VARIANTS (keeps the test fast).
    old = aot.VARIANTS
    try:
        aot.VARIANTS = [(256, 4, 4)]
        argv = ["prog", "--out-dir", str(tmp_path)]
        import unittest.mock as mock

        with mock.patch("sys.argv", argv):
            aot.main()
    finally:
        aot.VARIANTS = old

    manifest = tmp_path / "manifest.tsv"
    assert manifest.exists()
    lines = [l for l in manifest.read_text().splitlines() if not l.startswith("#")]
    assert len(lines) == len(model.PROGRAMS)
    for line in lines:
        program, mcap, kcap, dcap, fname = line.split("\t")
        assert (tmp_path / fname).exists()
        assert int(mcap) == 256 and int(kcap) == 4 and int(dcap) == 4


def test_wlloyd_step_lowers_to_mxu_dots():
    """L2 perf invariant (DESIGN.md §7): both the L1 distance cross-term
    and the centroid update lower to `dot` ops (MXU on TPU), and the whole
    step is a single module with one ROOT tuple — no host round-trips."""
    text = aot.lower_variant("wlloyd_step", 256, 4, 4)
    assert text.count("dot(") >= 2 or text.count(" dot") >= 2, text[:500]
    assert text.count("ENTRY") == 1
    # No all-reduce/infeed/outfeed (pure function of its args).
    for banned in ("infeed", "outfeed", "send", "recv"):
        assert banned not in text


def test_variant_files_are_parseable_and_complete():
    """Every default variant lowers and mentions its padded shapes."""
    for program in model.PROGRAMS:
        for mcap, kcap, dcap in [(256, 4, 4), (256, 32, 20)]:
            text = aot.lower_variant(program, mcap, kcap, dcap)
            assert f"f32[{mcap},{dcap}]" in text
            assert f"f32[{kcap},{dcap}]" in text


def test_hlo_text_has_no_64bit_ids():
    """Guard against the serialized-proto pitfall: text ids stay small."""
    text = aot.lower_variant("assign_err", 256, 4, 4)
    # HLO text uses %name.N identifiers; ensure it parses as text at all and
    # contains a ROOT instruction (sanity of the text emission path).
    assert re.search(r"ROOT\s", text)
