"""L1 correctness: the Pallas distance+top-2 kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled hot path: hypothesis
sweeps shapes, masks and magnitudes; every case asserts the kernel's top-2
distances match ref.py, and the argmin matches wherever the decision is not
numerically ambiguous at f32.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import distance_top2
from compile.kernels.ref import distance_top2_ref

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def _check_case(m, k, d, live, scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, d)) * scale).astype(np.float32)
    c = (rng.standard_normal((k, d)) * scale).astype(np.float32)
    cmask = np.zeros(k, np.float32)
    cmask[:live] = 1.0

    d1, d2, idx = distance_top2(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask))
    r1, r2, ridx = distance_top2_ref(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask))
    d1, d2, idx = np.asarray(d1), np.asarray(d2), np.asarray(idx)
    r1, r2, ridx = np.asarray(r1), np.asarray(r2), np.asarray(ridx)

    # f32 matmul decomposition vs direct differences: tolerance scales with
    # the squared magnitudes involved.
    tol = 1e-4 * max(1.0, scale * scale) * max(1.0, d)
    np.testing.assert_allclose(d1, r1, rtol=1e-4, atol=tol)
    if live > 1:
        np.testing.assert_allclose(d2, r2, rtol=1e-4, atol=tol)
    # argmin must agree wherever the top-2 gap is unambiguous at f32.
    clear = (r2 - r1) > 10 * tol
    assert (idx[clear] == ridx[clear]).all()
    # The winner is always a live centroid.
    assert (idx < live).all()


@hypothesis.given(
    m=st.integers(1, 300),
    k=st.integers(2, 32),
    d=st.integers(1, 20),
    live_frac=st.floats(0.1, 1.0),
    scale=st.sampled_from([0.1, 1.0, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref(m, k, d, live_frac, scale, seed):
    live = max(2, int(round(k * live_frac)))
    live = min(live, k)
    _check_case(m, k, d, live, scale, seed)


def test_single_live_centroid_d2_is_big():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((17, 3)), jnp.float32)
    c = jnp.zeros((4, 3), jnp.float32)
    cmask = jnp.asarray([1.0, 0.0, 0.0, 0.0], jnp.float32)
    d1, d2, idx = distance_top2(x, c, cmask)
    assert (np.asarray(idx) == 0).all()
    assert (np.asarray(d2) > 1e29).all()


def test_exact_tiny_case():
    # Hand-checkable: two centroids on the x axis.
    x = jnp.asarray([[0.0, 0.0], [10.0, 0.0], [4.0, 3.0]], jnp.float32)
    c = jnp.asarray([[0.0, 0.0], [10.0, 0.0]], jnp.float32)
    cmask = jnp.ones(2, jnp.float32)
    d1, d2, idx = distance_top2(x, c, cmask)
    np.testing.assert_allclose(np.asarray(d1), [0.0, 0.0, 25.0], atol=1e-4)
    np.testing.assert_allclose(np.asarray(d2), [100.0, 100.0, 45.0], atol=1e-3)
    np.testing.assert_array_equal(np.asarray(idx), [0, 1, 0])


def test_row_padding_invariance():
    # Appending rows must not change the results of the original rows
    # (wrapper pads to a tile multiple internally).
    rng = np.random.default_rng(7)
    x = rng.standard_normal((130, 5)).astype(np.float32)
    c = rng.standard_normal((8, 5)).astype(np.float32)
    cmask = np.ones(8, np.float32)
    d1a, d2a, idxa = distance_top2(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask))
    big = np.vstack([x, rng.standard_normal((126, 5)).astype(np.float32)])
    d1b, d2b, idxb = distance_top2(jnp.asarray(big), jnp.asarray(c), jnp.asarray(cmask))
    np.testing.assert_allclose(np.asarray(d1a), np.asarray(d1b)[:130], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idxa), np.asarray(idxb)[:130])


def test_dim_padding_invariance():
    # Zero-padding coordinates changes nothing.
    rng = np.random.default_rng(8)
    x = rng.standard_normal((50, 3)).astype(np.float32)
    c = rng.standard_normal((4, 3)).astype(np.float32)
    cmask = np.ones(4, np.float32)
    d1a, _, idxa = distance_top2(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask))
    xp = np.pad(x, ((0, 0), (0, 5)))
    cp = np.pad(c, ((0, 0), (0, 5)))
    d1b, _, idxb = distance_top2(jnp.asarray(xp), jnp.asarray(cp), jnp.asarray(cmask))
    np.testing.assert_allclose(np.asarray(d1a), np.asarray(d1b), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idxa), np.asarray(idxb))


@pytest.mark.parametrize("tile_m", [8, 64, 128, 256])
def test_tile_size_invariance(tile_m):
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((200, 6)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((5, 6)), jnp.float32)
    cmask = jnp.ones(5, jnp.float32)
    d1, d2, idx = distance_top2(x, c, cmask, tile_m=tile_m)
    r1, r2, ridx = distance_top2(x, c, cmask)  # default tile
    np.testing.assert_allclose(np.asarray(d1), np.asarray(r1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(r2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
