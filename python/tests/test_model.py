"""L2 correctness: weighted-Lloyd step / assign_err vs numpy oracles,
including the padding conventions the Rust runtime relies on."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import weighted_lloyd_step_ref

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("model")


def _numpy_weighted_lloyd(reps, weights, centroids):
    """Independent numpy oracle (no jax), live centroids only."""
    dist = ((reps[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    idx = dist.argmin(1)
    new_c = centroids.copy()
    for k in range(centroids.shape[0]):
        sel = (idx == k) & (weights > 0)
        w = weights[sel]
        if w.sum() > 0:
            new_c[k] = (reps[sel] * w[:, None]).sum(0) / w.sum()
    wss = (weights * dist[np.arange(len(reps)), idx]).sum()
    return new_c, idx, wss


@hypothesis.given(
    m=st.integers(2, 200),
    k=st.integers(2, 16),
    d=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_step_matches_numpy(m, k, d, seed):
    rng = np.random.default_rng(seed)
    reps = rng.standard_normal((m, d)).astype(np.float32)
    weights = rng.integers(1, 50, m).astype(np.float32)
    cent = rng.standard_normal((k, d)).astype(np.float32)
    cmask = np.ones(k, np.float32)

    new_c, idx, d1, d2, wss = model.weighted_lloyd_step(
        jnp.asarray(reps), jnp.asarray(weights), jnp.asarray(cent), jnp.asarray(cmask)
    )
    rn_c, ridx, rwss = _numpy_weighted_lloyd(
        reps.astype(np.float64), weights.astype(np.float64), cent.astype(np.float64)
    )
    # Ambiguous assignments (f32 ties) are tolerated; compare errors instead.
    np.testing.assert_allclose(float(wss), rwss, rtol=2e-3)
    gap_ok = np.asarray(d2) - np.asarray(d1) > 1e-3
    assert (np.asarray(idx)[gap_ok] == ridx[gap_ok]).all()
    np.testing.assert_allclose(np.asarray(new_c), rn_c, rtol=2e-3, atol=2e-3)


def test_step_matches_ref_exactly():
    rng = np.random.default_rng(3)
    reps = jnp.asarray(rng.standard_normal((100, 5)), jnp.float32)
    weights = jnp.asarray(rng.integers(1, 10, 100), jnp.float32)
    cent = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    cmask = jnp.ones(8, jnp.float32)
    out = model.weighted_lloyd_step(reps, weights, cent, cmask)
    ref = weighted_lloyd_step_ref(reps, weights, cent, cmask)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_padding_rows_are_inert():
    """Weight-0 rows must not move centroids or contribute to wss."""
    rng = np.random.default_rng(4)
    reps = rng.standard_normal((60, 4)).astype(np.float32)
    weights = rng.integers(1, 9, 60).astype(np.float32)
    cent = rng.standard_normal((6, 4)).astype(np.float32)
    cmask = np.ones(6, np.float32)

    out_small = model.weighted_lloyd_step(
        jnp.asarray(reps), jnp.asarray(weights), jnp.asarray(cent), jnp.asarray(cmask)
    )
    reps_p = np.vstack([reps, rng.standard_normal((68, 4)).astype(np.float32) * 100])
    weights_p = np.concatenate([weights, np.zeros(68, np.float32)])
    out_pad = model.weighted_lloyd_step(
        jnp.asarray(reps_p), jnp.asarray(weights_p), jnp.asarray(cent), jnp.asarray(cmask)
    )
    np.testing.assert_allclose(
        np.asarray(out_small[0]), np.asarray(out_pad[0]), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(float(out_small[4]), float(out_pad[4]), rtol=1e-5)


def test_masked_centroids_keep_value_and_never_win():
    rng = np.random.default_rng(5)
    reps = rng.standard_normal((40, 3)).astype(np.float32)
    weights = np.ones(40, np.float32)
    cent = np.zeros((8, 3), np.float32)
    cent[:3] = rng.standard_normal((3, 3))
    cent[3:] = 777.0  # sentinel in masked slots
    cmask = np.array([1, 1, 1, 0, 0, 0, 0, 0], np.float32)
    new_c, idx, d1, d2, wss = model.weighted_lloyd_step(
        jnp.asarray(reps), jnp.asarray(weights), jnp.asarray(cent), jnp.asarray(cmask)
    )
    assert (np.asarray(idx) < 3).all()
    np.testing.assert_array_equal(np.asarray(new_c)[3:], cent[3:])


def test_empty_cluster_keeps_previous_centroid():
    reps = jnp.asarray([[0.0, 0.0], [1.0, 0.0]], jnp.float32)
    weights = jnp.asarray([1.0, 1.0], jnp.float32)
    cent = jnp.asarray([[0.5, 0.0], [50.0, 50.0]], jnp.float32)
    cmask = jnp.ones(2, jnp.float32)
    new_c, idx, *_ = model.weighted_lloyd_step(reps, weights, cent, cmask)
    np.testing.assert_allclose(np.asarray(new_c)[0], [0.5, 0.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_c)[1], [50.0, 50.0], atol=1e-6)


def test_assign_err_matches_step_error():
    rng = np.random.default_rng(6)
    pts = jnp.asarray(rng.standard_normal((90, 4)), jnp.float32)
    w = jnp.ones(90, jnp.float32)
    cent = jnp.asarray(rng.standard_normal((5, 4)), jnp.float32)
    cmask = jnp.ones(5, jnp.float32)
    idx, sse = model.assign_err(pts, w, cent, cmask)
    _, idx2, d1, _, wss = model.weighted_lloyd_step(pts, w, cent, cmask)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))
    np.testing.assert_allclose(float(sse), float(wss), rtol=1e-6)


def test_fixed_point_of_step():
    """A converged configuration must not move (weighted Lloyd fixed point)."""
    reps = jnp.asarray([[-1.0, 0.0], [1.0, 0.0], [9.0, 0.0], [11.0, 0.0]], jnp.float32)
    weights = jnp.asarray([2.0, 2.0, 3.0, 3.0], jnp.float32)
    cent = jnp.asarray([[0.0, 0.0], [10.0, 0.0]], jnp.float32)
    cmask = jnp.ones(2, jnp.float32)
    new_c, *_ = model.weighted_lloyd_step(reps, weights, cent, cmask)
    np.testing.assert_allclose(np.asarray(new_c), np.asarray(cent), atol=1e-6)
