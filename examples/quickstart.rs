//! Quickstart: cluster a simulated Table-1 dataset with BWKM and compare
//! the distance bill against K-means++ + Lloyd.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bwkm::bwkm::BwkmCfg;
use bwkm::data::simulate;
use bwkm::kmeans::init::kmeanspp;
use bwkm::kmeans::{lloyd, LloydCfg};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::util::{fmt_count, Rng};

fn main() {
    let k = 9;
    let ds = simulate("WUY", 0.001, 42).expect("simulator");
    println!("dataset: simulated WUY, n={}, d={}, K={k}", ds.n, ds.d);

    // --- BWKM.
    let c_bwkm = DistanceCounter::new();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cfg.eval_full_error = true;
    let out = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(7), &c_bwkm);
    let e_bwkm = out.trace.last().unwrap().full_error.unwrap();
    println!("\nBWKM trace (outer iterations):");
    for t in &out.trace {
        println!(
            "  iter={:<3} |B|={:<5} boundary={:<5} distances={:>12} E^D={:.5e}",
            t.outer_iter,
            t.blocks,
            t.boundary,
            fmt_count(t.distances),
            t.full_error.unwrap()
        );
    }
    println!("stopped: {:?}", out.stop);

    // --- KM++ + Lloyd reference.
    let c_ref = DistanceCounter::new();
    let init = kmeanspp(&ds.data, ds.d, k, &mut Rng::new(7), &c_ref);
    let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &c_ref);
    let eval = DistanceCounter::new();
    let e_ref = kmeans_error(&ds.data, ds.d, &l.centroids, &eval);

    println!("\n{:<12} {:>14} {:>14}", "method", "distances", "E^D");
    println!("{:<12} {:>14} {:>14.5e}", "BWKM", fmt_count(c_bwkm.get()), e_bwkm);
    println!("{:<12} {:>14} {:>14.5e}", "KM++ +Lloyd", fmt_count(c_ref.get()), e_ref);
    println!(
        "\nBWKM used {:.1}x fewer distance computations; relative error {:+.2}%",
        c_ref.get() as f64 / c_bwkm.get() as f64,
        100.0 * (e_bwkm - e_ref) / e_ref
    );
}
