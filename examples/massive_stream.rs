//! Massive-data streaming scenario: the dataset lives on disk and never
//! fits in memory at once. `StreamingBwkm` (DESIGN.md §5.1) runs the
//! *full* BWKM loop — Alg. 2–4 initialization, weighted Lloyd over the
//! tiny representative set, ε-guided partition refinement, §2.4.2
//! stopping — against the file in bounded memory, streaming one pass per
//! refinement and fanning each pass over sharded chunk workers. This is
//! the workload the paper's title is about, and the run is pinned
//! **bit-identical** to the in-memory `bwkm::run` on the same data and
//! seed — which this example verifies at demo scale.
//!
//! ```bash
//! cargo run --release --example massive_stream
//! ```

use bwkm::coordinator::{stream_assign_err, StreamingBwkm};
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::simulate;
use bwkm::metrics::DistanceCounter;
use bwkm::util::{fmt_count, Rng};

fn main() {
    let k = 9;
    let seed = 11;
    // Materialize a "massive" source on disk (simulated WUY), keeping the
    // in-memory copy only to verify the bit-identity claim at the end —
    // the streaming run itself touches nothing but the file.
    let ds = simulate("WUY", 0.005, 23).expect("simulator");
    let path = std::env::temp_dir().join("bwkm_massive_stream.bin");
    save_bin(&ds, &path).expect("write stream source");
    let (n, d) = (ds.n, ds.d);
    println!(
        "stream source: {} rows x {d} dims at {}",
        fmt_count(n as u64),
        path.display()
    );

    let chunk_rows = 4096;
    let threads = 4;
    let cfg = bwkm::bwkm::BwkmCfg::for_dataset(n, d, k);

    // --- The out-of-core run: full Alg. 5 against the file.
    let counter = DistanceCounter::new();
    let t0 = std::time::Instant::now();
    let mut coordinator =
        StreamingBwkm::new(BinChunks::opener(&path, chunk_rows), d).with_threads(threads);
    let out = coordinator
        .run(k, &cfg, &mut Rng::new(seed), &counter)
        .expect("streaming BWKM");
    println!(
        "\nstreamed BWKM: {} blocks, {} representatives, {} outer iterations, \
         {} streaming passes, {} distances, {:.2?} ({:?})",
        out.partition.len(),
        out.weights.len(),
        out.trace.len(),
        out.passes,
        fmt_count(counter.get()),
        t0.elapsed(),
        out.stop
    );
    for t in out.trace.iter().take(4) {
        println!(
            "  outer={:<3} dists={:>12} |B|={:<5} boundary={:<5} E^P={:.5e}",
            t.outer_iter,
            fmt_count(t.distances),
            t.blocks,
            t.boundary,
            t.weighted_error
        );
    }
    if out.trace.len() > 4 {
        println!("  ... ({} more iterations)", out.trace.len() - 4);
    }

    // --- Final E^D by one more streamed scoring pass (separate counter).
    let eval = DistanceCounter::new();
    let chunks = BinChunks::open(&path, chunk_rows).expect("open stream");
    let (rows, sse) =
        stream_assign_err(d, &out.centroids, chunks, &eval).expect("stream eval");
    assert_eq!(rows, n);
    println!(
        "final E^D = {sse:.6e} ({} scoring distances); peak working set ≈ \
         {chunk_rows} rows/chunk + {} representatives (vs {} source rows)",
        fmt_count(eval.get()),
        out.weights.len(),
        fmt_count(n as u64)
    );

    // --- The §5.1 guarantee, demonstrated: the in-memory run on the same
    // data and seed produces the same centroids and the same bill, bit
    // for bit.
    let c_mem = DistanceCounter::new();
    let mem = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(seed), &c_mem);
    assert_eq!(out.centroids, mem.centroids, "bit-identity violated: centroids");
    assert_eq!(counter.get(), c_mem.get(), "bit-identity violated: distance bill");
    assert_eq!(out.stop, mem.stop);
    println!(
        "\nbit-identity check vs in-memory bwkm::run: centroids equal, \
         {} = {} distances — out-of-core is the same algorithm, not an approximation",
        fmt_count(counter.get()),
        fmt_count(c_mem.get())
    );
    std::fs::remove_file(&path).ok();
}
