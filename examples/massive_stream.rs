//! Massive-data streaming scenario: the dataset lives on disk and never
//! fits in memory at once. The coordinator streams binary chunks to
//! (1) build BWKM's partition statistics, (2) run weighted Lloyd over the
//! (tiny) representative set, and (3) evaluate the final E^D — all with
//! bounded memory. This is the workload the paper's title is about.
//!
//! ```bash
//! cargo run --release --example massive_stream
//! ```

use bwkm::coordinator::{stream_assign_err, stream_partition_stats};
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::simulate;
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::kmeans::{weighted_lloyd, WLloydCfg};
use bwkm::metrics::DistanceCounter;
use bwkm::partition::Partition;
use bwkm::util::{fmt_count, Rng};

fn main() {
    let k = 9;
    // Materialize a "massive" source on disk (simulated WUY), then forget
    // the in-memory copy — everything below streams it in 4096-row chunks.
    let ds = simulate("WUY", 0.005, 23).expect("simulator");
    let path = std::env::temp_dir().join("bwkm_massive_stream.bin");
    save_bin(&ds, &path).expect("write stream source");
    let (n, d) = (ds.n, ds.d);
    let bbox = bwkm::geometry::BBox::of(&ds.data, d, None).unwrap();
    drop(ds);
    println!("stream source: {} rows x {d} dims at {}", fmt_count(n as u64), path.display());

    let chunk_rows = 4096;
    let counter = DistanceCounter::new();
    let mut rng = Rng::new(11);

    // --- Build a spatial partition by iterative streaming refinement:
    // each epoch streams the file once, accumulates per-block stats, and
    // splits the heaviest x largest blocks (the Alg. 3 criterion computed
    // from the stream instead of an in-memory sample).
    let mut partition = Partition::root_spatial(bbox, d);
    let target_blocks = 10 * ((k * d) as f64).sqrt().ceil() as usize;
    let mut stats = None;
    for epoch in 0..12 {
        let chunks = BinChunks::open(&path, chunk_rows).expect("open stream");
        let st = stream_partition_stats(&partition, d, chunks).expect("stream stats");
        assert_eq!(st.rows, n);
        if partition.len() >= target_blocks {
            stats = Some(st);
            break;
        }
        // Split the top blocks by l_B * |B| (streamed Alg. 3 heuristic).
        let mut scored: Vec<(f64, usize)> = (0..partition.len())
            .filter(|&b| st.counts[b] > 1)
            .map(|b| {
                let diag = st.tight[b].as_ref().map(|t| t.diagonal()).unwrap_or(0.0);
                (diag * st.counts[b] as f64, b)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let budget = (target_blocks - partition.len()).min(scored.len()).max(1);
        for &(_, b) in scored.iter().take(budget) {
            if let Some(t) = st.tight[b].clone() {
                let (axis, thr) = t.split_plane();
                partition.split_at(b, axis, thr, None);
            }
        }
        println!("epoch {epoch}: partition grew to {} blocks", partition.len());
        stats = Some(st);
    }
    let stats = stats.expect("at least one epoch");

    // --- Weighted Lloyd over the streamed representatives (in-memory: the
    // representative set is tiny compared to the source).
    let (reps, weights, _) = stats.reps_weights(d);
    println!(
        "representatives: {} (weights sum {}, {:.4}% of the source rows)",
        weights.len(),
        fmt_count(weights.iter().sum::<f64>() as u64),
        100.0 * weights.len() as f64 / n as f64
    );
    let init = weighted_kmeanspp(&reps, &weights, d, k, &mut rng, &counter);
    let out = weighted_lloyd(&reps, &weights, d, &init, &WLloydCfg::default(), &counter);

    // --- Final E^D evaluated by streaming the source once more.
    let eval = DistanceCounter::new();
    let chunks = BinChunks::open(&path, chunk_rows).expect("open stream");
    let (rows, sse) = stream_assign_err(d, &out.centroids, chunks, &eval).expect("stream eval");
    assert_eq!(rows, n);
    println!(
        "\nclustered {} streamed rows with {} algorithm distances \
         (plus {} for the final scoring pass)",
        fmt_count(n as u64),
        fmt_count(counter.get()),
        fmt_count(eval.get()),
    );
    println!("final E^D = {sse:.6e}, weighted E^P = {:.6e}", out.werr);
    println!(
        "peak working set ≈ {} rows/chunk + {} representatives (vs {} source rows)",
        chunk_rows,
        weights.len(),
        fmt_count(n as u64)
    );
    std::fs::remove_file(&path).ok();
}
