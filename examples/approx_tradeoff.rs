//! Approximate-assignment trade-off: run BWKM on one simulated dataset
//! under all three §2.9 assignment regimes (exact, cluster closures,
//! sampled steps) and compare the exact distance bill, the resulting
//! full-data error E^D, and the self-reported quality gap of each mode.
//!
//! The exact mode emits no gap note by contract (there is no gap to
//! report); every approximate run self-reports exactly one `gap[...]`
//! note on its counter.
//!
//! ```bash
//! cargo run --release --example approx_tradeoff
//! ```

use bwkm::bwkm::BwkmCfg;
use bwkm::data::simulate;
use bwkm::kmeans::{AssignCfg, AssignMode};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::util::{fmt_count, Rng};

fn main() {
    let k = 9;
    let ds = simulate("GS", 0.002, 23).expect("simulator");
    println!("dataset: simulated GS, n={}, d={}, K={k}", ds.n, ds.d);

    let modes: Vec<(&str, AssignCfg)> = vec![
        ("exact", AssignCfg::default()),
        (
            "closure",
            AssignCfg { mode: AssignMode::Closure, closure_expand: 2, ..Default::default() },
        ),
        (
            "sampled",
            AssignCfg { mode: AssignMode::Sampled, sample_rows: 96, ..Default::default() },
        ),
    ];

    println!("\n{:<10} {:>14} {:>14}  {}", "assign", "distances", "E^D", "self-reported gap");
    for (name, assign) in modes {
        let counter = DistanceCounter::new();
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
        cfg.assign = assign;
        // Same seed for every mode: the main RNG stream is pinned across
        // assign modes (the sampler draws from its own private stream),
        // so the runs differ only in the assignment regime.
        let out = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(7), &counter);
        let eval = DistanceCounter::new();
        let err = kmeans_error(&ds.data, ds.d, &out.centroids, &eval);
        let gap_note = counter
            .notes()
            .iter()
            .rev()
            .find(|n| n.starts_with("gap["))
            .cloned()
            .unwrap_or_else(|| "-".to_string());
        println!("{:<10} {:>14} {:>14.5e}  {}", name, fmt_count(counter.get()), err, gap_note);
    }

    println!(
        "\nBit-identity is pinned only for total closures and full samples \
         (DESIGN.md §2.9); otherwise the gap note above is the contract."
    );
}
