//! End-to-end three-layer driver (the repo's integration proof): the BWKM
//! coordinator (L3/Rust) runs its weighted-Lloyd inner loop on the
//! AOT-compiled HLO artifacts (L2 JAX + L1 Pallas) through PJRT, on a real
//! small workload — the simulated 3RN dataset — and the final E^D is also
//! evaluated on-device via the chunked `assign_err` program. Results are
//! cross-checked against the all-native path and recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use bwkm::bwkm::{run, run_with, BwkmCfg};
use bwkm::data::simulate;
use bwkm::metrics::DistanceCounter;
use bwkm::runtime::{PjrtStepper, Runtime};
use bwkm::util::{fmt_count, Rng};

fn main() {
    let k = 9;
    let ds = simulate("3RN", 0.02, 5).expect("simulator");
    println!("e2e: simulated 3RN, n={}, d={}, K={k}", ds.n, ds.d);

    let runtime = Runtime::open_default().expect(
        "artifacts missing — run `make artifacts` first (python AOT-lowers \
         the L2/L1 programs to artifacts/*.hlo.txt)",
    );
    println!(
        "loaded manifest with {} variants from {}",
        runtime.manifest().variants.len(),
        Runtime::default_dir().display()
    );

    // --- L3 loop over the PJRT stepper (L2 weighted_lloyd_step + L1
    // pallas distance_top2, compiled once, executed per iteration).
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cfg.eval_full_error = true;
    cfg.max_outer = 12;
    let c_pjrt = DistanceCounter::new();
    let t0 = std::time::Instant::now();
    let mut stepper = PjrtStepper::new(runtime);
    let out = run_with(&mut stepper, &ds, k, &cfg, &mut Rng::new(3), &c_pjrt);
    let wall_pjrt = t0.elapsed();
    println!("\nPJRT-backed BWKM:");
    for t in &out.trace {
        println!(
            "  iter={:<3} |B|={:<5} boundary={:<5} distances={:>12} E^P={:.5e} E^D={:.5e}",
            t.outer_iter,
            t.blocks,
            t.boundary,
            fmt_count(t.distances),
            t.weighted_error,
            t.full_error.unwrap()
        );
    }
    println!(
        "  device steps: {}, native fallbacks: {}, stop: {:?}, wall: {wall_pjrt:.2?}",
        stepper.device_steps, stepper.fallback_steps, out.stop
    );
    assert!(stepper.device_steps > 0, "PJRT path must actually execute");

    // --- Final error evaluated ON DEVICE through the chunked assign_err
    // program (the L1 kernel again), cross-checked against host eval.
    let mut runtime = stepper.into_runtime();
    let (_, sse_device) = runtime
        .assign_err(&ds.data, ds.d, &out.centroids)
        .expect("device assign_err");
    let eval = DistanceCounter::new();
    let sse_host = bwkm::metrics::kmeans_error(&ds.data, ds.d, &out.centroids, &eval);
    let rel = (sse_device - sse_host).abs() / sse_host;
    println!("\nfinal E^D: device={sse_device:.6e} host={sse_host:.6e} (rel diff {rel:.2e})");
    assert!(rel < 1e-3, "device/host divergence too large: {rel}");

    // --- Same run all-native, for the wallclock + numerics comparison.
    let c_native = DistanceCounter::new();
    let t1 = std::time::Instant::now();
    let out_native = run(&ds, k, &cfg, &mut Rng::new(3), &c_native);
    let wall_native = t1.elapsed();
    let e_pjrt = out.trace.last().unwrap().full_error.unwrap();
    let e_native = out_native.trace.last().unwrap().full_error.unwrap();
    println!(
        "\n{:<10} {:>12} {:>14} {:>12}",
        "backend", "wall", "distances", "E^D"
    );
    println!(
        "{:<10} {:>12.2?} {:>14} {:>12.5e}",
        "pjrt", wall_pjrt, fmt_count(c_pjrt.get()), e_pjrt
    );
    println!(
        "{:<10} {:>12.2?} {:>14} {:>12.5e}",
        "native", wall_native, fmt_count(c_native.get()), e_native
    );
    println!(
        "\ne2e OK: same seeds, |E^D(pjrt) - E^D(native)|/E^D = {:.2e} (f32 artifacts vs f64 host)",
        (e_pjrt - e_native).abs() / e_native
    );
}
