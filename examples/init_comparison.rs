//! Seeding-strategy comparison through the `Seeder` trait (DESIGN.md
//! §2.8): run all four backends — Forgy, K-means++, AFK-MC² and
//! K-means|| — as initializers, hand each result to the same Lloyd
//! refinement, and report seeding cost vs final quality on the simulated
//! SUSY dataset. BWKM-as-initializer rides along as the paper's §3
//! closing comparison point.
//!
//! ```bash
//! cargo run --release --example init_comparison
//! ```

use bwkm::bwkm::BwkmCfg;
use bwkm::data::simulate;
use bwkm::kmeans::init::{SeedMethod, SeedPolicy, Seeder};
use bwkm::kmeans::{lloyd, LloydCfg};
use bwkm::metrics::{kmeans_error, Budget, DistanceCounter};
use bwkm::util::{fmt_count, mean_std, Rng};

fn main() {
    let k = 27;
    let reps = 5;
    let ds = simulate("SUSY", 0.004, 31).expect("simulator");
    let weights = vec![1.0f64; ds.n]; // raw instances: unit weights
    println!(
        "init comparison: simulated SUSY, n={}, d={}, K={k}, {reps} repetitions\n",
        ds.n, ds.d
    );

    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>8}",
        "seeding", "init dists", "E^D (seed)", "E^D (+Lloyd)", "iters"
    );

    // The four Seeder backends, selected exactly as the CLI's `init=`
    // policy would select them.
    let methods = [SeedMethod::Forgy, SeedMethod::Kmpp, SeedMethod::Kmc2, SeedMethod::Par];
    for method in methods {
        let policy = SeedPolicy::of(method);
        let mut seeder = policy.seeder();
        report(seeder.name(), reps, |rng, c| {
            seeder.seed(&ds.data, &weights, ds.d, k, rng, c)
        }, &ds);
    }

    // BWKM as an initializer (the §3 closing observation): stop early,
    // cap the budget at ~2 full-data passes worth of distances.
    report("BWKM", reps, |rng, c| {
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
        cfg.max_outer = 6;
        cfg.budget = Budget::of((2 * ds.n * k) as u64);
        bwkm::bwkm::run(&ds, k, &cfg, rng, c).centroids
    }, &ds);

    println!(
        "\nreading: `par` (K-means||) buys K-means++-grade seeds in r+2 passes \
         instead of K serial ones at a comparable bill (m·|C| + |C|·(K−1) \
         distances); BWKM's seeds still start Lloyd closest to its fixed \
         point at a comparable budget (the paper's §3 closing observation)."
    );
}

/// Run one seeding strategy `reps` times and print its table row.
fn report<F>(name: &str, reps: u64, mut init_fn: F, ds: &bwkm::data::Dataset)
where
    F: FnMut(&mut Rng, &DistanceCounter) -> Vec<f64>,
{
    let lcfg = LloydCfg { max_iters: 30, ..Default::default() };
    let mut init_d = Vec::new();
    let mut seed_e = Vec::new();
    let mut final_e = Vec::new();
    let mut iters = Vec::new();
    for rep in 0..reps {
        let mut rng = Rng::new(0x5EED ^ rep);
        let c = DistanceCounter::new();
        let init = init_fn(&mut rng, &c);
        init_d.push(c.get() as f64);
        let eval = DistanceCounter::new();
        seed_e.push(kmeans_error(&ds.data, ds.d, &init, &eval));
        let l = lloyd(&ds.data, ds.d, &init, &lcfg, &DistanceCounter::new());
        final_e.push(l.error);
        iters.push(l.iters as f64);
    }
    println!(
        "{:<8} {:>14} {:>14.5e} {:>14.5e} {:>8.1}",
        name,
        fmt_count(mean_std(&init_d).0 as u64),
        mean_std(&seed_e).0,
        mean_std(&final_e).0,
        mean_std(&iters).0,
    );
}
