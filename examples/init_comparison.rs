//! Seeding-strategy comparison (the paper's §3 conclusion that BWKM is "a
//! competitive initialization strategy for Lloyd's algorithm"): run Forgy,
//! K-means++, AFK-MC² and BWKM as *initializers*, hand each result to the
//! same Lloyd refinement, and report seeding cost vs final quality on the
//! simulated SUSY dataset.
//!
//! ```bash
//! cargo run --release --example init_comparison
//! ```

use bwkm::bwkm::BwkmCfg;
use bwkm::data::simulate;
use bwkm::kmeans::init::{forgy, kmc2, kmeanspp, Kmc2Cfg};
use bwkm::kmeans::{lloyd, LloydCfg};
use bwkm::metrics::{kmeans_error, Budget, DistanceCounter};
use bwkm::util::{fmt_count, mean_std, Rng};

fn main() {
    let k = 27;
    let reps = 5;
    let ds = simulate("SUSY", 0.004, 31).expect("simulator");
    println!("init comparison: simulated SUSY, n={}, d={}, K={k}, {reps} repetitions\n", ds.n, ds.d);

    let strategies: Vec<&str> = vec!["Forgy", "KM++", "KMC2", "BWKM"];
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>8}",
        "seeding", "init dists", "E^D (seed)", "E^D (+Lloyd)", "iters"
    );
    for name in strategies {
        let mut init_d = Vec::new();
        let mut seed_e = Vec::new();
        let mut final_e = Vec::new();
        let mut iters = Vec::new();
        for rep in 0..reps {
            let mut rng = Rng::new(0x5EED ^ rep);
            let c = DistanceCounter::new();
            let init = match name {
                "Forgy" => forgy(&ds.data, ds.d, k, &mut rng),
                "KM++" => kmeanspp(&ds.data, ds.d, k, &mut rng, &c),
                "KMC2" => kmc2(&ds.data, ds.d, k, &Kmc2Cfg::default(), &mut rng, &c),
                "BWKM" => {
                    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
                    // As an initializer: stop early, cap the budget at ~2
                    // full-data passes worth of distances.
                    cfg.max_outer = 6;
                    cfg.budget = Budget::of((2 * ds.n * k) as u64);
                    bwkm::bwkm::run(&ds, k, &cfg, &mut rng, &c).centroids
                }
                _ => unreachable!(),
            };
            let eval = DistanceCounter::new();
            seed_e.push(kmeans_error(&ds.data, ds.d, &init, &eval));
            init_d.push(c.get() as f64);
            let l = lloyd(
                &ds.data,
                ds.d,
                &init,
                &LloydCfg { max_iters: 30, ..Default::default() },
                &DistanceCounter::new(),
            );
            final_e.push(l.error);
            iters.push(l.iters as f64);
        }
        println!(
            "{:<8} {:>14} {:>14.5e} {:>14.5e} {:>8.1}",
            name,
            fmt_count(mean_std(&init_d).0 as u64),
            mean_std(&seed_e).0,
            mean_std(&final_e).0,
            mean_std(&iters).0,
        );
    }
    println!(
        "\nreading: compare `E^D (seed)` — BWKM's seeds start Lloyd far closer to \
         its fixed point than the sampling-based seedings at a comparable \
         distance bill (the paper's §3 closing observation)."
    );
}
