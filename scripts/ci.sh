#!/usr/bin/env bash
# Offline-safe CI gate for the bwkm crate (DESIGN.md §6).
#
#   scripts/ci.sh              # full tier-1: fmt check, release build, tests
#   scripts/ci.sh --quick      # engine conformance + streaming degenerate subset
#   scripts/ci.sh --streaming  # the full streaming conformance suite
#                              # (includes the generated multi-chunk-file run)
#   scripts/ci.sh --init       # the seeding conformance + counter-pin suite
#                              # (Seeder backends, K-means|| grids, closed forms)
#   scripts/ci.sh --approx     # the approximate-regime gap-conformance suite
#                              # (closures, sampled steps, pinned bills, gaps)
#   scripts/ci.sh --simd       # build + engine conformance with AND without
#                              # the `simd` feature (the scalar fallback must
#                              # stay green on targets without the lane paths)
#   scripts/ci.sh --service    # the resident-service suite: model-store
#                              # round-trip/resume/ingest conformance plus
#                              # the store failure-injection subset
#   scripts/ci.sh --obs        # the observability suite: §2.11 telemetry
#                              # non-perturbation pins (off vs jsonl, `==`),
#                              # JSONL schema stability, typed-vs-note
#                              # cross-checks, NOTE_CAP flood completeness
#   scripts/ci.sh --pool       # the §2.12 pool/arena/generation-cache suite:
#                              # bit-identity across backends × thread counts,
#                              # the counting-allocator zero-alloc pins, and
#                              # the worker-pool unit tests
#
# The build is hermetic (vendored path deps, no crates.io), so the script
# forces cargo offline and never touches the network.

set -euo pipefail
cd "$(dirname "$0")/../rust"
export CARGO_NET_OFFLINE=true

# Fail loudly, not cryptically, when the toolchain itself is missing: every
# path below needs cargo, and a bare `command not found` half-way through a
# run has cost real debugging time.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: no cargo in PATH — tier-1 (cargo build --release && cargo test -q) cannot run." >&2
    echo "Install a Rust toolchain (rustup or a distro package) and re-run scripts/ci.sh." >&2
    echo "With a toolchain available, the priority order is:" >&2
    echo "    scripts/ci.sh                                 # full tier-1 gate" >&2
    echo "    scripts/ci.sh --pool                          # §2.12 pool/arena/zero-alloc pins" >&2
    echo "    (cd rust && cargo test -q --test pool_conformance)   # just the §2.12 suite" >&2
    echo "    (cd rust && cargo test -q --lib util::pool)          # just the pool unit tests" >&2
    echo "    (cd rust && cargo bench --bench perf_assignment)     # warm/cold + allocs/step rows" >&2
    echo "                                                  # (emits rust/BENCH_assignment.json)" >&2
    exit 1
fi

if [[ "${1:-}" == "--quick" ]]; then
    echo "== quick: engine conformance suite =="
    cargo test -q --test engine_conformance
    echo "== quick: streaming degenerate subset =="
    cargo test -q --test streaming_conformance degenerate
    echo "== quick: telemetry non-perturbation pins =="
    cargo test -q --test obs_conformance non_perturb
    echo "== quick: pool/arena bit-identity + zero-alloc pins =="
    cargo test -q --test pool_conformance
    exit 0
fi

if [[ "${1:-}" == "--streaming" ]]; then
    echo "== streaming conformance suite (incl. generated multi-chunk file) =="
    cargo test -q --test streaming_conformance
    exit 0
fi

if [[ "${1:-}" == "--init" ]]; then
    echo "== seeding conformance + counter-pin suite =="
    cargo test -q --test init_conformance
    exit 0
fi

if [[ "${1:-}" == "--approx" ]]; then
    echo "== approximate-regime gap-conformance suite =="
    cargo test -q --test approx_conformance
    exit 0
fi

if [[ "${1:-}" == "--service" ]]; then
    echo "== model store + resume/ingest conformance suite =="
    cargo test -q --test service_conformance
    echo "== store failure-injection subset =="
    cargo test -q --test failure_injection store_
    exit 0
fi

if [[ "${1:-}" == "--obs" ]]; then
    echo "== observability conformance suite (DESIGN.md 2.11) =="
    cargo test -q --test obs_conformance
    echo "== obs unit tests (recorder, sinks, scopes) =="
    cargo test -q --lib obs::
    exit 0
fi

if [[ "${1:-}" == "--pool" ]]; then
    echo "== pool/arena/generation-cache conformance suite (DESIGN.md 2.12) =="
    cargo test -q --test pool_conformance
    echo "== worker-pool unit tests =="
    cargo test -q --lib util::pool
    exit 0
fi

if [[ "${1:-}" == "--simd" ]]; then
    echo "== simd feature ON: build + engine conformance =="
    cargo build --release
    cargo test -q --test engine_conformance
    cargo test -q --lib kmeans::assign
    echo "== simd feature OFF (scalar fallback): build + engine conformance =="
    cargo build --release --no-default-features
    cargo test -q --no-default-features --test engine_conformance
    cargo test -q --no-default-features --lib kmeans::assign
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt unavailable (rustfmt component not installed); skipping =="
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q
