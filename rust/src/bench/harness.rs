//! Small timing/IO helpers for the hand-rolled benches.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::obs::Stopwatch;

/// Median wall-clock seconds of `iters` runs of `f` (after one warmup).
/// Timing runs on the [`Stopwatch`] monotonic clock (DESIGN.md §2.11) so
/// bench columns and run-report span timings come from one abstraction.
pub fn bench_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t = Stopwatch::start();
            f();
            t.elapsed_s()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// `bench_out/` under the repo root (created on demand).
pub fn out_dir() -> PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../bench_out");
    std::fs::create_dir_all(&dir).expect("create bench_out");
    dir
}

/// Write CSV rows (first row = header) to `bench_out/<name>.csv`.
pub fn write_csv(name: &str, rows: &[Vec<String>]) {
    let path = out_dir().join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path).expect("create csv");
    for row in rows {
        writeln!(f, "{}", row.join(",")).expect("write csv");
    }
    eprintln!("wrote {}", path.display());
}

/// One typed cell of a bench JSON row: the producing bench decides the
/// JSON type **explicitly** — nothing is inferred from string shape, so a
/// leading-zero id or a `1e5`-looking label can never silently turn into
/// a number, and a numeric column can never flip to a string mid-series.
/// A non-finite [`Cell::F64`] is emitted as JSON `null` (JSON has no
/// NaN/inf; `null` in a numeric column is the unambiguous "no value").
#[derive(Clone, Debug, PartialEq)]
pub enum Cell {
    Str(String),
    U64(u64),
    F64(f64),
}

impl From<&str> for Cell {
    fn from(v: &str) -> Cell {
        Cell::Str(v.to_string())
    }
}

impl From<String> for Cell {
    fn from(v: String) -> Cell {
        Cell::Str(v)
    }
}

impl From<u64> for Cell {
    fn from(v: u64) -> Cell {
        Cell::U64(v)
    }
}

impl From<usize> for Cell {
    fn from(v: usize) -> Cell {
        Cell::U64(v as u64)
    }
}

impl From<f64> for Cell {
    fn from(v: f64) -> Cell {
        Cell::F64(v)
    }
}

/// Write rows of `(key, cell)` pairs as a machine-readable JSON array of
/// objects to `BENCH_<name>.json` at the **repo root** (the drivers'
/// pickup location; the human-facing CSVs stay in `bench_out/`). Each
/// value's JSON type is declared by its [`Cell`] variant. The write is
/// **atomic**: the document goes to a same-directory temp file first and
/// is `rename`d into place, so a reader (or a crash) can never observe a
/// truncated `BENCH_*.json`. Hand-rolled because serde is unavailable
/// offline (DESIGN.md §4).
pub fn write_bench_json(name: &str, rows: &[Vec<(String, Cell)>]) {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    write_bench_json_to(&root.join(format!("BENCH_{name}.json")), rows);
}

/// [`write_bench_json`] with an explicit destination: same typed-cell
/// document, same atomic temp-then-rename write, caller-chosen path. The
/// CLI run report (DESIGN.md §2.11) uses this to land its summary next to
/// a `metrics_path=` trace instead of at the repo root.
pub fn write_bench_json_to(path: &Path, rows: &[Vec<(String, Cell)>]) {
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    let mut s = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        s.push_str("  {");
        for (j, (k, v)) in row.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push('"');
            s.push_str(&json_escape(k));
            s.push_str("\": ");
            s.push_str(&json_value(v));
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    std::fs::write(&tmp, s).expect("write bench json temp file");
    if let Err(e) = std::fs::rename(&tmp, &path) {
        std::fs::remove_file(&tmp).ok();
        panic!("rename bench json into place: {e}");
    }
    eprintln!("wrote {}", path.display());
}

/// One JSON value from a typed bench cell (see [`write_bench_json`]).
/// Finite floats use Rust's `{:?}` — the shortest representation that
/// round-trips — so the emitted trajectory is stable across runs.
/// `pub(crate)` so the JSONL trace sink (DESIGN.md §2.11) shares one
/// escaping/typing implementation with the bench documents.
pub(crate) fn json_value(v: &Cell) -> String {
    match v {
        Cell::Str(s) => format!("\"{}\"", json_escape(s)),
        Cell::U64(u) => u.to_string(),
        Cell::F64(x) if x.is_finite() => format!("{x:?}"),
        Cell::F64(_) => "null".to_string(),
    }
}

pub(crate) fn json_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Env-var override with default (the BWKM_SCALE / BWKM_REPS knobs).
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_secs_measures_something() {
        let s = bench_secs(3, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(s >= 0.0 && s < 1.0);
    }

    #[test]
    fn json_values_follow_the_declared_cell_type() {
        // Emission is per-cell explicit: the declared variant wins, never
        // the string's shape.
        assert_eq!(json_value(&Cell::U64(42)), "42");
        assert_eq!(json_value(&Cell::F64(0.25)), "0.25");
        assert_eq!(json_value(&Cell::F64(1e300)), "1e300");
        // Numeric-looking *strings* stay strings — leading-zero ids and
        // exponent-shaped labels no longer coerce (the satellite bug).
        assert_eq!(json_value(&Cell::Str("007".into())), "\"007\"");
        assert_eq!(json_value(&Cell::Str("1e5".into())), "\"1e5\"");
        // Non-finite floats stay in the numeric column as null, instead
        // of flipping the column to strings.
        assert_eq!(json_value(&Cell::F64(f64::NAN)), "null");
        assert_eq!(json_value(&Cell::F64(f64::INFINITY)), "null");
        assert_eq!(json_value(&Cell::Str("exact".into())), "\"exact\"");
        assert_eq!(json_value(&Cell::Str("".into())), "\"\"");
        assert_eq!(json_value(&Cell::Str("a\"b\\c".into())), "\"a\\\"b\\\\c\"");
        // Floats round-trip in shortest form, stable across runs.
        assert_eq!(json_value(&Cell::F64(0.1)), "0.1");
    }

    #[test]
    fn bench_json_lands_at_the_repo_root_atomically() {
        let name = format!("harness_selftest_{}", std::process::id());
        write_bench_json(
            &name,
            &[vec![
                ("backend".to_string(), Cell::from("exact")),
                ("pairs".to_string(), Cell::from(123u64)),
                ("frac".to_string(), Cell::from(0.5)),
                ("gap".to_string(), Cell::F64(f64::NAN)),
            ]],
        );
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let path = root.join(format!("BENCH_{name}.json"));
        let text = std::fs::read_to_string(&path).expect("bench json written");
        assert_eq!(
            text,
            "[\n  {\"backend\": \"exact\", \"pairs\": 123, \"frac\": 0.5, \"gap\": null}\n]\n"
        );
        // The temp file was renamed away, not left behind.
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        assert!(!tmp.exists(), "temp file left behind at {}", tmp.display());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn env_parsing() {
        assert_eq!(env_f64("BWKM_NO_SUCH_VAR", 0.5), 0.5);
        assert_eq!(env_u64("BWKM_NO_SUCH_VAR", 7), 7);
    }
}
