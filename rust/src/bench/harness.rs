//! Small timing/IO helpers for the hand-rolled benches.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// Median wall-clock seconds of `iters` runs of `f` (after one warmup).
pub fn bench_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// `bench_out/` under the repo root (created on demand).
pub fn out_dir() -> PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../bench_out");
    std::fs::create_dir_all(&dir).expect("create bench_out");
    dir
}

/// Write CSV rows (first row = header) to `bench_out/<name>.csv`.
pub fn write_csv(name: &str, rows: &[Vec<String>]) {
    let path = out_dir().join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path).expect("create csv");
    for row in rows {
        writeln!(f, "{}", row.join(",")).expect("write csv");
    }
    eprintln!("wrote {}", path.display());
}

/// Write rows of `(key, value)` string pairs as a machine-readable JSON
/// array of objects to `BENCH_<name>.json` at the **repo root** (the
/// drivers' pickup location; the human-facing CSVs stay in `bench_out/`).
/// Values are typed conservatively: anything that parses as a `u64` or a
/// finite `f64` is emitted as a JSON number in Rust's canonical shortest
/// round-trip form (so `"007"` becomes `7`, never invalid-JSON
/// passthrough); everything else is an escaped string. Hand-rolled
/// because serde is unavailable offline (DESIGN.md §4).
pub fn write_bench_json(name: &str, rows: &[Vec<(String, String)>]) {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("../BENCH_{name}.json"));
    let mut s = String::from("[\n");
    for (i, row) in rows.iter().enumerate() {
        s.push_str("  {");
        for (j, (k, v)) in row.iter().enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push('"');
            s.push_str(&json_escape(k));
            s.push_str("\": ");
            s.push_str(&json_value(v));
        }
        s.push('}');
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n");
    std::fs::write(&path, s).expect("write bench json");
    eprintln!("wrote {}", path.display());
}

/// One JSON value from a bench cell (see [`write_bench_json`]).
fn json_value(v: &str) -> String {
    if let Ok(u) = v.parse::<u64>() {
        return u.to_string();
    }
    if let Ok(x) = v.parse::<f64>() {
        if x.is_finite() {
            return x.to_string();
        }
    }
    format!("\"{}\"", json_escape(v))
}

fn json_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Env-var override with default (the BWKM_SCALE / BWKM_REPS knobs).
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_secs_measures_something() {
        let s = bench_secs(3, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(s >= 0.0 && s < 1.0);
    }

    #[test]
    fn json_values_are_typed_conservatively() {
        assert_eq!(json_value("42"), "42");
        assert_eq!(json_value("007"), "7", "canonical form, never invalid passthrough");
        assert_eq!(json_value("0.25"), "0.25");
        assert_eq!(json_value("0.2500"), "0.25");
        assert_eq!(json_value("NaN"), "\"NaN\"", "non-finite floats stay strings");
        assert_eq!(json_value("inf"), "\"inf\"");
        assert_eq!(json_value("exact"), "\"exact\"");
        assert_eq!(json_value(""), "\"\"");
        assert_eq!(json_value("a\"b\\c"), "\"a\\\"b\\\\c\"");
    }

    #[test]
    fn bench_json_lands_at_the_repo_root() {
        let name = format!("harness_selftest_{}", std::process::id());
        write_bench_json(
            &name,
            &[vec![
                ("backend".to_string(), "exact".to_string()),
                ("pairs".to_string(), "123".to_string()),
                ("frac".to_string(), "0.5".to_string()),
            ]],
        );
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join(format!("../BENCH_{name}.json"));
        let text = std::fs::read_to_string(&path).expect("bench json written");
        assert_eq!(text, "[\n  {\"backend\": \"exact\", \"pairs\": 123, \"frac\": 0.5}\n]\n");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn env_parsing() {
        assert_eq!(env_f64("BWKM_NO_SUCH_VAR", 0.5), 0.5);
        assert_eq!(env_u64("BWKM_NO_SUCH_VAR", 7), 7);
    }
}
