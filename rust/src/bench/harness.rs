//! Small timing/IO helpers for the hand-rolled benches.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// Median wall-clock seconds of `iters` runs of `f` (after one warmup).
pub fn bench_secs<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// `bench_out/` under the repo root (created on demand).
pub fn out_dir() -> PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../bench_out");
    std::fs::create_dir_all(&dir).expect("create bench_out");
    dir
}

/// Write CSV rows (first row = header) to `bench_out/<name>.csv`.
pub fn write_csv(name: &str, rows: &[Vec<String>]) {
    let path = out_dir().join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path).expect("create csv");
    for row in rows {
        writeln!(f, "{}", row.join(",")).expect("write csv");
    }
    eprintln!("wrote {}", path.display());
}

/// Env-var override with default (the BWKM_SCALE / BWKM_REPS knobs).
pub fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_secs_measures_something() {
        let s = bench_secs(3, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(s >= 0.0 && s < 1.0);
    }

    #[test]
    fn env_parsing() {
        assert_eq!(env_f64("BWKM_NO_SUCH_VAR", 0.5), 0.5);
        assert_eq!(env_u64("BWKM_NO_SUCH_VAR", 7), 7);
    }
}
