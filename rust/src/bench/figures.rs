//! Figure regeneration (paper §3, Figures 2–6): for one dataset, run every
//! method of the paper's comparison for K ∈ {3, 9, 27}, repeat, and report
//! the trade-off between distance computations and relative error (Eq. 6).
//!
//! Protocol, following the paper:
//! 1. run the benchmark methods (FKM, KM++, KM++_init, KMC2, MB100/500/
//!    1000) to their own convergence, recording distances + final error;
//! 2. cap BWKM's distance budget at the **minimum** distances any
//!    benchmark used across all repetitions ("we have limited its maximum
//!    number of distance computations to the minimum required by the set
//!    of selected benchmark algorithms in all the runs");
//! 3. per repetition, the relative error of each method is measured
//!    against the best solution found in that repetition (Eq. 6);
//! 4. BWKM additionally reports its whole per-outer-iteration trajectory.
//!
//! E^D evaluations used for *scoring* run on separate counters — they are
//! measurements, not part of any method's cost (the paper's x-axis counts
//! only the work the algorithm itself does).

use crate::bwkm::{self, BwkmCfg};
use crate::data::{simulate, Dataset};
use crate::kmeans::init::{forgy, kmc2, kmeanspp, Kmc2Cfg};
use crate::kmeans::{lloyd, minibatch_kmeans, LloydCfg, MiniBatchCfg};
use crate::metrics::{kmeans_error, Budget, DistanceCounter};
use crate::rpkm::{grid_rpkm, RpkmCfg};
use crate::util::{fmt_count, mean_std, Rng};

/// Figure experiment configuration.
#[derive(Clone, Debug)]
pub struct FigureCfg {
    pub dataset: String,
    pub scale: f64,
    pub ks: Vec<usize>,
    pub reps: usize,
    pub seed: u64,
    /// Lloyd iteration cap for the baselines (keeps bench wallclock sane;
    /// the paper runs to the Eq. 2 criterion, which these caps dominate).
    pub lloyd_iters: usize,
    pub mb_iters: usize,
}

impl FigureCfg {
    /// CI-sized default for a Table-1 dataset: `base_scale` targets
    /// ~20k rows; `BWKM_SCALE` multiplies it, `BWKM_REPS` overrides reps.
    pub fn for_dataset(name: &str, base_scale: f64) -> FigureCfg {
        FigureCfg {
            dataset: name.to_string(),
            scale: base_scale * super::harness::env_f64("BWKM_SCALE", 1.0),
            ks: vec![3, 9, 27],
            reps: super::harness::env_u64("BWKM_REPS", 5) as usize,
            seed: 0xF16,
            lloyd_iters: 30,
            mb_iters: 120,
        }
    }
}

/// One aggregated method row (per K).
#[derive(Clone, Debug)]
pub struct MethodRow {
    pub method: String,
    pub k: usize,
    pub mean_distances: f64,
    pub mean_error: f64,
    pub mean_rel_err: f64,
    pub std_rel_err: f64,
}

/// One averaged BWKM trajectory point (per K).
#[derive(Clone, Debug)]
pub struct TrajRow {
    pub k: usize,
    pub outer_iter: usize,
    pub mean_distances: f64,
    pub mean_rel_err: f64,
    /// Repetitions contributing to this iteration index.
    pub support: usize,
}

/// Full figure result.
#[derive(Clone, Debug)]
pub struct FigureResult {
    pub dataset: String,
    pub n: usize,
    pub d: usize,
    pub rows: Vec<MethodRow>,
    pub trajectory: Vec<TrajRow>,
}

struct RepOutcome {
    method: String,
    distances: u64,
    error: f64,
}

/// Run one figure experiment.
pub fn run_figure(cfg: &FigureCfg) -> FigureResult {
    let ds = simulate(&cfg.dataset, cfg.scale, cfg.seed).expect("known dataset");
    eprintln!(
        "figure[{}]: n={} d={} ks={:?} reps={}",
        cfg.dataset, ds.n, ds.d, cfg.ks, cfg.reps
    );

    let mut rows = Vec::new();
    let mut trajectory = Vec::new();

    for &k in &cfg.ks {
        // ---- Pass 1: the benchmark methods, all repetitions.
        let mut per_rep: Vec<Vec<RepOutcome>> = Vec::with_capacity(cfg.reps);
        for rep in 0..cfg.reps {
            let mut rng = Rng::new(cfg.seed ^ ((k as u64) << 24) ^ rep as u64);
            per_rep.push(run_benchmarks(&ds, k, cfg, &mut rng));
        }

        // ---- BWKM budget = min distances over all benchmark runs.
        // Paper protocol: the budget is the minimum over *its* benchmark
        // set (Lloyd-based + MB); KM++_init is an init-only point and RPKM
        // is our extra baseline — both excluded.
        let budget = per_rep
            .iter()
            .flat_map(|r| r.iter())
            .filter(|o| o.method != "KM++_init" && o.method != "RPKM")
            .map(|o| o.distances)
            .min()
            .unwrap_or(u64::MAX);

        // ---- Pass 2: BWKM with that budget, tracing its trajectory.
        let mut traces: Vec<Vec<(u64, f64)>> = Vec::with_capacity(cfg.reps);
        for rep in 0..cfg.reps {
            let mut rng = Rng::new(cfg.seed ^ ((k as u64) << 24) ^ (0xB00 + rep as u64));
            let counter = DistanceCounter::new();
            let mut bcfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
            bcfg.budget = Budget::of(budget);
            bcfg.max_outer = 200;
            bcfg.eval_full_error = true;
            let out = bwkm::run(&ds, k, &bcfg, &mut rng, &counter);
            let traj: Vec<(u64, f64)> = out
                .trace
                .iter()
                .map(|t| (t.distances, t.full_error.unwrap()))
                .collect();
            per_rep[rep].push(RepOutcome {
                method: "BWKM".into(),
                distances: counter.get(),
                error: traj.last().map(|t| t.1).unwrap_or(f64::INFINITY),
            });
            traces.push(traj);
        }

        // ---- Eq. 6 relative errors per repetition.
        let methods: Vec<String> = per_rep[0].iter().map(|o| o.method.clone()).collect();
        for m in &methods {
            let mut dists = Vec::new();
            let mut errs = Vec::new();
            let mut rels = Vec::new();
            for rep in per_rep.iter() {
                let best = rep.iter().map(|o| o.error).fold(f64::INFINITY, f64::min);
                let o = rep.iter().find(|o| &o.method == m).unwrap();
                dists.push(o.distances as f64);
                errs.push(o.error);
                rels.push((o.error - best) / best);
            }
            let (mr, sr) = mean_std(&rels);
            rows.push(MethodRow {
                method: m.clone(),
                k,
                mean_distances: mean_std(&dists).0,
                mean_error: mean_std(&errs).0,
                mean_rel_err: mr,
                std_rel_err: sr,
            });
        }

        // ---- Average the BWKM trajectory per outer-iteration index.
        let max_len = traces.iter().map(|t| t.len()).max().unwrap_or(0);
        for it in 0..max_len {
            let mut dists = Vec::new();
            let mut rels = Vec::new();
            for (rep, traj) in traces.iter().enumerate() {
                if let Some(&(dd, ee)) = traj.get(it) {
                    let best = per_rep[rep]
                        .iter()
                        .map(|o| o.error)
                        .fold(f64::INFINITY, f64::min);
                    dists.push(dd as f64);
                    rels.push((ee - best) / best);
                }
            }
            // The paper plots the iterations within the 95% CI of iteration
            // counts; we report indices supported by ≥ half the runs.
            if dists.len() * 2 >= cfg.reps {
                trajectory.push(TrajRow {
                    k,
                    outer_iter: it,
                    mean_distances: mean_std(&dists).0,
                    mean_rel_err: mean_std(&rels).0,
                    support: dists.len(),
                });
            }
        }
    }

    FigureResult { dataset: cfg.dataset.clone(), n: ds.n, d: ds.d, rows, trajectory }
}

/// All benchmark methods for one repetition.
fn run_benchmarks(ds: &Dataset, k: usize, cfg: &FigureCfg, rng: &mut Rng) -> Vec<RepOutcome> {
    let eval = DistanceCounter::new(); // scoring-only counter
    let lcfg = LloydCfg { max_iters: cfg.lloyd_iters, eps: 1e-9, ..Default::default() };
    let mut out = Vec::new();

    // FKM: Forgy + Lloyd.
    {
        let c = DistanceCounter::new();
        let init = forgy(&ds.data, ds.d, k, rng);
        let l = lloyd(&ds.data, ds.d, &init, &lcfg, &c);
        out.push(RepOutcome { method: "FKM".into(), distances: c.get(), error: l.error });
    }
    // KM++ (+ the KM++_init point).
    {
        let c = DistanceCounter::new();
        let init = kmeanspp(&ds.data, ds.d, k, rng, &c);
        let init_dists = c.get();
        let init_err = kmeans_error(&ds.data, ds.d, &init, &eval);
        out.push(RepOutcome {
            method: "KM++_init".into(),
            distances: init_dists,
            error: init_err,
        });
        let l = lloyd(&ds.data, ds.d, &init, &lcfg, &c);
        out.push(RepOutcome { method: "KM++".into(), distances: c.get(), error: l.error });
    }
    // KMC2 + Lloyd.
    {
        let c = DistanceCounter::new();
        let init = kmc2(&ds.data, ds.d, k, &Kmc2Cfg::default(), rng, &c);
        let l = lloyd(&ds.data, ds.d, &init, &lcfg, &c);
        out.push(RepOutcome { method: "KMC2".into(), distances: c.get(), error: l.error });
    }
    // Mini-batch b ∈ {100, 500, 1000}.
    for b in [100usize, 500, 1000] {
        let c = DistanceCounter::new();
        let mcfg = MiniBatchCfg {
            batch: b,
            max_iters: cfg.mb_iters,
            tol: 1e-4,
            budget: Budget::unlimited(),
        };
        let r = minibatch_kmeans(&ds.data, ds.d, k, &mcfg, rng, &c);
        let err = kmeans_error(&ds.data, ds.d, &r.centroids, &eval);
        out.push(RepOutcome { method: format!("MB{b}"), distances: c.get(), error: err });
    }
    // Grid-based RPKM [8] — the paper's predecessor (not in its Figures
    // 2–6, but the natural extra baseline; its [8] evaluation is exactly
    // this comparison).
    {
        let c = DistanceCounter::new();
        let rcfg = RpkmCfg { max_levels: 4, ..Default::default() };
        let r = grid_rpkm(ds, k, &rcfg, rng, &c);
        let err = kmeans_error(&ds.data, ds.d, &r.centroids, &eval);
        out.push(RepOutcome { method: "RPKM".into(), distances: c.get(), error: err });
    }
    out
}

/// Pretty-print + CSV-dump a figure result. Returns the CSV row count.
pub fn emit(result: &FigureResult, csv_name: &str) -> usize {
    println!(
        "\n=== {} (n={}, d={}) — distances vs relative error (Eq. 6) ===",
        result.dataset, result.n, result.d
    );
    println!(
        "{:<10} {:>3} {:>16} {:>14} {:>12} {:>12}",
        "method", "K", "distances", "E^D", "rel_err", "±std"
    );
    for r in &result.rows {
        println!(
            "{:<10} {:>3} {:>16} {:>14.6e} {:>11.3}% {:>11.3}%",
            r.method,
            r.k,
            fmt_count(r.mean_distances as u64),
            r.mean_error,
            100.0 * r.mean_rel_err,
            100.0 * r.std_rel_err,
        );
    }
    println!("--- BWKM trajectory (averaged over repetitions) ---");
    for t in &result.trajectory {
        println!(
            "K={:<3} iter={:<3} distances={:>14} rel_err={:>9.3}% (n={})",
            t.k,
            t.outer_iter,
            fmt_count(t.mean_distances as u64),
            100.0 * t.mean_rel_err,
            t.support,
        );
    }

    let mut rows = vec![vec![
        "method".into(),
        "k".into(),
        "distances".into(),
        "error".into(),
        "rel_err".into(),
        "rel_err_std".into(),
    ]];
    for r in &result.rows {
        rows.push(vec![
            r.method.clone(),
            r.k.to_string(),
            format!("{:.1}", r.mean_distances),
            format!("{:.8e}", r.mean_error),
            format!("{:.6}", r.mean_rel_err),
            format!("{:.6}", r.std_rel_err),
        ]);
    }
    super::harness::write_csv(csv_name, &rows);

    let mut traj = vec![vec![
        "k".into(),
        "outer_iter".into(),
        "distances".into(),
        "rel_err".into(),
        "support".into(),
    ]];
    for t in &result.trajectory {
        traj.push(vec![
            t.k.to_string(),
            t.outer_iter.to_string(),
            format!("{:.1}", t.mean_distances),
            format!("{:.6}", t.mean_rel_err),
            t.support.to_string(),
        ]);
    }
    super::harness::write_csv(&format!("{csv_name}_bwkm_traj"), &traj);

    for &k in &result.rows.iter().map(|r| r.k).collect::<std::collections::BTreeSet<_>>() {
        ascii_panel(result, k);
    }
    rows.len() - 1
}

/// One ASCII log-log panel (distances → x, relative error → y), the
/// terminal rendition of a Figure 2–6 panel: benchmark methods as single
/// letters, the BWKM trajectory as `*`.
fn ascii_panel(result: &FigureResult, k: usize) {
    const W: usize = 68;
    const H: usize = 16;
    let floor = 1e-4; // 0.01% relative error floor for the log axis
    let mut pts: Vec<(f64, f64, char)> = Vec::new();
    for r in result.rows.iter().filter(|r| r.k == k) {
        let ch = match r.method.as_str() {
            "FKM" => 'F',
            "KM++" => 'P',
            "KM++_init" => 'i',
            "KMC2" => 'C',
            "MB100" => '1',
            "MB500" => '5',
            "MB1000" => '0',
            "RPKM" => 'R',
            "BWKM" => 'B',
            _ => '?',
        };
        pts.push((r.mean_distances, r.mean_rel_err.max(floor), ch));
    }
    for t in result.trajectory.iter().filter(|t| t.k == k) {
        pts.push((t.mean_distances, t.mean_rel_err.max(floor), '*'));
    }
    if pts.is_empty() {
        return;
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y, _) in &pts {
        x0 = x0.min(x.ln());
        x1 = x1.max(x.ln());
        y0 = y0.min(y.ln());
        y1 = y1.max(y.ln());
    }
    let (xs, ys) = ((x1 - x0).max(1e-9), (y1 - y0).max(1e-9));
    let mut grid = vec![vec![' '; W]; H];
    for &(x, y, ch) in &pts {
        let cx = (((x.ln() - x0) / xs) * (W - 1) as f64).round() as usize;
        let cy = (((y.ln() - y0) / ys) * (H - 1) as f64).round() as usize;
        let cell = &mut grid[H - 1 - cy][cx];
        // Trajectory dots never overwrite method markers.
        if *cell == ' ' || (ch != '*' && *cell == '*') {
            *cell = ch;
        }
    }
    println!(
        "\n[{} K={k}] log(distances) → / log(rel err) ↑   \
         (F=FKM P=KM++ i=init C=KMC2 1/5/0=MB R=RPKM B/*=BWKM)",
        result.dataset
    );
    println!("  {:.1e} ┬{}", (y1).exp(), "─".repeat(W));
    for row in grid {
        println!("          │{}", row.iter().collect::<String>());
    }
    println!("  {:.1e} ┴{}", (y0).exp(), "─".repeat(W));
    println!(
        "           {:<34}{:>34}",
        format!("{:.1e}", x0.exp()),
        format!("{:.1e} distances", x1.exp())
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_figure_run_produces_all_methods() {
        let cfg = FigureCfg {
            dataset: "3RN".into(),
            scale: 0.003,
            ks: vec![3],
            reps: 2,
            seed: 9,
            lloyd_iters: 6,
            mb_iters: 20,
        };
        let res = run_figure(&cfg);
        let methods: Vec<&str> = res.rows.iter().map(|r| r.method.as_str()).collect();
        for m in ["FKM", "KM++", "KM++_init", "KMC2", "MB100", "MB500", "MB1000", "BWKM"] {
            assert!(methods.contains(&m), "missing {m} in {methods:?}");
        }
        // Relative errors are non-negative and some method is the best (0).
        let min_rel = res.rows.iter().map(|r| r.mean_rel_err).fold(f64::INFINITY, f64::min);
        assert!(min_rel >= -1e-12);
        assert!(!res.trajectory.is_empty());
    }
}
