//! Benchmark harness (offline substitute for criterion; DESIGN.md §4):
//! timing helpers, the figure-regeneration experiment runner (paper §3,
//! Figures 2–6) and CSV/ASCII emitters. The `benches/*.rs` binaries are
//! thin wrappers over this module.

pub mod figures;
pub mod harness;

pub use figures::{run_figure, FigureCfg, FigureResult};
pub use harness::{
    bench_secs, env_f64, env_u64, out_dir, write_bench_json, write_bench_json_to, write_csv, Cell,
};
