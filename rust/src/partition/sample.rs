//! Sample-induced statistics over a spatial partition — the machinery of
//! the initialization (Alg. 3 needs |B(S)| per block; Alg. 4 needs the
//! representatives and tight boxes of P = B(Sⁱ) for subsamples Sⁱ).

use crate::data::Dataset;
use crate::geometry::BBox;

use super::Partition;

/// Per-block statistics of a subsample located through the partition tree.
#[derive(Clone, Debug)]
pub struct SampleStats {
    /// Sample count per block (|B(S)|).
    pub counts: Vec<usize>,
    /// Coordinate sums of sample members per block.
    pub sums: Vec<Vec<f64>>,
    /// Tight bbox of the sample members per block.
    pub tight: Vec<Option<BBox>>,
}

impl SampleStats {
    /// Locate every sampled row and accumulate per-block stats.
    pub fn collect(partition: &Partition, data: &Dataset, sample: &[usize]) -> SampleStats {
        let mut rows = Vec::with_capacity(sample.len() * data.d);
        for &i in sample {
            rows.extend_from_slice(data.row(i));
        }
        Self::collect_rows(partition, &rows, data.d)
    }

    /// [`collect`](Self::collect) from already-materialized rows (flat
    /// `s×d`, in sample order) — the shape the source-generic Alg. 3/4
    /// drivers use after `RefineSource::fetch_rows` (streaming sources
    /// fetch sampled rows from the stream; DESIGN.md §5.1). The fold
    /// order is the row order of `rows`, so both entry points accumulate
    /// identically.
    pub fn collect_rows(partition: &Partition, rows: &[f64], d: usize) -> SampleStats {
        let nb = partition.len();
        debug_assert_eq!(d, partition.d);
        let mut stats = SampleStats {
            counts: vec![0; nb],
            sums: vec![vec![0.0; d]; nb],
            tight: vec![None; nb],
        };
        for row in rows.chunks_exact(d) {
            let b = partition.locate(row);
            stats.counts[b] += 1;
            for j in 0..d {
                stats.sums[b][j] += row[j];
            }
            match &mut stats.tight[b] {
                Some(bb) => bb.expand(row),
                None => stats.tight[b] = Some(BBox::at(row)),
            }
        }
        stats
    }

    /// Representative (sample center of mass) of block `b`, if sampled.
    pub fn rep(&self, b: usize) -> Option<Vec<f64>> {
        if self.counts[b] == 0 {
            return None;
        }
        let inv = 1.0 / self.counts[b] as f64;
        Some(self.sums[b].iter().map(|s| s * inv).collect())
    }

    /// Flat (reps, weights, block_ids) over sampled blocks — the weighted
    /// set Alg. 4 runs K-means++ on.
    pub fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        let mut reps = Vec::new();
        let mut weights = Vec::new();
        let mut ids = Vec::new();
        for b in 0..self.counts.len() {
            if let Some(r) = self.rep(b) {
                reps.extend_from_slice(&r);
                weights.push(self.counts[b] as f64);
                ids.push(b);
            }
        }
        (reps, weights, ids)
    }

    /// Diagonal of the sample-tight bbox of block `b`, falling back to the
    /// block's own effective diagonal when the sample missed it.
    pub fn diagonal(&self, partition: &Partition, b: usize) -> f64 {
        match &self.tight[b] {
            Some(bb) => bb.diagonal(),
            None => partition.blocks[b].diagonal(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn counts_cover_sample() {
        let ds = Dataset::new(
            vec![0.0, 0.0, 1.0, 0.0, 9.0, 0.0, 10.0, 0.0],
            2,
        );
        let mut p = Partition::root(&ds);
        p.split_at(0, 0, 5.0, Some(&ds));
        let stats = SampleStats::collect(&p, &ds, &[0, 2, 3]);
        assert_eq!(stats.counts, vec![1, 2]);
        assert_eq!(stats.rep(0).unwrap(), vec![0.0, 0.0]);
        assert_eq!(stats.rep(1).unwrap(), vec![9.5, 0.0]);
        let (_, w, ids) = stats.reps_weights();
        assert_eq!(w, vec![1.0, 2.0]);
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn prop_sample_stats_match_full_when_sample_is_everything() {
        prop::check("sample-full", 20, |g| {
            let n = g.int(5, 150);
            let d = g.int(1, 4);
            let ds = Dataset::new(g.blobs(n, d, 2, 1.0), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(5);
            for _ in 0..6 {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let all: Vec<usize> = (0..n).collect();
            let stats = SampleStats::collect(&p, &ds, &all);
            for (b, blk) in p.blocks.iter().enumerate() {
                assert_eq!(stats.counts[b], blk.weight());
                if let Some(r) = blk.rep() {
                    let sr = stats.rep(b).unwrap();
                    for j in 0..d {
                        assert!((r[j] - sr[j]).abs() < 1e-9);
                    }
                }
            }
        });
    }

    #[test]
    fn diagonal_falls_back_to_block() {
        let ds = Dataset::new(vec![0.0, 0.0, 4.0, 3.0], 2);
        let p = Partition::root(&ds);
        let stats = SampleStats::collect(&p, &ds, &[]);
        assert!((stats.diagonal(&p, 0) - 5.0).abs() < 1e-12);
        let mut rng = Rng::new(1);
        let _ = &mut rng;
    }
}
