//! Spatial partitions and their induced dataset partitions (paper Def. 1).
//!
//! A [`Partition`] is a binary split tree over the dataset's smallest
//! bounding box: internal nodes carry an axis-aligned cutting plane, leaves
//! carry [`Block`] payloads. Splitting a block "in the middle point of its
//! longest side" (the paper's cutting rule) replaces its leaf with an
//! internal node and two child leaves; locating a point is a tree descent,
//! so building the induced dataset partition P = B(D) costs
//! O(n·depth) — the incremental design that addresses the paper's
//! Problem 2 (grid-RPKM pays O(n·d) per full partition rebuild).
//!
//! Blocks keep their member indices, coordinate sums and the **tight**
//! bounding box of their members — §2.3: "when updating the data partition
//! ... we also recompute the diagonal of the smallest bounding box of each
//! subset", which makes the misassignment criterion (Eq. 3) strictly more
//! accurate.

use crate::data::Dataset;
use crate::geometry::BBox;

mod sample;
pub use sample::SampleStats;

/// Tree node: either a cutting plane or a leaf holding a block id.
#[derive(Clone, Debug)]
enum Node {
    Internal { axis: usize, thr: f64, left: u32, right: u32 },
    Leaf { block: u32 },
}

/// Serializable view of one tree node — the model store (DESIGN.md §5.2)
/// persists the split tree as a flat array of these and rebuilds it with
/// [`Partition::from_flat`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlatNode {
    Internal { axis: u32, thr: f64, left: u32, right: u32 },
    Leaf { block: u32 },
}

/// One block (hyperrectangular cell) of the spatial partition together
/// with its induced dataset subset.
#[derive(Clone, Debug)]
pub struct Block {
    /// Spatial cell of the leaf (always defined).
    pub cell: BBox,
    /// Tight bounding box of the member points (None when empty).
    pub tight: Option<BBox>,
    /// Indices of the dataset rows lying in this block.
    pub members: Vec<u32>,
    /// Coordinate sums of the members (for O(1) representatives).
    pub sum: Vec<f64>,
    /// Leaf node index in the tree.
    node: u32,
}

impl Block {
    /// |P| — the weight of the representative.
    pub fn weight(&self) -> usize {
        self.members.len()
    }

    /// Center of mass (representative) — None when the block is empty.
    pub fn rep(&self) -> Option<Vec<f64>> {
        if self.members.is_empty() {
            return None;
        }
        let inv = 1.0 / self.members.len() as f64;
        Some(self.sum.iter().map(|s| s * inv).collect())
    }

    /// The diagonal `l_B` used by the misassignment function: the tight
    /// member bbox when known, else the spatial cell.
    pub fn diagonal(&self) -> f64 {
        match &self.tight {
            Some(bb) => bb.diagonal(),
            None => self.cell.diagonal(),
        }
    }

    /// Effective bbox for the cutting rule (tight when available).
    pub fn effective_bbox(&self) -> &BBox {
        self.tight.as_ref().unwrap_or(&self.cell)
    }
}

/// Binary-split spatial partition with induced dataset partition.
#[derive(Clone, Debug)]
pub struct Partition {
    pub d: usize,
    nodes: Vec<Node>,
    pub blocks: Vec<Block>,
}

impl Partition {
    /// Single-block partition over the dataset's smallest bounding box,
    /// with all points as members (paper: "Starting with the smallest
    /// bounding box of the dataset").
    pub fn root(data: &Dataset) -> Partition {
        let bbox = BBox::of(&data.data, data.d, None).expect("non-empty dataset");
        let members: Vec<u32> = (0..data.n as u32).collect();
        let mut sum = vec![0.0; data.d];
        for i in 0..data.n {
            let row = data.row(i);
            for j in 0..data.d {
                sum[j] += row[j];
            }
        }
        let block = Block {
            cell: bbox.clone(),
            tight: Some(bbox),
            members,
            sum,
            node: 0,
        };
        Partition { d: data.d, nodes: vec![Node::Leaf { block: 0 }], blocks: vec![block] }
    }

    /// Same tree but with no member bookkeeping (used by the streaming
    /// coordinator, which re-scans the source instead of holding indices).
    pub fn root_spatial(bbox: BBox, d: usize) -> Partition {
        let block = Block { cell: bbox, tight: None, members: Vec::new(), sum: vec![0.0; d], node: 0 };
        Partition { d, nodes: vec![Node::Leaf { block: 0 }], blocks: vec![block] }
    }

    /// Number of blocks (|B|; includes empty ones).
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Number of non-empty blocks (|P| of the induced dataset partition).
    pub fn occupied(&self) -> usize {
        self.blocks.iter().filter(|b| !b.members.is_empty()).count()
    }

    /// Locate the block id containing point `p` (tree descent).
    pub fn locate(&self, p: &[f64]) -> usize {
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf { block } => return *block as usize,
                Node::Internal { axis, thr, left, right } => {
                    node = if p[*axis] <= *thr { *left as usize } else { *right as usize };
                }
            }
        }
    }

    /// Split block `b` with the paper's rule: middle of the longest side of
    /// its effective bounding box. Member points are redistributed (only
    /// this block's members are touched) and the children's tight boxes and
    /// sums recomputed. Returns (left_id, right_id) where `left_id == b`
    /// (the split block is replaced in place; the right child is appended).
    pub fn split(&mut self, b: usize, data: &Dataset) -> (usize, usize) {
        let (axis, thr) = self.blocks[b].effective_bbox().split_plane();
        self.split_at(b, axis, thr, Some(data))
    }

    /// Split block `b` at an explicit plane. `data` is required to
    /// redistribute members (pass None for spatial-only partitions).
    pub fn split_at(
        &mut self,
        b: usize,
        axis: usize,
        thr: f64,
        data: Option<&Dataset>,
    ) -> (usize, usize) {
        let d = self.d;
        let old_node = self.blocks[b].node;
        let members = std::mem::take(&mut self.blocks[b].members);

        // Child spatial cells.
        let mut lcell = self.blocks[b].cell.clone();
        let mut rcell = self.blocks[b].cell.clone();
        lcell.hi[axis] = thr;
        rcell.lo[axis] = thr;

        // Redistribute members.
        let (mut lmem, mut rmem) = (Vec::new(), Vec::new());
        if let Some(ds) = data {
            lmem.reserve(members.len() / 2);
            rmem.reserve(members.len() / 2);
            for &i in &members {
                if ds.row(i as usize)[axis] <= thr {
                    lmem.push(i);
                } else {
                    rmem.push(i);
                }
            }
        }
        let stats = |mem: &[u32]| -> (Option<BBox>, Vec<f64>) {
            match data {
                Some(ds) if !mem.is_empty() => {
                    let bb = BBox::of(&ds.data, d, Some(mem));
                    let mut sum = vec![0.0; d];
                    for &i in mem {
                        let row = ds.row(i as usize);
                        for j in 0..d {
                            sum[j] += row[j];
                        }
                    }
                    (bb, sum)
                }
                _ => (None, vec![0.0; d]),
            }
        };
        let (ltight, lsum) = stats(&lmem);
        let (rtight, rsum) = stats(&rmem);

        // Left child replaces the split block in place; right is appended.
        let lnode = self.nodes.len() as u32;
        let rnode = lnode + 1;
        self.nodes.push(Node::Leaf { block: b as u32 });
        let rblock = self.blocks.len() as u32;
        self.nodes.push(Node::Leaf { block: rblock });
        self.nodes[old_node as usize] = Node::Internal { axis, thr, left: lnode, right: rnode };

        self.blocks[b] = Block { cell: lcell, tight: ltight, members: lmem, sum: lsum, node: lnode };
        self.blocks.push(Block { cell: rcell, tight: rtight, members: rmem, sum: rsum, node: rnode });
        (b, rblock as usize)
    }

    /// (Re)compute the full induced dataset partition P = B(D): locate all
    /// rows, fill members/sums/tight boxes. O(n·depth + n·d). This is
    /// Step 5 of Alg. 2.
    pub fn assign_members(&mut self, data: &Dataset) {
        for blk in &mut self.blocks {
            blk.members.clear();
            blk.sum.iter_mut().for_each(|s| *s = 0.0);
            blk.tight = None;
        }
        for i in 0..data.n {
            let row = data.row(i);
            let b = self.locate(row);
            let blk = &mut self.blocks[b];
            blk.members.push(i as u32);
            for j in 0..data.d {
                blk.sum[j] += row[j];
            }
            match &mut blk.tight {
                Some(bb) => bb.expand(row),
                None => blk.tight = Some(BBox::at(row)),
            }
        }
    }

    /// Flat (reps, weights, block_ids) of the non-empty blocks — the
    /// weighted point set the weighted Lloyd engine consumes.
    pub fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        let occ = self.occupied();
        let mut reps = Vec::with_capacity(occ * self.d);
        let mut weights = Vec::with_capacity(occ);
        let mut ids = Vec::with_capacity(occ);
        for (i, b) in self.blocks.iter().enumerate() {
            if let Some(r) = b.rep() {
                reps.extend_from_slice(&r);
                weights.push(b.weight() as f64);
                ids.push(i);
            }
        }
        (reps, weights, ids)
    }

    /// Flat serializable view of the split tree, index-for-index with the
    /// internal node array (node 0 is the root).
    pub fn flat_nodes(&self) -> Vec<FlatNode> {
        self.nodes
            .iter()
            .map(|n| match n {
                Node::Leaf { block } => FlatNode::Leaf { block: *block },
                Node::Internal { axis, thr, left, right } => FlatNode::Internal {
                    axis: *axis as u32,
                    thr: *thr,
                    left: *left,
                    right: *right,
                },
            })
            .collect()
    }

    /// Rebuild a partition from a persisted flat tree plus per-block cells.
    /// Blocks come back with **empty member bookkeeping** (no members, zero
    /// sums, stored tight boxes) — callers that need the induced dataset
    /// partition must run [`Partition::assign_members`] over the original
    /// dataset, which is pinned bit-identical to the incrementally
    /// maintained stats. Structural invariants (every block referenced by
    /// exactly one leaf, child/axis indices in range, bbox dims matching
    /// `d`) are validated so a corrupted store fails here, not downstream.
    pub fn from_flat(
        d: usize,
        nodes: &[FlatNode],
        cells: Vec<(BBox, Option<BBox>)>,
    ) -> anyhow::Result<Partition> {
        use anyhow::{bail, ensure};
        ensure!(d > 0, "partition dimension must be positive");
        ensure!(!nodes.is_empty(), "partition tree has no nodes");
        let nb = cells.len();
        let mut leaf_of = vec![None::<u32>; nb];
        let mut built = Vec::with_capacity(nodes.len());
        for (i, fnode) in nodes.iter().enumerate() {
            match *fnode {
                FlatNode::Leaf { block } => {
                    let b = block as usize;
                    ensure!(b < nb, "node {i}: leaf references block {b} of {nb}");
                    if let Some(prev) = leaf_of[b] {
                        bail!("block {b} referenced by two leaves (nodes {prev} and {i})");
                    }
                    leaf_of[b] = Some(i as u32);
                    built.push(Node::Leaf { block });
                }
                FlatNode::Internal { axis, thr, left, right } => {
                    let (l, r) = (left as usize, right as usize);
                    ensure!(
                        l < nodes.len() && r < nodes.len(),
                        "node {i}: child index out of range ({l}, {r} of {})",
                        nodes.len()
                    );
                    ensure!(l != i && r != i, "node {i}: self-referential child");
                    ensure!((axis as usize) < d, "node {i}: split axis {axis} ≥ d={d}");
                    ensure!(thr.is_finite(), "node {i}: non-finite split threshold");
                    built.push(Node::Internal { axis: axis as usize, thr, left, right });
                }
            }
        }
        let mut blocks = Vec::with_capacity(nb);
        for (b, (cell, tight)) in cells.into_iter().enumerate() {
            let node = match leaf_of[b] {
                Some(n) => n,
                None => bail!("block {b} is not referenced by any leaf"),
            };
            ensure!(
                cell.lo.len() == d && cell.hi.len() == d,
                "block {b}: cell bbox dimension mismatch"
            );
            if let Some(t) = &tight {
                ensure!(
                    t.lo.len() == d && t.hi.len() == d,
                    "block {b}: tight bbox dimension mismatch"
                );
            }
            blocks.push(Block {
                cell,
                tight,
                members: Vec::new(),
                sum: vec![0.0; d],
                node,
            });
        }
        Ok(Partition { d, nodes: built, blocks })
    }

    /// Tree depth (diagnostics).
    pub fn depth(&self) -> usize {
        fn go(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 1,
                Node::Internal { left, right, .. } => {
                    1 + go(nodes, *left as usize).max(go(nodes, *right as usize))
                }
            }
        }
        go(&self.nodes, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn dataset(data: Vec<f64>, d: usize) -> Dataset {
        Dataset::new(data, d)
    }

    #[test]
    fn root_holds_everything() {
        let ds = dataset(vec![0.0, 0.0, 1.0, 1.0, 2.0, 0.5], 2);
        let p = Partition::root(&ds);
        assert_eq!(p.len(), 1);
        assert_eq!(p.blocks[0].weight(), 3);
        assert_eq!(p.blocks[0].rep().unwrap(), vec![1.0, 0.5]);
    }

    #[test]
    fn split_redistributes_members_and_sums() {
        let ds = dataset(vec![0.0, 0.0, 10.0, 0.0, 1.0, 0.0, 9.0, 0.0], 2);
        let mut p = Partition::root(&ds);
        let (l, r) = p.split(0, &ds); // longest side is x, thr = 5
        assert_eq!(l, 0);
        assert_eq!(r, 1);
        let mut left: Vec<u32> = p.blocks[l].members.clone();
        left.sort();
        assert_eq!(left, vec![0, 2]);
        assert_eq!(p.blocks[l].rep().unwrap(), vec![0.5, 0.0]);
        assert_eq!(p.blocks[r].rep().unwrap(), vec![9.5, 0.0]);
        // Tight boxes shrank to the member extents.
        assert_eq!(p.blocks[l].tight.as_ref().unwrap().hi[0], 1.0);
        assert_eq!(p.blocks[r].tight.as_ref().unwrap().lo[0], 9.0);
    }

    #[test]
    fn locate_agrees_with_membership() {
        let mut rng = Rng::new(12);
        let data: Vec<f64> = (0..600).map(|_| rng.normal() * 4.0).collect();
        let ds = dataset(data, 3);
        let mut p = Partition::root(&ds);
        for _ in 0..25 {
            let b = rng.usize(p.len());
            if p.blocks[b].weight() > 1 {
                p.split(b, &ds);
            }
        }
        for i in 0..ds.n {
            let b = p.locate(ds.row(i));
            assert!(p.blocks[b].members.contains(&(i as u32)));
        }
    }

    #[test]
    fn assign_members_matches_incremental() {
        let mut rng = Rng::new(13);
        let data: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        let ds = dataset(data, 2);
        let mut p = Partition::root(&ds);
        for _ in 0..15 {
            let b = rng.usize(p.len());
            if p.blocks[b].weight() > 1 {
                p.split(b, &ds);
            }
        }
        let incr: Vec<Vec<u32>> = p
            .blocks
            .iter()
            .map(|b| {
                let mut m = b.members.clone();
                m.sort();
                m
            })
            .collect();
        let mut p2 = p.clone();
        p2.assign_members(&ds);
        for (a, b) in incr.iter().zip(&p2.blocks) {
            let mut m = b.members.clone();
            m.sort();
            assert_eq!(a, &m);
        }
    }

    #[test]
    fn prop_partition_invariants() {
        // Disjoint cover, representative = center of mass, tight ⊆ cell,
        // weights sum to n — after arbitrary split sequences.
        prop::check("partition-invariants", 25, |g| {
            let n = g.int(5, 300);
            let d = g.int(1, 5);
            let data = g.blobs(n, d, 3, 1.0);
            let ds = dataset(data, d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(9);
            let splits = g.int(0, 30);
            for _ in 0..splits {
                let b = rng.usize(p.len());
                if p.blocks[b].weight() > 0 {
                    p.split(b, &ds);
                }
            }
            // Cover + disjoint.
            let mut seen = vec![false; ds.n];
            let mut total = 0usize;
            for b in &p.blocks {
                total += b.weight();
                for &i in &b.members {
                    assert!(!seen[i as usize], "point {i} in two blocks");
                    seen[i as usize] = true;
                }
                // Tight bbox within cell, members inside tight bbox.
                if let Some(t) = &b.tight {
                    for j in 0..d {
                        assert!(t.lo[j] >= b.cell.lo[j] - 1e-12);
                        assert!(t.hi[j] <= b.cell.hi[j] + 1e-12);
                    }
                    for &i in &b.members {
                        assert!(t.contains(ds.row(i as usize)));
                    }
                }
                // Representative is the center of mass.
                if let Some(rep) = b.rep() {
                    let m = crate::geometry::mean_of(&ds.data, d, &b.members);
                    for j in 0..d {
                        assert!((rep[j] - m[j]).abs() < 1e-9);
                    }
                }
            }
            assert_eq!(total, ds.n);
            assert!(seen.iter().all(|&s| s));
        });
    }

    #[test]
    fn prop_thinner_partition_property() {
        // After a split, every new block's member set is a subset of some
        // old block's member set (Def: P' thinner than P).
        prop::check("thinner", 20, |g| {
            let n = g.int(10, 200);
            let d = g.int(1, 4);
            let data = g.cloud(n, d, 2.0);
            let ds = dataset(data, d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(4);
            for _ in 0..8 {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let old: Vec<std::collections::HashSet<u32>> =
                p.blocks.iter().map(|b| b.members.iter().copied().collect()).collect();
            let mut p2 = p.clone();
            for _ in 0..8 {
                let b = rng.usize(p2.len());
                p2.split(b, &ds);
            }
            for nb in &p2.blocks {
                if nb.members.is_empty() {
                    continue;
                }
                let sub: std::collections::HashSet<u32> =
                    nb.members.iter().copied().collect();
                assert!(
                    old.iter().any(|ob| sub.is_subset(ob)),
                    "new block is not a subset of any old block"
                );
            }
        });
    }

    #[test]
    fn flat_roundtrip_rebuilds_identical_tree() {
        let mut rng = Rng::new(21);
        let data: Vec<f64> = (0..900).map(|_| rng.normal() * 3.0).collect();
        let ds = dataset(data, 3);
        let mut p = Partition::root(&ds);
        for _ in 0..20 {
            let b = rng.usize(p.len());
            if p.blocks[b].weight() > 1 {
                p.split(b, &ds);
            }
        }
        let flat = p.flat_nodes();
        let cells: Vec<(BBox, Option<BBox>)> =
            p.blocks.iter().map(|b| (b.cell.clone(), b.tight.clone())).collect();
        let mut q = Partition::from_flat(3, &flat, cells).unwrap();
        assert_eq!(q.flat_nodes(), flat, "flat view survives the roundtrip");
        // Rebuilt partition locates every row in the same block, and
        // assign_members restores member-exact stats bit for bit.
        q.assign_members(&ds);
        for i in 0..ds.n {
            assert_eq!(p.locate(ds.row(i)), q.locate(ds.row(i)));
        }
        for (a, b) in p.blocks.iter().zip(&q.blocks) {
            let (mut ma, mut mb) = (a.members.clone(), b.members.clone());
            ma.sort();
            mb.sort();
            assert_eq!(ma, mb);
            assert_eq!(a.sum, b.sum, "sums fold in row order on both paths");
        }
    }

    #[test]
    fn from_flat_rejects_structural_corruption() {
        let ds = dataset(vec![0.0, 0.0, 4.0, 4.0], 2);
        let mut p = Partition::root(&ds);
        p.split(0, &ds);
        let flat = p.flat_nodes();
        let cells = || -> Vec<(BBox, Option<BBox>)> {
            p.blocks.iter().map(|b| (b.cell.clone(), b.tight.clone())).collect()
        };
        // Dangling block reference.
        let mut bad = flat.clone();
        if let FlatNode::Leaf { block } = &mut bad[1] {
            *block = 99;
        }
        assert!(Partition::from_flat(2, &bad, cells()).is_err());
        // Axis out of range.
        let mut bad = flat.clone();
        if let FlatNode::Internal { axis, .. } = &mut bad[0] {
            *axis = 7;
        }
        assert!(Partition::from_flat(2, &bad, cells()).is_err());
        // A block with no leaf (duplicate reference to another).
        let mut bad = flat.clone();
        if let FlatNode::Leaf { block } = &mut bad[2] {
            *block = 0;
        }
        assert!(Partition::from_flat(2, &bad, cells()).is_err());
        // The untampered tree still loads.
        assert!(Partition::from_flat(2, &flat, cells()).is_ok());
    }

    #[test]
    fn reps_weights_skips_empty_blocks() {
        let ds = dataset(vec![0.0, 0.0, 0.1, 0.1], 2);
        let mut p = Partition::root(&ds);
        // Split far from the data: right child is empty.
        p.split_at(0, 0, 5.0, Some(&ds));
        let (reps, w, ids) = p.reps_weights();
        assert_eq!(w, vec![2.0]);
        assert_eq!(ids, vec![0]);
        assert_eq!(reps.len(), 2);
    }
}
