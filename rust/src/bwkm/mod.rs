//! The paper's contribution: the **Boundary Weighted K-means** algorithm
//! (BWKM) — §2 of the paper.
//!
//! * [`misassignment`] — the ε criterion (Def. 3 / Thm 1), boundaries
//!   (Def. 4) and the Theorem 2 accuracy bound;
//! * [`init_partition`] — Algorithms 2–4 (the boundary-seeking initial
//!   partition);
//! * [`algorithm`] — Algorithm 5 (the main loop) with the §2.4.2 stopping
//!   criteria.

pub mod algorithm;
pub mod init_partition;
pub mod misassignment;

pub use algorithm::{run, run_auto, run_with, BwkmCfg, BwkmOutcome, StopReason, TracePoint};
pub use init_partition::{cutting_masses, initial_partition, starting_partition, InitCfg};
pub use misassignment::{boundary, eps_w_for, epsilon, epsilons, theorem2_bound};
