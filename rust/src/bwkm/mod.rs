//! The paper's contribution: the **Boundary Weighted K-means** algorithm
//! (BWKM) — §2 of the paper.
//!
//! * [`misassignment`] — the ε criterion (Def. 3 / Thm 1), boundaries
//!   (Def. 4) and the Theorem 2 accuracy bound;
//! * [`init_partition`] — Algorithms 2–4 (the boundary-seeking initial
//!   partition);
//! * [`algorithm`] — Algorithm 5 (the main loop) with the §2.4.2 stopping
//!   criteria;
//! * [`source`] — the [`RefineSource`] data-access seam (DESIGN.md §5.1)
//!   that lets the same Alg. 2–5 drivers run in memory ([`MemSource`])
//!   or out of core (`coordinator::streaming::StreamSource`).

pub mod algorithm;
pub mod init_partition;
pub mod misassignment;
pub mod source;

pub use algorithm::{
    resume_source, resume_source_rec, run, run_auto, run_auto_rec, run_rec, run_source,
    run_source_rec, run_with, run_with_rec, BwkmCfg, BwkmOutcome, ResumePoint, SourceOutcome,
    StopReason, TracePoint,
};
pub use init_partition::{
    cutting_masses, cutting_masses_source, initial_partition, initial_partition_source,
    starting_partition, starting_partition_source, InitCfg,
};
pub use misassignment::{
    boundary, eps_w_for, epsilon, epsilons, epsilons_from_diags, theorem2_bound,
    theorem2_bound_from_diags,
};
pub use source::{MemSource, RefineSource};
