//! The BWKM main loop — paper Algorithm 5 (§2.3) with the four stopping
//! criteria of §2.4.2.
//!
//! Per outer iteration: run weighted Lloyd over the current partition's
//! representatives (warm-started), compute ε for every block from the
//! top-2 distances the Lloyd step already produced, sample |F| blocks with
//! probability ∝ ε (only boundary blocks have mass), split them at the
//! middle of the longest side of their tight bounding boxes, and repeat.

use anyhow::Result;

use crate::data::Dataset;
use crate::kmeans::init::{SeedPolicy, Seeder as _};
use crate::kmeans::{
    stepper_for, weighted_lloyd_with, AssignCfg, AssignMode, AutoAssigner, EngineStepper,
    Stepper, WLloydCfg,
};
use crate::metrics::{Budget, DistanceCounter};
use crate::obs::{BillBridge, Recorder};
use crate::partition::Partition;
use crate::util::{Cdf, Rng};

use super::init_partition::{initial_partition_source, InitCfg};
use super::misassignment::{boundary, epsilons_from_diags, theorem2_bound_from_diags};
use super::source::{MemSource, RefineSource};

/// Why a BWKM run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// F_{C,D}(B) = ∅: every block is well assigned; by Theorem 3 the
    /// centroids are a fixed point of Lloyd's algorithm on the full
    /// dataset.
    EmptyBoundary,
    /// The distance-computation budget was exhausted.
    Budget,
    /// Outer-iteration cap.
    MaxIters,
    /// ‖C−C'‖∞ ≤ ε_w (Thm A.4 displacement criterion).
    CentroidShift,
    /// Theorem 2 accuracy bound fell below the configured threshold.
    AccuracyBound,
}

/// Full BWKM configuration.
#[derive(Clone, Copy, Debug)]
pub struct BwkmCfg {
    pub init: InitCfg,
    /// Seeding policy for the Alg. 5 Step-1 centroids over the initial
    /// partition's representatives (DESIGN.md §2.8). The default —
    /// weighted K-means++ — is the paper's Alg. 4 choice and reproduces
    /// the pre-policy pipeline bit for bit.
    pub seed: SeedPolicy,
    /// Inner weighted-Lloyd loop settings.
    pub wl: WLloydCfg,
    /// Maximum outer (partition-refinement) iterations.
    pub max_outer: usize,
    /// Hard distance budget for the whole run.
    pub budget: Budget,
    /// Optional ‖C−C'‖∞ threshold (Thm A.4's ε_w).
    pub shift_tol: Option<f64>,
    /// Optional Theorem 2 bound threshold.
    pub bound_tol: Option<f64>,
    /// Evaluate E^D(C) after every outer iteration into the trace. The
    /// evaluation uses a *separate* counter, so it never pollutes the
    /// method's own accounting (bench instrumentation only).
    pub eval_full_error: bool,
    /// Assignment regime for the inner weighted-Lloyd steps
    /// (DESIGN.md §2.9). The default — exact — reproduces the pre-regime
    /// pipeline bit for bit; the approximate modes self-report their
    /// measured quality gap as a `"gap[...]"` counter note.
    pub assign: AssignCfg,
}

impl BwkmCfg {
    /// The paper's §2.4.1 parameterization: m = 10·√(K·d), s = √n, r = 5;
    /// m' = max(K+1, m/4).
    pub fn for_dataset(n: usize, d: usize, k: usize) -> BwkmCfg {
        let m = (10.0 * ((k * d) as f64).sqrt()).ceil() as usize;
        let m = m.max(k + 2);
        let m_prime = (m / 4).max(k + 1).min(m);
        BwkmCfg {
            init: InitCfg { m_prime, m, s: (n as f64).sqrt().ceil() as usize, r: 5 },
            seed: SeedPolicy::default(),
            wl: WLloydCfg::default(),
            max_outer: 40,
            budget: Budget::unlimited(),
            shift_tol: None,
            bound_tol: None,
            eval_full_error: false,
            assign: AssignCfg::default(),
        }
    }
}

/// One row of the per-outer-iteration trace (the data behind the BWKM
/// trajectory curves in Figures 2–6).
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub outer_iter: usize,
    /// Cumulative distance computations at the end of this iteration.
    pub distances: u64,
    /// Blocks / non-empty blocks / boundary size.
    pub blocks: usize,
    pub occupied: usize,
    pub boundary: usize,
    /// Weighted error E^P(C).
    pub weighted_error: f64,
    /// Theorem 2 bound on |E^D − E^P|.
    pub bound: f64,
    /// E^D(C) when `eval_full_error` is set (uncounted evaluation).
    pub full_error: Option<f64>,
    /// Weighted-Lloyd iterations spent this outer step.
    pub lloyd_iters: usize,
}

/// Outcome of a BWKM run.
#[derive(Clone, Debug)]
pub struct BwkmOutcome {
    pub centroids: Vec<f64>,
    pub k: usize,
    pub d: usize,
    pub stop: StopReason,
    pub trace: Vec<TracePoint>,
    /// Final partition (for inspection / reuse as a coreset).
    pub partition: Partition,
    /// Stored top-2 squared distances per non-empty block (index-aligned
    /// with `partition.reps_weights()`), as produced by the **last inner
    /// weighted-Lloyd step against its pre-update centroids**. Not
    /// recomputable from `centroids` — the model store (DESIGN.md §5.2)
    /// persists them verbatim so a resumed run replays the deferred
    /// split step bit for bit.
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

/// Run BWKM with the stepper `cfg.assign` asks for: the native
/// weighted-Lloyd stepper in the default exact mode, or the closure /
/// sampled approximate backends (DESIGN.md §2.9).
pub fn run(
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> BwkmOutcome {
    run_rec(data, k, cfg, rng, counter, &Recorder::off())
}

/// [`run`] with telemetry (DESIGN.md §2.11). `rec` observes spans, bill
/// deltas and per-iteration gauges; it never participates in FP folds or
/// RNG draws, so the outcome is bit-identical to [`run`]'s.
pub fn run_rec(
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> BwkmOutcome {
    let mut stepper = stepper_for(&cfg.assign);
    run_with_rec(stepper.as_mut(), data, k, cfg, rng, counter, rec)
}

/// Run BWKM with the auto-selecting engine (DESIGN.md §2.7): each inner
/// weighted-Lloyd step picks serial / norm-pruned / cross-iteration
/// bounded per step, the bounds re-priming automatically whenever the
/// partition refines (the representative set changes). Under an unlimited
/// budget the trajectory is bit-identical to [`run`]'s — the backends
/// share the §2.1 canonical kernel — but the counter advances more
/// slowly (so a finite [`Budget`] buys *more* refinement before
/// tripping), and each step's engine choice is logged as a counter note.
pub fn run_auto(
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> BwkmOutcome {
    run_auto_rec(data, k, cfg, rng, counter, &Recorder::off())
}

/// [`run_auto`] with telemetry (DESIGN.md §2.11): the auto engine's
/// per-step choices additionally surface as typed `auto.choice.*` gauges
/// and `auto.switch` events, alongside the unchanged `auto[…]` note log.
pub fn run_auto_rec(
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> BwkmOutcome {
    match cfg.assign.mode {
        // Approximate regime: closure joins auto's choice set (§2.9);
        // the sampled stepper replaces the engine loop outright (it owns
        // the whole step, so there is nothing for auto to select).
        AssignMode::Closure => {
            let mut stepper =
                EngineStepper::with_engine(AutoAssigner::with_closure(cfg.assign.closure_expand));
            run_with_rec(&mut stepper, data, k, cfg, rng, counter, rec)
        }
        AssignMode::Sampled => run_rec(data, k, cfg, rng, counter, rec),
        AssignMode::Exact => {
            let mut stepper: EngineStepper<AutoAssigner> = EngineStepper::new();
            run_with_rec(&mut stepper, data, k, cfg, rng, counter, rec)
        }
    }
}

/// Run BWKM over an arbitrary weighted-Lloyd [`Stepper`] backend (the PJRT
/// runtime plugs in here — `runtime::PjrtStepper`).
pub fn run_with(
    stepper: &mut dyn Stepper,
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> BwkmOutcome {
    run_with_rec(stepper, data, k, cfg, rng, counter, &Recorder::off())
}

/// [`run_with`] with telemetry (DESIGN.md §2.11).
pub fn run_with_rec(
    stepper: &mut dyn Stepper,
    data: &Dataset,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> BwkmOutcome {
    let mut src = MemSource::new(data);
    let out = run_source_rec(stepper, &mut src, k, cfg, rng, counter, rec)
        .expect("the in-memory source is infallible");
    BwkmOutcome {
        centroids: out.centroids,
        k: out.k,
        d: out.d,
        stop: out.stop,
        trace: out.trace,
        partition: src.into_partition(),
        d1: out.d1,
        d2: out.d2,
    }
}

/// Outcome of [`run_source`]: everything in [`BwkmOutcome`] except the
/// partition, which stays with the [`RefineSource`] (the in-memory
/// wrapper extracts it with members; the streaming coordinator extracts
/// the spatial tree plus its own statistics).
#[derive(Clone, Debug)]
pub struct SourceOutcome {
    pub centroids: Vec<f64>,
    pub k: usize,
    pub d: usize,
    pub stop: StopReason,
    pub trace: Vec<TracePoint>,
    /// Last inner step's top-2 squared distances per non-empty block
    /// (against that step's pre-update centroids) — see
    /// [`BwkmOutcome::d1`].
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

/// Mutable Alg. 5 loop state, shared by [`run_source`] (fresh runs) and
/// [`resume_source`] (runs continued from a persisted model).
struct RefineState {
    reps: Vec<f64>,
    weights: Vec<f64>,
    ids: Vec<usize>,
    centroids: Vec<f64>,
    trace: Vec<TracePoint>,
    stop: StopReason,
    d1: Vec<f64>,
    d2: Vec<f64>,
}

/// Step 3 of Alg. 5: sample |F| blocks with replacement ∝ ε, split the
/// hit (weight > 1) blocks, refresh the source and reload the
/// representative set. Returns `Ok(false)` when ε carries no sampling
/// mass (empty boundary) — the caller stops.
fn split_step<S: RefineSource>(
    src: &mut S,
    eps: &[f64],
    f_len: usize,
    st: &mut RefineState,
    rng: &mut Rng,
) -> Result<bool> {
    let cdf = match Cdf::new(eps) {
        Some(c) => c,
        None => return Ok(false),
    };
    let mut hit = vec![false; st.ids.len()];
    for _ in 0..f_len {
        hit[cdf.sample(rng)] = true;
    }
    let mut any_split = false;
    for row in 0..st.ids.len() {
        if hit[row] && src.weight(st.ids[row]) > 1 {
            src.split(st.ids[row]);
            any_split = true;
        }
    }
    if any_split {
        src.refresh()?;
    }
    let rw = src.reps_weights();
    st.reps = rw.0;
    st.weights = rw.1;
    st.ids = rw.2;
    Ok(true)
}

/// The Alg. 5 iteration body, parameterized on the starting outer index so
/// fresh and resumed runs share one loop — outer indices are absolute, so
/// outer-index-sensitive criteria (the `outer > 0` guard on the shift
/// tolerance) behave identically on both paths.
fn refine_loop<S: RefineSource>(
    stepper: &mut dyn Stepper,
    src: &mut S,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    st: &mut RefineState,
    start_outer: usize,
    rec: &Recorder,
) -> Result<()> {
    let d = src.d();
    // Telemetry bridge (DESIGN.md §2.11): per-iteration bill deltas by
    // *reading* the shared counter — never writing it.
    let mut bill = BillBridge::new(counter);
    for outer in start_outer..cfg.max_outer {
        let _iter_span = rec.span("bwkm.iter");
        // ---- Step 2 / Step 4: weighted Lloyd (warm start).
        let mut wl_cfg = cfg.wl;
        wl_cfg.budget = cfg.budget;
        let out = {
            let _s = rec.span("bwkm.lloyd");
            weighted_lloyd_with(stepper, &st.reps, &st.weights, d, &st.centroids, &wl_cfg, counter)
        };
        stepper.record_metrics(rec);
        let shift = crate::kmeans::weighted_lloyd::max_shift(
            &st.centroids,
            &out.centroids,
            d,
            k,
        );
        st.centroids = out.centroids.clone();

        // ---- Step 3 preamble: ε per block from the stored top-2 distances
        // ("we store ... the two closest centroids to the representative").
        let eval_span = rec.span("bwkm.eval");
        let diags: Vec<f64> = st.ids.iter().map(|&b| src.diagonal(b)).collect();
        let eps = epsilons_from_diags(&diags, &out.d1, &out.d2);
        let f = boundary(&eps);
        let bound = theorem2_bound_from_diags(&diags, &st.weights, &out.d1, &eps);
        st.d1 = out.d1;
        st.d2 = out.d2;

        let full_error = if cfg.eval_full_error {
            Some(src.full_error(&st.centroids)?) // uncounted instrumentation
        } else {
            None
        };
        drop(eval_span);
        st.trace.push(TracePoint {
            outer_iter: outer,
            distances: counter.get(),
            blocks: src.partition().len(),
            occupied: src.occupied(),
            boundary: f.len(),
            weighted_error: out.werr,
            bound,
            full_error,
            lloyd_iters: out.iters,
        });
        bill.tick(rec, "bwkm.distances", counter);
        rec.gauge("bwkm.weighted_error", out.werr);
        rec.gauge("bwkm.bound", bound);
        rec.gauge_u64("bwkm.boundary", f.len() as u64);
        rec.gauge_u64("bwkm.blocks", src.partition().len() as u64);
        rec.gauge_u64("bwkm.lloyd_iters", out.iters as u64);

        // ---- Stopping criteria (§2.4.2).
        if f.is_empty() {
            st.stop = StopReason::EmptyBoundary;
            break;
        }
        if cfg.budget.exceeded(counter) {
            st.stop = StopReason::Budget;
            break;
        }
        if let Some(tol) = cfg.shift_tol {
            if shift <= tol && outer > 0 {
                st.stop = StopReason::CentroidShift;
                break;
            }
        }
        if let Some(tol) = cfg.bound_tol {
            if bound <= tol {
                st.stop = StopReason::AccuracyBound;
                break;
            }
        }
        if outer + 1 == cfg.max_outer {
            break; // stop = MaxIters
        }

        // ---- Step 3: sample |F| blocks with replacement ∝ ε and split.
        let _split_span = rec.span("bwkm.split");
        if !split_step(src, &eps, f.len(), st, rng)? {
            st.stop = StopReason::EmptyBoundary;
            break;
        }
    }
    Ok(())
}

/// Shared tail of fresh and resumed runs: emit the §2.9 quality-gap
/// summary (pinned — a capped per-step log cannot drop it) and package
/// the outcome.
fn finish(
    stepper: &mut dyn Stepper,
    st: RefineState,
    k: usize,
    d: usize,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<SourceOutcome> {
    // §2.9: every approximate run self-reports its measured quality gap
    // on the final representatives/centroids as a counter note (uncounted
    // instrumentation); exact steppers return None and add nothing, so
    // exact trajectories and note logs are untouched.
    if let Some(gap) = stepper.quality_gap(&st.reps, &st.weights, d, &st.centroids) {
        counter.note_pinned(gap.note());
        // The same values as typed gauges (DESIGN.md §2.11) — the pinned
        // note string stays the compatibility surface, and the
        // conformance suite rebuilds it `==` from these fields.
        rec.gauge("gap.approx_err", gap.approx_err);
        rec.gauge("gap.exact_err", gap.exact_err);
        rec.gauge("gap.rel", gap.rel_gap());
        rec.gauge("gap.hit_rate", gap.hit_rate);
        rec.gauge_u64("gap.fallbacks", gap.fallbacks);
        rec.event("gap.backend", gap.backend);
    }
    if rec.is_on() {
        rec.event("bwkm.stop", &format!("{:?}", st.stop));
    }
    Ok(SourceOutcome {
        centroids: st.centroids,
        k,
        d,
        stop: st.stop,
        trace: st.trace,
        d1: st.d1,
        d2: st.d2,
    })
}

/// The Alg. 5 main loop over any [`RefineSource`] (DESIGN.md §5.1) — the
/// one driver behind both the in-memory entry points above and the
/// out-of-core `coordinator::streaming::StreamingBwkm`. Control flow,
/// RNG draw order and distance accounting are source-independent, so two
/// sources exposing bit-identical block statistics produce bit-identical
/// outcomes (pinned by `tests/streaming_conformance.rs`).
pub fn run_source<S: RefineSource>(
    stepper: &mut dyn Stepper,
    src: &mut S,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<SourceOutcome> {
    run_source_rec(stepper, src, k, cfg, rng, counter, &Recorder::off())
}

/// [`run_source`] with telemetry (DESIGN.md §2.11): `bwkm.seed` spans the
/// Step-1 partition build + seeding, each outer iteration nests
/// `bwkm.lloyd` / `bwkm.eval` / `bwkm.split` under `bwkm.iter`, the bill
/// is bridged per iteration as `bwkm.distances`, and the stop reason is
/// emitted as a `bwkm.stop` event. Strictly observational: the outcome is
/// bit-identical with `rec` on or off.
pub fn run_source_rec<S: RefineSource>(
    stepper: &mut dyn Stepper,
    src: &mut S,
    k: usize,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<SourceOutcome> {
    assert!(k >= 1, "k must be ≥ 1");
    assert!(src.n() >= k, "n must be ≥ k");
    let d = src.d();

    // ---- Step 1: initial partition + seeding over its representatives
    // (the configured §2.8 policy; default: the paper's weighted
    // K-means++). Seeding always runs in memory — the representative set
    // is tiny — so in-memory and streamed runs draw identically.
    let seed_span = rec.span("bwkm.seed");
    let mut seed_bill = BillBridge::new(counter);
    initial_partition_source(src, k, &cfg.init, rng, counter)?;
    let (reps, weights, ids) = src.reps_weights();
    let centroids = cfg.seed.seeder().seed(&reps, &weights, d, k, rng, counter);
    seed_bill.tick(rec, "bwkm.seed_distances", counter);
    rec.gauge_u64("bwkm.seed_reps", weights.len() as u64);
    drop(seed_span);

    let mut st = RefineState {
        reps,
        weights,
        ids,
        centroids,
        trace: Vec::new(),
        stop: StopReason::MaxIters,
        d1: Vec::new(),
        d2: Vec::new(),
    };
    refine_loop(stepper, src, k, cfg, rng, counter, &mut st, 0, rec)?;
    finish(stepper, st, k, d, counter, rec)
}

/// A persisted mid-run snapshot (model store, DESIGN.md §5.2) from which
/// [`resume_source`] continues the Alg. 5 loop.
#[derive(Clone, Debug)]
pub struct ResumePoint {
    pub centroids: Vec<f64>,
    pub trace: Vec<TracePoint>,
    pub stop: StopReason,
    /// Stored top-2 squared distances per non-empty block — the last inner
    /// step's values against its *pre-update* centroids, persisted
    /// verbatim because they cannot be recomputed from the final
    /// centroids (see [`BwkmOutcome::d1`]).
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

/// Continue an Alg. 5 run from a persisted snapshot over a rebuilt
/// [`RefineSource`], bit-identical to the uninterrupted run.
///
/// An interrupted run (`stop == MaxIters`) broke at
/// `outer + 1 == max_outer` — *after* pushing its last trace point but
/// *before* the Step-3 split. Resuming with a larger `cfg.max_outer`
/// therefore first replays that deferred split (ε from the stored top-2
/// distances plus the rebuilt diagonals; the restored RNG supplies the
/// same draws the uninterrupted run would have made), then re-enters the
/// shared loop at absolute outer index `trace.len()`. Snapshots that
/// stopped for any other reason — or whose cap the caller did not raise —
/// return unchanged: every other criterion is terminal (re-running Lloyd
/// would also charge distances the uninterrupted run never billed).
pub fn resume_source<S: RefineSource>(
    stepper: &mut dyn Stepper,
    src: &mut S,
    k: usize,
    cfg: &BwkmCfg,
    point: ResumePoint,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<SourceOutcome> {
    resume_source_rec(stepper, src, k, cfg, point, rng, counter, &Recorder::off())
}

/// [`resume_source`] with telemetry (DESIGN.md §2.11): the deferred-split
/// replay runs under a `bwkm.resume` span, then the shared loop records
/// as in [`run_source_rec`].
#[allow(clippy::too_many_arguments)]
pub fn resume_source_rec<S: RefineSource>(
    stepper: &mut dyn Stepper,
    src: &mut S,
    k: usize,
    cfg: &BwkmCfg,
    point: ResumePoint,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<SourceOutcome> {
    assert!(k >= 1, "k must be ≥ 1");
    let d = src.d();
    let (reps, weights, ids) = src.reps_weights();
    let mut st = RefineState {
        reps,
        weights,
        ids,
        centroids: point.centroids,
        trace: point.trace,
        stop: point.stop,
        d1: point.d1,
        d2: point.d2,
    };
    rec.gauge_u64("bwkm.resume_outer", st.trace.len() as u64);
    if st.stop != StopReason::MaxIters || st.trace.len() >= cfg.max_outer {
        return finish(stepper, st, k, d, counter, rec);
    }
    if !st.trace.is_empty() {
        anyhow::ensure!(
            st.d1.len() == st.ids.len() && st.d2.len() == st.ids.len(),
            "resume point stores top-2 distances for {} blocks, partition has {} non-empty",
            st.d1.len(),
            st.ids.len()
        );
        // Replay the deferred Step-3 split the interrupted run skipped.
        let _resume_span = rec.span("bwkm.resume");
        let diags: Vec<f64> = st.ids.iter().map(|&b| src.diagonal(b)).collect();
        let eps = epsilons_from_diags(&diags, &st.d1, &st.d2);
        let f = boundary(&eps);
        if !split_step(src, &eps, f.len(), &mut st, rng)? {
            st.stop = StopReason::EmptyBoundary;
            return finish(stepper, st, k, d, counter, rec);
        }
    }
    let start = st.trace.len();
    refine_loop(stepper, src, k, cfg, rng, counter, &mut st, start, rec)?;
    finish(stepper, st, k, d, counter, rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::lloyd::{lloyd, LloydCfg};
    use crate::util::prop;

    fn blob_ds(g: &mut prop::Gen, n: usize, d: usize, k: usize) -> Dataset {
        Dataset::new(g.blobs(n, d, k, 0.5), d)
    }

    #[test]
    fn runs_and_traces_on_blobs() {
        let mut g = prop::Gen { rng: Rng::new(31), case: 0 };
        let ds = blob_ds(&mut g, 1200, 2, 3);
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
        cfg.eval_full_error = true;
        cfg.max_outer = 12;
        let c = DistanceCounter::new();
        let out = run(&ds, 3, &cfg, &mut Rng::new(1), &c);
        assert_eq!(out.centroids.len(), 3 * 2);
        assert!(!out.trace.is_empty());
        // Distances are cumulative and increasing.
        for w in out.trace.windows(2) {
            assert!(w[1].distances >= w[0].distances);
        }
        // The final full error is competitive with Lloyd from the same
        // seeding effort (coarse sanity: within 2x).
        let c2 = DistanceCounter::new();
        let init = crate::kmeans::init::kmeanspp(&ds.data, ds.d, 3, &mut Rng::new(1), &c2);
        let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &c2);
        let e_bwkm = out.trace.last().unwrap().full_error.unwrap();
        assert!(
            e_bwkm < l.error * 2.0 + 1e-9,
            "bwkm {e_bwkm} vs lloyd {}",
            l.error
        );
        // And it used far fewer distances than full Lloyd.
        assert!(c.get() < c2.get(), "bwkm {} vs lloyd {}", c.get(), c2.get());
    }

    #[test]
    fn run_auto_matches_run_at_lower_cost() {
        // Same seed, unlimited budget: the auto engine follows the exact
        // same trajectory (bit-identical backends, same rng draws) while
        // charging fewer distances, and logs one choice per inner step.
        let mut g = prop::Gen { rng: Rng::new(41), case: 0 };
        let ds = blob_ds(&mut g, 1500, 3, 5);
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 5);
        cfg.max_outer = 8;
        let c_plain = DistanceCounter::new();
        let plain = run(&ds, 5, &cfg, &mut Rng::new(6), &c_plain);
        let c_auto = DistanceCounter::new();
        let auto = run_auto(&ds, 5, &cfg, &mut Rng::new(6), &c_auto);
        assert_eq!(plain.centroids, auto.centroids);
        assert_eq!(plain.stop, auto.stop);
        // Warm bounded steps charge ~2 of k pairs per representative; a
        // demoted norm-pruned step may overshoot the serial bill by its
        // m + k norm overhead, hence the small slack.
        assert!(
            c_auto.get() <= c_plain.get() + c_plain.get() / 20,
            "auto {} vs plain {}",
            c_auto.get(),
            c_plain.get()
        );
        let notes = c_auto.notes();
        assert!(!notes.is_empty(), "auto must log its per-step choices");
        assert!(notes.iter().all(|n| n.starts_with("auto[")), "{notes:?}");
    }

    #[test]
    fn empty_boundary_is_lloyd_fixed_point() {
        // Theorem 3 end-to-end: when BWKM stops with an empty boundary,
        // one full Lloyd iteration must not move the centroids.
        let mut g = prop::Gen { rng: Rng::new(32), case: 0 };
        let ds = blob_ds(&mut g, 400, 2, 2);
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 2);
        cfg.max_outer = 200; // let it run to the empty-boundary criterion
        let c = DistanceCounter::new();
        let out = run(&ds, 2, &cfg, &mut Rng::new(2), &c);
        if out.stop == StopReason::EmptyBoundary {
            let c2 = DistanceCounter::new();
            let one = lloyd(
                &ds.data,
                ds.d,
                &out.centroids,
                &LloydCfg { max_iters: 1, eps: 0.0, ..Default::default() },
                &c2,
            );
            let shift = crate::kmeans::weighted_lloyd::max_shift(
                &out.centroids,
                &one.centroids,
                ds.d,
                2,
            );
            assert!(shift < 1e-9, "Theorem 3 violated: shift {shift}");
        }
    }

    #[test]
    fn budget_stops_early() {
        let mut g = prop::Gen { rng: Rng::new(33), case: 0 };
        let ds = blob_ds(&mut g, 3000, 3, 4);
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 4);
        cfg.budget = Budget::of(40_000);
        cfg.max_outer = 1000;
        let c = DistanceCounter::new();
        let out = run(&ds, 4, &cfg, &mut Rng::new(3), &c);
        assert!(matches!(out.stop, StopReason::Budget | StopReason::EmptyBoundary));
        // Overshoot is bounded by one inner Lloyd pass worth of work.
        assert!(c.get() < 40_000 + (out.trace.last().unwrap().occupied as u64 * 4 * 30));
    }

    #[test]
    fn prop_bwkm_improves_over_its_own_seeding() {
        prop::check("bwkm-improves", 6, |g| {
            let n = g.int(300, 1500);
            let d = g.int(2, 4);
            let k = g.int(2, 5);
            let ds = blob_ds(g, n, d, k);
            let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
            cfg.eval_full_error = true;
            cfg.max_outer = 10;
            let c = DistanceCounter::new();
            let out = run(&ds, k, &cfg, &mut g.rng.fork(1), &c);
            let first = out.trace.first().unwrap().full_error.unwrap();
            let last = out.trace.last().unwrap().full_error.unwrap();
            assert!(
                last <= first * (1.0 + 1e-6),
                "error went up across outer iterations: {first} -> {last}"
            );
        });
    }

    #[test]
    fn shift_tolerance_triggers() {
        let mut g = prop::Gen { rng: Rng::new(35), case: 0 };
        let ds = blob_ds(&mut g, 600, 2, 3);
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
        cfg.shift_tol = Some(1e9); // absurdly lax: trips at outer_iter 1
        cfg.max_outer = 50;
        let c = DistanceCounter::new();
        let out = run(&ds, 3, &cfg, &mut Rng::new(4), &c);
        assert!(matches!(
            out.stop,
            StopReason::CentroidShift | StopReason::EmptyBoundary
        ));
        assert!(out.trace.len() <= 2);
    }

    #[test]
    fn k1_degenerate() {
        let mut g = prop::Gen { rng: Rng::new(36), case: 0 };
        let ds = blob_ds(&mut g, 100, 2, 1);
        let cfg = BwkmCfg::for_dataset(ds.n, ds.d, 1);
        let c = DistanceCounter::new();
        let out = run(&ds, 1, &cfg, &mut Rng::new(5), &c);
        // k=1: the (single) centroid must be the dataset mean; boundary is
        // empty immediately.
        assert_eq!(out.stop, StopReason::EmptyBoundary);
        let mean = crate::geometry::mean_of(
            &ds.data,
            ds.d,
            &(0..ds.n as u32).collect::<Vec<_>>(),
        );
        for j in 0..ds.d {
            assert!((out.centroids[j] - mean[j]).abs() < 1e-9);
        }
    }
}
