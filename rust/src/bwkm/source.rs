//! Data access for the Alg. 2–5 pipeline — the seam between "the dataset
//! is a slice" and "the dataset is a stream" (DESIGN.md §5.1).
//!
//! Every place the BWKM pipeline touches raw instances reduces to four
//! operations: draw sampled rows by index, split a block by the paper's
//! cutting rule, (re)establish per-block statistics (count, coordinate
//! sum, tight bounding box), and evaluate E^D for instrumentation.
//! [`RefineSource`] names exactly those operations, so one driver
//! (`algorithm::run_source`, `init_partition::initial_partition_source`)
//! serves both the in-memory path ([`MemSource`], wrapping
//! [`Partition`] + [`Dataset`] with full membership) and the out-of-core
//! path (`coordinator::streaming::StreamSource`, which re-scans a chunked
//! source instead of holding members).
//!
//! **The bit-identity contract.** Both implementations must produce, for
//! every block, *the same floating-point statistics*:
//!
//! * counts are integers and tight boxes are coordinate-wise min/max —
//!   both are order-insensitive, so any evaluation order agrees;
//! * coordinate sums are FP additions, which are **not** associative, so
//!   the contract fixes one canonical order: a block's sum is the
//!   sequential left-to-right sum over its member rows **in dataset row
//!   order**. The in-memory path satisfies this for free (member lists
//!   are built and split in row order, and `Partition::split_at` /
//!   `Partition::assign_members` both fold members in that order); the
//!   streaming path satisfies it by folding each pass serially in global
//!   row order (DESIGN.md §5.1 merge-determinism rule).
//!
//! Under this contract the two paths see identical representatives,
//! weights and diagonals at every step, draw identical random numbers,
//! choose identical splits, and charge identical `DistanceCounter`
//! totals — pinned with `==` by `tests/streaming_conformance.rs`.
//!
//! Seeding needs no hook here: the Alg. 5 Step-1 seeding (the §2.8
//! `SeedPolicy`, weighted K-means++ by default) runs on the
//! representative set both paths expose identically, so any policy is
//! source-independent for free. Seeding the *raw* rows of a stream —
//! K-means|| over data that never materializes — is the separate
//! `coordinator::streaming::StreamSeeder` path, built on the same
//! chunk-pass machinery (DESIGN.md §2.8).

use anyhow::Result;

use crate::data::Dataset;
use crate::metrics::{kmeans_error, DistanceCounter};
use crate::partition::Partition;

/// Abstract access to a dataset being refined into a spatial partition
/// (DESIGN.md §5.1). All methods are distance-free: implementations must
/// never tick a caller-visible [`DistanceCounter`] — locating, splitting
/// and statistics passes are partition work, not distance work
/// (DESIGN.md §2.4).
pub trait RefineSource {
    /// Number of rows of the underlying dataset.
    fn n(&self) -> usize;

    /// Dimension.
    fn d(&self) -> usize;

    /// The rows at the given dataset indices, flat `idx.len()×d`, in
    /// `idx` order (Alg. 3/4 sample in the RNG's draw order and fold
    /// sample statistics in that order — the order must be preserved).
    fn fetch_rows(&mut self, idx: &[usize]) -> Result<Vec<f64>>;

    /// The spatial split tree. Streaming implementations carry no member
    /// bookkeeping in the blocks; use the stats methods below instead of
    /// `blocks[b].weight()` / `blocks[b].diagonal()`.
    fn partition(&self) -> &Partition;

    /// |P_b| — the number of dataset rows in block `b`.
    fn weight(&self, b: usize) -> usize;

    /// Number of non-empty blocks (|P| of the induced dataset partition).
    fn occupied(&self) -> usize;

    /// l_B of block `b`: the tight member-bbox diagonal when the block is
    /// non-empty, the spatial cell diagonal otherwise (the same rule as
    /// `partition::Block::diagonal`).
    fn diagonal(&self, b: usize) -> f64;

    /// Flat (reps, weights, block_ids) of the non-empty blocks — the
    /// weighted point set the Lloyd engine consumes, in block-id order.
    fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>);

    /// Split block `b` with the paper's cutting rule (middle of the
    /// longest side of its tight bbox, cell when empty). Implementations
    /// may defer the children's statistics; callers must [`refresh`]
    /// after a batch of splits before reading any per-block statistic.
    ///
    /// [`refresh`]: RefineSource::refresh
    fn split(&mut self, b: usize);

    /// Bring every per-block statistic up to date after a split batch.
    /// In-memory: a no-op (splits maintain member-exact stats
    /// incrementally). Streaming: one pass over the source, committed
    /// only if the pass completes cleanly — a failed refresh must leave
    /// the previous statistics in place.
    fn refresh(&mut self) -> Result<()>;

    /// E^D(C) over the full dataset — instrumentation only: must use a
    /// private counter (never the method's own bill, DESIGN.md §2.4) and
    /// must equal `metrics::kmeans_error` on the materialized data bit
    /// for bit (reference kernel, SSE folded in row order).
    fn full_error(&mut self, centroids: &[f64]) -> Result<f64>;
}

/// The in-memory [`RefineSource`]: a [`Partition`] with full membership
/// over a borrowed [`Dataset`] — exactly the state `bwkm::run` always
/// operated on, behind the trait.
pub struct MemSource<'a> {
    data: &'a Dataset,
    partition: Partition,
}

impl<'a> MemSource<'a> {
    /// Start from the single-block root partition (Alg. 2 Step 1).
    pub fn new(data: &'a Dataset) -> MemSource<'a> {
        MemSource { data, partition: Partition::root(data) }
    }

    /// Surrender the refined partition (members, sums and tight boxes
    /// all populated).
    pub fn into_partition(self) -> Partition {
        self.partition
    }

    /// Wrap an existing partition (warm start / resume, DESIGN.md §5.2).
    /// The partition must carry **member-exact** statistics over `data` —
    /// a tree rebuilt from a persisted model must run
    /// `Partition::assign_members(data)` first, which is pinned
    /// bit-identical to incrementally maintained stats.
    pub fn with_partition(data: &'a Dataset, partition: Partition) -> MemSource<'a> {
        assert_eq!(partition.d, data.d, "partition/dataset dimension mismatch");
        MemSource { data, partition }
    }
}

/// Read-only in-memory source over a *borrowed* partition — the shape
/// behind the public `cutting_masses` wrapper, whose driver
/// (`init_partition::cutting_masses_source`) only ever samples and
/// locates: no splits, no refreshes, so no reason to deep-clone the
/// partition's member lists the way an owning [`MemSource`] would
/// require. Refinement through it is a programming error and panics.
pub(crate) struct SampleOnlySource<'a> {
    data: &'a Dataset,
    partition: &'a Partition,
}

impl<'a> SampleOnlySource<'a> {
    pub(crate) fn new(data: &'a Dataset, partition: &'a Partition) -> SampleOnlySource<'a> {
        SampleOnlySource { data, partition }
    }
}

impl RefineSource for SampleOnlySource<'_> {
    fn n(&self) -> usize {
        self.data.n
    }

    fn d(&self) -> usize {
        self.data.d
    }

    fn fetch_rows(&mut self, idx: &[usize]) -> Result<Vec<f64>> {
        Ok(self.data.gather(idx).data)
    }

    fn partition(&self) -> &Partition {
        self.partition
    }

    fn weight(&self, b: usize) -> usize {
        self.partition.blocks[b].weight()
    }

    fn occupied(&self) -> usize {
        self.partition.occupied()
    }

    fn diagonal(&self, b: usize) -> f64 {
        self.partition.blocks[b].diagonal()
    }

    fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        self.partition.reps_weights()
    }

    fn split(&mut self, _b: usize) {
        unreachable!("SampleOnlySource is read-only: the sampling drivers never split");
    }

    fn refresh(&mut self) -> Result<()> {
        unreachable!("SampleOnlySource is read-only: the sampling drivers never refresh");
    }

    fn full_error(&mut self, centroids: &[f64]) -> Result<f64> {
        let eval = DistanceCounter::new();
        Ok(kmeans_error(&self.data.data, self.data.d, centroids, &eval))
    }
}

impl RefineSource for MemSource<'_> {
    fn n(&self) -> usize {
        self.data.n
    }

    fn d(&self) -> usize {
        self.data.d
    }

    fn fetch_rows(&mut self, idx: &[usize]) -> Result<Vec<f64>> {
        Ok(self.data.gather(idx).data)
    }

    fn partition(&self) -> &Partition {
        &self.partition
    }

    fn weight(&self, b: usize) -> usize {
        self.partition.blocks[b].weight()
    }

    fn occupied(&self) -> usize {
        self.partition.occupied()
    }

    fn diagonal(&self, b: usize) -> f64 {
        self.partition.blocks[b].diagonal()
    }

    fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        self.partition.reps_weights()
    }

    fn split(&mut self, b: usize) {
        self.partition.split(b, self.data);
    }

    fn refresh(&mut self) -> Result<()> {
        // Incremental splits keep member-exact stats: `split_at` folds
        // each child's members in row order, which is exactly what a
        // full `assign_members` rebuild would produce (the bit-identity
        // contract above), so there is nothing to do.
        Ok(())
    }

    fn full_error(&mut self, centroids: &[f64]) -> Result<f64> {
        let eval = DistanceCounter::new(); // uncounted instrumentation
        Ok(kmeans_error(&self.data.data, self.data.d, centroids, &eval))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn mem_source_mirrors_partition_state() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(71), case: 0 };
        let ds = Dataset::new(g.blobs(200, 3, 2, 0.8), 3);
        let mut src = MemSource::new(&ds);
        assert_eq!(src.n(), 200);
        assert_eq!(src.d(), 3);
        assert_eq!(src.weight(0), 200);
        assert_eq!(src.occupied(), 1);

        src.split(0);
        src.refresh().unwrap();
        let p = src.partition();
        assert_eq!(p.len(), 2);
        for b in 0..2 {
            assert_eq!(src.weight(b), p.blocks[b].weight());
            assert_eq!(src.diagonal(b), p.blocks[b].diagonal());
        }
        let (reps, w, ids) = src.reps_weights();
        let (reps2, w2, ids2) = src.partition().reps_weights();
        assert_eq!(reps, reps2);
        assert_eq!(w, w2);
        assert_eq!(ids, ids2);
    }

    #[test]
    fn fetch_rows_preserves_index_order() {
        let ds = Dataset::new(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 2);
        let mut src = MemSource::new(&ds);
        let rows = src.fetch_rows(&[2, 0]).unwrap();
        assert_eq!(rows, vec![4.0, 5.0, 0.0, 1.0]);
    }

    #[test]
    fn full_error_matches_kmeans_error_and_counts_nothing() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(72), case: 0 };
        let ds = Dataset::new(g.cloud(50, 2, 2.0), 2);
        let cents = g.cloud(3, 2, 2.0);
        let mut src = MemSource::new(&ds);
        let c = DistanceCounter::new();
        let e_ref = kmeans_error(&ds.data, 2, &cents, &c);
        let before = c.get();
        let e_src = src.full_error(&cents).unwrap();
        assert_eq!(e_src.to_bits(), e_ref.to_bits());
        assert_eq!(c.get(), before, "full_error must not tick caller counters");
    }
}
