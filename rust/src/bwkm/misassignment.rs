//! The misassignment function ε (paper Def. 3, via the δ margin of
//! Def. 2), the boundary of a spatial partition (Def. 4) and the
//! Theorem 2 accuracy bound.
//!
//! ε_{C,D}(B) = max(0, 2·l_B − δ_P(C)),  δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖,
//!
//! where l_B is the block diagonal and c₁, c₂ the two nearest centroids to
//! the representative P̄. Theorem 1: ε = 0 ⇒ every instance in the block is
//! assigned to the same centroid as the representative (the block is *well
//! assigned*). Everything here consumes the squared top-2 distances
//! `(d1, d2)` that the unified assignment engine already produced — the
//! `d1`/`d2` fields of [`crate::kmeans::StepOut`] from the weighted-Lloyd
//! step, or of [`crate::kmeans::AssignOut`] from a bare assignment pass —
//! the "cheap criterion" of §2.1: **no distances are recomputed**, and ε
//! therefore costs zero entries on the `DistanceCounter` (DESIGN.md
//! §2.3).

/// Misassignment value from a block diagonal and squared top-2 distances.
/// `d2_sq = ∞` (single centroid) yields 0 — one centroid means every point
/// trivially shares the block's assignment.
#[inline]
pub fn epsilon(diag: f64, d1_sq: f64, d2_sq: f64) -> f64 {
    if !d2_sq.is_finite() {
        return 0.0;
    }
    let delta = d2_sq.sqrt() - d1_sq.sqrt();
    (2.0 * diag - delta).max(0.0)
}

/// Per-block ε for the non-empty blocks of a partition, given the top-2
/// squared distances of their representatives (aligned with `ids`).
pub fn epsilons(
    partition: &crate::partition::Partition,
    ids: &[usize],
    d1: &[f64],
    d2: &[f64],
) -> Vec<f64> {
    let diags: Vec<f64> =
        ids.iter().map(|&b| partition.blocks[b].diagonal()).collect();
    epsilons_from_diags(&diags, d1, d2)
}

/// [`epsilons`] from pre-gathered block diagonals (one per representative
/// row). This is the shape the source-generic driver uses: the streaming
/// path has no member-carrying blocks to read diagonals from, so the
/// `RefineSource` supplies them (DESIGN.md §5.1).
pub fn epsilons_from_diags(diags: &[f64], d1: &[f64], d2: &[f64]) -> Vec<f64> {
    diags
        .iter()
        .enumerate()
        .map(|(row, &l)| epsilon(l, d1[row], d2[row]))
        .collect()
}

/// Boundary F_{C,D}(B): indices (into `ids`/`eps`) of blocks with ε > 0.
pub fn boundary(eps: &[f64]) -> Vec<usize> {
    eps.iter()
        .enumerate()
        .filter_map(|(i, &e)| (e > 0.0).then_some(i))
        .collect()
}

/// Theorem 2 bound on |E^D(C) − E^P(C)|:
/// Σ_B 2·|P|·ε_B·(2·l_B + ‖P̄−c_P̄‖) + (|P|−1)/2 · l_B².
///
/// All inputs come from the last weighted-Lloyd iteration — O(|P|), no
/// distance computations (it is also the §2.4.2 "accuracy" stopping
/// criterion).
pub fn theorem2_bound(
    partition: &crate::partition::Partition,
    ids: &[usize],
    weights: &[f64],
    d1: &[f64],
    eps: &[f64],
) -> f64 {
    let diags: Vec<f64> =
        ids.iter().map(|&b| partition.blocks[b].diagonal()).collect();
    theorem2_bound_from_diags(&diags, weights, d1, eps)
}

/// [`theorem2_bound`] from pre-gathered block diagonals — the
/// source-generic shape (see [`epsilons_from_diags`]).
pub fn theorem2_bound_from_diags(
    diags: &[f64],
    weights: &[f64],
    d1: &[f64],
    eps: &[f64],
) -> f64 {
    let mut bound = 0.0;
    for (row, &l) in diags.iter().enumerate() {
        let w = weights[row];
        bound += 2.0 * w * eps[row] * (2.0 * l + d1[row].sqrt());
        bound += (w - 1.0) * 0.5 * l * l;
    }
    bound
}

/// Displacement threshold ε_w guaranteeing the Eq. 2 criterion (Thm A.4),
/// in its **corrected** form ε_w = sqrt(l² + ε/n) − l: the paper prints
/// sqrt(l² + ε²/n²) − l, but its own proof chain (n·ε_w² + 2·n·l·ε_w = ε)
/// requires ε/n under the root — see `tests/theorems.rs` and the erratum
/// note in EXPERIMENTS.md. Use with [`super::BwkmCfg::shift_tol`].
pub fn eps_w_for(eps: f64, bbox_diagonal: f64, n: usize) -> f64 {
    let l = bbox_diagonal;
    (l * l + eps / n as f64).sqrt() - l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::kmeans::{Assigner, NativeStepper, SerialAssigner, Stepper};
    use crate::metrics::{kmeans_error, weighted_error, DistanceCounter};
    use crate::partition::Partition;
    use crate::util::prop;

    #[test]
    fn epsilon_basics() {
        // diag 1, distances 4 and 49 (squared): delta = 7-2 = 5 > 2 → 0.
        assert_eq!(epsilon(1.0, 4.0, 49.0), 0.0);
        // diag 3: 2*3 - 5 = 1.
        assert!((epsilon(3.0, 4.0, 49.0) - 1.0).abs() < 1e-12);
        // Single centroid.
        assert_eq!(epsilon(10.0, 4.0, f64::INFINITY), 0.0);
        // Zero diagonal (singleton block) is always well assigned.
        assert_eq!(epsilon(0.0, 1.0, 1.0), 0.0);
    }

    /// ε from a bare engine pass (`AssignOut`) equals ε from the fused
    /// step (`StepOut`) on the same centroids — the "no recomputation"
    /// contract holds whichever engine shape produced the top-2.
    #[test]
    fn epsilons_agree_across_engine_shapes() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(12), case: 0 };
        let ds = Dataset::new(g.blobs(120, 2, 3, 1.0), 2);
        let mut p = Partition::root(&ds);
        for _ in 0..8 {
            let b = g.rng.usize(p.len());
            if p.blocks[b].weight() > 0 {
                p.split(b, &ds);
            }
        }
        let (reps, w, ids) = p.reps_weights();
        let cents = g.cloud(3, 2, 5.0);
        let c = DistanceCounter::new();
        let bare = crate::kmeans::SerialAssigner.assign_top2(&reps, 2, &cents, &c);
        let step = NativeStepper::new().step(&reps, &w, 2, &cents, &c);
        assert_eq!(
            epsilons(&p, &ids, &bare.d1, &bare.d2),
            epsilons(&p, &ids, &step.d1, &step.d2)
        );
    }

    #[test]
    fn boundary_filters_positive() {
        assert_eq!(boundary(&[0.0, 0.5, 0.0, 2.0]), vec![1, 3]);
        assert!(boundary(&[0.0, 0.0]).is_empty());
    }

    /// Theorem 1 (the paper's sufficiency proof), validated empirically:
    /// whenever ε_{C,D}(B) = 0, every instance in B is assigned to the
    /// representative's centroid.
    #[test]
    fn prop_theorem1_zero_eps_implies_well_assigned() {
        prop::check("thm1", 40, |g| {
            let n = g.int(10, 250);
            let d = g.int(1, 4);
            let k = g.int(2, 6);
            let ds = Dataset::new(g.blobs(n, d, k, 1.5), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(2);
            for _ in 0..g.int(3, 40) {
                let b = rng.usize(p.len());
                if p.blocks[b].weight() > 0 {
                    p.split(b, &ds);
                }
            }
            let (reps, w, ids) = p.reps_weights();
            let cents = g.cloud(k, d, 5.0);
            let c = DistanceCounter::new();
            let step = NativeStepper::new().step(&reps, &w, d, &cents, &c);
            let eps = epsilons(&p, &ids, &step.d1, &step.d2);
            for (row, &b) in ids.iter().enumerate() {
                if eps[row] == 0.0 {
                    let rep_assign = step.assign[row];
                    for &i in &p.blocks[b].members {
                        let (ci, _) =
                            crate::metrics::nearest(ds.row(i as usize), &cents, d, &c);
                        assert_eq!(
                            ci as u32, rep_assign,
                            "Theorem 1 violated: block {b} has eps=0 but point {i} \
                             assigned to {ci} != rep's {rep_assign}"
                        );
                    }
                }
            }
        });
    }

    /// Theorem 2: |E^D(C) − E^P(C)| is bounded by the computable bound.
    #[test]
    fn prop_theorem2_bound_holds() {
        prop::check("thm2", 40, |g| {
            let n = g.int(10, 200);
            let d = g.int(1, 4);
            let k = g.int(2, 5);
            let ds = Dataset::new(g.blobs(n, d, k, 1.0), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(3);
            for _ in 0..g.int(0, 25) {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let (reps, w, ids) = p.reps_weights();
            let cents = g.cloud(k, d, 4.0);
            let c = DistanceCounter::new();
            let step = NativeStepper::new().step(&reps, &w, d, &cents, &c);
            let eps = epsilons(&p, &ids, &step.d1, &step.d2);
            let bound = theorem2_bound(&p, &ids, &w, &step.d1, &eps);

            let e_full = kmeans_error(&ds.data, d, &cents, &c);
            let e_wtd = weighted_error(&reps, &w, d, &cents, &c);
            assert!(
                (e_full - e_wtd).abs() <= bound * (1.0 + 1e-9) + 1e-9,
                "Theorem 2 violated: |{e_full} - {e_wtd}| > {bound}"
            );
        });
    }

    /// Corollary of Lemma A.1: when every block is well assigned the
    /// weighted error *difference* between two centroid sets equals the
    /// full-dataset error difference.
    #[test]
    fn prop_lemma_a1_error_differences_match_when_well_assigned() {
        prop::check("lemma-a1", 25, |g| {
            let n = g.int(10, 150);
            let d = g.int(1, 3);
            let k = 2;
            let ds = Dataset::new(g.blobs(n, d, k, 0.5), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(7);
            // Split a lot so blocks become singletons / tiny → well assigned.
            for _ in 0..140 {
                let b = rng.usize(p.len());
                if p.blocks[b].weight() > 1 {
                    p.split(b, &ds);
                }
            }
            let (reps, w, ids) = p.reps_weights();
            let c1 = g.cloud(k, d, 4.0);
            let c2 = g.cloud(k, d, 4.0);
            let c = DistanceCounter::new();

            // Only check when *both* centroid sets leave all blocks well
            // assigned (the lemma's hypothesis).
            let mut stepper = NativeStepper::new();
            let s1 = stepper.step(&reps, &w, d, &c1, &c);
            let s2 = stepper.step(&reps, &w, d, &c2, &c);
            let e1 = epsilons(&p, &ids, &s1.d1, &s1.d2);
            let e2 = epsilons(&p, &ids, &s2.d1, &s2.d2);
            if e1.iter().any(|&e| e > 0.0) || e2.iter().any(|&e| e > 0.0) {
                return; // hypothesis not met for this case
            }
            let ef1 = kmeans_error(&ds.data, d, &c1, &c);
            let ef2 = kmeans_error(&ds.data, d, &c2, &c);
            let ew1 = weighted_error(&reps, &w, d, &c1, &c);
            let ew2 = weighted_error(&reps, &w, d, &c2, &c);
            let scale = ef1.abs().max(ef2.abs()).max(1.0);
            assert!(
                ((ef1 - ef2) - (ew1 - ew2)).abs() < 1e-7 * scale,
                "Lemma A.1 violated: full diff {} vs weighted diff {}",
                ef1 - ef2,
                ew1 - ew2
            );
        });
    }
}
