//! Construction of BWKM's initial partition — paper §2.2, Algorithms 2–4.
//!
//! * **Alg. 3** grows a starting spatial partition of size m' by
//!   repeatedly sampling s points and splitting blocks drawn with
//!   probability ∝ l_B · |B(S)| (big *and* dense blocks first).
//! * **Alg. 4** estimates, for the current partition, the probability that
//!   each block is *not* well assigned: r subsamples, a weighted
//!   K-means++ run over each sample-induced representative set, and the
//!   misassignment function ε of every block against those centroids
//!   (Eq. 5).
//! * **Alg. 2** alternates Alg. 4 with probability-guided splits until the
//!   partition has m blocks, then materializes the induced dataset
//!   partition P = B(D) (one full pass — the only O(n) work).

use anyhow::Result;

use crate::data::Dataset;
use crate::kmeans::init::{KmppSeeder, Seeder};
use crate::metrics::{nearest2, DistanceCounter};
use crate::partition::{Partition, SampleStats};
use crate::util::{Cdf, Rng};

use super::misassignment::epsilon;
use super::source::{MemSource, RefineSource, SampleOnlySource};

/// Parameters of the initial-partition construction (paper §2.4.1
/// recommends m = 10·√(K·d), s = √n, r = 5, and m' ≥ K).
#[derive(Clone, Copy, Debug)]
pub struct InitCfg {
    /// Size of the starting spatial partition (Alg. 3), ≥ K.
    pub m_prime: usize,
    /// Target size of the initial partition (Alg. 2), > m'.
    pub m: usize,
    /// Subsample size s.
    pub s: usize,
    /// Number of K-means++ repetitions r.
    pub r: usize,
}

/// Alg. 3: starting spatial partition of size m'.
///
/// No distance computations — only sampling, locating and splitting.
pub fn starting_partition(
    data: &Dataset,
    m_prime: usize,
    s: usize,
    rng: &mut Rng,
) -> Partition {
    let mut src = MemSource::new(data);
    starting_partition_source(&mut src, m_prime, s, rng)
        .expect("the in-memory source is infallible");
    src.into_partition()
}

/// [`starting_partition`] over any [`RefineSource`] (DESIGN.md §5.1),
/// refining the source's partition in place. Each round samples s row
/// indices, fetches those rows, scores blocks by Pr(B) ∝ l_B·|B(S)| from
/// the sample statistics, splits the drawn blocks at their tight-bbox
/// split planes, and refreshes block statistics before the next round
/// (a no-op in memory, one streamed pass out of core). The RNG draw
/// sequence is identical for every source, so so are the splits.
pub fn starting_partition_source<S: RefineSource>(
    src: &mut S,
    m_prime: usize,
    s: usize,
    rng: &mut Rng,
) -> Result<()> {
    while src.partition().len() < m_prime {
        let sample = sample_indices(rng, src.n(), s);
        let rows = src.fetch_rows(&sample)?;
        let stats = SampleStats::collect_rows(src.partition(), &rows, src.d());
        // Pr(B) ∝ l_B · |B(S)|.
        let probs: Vec<f64> = (0..src.partition().len())
            .map(|b| {
                if stats.counts[b] == 0 {
                    0.0
                } else {
                    stats.diagonal(src.partition(), b) * stats.counts[b] as f64
                }
            })
            .collect();
        let want = src.partition().len().min(m_prime - src.partition().len());
        let selected = sample_with_replacement(&probs, want, rng);
        if selected.is_empty() {
            break; // degenerate: all mass zero (e.g. all points identical)
        }
        for b in selected {
            src.split(b);
        }
        src.refresh()?;
    }
    Ok(())
}

/// Alg. 4: cutting probabilities Pr(B) (Eq. 5) for the current partition.
///
/// Returns the (unnormalized) accumulated misassignment mass per block;
/// `Cdf`-normalization happens at the sampling site. Distance accounting:
/// each repetition pays the weighted K-means++ cost over its sampled
/// representatives plus one top-2 scan per sampled block.
pub fn cutting_masses(
    partition: &Partition,
    data: &Dataset,
    k: usize,
    s: usize,
    r: usize,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Vec<f64> {
    // Read-only borrow: the driver only samples and locates, so no
    // partition clone is needed (SampleOnlySource panics on refinement).
    let mut src = SampleOnlySource::new(data, partition);
    cutting_masses_source(&mut src, k, s, r, rng, counter)
        .expect("the in-memory source is infallible")
}

/// [`cutting_masses`] over any [`RefineSource`]. Needs only the tree
/// (to locate sampled rows) and the sampled rows themselves — no
/// per-block dataset statistics — so it never triggers a streamed
/// statistics pass. Distance accounting is identical for every source:
/// the weighted K-means++ seeding cost plus one top-2 scan per sampled
/// block, per repetition.
pub fn cutting_masses_source<S: RefineSource>(
    src: &mut S,
    k: usize,
    s: usize,
    r: usize,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<Vec<f64>> {
    let d = src.d();
    let mut mass = vec![0.0; src.partition().len()];
    for _ in 0..r {
        let sample = sample_indices(rng, src.n(), s);
        let rows = src.fetch_rows(&sample)?;
        let stats = SampleStats::collect_rows(src.partition(), &rows, d);
        let (reps, weights, ids) = stats.reps_weights();
        if ids.is_empty() {
            continue;
        }
        let kk = k.min(ids.len());
        // Alg. 4 is pinned to weighted K-means++ by the paper (Eq. 5's
        // Cⁱ are D²-sampled) — deliberately *not* the configurable §2.8
        // seeding policy, which only governs the Alg. 5 Step-1 seeding.
        let cents = KmppSeeder.seed(&reps, &weights, d, kk, rng, counter);
        if kk < 2 {
            continue; // ε is 0 against a single centroid
        }
        for (row, &b) in ids.iter().enumerate() {
            let (_, d1, d2) = nearest2(&reps[row * d..(row + 1) * d], &cents, d, counter);
            mass[b] += epsilon(stats.diagonal(src.partition(), b), d1, d2);
        }
    }
    Ok(mass)
}

/// Alg. 2: the full initial-partition construction. Returns the partition
/// with the induced dataset partition materialized (Step 5).
pub fn initial_partition(
    data: &Dataset,
    k: usize,
    cfg: &InitCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Partition {
    let mut src = MemSource::new(data);
    initial_partition_source(&mut src, k, cfg, rng, counter)
        .expect("the in-memory source is infallible");
    src.into_partition()
}

/// [`initial_partition`] over any [`RefineSource`], refining the
/// source's partition in place (DESIGN.md §5.1). Step 5's explicit
/// `assign_members` rebuild of the retired in-memory-only version is
/// absorbed into the [`RefineSource::refresh`] contract: incremental
/// splits already maintain member-exact counts/sums/tight boxes (they
/// fold members in row order, exactly as a rebuild would — see
/// `bwkm::source`), so the final rebuild was provably a no-op and every
/// source ends this function with fully materialized block statistics.
pub fn initial_partition_source<S: RefineSource>(
    src: &mut S,
    k: usize,
    cfg: &InitCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<()> {
    assert!(cfg.m_prime >= k.max(1), "m' must be ≥ K");
    assert!(cfg.m >= cfg.m_prime, "m must be ≥ m'");
    starting_partition_source(src, cfg.m_prime, cfg.s, rng)?;

    while src.partition().len() < cfg.m {
        let mass = cutting_masses_source(src, k, cfg.s, cfg.r, rng, counter)?;
        let want = src.partition().len().min(cfg.m - src.partition().len());
        let selected = sample_with_replacement(&mass, want, rng);
        if selected.is_empty() {
            // Every sampled block is well assigned w.r.t. every seeding —
            // the partition is already good enough (paper: Pr(B)=0 means
            // well assigned for all Sⁱ, Cⁱ).
            break;
        }
        for b in selected {
            src.split(b);
        }
        src.refresh()?;
    }
    Ok(())
}

/// `want` draws with replacement ∝ `probs`, deduplicated (a block selected
/// twice is split once — its halves are candidates next round, exactly as
/// in the paper's "sample with replacement ... to determine a subset").
fn sample_with_replacement(probs: &[f64], want: usize, rng: &mut Rng) -> Vec<usize> {
    let cdf = match Cdf::new(probs) {
        Some(c) => c,
        None => return Vec::new(),
    };
    let mut hit = vec![false; probs.len()];
    for _ in 0..want {
        hit[cdf.sample(rng)] = true;
    }
    (0..probs.len()).filter(|&i| hit[i]).collect()
}

/// Uniform sample of `s` indices without replacement (capped at n).
fn sample_indices(rng: &mut Rng, n: usize, s: usize) -> Vec<usize> {
    rng.sample_indices(n, s.min(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn toy(g: &mut prop::Gen, n: usize, d: usize) -> Dataset {
        Dataset::new(g.blobs(n, d, 3, 0.6), d)
    }

    #[test]
    fn starting_partition_reaches_m_prime() {
        let mut g = prop::Gen { rng: Rng::new(21), case: 0 };
        let ds = toy(&mut g, 500, 3);
        let mut rng = Rng::new(1);
        let p = starting_partition(&ds, 40, 22, &mut rng);
        assert!(p.len() >= 40, "got {}", p.len());
        // Invariant: all points still covered.
        let total: usize = p.blocks.iter().map(|b| b.weight()).sum();
        assert_eq!(total, 500);
    }

    #[test]
    fn initial_partition_respects_m_and_covers() {
        let mut g = prop::Gen { rng: Rng::new(22), case: 0 };
        let ds = toy(&mut g, 800, 2);
        let mut rng = Rng::new(2);
        let c = DistanceCounter::new();
        let cfg = InitCfg { m_prime: 10, m: 60, s: 28, r: 3 };
        let p = initial_partition(&ds, 5, &cfg, &mut rng, &c);
        assert!(p.len() <= 60 + 60, "size {}", p.len()); // dedupe keeps it near m
        let total: usize = p.blocks.iter().map(|b| b.weight()).sum();
        assert_eq!(total, 800);
        assert!(c.get() > 0, "Alg.4 must have computed distances");
    }

    #[test]
    fn cutting_masses_zero_for_well_separated_singletons() {
        // Two singleton blocks far apart, k=2: every seeding puts a
        // centroid "near" each rep (reps are the only candidates), so the
        // diagonal-0 blocks are always well assigned → zero mass.
        let ds = Dataset::new(vec![0.0, 0.0, 100.0, 0.0], 2);
        let mut p = Partition::root(&ds);
        p.split_at(0, 0, 50.0, Some(&ds));
        let c = DistanceCounter::new();
        let mass = cutting_masses(&p, &ds, 2, 2, 4, &mut Rng::new(3), &c);
        assert!(mass.iter().all(|&m| m == 0.0), "{mass:?}");
    }

    #[test]
    fn prop_initial_partition_invariants() {
        prop::check("init-partition", 10, |g| {
            let n = g.int(50, 600);
            let d = g.int(1, 5);
            let k = g.int(2, 6);
            let ds = toy(g, n, d);
            let mut rng = g.rng.fork(11);
            let c = DistanceCounter::new();
            let m_prime = (k + 2).max(8);
            let cfg = InitCfg {
                m_prime,
                m: m_prime + g.int(0, 40),
                s: (n as f64).sqrt() as usize + 1,
                r: 3,
            };
            let p = initial_partition(&ds, k, &cfg, &mut rng, &c);
            // Cover and disjointness.
            let mut seen = vec![false; n];
            for b in &p.blocks {
                for &i in &b.members {
                    assert!(!seen[i as usize]);
                    seen[i as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
            // Representatives are inside their tight boxes.
            for b in &p.blocks {
                if let (Some(rep), Some(t)) = (b.rep(), b.tight.as_ref()) {
                    assert!(t.contains(&rep));
                }
            }
        });
    }

    #[test]
    fn degenerate_all_identical_points() {
        let ds = Dataset::new(vec![1.0; 50], 1);
        let mut rng = Rng::new(5);
        let c = DistanceCounter::new();
        let cfg = InitCfg { m_prime: 4, m: 8, s: 7, r: 2 };
        let p = initial_partition(&ds, 2, &cfg, &mut rng, &c);
        // Cannot split a zero-diameter box usefully; still valid.
        let total: usize = p.blocks.iter().map(|b| b.weight()).sum();
        assert_eq!(total, 50);
    }
}
