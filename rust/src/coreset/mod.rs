//! Coreset analysis of partition-based representations — paper Appendix
//! Theorem A.1: the i-th grid-RPKM iteration is a (K, ε)-coreset with ε
//! decaying exponentially in i.
//!
//! Two views of the same result are provided: the *absolute* gap bound
//! used inside the Theorem A.1 proof (directly testable, no OPT needed)
//! and the (K, ε)-coreset ε expressed against an OPT estimate (what the
//! theorem states; reported by `benches/coreset_bound`).

/// Absolute bound of the Thm A.1 proof chain:
/// |E^D(C) − E^P(C)| ≤ ((n−1)/2^(2i+1) + n/2^(i−1)) · l²,
/// where l is the diagonal of the dataset's bounding box and i the grid
/// level (every cell has diagonal l/2^i).
pub fn grid_abs_bound(level: u32, n: usize, l: f64) -> f64 {
    let n = n as f64;
    let a = (n - 1.0) / 2f64.powi(2 * level as i32 + 1);
    let b = n / 2f64.powi(level as i32 - 1);
    (a + b) * l * l
}

/// Theorem A.1's ε:  ε = (1/2^(i−1)) · (1 + (1/2^(i+2))·(n−1)/n) · n·l²/OPT.
pub fn grid_epsilon(level: u32, n: usize, l: f64, opt: f64) -> f64 {
    let nf = n as f64;
    (1.0 / 2f64.powi(level as i32 - 1))
        * (1.0 + (1.0 / 2f64.powi(level as i32 + 2)) * (nf - 1.0) / nf)
        * (nf * l * l / opt)
}

/// Empirical |E^D(C) − E^P(C)| for a weighted representation (uncounted —
/// analysis instrumentation).
pub fn empirical_gap(
    data: &[f64],
    d: usize,
    reps: &[f64],
    weights: &[f64],
    centroids: &[f64],
) -> f64 {
    let c = crate::metrics::DistanceCounter::new();
    let full = crate::metrics::kmeans_error(data, d, centroids, &c);
    let wtd = crate::metrics::weighted_error(reps, weights, d, centroids, &c);
    (full - wtd).abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::geometry::BBox;
    use crate::rpkm::grid_partition;
    use crate::util::prop;

    #[test]
    fn bound_decays_exponentially() {
        let b1 = grid_abs_bound(1, 1000, 1.0);
        let b4 = grid_abs_bound(4, 1000, 1.0);
        let b8 = grid_abs_bound(8, 1000, 1.0);
        assert!(b1 > 8.0 * b4 - 1e-9);
        assert!(b4 > 8.0 * b8);
    }

    #[test]
    fn epsilon_formula_matches_paper_shape() {
        // ε ≈ 2^{-(i-1)} · n l²/OPT for large i.
        let e = grid_epsilon(10, 10_000, 2.0, 100.0);
        let approx = (1.0 / 2f64.powi(9)) * (10_000.0 * 4.0 / 100.0);
        assert!((e / approx - 1.0).abs() < 0.01);
    }

    /// Theorem A.1 (proof-chain form), validated empirically on random
    /// data, grids and centroid sets.
    #[test]
    fn prop_grid_gap_within_abs_bound() {
        prop::check("thm-a1", 30, |g| {
            let n = g.int(20, 400);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.blobs(n, d, 3, 1.0), d);
            let bbox = BBox::of(&ds.data, d, None).unwrap();
            let l = bbox.diagonal();
            let level = g.int(1, 5) as u32;
            let (reps, weights) = grid_partition(&ds, &bbox, level);
            // The Thm A.1 proof assumes d(x, C) ≤ l, which holds whenever
            // the centroids lie inside the bounding box — pick dataset rows.
            let mut cents = Vec::with_capacity(k * d);
            for _ in 0..k {
                let i = g.rng.usize(n);
                cents.extend_from_slice(ds.row(i));
            }
            let gap = empirical_gap(&ds.data, d, &reps, &weights, &cents);
            let bound = grid_abs_bound(level, n, l);
            assert!(
                gap <= bound * (1.0 + 1e-9),
                "Theorem A.1 violated: gap {gap} > bound {bound} (level {level})"
            );
        });
    }
}
