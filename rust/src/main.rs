//! `bwkm` — the leader binary: CLI entry point over [`bwkm::cli`].

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = bwkm::cli::main(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
