//! Grid-based RPKM (Capó et al. [8]) — the paper's predecessor baseline
//! (§1.2.2.1) and the subject of the Theorem A.1 coreset bound.
//!
//! At iteration i the smallest bounding box is divided into a uniform grid
//! of 2^(i·d) cells; the weighted Lloyd algorithm runs over the occupied
//! cells' representatives, warm-started from the previous level. This is
//! exactly the strategy whose Problems 1–3 (no d-scaling, dataset- and
//! problem-independence) motivate BWKM.

use std::collections::HashMap;

use crate::data::Dataset;
use crate::geometry::BBox;
use crate::kmeans::init::{SeedMethod, SeedPolicy, Seeder as _};
use crate::kmeans::{stepper_for, weighted_lloyd_with, AssignCfg, WLloydCfg};
use crate::metrics::{kmeans_error, Budget, DistanceCounter};
use crate::obs::{BillBridge, Recorder};
use crate::util::Rng;

/// Occupied-cell representatives of the level-`i` uniform grid:
/// (reps flat, weights). Cells are keyed by their per-axis bin indices;
/// only occupied cells are materialized (≤ n).
pub fn grid_partition(data: &Dataset, bbox: &BBox, level: u32) -> (Vec<f64>, Vec<f64>) {
    let d = data.d;
    let bins = 1u64 << level; // 2^i bins per axis
    let mut cells: HashMap<Box<[u32]>, (Vec<f64>, usize)> = HashMap::new();
    let mut key = vec![0u32; d];
    for i in 0..data.n {
        let row = data.row(i);
        for j in 0..d {
            let span = bbox.hi[j] - bbox.lo[j];
            let t = if span > 0.0 { (row[j] - bbox.lo[j]) / span } else { 0.0 };
            key[j] = ((t * bins as f64) as u64).min(bins - 1) as u32;
        }
        let e = cells
            .entry(key.clone().into_boxed_slice())
            .or_insert_with(|| (vec![0.0; d], 0));
        for j in 0..d {
            e.0[j] += row[j];
        }
        e.1 += 1;
    }
    let mut reps = Vec::with_capacity(cells.len() * d);
    let mut weights = Vec::with_capacity(cells.len());
    // Deterministic order (sorted keys) so runs are reproducible.
    let mut entries: Vec<_> = cells.into_iter().collect();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    for (_, (sum, count)) in entries {
        let inv = 1.0 / count as f64;
        reps.extend(sum.iter().map(|s| s * inv));
        weights.push(count as f64);
    }
    (reps, weights)
}

/// Grid-RPKM configuration.
#[derive(Clone, Copy, Debug)]
pub struct RpkmCfg {
    /// Maximum grid levels (paper [8] uses i ≤ 10; cells grow as 2^(i·d)).
    pub max_levels: u32,
    pub wl: WLloydCfg,
    pub budget: Budget,
    /// First-level seeding policy over the grid representatives
    /// (DESIGN.md §2.8). [8] seeds with Forgy, so that is the default
    /// (bit-identical to the pre-policy behavior); later levels always
    /// warm-start from the previous level's centroids.
    pub seed: SeedPolicy,
    /// Trace E^D after every level (uncounted instrumentation).
    pub eval_full_error: bool,
    /// Assignment regime for the per-level weighted Lloyd runs
    /// (DESIGN.md §2.9). `Exact` (the default) is bit-identical to the
    /// pre-regime behavior; the approximate modes self-report their bill
    /// and final quality gap through the counter.
    pub assign: AssignCfg,
}

impl Default for RpkmCfg {
    fn default() -> Self {
        RpkmCfg {
            max_levels: 6,
            wl: WLloydCfg::default(),
            budget: Budget::unlimited(),
            seed: SeedPolicy::of(SeedMethod::Forgy),
            eval_full_error: false,
            assign: AssignCfg::default(),
        }
    }
}

/// One grid level's trace entry.
#[derive(Clone, Debug)]
pub struct RpkmTracePoint {
    pub level: u32,
    pub distances: u64,
    pub representatives: usize,
    pub weighted_error: f64,
    pub full_error: Option<f64>,
}

/// Outcome of a grid-RPKM run.
#[derive(Clone, Debug)]
pub struct RpkmOutcome {
    pub centroids: Vec<f64>,
    pub trace: Vec<RpkmTracePoint>,
}

/// Run grid-based RPKM (Alg. 1 with the [8] partition strategy).
pub fn grid_rpkm(
    data: &Dataset,
    k: usize,
    cfg: &RpkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> RpkmOutcome {
    grid_rpkm_rec(data, k, cfg, rng, counter, &Recorder::off())
}

/// [`grid_rpkm`] with telemetry (DESIGN.md §2.11): per-level
/// `rpkm.partition` / `rpkm.lloyd` spans, a bridged `rpkm.distances`
/// bill, and per-level gauges. Strictly observational — the outcome is
/// bit-identical with `rec` on or off.
pub fn grid_rpkm_rec(
    data: &Dataset,
    k: usize,
    cfg: &RpkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> RpkmOutcome {
    let bbox = BBox::of(&data.data, data.d, None).expect("non-empty dataset");
    let mut centroids: Option<Vec<f64>> = None;
    let mut trace = Vec::new();
    // One stepper for the whole run: approximate backends carry warm
    // state (closures, retained assignments) across levels.
    let mut stepper = stepper_for(&cfg.assign);
    let mut last_rw: Option<(Vec<f64>, Vec<f64>)> = None;
    let mut bill = BillBridge::new(counter);

    for level in 1..=cfg.max_levels {
        if cfg.budget.exceeded(counter) {
            break;
        }
        let (reps, weights) = {
            let _s = rec.span("rpkm.partition");
            grid_partition(data, &bbox, level)
        };
        let m = weights.len();
        let init = match centroids.take() {
            Some(c) => c,
            // First level: the configured §2.8 policy over the grid
            // representatives ([8]'s choice — Forgy — is the default).
            None => cfg.seed.seeder().seed(&reps, &weights, data.d, k.min(m), rng, counter),
        };
        let mut wl_cfg = cfg.wl;
        wl_cfg.budget = cfg.budget;
        let out = {
            let _s = rec.span("rpkm.lloyd");
            weighted_lloyd_with(stepper.as_mut(), &reps, &weights, data.d, &init, &wl_cfg, counter)
        };
        stepper.record_metrics(rec);
        let full_error = cfg.eval_full_error.then(|| {
            let eval = DistanceCounter::new();
            kmeans_error(&data.data, data.d, &out.centroids, &eval)
        });
        trace.push(RpkmTracePoint {
            level,
            distances: counter.get(),
            representatives: m,
            weighted_error: out.werr,
            full_error,
        });
        bill.tick(rec, "rpkm.distances", counter);
        rec.gauge_u64("rpkm.level", level as u64);
        rec.gauge_u64("rpkm.representatives", m as u64);
        rec.gauge("rpkm.weighted_error", out.werr);
        centroids = Some(out.centroids);
        last_rw = Some((reps, weights));
        // No reduction left: the partition is as fine as the dataset.
        if m == data.n {
            break;
        }
    }
    let centroids = centroids.expect("at least one level");
    // Approximate regimes self-report their final measured gap (§2.9);
    // exact steppers return None and nothing is emitted. The summary is
    // pinned: a per-step note log past its cap cannot drop it.
    if let Some((reps, weights)) = &last_rw {
        if let Some(gap) = stepper.quality_gap(reps, weights, data.d, &centroids) {
            counter.note_pinned(gap.note());
            rec.gauge("gap.approx_err", gap.approx_err);
            rec.gauge("gap.exact_err", gap.exact_err);
            rec.gauge("gap.rel", gap.rel_gap());
            rec.gauge("gap.hit_rate", gap.hit_rate);
            rec.gauge_u64("gap.fallbacks", gap.fallbacks);
            rec.event("gap.backend", gap.backend);
        }
    }
    RpkmOutcome { centroids, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn grid_partition_preserves_mass_and_mean() {
        let mut g = prop::Gen { rng: Rng::new(41), case: 0 };
        let ds = Dataset::new(g.blobs(300, 3, 2, 1.0), 3);
        let bbox = BBox::of(&ds.data, 3, None).unwrap();
        for level in 1..=4 {
            let (reps, weights) = grid_partition(&ds, &bbox, level);
            let total: f64 = weights.iter().sum();
            assert_eq!(total as usize, 300);
            // Weighted mean of reps == dataset mean.
            let mut wm = vec![0.0; 3];
            for (i, w) in weights.iter().enumerate() {
                for j in 0..3 {
                    wm[j] += w * reps[i * 3 + j];
                }
            }
            let all: Vec<u32> = (0..300).collect();
            let mean = crate::geometry::mean_of(&ds.data, 3, &all);
            for j in 0..3 {
                assert!((wm[j] / 300.0 - mean[j]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn levels_refine_monotonically() {
        let mut g = prop::Gen { rng: Rng::new(42), case: 0 };
        let ds = Dataset::new(g.blobs(500, 2, 3, 1.2), 2);
        let bbox = BBox::of(&ds.data, 2, None).unwrap();
        let mut prev = 0;
        for level in 1..=5 {
            let (_, w) = grid_partition(&ds, &bbox, level);
            assert!(w.len() >= prev, "partition got coarser");
            prev = w.len();
        }
    }

    #[test]
    fn rpkm_runs_and_improves() {
        let mut g = prop::Gen { rng: Rng::new(43), case: 0 };
        let ds = Dataset::new(g.blobs(1000, 2, 3, 0.4), 2);
        let cfg = RpkmCfg { eval_full_error: true, max_levels: 6, ..Default::default() };
        let c = DistanceCounter::new();
        let out = grid_rpkm(&ds, 3, &cfg, &mut Rng::new(2), &c);
        assert!(out.trace.len() >= 2);
        let first = out.trace.first().unwrap().full_error.unwrap();
        let last = out.trace.last().unwrap().full_error.unwrap();
        assert!(last <= first * 1.01, "{first} -> {last}");
    }

    #[test]
    fn prop_rpkm_matches_lloyd_at_full_resolution() {
        // With enough levels on a small dataset, the partition becomes
        // (near-)singleton and RPKM's solution is a Lloyd fixed point.
        prop::check("rpkm-fixed-point", 5, |g| {
            let ds = Dataset::new(g.blobs(120, 2, 2, 0.3), 2);
            let cfg = RpkmCfg { max_levels: 12, ..Default::default() };
            let c = DistanceCounter::new();
            let out = grid_rpkm(&ds, 2, &cfg, &mut g.rng.fork(3), &c);
            let c2 = DistanceCounter::new();
            let one = crate::kmeans::lloyd::lloyd(
                &ds.data,
                ds.d,
                &out.centroids,
                &crate::kmeans::LloydCfg { max_iters: 1, eps: 0.0, ..Default::default() },
                &c2,
            );
            let shift = crate::kmeans::weighted_lloyd::max_shift(
                &out.centroids,
                &one.centroids,
                ds.d,
                2,
            );
            assert!(shift < 1e-7, "not a fixed point: shift {shift}");
        });
    }
}
