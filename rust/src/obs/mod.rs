//! Structured run telemetry (DESIGN.md §2.11): phase **spans** on a
//! monotonic clock, typed **counters/gauges**, and discrete **events**,
//! fanned out to pluggable sinks.
//!
//! The repo's exact-accounting story (DESIGN.md §2.4) covers the distance
//! axis of the paper's cost/quality trade-off; everything else — phase
//! timings, prune rates, auto-engine choices, per-job service behavior —
//! used to be smeared across free-form `note()` strings and stdout
//! prints. This module promotes those to typed metrics behind one
//! [`Recorder`] handle while the pinned note formats (`auto[…]`,
//! `gap[…]`) stay untouched as a compatibility surface.
//!
//! ## The non-perturbation contract (DESIGN.md §2.11)
//!
//! Observability **observes** FP folds, RNG draws and distance bills; it
//! never participates in them. A run with `metrics=off` and the same run
//! with `metrics=jsonl` produce bit-identical centroids, traces, counter
//! totals and notes — pinned by `tests/obs_conformance.rs` with `==`, no
//! tolerances. Wall-clock timing values are the only nondeterministic
//! fields, and they exist *only* in sink output, never in algorithm
//! results. Concretely that means:
//!
//! - recorders never touch a [`DistanceCounter`] or an RNG — bill deltas
//!   are bridged by *reading* the counter ([`BillBridge`]);
//! - the off path is a no-op: [`Recorder::off`] holds no allocation and
//!   [`Recorder::span`] takes no clock reading when off;
//! - instrumented entry points are `_rec`-suffixed variants; the original
//!   names delegate with [`Recorder::off`] and stay byte-for-byte on the
//!   old code path.
//!
//! ## Sinks
//!
//! Three sinks implement the one [`Sink`] trait:
//!
//! - [`NullRecorder`] — discards every record (the explicit form of the
//!   default-off stance; also the bench baseline for the record path);
//! - [`SummaryRecorder`] — in-memory aggregation (spans: count/total;
//!   counters: sum; gauges: last-value; events: count + capped tail),
//!   printed by the CLI as a run report and emitted as `BENCH_`-style
//!   typed JSON via the existing [`crate::bench::harness::Cell`] cells;
//! - [`JsonlRecorder`] — an append-only trace file, one JSON object per
//!   line: `{"ts":<µs-since-epoch>,"kind":"span|counter|gauge|event",
//!   "name":"…","value":<typed>}`, sharing the bench harness's escaping
//!   so value typing is identical across both documents.
//!
//! `metrics=jsonl` attaches **both** the summary and the trace sink, so a
//! traced run still yields the typed-cell summary document.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::bench::harness::{json_escape, json_value, Cell};
use crate::metrics::DistanceCounter;

/// How many distinct event payload strings a [`SummaryRecorder`] retains
/// per event name (the count is always exact; only the stored tail is
/// capped, mirroring the `NOTE_CAP` stance of DESIGN.md §2.4).
pub const EVENT_TAIL_CAP: usize = 64;

// ---------------------------------------------------------------------------
// Modes, clock, records
// ---------------------------------------------------------------------------

/// The `metrics=` run key (DESIGN.md §2.11).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MetricsMode {
    /// No recorder: the hot path is the pre-observability byte sequence.
    #[default]
    Off,
    /// In-memory aggregation + CLI run report + typed summary JSON.
    Summary,
    /// Everything `Summary` does, plus an append-only JSONL trace file.
    Jsonl,
}

impl MetricsMode {
    pub fn parse(v: &str) -> Result<MetricsMode> {
        match v {
            "off" => Ok(MetricsMode::Off),
            "summary" => Ok(MetricsMode::Summary),
            "jsonl" => Ok(MetricsMode::Jsonl),
            _ => bail!("unknown metrics mode `{v}` (off|summary|jsonl)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MetricsMode::Off => "off",
            MetricsMode::Summary => "summary",
            MetricsMode::Jsonl => "jsonl",
        }
    }
}

/// The one monotonic clock abstraction (DESIGN.md §2.11): span timing and
/// bench wall-clock columns both read it, so "seconds" means the same
/// thing in a run report and a `BENCH_*.json` row.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Record kind discriminant; `name()` is the JSONL `kind` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Span,
    Counter,
    Gauge,
    Event,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Span => "span",
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Event => "event",
        }
    }
}

/// One telemetry record. `ts_us` is microseconds since the recorder's
/// epoch (the only nondeterministic field besides span durations); the
/// value reuses the bench harness's typed [`Cell`] so sink emission can
/// never re-infer a type from string shape.
#[derive(Clone, Debug)]
pub struct Record {
    pub ts_us: u64,
    pub kind: Kind,
    pub name: String,
    pub value: Cell,
}

// ---------------------------------------------------------------------------
// The sink trait and its three implementations
// ---------------------------------------------------------------------------

/// One telemetry sink. Implementations must be cheap and lock-scoped:
/// `emit` is called from the leader thread of parallel sections and from
/// per-job service workers concurrently.
pub trait Sink: Send + Sync {
    fn emit(&self, rec: &Record);
}

/// The no-op sink: every record is discarded. [`Recorder::off`] is the
/// allocation-free form of the same stance; this type exists so the
/// record path itself (timestamping + fan-out, no aggregation, no I/O)
/// can be measured in `benches/obs_overhead.rs`.
pub struct NullRecorder;

impl Sink for NullRecorder {
    fn emit(&self, _rec: &Record) {}
}

#[derive(Clone, Debug, Default)]
struct SpanAgg {
    count: u64,
    total_s: f64,
    max_s: f64,
}

#[derive(Clone, Debug, Default)]
struct GaugeAgg {
    count: u64,
    last: f64,
}

#[derive(Clone, Debug, Default)]
struct EventAgg {
    count: u64,
    tail: Vec<String>,
}

#[derive(Debug, Default)]
struct Summary {
    spans: BTreeMap<String, SpanAgg>,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, GaugeAgg>,
    events: BTreeMap<String, EventAgg>,
}

/// In-memory aggregation: spans fold to count/total/max seconds, counters
/// sum, gauges keep their last value (cumulative quantities — e.g. auto
/// choice counts — are re-gauged each step, so "last" is the total),
/// events count with a capped payload tail.
#[derive(Default)]
pub struct SummaryRecorder {
    agg: Mutex<Summary>,
}

impl SummaryRecorder {
    pub fn new() -> SummaryRecorder {
        SummaryRecorder::default()
    }
}

impl Sink for SummaryRecorder {
    fn emit(&self, rec: &Record) {
        let mut agg = self.agg.lock().expect("summary lock");
        match rec.kind {
            Kind::Span => {
                let secs = match rec.value {
                    Cell::F64(x) => x,
                    _ => return,
                };
                let e = agg.spans.entry(rec.name.clone()).or_default();
                e.count += 1;
                e.total_s += secs;
                e.max_s = e.max_s.max(secs);
            }
            Kind::Counter => {
                let delta = match rec.value {
                    Cell::U64(u) => u,
                    _ => return,
                };
                *agg.counters.entry(rec.name.clone()).or_default() += delta;
            }
            Kind::Gauge => {
                let v = match rec.value {
                    Cell::F64(x) => x,
                    Cell::U64(u) => u as f64,
                    _ => return,
                };
                let e = agg.gauges.entry(rec.name.clone()).or_default();
                e.count += 1;
                e.last = v;
            }
            Kind::Event => {
                let s = match &rec.value {
                    Cell::Str(s) => s.clone(),
                    other => json_value(other),
                };
                let e = agg.events.entry(rec.name.clone()).or_default();
                e.count += 1;
                if e.tail.len() < EVENT_TAIL_CAP {
                    e.tail.push(s);
                }
            }
        }
    }
}

/// Append-only per-record trace file. Lines are written through one
/// buffered writer behind a mutex (jobs from many worker threads
/// interleave whole lines, never bytes) and flushed on drop or via
/// [`Recorder::flush`].
pub struct JsonlRecorder {
    path: PathBuf,
    out: Mutex<BufWriter<File>>,
}

impl JsonlRecorder {
    /// Create (truncate) the trace file at `path`.
    pub fn create(path: &Path) -> Result<JsonlRecorder> {
        let file = File::create(path)
            .with_context(|| format!("create metrics trace `{}`", path.display()))?;
        Ok(JsonlRecorder { path: path.to_path_buf(), out: Mutex::new(BufWriter::new(file)) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn flush(&self) {
        self.out.lock().expect("jsonl lock").flush().ok();
    }
}

impl Sink for JsonlRecorder {
    fn emit(&self, rec: &Record) {
        let line = format!(
            "{{\"ts\": {}, \"kind\": \"{}\", \"name\": \"{}\", \"value\": {}}}\n",
            rec.ts_us,
            rec.kind.name(),
            json_escape(&rec.name),
            json_value(&rec.value),
        );
        self.out.lock().expect("jsonl lock").write_all(line.as_bytes()).ok();
    }
}

impl Drop for JsonlRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

// ---------------------------------------------------------------------------
// The Recorder handle
// ---------------------------------------------------------------------------

struct Inner {
    epoch: Instant,
    /// Name prefix, e.g. `"job3."` — per-job metric isolation mirrors the
    /// per-job `DistanceCounter` of `coordinator::jobs` (DESIGN.md §5.2).
    scope: String,
    /// The aggregating sink, kept typed so reports/cells can be read back.
    summary: Option<Arc<SummaryRecorder>>,
    /// The trace sink, kept typed so scopes can share one file.
    trace: Option<Arc<JsonlRecorder>>,
    /// Fan-out list (the [`Sink`] trait objects actually emitted to).
    sinks: Vec<Arc<dyn Sink>>,
}

/// Cheap cloneable telemetry handle (DESIGN.md §2.11). `Recorder::off()`
/// is the default everywhere: no allocation, no clock reads, no-op
/// methods — the instrumented hot paths cost a branch on a `None`.
#[derive(Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder").field("on", &self.is_on()).finish()
    }
}

impl Recorder {
    /// The default: metrics disabled, zero allocation, zero clock reads.
    pub fn off() -> Recorder {
        Recorder { inner: None }
    }

    /// A live recorder that discards every record ([`NullRecorder`]):
    /// timestamps are taken and fan-out runs, nothing is retained. Bench
    /// baseline for the record path; not reachable from run keys.
    pub fn null() -> Recorder {
        Recorder {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                scope: String::new(),
                summary: None,
                trace: None,
                sinks: vec![Arc::new(NullRecorder)],
            })),
        }
    }

    /// In-memory aggregation only (`metrics=summary`).
    pub fn summary() -> Recorder {
        let s = Arc::new(SummaryRecorder::new());
        Recorder {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                scope: String::new(),
                summary: Some(s.clone()),
                trace: None,
                sinks: vec![s],
            })),
        }
    }

    /// Aggregation **plus** an append-only JSONL trace (`metrics=jsonl`).
    pub fn jsonl(path: &Path) -> Result<Recorder> {
        let s = Arc::new(SummaryRecorder::new());
        let j = Arc::new(JsonlRecorder::create(path)?);
        Ok(Recorder {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                scope: String::new(),
                summary: Some(s.clone()),
                trace: Some(j.clone()),
                sinks: vec![s, j],
            })),
        })
    }

    /// Build from the `metrics=` / `metrics_path=` run keys.
    pub fn for_mode(mode: MetricsMode, path: Option<&Path>) -> Result<Recorder> {
        match mode {
            MetricsMode::Off => Ok(Recorder::off()),
            MetricsMode::Summary => Ok(Recorder::summary()),
            MetricsMode::Jsonl => {
                let default = Path::new("bwkm_trace.jsonl");
                Recorder::jsonl(path.unwrap_or(default))
            }
        }
    }

    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    /// Where the JSONL trace is being written, if this recorder has one.
    pub fn trace_path(&self) -> Option<&Path> {
        self.inner.as_ref()?.trace.as_ref().map(|j| j.path())
    }

    /// A scoped child for per-job isolation: fresh summary aggregation
    /// (so this handle's accessors see only its own job, mirroring the
    /// per-job `DistanceCounter`), the **shared** trace file, and every
    /// record name prefixed `job<j>.`. The parent's summary also keeps
    /// receiving the (prefixed) records, so the end-of-run report covers
    /// all jobs — keyed apart by the prefix, never mixed.
    pub fn job_scope(&self, job: usize) -> Recorder {
        let Some(inner) = &self.inner else {
            return Recorder::off();
        };
        let s = inner.summary.as_ref().map(|_| Arc::new(SummaryRecorder::new()));
        let mut sinks: Vec<Arc<dyn Sink>> = Vec::new();
        if let Some(s) = &s {
            sinks.push(s.clone());
        }
        if let Some(parent) = &inner.summary {
            sinks.push(parent.clone());
        }
        if let Some(j) = &inner.trace {
            sinks.push(j.clone());
        }
        if sinks.is_empty() {
            sinks.push(Arc::new(NullRecorder));
        }
        Recorder {
            inner: Some(Arc::new(Inner {
                epoch: inner.epoch,
                scope: format!("{}job{}.", inner.scope, job),
                summary: s,
                trace: inner.trace.clone(),
                sinks,
            })),
        }
    }

    fn record(&self, kind: Kind, name: &str, value: Cell, ts_us: u64) {
        let Some(inner) = &self.inner else { return };
        let name =
            if inner.scope.is_empty() { name.to_string() } else { format!("{}{name}", inner.scope) };
        let rec = Record { ts_us, kind, name, value };
        for sink in &inner.sinks {
            sink.emit(&rec);
        }
    }

    fn now_us(&self) -> u64 {
        match &self.inner {
            Some(inner) => inner.epoch.elapsed().as_micros() as u64,
            None => 0,
        }
    }

    /// Open a phase span; the RAII guard records its wall-clock duration
    /// on drop. When the recorder is off, no clock reading is taken.
    /// Spans nest lexically (outer BWKM iteration → Lloyd step →
    /// per-pass chunk I/O), and the trace keeps them apart by name.
    pub fn span(&self, name: &'static str) -> Span {
        if self.inner.is_none() {
            return Span { rec: None };
        }
        Span { rec: Some((self.clone(), name, self.now_us(), Stopwatch::start())) }
    }

    /// Record an already-measured span duration. For sections that can't
    /// use the RAII [`Recorder::span`] guard because the time is
    /// *accumulated* across interleaved slices — e.g. the leader's
    /// per-pass chunk-read vs. worker-compute split in
    /// `coordinator::streaming::ChunkCrew`, where read and compute
    /// alternate per chunk but report as two per-pass spans.
    pub fn span_s(&self, name: &str, secs: f64) {
        if self.inner.is_none() {
            return;
        }
        self.record(Kind::Span, name, Cell::F64(secs), self.now_us());
    }

    /// Add `delta` to a monotone counter (summed in the summary).
    pub fn counter(&self, name: &str, delta: u64) {
        if self.inner.is_none() {
            return;
        }
        self.record(Kind::Counter, name, Cell::U64(delta), self.now_us());
    }

    /// Set a gauge (last-value-wins in the summary).
    pub fn gauge(&self, name: &str, value: f64) {
        if self.inner.is_none() {
            return;
        }
        self.record(Kind::Gauge, name, Cell::F64(value), self.now_us());
    }

    /// Integer-valued gauge: recorded as `Cell::U64` in the trace so the
    /// JSON stays integral; aggregated as a gauge (last value wins).
    pub fn gauge_u64(&self, name: &str, value: u64) {
        if self.inner.is_none() {
            return;
        }
        self.record(Kind::Gauge, name, Cell::U64(value), self.now_us());
    }

    /// Record a discrete event with a string payload.
    pub fn event(&self, name: &str, detail: &str) {
        if self.inner.is_none() {
            return;
        }
        self.record(Kind::Event, name, Cell::Str(detail.to_string()), self.now_us());
    }

    /// Flush the JSONL sink (a no-op for the others).
    pub fn flush(&self) {
        if let Some(inner) = &self.inner {
            if let Some(j) = &inner.trace {
                j.flush();
            }
        }
    }

    fn with_agg<T>(&self, f: impl FnOnce(&Summary) -> T) -> Option<T> {
        let summary = self.inner.as_ref()?.summary.as_ref()?;
        let agg = summary.agg.lock().expect("summary lock");
        Some(f(&agg))
    }

    /// The aggregation key `name` lands under in this recorder's own
    /// summary: records are scoped *before* they reach any sink, so a
    /// `job_scope` child's accessors must look up the prefixed name.
    fn scoped(&self, name: &str) -> String {
        match &self.inner {
            Some(inner) if !inner.scope.is_empty() => format!("{}{name}", inner.scope),
            _ => name.to_string(),
        }
    }

    /// Summed total of a counter, by unscoped name within this recorder's
    /// own scope (test/report accessor).
    pub fn counter_total(&self, name: &str) -> Option<u64> {
        let key = self.scoped(name);
        self.with_agg(|a| a.counters.get(&key).copied()).flatten()
    }

    /// Last value of a gauge (test/report accessor).
    pub fn gauge_last(&self, name: &str) -> Option<f64> {
        let key = self.scoped(name);
        self.with_agg(|a| a.gauges.get(&key).map(|g| g.last)).flatten()
    }

    /// `(count, total seconds)` of a span (test/report accessor).
    pub fn span_stats(&self, name: &str) -> Option<(u64, f64)> {
        let key = self.scoped(name);
        self.with_agg(|a| a.spans.get(&key).map(|s| (s.count, s.total_s))).flatten()
    }

    /// `(count, retained payload tail)` of an event (test/report accessor).
    pub fn event_stats(&self, name: &str) -> Option<(u64, Vec<String>)> {
        let key = self.scoped(name);
        self.with_agg(|a| a.events.get(&key).map(|e| (e.count, e.tail.clone()))).flatten()
    }

    /// Human-readable run report: one aligned line per metric, grouped
    /// spans → counters → gauges → events. Span timings are wall-clock
    /// and therefore nondeterministic; everything else is pinned by the
    /// conformance suite.
    pub fn report(&self) -> Vec<String> {
        self.with_agg(|a| {
            let mut out = Vec::new();
            for (name, s) in &a.spans {
                out.push(format!(
                    "span    {name:<32} n={:<6} total={:.3}s max={:.3}s",
                    s.count, s.total_s, s.max_s
                ));
            }
            for (name, total) in &a.counters {
                out.push(format!("counter {name:<32} total={total}"));
            }
            for (name, g) in &a.gauges {
                out.push(format!("gauge   {name:<32} n={:<6} last={:.6}", g.count, g.last));
            }
            for (name, e) in &a.events {
                let last = e.tail.last().map(String::as_str).unwrap_or("");
                out.push(format!("event   {name:<32} n={:<6} last={last}", e.count));
            }
            out
        })
        .unwrap_or_default()
    }

    /// The summary as `BENCH_`-style typed rows (one row per metric) for
    /// [`crate::bench::harness::write_bench_json_to`].
    pub fn summary_rows(&self) -> Vec<Vec<(String, Cell)>> {
        self.with_agg(|a| {
            let mut rows = Vec::new();
            let key = |k: &str| k.to_string();
            for (name, s) in &a.spans {
                rows.push(vec![
                    (key("kind"), Cell::from("span")),
                    (key("name"), Cell::from(name.clone())),
                    (key("n"), Cell::from(s.count)),
                    (key("total_s"), Cell::from(s.total_s)),
                    (key("max_s"), Cell::from(s.max_s)),
                ]);
            }
            for (name, total) in &a.counters {
                rows.push(vec![
                    (key("kind"), Cell::from("counter")),
                    (key("name"), Cell::from(name.clone())),
                    (key("total"), Cell::from(*total)),
                ]);
            }
            for (name, g) in &a.gauges {
                rows.push(vec![
                    (key("kind"), Cell::from("gauge")),
                    (key("name"), Cell::from(name.clone())),
                    (key("n"), Cell::from(g.count)),
                    (key("last"), Cell::from(g.last)),
                ]);
            }
            for (name, e) in &a.events {
                let last = e.tail.last().cloned().unwrap_or_default();
                rows.push(vec![
                    (key("kind"), Cell::from("event")),
                    (key("name"), Cell::from(name.clone())),
                    (key("n"), Cell::from(e.count)),
                    (key("last"), Cell::from(last)),
                ]);
            }
            rows
        })
        .unwrap_or_default()
    }
}

/// RAII span guard from [`Recorder::span`]; records duration on drop.
/// Inert (no clock reads, no drop work) when the recorder is off.
pub struct Span {
    rec: Option<(Recorder, &'static str, u64, Stopwatch)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((rec, name, ts_us, watch)) = self.rec.take() {
            rec.record(Kind::Span, name, Cell::F64(watch.elapsed_s()), ts_us);
        }
    }
}

/// Bridges exact distance bills (DESIGN.md §2.4) into counter deltas by
/// **reading** the shared [`DistanceCounter`] — never writing it, so the
/// bill a run reports is bit-identical with metrics on or off.
pub struct BillBridge {
    last: u64,
}

impl BillBridge {
    pub fn new(counter: &DistanceCounter) -> BillBridge {
        BillBridge { last: counter.get() }
    }

    /// Record the bill growth since the previous tick as `name`.
    pub fn tick(&mut self, rec: &Recorder, name: &str, counter: &DistanceCounter) {
        let now = counter.get();
        rec.counter(name, now.saturating_sub(self.last));
        self.last = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bwkm_obs_{}_{name}", std::process::id()))
    }

    #[test]
    fn off_recorder_is_inert_and_free() {
        let rec = Recorder::off();
        assert!(!rec.is_on());
        let _s = rec.span("never");
        rec.counter("c", 1);
        rec.gauge("g", 1.0);
        rec.event("e", "x");
        rec.flush();
        assert_eq!(rec.counter_total("c"), None);
        assert_eq!(rec.report(), Vec::<String>::new());
        assert!(rec.summary_rows().is_empty());
        assert!(rec.trace_path().is_none());
    }

    #[test]
    fn null_sink_discards_but_runs_the_record_path() {
        let rec = Recorder::null();
        assert!(rec.is_on());
        {
            let _s = rec.span("phase");
        }
        rec.counter("c", 3);
        // NullRecorder aggregates nothing: accessors see no summary.
        assert_eq!(rec.counter_total("c"), None);
        assert!(rec.summary_rows().is_empty());
    }

    #[test]
    fn summary_aggregates_by_kind() {
        let rec = Recorder::summary();
        {
            let _s = rec.span("phase");
        }
        {
            let _s = rec.span("phase");
        }
        rec.span_s("io", 1.5);
        rec.span_s("io", 0.5);
        rec.counter("bill", 10);
        rec.counter("bill", 32);
        rec.gauge("rate", 0.25);
        rec.gauge("rate", 0.75);
        rec.gauge_u64("rounds", 5);
        rec.event("stop", "Budget");
        rec.event("stop", "MaxIters");

        assert_eq!(rec.counter_total("bill"), Some(42));
        assert_eq!(rec.gauge_last("rate"), Some(0.75));
        assert_eq!(rec.gauge_last("rounds"), Some(5.0));
        let (n, total) = rec.span_stats("phase").unwrap();
        assert_eq!(n, 2);
        assert!(total >= 0.0);
        let (n, total) = rec.span_stats("io").unwrap();
        assert_eq!(n, 2);
        assert_eq!(total, 2.0);
        let (n, tail) = rec.event_stats("stop").unwrap();
        assert_eq!(n, 2);
        assert_eq!(tail, vec!["Budget".to_string(), "MaxIters".to_string()]);

        // Report + typed rows cover every metric exactly once.
        assert_eq!(rec.report().len(), 6);
        let rows = rec.summary_rows();
        assert_eq!(rows.len(), 6);
        for row in &rows {
            assert_eq!(row[0].0, "kind");
            assert_eq!(row[1].0, "name");
        }
    }

    #[test]
    fn event_tail_caps_but_count_stays_exact() {
        let rec = Recorder::summary();
        for i in 0..(EVENT_TAIL_CAP + 9) {
            rec.event("e", &format!("v{i}"));
        }
        let (n, tail) = rec.event_stats("e").unwrap();
        assert_eq!(n, (EVENT_TAIL_CAP + 9) as u64);
        assert_eq!(tail.len(), EVENT_TAIL_CAP);
    }

    #[test]
    fn jsonl_lines_have_the_pinned_schema() {
        let path = tmp("schema.jsonl");
        {
            let rec = Recorder::jsonl(&path).unwrap();
            {
                let _s = rec.span("bwkm.lloyd");
            }
            rec.counter("bwkm.distances", 7);
            rec.gauge("auto.prune_rate", 0.5);
            rec.gauge_u64("stream.pass", 3);
            rec.event("bwkm.stop", "AccuracyBound");
            rec.flush();
            assert_eq!(rec.trace_path(), Some(path.as_path()));
            // The jsonl recorder still aggregates: summary available too.
            assert_eq!(rec.counter_total("bwkm.distances"), Some(7));
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in &lines {
            assert!(line.starts_with("{\"ts\": "), "line {line}");
            assert!(line.ends_with('}'), "line {line}");
            for field in ["\"ts\": ", "\"kind\": \"", "\"name\": \"", "\"value\": "] {
                assert!(line.contains(field), "missing {field} in {line}");
            }
        }
        assert!(lines[1].contains("\"kind\": \"counter\""));
        assert!(lines[1].contains("\"value\": 7"));
        assert!(lines[2].contains("\"value\": 0.5"));
        assert!(lines[3].contains("\"value\": 3"));
        assert!(lines[4].contains("\"value\": \"AccuracyBound\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn job_scope_isolates_summaries_and_shares_the_trace() {
        let path = tmp("scope.jsonl");
        {
            let rec = Recorder::jsonl(&path).unwrap();
            let j0 = rec.job_scope(0);
            let j1 = rec.job_scope(1);
            j0.counter("bill", 10);
            j1.counter("bill", 20);
            // Isolation: each scope aggregates only its own records.
            assert_eq!(j0.counter_total("bill"), Some(10));
            assert_eq!(j1.counter_total("bill"), Some(20));
            // The parent still sees everything, keyed apart by prefix —
            // never under the unscoped name.
            assert_eq!(rec.counter_total("bill"), None);
            assert_eq!(rec.counter_total("job0.bill"), Some(10));
            assert_eq!(rec.counter_total("job1.bill"), Some(20));
            rec.flush();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        // Shared trace: both jobs' records land in one file, scoped names.
        assert!(text.contains("\"name\": \"job0.bill\""));
        assert!(text.contains("\"name\": \"job1.bill\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bill_bridge_reads_the_counter_without_writing_it() {
        let counter = DistanceCounter::new();
        counter.add(100);
        let rec = Recorder::summary();
        let mut bridge = BillBridge::new(&counter);
        counter.add(42);
        bridge.tick(&rec, "bill", &counter);
        counter.add(8);
        bridge.tick(&rec, "bill", &counter);
        assert_eq!(rec.counter_total("bill"), Some(50));
        // Observation did not perturb the bill itself.
        assert_eq!(counter.get(), 150);
    }

    #[test]
    fn metrics_mode_parses_and_rejects() {
        assert_eq!(MetricsMode::parse("off").unwrap(), MetricsMode::Off);
        assert_eq!(MetricsMode::parse("summary").unwrap(), MetricsMode::Summary);
        assert_eq!(MetricsMode::parse("jsonl").unwrap(), MetricsMode::Jsonl);
        assert!(MetricsMode::parse("trace").is_err());
        assert_eq!(MetricsMode::default(), MetricsMode::Off);
        assert_eq!(MetricsMode::Jsonl.name(), "jsonl");
    }

    #[test]
    fn for_mode_builds_the_right_recorder() {
        assert!(!Recorder::for_mode(MetricsMode::Off, None).unwrap().is_on());
        let s = Recorder::for_mode(MetricsMode::Summary, None).unwrap();
        assert!(s.is_on() && s.trace_path().is_none());
        let path = tmp("mode.jsonl");
        let j = Recorder::for_mode(MetricsMode::Jsonl, Some(&path)).unwrap();
        assert_eq!(j.trace_path(), Some(path.as_path()));
        drop(j);
        std::fs::remove_file(&path).ok();
    }
}
