//! Geometric substrate: flat row-major point buffers, axis-aligned bounding
//! boxes (the paper's hyperrectangular *blocks*, §2 footnote 9), diagonals
//! and longest-side splits.
//!
//! Points live in `&[f64]` row-major buffers (`n * d`); all algorithms index
//! rows as `&data[i*d..(i+1)*d]`, keeping the hot loops allocation-free.

/// Squared Euclidean distance between two points. This is *the* distance
/// computation the paper counts; callers must tick their
/// [`crate::metrics::DistanceCounter`] once per call on accounted paths.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // Unrolled-friendly form; LLVM vectorizes this cleanly.
    let mut acc = 0.0;
    for i in 0..a.len() {
        let t = a[i] - b[i];
        acc += t * t;
    }
    acc
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    sq_dist(a, b).sqrt()
}

/// Axis-aligned bounding box (a *block* of a spatial partition).
#[derive(Clone, Debug, PartialEq)]
pub struct BBox {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl BBox {
    /// Degenerate box at a single point.
    pub fn at(p: &[f64]) -> BBox {
        BBox { lo: p.to_vec(), hi: p.to_vec() }
    }

    /// Smallest bounding box of the rows of `data` selected by `members`
    /// (all rows when `members` is None). Returns None for empty input.
    /// Two monomorphic loops — this sits on the split/refresh hot path,
    /// where a boxed iterator would cost an allocation plus a virtual
    /// call per row.
    pub fn of(data: &[f64], d: usize, members: Option<&[u32]>) -> Option<BBox> {
        match members {
            Some(m) => {
                let (&first, rest) = m.split_first()?;
                let first = first as usize;
                let mut bb = BBox::at(&data[first * d..(first + 1) * d]);
                for &i in rest {
                    let i = i as usize;
                    bb.expand(&data[i * d..(i + 1) * d]);
                }
                Some(bb)
            }
            None => {
                let n = data.len() / d;
                if n == 0 {
                    return None;
                }
                let mut bb = BBox::at(&data[..d]);
                for i in 1..n {
                    bb.expand(&data[i * d..(i + 1) * d]);
                }
                Some(bb)
            }
        }
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Grow to include `p`.
    pub fn expand(&mut self, p: &[f64]) {
        for j in 0..self.lo.len() {
            if p[j] < self.lo[j] {
                self.lo[j] = p[j];
            }
            if p[j] > self.hi[j] {
                self.hi[j] = p[j];
            }
        }
    }

    /// Length of the diagonal, `l_B` in the paper (Def. 3).
    pub fn diagonal(&self) -> f64 {
        sq_dist(&self.lo, &self.hi).sqrt()
    }

    /// Index and length of the longest side.
    pub fn longest_side(&self) -> (usize, f64) {
        let mut best = (0, f64::NEG_INFINITY);
        for j in 0..self.lo.len() {
            let len = self.hi[j] - self.lo[j];
            if len > best.1 {
                best = (j, len);
            }
        }
        best
    }

    /// Split plane of the paper's cutting rule: middle of the longest side.
    /// Returns (axis, threshold).
    pub fn split_plane(&self) -> (usize, f64) {
        let (axis, _) = self.longest_side();
        (axis, 0.5 * (self.lo[axis] + self.hi[axis]))
    }

    /// Closed containment test.
    pub fn contains(&self, p: &[f64]) -> bool {
        (0..self.lo.len()).all(|j| p[j] >= self.lo[j] && p[j] <= self.hi[j])
    }

    /// Center of the box.
    pub fn center(&self) -> Vec<f64> {
        (0..self.lo.len()).map(|j| 0.5 * (self.lo[j] + self.hi[j])).collect()
    }

    /// Volume (product of side lengths).
    pub fn volume(&self) -> f64 {
        (0..self.lo.len()).map(|j| self.hi[j] - self.lo[j]).product()
    }
}

/// Mean of selected rows (center of mass of a block's instances).
pub fn mean_of(data: &[f64], d: usize, members: &[u32]) -> Vec<f64> {
    let mut m = vec![0.0; d];
    for &i in members {
        let row = &data[i as usize * d..(i as usize + 1) * d];
        for j in 0..d {
            m[j] += row[j];
        }
    }
    let inv = 1.0 / members.len() as f64;
    for v in &mut m {
        *v *= inv;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn sq_dist_basics() {
        assert_eq!(sq_dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
        assert_eq!(sq_dist(&[1.5], &[1.5]), 0.0);
    }

    #[test]
    fn bbox_of_points() {
        let data = [0.0, 1.0, 2.0, -1.0, 1.0, 3.0];
        let bb = BBox::of(&data, 2, None).unwrap();
        assert_eq!(bb.lo, vec![0.0, -1.0]);
        assert_eq!(bb.hi, vec![2.0, 3.0]);
        assert!((bb.diagonal() - (4.0f64 + 16.0).sqrt()).abs() < 1e-12);
        assert_eq!(bb.longest_side(), (1, 4.0));
        assert_eq!(BBox::of(&data, 2, Some(&[])), None);
    }

    #[test]
    fn bbox_members_subset() {
        let data = [0.0, 0.0, 10.0, 10.0, 5.0, 5.0];
        let bb = BBox::of(&data, 2, Some(&[0, 2])).unwrap();
        assert_eq!(bb.hi, vec![5.0, 5.0]);
    }

    #[test]
    fn split_plane_halves_longest_side() {
        let bb = BBox { lo: vec![0.0, 0.0], hi: vec![4.0, 1.0] };
        assert_eq!(bb.split_plane(), (0, 2.0));
    }

    #[test]
    fn mean_of_members() {
        let data = [0.0, 0.0, 2.0, 4.0, 100.0, 100.0];
        assert_eq!(mean_of(&data, 2, &[0, 1]), vec![1.0, 2.0]);
    }

    #[test]
    fn prop_bbox_contains_all_members_and_mean() {
        prop::check("bbox-contains", 50, |g| {
            let n = g.int(1, 80);
            let d = g.int(1, 6);
            let data = g.cloud(n, d, 5.0);
            let members: Vec<u32> = (0..n as u32).collect();
            let bb = BBox::of(&data, d, Some(&members)).unwrap();
            for i in 0..n {
                assert!(bb.contains(&data[i * d..(i + 1) * d]));
            }
            // Center of mass lies in the (convex) box — Thm 1's key fact.
            let m = mean_of(&data, d, &members);
            assert!(bb.contains(&m) || m.iter().enumerate().all(|(j, &v)| {
                v >= bb.lo[j] - 1e-12 && v <= bb.hi[j] + 1e-12
            }));
        });
    }

    #[test]
    fn prop_diagonal_bounds_pairwise_distance() {
        prop::check("diag-bound", 50, |g| {
            let n = g.int(2, 60);
            let d = g.int(1, 5);
            let data = g.cloud(n, d, 3.0);
            let bb = BBox::of(&data, d, None).unwrap();
            let l = bb.diagonal();
            for i in 0..n.min(10) {
                for j in 0..n {
                    let dd = dist(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]);
                    assert!(dd <= l + 1e-9, "pair dist {dd} > diagonal {l}");
                }
            }
        });
    }
}
