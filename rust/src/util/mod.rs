//! Shared substrates: deterministic PRNG, mini property-test harness,
//! and small formatting helpers used by the CLI/bench output.

pub mod pool;
pub mod prop;
pub mod rng;

pub use rng::{Cdf, Rng};

/// Format a count with thousands separators (bench tables).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Mean and (population) std of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_count_groups() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
    }
}
