//! Process-wide persistent worker pool (DESIGN.md §2.12).
//!
//! Every parallel surface in the crate — [`Sharded`](crate::kmeans::Sharded),
//! the CLI `threads>1` coordinator path, the job scheduler
//! (`coordinator/jobs.rs`) and the streaming `ChunkCrew` — used to stand up
//! its own scoped OS threads per call. On the warm Lloyd loop that means a
//! spawn/join pair *per iteration*, which dominates wall-clock once the
//! distance kernel itself is cheap. This module replaces all of that with
//! one set of long-lived workers, parked on a condvar between jobs.
//!
//! ## Contract (DESIGN.md §2.12)
//!
//! * **Single published slot.** At most one job occupies the pool. A
//!   [`WorkerPool::run`] that finds the slot busy — including every
//!   re-entrant call from inside a pool task — executes all shards inline
//!   on the caller. Inline execution is the *same code on the same shard
//!   indices in the same order*, so results are bit-identical; only timing
//!   changes. This rule is also the oversubscription policy: when a
//!   sharded job runs under the job scheduler, the inner shards degrade to
//!   inline instead of competing with the outer workers for cores.
//! * **Shard indices are determinism keys, not threads.** A job publishes
//!   `shards` logical shards; callers choose `shards` (e.g. the CLI
//!   `threads=` value) and the split rule
//!   ([`shard_ranges`](crate::kmeans::assign::shard_ranges)) depends only
//!   on it.
//!   Physical concurrency is capped by the machine-sized pool no matter
//!   what `shards` is.
//! * **Leader participates and joins.** [`WorkerPool::run`] claims shards
//!   alongside the workers and returns only after every shard has
//!   finished, so borrowing the task by reference is sound even though the
//!   workers are `'static` threads (the task pointer is lifetime-erased
//!   internally and never outlives the call).
//! * **Panics propagate.** A panicking shard is caught on the worker, the
//!   job drains, and the first payload is re-thrown on the leader — the
//!   pool itself survives.
//! * **No allocation on the leader path.** Publishing, claiming and
//!   joining touch only the mutex/condvars and in-place state, so a warm
//!   caller with pre-sized output buffers stays allocation-free
//!   (pinned by `tests/pool_conformance.rs`).
//!
//! The [`WorkerPool::defer`]/[`WorkerPool::wait`] pair exposes the same
//! slot without leader participation until `wait`, which is what the
//! streaming crew's read-ahead overlap needs (read chunk N+1 while the
//! pool chews chunk N).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, Once, OnceLock};
use std::time::Instant;

use crate::obs::Recorder;

/// A unit of pool work: `run(shard)` is called exactly once for every
/// shard index in `0..shards`, possibly concurrently and in any order.
/// Implementations must make shard writes disjoint (each shard owns its
/// slice of any shared output) — the pool guarantees each index is
/// claimed exactly once.
pub trait PoolTask: Sync {
    fn run(&self, shard: usize);
}

/// Adapter: any `Fn(usize) + Sync` closure as a [`PoolTask`].
pub struct FnTask<F: Fn(usize) + Sync>(pub F);

impl<F: Fn(usize) + Sync> PoolTask for FnTask<F> {
    fn run(&self, shard: usize) {
        (self.0)(shard)
    }
}

/// A raw pointer that may cross threads. Used by pool tasks to hand each
/// shard a base pointer into a shared output buffer; soundness is the
/// *caller's* obligation (disjoint per-shard regions — the pool claims
/// each shard index exactly once, so indexing by shard is enough).
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Lifetime-erased fat pointer to the published task. Only ever
/// dereferenced between publish and the leader's join, which the borrow
/// in [`WorkerPool::run`]/[`WorkerPool::wait`] outlives by construction.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn PoolTask + 'static));

unsafe impl Send for TaskPtr {}

struct Job {
    task: TaskPtr,
    shards: usize,
    /// Next shard index to claim.
    next: usize,
    /// Shards claimed but not yet finished.
    active: usize,
    published: Instant,
    /// First panic payload from any shard, re-thrown by the leader.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

#[derive(Default)]
struct State {
    job: Option<Job>,
}

/// Cumulative pool telemetry (atomics — never on the result path).
#[derive(Default)]
struct Stats {
    jobs: AtomicU64,
    shards: AtomicU64,
    inline_shards: AtomicU64,
    busy_ns: AtomicU64,
    queue_wait_ns: AtomicU64,
}

/// The persistent worker pool. One process-wide instance lives behind
/// [`global`]; tests may `Box::leak` private instances.
pub struct WorkerPool {
    state: Mutex<State>,
    /// Signalled when a job (or more claimable shards) appears.
    work: Condvar,
    /// Signalled when a job's last active shard finishes.
    done: Condvar,
    workers: usize,
    spawn: Once,
    stats: Stats,
}

impl WorkerPool {
    /// A pool with `workers` background threads (not yet spawned — they
    /// start lazily on first [`run`](Self::run)/[`defer`](Self::defer)).
    /// `workers == 0` is valid: every job runs inline on its caller.
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool {
            state: Mutex::new(State::default()),
            work: Condvar::new(),
            done: Condvar::new(),
            workers,
            spawn: Once::new(),
            stats: Stats::default(),
        }
    }

    /// Background worker count (the leader adds one more lane while it
    /// participates in a job).
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn ensure_spawned(&'static self) {
        self.spawn.call_once(|| {
            for i in 0..self.workers {
                std::thread::Builder::new()
                    .name(format!("bwkm-pool-{i}"))
                    .spawn(move || self.worker_loop())
                    .expect("failed to spawn pool worker");
            }
        });
    }

    fn worker_loop(&'static self) {
        let mut guard = self.state.lock().expect("pool state poisoned");
        loop {
            let claim = match guard.job.as_mut() {
                Some(j) if j.next < j.shards => {
                    let s = j.next;
                    j.next += 1;
                    j.active += 1;
                    let wait_ns = j.published.elapsed().as_nanos() as u64;
                    Some((j.task, s, wait_ns))
                }
                _ => None,
            };
            match claim {
                Some((task, shard, wait_ns)) => {
                    drop(guard);
                    self.stats.queue_wait_ns.fetch_add(wait_ns, Ordering::Relaxed);
                    let t0 = Instant::now();
                    let r = catch_unwind(AssertUnwindSafe(|| unsafe { &*task.0 }.run(shard)));
                    self.stats.busy_ns.fetch_add(
                        t0.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    self.stats.shards.fetch_add(1, Ordering::Relaxed);
                    guard = self.state.lock().expect("pool state poisoned");
                    let j = guard.job.as_mut().expect("job cleared while shards active");
                    j.active -= 1;
                    if let Err(p) = r {
                        j.panic.get_or_insert(p);
                    }
                    if j.next >= j.shards && j.active == 0 {
                        self.done.notify_all();
                    }
                }
                None => {
                    guard = self.work.wait(guard).expect("pool state poisoned");
                }
            }
        }
    }

    fn run_inline(&self, shards: usize, task: &dyn PoolTask) {
        for s in 0..shards {
            task.run(s);
        }
        self.stats.inline_shards.fetch_add(shards as u64, Ordering::Relaxed);
    }

    fn publish(&'static self, shards: usize, task: &dyn PoolTask) -> bool {
        self.ensure_spawned();
        let mut guard = self.state.lock().expect("pool state poisoned");
        if guard.job.is_some() {
            return false; // busy (possibly re-entrant): caller degrades inline
        }
        // Erase the task's lifetime for the 'static workers. Sound: the
        // slot is cleared (and all shards joined) before the publishing
        // call returns, so the pointer never outlives the borrow.
        let task: TaskPtr = unsafe {
            TaskPtr(std::mem::transmute::<*const dyn PoolTask, *const (dyn PoolTask + 'static)>(
                task as *const dyn PoolTask,
            ))
        };
        guard.job = Some(Job {
            task,
            shards,
            next: 0,
            active: 0,
            published: Instant::now(),
            panic: None,
        });
        self.stats.jobs.fetch_add(1, Ordering::Relaxed);
        self.work.notify_all();
        true
    }

    /// Claim shards alongside the workers until none remain, then block
    /// until the last active shard finishes, clear the slot and re-throw
    /// any shard panic. Only the publisher calls this.
    fn join_published(&self) {
        let mut guard = self.state.lock().expect("pool state poisoned");
        loop {
            let claim = match guard.job.as_mut() {
                Some(j) if j.next < j.shards => {
                    let s = j.next;
                    j.next += 1;
                    j.active += 1;
                    Some((j.task, s))
                }
                _ => None,
            };
            match claim {
                Some((task, shard)) => {
                    drop(guard);
                    let t0 = Instant::now();
                    let r = catch_unwind(AssertUnwindSafe(|| unsafe { &*task.0 }.run(shard)));
                    self.stats.busy_ns.fetch_add(
                        t0.elapsed().as_nanos() as u64,
                        Ordering::Relaxed,
                    );
                    self.stats.shards.fetch_add(1, Ordering::Relaxed);
                    guard = self.state.lock().expect("pool state poisoned");
                    let j = guard.job.as_mut().expect("job cleared while shards active");
                    j.active -= 1;
                    if let Err(p) = r {
                        j.panic.get_or_insert(p);
                    }
                }
                None => {
                    let j = guard.job.as_ref().expect("join without a published job");
                    if j.active == 0 {
                        break;
                    }
                    guard = self.done.wait(guard).expect("pool state poisoned");
                }
            }
        }
        let job = guard.job.take().expect("join without a published job");
        drop(guard);
        if let Some(p) = job.panic {
            resume_unwind(p);
        }
    }

    /// Run `task.run(s)` once for every `s in 0..shards`, concurrently
    /// across the pool, and return when all shards are done. Falls back to
    /// inline serial execution (identical results) when the pool is busy,
    /// the call is re-entrant, `shards <= 1` or the pool has no workers.
    pub fn run(&'static self, shards: usize, task: &dyn PoolTask) {
        if shards == 0 {
            return;
        }
        if shards == 1 || self.workers == 0 || !self.publish(shards, task) {
            return self.run_inline(shards, task);
        }
        self.join_published();
    }

    /// Publish a job *without* participating, so the caller can overlap
    /// its own work (the streaming crew's chunk read-ahead) with the
    /// pool's. Returns `false` — and runs **nothing** — when the slot is
    /// busy or the pool has no workers; the caller must then execute the
    /// task itself (inline) instead of calling [`wait`](Self::wait).
    ///
    /// # Safety
    ///
    /// On `true`, the caller must keep `task` (and everything it borrows)
    /// alive and un-moved until the matching [`wait`](Self::wait)
    /// returns, and must call `wait` before publishing anything else.
    pub unsafe fn defer(&'static self, shards: usize, task: &dyn PoolTask) -> bool {
        if shards == 0 || self.workers == 0 {
            return false;
        }
        self.publish(shards, task)
    }

    /// Join a job published with [`defer`](Self::defer): help claim any
    /// unclaimed shards, block until the job drains, re-throw panics.
    pub fn wait(&'static self) {
        self.join_published();
    }

    /// Publish cumulative pool telemetry as `pool.*` gauges (DESIGN.md
    /// §2.11: strictly observational, allocation-free when `rec` is off).
    pub fn record_metrics(&self, rec: &Recorder) {
        if !rec.is_on() {
            return;
        }
        rec.gauge_u64("pool.workers", self.workers as u64);
        rec.gauge_u64("pool.jobs", self.stats.jobs.load(Ordering::Relaxed));
        rec.gauge_u64("pool.shards", self.stats.shards.load(Ordering::Relaxed));
        rec.gauge_u64("pool.inline_shards", self.stats.inline_shards.load(Ordering::Relaxed));
        rec.gauge("pool.busy_s", self.stats.busy_ns.load(Ordering::Relaxed) as f64 * 1e-9);
        rec.gauge(
            "pool.queue_wait_s",
            self.stats.queue_wait_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        );
        let depth = {
            let guard = self.state.lock().expect("pool state poisoned");
            guard.job.as_ref().map_or(0, |j| (j.shards - j.next) as u64)
        };
        rec.gauge_u64("pool.queue_depth", depth);
    }
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool: `available_parallelism - 1` background workers
/// (the leader of any job is the extra lane), spawned lazily on first use.
pub fn global() -> &'static WorkerPool {
    POOL.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool::new(cores.saturating_sub(1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn leaked(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new(workers)))
    }

    #[test]
    fn every_shard_runs_exactly_once() {
        let pool = leaked(3);
        for shards in [1usize, 2, 5, 16, 33] {
            let hits: Vec<AtomicUsize> = (0..shards).map(|_| AtomicUsize::new(0)).collect();
            pool.run(
                shards,
                &FnTask(|s| {
                    hits[s].fetch_add(1, Ordering::Relaxed);
                }),
            );
            for (s, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "shard {s} of {shards}");
            }
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = leaked(0);
        let hits = AtomicUsize::new(0);
        pool.run(4, &FnTask(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        assert_eq!(pool.stats.inline_shards.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn reentrant_run_degrades_inline_and_completes() {
        let pool = leaked(2);
        let outer = AtomicUsize::new(0);
        let inner = AtomicUsize::new(0);
        pool.run(
            4,
            &FnTask(|_| {
                outer.fetch_add(1, Ordering::Relaxed);
                // Nested publish finds the slot busy: inline fallback.
                pool.run(3, &FnTask(|_| {
                    inner.fetch_add(1, Ordering::Relaxed);
                }));
            }),
        );
        assert_eq!(outer.load(Ordering::Relaxed), 4);
        assert_eq!(inner.load(Ordering::Relaxed), 12);
        assert!(pool.stats.inline_shards.load(Ordering::Relaxed) >= 12);
    }

    #[test]
    fn shard_panic_propagates_and_pool_survives() {
        let pool = leaked(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &FnTask(|s| {
                if s == 2 {
                    panic!("shard boom");
                }
            }));
        }));
        let payload = r.expect_err("shard panic must reach the leader");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "shard boom");
        // The slot is clear and the workers are still alive.
        let hits = AtomicUsize::new(0);
        pool.run(8, &FnTask(|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn defer_then_wait_runs_everything() {
        let pool = leaked(2);
        let hits: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
        let task = FnTask(|s: usize| {
            hits[s].fetch_add(1, Ordering::Relaxed);
        });
        // Safety: `task` outlives the wait() below.
        if unsafe { pool.defer(6, &task) } {
            pool.wait();
        } else {
            pool.run_inline(6, &task);
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn stats_accumulate_and_metrics_record() {
        let pool = leaked(2);
        pool.run(4, &FnTask(|_| {}));
        assert_eq!(pool.stats.jobs.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats.shards.load(Ordering::Relaxed), 4);
        let rec = Recorder::summary();
        pool.record_metrics(&rec);
        assert_eq!(rec.gauge_last("pool.shards"), Some(4.0));
        assert_eq!(rec.gauge_last("pool.queue_depth"), Some(0.0));
        // Off recorder: no-op, no panic.
        pool.record_metrics(&Recorder::off());
    }

    #[test]
    fn global_pool_is_machine_sized_and_stable() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(global().workers(), cores.saturating_sub(1));
    }
}
