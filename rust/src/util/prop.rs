//! Minimal property-testing harness (offline replacement for `proptest`).
//!
//! A property is a closure over a deterministic [`Rng`]; `check` runs it
//! for `cases` cases. Every property is seeded from its **name** (an
//! FNV-1a hash mixed per case), so distinct properties explore
//! independent random streams and a named run is reproducible forever;
//! on failure the harness prints both the failing case index and the
//! derived RNG seed, and `PROP_SEED=<case> cargo test <name>` replays
//! exactly that case.
//!
//! This is intentionally tiny: generators are just helper methods on the
//! per-case [`Gen`], and there is no shrinking — failing seeds are printed
//! instead, which has proven sufficient for the numeric invariants tested
//! here (paper Theorems 1, 2, 3, A.1, A.2, the partition invariants and
//! the streaming-conformance pins).

use super::rng::Rng;

/// FNV-1a over the property name: the per-property base seed.
fn name_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The RNG seed of one case of one named property (documented so failure
/// messages and external tooling can re-derive it).
pub fn case_seed(name: &str, case: u64) -> u64 {
    name_seed(name) ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Per-case generator handle.
pub struct Gen {
    pub rng: Rng,
    pub case: u64,
}

impl Gen {
    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.usize(hi - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// Random point cloud: `n` rows, `d` columns, N(0, scale) entries.
    pub fn cloud(&mut self, n: usize, d: usize, scale: f64) -> Vec<f64> {
        (0..n * d).map(|_| self.rng.normal() * scale).collect()
    }

    /// Clustered point cloud: `n` rows around `k` random centers.
    pub fn blobs(&mut self, n: usize, d: usize, k: usize, spread: f64) -> Vec<f64> {
        let centers: Vec<f64> = (0..k * d).map(|_| self.rng.normal() * 10.0).collect();
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            let c = self.rng.usize(k);
            for j in 0..d {
                data.push(centers[c * d + j] + self.rng.normal() * spread);
            }
        }
        data
    }
}

/// Run `body` for `cases` generated cases; panic with the reproducing
/// case index *and* the derived RNG seed on the first failure (assertion
/// panic inside `body`).
pub fn check(name: &str, cases: u64, mut body: impl FnMut(&mut Gen)) {
    // Replay support: PROP_SEED pins a single case (of this property —
    // the name participates in the seed).
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let case: u64 = seed.parse().expect("PROP_SEED must be a u64");
        let mut g = Gen { rng: Rng::new(case_seed(name, case)), case };
        body(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = case_seed(name, case);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (rng seed {seed:#018x}; \
                 replay: PROP_SEED={case}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("trivial", 50, |g| {
            let x = g.f64(0.0, 10.0);
            assert!(x >= 0.0 && x < 10.0);
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn reports_failing_case() {
        check("fails", 50, |g| {
            // Deterministic failure at case 45.
            assert!(g.case < 45, "case={}", g.case);
        });
    }

    #[test]
    fn names_derive_distinct_deterministic_seeds() {
        assert_eq!(case_seed("a", 0), case_seed("a", 0));
        assert_ne!(case_seed("a", 0), case_seed("b", 0));
        assert_ne!(case_seed("a", 0), case_seed("a", 1));
    }

    #[test]
    fn blobs_shape() {
        check("blobs-shape", 10, |g| {
            let n = g.int(1, 50);
            let d = g.int(1, 5);
            let data = g.blobs(n, d, 3, 0.5);
            assert_eq!(data.len(), n * d);
        });
    }
}
