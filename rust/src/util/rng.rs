//! Deterministic PRNG substrate (offline replacement for the `rand` crate).
//!
//! `Rng` is xoshiro256\*\* seeded through SplitMix64 — the standard pairing:
//! SplitMix64 decorrelates arbitrary u64 seeds, xoshiro256\*\* provides the
//! stream. Everything in the repository that needs randomness takes an
//! `&mut Rng`, so every experiment is exactly reproducible from its seed.

/// xoshiro256\*\* generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-worker/per-repetition
    /// seeding without sharing mutable state).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Snapshot of the raw generator state (the model store persists this,
    /// DESIGN.md §5.2). Restoring it with [`Rng::from_state`] continues the
    /// stream bit for bit.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot. The all-zero
    /// state is xoshiro's fixed point and unreachable from any seed, so it
    /// can only come from corrupted persisted state — rejected loudly.
    pub fn from_state(s: [u64; 4]) -> Rng {
        assert!(
            s.iter().any(|&x| x != 0),
            "all-zero xoshiro256** state (corrupted snapshot?)"
        );
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (single value; simple and adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates when k
    /// is large relative to n, rejection otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.usize(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.usize(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Index sampled proportionally to non-negative `weights`.
    /// Returns None if the total weight is not positive/finite.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return Some(i);
            }
        }
        // Floating point slop: return the last positive-weight index.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// Pre-computed cumulative distribution for repeated weighted sampling
/// (binary search per draw — used for sampling-with-replacement loops).
pub struct Cdf {
    cum: Vec<f64>,
    total: f64,
}

impl Cdf {
    pub fn new(weights: &[f64]) -> Option<Cdf> {
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            debug_assert!(w >= 0.0);
            acc += w.max(0.0);
            cum.push(acc);
        }
        if acc > 0.0 && acc.is_finite() {
            Some(Cdf { cum, total: acc })
        } else {
            None
        }
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let target = rng.f64() * self.total;
        match self
            .cum
            .binary_search_by(|c| c.partial_cmp(&target).unwrap())
        {
            Ok(i) => (i + 1).min(self.cum.len() - 1),
            Err(i) => i.min(self.cum.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut rng = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[rng.usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Rng::new(4);
        let w = [0.0, 3.0, 1.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn weighted_index_degenerate() {
        let mut rng = Rng::new(5);
        assert_eq!(rng.weighted_index(&[0.0, 0.0]), None);
        assert_eq!(rng.weighted_index(&[]), None);
        assert_eq!(rng.weighted_index(&[0.0, 2.5]), Some(1));
    }

    #[test]
    fn cdf_matches_weighted_index_distribution() {
        let mut rng = Rng::new(6);
        let w = [1.0, 0.0, 2.0, 7.0];
        let cdf = Cdf::new(&w).unwrap();
        let mut counts = [0usize; 4];
        for _ in 0..50_000 {
            counts[cdf.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!((counts[3] as f64 / counts[0] as f64 - 7.0).abs() < 1.0);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(7);
        for &(n, k) in &[(10, 10), (100, 3), (50, 40)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn state_snapshot_continues_bit_for_bit() {
        let mut a = Rng::new(99);
        for _ in 0..37 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay, "restored stream must continue identically");
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn from_state_rejects_zero_state() {
        Rng::from_state([0; 4]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
