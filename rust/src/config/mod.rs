//! Run configuration: `key = value` files (a TOML subset) plus CLI
//! overrides — the launcher's configuration surface. Hand-rolled because
//! the crates.io mirror is unavailable offline (DESIGN.md §4).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::bwkm::BwkmCfg;
use crate::kmeans::init::{SeedMethod, SeedPolicy};
use crate::kmeans::{AssignCfg, AssignMode, KernelKind, Precision};
use crate::metrics::Budget;
use crate::obs::{MetricsMode, Recorder};

/// Which clustering method a run executes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Method {
    Bwkm,
    /// Lloyd + Forgy.
    Fkm,
    /// Lloyd + K-means++.
    Kmpp,
    /// K-means++ initialization only.
    KmppInit,
    /// Lloyd + AFK-MC².
    Kmc2,
    /// Mini-batch with batch size b.
    MiniBatch(usize),
    /// Grid-based RPKM.
    Rpkm,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        let t = s.trim().to_ascii_lowercase();
        Ok(match t.as_str() {
            "bwkm" => Method::Bwkm,
            "fkm" | "forgy" => Method::Fkm,
            "kmpp" | "km++" | "kmeans++" => Method::Kmpp,
            "kmpp_init" | "km++_init" => Method::KmppInit,
            "kmc2" | "afkmc2" => Method::Kmc2,
            "rpkm" => Method::Rpkm,
            _ => {
                if let Some(b) = t.strip_prefix("mb") {
                    Method::MiniBatch(b.parse().context("mini-batch size")?)
                } else {
                    bail!("unknown method `{s}`")
                }
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            Method::Bwkm => "BWKM".into(),
            Method::Fkm => "FKM".into(),
            Method::Kmpp => "KM++".into(),
            Method::KmppInit => "KM++_init".into(),
            Method::Kmc2 => "KMC2".into(),
            Method::MiniBatch(b) => format!("MB{b}"),
            Method::Rpkm => "RPKM".into(),
        }
    }
}

/// A single clustering run's configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Table-1 dataset name (simulated), a `path:` prefixed file loaded
    /// into memory, or a `stream:` prefixed binary file clustered out of
    /// core (method=bwkm only; see `coordinator::streaming`).
    pub dataset: String,
    /// Simulator scale ∈ (0, 1].
    pub scale: f64,
    pub seed: u64,
    pub k: usize,
    pub method: Method,
    /// Distance budget (0 = unlimited).
    pub budget: u64,
    /// Worker threads for sharded phases.
    pub threads: usize,
    /// Run the weighted-Lloyd inner loop on the PJRT artifacts.
    pub use_pjrt: bool,
    /// Trace E^D per outer iteration (instrumentation).
    pub eval_full_error: bool,
    /// Whether `eval_full_error` was explicitly set (config file or CLI)
    /// rather than defaulted. The streaming runner consults this: out of
    /// core, every trace evaluation costs one full pass over the source,
    /// so it stays off unless asked for.
    pub eval_full_error_explicit: bool,
    /// Rows per chunk for `stream:` datasets (the out-of-core working
    /// set; results are chunk-size independent, bit for bit).
    pub chunk_rows: usize,
    /// Save the fitted model to this store file (DESIGN.md §5.2).
    pub save: Option<String>,
    /// Resume a run (or anchor an ingest) from this store file.
    pub resume: Option<String>,
    /// Ingest this dataset file as a warm-start mini-batch into the
    /// `resume=` model instead of running a clustering method.
    pub ingest: Option<String>,
    /// Independent jobs to multiplex over the worker pool (seed streams
    /// fork per job; results are worker-count independent).
    pub jobs: usize,
    /// Run telemetry (DESIGN.md §2.11): `off` (default, the
    /// pre-observability byte sequence), `summary` (in-memory aggregation
    /// + run report + typed summary JSON), or `jsonl` (summary plus an
    /// append-only trace file). Strictly observational in every mode.
    pub metrics: MetricsMode,
    /// Where `metrics=jsonl` writes its trace (default
    /// `bwkm_trace.jsonl`). The summary JSON lands next to it.
    pub metrics_path: Option<String>,
    /// Raw key/values for method-specific extras (m, m_prime, s, r, ...).
    pub extra: BTreeMap<String, String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "WUY".into(),
            scale: 0.001,
            seed: 42,
            k: 9,
            method: Method::Bwkm,
            budget: 0,
            threads: 1,
            use_pjrt: false,
            eval_full_error: true,
            eval_full_error_explicit: false,
            chunk_rows: 4096,
            save: None,
            resume: None,
            ingest: None,
            jobs: 1,
            metrics: MetricsMode::Off,
            metrics_path: None,
            extra: BTreeMap::new(),
        }
    }
}

impl RunConfig {
    /// Parse a config file (lines of `key = value`, `#` comments). A key
    /// appearing twice in one file is a hard error, not a silent
    /// last-wins overwrite: in-file duplicates are always a typo or a
    /// stale edit, and the value that "won" used to depend on line order.
    /// (CLI overrides still layer *on top of* the file — that is the
    /// intended precedence, applied by the caller after parsing.)
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut cfg = RunConfig::default();
        let mut seen: BTreeMap<String, usize> = BTreeMap::new();
        for (no, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let (k, v) = t
                .split_once('=')
                .ok_or_else(|| anyhow!("{}:{}: expected key = value", path.display(), no + 1))?;
            let k = k.trim();
            if let Some(first) = seen.insert(k.to_string(), no + 1) {
                bail!(
                    "{}:{}: duplicate key `{k}` (first set at line {first}); \
                     keep one line per key — to override a file value, pass {k}=... \
                     on the command line instead",
                    path.display(),
                    no + 1
                );
            }
            cfg.set(k, v.trim())?;
        }
        Ok(cfg)
    }

    /// Apply one `key=value` override (also used for CLI args).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let value = value.trim_matches('"');
        match key {
            "dataset" => self.dataset = value.to_string(),
            "scale" => self.scale = value.parse().context("scale")?,
            "seed" => self.seed = value.parse().context("seed")?,
            "k" => self.k = value.parse().context("k")?,
            "method" => self.method = Method::parse(value)?,
            "budget" => self.budget = value.parse().context("budget")?,
            "threads" => self.threads = value.parse().context("threads")?,
            "use_pjrt" => self.use_pjrt = parse_bool(value)?,
            "eval_full_error" => {
                self.eval_full_error = parse_bool(value)?;
                self.eval_full_error_explicit = true;
            }
            "chunk_rows" => {
                self.chunk_rows = value.parse().context("chunk_rows")?;
                if self.chunk_rows == 0 {
                    bail!("chunk_rows must be ≥ 1");
                }
            }
            "save" => self.save = Some(value.to_string()),
            "resume" => self.resume = Some(value.to_string()),
            "ingest" => self.ingest = Some(value.to_string()),
            "jobs" => {
                self.jobs = value.parse().context("jobs")?;
                if self.jobs == 0 {
                    bail!("jobs must be ≥ 1");
                }
            }
            "metrics" => self.metrics = MetricsMode::parse(value)?,
            "metrics_path" => {
                if value.is_empty() {
                    bail!("metrics_path must name a file (omit the key for the default)");
                }
                self.metrics_path = Some(value.to_string());
            }
            _ => {
                self.extra.insert(key.to_string(), value.to_string());
            }
        }
        Ok(())
    }

    /// Build the run's telemetry recorder from the `metrics=` /
    /// `metrics_path=` keys (DESIGN.md §2.11). `off` costs nothing;
    /// `jsonl` creates (truncates) the trace file here, so an unwritable
    /// path fails before the run starts, not after it.
    pub fn recorder(&self) -> Result<Recorder> {
        Recorder::for_mode(self.metrics, self.metrics_path.as_deref().map(Path::new))
    }

    /// Budget object (0 = unlimited).
    pub fn budget(&self) -> Budget {
        if self.budget == 0 {
            Budget::unlimited()
        } else {
            Budget::of(self.budget)
        }
    }

    /// Seeding policy (DESIGN.md §2.8) from the `init`, `oversample_l`
    /// and `init_rounds` keys. `default` is the consumer's paper-pinned
    /// method when no `init` key is present: weighted K-means++ for BWKM
    /// (Alg. 4), Forgy for RPKM ([8]).
    pub fn seed_policy(&self, default: SeedMethod) -> Result<SeedPolicy> {
        let mut policy = SeedPolicy { method: default, ..SeedPolicy::default() };
        if let Some(v) = self.extra.get("init") {
            policy.method = SeedMethod::parse(v)?;
        }
        if let Some(v) = self.extra.get("oversample_l") {
            policy.oversample_l = v.parse().context("oversample_l")?;
            if !(policy.oversample_l >= 0.0) || !policy.oversample_l.is_finite() {
                bail!("oversample_l must be a finite value ≥ 0 (0 = auto)");
            }
        }
        if let Some(v) = self.extra.get("init_rounds") {
            policy.init_rounds = v.parse().context("init_rounds")?;
            if policy.init_rounds == 0 {
                bail!("init_rounds must be ≥ 1");
            }
        }
        if let Some(v) = self.extra.get("chain_length") {
            policy.chain_length = v.parse().context("chain_length")?;
            if policy.chain_length == 0 {
                bail!("chain_length must be ≥ 1");
            }
        }
        Ok(policy)
    }

    /// Assignment-regime configuration (DESIGN.md §2.9) from the
    /// `assign`, `closure_expand`, `sample_rows` and `sample_seed` keys,
    /// plus the exact engine's `kernel` / `precision` selection (§2.10).
    /// No keys → the exact default (bit-identical to the pre-regime
    /// behavior). Bad values are rejected *here*, at parse time, with the
    /// valid alternatives spelled out — never defaulted silently or left
    /// to surface deep inside a run.
    pub fn assign_cfg(&self) -> Result<AssignCfg> {
        let mut cfg = AssignCfg::default();
        if let Some(v) = self.extra.get("assign") {
            cfg.mode = match v.to_ascii_lowercase().as_str() {
                "exact" => AssignMode::Exact,
                "closure" => AssignMode::Closure,
                "sampled" => AssignMode::Sampled,
                _ => bail!("unknown assign mode `{v}` (exact|closure|sampled)"),
            };
        }
        if let Some(v) = self.extra.get("closure_expand") {
            cfg.closure_expand = v.parse().context("closure_expand")?;
            if cfg.closure_expand == 0 {
                bail!("closure_expand must be ≥ 1");
            }
        }
        if let Some(v) = self.extra.get("sample_rows") {
            cfg.sample_rows = v.parse().context("sample_rows")?;
            if cfg.sample_rows == 0 {
                bail!(
                    "sample_rows must be ≥ 1 (it is the per-step row budget; \
                     omit the key entirely to run without sampling)"
                );
            }
        }
        if let Some(v) = self.extra.get("sample_seed") {
            cfg.sample_seed = v.parse().context("sample_seed")?;
        }
        if let Some(v) = self.extra.get("kernel") {
            cfg.kernel = match KernelKind::parse(v) {
                Some(k) => k,
                None => bail!("unknown kernel `{v}` (scalar|simd|auto)"),
            };
        }
        if let Some(v) = self.extra.get("precision") {
            cfg.precision = match Precision::parse(v) {
                Some(p) => p,
                None => bail!("unknown precision `{v}` (f64|f32)"),
            };
        }
        if cfg.mode == AssignMode::Sampled && cfg.sample_rows == 0 {
            bail!("assign = sampled requires sample_rows ≥ 1");
        }
        if cfg.mode != AssignMode::Exact
            && (cfg.kernel != KernelKind::Scalar || cfg.precision != Precision::F64)
        {
            bail!(
                "kernel=/precision= select the exact engine's kernel (DESIGN.md §2.10) and \
                 require assign = exact; the approximate regime (assign = {}) always runs \
                 the canonical scalar f64 kernel — drop the kernel/precision keys or use \
                 assign = exact",
                cfg.mode.name()
            );
        }
        Ok(cfg)
    }

    /// BWKM configuration for a dataset of n rows, honoring `extra`
    /// overrides m, m_prime, s, r, max_outer, the seeding-policy keys
    /// init / oversample_l / init_rounds / chain_length, and the §2.9
    /// assignment-regime keys assign / closure_expand / sample_rows /
    /// sample_seed.
    pub fn bwkm_cfg(&self, n: usize, d: usize) -> Result<BwkmCfg> {
        let mut cfg = BwkmCfg::for_dataset(n, d, self.k);
        if let Some(v) = self.extra.get("m") {
            cfg.init.m = v.parse().context("m")?;
        }
        if let Some(v) = self.extra.get("m_prime") {
            cfg.init.m_prime = v.parse().context("m_prime")?;
        }
        if let Some(v) = self.extra.get("s") {
            cfg.init.s = v.parse().context("s")?;
        }
        if let Some(v) = self.extra.get("r") {
            cfg.init.r = v.parse().context("r")?;
        }
        if let Some(v) = self.extra.get("max_outer") {
            cfg.max_outer = v.parse().context("max_outer")?;
        }
        cfg.seed = self.seed_policy(SeedMethod::Kmpp)?;
        cfg.budget = self.budget();
        cfg.eval_full_error = self.eval_full_error;
        cfg.assign = self.assign_cfg()?;
        Ok(cfg)
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        _ => bail!("expected a boolean, got `{v}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_methods() {
        assert_eq!(Method::parse("bwkm").unwrap(), Method::Bwkm);
        assert_eq!(Method::parse("KM++").unwrap(), Method::Kmpp);
        assert_eq!(Method::parse("mb500").unwrap(), Method::MiniBatch(500));
        assert_eq!(Method::parse("km++_init").unwrap(), Method::KmppInit);
        assert!(Method::parse("quantum").is_err());
    }

    #[test]
    fn file_roundtrip_and_overrides() {
        let p = std::env::temp_dir().join(format!("bwkm_cfg_{}.conf", std::process::id()));
        std::fs::write(
            &p,
            "# experiment\ndataset = 3RN\nk = 27\nmethod = mb100\nscale = 0.01\nm = 80\n",
        )
        .unwrap();
        let mut cfg = RunConfig::from_file(&p).unwrap();
        assert_eq!(cfg.dataset, "3RN");
        assert_eq!(cfg.k, 27);
        assert_eq!(cfg.method, Method::MiniBatch(100));
        assert_eq!(cfg.extra.get("m").unwrap(), "80");
        cfg.set("k", "3").unwrap();
        assert_eq!(cfg.k, 3);
        assert!(cfg.set("scale", "abc").is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn duplicate_file_keys_are_a_parse_error() {
        let p = std::env::temp_dir().join(format!("bwkm_cfg_dup_{}.conf", std::process::id()));
        std::fs::write(&p, "k = 9\ndataset = 3RN\n# comment\nk = 27\n").unwrap();
        let err = RunConfig::from_file(&p).unwrap_err().to_string();
        assert!(err.contains("duplicate key `k`"), "{err}");
        assert!(err.contains(":4:"), "should cite the duplicate line: {err}");
        assert!(err.contains("line 1"), "should cite the first line: {err}");
        // Extra keys get the same protection as typed ones.
        std::fs::write(&p, "m = 80\nm = 90\n").unwrap();
        let err = RunConfig::from_file(&p).unwrap_err().to_string();
        assert!(err.contains("duplicate key `m`"), "{err}");
        // CLI-style overrides on top of a clean file remain legal.
        std::fs::write(&p, "k = 9\n").unwrap();
        let mut cfg = RunConfig::from_file(&p).unwrap();
        cfg.set("k", "3").unwrap();
        assert_eq!(cfg.k, 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn service_keys_parse_and_validate() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.jobs, 1);
        assert!(cfg.save.is_none() && cfg.resume.is_none() && cfg.ingest.is_none());
        cfg.set("save", "model.bin").unwrap();
        cfg.set("resume", "old.bin").unwrap();
        cfg.set("ingest", "batch.bin").unwrap();
        cfg.set("jobs", "4").unwrap();
        assert_eq!(cfg.save.as_deref(), Some("model.bin"));
        assert_eq!(cfg.resume.as_deref(), Some("old.bin"));
        assert_eq!(cfg.ingest.as_deref(), Some("batch.bin"));
        assert_eq!(cfg.jobs, 4);
        assert!(cfg.set("jobs", "0").is_err());
        assert!(cfg.set("jobs", "many").is_err());
    }

    #[test]
    fn metrics_keys_parse_and_validate() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.metrics, MetricsMode::Off);
        assert!(cfg.metrics_path.is_none());
        assert!(!cfg.recorder().unwrap().is_on(), "off must build the inert recorder");
        cfg.set("metrics", "summary").unwrap();
        assert_eq!(cfg.metrics, MetricsMode::Summary);
        let rec = cfg.recorder().unwrap();
        assert!(rec.is_on() && rec.trace_path().is_none());
        let err = cfg.set("metrics", "verbose").unwrap_err().to_string();
        assert!(err.contains("off|summary|jsonl"), "unhelpful error: {err}");
        assert!(cfg.set("metrics_path", "").is_err());
        let p = std::env::temp_dir().join(format!("bwkm_cfg_{}.trace.jsonl", std::process::id()));
        cfg.set("metrics", "jsonl").unwrap();
        cfg.set("metrics_path", p.to_str().unwrap()).unwrap();
        let rec = cfg.recorder().unwrap();
        assert_eq!(rec.trace_path(), Some(p.as_path()));
        drop(rec);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunk_rows_parses_and_rejects_zero() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.chunk_rows, 4096);
        cfg.set("chunk_rows", "512").unwrap();
        assert_eq!(cfg.chunk_rows, 512);
        assert!(cfg.set("chunk_rows", "0").is_err());
        assert!(cfg.set("chunk_rows", "lots").is_err());
    }

    #[test]
    fn bwkm_cfg_honors_extras() {
        let mut cfg = RunConfig::default();
        cfg.set("m", "123").unwrap();
        cfg.set("r", "2").unwrap();
        cfg.set("budget", "5000").unwrap();
        let b = cfg.bwkm_cfg(10_000, 5).unwrap();
        assert_eq!(b.init.m, 123);
        assert_eq!(b.init.r, 2);
        assert_eq!(b.budget.max_distances, 5000);
        // No init key: BWKM defaults to the paper's weighted K-means++.
        assert_eq!(b.seed.method, SeedMethod::Kmpp);
    }

    #[test]
    fn assign_cfg_keys_parse_and_validate() {
        let mut cfg = RunConfig::default();
        // No keys: the exact default, bit-identical to pre-regime runs.
        assert_eq!(cfg.assign_cfg().unwrap(), AssignCfg::default());
        cfg.set("assign", "closure").unwrap();
        cfg.set("closure_expand", "4").unwrap();
        let a = cfg.assign_cfg().unwrap();
        assert_eq!(a.mode, AssignMode::Closure);
        assert_eq!(a.closure_expand, 4);
        // Flows into the BWKM config.
        assert_eq!(cfg.bwkm_cfg(1000, 3).unwrap().assign, a);
        // Sampled requires an explicit sample size.
        cfg.set("assign", "sampled").unwrap();
        assert!(cfg.assign_cfg().is_err());
        cfg.set("sample_rows", "256").unwrap();
        cfg.set("sample_seed", "7").unwrap();
        let s = cfg.assign_cfg().unwrap();
        assert_eq!(s.mode, AssignMode::Sampled);
        assert_eq!(s.sample_rows, 256);
        assert_eq!(s.sample_seed, 7);
        // Validation.
        cfg.set("assign", "psychic").unwrap();
        assert!(cfg.assign_cfg().is_err());
        cfg.set("assign", "exact").unwrap();
        cfg.set("closure_expand", "0").unwrap();
        assert!(cfg.assign_cfg().is_err());
        cfg.set("closure_expand", "2").unwrap();
        // An explicit sample_rows = 0 is a contradiction, not a disable
        // switch — rejected at parse time even outside sampled mode.
        cfg.set("sample_rows", "0").unwrap();
        assert!(cfg.assign_cfg().is_err());
    }

    #[test]
    fn kernel_precision_keys_parse_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.set("kernel", "simd").unwrap();
        cfg.set("precision", "f32").unwrap();
        let a = cfg.assign_cfg().unwrap();
        assert_eq!(a.kernel, KernelKind::Simd);
        assert_eq!(a.precision, Precision::F32);
        // Flows into the BWKM config like every other assign key.
        assert_eq!(cfg.bwkm_cfg(1000, 3).unwrap().assign, a);
        // Case-insensitive, like the other enum keys.
        cfg.set("kernel", "AUTO").unwrap();
        assert_eq!(cfg.assign_cfg().unwrap().kernel, KernelKind::Auto);
        // Invalid values fail at parse time with the alternatives named.
        cfg.set("kernel", "avx512").unwrap();
        let err = format!("{:#}", cfg.assign_cfg().unwrap_err());
        assert!(err.contains("scalar|simd|auto"), "unhelpful error: {err}");
        cfg.set("kernel", "simd").unwrap();
        cfg.set("precision", "f16").unwrap();
        let err = format!("{:#}", cfg.assign_cfg().unwrap_err());
        assert!(err.contains("f64|f32"), "unhelpful error: {err}");
        // kernel/precision contradict the approximate regime: rejected,
        // never silently ignored.
        cfg.set("precision", "f32").unwrap();
        cfg.set("assign", "closure").unwrap();
        let err = format!("{:#}", cfg.assign_cfg().unwrap_err());
        assert!(err.contains("assign = exact"), "unhelpful error: {err}");
        // Explicit defaults are compatible with any mode.
        cfg.set("kernel", "scalar").unwrap();
        cfg.set("precision", "f64").unwrap();
        assert_eq!(cfg.assign_cfg().unwrap().mode, AssignMode::Closure);
    }

    #[test]
    fn seed_policy_keys_parse_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.set("init", "par").unwrap();
        cfg.set("oversample_l", "6.5").unwrap();
        cfg.set("init_rounds", "3").unwrap();
        let p = cfg.seed_policy(SeedMethod::Kmpp).unwrap();
        assert_eq!(p.method, SeedMethod::Par);
        assert_eq!(p.oversample_l, 6.5);
        assert_eq!(p.init_rounds, 3);
        // The policy flows into the BWKM config.
        assert_eq!(cfg.bwkm_cfg(1000, 3).unwrap().seed, p);
        // Per-consumer defaults differ.
        let q = RunConfig::default().seed_policy(SeedMethod::Forgy).unwrap();
        assert_eq!(q.method, SeedMethod::Forgy);
        // Validation.
        cfg.set("init", "quantum").unwrap();
        assert!(cfg.seed_policy(SeedMethod::Kmpp).is_err());
        cfg.set("init", "pp").unwrap();
        cfg.set("init_rounds", "0").unwrap();
        assert!(cfg.seed_policy(SeedMethod::Kmpp).is_err());
        cfg.set("init_rounds", "2").unwrap();
        cfg.set("oversample_l", "-1").unwrap();
        assert!(cfg.seed_policy(SeedMethod::Kmpp).is_err());
    }
}
