//! # BWKM — Boundary Weighted K-means for massive data
//!
//! Production-shaped reproduction of Capó, Pérez & Lozano (2018),
//! *"An efficient K-means clustering algorithm for massive data"*, as a
//! three-layer Rust + JAX + Pallas system (DESIGN.md §1):
//!
//! * **L3 (this crate)** — the BWKM coordinator: spatial partitions,
//!   boundary detection, the Alg. 2–5 pipeline, every baseline of the
//!   paper's evaluation, the unified assignment engine every method's
//!   distance hot path runs through ([`kmeans::assign`], DESIGN.md §2),
//!   exact distance accounting, a sharded leader/worker runtime, the
//!   out-of-core streaming coordinator (`coordinator::streaming`,
//!   DESIGN.md §5.1 — bit-identical to the in-memory path) and the
//!   bench harness regenerating Figures 2–6.
//! * **L2/L1 (python/, build-time only)** — the weighted-Lloyd step and a
//!   Pallas distance+top-2 kernel, AOT-lowered to HLO text artifacts that
//!   [`runtime`] loads and executes through PJRT (`xla` crate).
//!
//! Quick start:
//!
//! ```no_run
//! use bwkm::prelude::*;
//!
//! let ds = bwkm::data::simulate("WUY", 0.001, 42).expect("known Table-1 name");
//! let counter = DistanceCounter::new();
//! let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 9);
//! cfg.eval_full_error = true; // trace E^D per outer iteration (uncounted)
//! let out = bwkm::bwkm::run(&ds, 9, &cfg, &mut Rng::new(7), &counter);
//! let last = out.trace.last().expect("at least one outer iteration");
//! println!(
//!     "E^D = {:.4e} after {} distances (stop: {:?})",
//!     last.full_error.unwrap_or(f64::NAN),
//!     counter.get(),
//!     out.stop,
//! );
//! ```

pub mod bench;
pub mod bwkm;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod geometry;
pub mod kmeans;
pub mod metrics;
pub mod obs;
pub mod partition;
pub mod rpkm;
pub mod runtime;
pub mod store;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::bwkm::{BwkmCfg, StopReason};
    pub use crate::data::Dataset;
    pub use crate::kmeans::{LloydCfg, MiniBatchCfg, WLloydCfg};
    pub use crate::metrics::{Budget, DistanceCounter};
    pub use crate::obs::{MetricsMode, Recorder};
    pub use crate::util::Rng;
}
