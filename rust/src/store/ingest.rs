//! Warm-start incremental ingestion (DESIGN.md §5.2): route a mini-batch
//! of new rows into a loaded [`Model`]'s existing BWKM partition instead
//! of re-running from scratch.
//!
//! The pass has three parts, each with an exact distance bill:
//!
//! 1. **Routing** — every batch row descends the spatial tree to its cell
//!    (distance-free, like every partition operation) and folds into the
//!    cell's count/sum/tight-box statistics in batch row order; the batch
//!    is also assigned through the unified engine
//!    ([`SerialAssigner`], `batch_rows · k` distances) so the report can
//!    state where the new mass landed and what it costs the current
//!    centroids.
//! 2. **Diagnostics** — each *touched* cell's representative is re-scored
//!    against the centroids (`touched · k` distances) and its
//!    misassignment ε (paper Def. 3) recomputed from the updated tight
//!    box. Cells whose ε did not move — and no cell went from empty to
//!    occupied — need no further work.
//! 3. **Bounded re-refinement** — only when some ε moved: a weighted
//!    Lloyd pass over the updated representative set, warm-started from
//!    the model's centroids and capped at [`INGEST_REFINE_ITERS`]
//!    iterations (`iters · occupied · k` distances in the exact regime).
//!
//! An empty batch is a no-op with a **zero** distance bill. Ingestion
//! never splits cells — splitting redistributes raw rows the model does
//! not hold; growing the partition itself is `store::resume`'s job, which
//! has the original dataset in hand.

use anyhow::{bail, ensure, Result};

use crate::bwkm::{epsilon, BwkmCfg};
use crate::data::Dataset;
use crate::geometry::BBox;
use crate::kmeans::{stepper_for, weighted_lloyd_with, Assigner, SerialAssigner, WLloydCfg};
use crate::metrics::DistanceCounter;
use crate::obs::{BillBridge, Recorder};

use super::{config_digest, Model};

/// Iteration cap for the post-ingest weighted-Lloyd touch-up. Small by
/// design: ingest is the fast path; a full re-refinement (with splits) is
/// a `resume` over the grown dataset.
pub const INGEST_REFINE_ITERS: usize = 4;

/// What an [`ingest`] pass did, with its exact distance bill.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IngestReport {
    /// Batch rows folded into the model.
    pub rows: usize,
    /// Distinct cells that received at least one new row.
    pub touched: usize,
    /// Touched cells whose misassignment ε moved (including cells that
    /// went from empty to occupied) — what forced re-refinement.
    pub moved: usize,
    /// Weighted-Lloyd iterations spent re-refining (0 when no ε moved).
    pub refine_iters: usize,
    /// SSE of the batch against the pre-ingest centroids (diagnostic,
    /// folded in batch row order).
    pub batch_err: f64,
    /// Exact distances charged by the whole pass:
    /// `rows·k + touched·k + refine_iters·occupied·k` in the exact regime.
    pub bill: u64,
}

/// Fold `batch` into `model`. See the module docs for the exact pass
/// structure and billing. The model's trace, stop reason, and RNG state
/// are untouched — ingestion draws no randomness.
pub fn ingest(
    model: &mut Model,
    batch: &Dataset,
    cfg: &BwkmCfg,
    counter: &DistanceCounter,
) -> Result<IngestReport> {
    ingest_rec(model, batch, cfg, counter, &Recorder::off())
}

/// [`ingest`] with telemetry (DESIGN.md §2.11): `ingest.route` /
/// `ingest.diagnose` / `ingest.refine` phase spans, the report's counts
/// as gauges, a bridged `ingest.distances` bill, and an
/// `ingest.refine` event when diagnostics forced re-refinement.
/// Strictly observational — the report and the model mutation are
/// bit-identical with `rec` on or off.
pub fn ingest_rec(
    model: &mut Model,
    batch: &Dataset,
    cfg: &BwkmCfg,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<IngestReport> {
    model.validate()?;
    ensure!(
        batch.d == model.d,
        "batch dimension {} does not match the model's {}",
        batch.d,
        model.d
    );
    let expect = config_digest(model.d, model.k, cfg);
    ensure!(
        expect == model.digest,
        "configuration digest mismatch ({expect:#018x} vs stored {:#018x}): ingest must run \
         under the configuration the model was saved with",
        model.digest
    );
    if batch.n == 0 {
        return Ok(IngestReport::default());
    }
    for i in 0..batch.n {
        if batch.row(i).iter().any(|v| !v.is_finite()) {
            bail!("batch row {i} contains a non-finite value");
        }
    }

    let d = model.d;
    let before = counter.get();
    let partition = model.partition()?; // tree descent only — cells stay in the model

    // Pre-ingest per-cell state the diagnostics need: diagonals and the
    // rank of each occupied cell in the stored top-2 arrays.
    let old_diag: Vec<f64> = model
        .cells
        .iter()
        .map(|c| c.tight.as_ref().unwrap_or(&c.cell).diagonal())
        .collect();
    let mut old_rank = vec![None::<usize>; model.cells.len()];
    let mut rank = 0usize;
    for (b, c) in model.cells.iter().enumerate() {
        if c.count > 0 {
            old_rank[b] = Some(rank);
            rank += 1;
        }
    }
    let has_top2 = model.d1.len() == rank;

    let mut bridge = BillBridge::new(counter);

    // ---- 1. Route the batch: tree descent + stats fold, in row order.
    let route_span = rec.span("ingest.route");
    let mut touched_flag = vec![false; model.cells.len()];
    for i in 0..batch.n {
        let row = batch.row(i);
        let b = partition.locate(row);
        let cell = &mut model.cells[b];
        cell.count += 1;
        for j in 0..d {
            cell.sum[j] += row[j];
        }
        match &mut cell.tight {
            Some(bb) => bb.expand(row),
            None => cell.tight = Some(BBox::at(row)),
        }
        touched_flag[b] = true;
    }
    let touched: Vec<usize> =
        (0..model.cells.len()).filter(|&b| touched_flag[b]).collect();

    // Engine assignment of the raw batch (rows·k): where the new mass
    // lands and what it costs the current centroids.
    let mut assigner = SerialAssigner;
    let batch_out = assigner.assign_top2(&batch.data, d, &model.centroids, counter);
    let batch_err: f64 = batch_out.d1.iter().sum();
    drop(route_span);

    // ---- 2. Re-score the touched representatives (touched·k).
    let diagnose_span = rec.span("ingest.diagnose");
    let mut treps = Vec::with_capacity(touched.len() * d);
    for &b in &touched {
        let c = &model.cells[b];
        let inv = 1.0 / c.count as f64;
        treps.extend(c.sum.iter().map(|s| s * inv));
    }
    let tout = assigner.assign_top2(&treps, d, &model.centroids, counter);

    let mut moved = 0usize;
    let mut patches = Vec::with_capacity(touched.len());
    for (row, &b) in touched.iter().enumerate() {
        let new_diag = model.cells[b]
            .tight
            .as_ref()
            .expect("touched cells are occupied")
            .diagonal();
        let new_eps = epsilon(new_diag, tout.d1[row], tout.d2[row]);
        let cell_moved = match old_rank[b] {
            None => true, // empty → occupied: no prior bound at all
            Some(r) if has_top2 => {
                let old_eps = epsilon(old_diag[b], model.d1[r], model.d2[r]);
                patches.push((r, tout.d1[row], tout.d2[row]));
                new_eps != old_eps
            }
            Some(_) => true, // model predates any inner step: no baseline
        };
        if cell_moved {
            moved += 1;
        }
    }

    drop(diagnose_span);

    // ---- 3. Bounded re-refinement, only when a bound moved.
    let mut refine_iters = 0usize;
    if moved > 0 {
        let _refine_span = rec.span("ingest.refine");
        if rec.is_on() {
            rec.event(
                "ingest.refine",
                &format!("moved={moved} touched={} rows={}", touched.len(), batch.n),
            );
        }
        let mut reps = Vec::new();
        let mut weights = Vec::new();
        for c in model.cells.iter().filter(|c| c.count > 0) {
            let inv = 1.0 / c.count as f64;
            reps.extend(c.sum.iter().map(|s| s * inv));
            weights.push(c.count as f64);
        }
        let wcfg = WLloydCfg {
            max_iters: INGEST_REFINE_ITERS.min(cfg.wl.max_iters),
            tol: cfg.wl.tol,
            budget: cfg.budget,
        };
        let mut stepper = stepper_for(&cfg.assign);
        let out = weighted_lloyd_with(
            stepper.as_mut(),
            &reps,
            &weights,
            d,
            &model.centroids,
            &wcfg,
            counter,
        );
        refine_iters = out.iters;
        model.centroids = out.centroids;
        model.d1 = out.d1;
        model.d2 = out.d2;
    } else {
        // Bounds are unchanged, but the stored top-2 distances of touched
        // cells still track the (marginally shifted) representatives.
        for (r, nd1, nd2) in patches {
            model.d1[r] = nd1;
            model.d2[r] = nd2;
        }
    }

    model.rows += batch.n as u64;
    bridge.tick(rec, "ingest.distances", counter);
    rec.gauge_u64("ingest.rows", batch.n as u64);
    rec.gauge_u64("ingest.touched", touched.len() as u64);
    rec.gauge_u64("ingest.moved", moved as u64);
    rec.gauge_u64("ingest.refine_iters", refine_iters as u64);
    rec.gauge("ingest.batch_err", batch_err);
    let bill = counter.get() - before;
    model.distances += bill;
    Ok(IngestReport {
        rows: batch.n,
        touched: touched.len(),
        moved,
        refine_iters,
        batch_err,
        bill,
    })
}
