//! The versioned model store — the resident-service persistence layer
//! (DESIGN.md §5.2).
//!
//! A [`Model`] is everything a BWKM run needs to continue exactly where
//! it stopped: the final centroids, the spatial split tree with per-cell
//! statistics, the last inner step's stored top-2 distances (which are
//! **not** recomputable from the final centroids — they were measured
//! against the last step's *pre-update* centroids), the seeding policy,
//! the raw RNG stream state, the cumulative distance bill, and the full
//! trace. `save → load → resume` over the original dataset is pinned
//! **bit-identical** (`==`, no tolerances) to the uninterrupted run —
//! centroids, trace, and counter totals — by
//! `tests/service_conformance.rs`.
//!
//! The on-disk format is the hand-rolled little-endian layout of
//! [`format`]: magic, format version (unknown versions are rejected, not
//! guessed at), a config digest binding the model to the configuration
//! that produced it, the payload sections, and a trailing whole-file
//! checksum. Warm-start ingestion of new rows lives in [`ingest`].

pub mod format;
pub mod ingest;

pub use ingest::{ingest, ingest_rec, IngestReport, INGEST_REFINE_ITERS};

use anyhow::{bail, ensure, Context, Result};

use crate::bwkm::{
    resume_source_rec, BwkmCfg, BwkmOutcome, MemSource, ResumePoint, StopReason, TracePoint,
};
use crate::data::Dataset;
use crate::geometry::BBox;
use crate::kmeans::init::{SeedMethod, SeedPolicy};
use crate::kmeans::{stepper_for, Stepper};
use crate::metrics::DistanceCounter;
use crate::obs::Recorder;
use crate::partition::{FlatNode, Partition};
use crate::util::Rng;

use format::{fnv1a, Reader, Writer, MAGIC, VERSION};

/// One spatial cell's persisted statistics: the leaf's cell box, the
/// tight member box, and the member count/coordinate-sum (folded in
/// dataset row order — the §5.1 determinism contract).
#[derive(Clone, Debug)]
pub struct CellState {
    pub cell: BBox,
    pub tight: Option<BBox>,
    pub count: u64,
    pub sum: Vec<f64>,
}

/// A persisted clustering model (DESIGN.md §5.2).
#[derive(Clone, Debug)]
pub struct Model {
    pub d: usize,
    pub k: usize,
    /// [`config_digest`] of the configuration that produced the model;
    /// `resume`/`ingest` refuse to run under a different one.
    pub digest: u64,
    /// Rows the model covers (original dataset plus every ingested batch).
    pub rows: u64,
    pub centroids: Vec<f64>,
    /// Spatial split tree (flat, index-aligned with `cells`).
    pub tree: Vec<FlatNode>,
    pub cells: Vec<CellState>,
    /// Stored top-2 squared distances per non-empty cell, in cell-id
    /// order — the last inner step's values against its pre-update
    /// centroids (`bwkm::BwkmOutcome::d1`), persisted verbatim.
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
    pub trace: Vec<TracePoint>,
    pub stop: StopReason,
    /// Raw xoshiro256** state at save time — resuming restores the
    /// stream bit for bit.
    pub rng: [u64; 4],
    /// Cumulative `DistanceCounter` total at save time.
    pub distances: u64,
    pub seed: SeedPolicy,
}

fn stop_tag(s: StopReason) -> u8 {
    match s {
        StopReason::EmptyBoundary => 0,
        StopReason::Budget => 1,
        StopReason::MaxIters => 2,
        StopReason::CentroidShift => 3,
        StopReason::AccuracyBound => 4,
    }
}

fn stop_from(tag: u8) -> Result<StopReason> {
    Ok(match tag {
        0 => StopReason::EmptyBoundary,
        1 => StopReason::Budget,
        2 => StopReason::MaxIters,
        3 => StopReason::CentroidShift,
        4 => StopReason::AccuracyBound,
        other => bail!("store file corrupt: unknown stop-reason tag {other}"),
    })
}

fn seed_tag(m: SeedMethod) -> u8 {
    match m {
        SeedMethod::Forgy => 0,
        SeedMethod::Kmpp => 1,
        SeedMethod::Kmc2 => 2,
        SeedMethod::Par => 3,
    }
}

fn seed_from(tag: u8) -> Result<SeedMethod> {
    Ok(match tag {
        0 => SeedMethod::Forgy,
        1 => SeedMethod::Kmpp,
        2 => SeedMethod::Kmc2,
        3 => SeedMethod::Par,
        other => bail!("store file corrupt: unknown seed-method tag {other}"),
    })
}

/// Fingerprint of every configuration knob that shapes the trajectory a
/// model encodes: dims/k, the Alg. 2–4 initial-partition sizes, the
/// seeding policy, the inner-Lloyd knobs, the assignment regime, and the
/// shift/bound stopping tolerances. Floats enter through their exact bit
/// patterns. Deliberately **excluded**: `max_outer` and `budget` —
/// raising a cap is precisely what `resume=` is for — and
/// `eval_full_error`, which is uncounted instrumentation.
pub fn config_digest(d: usize, k: usize, cfg: &BwkmCfg) -> u64 {
    let opt_bits = |o: Option<f64>| match o {
        Some(v) => format!("{:016x}", v.to_bits()),
        None => "none".to_string(),
    };
    let s = format!(
        "v{VERSION};d={d};k={k};init={},{},{},{};seed={},{:016x},{},{};wl={},{:016x};\
         assign={},{},{},{:016x},{},{};shift={};bound={}",
        cfg.init.m_prime,
        cfg.init.m,
        cfg.init.s,
        cfg.init.r,
        cfg.seed.method.name(),
        cfg.seed.oversample_l.to_bits(),
        cfg.seed.init_rounds,
        cfg.seed.chain_length,
        cfg.wl.max_iters,
        cfg.wl.tol.to_bits(),
        cfg.assign.mode.name(),
        cfg.assign.closure_expand,
        cfg.assign.sample_rows,
        cfg.assign.sample_seed,
        cfg.assign.kernel.name(),
        cfg.assign.precision.name(),
        opt_bits(cfg.shift_tol),
        opt_bits(cfg.bound_tol),
    );
    fnv1a(s.as_bytes())
}

impl Model {
    /// Capture a finished (or iteration-capped) in-memory run as a model.
    pub fn from_run(
        out: &BwkmOutcome,
        cfg: &BwkmCfg,
        rng: &Rng,
        counter: &DistanceCounter,
    ) -> Model {
        let cells: Vec<CellState> = out
            .partition
            .blocks
            .iter()
            .map(|b| CellState {
                cell: b.cell.clone(),
                tight: b.tight.clone(),
                count: b.weight() as u64,
                sum: b.sum.clone(),
            })
            .collect();
        let rows = cells.iter().map(|c| c.count).sum();
        Model {
            d: out.d,
            k: out.k,
            digest: config_digest(out.d, out.k, cfg),
            rows,
            centroids: out.centroids.clone(),
            tree: out.partition.flat_nodes(),
            cells,
            d1: out.d1.clone(),
            d2: out.d2.clone(),
            trace: out.trace.clone(),
            stop: out.stop,
            rng: rng.state(),
            distances: counter.get(),
            seed: cfg.seed,
        }
    }

    /// Structural validation: every internal consistency rule a correct
    /// writer upholds. Violations mean corruption (that slipped past the
    /// checksum) or a buggy producer — never user error.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.d > 0, "model dimension must be positive");
        ensure!(self.k > 0, "model k must be positive");
        ensure!(
            self.centroids.len() == self.k * self.d,
            "model stores {} centroid values, k·d = {}",
            self.centroids.len(),
            self.k * self.d
        );
        ensure!(
            self.centroids.iter().all(|v| v.is_finite()),
            "model centroids contain non-finite values"
        );
        ensure!(
            self.d1.len() == self.d2.len(),
            "top-2 arrays disagree in length ({} vs {})",
            self.d1.len(),
            self.d2.len()
        );
        ensure!(
            self.rng.iter().any(|&x| x != 0),
            "all-zero RNG state (unreachable from any seed — corrupted model)"
        );
        let occupied = self.cells.iter().filter(|c| c.count > 0).count();
        ensure!(
            self.d1.is_empty() || self.d1.len() == occupied,
            "model stores top-2 distances for {} cells, {} are occupied",
            self.d1.len(),
            occupied
        );
        let total: u64 = self.cells.iter().map(|c| c.count).sum();
        ensure!(
            total == self.rows,
            "cell counts sum to {total}, model claims {} rows",
            self.rows
        );
        for (i, c) in self.cells.iter().enumerate() {
            ensure!(
                c.sum.len() == self.d,
                "cell {i}: coordinate sum has {} entries, d = {}",
                c.sum.len(),
                self.d
            );
            ensure!(
                (c.count > 0) == c.tight.is_some(),
                "cell {i}: occupancy ({} rows) disagrees with tight-box presence",
                c.count
            );
        }
        // The tree's own invariants (leaf/block bijection, index ranges).
        self.partition()?;
        Ok(())
    }

    /// Rebuild the spatial partition (member bookkeeping empty — run
    /// `assign_members` over the original dataset to populate it).
    pub fn partition(&self) -> Result<Partition> {
        let cells: Vec<(BBox, Option<BBox>)> =
            self.cells.iter().map(|c| (c.cell.clone(), c.tight.clone())).collect();
        Partition::from_flat(self.d, &self.tree, cells)
    }

    /// Serialize to the sealed §5.2 byte layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        w.u64(self.d as u64);
        w.u64(self.k as u64);
        w.u64(self.digest);
        w.u64(self.rows);
        w.u64(self.distances);
        for &s in &self.rng {
            w.u64(s);
        }
        w.u8(seed_tag(self.seed.method));
        w.f64(self.seed.oversample_l);
        w.u64(self.seed.init_rounds as u64);
        w.u64(self.seed.chain_length as u64);
        w.u8(stop_tag(self.stop));
        w.f64s(&self.centroids);
        w.u64(self.d1.len() as u64);
        w.f64s(&self.d1);
        w.f64s(&self.d2);
        w.u64(self.tree.len() as u64);
        for n in &self.tree {
            match *n {
                FlatNode::Leaf { block } => {
                    w.u8(0);
                    w.u32(block);
                }
                FlatNode::Internal { axis, thr, left, right } => {
                    w.u8(1);
                    w.u32(axis);
                    w.f64(thr);
                    w.u32(left);
                    w.u32(right);
                }
            }
        }
        w.u64(self.cells.len() as u64);
        for c in &self.cells {
            w.f64s(&c.cell.lo);
            w.f64s(&c.cell.hi);
            match &c.tight {
                Some(t) => {
                    w.u8(1);
                    w.f64s(&t.lo);
                    w.f64s(&t.hi);
                }
                None => w.u8(0),
            }
            w.u64(c.count);
            w.f64s(&c.sum);
        }
        w.u64(self.trace.len() as u64);
        for t in &self.trace {
            w.u64(t.outer_iter as u64);
            w.u64(t.distances);
            w.u64(t.blocks as u64);
            w.u64(t.occupied as u64);
            w.u64(t.boundary as u64);
            w.f64(t.weighted_error);
            w.f64(t.bound);
            match t.full_error {
                Some(e) => {
                    w.u8(1);
                    w.f64(e);
                }
                None => w.u8(0),
            }
            w.u64(t.lloyd_iters as u64);
        }
        w.finish()
    }

    /// Decode and validate a sealed byte stream.
    pub fn from_bytes(bytes: &[u8]) -> Result<Model> {
        let mut r = Reader::open(bytes)?;
        let mut magic = [0u8; 8];
        for b in magic.iter_mut() {
            *b = r.u8("magic")?;
        }
        ensure!(
            magic == MAGIC,
            "not a BWKM model store (bad magic {magic:02x?})"
        );
        let version = r.u32("format version")?;
        ensure!(
            version == VERSION,
            "store format version {version} is not supported by this build \
             (it reads version {VERSION} only) — written by a newer release?"
        );
        let d = r.u64("d")? as usize;
        let k = r.u64("k")? as usize;
        ensure!(d > 0 && k > 0, "store file corrupt: d={d}, k={k}");
        let digest = r.u64("config digest")?;
        let rows = r.u64("row count")?;
        let distances = r.u64("distance total")?;
        let mut rng = [0u64; 4];
        for s in rng.iter_mut() {
            *s = r.u64("rng state")?;
        }
        let seed = SeedPolicy {
            method: seed_from(r.u8("seed method")?)?,
            oversample_l: r.f64("oversample_l")?,
            init_rounds: r.u64("init_rounds")? as usize,
            chain_length: r.u64("chain_length")? as usize,
        };
        let stop = stop_from(r.u8("stop reason")?)?;
        let kd = (k as u64)
            .checked_mul(d as u64)
            .ok_or_else(|| anyhow::anyhow!("store file corrupt: k·d overflows (k={k}, d={d})"))?;
        let nc = r.len_of(kd, 8, "centroids")?;
        let centroids = r.f64s(nc, "centroids")?;
        let top2 = r.u64("top-2 count")?;
        let top2 = r.len_of(top2, 16, "top-2 distances")?;
        let d1 = r.f64s(top2, "d1")?;
        let d2 = r.f64s(top2, "d2")?;
        let nn = r.u64("tree node count")?;
        let nn = r.len_of(nn, 5, "tree nodes")?;
        let mut tree = Vec::with_capacity(nn);
        for i in 0..nn {
            let tag = r.u8("node tag")?;
            tree.push(match tag {
                0 => FlatNode::Leaf { block: r.u32("leaf block")? },
                1 => FlatNode::Internal {
                    axis: r.u32("split axis")?,
                    thr: r.f64("split threshold")?,
                    left: r.u32("left child")?,
                    right: r.u32("right child")?,
                },
                other => bail!("store file corrupt: node {i} has unknown tag {other}"),
            });
        }
        let ncells = r.u64("cell count")?;
        let ncells = r.len_of(ncells, 2 * d * 8 + 1, "cells")?;
        let mut cells = Vec::with_capacity(ncells);
        for _ in 0..ncells {
            let cell = BBox { lo: r.f64s(d, "cell lo")?, hi: r.f64s(d, "cell hi")? };
            let tight = match r.u8("tight flag")? {
                0 => None,
                1 => Some(BBox { lo: r.f64s(d, "tight lo")?, hi: r.f64s(d, "tight hi")? }),
                other => bail!("store file corrupt: tight-box flag {other}"),
            };
            let count = r.u64("cell row count")?;
            let sum = r.f64s(d, "cell sum")?;
            cells.push(CellState { cell, tight, count, sum });
        }
        let nt = r.u64("trace length")?;
        let nt = r.len_of(nt, 7 * 8 + 1, "trace")?;
        let mut trace = Vec::with_capacity(nt);
        for _ in 0..nt {
            trace.push(TracePoint {
                outer_iter: r.u64("trace outer")? as usize,
                distances: r.u64("trace distances")?,
                blocks: r.u64("trace blocks")? as usize,
                occupied: r.u64("trace occupied")? as usize,
                boundary: r.u64("trace boundary")? as usize,
                weighted_error: r.f64("trace weighted error")?,
                bound: r.f64("trace bound")?,
                full_error: match r.u8("trace full-error flag")? {
                    0 => None,
                    1 => Some(r.f64("trace full error")?),
                    other => bail!("store file corrupt: full-error flag {other}"),
                },
                lloyd_iters: r.u64("trace lloyd iters")? as usize,
            });
        }
        r.done()?;
        let model = Model {
            d,
            k,
            digest,
            rows,
            centroids,
            tree,
            cells,
            d1,
            d2,
            trace,
            stop,
            rng,
            distances,
            seed,
        };
        model.validate()?;
        Ok(model)
    }
}

/// Atomically persist a model (write-then-rename, the same durability
/// idiom as the bench JSON emitter).
pub fn save(model: &Model, path: &str) -> Result<()> {
    let bytes = model.to_bytes();
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &bytes).with_context(|| format!("writing {tmp}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp} -> {path}"))?;
    Ok(())
}

/// Load and validate a persisted model.
pub fn load(path: &str) -> Result<Model> {
    let bytes = std::fs::read(path).with_context(|| format!("reading model store {path}"))?;
    Model::from_bytes(&bytes).with_context(|| format!("decoding model store {path}"))
}

/// Continue a persisted run over its original dataset, bit-identical to
/// the uninterrupted run (DESIGN.md §5.2): rebuild the partition and its
/// member-exact statistics, restore the counter total and the RNG stream
/// (the caller's `rng` is overwritten so a follow-up `save` captures the
/// advanced state), and re-enter the Alg. 5 loop at the saved outer
/// index. The stepper is the one `cfg.assign` selects — the same one
/// `bwkm::run` would use.
pub fn resume(
    model: &Model,
    data: &Dataset,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<BwkmOutcome> {
    resume_rec(model, data, cfg, rng, counter, &Recorder::off())
}

/// [`resume`] with telemetry (DESIGN.md §2.11): a `store.resume` event
/// recording the saved run's shape, then everything
/// [`crate::bwkm::resume_source_rec`] emits. Strictly observational.
pub fn resume_rec(
    model: &Model,
    data: &Dataset,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<BwkmOutcome> {
    let mut stepper = stepper_for(&cfg.assign);
    resume_with_rec(stepper.as_mut(), model, data, cfg, rng, counter, rec)
}

/// [`resume`] over an explicit stepper backend.
pub fn resume_with(
    stepper: &mut dyn Stepper,
    model: &Model,
    data: &Dataset,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<BwkmOutcome> {
    resume_with_rec(stepper, model, data, cfg, rng, counter, &Recorder::off())
}

/// [`resume_with`] with telemetry (DESIGN.md §2.11).
#[allow(clippy::too_many_arguments)]
pub fn resume_with_rec(
    stepper: &mut dyn Stepper,
    model: &Model,
    data: &Dataset,
    cfg: &BwkmCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
    rec: &Recorder,
) -> Result<BwkmOutcome> {
    model.validate()?;
    ensure!(
        data.d == model.d,
        "dataset dimension {} does not match the model's {}",
        data.d,
        model.d
    );
    let expect = config_digest(model.d, model.k, cfg);
    ensure!(
        expect == model.digest,
        "configuration digest mismatch ({expect:#018x} vs stored {:#018x}): the model was \
         saved under a different configuration — resume with the saving run's settings \
         (only max_outer and the distance budget may change)",
        model.digest
    );
    ensure!(
        data.n as u64 == model.rows,
        "dataset has {} rows, the model covers {} — resume requires the dataset the model \
         was built (and ingested) from",
        data.n,
        model.rows
    );
    let mut partition = model.partition()?;
    partition.assign_members(data);
    for (b, cell) in model.cells.iter().enumerate() {
        ensure!(
            partition.blocks[b].weight() as u64 == cell.count,
            "dataset does not match the stored model: block {b} holds {} rows, the model \
             recorded {}",
            partition.blocks[b].weight(),
            cell.count
        );
    }
    counter.add(model.distances);
    *rng = Rng::from_state(model.rng);
    if rec.is_on() {
        rec.event(
            "store.resume",
            &format!(
                "k={} d={} rows={} outer={} bill={}",
                model.k,
                model.d,
                model.rows,
                model.trace.len(),
                model.distances
            ),
        );
    }
    let mut src = MemSource::with_partition(data, partition);
    let point = ResumePoint {
        centroids: model.centroids.clone(),
        trace: model.trace.clone(),
        stop: model.stop,
        d1: model.d1.clone(),
        d2: model.d2.clone(),
    };
    let out = resume_source_rec(stepper, &mut src, model.k, cfg, point, rng, counter, rec)?;
    Ok(BwkmOutcome {
        centroids: out.centroids,
        k: out.k,
        d: out.d,
        stop: out.stop,
        trace: out.trace,
        partition: src.into_partition(),
        d1: out.d1,
        d2: out.d2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn small_model() -> Model {
        let mut g = prop::Gen { rng: Rng::new(91), case: 0 };
        let ds = Dataset::new(g.blobs(300, 2, 3, 0.6), 2);
        let cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
        let c = DistanceCounter::new();
        let mut rng = Rng::new(7);
        let out = crate::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
        Model::from_run(&out, &cfg, &rng, &c)
    }

    #[test]
    fn byte_roundtrip_is_exact() {
        let m = small_model();
        let bytes = m.to_bytes();
        let back = Model::from_bytes(&bytes).unwrap();
        // Re-encoding the decoded model reproduces the file byte for byte:
        // every field survives exactly (floats via their bit patterns).
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.d, m.d);
        assert_eq!(back.k, m.k);
        assert_eq!(back.rows, m.rows);
        assert_eq!(back.rng, m.rng);
        assert_eq!(back.centroids, m.centroids);
        assert_eq!(back.tree, m.tree);
        assert_eq!(back.stop, m.stop);
        assert_eq!(back.distances, m.distances);
    }

    #[test]
    fn digest_tracks_trajectory_shaping_knobs_only() {
        let base = BwkmCfg::for_dataset(1000, 4, 5);
        let d0 = config_digest(4, 5, &base);
        // Raising the caps leaves the digest alone (that is what resume is
        // for) …
        let mut c = base;
        c.max_outer += 100;
        c.budget = crate::metrics::Budget::of(123);
        assert_eq!(config_digest(4, 5, &c), d0);
        // … while every trajectory-shaping knob moves it.
        let mut c = base;
        c.wl.max_iters += 1;
        assert_ne!(config_digest(4, 5, &c), d0);
        let mut c = base;
        c.seed.method = SeedMethod::Forgy;
        assert_ne!(config_digest(4, 5, &c), d0);
        let mut c = base;
        c.shift_tol = Some(1e-6);
        assert_ne!(config_digest(4, 5, &c), d0);
        let mut c = base;
        c.init.m += 1;
        assert_ne!(config_digest(4, 5, &c), d0);
        assert_ne!(config_digest(4, 6, &base), d0, "k is part of the identity");
    }

    #[test]
    fn validate_rejects_internal_inconsistency() {
        let good = small_model();
        assert!(good.validate().is_ok());

        let mut m = good.clone();
        m.rows += 1;
        assert!(m.validate().is_err(), "row total must match cell counts");

        let mut m = good.clone();
        m.rng = [0; 4];
        assert!(m.validate().is_err(), "all-zero rng state rejected");

        let mut m = good.clone();
        m.centroids.pop();
        assert!(m.validate().is_err(), "centroid shape mismatch rejected");

        let mut m = good.clone();
        m.d1.pop();
        assert!(m.validate().is_err(), "top-2 arrays must stay aligned");
    }
}
