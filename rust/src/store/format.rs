//! The model store's binary wire format (DESIGN.md §5.2): hand-rolled,
//! serde-less little-endian encoding with a magic tag, an explicit format
//! version, and a trailing FNV-1a checksum over every preceding byte.
//!
//! The same [`Writer`]/[`Reader`] cursor pair serves serialization and
//! deserialization; the reader bails loudly on truncation, trailing
//! garbage, bad magic, checksum mismatch, and — forward compatibility —
//! any format version newer than this build understands.

use anyhow::{bail, ensure, Result};

/// File magic: identifies a BWKM model store.
pub const MAGIC: [u8; 8] = *b"BWKMMDL\0";

/// Current format version. Readers reject anything newer; older versions
/// gain explicit migration arms if the layout ever changes.
pub const VERSION: u32 = 1;

/// FNV-1a 64-bit hash — also the whole-file checksum and the config
/// fingerprint hash (`store::config_digest`). Chosen for being trivially
/// hand-rolled and byte-order independent; this is corruption detection,
/// not cryptography.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64s(&mut self, vs: &[f64]) {
        for &v in vs {
            self.f64(v);
        }
    }

    /// Seal the buffer: append the FNV-1a checksum of everything written
    /// so far and return the finished byte stream.
    pub fn finish(mut self) -> Vec<u8> {
        let sum = fnv1a(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

/// Checked little-endian decoder over a sealed byte stream.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Open a sealed stream: verifies the trailing checksum before any
    /// field is decoded, so every downstream parse error means "layout
    /// bug or version skew", never silent bit rot.
    pub fn open(bytes: &'a [u8]) -> Result<Reader<'a>> {
        ensure!(
            bytes.len() >= MAGIC.len() + 4 + 8,
            "store file truncated: {} bytes is smaller than any valid model",
            bytes.len()
        );
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        let actual = fnv1a(body);
        ensure!(
            stored == actual,
            "store file checksum mismatch (stored {stored:#018x}, computed {actual:#018x}): \
             the file is corrupted or was truncated/extended"
        );
        Ok(Reader { buf: body, pos: 0 })
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "store file truncated while reading {what}: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// A length already read from the stream, about to size an allocation:
    /// cap it by what the remaining bytes could possibly hold so a
    /// corrupted count cannot force an absurd allocation.
    pub fn len_of(&self, count: u64, elem_bytes: usize, what: &str) -> Result<usize> {
        let remaining = (self.buf.len() - self.pos) as u64;
        let need = count.checked_mul(elem_bytes as u64);
        match need {
            Some(n) if n <= remaining => Ok(count as usize),
            _ => bail!(
                "store file corrupt: {what} count {count} needs more bytes than the {remaining} remaining"
            ),
        }
    }

    pub fn f64s(&mut self, n: usize, what: &str) -> Result<Vec<f64>> {
        let raw = self.take(n * 8, what)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Assert the stream is fully consumed (catches trailing garbage and
    /// writer/reader layout skew).
    pub fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "store file has {} trailing bytes after the last field — \
             writer/reader layout mismatch or corruption",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_field_kinds() {
        let mut w = Writer::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        w.u8(7);
        w.u64(u64::MAX - 3);
        w.f64(-0.0);
        w.f64s(&[1.5, f64::INFINITY, 2.25e-300]);
        let bytes = w.finish();

        let mut r = Reader::open(&bytes).unwrap();
        let mut magic = [0u8; 8];
        magic.copy_from_slice(r.take(8, "magic").unwrap());
        assert_eq!(magic, MAGIC);
        assert_eq!(r.u32("version").unwrap(), VERSION);
        assert_eq!(r.u8("tag").unwrap(), 7);
        assert_eq!(r.u64("big").unwrap(), u64::MAX - 3);
        assert_eq!(r.f64("negzero").unwrap().to_bits(), (-0.0f64).to_bits());
        let v = r.f64s(3, "vec").unwrap();
        assert_eq!(v[0], 1.5);
        assert!(v[1].is_infinite());
        assert_eq!(v[2], 2.25e-300);
        r.done().unwrap();
    }

    #[test]
    fn open_rejects_corruption_and_truncation() {
        let mut w = Writer::new();
        w.u64(42);
        let good = w.finish();
        assert!(Reader::open(&good).is_err(), "below minimum size still rejected");

        let mut w = Writer::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        w.u64(42);
        let good = w.finish();
        assert!(Reader::open(&good).is_ok());

        // Flip one payload bit.
        let mut bad = good.clone();
        bad[9] ^= 0x10;
        let err = Reader::open(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");

        // Truncate.
        let err = Reader::open(&good[..good.len() - 3]).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("truncated"), "{err}");

        // Trailing garbage breaks the checksum too.
        let mut long = good.clone();
        long.push(0);
        assert!(Reader::open(&long).is_err());
    }

    #[test]
    fn reader_reports_which_field_was_truncated() {
        let mut w = Writer::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        w.u32(5);
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        let _ = r.take(8, "magic").unwrap();
        let _ = r.u32("version").unwrap();
        let _ = r.u32("half").unwrap();
        let err = r.u64("centroid count").unwrap_err().to_string();
        assert!(err.contains("centroid count"), "{err}");
    }

    #[test]
    fn len_of_rejects_absurd_counts() {
        let mut w = Writer::new();
        w.bytes(&MAGIC);
        w.u32(VERSION);
        w.u64(u64::MAX / 2); // a "count" the remaining bytes cannot hold
        let bytes = w.finish();
        let mut r = Reader::open(&bytes).unwrap();
        let _ = r.take(8, "magic").unwrap();
        let _ = r.u32("version").unwrap();
        let count = r.u64("count").unwrap();
        assert!(r.len_of(count, 8, "cells").is_err());
        assert!(r.len_of(0, 8, "cells").is_ok());
    }
}
