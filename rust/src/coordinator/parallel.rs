//! Sharded data-parallel primitives over `std::thread::scope` workers.
//!
//! Each worker processes a contiguous shard; the leader reduces partials in
//! shard order (deterministic, serial-identical results). Distance
//! accounting goes through the shared atomic [`DistanceCounter`].

use crate::data::Dataset;
use crate::geometry::sq_dist;
use crate::kmeans::{StepOut, Stepper};
use crate::metrics::DistanceCounter;

/// Full-dataset assignment + SSE fanned out over `threads` workers.
/// Counts n·k distances. Returns (assignments, sse).
pub fn sharded_assign_err(
    data: &Dataset,
    centroids: &[f64],
    threads: usize,
    counter: &DistanceCounter,
) -> (Vec<u32>, f64) {
    let d = data.d;
    let k = centroids.len() / d;
    let ranges = data.shard_ranges(threads);
    let mut partials: Vec<(Vec<u32>, f64)> = Vec::with_capacity(ranges.len());

    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let r = r.clone();
                scope.spawn(move || {
                    let mut assign = Vec::with_capacity(r.len());
                    let mut sse = 0.0f64;
                    for i in r.clone() {
                        let p = data.row(i);
                        let (mut bi, mut bd) = (0usize, f64::INFINITY);
                        for c in 0..k {
                            let dd = sq_dist(p, &centroids[c * d..(c + 1) * d]);
                            if dd < bd {
                                bd = dd;
                                bi = c;
                            }
                        }
                        assign.push(bi as u32);
                        sse += bd;
                    }
                    counter.add((r.len() * k) as u64);
                    (assign, sse)
                })
            })
            .collect();
        for h in handles {
            partials.push(h.join().expect("worker panicked"));
        }
    });

    // Ordered reduction.
    let mut assign = Vec::with_capacity(data.n);
    let mut sse = 0.0;
    for (a, s) in partials {
        assign.extend(a);
        sse += s;
    }
    (assign, sse)
}

/// One weighted-Lloyd step with the assignment phase fanned out over
/// shards of the representatives; the leader merges per-shard cluster
/// aggregates in shard order and applies the update rule (empty clusters
/// keep their centroid — identical semantics to `NativeStepper`).
pub fn sharded_weighted_step(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    threads: usize,
    counter: &DistanceCounter,
) -> StepOut {
    let m = weights.len();
    let k = centroids.len() / d;
    let threads = threads.max(1).min(m.max(1));
    let base = m / threads;
    let extra = m % threads;
    let mut ranges = Vec::with_capacity(threads);
    let mut start = 0usize;
    for t in 0..threads {
        let len = base + usize::from(t < extra);
        ranges.push(start..start + len);
        start += len;
    }

    struct Partial {
        assign: Vec<u32>,
        d1: Vec<f64>,
        d2: Vec<f64>,
        sums: Vec<f64>,
        counts: Vec<f64>,
        werr: f64,
    }

    let mut partials: Vec<Partial> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let r = r.clone();
                scope.spawn(move || {
                    let mut p = Partial {
                        assign: Vec::with_capacity(r.len()),
                        d1: Vec::with_capacity(r.len()),
                        d2: Vec::with_capacity(r.len()),
                        sums: vec![0.0; k * d],
                        counts: vec![0.0; k],
                        werr: 0.0,
                    };
                    for i in r.clone() {
                        let row = &reps[i * d..(i + 1) * d];
                        let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
                        for c in 0..k {
                            let dd = sq_dist(row, &centroids[c * d..(c + 1) * d]);
                            if dd < b1 {
                                b2 = b1;
                                b1 = dd;
                                i1 = c;
                            } else if dd < b2 {
                                b2 = dd;
                            }
                        }
                        p.assign.push(i1 as u32);
                        p.d1.push(b1);
                        p.d2.push(b2);
                        let w = weights[i];
                        p.werr += w * b1;
                        p.counts[i1] += w;
                        for j in 0..d {
                            p.sums[i1 * d + j] += w * row[j];
                        }
                    }
                    counter.add((r.len() * k) as u64);
                    p
                })
            })
            .collect();
        for h in handles {
            partials.push(h.join().expect("worker panicked"));
        }
    });

    let mut assign = Vec::with_capacity(m);
    let mut d1 = Vec::with_capacity(m);
    let mut d2 = Vec::with_capacity(m);
    let mut sums = vec![0.0; k * d];
    let mut counts = vec![0.0; k];
    let mut werr = 0.0;
    for p in partials {
        assign.extend(p.assign);
        d1.extend(p.d1);
        d2.extend(p.d2);
        werr += p.werr;
        for c in 0..k {
            counts[c] += p.counts[c];
            for j in 0..d {
                sums[c * d + j] += p.sums[c * d + j];
            }
        }
    }
    let mut out = centroids.to_vec();
    for c in 0..k {
        if counts[c] > 0.0 {
            let inv = 1.0 / counts[c];
            for j in 0..d {
                out[c * d + j] = sums[c * d + j] * inv;
            }
        }
    }
    StepOut { centroids: out, assign, d1, d2, werr }
}

/// [`Stepper`] adapter running every iteration through
/// [`sharded_weighted_step`] — plug-in parallelism for `bwkm::run_with`.
pub struct ShardedStepper {
    pub threads: usize,
}

impl Stepper for ShardedStepper {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        sharded_weighted_step(reps, weights, d, centroids, self.threads, counter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::NativeStepper;
    use crate::util::prop;

    #[test]
    fn prop_sharded_step_equals_serial() {
        prop::check("sharded-step", 20, |g| {
            let m = g.int(1, 200);
            let d = g.int(1, 5);
            let k = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 7) as f64).collect();
            let cents = g.cloud(k, d, 2.0);
            let threads = g.int(1, 5);

            let c1 = DistanceCounter::new();
            let serial = NativeStepper::new().step(&reps, &weights, d, &cents, &c1);
            let c2 = DistanceCounter::new();
            let sharded =
                sharded_weighted_step(&reps, &weights, d, &cents, threads, &c2);

            assert_eq!(serial.assign, sharded.assign);
            assert_eq!(c1.get(), c2.get());
            for (a, b) in serial.centroids.iter().zip(&sharded.centroids) {
                assert!((a - b).abs() < 1e-9);
            }
            assert!((serial.werr - sharded.werr).abs() < 1e-9 * serial.werr.max(1.0));
        });
    }

    #[test]
    fn prop_sharded_assign_err_equals_serial() {
        prop::check("sharded-err", 15, |g| {
            let n = g.int(1, 300);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.cloud(n, d, 3.0), d);
            let cents = g.cloud(k, d, 3.0);
            let threads = g.int(1, 6);

            let c1 = DistanceCounter::new();
            let (_, sse) = sharded_assign_err(&ds, &cents, threads, &c1);
            let c2 = DistanceCounter::new();
            let serial = crate::metrics::kmeans_error(&ds.data, d, &cents, &c2);
            assert!((sse - serial).abs() < 1e-9 * serial.max(1.0));
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn bwkm_runs_on_sharded_stepper() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(55), case: 0 };
        let ds = Dataset::new(g.blobs(600, 2, 3, 0.5), 2);
        let cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 3);
        let c = DistanceCounter::new();
        let mut stepper = ShardedStepper { threads: 3 };
        let out = crate::bwkm::run_with(
            &mut stepper,
            &ds,
            3,
            &cfg,
            &mut crate::util::Rng::new(1),
            &c,
        );
        assert_eq!(out.centroids.len(), 6);
    }
}
