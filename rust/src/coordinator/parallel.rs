//! Sharded data-parallel primitives over the shared persistent worker
//! pool ([`crate::util::pool`], DESIGN.md §2.12).
//!
//! Both fan-out shapes here are thin wrappers over the shared assignment
//! engine's sharding **combinator**
//! ([`crate::kmeans::assign::Sharded`]`<B>`, DESIGN.md §2.5): rows are
//! split with the one canonical [`crate::kmeans::assign::shard_ranges`]
//! rule (the same split `Dataset::shard_ranges` uses, so leader and
//! workers can never disagree about row ownership), each worker runs any
//! inner engine backend on its contiguous shard, and the reduction is
//! serial in row order. Results are therefore **bit-identical** to the
//! serial path — not merely close — for every inner backend and thread
//! count, and distance accounting goes through the shared atomic
//! [`DistanceCounter`] exactly as in the serial case (n·k per assignment
//! pass for the serial-kernel workers; the inner backend's own §2.4 rule,
//! summed over shards, otherwise — e.g.
//! `Sharded<BoundedAssigner>` keeps per-shard bounds warm between
//! weighted-Lloyd iterations, DESIGN.md §2.7).

use crate::data::Dataset;
use crate::kmeans::assign::{
    self, AssignCfg, KernelKind, Precision, Sharded, ShardedAssigner, VectorAssigner,
};
use crate::kmeans::{EngineStepper, StepOut, Stepper};
use crate::metrics::DistanceCounter;

/// Full-dataset assignment + SSE fanned out over `threads` workers.
/// Counts n·k distances. Returns (assignments, sse).
pub fn sharded_assign_err(
    data: &Dataset,
    centroids: &[f64],
    threads: usize,
    counter: &DistanceCounter,
) -> (Vec<u32>, f64) {
    assign::assign_err(
        &mut ShardedAssigner::new(threads),
        &data.data,
        data.d,
        centroids,
        counter,
    )
}

/// One weighted-Lloyd step with the assignment phase fanned out over
/// shards of the representatives. Accumulation and the update rule (empty
/// clusters keep their centroid) run serially in row order inside
/// [`assign::weighted_step`], so the result equals `NativeStepper`'s bit
/// for bit.
pub fn sharded_weighted_step(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    threads: usize,
    counter: &DistanceCounter,
) -> StepOut {
    assign::weighted_step(
        &mut ShardedAssigner::new(threads),
        reps,
        weights,
        d,
        centroids,
        counter,
    )
}

/// [`Stepper`] adapter fanning every iteration's assignment phase out
/// over `threads` shards — plug-in parallelism for `bwkm::run_with`.
///
/// Persistent (DESIGN.md §2.12): the inner [`ShardedAssigner`] and the
/// accumulation scratch live across iterations, so warm steps reuse their
/// buffers and run on the shared worker pool instead of standing up
/// per-call state. Outputs stay bit-identical to [`NativeStepper`]
/// (leader-side row-order folds, §2.5) for every thread count.
pub struct ShardedStepper {
    inner: EngineStepper<ShardedAssigner>,
}

impl ShardedStepper {
    pub fn new(threads: usize) -> Self {
        ShardedStepper { inner: EngineStepper::with_engine(ShardedAssigner::new(threads)) }
    }

    /// The configured shard count (a determinism key, not a tolerance —
    /// outputs are identical for every value).
    pub fn threads(&self) -> usize {
        self.inner.engine().threads()
    }
}

impl Stepper for ShardedStepper {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        self.inner.step(reps, weights, d, centroids, counter)
    }

    fn step_into(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut StepOut,
    ) {
        self.inner.step_into(reps, weights, d, centroids, counter, out);
    }
}

/// The sharded stepper for an exact-mode [`AssignCfg`], honoring its
/// §2.10 `kernel`/`precision` selection: the default scalar/f64 pair is
/// the classic [`ShardedStepper`]; anything else mounts the sharding
/// combinator over per-worker [`VectorAssigner`]s. f64 selections stay
/// bit-identical to the serial and classic sharded paths (pinned —
/// DESIGN.md §2.10); f32 follows the documented relaxed contract, but is
/// still bit-identical to the *serial* f32 run for every thread count
/// (§2.5 holds per precision).
pub fn sharded_stepper_for(assign: &AssignCfg, threads: usize) -> Box<dyn Stepper> {
    if assign.kernel == KernelKind::Scalar && assign.precision == Precision::F64 {
        Box::new(ShardedStepper::new(threads))
    } else {
        Box::new(EngineStepper::with_engine(Sharded::with_backend(
            threads,
            VectorAssigner::from_cfg(assign),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::NativeStepper;
    use crate::util::prop;

    #[test]
    fn prop_sharded_step_equals_serial() {
        // Since the port onto the unified engine this equivalence is exact
        // (bit-for-bit), not tolerance-based: the sharded backend computes
        // the same canonical kernel on the same rows and the accumulation
        // is serial either way (DESIGN.md §2.5).
        prop::check("sharded-step", 20, |g| {
            let m = g.int(1, 200);
            let d = g.int(1, 5);
            let k = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 7) as f64).collect();
            let cents = g.cloud(k, d, 2.0);
            let threads = g.int(1, 5);

            let c1 = DistanceCounter::new();
            let serial = NativeStepper::new().step(&reps, &weights, d, &cents, &c1);
            let c2 = DistanceCounter::new();
            let sharded =
                sharded_weighted_step(&reps, &weights, d, &cents, threads, &c2);

            assert_eq!(serial.assign, sharded.assign);
            assert_eq!(serial.d1, sharded.d1);
            assert_eq!(serial.d2, sharded.d2);
            assert_eq!(serial.centroids, sharded.centroids);
            assert_eq!(serial.werr.to_bits(), sharded.werr.to_bits());
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn prop_sharded_assign_err_equals_serial() {
        prop::check("sharded-err", 15, |g| {
            let n = g.int(1, 300);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.cloud(n, d, 3.0), d);
            let cents = g.cloud(k, d, 3.0);
            let threads = g.int(1, 6);

            let c1 = DistanceCounter::new();
            let (_, sse) = sharded_assign_err(&ds, &cents, threads, &c1);
            let c2 = DistanceCounter::new();
            let serial = crate::metrics::kmeans_error(&ds.data, d, &cents, &c2);
            assert!((sse - serial).abs() < 1e-9 * serial.max(1.0));
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn sharded_paths_share_shard_ranges() {
        // The former hand-rolled base/extra split in this file could in
        // principle drift from `Dataset::shard_ranges`; both now route
        // through `assign::shard_ranges`, asserted here on the boundary
        // cases (n < threads, n % threads != 0).
        for n in [1usize, 5, 7, 64, 65, 100] {
            for threads in 1..=8 {
                let ds = Dataset::new(vec![0.0; n], 1);
                assert_eq!(ds.shard_ranges(threads), assign::shard_ranges(n, threads));
            }
        }
    }

    #[test]
    fn prop_sharded_bounded_stepper_equals_serial_across_iterations() {
        // The combinator payoff: a stepper over Sharded<BoundedAssigner>
        // keeps per-shard bounds warm across weighted-Lloyd iterations and
        // still matches the serial stepper bit for bit at every step.
        use crate::kmeans::assign::{BoundedAssigner, Sharded};
        use crate::kmeans::EngineStepper;
        prop::check("sharded-bounded-stepper", 10, |g| {
            let m = g.int(2, 180);
            let d = g.int(1, 4);
            let k = g.int(1, 6);
            let threads = g.int(1, 5);
            let reps = g.cloud(m, d, 2.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 7) as f64).collect();
            let mut cents = g.cloud(k, d, 2.0);

            let mut serial = NativeStepper::new();
            let mut sharded_bounded =
                EngineStepper::with_engine(Sharded::<BoundedAssigner>::new(threads));
            for _ in 0..5 {
                let c1 = DistanceCounter::new();
                let a = serial.step(&reps, &weights, d, &cents, &c1);
                let c2 = DistanceCounter::new();
                let b = sharded_bounded.step(&reps, &weights, d, &cents, &c2);
                assert_eq!(a.assign, b.assign);
                assert_eq!(a.d1, b.d1);
                assert_eq!(a.d2, b.d2);
                assert_eq!(a.centroids, b.centroids);
                assert_eq!(a.werr.to_bits(), b.werr.to_bits());
                // Warm bounded shards charge at most the serial bill.
                assert!(c2.get() <= c1.get() + (k * threads) as u64);
                cents = a.centroids;
            }
        });
    }

    #[test]
    fn bwkm_runs_on_sharded_stepper() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(55), case: 0 };
        let ds = Dataset::new(g.blobs(600, 2, 3, 0.5), 2);
        let cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 3);
        let c = DistanceCounter::new();
        let mut stepper = ShardedStepper::new(3);
        let out = crate::bwkm::run_with(
            &mut stepper,
            &ds,
            3,
            &cfg,
            &mut crate::util::Rng::new(1),
            &c,
        );
        assert_eq!(out.centroids.len(), 6);
    }
}
