//! Streaming ingestion: the massive-data path where the dataset never fits
//! in memory. Chunks come from any `Iterator<Item = Result<Vec<f64>>>`
//! (e.g. [`crate::data::loader::BinChunks`]); the coordinator accumulates
//! per-block statistics against a spatial [`Partition`] and evaluates
//! errors chunk-by-chunk with bounded memory.

use anyhow::Result;

use crate::geometry::BBox;
use crate::metrics::{nearest, DistanceCounter};
use crate::partition::Partition;

/// Per-block statistics accumulated from a stream (counts, sums and tight
/// boxes — exactly what `Partition::assign_members` computes in-memory).
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub counts: Vec<usize>,
    pub sums: Vec<Vec<f64>>,
    pub tight: Vec<Option<BBox>>,
    pub rows: usize,
}

impl StreamStats {
    /// Flat (reps, weights, block_ids) — same contract as
    /// `Partition::reps_weights`, but built from the stream.
    pub fn reps_weights(&self, d: usize) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        let mut reps = Vec::new();
        let mut weights = Vec::new();
        let mut ids = Vec::new();
        for b in 0..self.counts.len() {
            if self.counts[b] > 0 {
                let inv = 1.0 / self.counts[b] as f64;
                reps.extend(self.sums[b].iter().map(|s| s * inv));
                weights.push(self.counts[b] as f64);
                ids.push(b);
                debug_assert_eq!(self.sums[b].len(), d);
            }
        }
        (reps, weights, ids)
    }
}

/// One pass over a chunked source, locating every row through the
/// partition tree. O(chunk) memory.
pub fn stream_partition_stats<I>(
    partition: &Partition,
    d: usize,
    chunks: I,
) -> Result<StreamStats>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    let nb = partition.len();
    let mut stats = StreamStats {
        counts: vec![0; nb],
        sums: vec![vec![0.0; d]; nb],
        tight: vec![None; nb],
        rows: 0,
    };
    for chunk in chunks {
        let chunk = chunk?;
        for row in chunk.chunks_exact(d) {
            let b = partition.locate(row);
            stats.counts[b] += 1;
            for j in 0..d {
                stats.sums[b][j] += row[j];
            }
            match &mut stats.tight[b] {
                Some(bb) => bb.expand(row),
                None => stats.tight[b] = Some(BBox::at(row)),
            }
            stats.rows += 1;
        }
    }
    Ok(stats)
}

/// Streaming E^D evaluation: assignment + SSE over a chunked source.
/// Counts rows·k distances. Returns (rows, sse).
pub fn stream_assign_err<I>(
    d: usize,
    centroids: &[f64],
    chunks: I,
    counter: &DistanceCounter,
) -> Result<(usize, f64)>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    let mut sse = 0.0;
    let mut rows = 0usize;
    for chunk in chunks {
        let chunk = chunk?;
        for row in chunk.chunks_exact(d) {
            let (_, dd) = nearest(row, centroids, d, counter);
            sse += dd;
            rows += 1;
        }
    }
    Ok((rows, sse))
}

/// Out-of-core BWKM: the full boundary-weighted loop against a re-openable
/// chunked source. Per outer iteration the source is streamed once to
/// rebuild per-block statistics (the streaming trade-off the paper's
/// Problem 2 discussion prices at O(n·d) per partition update); the
/// weighted-Lloyd inner loop and the ε/boundary machinery run over the
/// (tiny) representative set in memory.
pub struct StreamBwkmCfg {
    /// Initial partition size (the §2.4.1 m).
    pub target_blocks: usize,
    pub max_outer: usize,
    pub wl: crate::kmeans::WLloydCfg,
}

/// Outcome of a streaming BWKM run.
pub struct StreamBwkmOutcome {
    pub centroids: Vec<f64>,
    /// Streaming passes over the source.
    pub passes: usize,
    pub blocks: usize,
    /// True if the run ended on an empty boundary (Thm 3 fixed point).
    pub converged: bool,
}

/// Run BWKM against a source that can be re-opened for each pass.
pub fn stream_bwkm<I, F>(
    open: F,
    d: usize,
    k: usize,
    cfg: &StreamBwkmCfg,
    rng: &mut crate::util::Rng,
    counter: &DistanceCounter,
) -> Result<StreamBwkmOutcome>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
    F: Fn() -> Result<I>,
{
    use crate::kmeans::init::weighted_kmeanspp;
    use crate::kmeans::{weighted_lloyd, NativeStepper, Stepper};

    // Pass 1: bounding box of the stream.
    let mut bbox: Option<BBox> = None;
    let mut passes = 1usize;
    for chunk in open()? {
        for row in chunk?.chunks_exact(d) {
            match &mut bbox {
                Some(bb) => bb.expand(row),
                None => bbox = Some(BBox::at(row)),
            }
        }
    }
    let bbox = bbox.ok_or_else(|| anyhow::anyhow!("empty stream"))?;
    let mut partition = Partition::root_spatial(bbox, d);

    // Growth passes: streamed Alg. 3 (split heavy × large blocks).
    let mut stats;
    loop {
        passes += 1;
        stats = stream_partition_stats(&partition, d, open()?)?;
        if partition.len() >= cfg.target_blocks {
            break;
        }
        let mut scored: Vec<(f64, usize)> = (0..partition.len())
            .filter(|&b| stats.counts[b] > 1)
            .map(|b| {
                let diag = stats.tight[b].as_ref().map(|t| t.diagonal()).unwrap_or(0.0);
                (diag * stats.counts[b] as f64, b)
            })
            .filter(|&(s, _)| s > 0.0)
            .collect();
        if scored.is_empty() {
            break;
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let budget = (cfg.target_blocks - partition.len()).min(scored.len()).max(1);
        for &(_, b) in scored.iter().take(budget) {
            if let Some(t) = stats.tight[b].clone() {
                let (axis, thr) = t.split_plane();
                partition.split_at(b, axis, thr, None);
            }
        }
    }

    // Seed + boundary-weighted outer loop.
    let (mut reps, mut weights, mut ids) = stats.reps_weights(d);
    let mut centroids = weighted_kmeanspp(&reps, &weights, d, k.min(weights.len()), rng, counter);
    let mut converged = false;
    for _ in 0..cfg.max_outer {
        let out = weighted_lloyd(&reps, &weights, d, &centroids, &cfg.wl, counter);
        centroids = out.centroids.clone();

        // ε from sample-tight diagonals (streamed equivalent of §2.3).
        let eps: Vec<f64> = ids
            .iter()
            .enumerate()
            .map(|(row, &b)| {
                let diag = stats.tight[b].as_ref().map(|t| t.diagonal()).unwrap_or(0.0);
                crate::bwkm::epsilon(diag, out.d1[row], out.d2[row])
            })
            .collect();
        let boundary: Vec<usize> =
            (0..eps.len()).filter(|&i| eps[i] > 0.0).collect();
        if boundary.is_empty() {
            converged = true;
            break;
        }
        // Split every boundary block once (deterministic streamed variant;
        // the in-memory path samples ∝ ε).
        for &row in &boundary {
            let b = ids[row];
            if let Some(t) = stats.tight[b].clone() {
                if stats.counts[b] > 1 && t.diagonal() > 0.0 {
                    let (axis, thr) = t.split_plane();
                    partition.split_at(b, axis, thr, None);
                }
            }
        }
        passes += 1;
        stats = stream_partition_stats(&partition, d, open()?)?;
        let rw = stats.reps_weights(d);
        reps = rw.0;
        weights = rw.1;
        ids = rw.2;
        // Keep the assignment warm for the next inner loop.
        let _ = NativeStepper::new(); // (stepper is stateless between loops)
    }

    Ok(StreamBwkmOutcome { centroids, passes, blocks: partition.len(), converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::prop;

    #[test]
    fn stream_bwkm_matches_in_memory_quality() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(91), case: 0 };
        let ds = Dataset::new(g.blobs(3000, 3, 4, 0.4), 3);
        let data = ds.data.clone();
        let open = move || -> Result<Vec<Result<Vec<f64>>>> {
            Ok(data.chunks(3 * 256).map(|c| Ok(c.to_vec())).collect())
        };
        let counter = DistanceCounter::new();
        let cfg = StreamBwkmCfg {
            target_blocks: 80,
            max_outer: 10,
            wl: crate::kmeans::WLloydCfg::default(),
        };
        let out =
            stream_bwkm(open, 3, 4, &cfg, &mut crate::util::Rng::new(2), &counter).unwrap();
        assert_eq!(out.centroids.len(), 4 * 3);
        assert!(out.passes >= 3);

        // Quality sanity: within 2x of an in-memory BWKM run.
        let c2 = DistanceCounter::new();
        let mut bcfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 4);
        bcfg.max_outer = 10;
        let mem = crate::bwkm::run(&ds, 4, &bcfg, &mut crate::util::Rng::new(2), &c2);
        let eval = DistanceCounter::new();
        let e_stream = crate::metrics::kmeans_error(&ds.data, 3, &out.centroids, &eval);
        let e_mem = crate::metrics::kmeans_error(&ds.data, 3, &mem.centroids, &eval);
        assert!(
            e_stream < e_mem * 2.0 + 1e-9,
            "stream {e_stream} vs in-memory {e_mem}"
        );
    }

    #[test]
    fn stream_bwkm_rejects_empty_stream() {
        let open = || -> Result<Vec<Result<Vec<f64>>>> { Ok(vec![]) };
        let counter = DistanceCounter::new();
        let cfg = StreamBwkmCfg {
            target_blocks: 10,
            max_outer: 3,
            wl: crate::kmeans::WLloydCfg::default(),
        };
        assert!(stream_bwkm(open, 2, 2, &cfg, &mut crate::util::Rng::new(1), &counter).is_err());
    }

    fn chunked(data: &[f64], d: usize, rows_per_chunk: usize) -> Vec<Result<Vec<f64>>> {
        data.chunks(rows_per_chunk * d).map(|c| Ok(c.to_vec())).collect()
    }

    #[test]
    fn prop_stream_stats_match_in_memory() {
        prop::check("stream-stats", 15, |g| {
            let n = g.int(5, 300);
            let d = g.int(1, 4);
            let ds = Dataset::new(g.blobs(n, d, 2, 1.0), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(8);
            for _ in 0..10 {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let stats =
                stream_partition_stats(&p, d, chunked(&ds.data, d, g.int(1, 50))).unwrap();
            assert_eq!(stats.rows, n);
            for (b, blk) in p.blocks.iter().enumerate() {
                assert_eq!(stats.counts[b], blk.weight(), "block {b}");
                if blk.weight() > 0 {
                    for j in 0..d {
                        assert!((stats.sums[b][j] - blk.sum[j]).abs() < 1e-9);
                    }
                }
            }
        });
    }

    #[test]
    fn prop_stream_error_matches_in_memory() {
        prop::check("stream-err", 15, |g| {
            let n = g.int(1, 250);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.cloud(n, d, 2.0), d);
            let cents = g.cloud(k, d, 2.0);
            let c1 = DistanceCounter::new();
            let (rows, sse) =
                stream_assign_err(d, &cents, chunked(&ds.data, d, 17), &c1).unwrap();
            assert_eq!(rows, n);
            let c2 = DistanceCounter::new();
            let full = crate::metrics::kmeans_error(&ds.data, d, &cents, &c2);
            assert!((sse - full).abs() < 1e-9 * full.max(1.0));
            assert_eq!(c1.get(), c2.get());
        });
    }
}
