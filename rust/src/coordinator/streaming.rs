//! Out-of-core BWKM (DESIGN.md §5.1): the massive-data path where the
//! dataset never fits in memory.
//!
//! Chunks come from any *restartable* chunked source — a closure
//! `FnMut() -> Result<I>` yielding an `Iterator<Item = Result<Vec<f64>>>`
//! per pass (e.g. [`crate::data::loader::BinChunks`]). [`StreamSource`]
//! implements the [`RefineSource`] data-access seam over such a source,
//! so the *same* Alg. 2–5 drivers that power `bwkm::run` execute the
//! full boundary-weighted loop while holding only
//! O(chunk + |partition|) rows: per-block statistics live in a
//! [`StreamStats`] side table instead of member lists, sampled rows are
//! fetched by streaming, and every split batch is followed by one
//! statistics pass (the O(n·d)-per-refinement price the paper's
//! Problem 2 discussion assigns to partition updates).
//!
//! **Merge determinism (the §5.1 rule).** Each pass fans a chunk's rows
//! out over sharded chunk workers ([`ChunkCrew`], the `Sharded<B>` idiom
//! of `kmeans::assign`): workers compute only *per-row pure* results
//! (block ids via tree descent, per-row nearest distances), which are
//! concatenated in shard order; every floating-point accumulation —
//! block coordinate sums, tight-box folds are order-free min/max, SSE —
//! is performed by the leader serially in global row order. FP sums are
//! therefore never merged across workers, and the result is bit-identical
//! for every (chunk size, worker count) — and, because a block's members
//! always appear in row order, bit-identical to the in-memory path's
//! incremental member folds (see `bwkm::source`). The conformance suite
//! (`tests/streaming_conformance.rs`) pins [`StreamingBwkm`] `==`
//! `bwkm::run` — same splits, same reps/weights, same centroids, same
//! `DistanceCounter` totals — with no tolerances.
//!
//! **Counting.** Statistics/fetch/extent passes are partition work and
//! tick nothing (DESIGN.md §2.4); the distance bill comes only from the
//! same seeding/Lloyd/ε machinery the in-memory path runs on the (tiny)
//! representative set, plus any explicitly requested streamed E^D
//! evaluation ([`stream_assign_err`], rows·k). Pass counts are reported
//! in [`StreamBwkmOutcome::passes`].

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::bwkm::source::RefineSource;
use crate::bwkm::{run_source_rec, BwkmCfg, StopReason, TracePoint};
use crate::geometry::BBox;
use crate::kmeans::assign::{nearest_in, shard_count, shard_range};
use crate::util::pool::{self, PoolTask};
use crate::kmeans::init::kmeans_par::{kmeans_par_source, ParSource};
use crate::kmeans::init::ParCfg;
use crate::kmeans::{stepper_for, AssignMode, AutoAssigner, EngineStepper, Stepper};
use crate::metrics::{nearest, DistanceCounter};
use crate::obs::{Recorder, Stopwatch};
use crate::partition::Partition;
use crate::util::Rng;

/// Per-block statistics accumulated from a stream (counts, sums and tight
/// boxes — exactly what `Partition::assign_members` computes in-memory,
/// held beside a member-free spatial [`Partition`]).
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub counts: Vec<usize>,
    pub sums: Vec<Vec<f64>>,
    pub tight: Vec<Option<BBox>>,
    pub rows: usize,
}

impl StreamStats {
    /// Flat (reps, weights, block_ids) — same contract (and same
    /// floating-point divisions) as `Partition::reps_weights`, but built
    /// from the stream.
    pub fn reps_weights(&self, d: usize) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        let mut reps = Vec::new();
        let mut weights = Vec::new();
        let mut ids = Vec::new();
        for b in 0..self.counts.len() {
            if self.counts[b] > 0 {
                let inv = 1.0 / self.counts[b] as f64;
                reps.extend(self.sums[b].iter().map(|s| s * inv));
                weights.push(self.counts[b] as f64);
                ids.push(b);
                debug_assert_eq!(self.sums[b].len(), d);
            }
        }
        (reps, weights, ids)
    }

    /// Number of non-empty blocks.
    pub fn occupied(&self) -> usize {
        self.counts.iter().filter(|&&c| c > 0).count()
    }
}

/// Validated row count of one chunk: a chunk whose length is not a
/// multiple of `d` is a short read / corruption, never silently dropped.
fn chunk_row_count(chunk: &[f64], d: usize) -> Result<usize> {
    if chunk.len() % d != 0 {
        bail!("ragged chunk: {} values is not a multiple of d={d}", chunk.len());
    }
    Ok(chunk.len() / d)
}

/// Below this many rows per chunk the worker fan-out costs more than it
/// saves; the leader computes such chunks itself (bit-identical either
/// way — workers only ever compute per-row pure results).
const PAR_MIN_ROWS: usize = 64;

/// The streamed-pass worker crew — the `Sharded<B>` idiom of
/// `kmeans::assign` (DESIGN.md §2.5) applied to chunk passes, executed
/// on the shared persistent worker pool ([`crate::util::pool`],
/// DESIGN.md §2.12) instead of per-pass threads: for each chunk, rows
/// are split with the one canonical [`shard_range`] rule, every shard
/// computes a *per-row pure* function on its contiguous row range (no
/// FP accumulation), and the per-shard values are concatenated in shard
/// order. The leader then folds in global row order, so results are
/// bit-identical for every worker count (DESIGN.md §5.1). When the pool
/// slot is busy — e.g. this pass runs inside a scheduler job that
/// already occupies it — shards degrade to leader-inline execution in
/// the same order (the §2.12 oversubscription rule): same bits, only
/// timing changes.
#[derive(Clone, Debug)]
pub struct ChunkCrew {
    threads: usize,
    /// Telemetry handle (DESIGN.md §2.11), default off. When on, each
    /// pass reports the leader's chunk-read time vs. its compute/fold
    /// time as `stream.read` / `stream.compute` spans — the I/O-overlap
    /// split the double-buffered pipeline exists to exploit. Strictly
    /// observational: timing never reorders a fold.
    rec: Recorder,
}

impl ChunkCrew {
    pub fn new(threads: usize) -> ChunkCrew {
        ChunkCrew { threads: threads.max(1), rec: Recorder::off() }
    }

    /// Attach a telemetry recorder (builder-style).
    pub fn with_recorder(mut self, rec: &Recorder) -> ChunkCrew {
        self.rec = rec.clone();
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// One streamed pass: `per_row` is computed for every row (fanned
    /// out over the persistent worker team), then `fold` is called once
    /// per chunk with the chunk and its per-row values, **in stream
    /// order** — all FP accumulation belongs in `fold`, on the leader.
    /// Validates every chunk's shape; returns the total row count.
    fn map_pass<I, T, W, FOLD>(
        &self,
        d: usize,
        chunks: I,
        per_row: W,
        mut fold: FOLD,
    ) -> Result<usize>
    where
        I: IntoIterator<Item = Result<Vec<f64>>>,
        T: Send,
        W: Fn(&[f64]) -> T + Sync,
        FOLD: FnMut(&[f64], Vec<T>) -> Result<()>,
    {
        if d == 0 {
            bail!("dimension must be positive");
        }
        // Read-vs-compute timing is leader-side only and gated on the
        // recorder: when off, the pass takes no clock readings at all.
        let rec = &self.rec;
        let timed = rec.is_on();
        if self.threads == 1 {
            let mut rows = 0usize;
            let mut read_s = 0.0f64;
            let mut work_s = 0.0f64;
            let mut iter = chunks.into_iter();
            loop {
                let t = timed.then(Stopwatch::start);
                let next = iter.next().transpose()?;
                if let Some(w) = t {
                    read_s += w.elapsed_s();
                }
                let Some(chunk) = next else { break };
                let t = timed.then(Stopwatch::start);
                rows += chunk_row_count(&chunk, d)?;
                let vals: Vec<T> = chunk.chunks_exact(d).map(&per_row).collect();
                fold(&chunk, vals)?;
                if let Some(w) = t {
                    work_s += w.elapsed_s();
                }
            }
            if timed {
                rec.span_s("stream.read", read_s);
                rec.span_s("stream.compute", work_s);
            }
            return Ok(rows);
        }
        let per_row = &per_row;
        let pool = pool::global();
        // Double-buffered pipeline on the shared pool: while the pool
        // chews chunk N (published with `defer`, leader not
        // participating), the leader reads chunk N+1 from the (possibly
        // disk-bound) source, then joins N and folds its per-shard values
        // — fold order is stream order, so the §5.1 determinism rule is
        // untouched; only the read latency hides behind compute.
        let mut rows = 0usize;
        let mut read_s = 0.0f64;
        let mut work_s = 0.0f64;
        let mut iter = chunks.into_iter();
        // The deferred job: the boxed task must stay alive and un-moved
        // until the matching `wait` — that is `defer`'s safety contract
        // (the box's heap allocation never moves). `pooled == false`
        // means the slot was busy and the shards already ran inline.
        let mut in_flight: Option<(Box<ChunkTask<'_, T, W>>, bool)> = None;
        loop {
            let t = timed.then(Stopwatch::start);
            // Overlaps in-flight compute. A read error must NOT return
            // yet: the deferred job still holds a pointer into the boxed
            // task, so we join it below before `?` can drop the box.
            let next = iter.next().transpose();
            if let Some(w) = t {
                read_s += w.elapsed_s();
            }
            let t = timed.then(Stopwatch::start);
            if let Some((task, pooled)) = in_flight.take() {
                if pooled {
                    pool.wait();
                }
                // Ordered reduction: slot order == shard order == row
                // order.
                let mut vals: Vec<T> = Vec::with_capacity(task.chunk.len() / d);
                for slot in &task.slots {
                    vals.extend(
                        slot.lock()
                            .expect("chunk slot poisoned")
                            .take()
                            .expect("pool shard never ran"),
                    );
                }
                fold(task.chunk.as_slice(), vals)?;
            }
            let chunk = match next? {
                Some(chunk) => chunk,
                None => {
                    if let Some(w) = t {
                        work_s += w.elapsed_s();
                    }
                    break;
                }
            };
            let n = chunk_row_count(&chunk, d)?;
            rows += n;
            if n < PAR_MIN_ROWS {
                let vals: Vec<T> = chunk.chunks_exact(d).map(per_row).collect();
                fold(&chunk, vals)?;
            } else {
                let shards = shard_count(n, self.threads);
                let task = Box::new(ChunkTask {
                    chunk,
                    d,
                    shards,
                    per_row,
                    slots: (0..shards).map(|_| std::sync::Mutex::new(None)).collect(),
                });
                // Safety: the box is parked in `in_flight` until the
                // `wait` at the top of the next loop turn.
                let pooled = unsafe { pool.defer(shards, &*task) };
                if !pooled {
                    // Busy slot (§2.12 oversubscription rule): run the
                    // same shards inline in the same order — same bits.
                    for s in 0..shards {
                        task.run(s);
                    }
                }
                in_flight = Some((task, pooled));
            }
            if let Some(w) = t {
                work_s += w.elapsed_s();
            }
        }
        if timed {
            rec.span_s("stream.read", read_s);
            rec.span_s("stream.compute", work_s);
            pool.record_metrics(rec);
        }
        Ok(rows)
    }
}

/// One chunk's per-row map as a pool job (DESIGN.md §2.12): shard `s`
/// maps the rows of its canonical [`shard_range`] and parks the values
/// in its own slot, so writes are disjoint; the leader drains the slots
/// in shard order (== row order) after joining, which keeps the §5.1
/// merge rule byte-for-byte.
struct ChunkTask<'a, T, W> {
    chunk: Vec<f64>,
    d: usize,
    shards: usize,
    per_row: &'a W,
    slots: Vec<std::sync::Mutex<Option<Vec<T>>>>,
}

impl<T: Send, W: Fn(&[f64]) -> T + Sync> PoolTask for ChunkTask<'_, T, W> {
    fn run(&self, s: usize) {
        let n = self.chunk.len() / self.d;
        let r = shard_range(n, self.shards, s);
        let vals: Vec<T> = self.chunk[r.start * self.d..r.end * self.d]
            .chunks_exact(self.d)
            .map(self.per_row)
            .collect();
        *self.slots[s].lock().expect("chunk slot poisoned") = Some(vals);
    }
}

/// One pass over a chunked source, locating every row through the
/// partition tree and folding per-block statistics in global row order
/// (the §5.1 merge rule). O(chunk + |partition|) memory.
pub fn stream_partition_stats<I>(
    partition: &Partition,
    d: usize,
    chunks: I,
) -> Result<StreamStats>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    stream_partition_stats_with(partition, d, chunks, &ChunkCrew::new(1))
}

/// [`stream_partition_stats`] with locate fanned out over a
/// [`ChunkCrew`]; bit-identical to the serial form for every crew size.
pub fn stream_partition_stats_with<I>(
    partition: &Partition,
    d: usize,
    chunks: I,
    crew: &ChunkCrew,
) -> Result<StreamStats>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    let nb = partition.len();
    let mut stats = StreamStats {
        counts: vec![0; nb],
        sums: vec![vec![0.0; d]; nb],
        tight: vec![None; nb],
        rows: 0,
    };
    // Workers locate (per-row pure, no distance computations); the
    // leader folds counts/sums/boxes in global row order (§5.1).
    let rows = crew.map_pass(
        d,
        chunks,
        |row| partition.locate(row) as u32,
        |chunk, ids| {
            for (r, row) in chunk.chunks_exact(d).enumerate() {
                let b = ids[r] as usize;
                stats.counts[b] += 1;
                for j in 0..d {
                    stats.sums[b][j] += row[j];
                }
                match &mut stats.tight[b] {
                    Some(bb) => bb.expand(row),
                    None => stats.tight[b] = Some(BBox::at(row)),
                }
            }
            Ok(())
        },
    )?;
    stats.rows = rows;
    Ok(stats)
}

/// Streaming E^D evaluation: assignment + SSE over a chunked source.
/// Counts rows·k distances. Returns (rows, sse); bit-identical to
/// `metrics::kmeans_error` on the materialized data.
pub fn stream_assign_err<I>(
    d: usize,
    centroids: &[f64],
    chunks: I,
    counter: &DistanceCounter,
) -> Result<(usize, f64)>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    stream_assign_err_with(d, centroids, chunks, counter, &ChunkCrew::new(1))
}

/// [`stream_assign_err`] with the per-row distance work fanned out over a
/// [`ChunkCrew`]; the SSE is still folded by the leader in row order, so
/// the sum is bit-identical for every crew size.
pub fn stream_assign_err_with<I>(
    d: usize,
    centroids: &[f64],
    chunks: I,
    counter: &DistanceCounter,
    crew: &ChunkCrew,
) -> Result<(usize, f64)>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    // Workers compute per-row nearest distances through the reference
    // kernel (`metrics::nearest` — the same per-row function
    // `kmeans_error` uses; the counter is atomic, so the rows·k total is
    // worker-count independent); the leader folds the SSE in global row
    // order, so the sum is bit-identical for every crew size.
    let mut sse = 0.0;
    let rows = crew.map_pass(
        d,
        chunks,
        |row| nearest(row, centroids, d, counter).1,
        |_chunk, d1s| {
            for dd in d1s {
                sse += dd;
            }
            Ok(())
        },
    )?;
    Ok((rows, sse))
}

/// Extent pass: row count, bounding box and total coordinate sum of the
/// stream — the root-block statistics (`Partition::root` computes the
/// same three quantities in-memory, in the same fold order). This first
/// pass also enforces the finite-data guard every in-memory entry point
/// gets from `Dataset::is_finite`: a NaN/Inf value would silently poison
/// bbox folds and tree descents, so it is a loud error here instead.
fn pass_extent<I>(d: usize, chunks: I) -> Result<(usize, Option<BBox>, Vec<f64>)>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    let mut rows = 0usize;
    let mut bbox: Option<BBox> = None;
    let mut sum = vec![0.0; d];
    for chunk in chunks {
        let chunk = chunk?;
        chunk_row_count(&chunk, d)?;
        for row in chunk.chunks_exact(d) {
            if let Some(j) = (0..d).find(|&j| !row[j].is_finite()) {
                bail!("stream contains a non-finite value at row {rows}, column {j}");
            }
            for j in 0..d {
                sum[j] += row[j];
            }
            match &mut bbox {
                Some(bb) => bb.expand(row),
                None => bbox = Some(BBox::at(row)),
            }
            rows += 1;
        }
    }
    Ok((rows, bbox, sum))
}

/// Fetch pass: the rows at the given dataset indices, flat `idx.len()×d`
/// in `idx` order (duplicates allowed), plus the stream's total row count
/// for cross-pass validation. O(idx + chunk) memory.
fn pass_fetch<I>(d: usize, chunks: I, idx: &[usize]) -> Result<(Vec<f64>, usize)>
where
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    let mut want: HashMap<usize, Vec<usize>> = HashMap::new();
    for (pos, &i) in idx.iter().enumerate() {
        want.entry(i).or_default().push(pos);
    }
    let mut out = vec![0.0; idx.len() * d];
    let mut found = 0usize;
    let mut row_id = 0usize;
    for chunk in chunks {
        let chunk = chunk?;
        chunk_row_count(&chunk, d)?;
        for row in chunk.chunks_exact(d) {
            if let Some(positions) = want.get(&row_id) {
                for &pos in positions {
                    out[pos * d..(pos + 1) * d].copy_from_slice(row);
                }
                found += positions.len();
            }
            row_id += 1;
        }
    }
    if found != idx.len() {
        bail!(
            "sample fetch found {found} of {} requested rows (stream has {row_id} rows)",
            idx.len()
        );
    }
    Ok((out, row_id))
}

/// [`RefineSource`] over a restartable chunked source: the spatial
/// [`Partition`] plus a [`StreamStats`] side table stand in for member
/// bookkeeping, and every statistic is (re)established by streamed
/// passes. A failed pass leaves the previously committed statistics in
/// place (commit-on-success), and every pass validates chunk integrity
/// and the cross-pass row count, so a source that shrinks, grows or
/// short-reads between passes surfaces as a clean `Err`.
pub struct StreamSource<F> {
    open: F,
    d: usize,
    n: usize,
    partition: Partition,
    stats: StreamStats,
    crew: ChunkCrew,
    passes: usize,
    /// Splits applied since the last committed statistics pass.
    dirty: bool,
    /// Telemetry (DESIGN.md §2.11): pass-kind spans + a pass-count gauge.
    rec: Recorder,
}

impl<F, I> StreamSource<F>
where
    F: FnMut() -> Result<I>,
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    /// Open the source once (the extent pass) and stand up the root
    /// partition over the stream's bounding box.
    pub fn new(open: F, d: usize, threads: usize) -> Result<StreamSource<F>> {
        StreamSource::new_rec(open, d, threads, &Recorder::off())
    }

    /// [`StreamSource::new`] with telemetry (DESIGN.md §2.11): the extent
    /// pass is spanned as `stream.extent`, every later pass as
    /// `stream.fetch` / `stream.refresh` / `stream.eval`, the crew splits
    /// each pass into `stream.read` vs `stream.compute`, and the running
    /// pass count is the `stream.passes` gauge. Strictly observational.
    pub fn new_rec(
        mut open: F,
        d: usize,
        threads: usize,
        rec: &Recorder,
    ) -> Result<StreamSource<F>> {
        if d == 0 {
            bail!("dimension must be positive");
        }
        let extent_span = rec.span("stream.extent");
        let (rows, bbox, sum) = pass_extent(d, open()?)?;
        drop(extent_span);
        rec.gauge_u64("stream.rows", rows as u64);
        let bbox = bbox.ok_or_else(|| anyhow!("empty stream"))?;
        let partition = Partition::root_spatial(bbox.clone(), d);
        let stats = StreamStats {
            counts: vec![rows],
            sums: vec![sum],
            tight: vec![Some(bbox)],
            rows,
        };
        Ok(StreamSource {
            open,
            d,
            n: rows,
            partition,
            stats,
            crew: ChunkCrew::new(threads).with_recorder(rec),
            passes: 1,
            dirty: false,
            rec: rec.clone(),
        })
    }

    /// Streaming passes over the source so far.
    pub fn passes(&self) -> usize {
        self.passes
    }

    /// The committed per-block statistics.
    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// Surrender the spatial partition (blocks carry no members; the
    /// statistics live in [`stats`](Self::stats)).
    pub fn into_partition(self) -> Partition {
        self.partition
    }

    fn open_pass(&mut self) -> Result<I> {
        self.passes += 1;
        self.rec.gauge_u64("stream.passes", self.passes as u64);
        (self.open)()
    }
}

impl<F, I> RefineSource for StreamSource<F>
where
    F: FnMut() -> Result<I>,
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn fetch_rows(&mut self, idx: &[usize]) -> Result<Vec<f64>> {
        let _fetch_span = self.rec.span("stream.fetch");
        let chunks = self.open_pass()?;
        let (rows, seen) = pass_fetch(self.d, chunks, idx)?;
        if seen != self.n {
            bail!("source changed between passes: {seen} rows, expected {}", self.n);
        }
        Ok(rows)
    }

    fn partition(&self) -> &Partition {
        &self.partition
    }

    fn weight(&self, b: usize) -> usize {
        // Valid mid-split-batch only for blocks not split in the batch —
        // exactly how the drivers use it (split targets are distinct).
        self.stats.counts[b]
    }

    fn occupied(&self) -> usize {
        debug_assert!(!self.dirty, "occupied() before refresh()");
        self.stats.occupied()
    }

    fn diagonal(&self, b: usize) -> f64 {
        debug_assert!(!self.dirty, "diagonal() before refresh()");
        match &self.stats.tight[b] {
            Some(bb) => bb.diagonal(),
            None => self.partition.blocks[b].cell.diagonal(),
        }
    }

    fn reps_weights(&self) -> (Vec<f64>, Vec<f64>, Vec<usize>) {
        debug_assert!(!self.dirty, "reps_weights() before refresh()");
        self.stats.reps_weights(self.d)
    }

    fn split(&mut self, b: usize) {
        // The paper's cutting rule on the streamed statistics: tight
        // member bbox when the block is non-empty, spatial cell otherwise
        // (the same effective-bbox rule as `Partition::split`).
        let (axis, thr) = match &self.stats.tight[b] {
            Some(bb) => bb.split_plane(),
            None => self.partition.blocks[b].cell.split_plane(),
        };
        self.partition.split_at(b, axis, thr, None);
        self.dirty = true;
    }

    fn refresh(&mut self) -> Result<()> {
        if !self.dirty {
            return Ok(()); // committed stats are already current
        }
        let _refresh_span = self.rec.span("stream.refresh");
        let chunks = self.open_pass()?;
        let stats = stream_partition_stats_with(&self.partition, self.d, chunks, &self.crew)?;
        if stats.rows != self.n {
            bail!("source changed between passes: {} rows, expected {}", stats.rows, self.n);
        }
        self.stats = stats; // commit only on success
        self.dirty = false;
        Ok(())
    }

    fn full_error(&mut self, centroids: &[f64]) -> Result<f64> {
        let _eval_span = self.rec.span("stream.eval");
        let eval = DistanceCounter::new(); // uncounted instrumentation
        let chunks = self.open_pass()?;
        let crew = self.crew.clone();
        let (rows, sse) = stream_assign_err_with(self.d, centroids, chunks, &eval, &crew)?;
        if rows != self.n {
            bail!("source changed between passes: {rows} rows, expected {}", self.n);
        }
        Ok(sse)
    }
}

/// Outcome of a [`StreamingBwkm`] run: everything `bwkm::run` reports,
/// plus the final representative set (the partition's blocks carry no
/// members out of core) and the number of streaming passes consumed.
#[derive(Clone, Debug)]
pub struct StreamBwkmOutcome {
    pub centroids: Vec<f64>,
    pub k: usize,
    pub d: usize,
    pub stop: StopReason,
    pub trace: Vec<TracePoint>,
    /// Final spatial partition (member-free blocks).
    pub partition: Partition,
    /// Final flat representatives / weights / block ids — what
    /// `partition.reps_weights()` returns on the in-memory side.
    pub reps: Vec<f64>,
    pub weights: Vec<f64>,
    pub ids: Vec<usize>,
    /// Streaming passes over the source (extent + sample fetches +
    /// statistics refreshes + any `eval_full_error` evaluations).
    pub passes: usize,
    /// Last inner step's top-2 squared distances per non-empty block
    /// (pre-update centroids) — see `bwkm::BwkmOutcome::d1`; the model
    /// store persists them verbatim.
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

/// The out-of-core BWKM coordinator: the full Alg. 5 loop (initial
/// partition, weighted Lloyd through any engine backend, ε-guided
/// refinement, §2.4.2 stopping) against a restartable chunked source,
/// pinned **bit-identical** to the in-memory `bwkm::run`/`run_auto` on
/// the same data and seed (DESIGN.md §5.1).
pub struct StreamingBwkm<F> {
    open: F,
    d: usize,
    threads: usize,
}

impl<F, I> StreamingBwkm<F>
where
    F: FnMut() -> Result<I>,
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    /// A coordinator over `open`, which must yield the same chunked rows
    /// on every call (chunk *boundaries* may differ between passes;
    /// values and row order may not).
    pub fn new(open: F, d: usize) -> StreamingBwkm<F> {
        StreamingBwkm { open, d, threads: 1 }
    }

    /// Fan each streamed pass out over `threads` chunk workers
    /// (bit-identical results for every value — the §5.1 merge rule).
    pub fn with_threads(mut self, threads: usize) -> StreamingBwkm<F> {
        self.threads = threads.max(1);
        self
    }

    /// Run with the stepper `cfg.assign` selects (DESIGN.md §2.9; the
    /// exact default is the serial native engine) — the streamed twin of
    /// [`crate::bwkm::run`].
    pub fn run(
        &mut self,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Result<StreamBwkmOutcome> {
        self.run_rec(k, cfg, rng, counter, &Recorder::off())
    }

    /// [`StreamingBwkm::run`] with telemetry (DESIGN.md §2.11).
    pub fn run_rec(
        &mut self,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
        rec: &Recorder,
    ) -> Result<StreamBwkmOutcome> {
        let mut stepper = stepper_for(&cfg.assign);
        self.run_with_rec(stepper.as_mut(), k, cfg, rng, counter, rec)
    }

    /// Run with the auto-selecting engine (serial / norm-pruned /
    /// bounded per inner step, DESIGN.md §2.7) — the streamed twin of
    /// [`crate::bwkm::run_auto`]: same trajectory, smaller bill. With
    /// `assign = closure` the selector additionally learns the closure
    /// backend (§2.9); `assign = sampled` has nothing for the selector
    /// to choose between and delegates to [`StreamingBwkm::run`].
    pub fn run_auto(
        &mut self,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Result<StreamBwkmOutcome> {
        self.run_auto_rec(k, cfg, rng, counter, &Recorder::off())
    }

    /// [`StreamingBwkm::run_auto`] with telemetry (DESIGN.md §2.11).
    pub fn run_auto_rec(
        &mut self,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
        rec: &Recorder,
    ) -> Result<StreamBwkmOutcome> {
        match cfg.assign.mode {
            AssignMode::Closure => {
                let mut stepper =
                    EngineStepper::with_engine(AutoAssigner::with_closure(cfg.assign.closure_expand));
                self.run_with_rec(&mut stepper, k, cfg, rng, counter, rec)
            }
            AssignMode::Sampled => self.run_rec(k, cfg, rng, counter, rec),
            AssignMode::Exact => {
                let mut stepper: EngineStepper<AutoAssigner> = EngineStepper::new();
                self.run_with_rec(&mut stepper, k, cfg, rng, counter, rec)
            }
        }
    }

    /// Run over an arbitrary weighted-Lloyd [`Stepper`] backend.
    pub fn run_with(
        &mut self,
        stepper: &mut dyn Stepper,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Result<StreamBwkmOutcome> {
        self.run_with_rec(stepper, k, cfg, rng, counter, &Recorder::off())
    }

    /// [`StreamingBwkm::run_with`] with telemetry (DESIGN.md §2.11):
    /// Alg. 5 spans/gauges from [`run_source_rec`] plus the streaming
    /// pass machinery's `stream.*` spans. Strictly observational — the
    /// outcome is bit-identical with `rec` on or off
    /// (`tests/obs_conformance.rs`).
    pub fn run_with_rec(
        &mut self,
        stepper: &mut dyn Stepper,
        k: usize,
        cfg: &BwkmCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
        rec: &Recorder,
    ) -> Result<StreamBwkmOutcome> {
        if k < 1 {
            bail!("k must be ≥ 1");
        }
        let mut src = StreamSource::new_rec(&mut self.open, self.d, self.threads, rec)?;
        if src.n() < k {
            bail!("n must be ≥ k (stream has {} rows, k={k})", src.n());
        }
        let out = run_source_rec(stepper, &mut src, k, cfg, rng, counter, rec)?;
        let (reps, weights, ids) = src.reps_weights();
        let passes = src.passes();
        Ok(StreamBwkmOutcome {
            centroids: out.centroids,
            k: out.k,
            d: out.d,
            stop: out.stop,
            trace: out.trace,
            reps,
            weights,
            ids,
            passes,
            d1: out.d1,
            d2: out.d2,
            partition: src.into_partition(),
        })
    }
}

// ---------------------------------------------------------------------------
// Out-of-core seeding (DESIGN.md §2.8).
// ---------------------------------------------------------------------------

/// The streamed [`ParSource`]: each K-means|| round is **one** chunked
/// pass over the restartable source. Workers compute only the per-row
/// pure nearest-candidate value ([`nearest_in`] against the round's
/// batch — bit-identical to the in-memory engine refresh, §2.1); the
/// leader replays every row in global row order through the shared
/// driver's `visit` fold, which owns all FP accumulation (ψ, candidate
/// masses) and every RNG draw — the §5.1 merge-determinism rule applied
/// to seeding. Per-row side state (min-distance, nearest-candidate id)
/// lives with the driver: O(n) *scalars*, a factor d smaller than
/// materializing the rows.
struct StreamParSource<'a, F> {
    open: &'a mut F,
    d: usize,
    n: usize,
    crew: ChunkCrew,
    passes: usize,
}

impl<F, I> ParSource for StreamParSource<'_, F>
where
    F: FnMut() -> Result<I>,
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    fn rows(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn fetch(&mut self, idx: usize) -> Result<Vec<f64>> {
        self.passes += 1;
        let (row, seen) = pass_fetch(self.d, (self.open)()?, &[idx])?;
        if seen != self.n {
            bail!("source changed between passes: {seen} rows, expected {}", self.n);
        }
        Ok(row)
    }

    fn pass(
        &mut self,
        batch: &[f64],
        counter: &DistanceCounter,
        visit: &mut dyn FnMut(usize, &[f64], f64, u32),
    ) -> Result<()> {
        self.passes += 1;
        let d = self.d;
        let b = batch.len() / d;
        let n = self.n;
        let mut gi = 0usize;
        let chunks = (self.open)()?;
        let crew = &self.crew;
        let rows = crew.map_pass(
            d,
            chunks,
            |row| nearest_in(row, batch, d),
            |chunk, vals| {
                for (r, row) in chunk.chunks_exact(d).enumerate() {
                    // The driver's fold state is sized to the count
                    // pass's row total: a source that *grows* between
                    // passes must be a clean Err before the extra row
                    // reaches `visit` (the shrink case is caught by the
                    // row-count check after the pass).
                    if gi >= n {
                        bail!("source changed between passes: more than {n} rows");
                    }
                    let (dnew, jnew) = vals[r];
                    visit(gi, row, dnew, jnew);
                    gi += 1;
                }
                Ok(())
            },
        )?;
        if rows != self.n {
            bail!("source changed between passes: {rows} rows, expected {}", self.n);
        }
        // rows·b, exactly the engine's bill for the same refresh (§2.4).
        counter.add((rows as u64) * (b as u64));
        Ok(())
    }
}

/// Outcome of a streamed seeding run.
#[derive(Clone, Debug)]
pub struct StreamSeedOutcome {
    /// Flat k×d centroids — bit-identical to the in-memory seeder's.
    pub centroids: Vec<f64>,
    /// Candidates |C| the K-means|| rounds accumulated.
    pub candidates: usize,
    /// Rows in the stream.
    pub rows: usize,
    /// Streaming passes consumed (count + c₀ fetch + prime + rounds +
    /// final refresh).
    pub passes: usize,
}

/// Out-of-core seeding over a restartable chunked source (DESIGN.md
/// §2.8): true K-means|| seeding of a dataset that never fits in memory,
/// pinned **bit-identical** — centroids, `DistanceCounter` totals and
/// notes — to [`crate::kmeans::init::KmeansParSeeder`] on the
/// materialized rows with unit weights, for every chunk size and worker
/// count (`tests/init_conformance.rs`).
pub struct StreamSeeder<F> {
    open: F,
    d: usize,
    threads: usize,
}

impl<F, I> StreamSeeder<F>
where
    F: FnMut() -> Result<I>,
    I: IntoIterator<Item = Result<Vec<f64>>>,
{
    /// A seeder over `open`, which must yield the same chunked rows on
    /// every call (chunk boundaries may differ between passes).
    pub fn new(open: F, d: usize) -> StreamSeeder<F> {
        StreamSeeder { open, d, threads: 1 }
    }

    /// Fan each pass's per-row work out over `threads` chunk workers
    /// (bit-identical results for every value — the §5.1 merge rule).
    pub fn with_threads(mut self, threads: usize) -> StreamSeeder<F> {
        self.threads = threads.max(1);
        self
    }

    /// Streamed K-means|| (unit row weights — the raw-instance shape).
    pub fn kmeans_par(
        &mut self,
        k: usize,
        cfg: &ParCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Result<StreamSeedOutcome> {
        self.kmeans_par_rec(k, cfg, rng, counter, &Recorder::off())
    }

    /// [`StreamSeeder::kmeans_par`] with telemetry (DESIGN.md §2.11): the
    /// whole seeding run is the `seed.kmeans_par` span (the count pass is
    /// `seed.count`), and round structure lands as `seed.rounds` /
    /// `seed.candidates` / `seed.rows` / `seed.passes` gauges. Strictly
    /// observational.
    pub fn kmeans_par_rec(
        &mut self,
        k: usize,
        cfg: &ParCfg,
        rng: &mut Rng,
        counter: &DistanceCounter,
        rec: &Recorder,
    ) -> Result<StreamSeedOutcome> {
        if self.d == 0 {
            bail!("dimension must be positive");
        }
        if k < 1 {
            bail!("k must be ≥ 1");
        }
        let _seed_span = rec.span("seed.kmeans_par");
        // Count pass: row total + chunk-shape validation, plus the same
        // finite-data guard as `pass_extent`: a NaN/Inf value would
        // poison every min-distance fold (NaN fails every strict `<`, so
        // ψ saturates at ∞ and no round could ever sample a batch — the
        // seeder would silently return k copies of c₀), so it is a loud
        // error here instead.
        let count_span = rec.span("seed.count");
        let mut rows = 0usize;
        for chunk in (self.open)()? {
            let chunk = chunk?;
            chunk_row_count(&chunk, self.d)?;
            for row in chunk.chunks_exact(self.d) {
                if let Some(j) = (0..self.d).find(|&j| !row[j].is_finite()) {
                    bail!("stream contains a non-finite value at row {rows}, column {j}");
                }
                rows += 1;
            }
        }
        drop(count_span);
        if rows == 0 {
            bail!("empty stream");
        }
        let weights = vec![1.0f64; rows];
        let mut src = StreamParSource {
            open: &mut self.open,
            d: self.d,
            n: rows,
            crew: ChunkCrew::new(self.threads).with_recorder(rec),
            passes: 1,
        };
        let (centroids, stats) = kmeans_par_source(&mut src, &weights, k, cfg, rng, counter)?;
        let passes = src.passes;
        rec.gauge_u64("seed.rounds", stats.batches.len() as u64);
        rec.gauge_u64("seed.candidates", stats.candidates as u64);
        rec.gauge_u64("seed.rows", rows as u64);
        rec.gauge_u64("seed.passes", passes as u64);
        Ok(StreamSeedOutcome { centroids, candidates: stats.candidates, rows, passes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::prop;

    fn chunked(data: &[f64], d: usize, rows_per_chunk: usize) -> Vec<Result<Vec<f64>>> {
        data.chunks(rows_per_chunk * d).map(|c| Ok(c.to_vec())).collect()
    }

    fn vec_opener(
        data: Vec<f64>,
        d: usize,
        rows_per_chunk: usize,
    ) -> impl FnMut() -> Result<Vec<Result<Vec<f64>>>> {
        move || Ok(chunked(&data, d, rows_per_chunk))
    }

    #[test]
    fn streaming_bwkm_is_bit_identical_to_in_memory() {
        // The tentpole property in miniature (the full grid lives in
        // tests/streaming_conformance.rs): same data, same seed — same
        // centroids, same stop, same bill, to the bit.
        let mut g = prop::Gen { rng: crate::util::Rng::new(91), case: 0 };
        let ds = Dataset::new(g.blobs(700, 3, 4, 0.4), 3);
        let mut cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 4);
        cfg.max_outer = 6;

        let c_mem = DistanceCounter::new();
        let mem = crate::bwkm::run(&ds, 4, &cfg, &mut crate::util::Rng::new(2), &c_mem);

        let c_str = DistanceCounter::new();
        let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), 3, 97), 3).with_threads(3);
        let out = sb.run(4, &cfg, &mut crate::util::Rng::new(2), &c_str).unwrap();

        assert_eq!(out.centroids, mem.centroids);
        assert_eq!(out.stop, mem.stop);
        assert_eq!(c_str.get(), c_mem.get());
        let (mreps, mweights, mids) = mem.partition.reps_weights();
        assert_eq!(out.reps, mreps);
        assert_eq!(out.weights, mweights);
        assert_eq!(out.ids, mids);
        assert!(out.passes >= 2, "at least the extent pass plus one fetch");
    }

    #[test]
    fn recorder_does_not_perturb_the_streamed_run() {
        // The §2.11 contract in miniature (the full grid lives in
        // tests/obs_conformance.rs): metrics on vs off — same centroids,
        // same passes, same bill, to the bit; and the recorder saw the
        // pass machinery.
        let mut g = prop::Gen { rng: crate::util::Rng::new(93), case: 0 };
        let ds = Dataset::new(g.blobs(400, 2, 3, 0.5), 2);
        let mut cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 3);
        cfg.max_outer = 4;

        let c_off = DistanceCounter::new();
        let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), 2, 61), 2).with_threads(2);
        let off = sb.run(3, &cfg, &mut crate::util::Rng::new(5), &c_off).unwrap();

        let rec = Recorder::summary();
        let c_on = DistanceCounter::new();
        let mut sb2 = StreamingBwkm::new(vec_opener(ds.data.clone(), 2, 61), 2).with_threads(2);
        let on = sb2.run_rec(3, &cfg, &mut crate::util::Rng::new(5), &c_on, &rec).unwrap();

        assert_eq!(on.centroids, off.centroids);
        assert_eq!(on.stop, off.stop);
        assert_eq!(on.passes, off.passes);
        assert_eq!(c_on.get(), c_off.get());
        assert_eq!(rec.gauge_last("stream.passes"), Some(on.passes as f64));
        assert!(rec.span_stats("stream.extent").is_some(), "extent pass was spanned");
        assert!(rec.span_stats("stream.read").is_some(), "read timing was recorded");
        assert!(rec.span_stats("stream.compute").is_some(), "compute timing was recorded");
    }

    #[test]
    fn streaming_bwkm_rejects_empty_stream() {
        let mut sb = StreamingBwkm::new(|| Ok(Vec::<Result<Vec<f64>>>::new()), 2);
        let cfg = crate::bwkm::BwkmCfg::for_dataset(10, 2, 2);
        let c = DistanceCounter::new();
        assert!(sb.run(2, &cfg, &mut crate::util::Rng::new(1), &c).is_err());
    }

    #[test]
    fn ragged_chunk_is_a_clean_error() {
        let ds = Dataset::new(vec![0.0; 20], 2);
        let p = Partition::root(&ds);
        // 5 values with d=2: not a multiple — must Err, not silently drop.
        let chunks: Vec<Result<Vec<f64>>> = vec![Ok(vec![0.0; 5])];
        assert!(stream_partition_stats(&p, 2, chunks).is_err());
        let chunks: Vec<Result<Vec<f64>>> = vec![Ok(vec![0.0; 5])];
        let c = DistanceCounter::new();
        assert!(stream_assign_err(2, &[0.0, 0.0], chunks, &c).is_err());
    }

    #[test]
    fn refresh_failure_preserves_committed_stats() {
        // Pass 1 (extent) and pass 2 (fetch-free refresh) see different
        // sources: the refresh must fail cleanly and leave the committed
        // statistics untouched.
        let data: Vec<f64> = (0..40).map(|x| x as f64).collect();
        let mut opens = 0usize;
        let open = move || -> Result<Vec<Result<Vec<f64>>>> {
            opens += 1;
            if opens == 1 {
                Ok(data.chunks(10).map(|c| Ok(c.to_vec())).collect())
            } else {
                // Second pass drops the last row: row-count mismatch.
                Ok(data[..38].chunks(10).map(|c| Ok(c.to_vec())).collect())
            }
        };
        let mut src = StreamSource::new(open, 2, 1).unwrap();
        let before = src.stats().clone();
        src.split(0);
        let err = src.refresh();
        assert!(err.is_err(), "shrinking source must fail the refresh");
        assert_eq!(src.stats().counts, before.counts, "failed refresh must not commit");
        assert_eq!(src.stats().rows, before.rows);
    }

    #[test]
    fn prop_stream_stats_match_in_memory() {
        prop::check("stream-stats", 15, |g| {
            let n = g.int(5, 300);
            let d = g.int(1, 4);
            let ds = Dataset::new(g.blobs(n, d, 2, 1.0), d);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(8);
            for _ in 0..10 {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let stats =
                stream_partition_stats(&p, d, chunked(&ds.data, d, g.int(1, 50))).unwrap();
            assert_eq!(stats.rows, n);
            for (b, blk) in p.blocks.iter().enumerate() {
                assert_eq!(stats.counts[b], blk.weight(), "block {b}");
                if blk.weight() > 0 {
                    for j in 0..d {
                        // Bit-identity, not closeness: both are sequential
                        // member folds in row order.
                        assert_eq!(
                            stats.sums[b][j].to_bits(),
                            blk.sum[j].to_bits(),
                            "block {b} dim {j}"
                        );
                    }
                    assert_eq!(stats.tight[b], blk.tight, "block {b}");
                }
            }
        });
    }

    #[test]
    fn prop_crew_sizes_are_bit_identical() {
        // The §5.1 merge rule in isolation: any worker count, any chunk
        // size — same stats, same SSE, same counter, to the bit.
        prop::check("stream-crew", 10, |g| {
            let n = g.int(80, 400);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.blobs(n, d, 3, 1.0), d);
            let cents = g.cloud(k, d, 3.0);
            let mut p = Partition::root(&ds);
            let mut rng = g.rng.fork(3);
            for _ in 0..8 {
                let b = rng.usize(p.len());
                p.split(b, &ds);
            }
            let chunk = g.int(1, n + 10);
            let base =
                stream_partition_stats(&p, d, chunked(&ds.data, d, chunk)).unwrap();
            let c_base = DistanceCounter::new();
            let (rows_b, sse_b) =
                stream_assign_err(d, &cents, chunked(&ds.data, d, chunk), &c_base).unwrap();
            for threads in [2usize, 5, 8] {
                let crew = ChunkCrew::new(threads);
                let st = stream_partition_stats_with(
                    &p,
                    d,
                    chunked(&ds.data, d, chunk),
                    &crew,
                )
                .unwrap();
                assert_eq!(st.counts, base.counts);
                for b in 0..p.len() {
                    for j in 0..d {
                        assert_eq!(st.sums[b][j].to_bits(), base.sums[b][j].to_bits());
                    }
                    assert_eq!(st.tight[b], base.tight[b]);
                }
                let c = DistanceCounter::new();
                let (rows, sse) = stream_assign_err_with(
                    d,
                    &cents,
                    chunked(&ds.data, d, chunk),
                    &c,
                    &crew,
                )
                .unwrap();
                assert_eq!(rows, rows_b);
                assert_eq!(sse.to_bits(), sse_b.to_bits());
                assert_eq!(c.get(), c_base.get());
            }
        });
    }

    #[test]
    fn prop_stream_error_matches_in_memory() {
        prop::check("stream-err", 15, |g| {
            let n = g.int(1, 250);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let ds = Dataset::new(g.cloud(n, d, 2.0), d);
            let cents = g.cloud(k, d, 2.0);
            let c1 = DistanceCounter::new();
            let (rows, sse) =
                stream_assign_err(d, &cents, chunked(&ds.data, d, 17), &c1).unwrap();
            assert_eq!(rows, n);
            let c2 = DistanceCounter::new();
            let full = crate::metrics::kmeans_error(&ds.data, d, &cents, &c2);
            assert_eq!(sse.to_bits(), full.to_bits(), "row-order fold must match exactly");
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn fetch_rows_match_dataset_rows_in_index_order() {
        let data: Vec<f64> = (0..60).map(|x| x as f64 * 0.5).collect();
        let ds = Dataset::new(data.clone(), 3);
        let mut src = StreamSource::new(vec_opener(data, 3, 7), 3, 2).unwrap();
        let idx = [19usize, 0, 7, 19];
        let rows = src.fetch_rows(&idx).unwrap();
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(&rows[pos * 3..(pos + 1) * 3], ds.row(i), "index {i}");
        }
        assert!(src.fetch_rows(&[99]).is_err(), "out-of-range index must Err");
    }
}
