//! Concurrent job scheduler (DESIGN.md §5.2): multiplex independent
//! clustering jobs over the shared persistent worker pool
//! ([`crate::util::pool`], DESIGN.md §2.12).
//!
//! Each job gets a **private** [`DistanceCounter`] and a deterministic RNG
//! stream forked from the base seed *in job order*, so every job's results
//! and bill are bit-identical no matter how many workers run or which
//! worker happens to pick the job up. Worker lanes pull job indices from a
//! single atomic queue (work stealing degenerates to round-robin when jobs
//! are uniform) and publish into per-job slots; the caller always receives
//! results in job order.
//!
//! **Oversubscription rule (DESIGN.md §2.12).** The scheduler's lanes run
//! as one pool job, so they and any sharded work *inside* a job no longer
//! compete blindly for cores: while the lanes occupy the pool's single
//! slot, a nested `Sharded<B>` assignment or streaming `ChunkCrew` pass
//! finds the slot busy and degrades to leader-inline execution — same
//! shard order, bit-identical outputs, no thread explosion. The wait each
//! job spent queued behind earlier jobs is reported per job as
//! [`JobResult::queue_wait_s`] (the CLI prints it as `wait=`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::metrics::DistanceCounter;
use crate::obs::{Recorder, Stopwatch};
use crate::util::pool::{self, FnTask};
use crate::util::Rng;

/// One job's outcome, with its isolated accounting.
#[derive(Clone, Debug)]
pub struct JobResult<T> {
    /// Job index (also the result's position in the returned vector).
    pub job: usize,
    /// This job's own distance bill — no cross-job bleed.
    pub distances: u64,
    /// This job's counter notes (capped log, pinned summaries last).
    pub notes: Vec<String>,
    /// Wall-clock seconds the job closure ran for. Always measured (two
    /// clock reads per job — the CLI's per-job summary line needs it even
    /// with `metrics=off`); nondeterministic, so never compared by the
    /// conformance suites.
    pub elapsed_s: f64,
    /// Seconds between pool start and this job being claimed by a worker
    /// — the queue wait the shared pool imposed on it. Always measured.
    pub queue_wait_s: f64,
    /// Whatever the job closure returned.
    pub out: T,
}

/// Run `jobs` independent jobs over at most `workers` OS threads.
///
/// `run(job, rng, counter)` executes job `job` with its private RNG stream
/// and counter. Determinism contract: the RNG handed to job `j` depends
/// only on `base_seed` and `j`, so `run_jobs(n, 1, s, f)` and
/// `run_jobs(n, 8, s, f)` return bit-identical results.
pub fn run_jobs<T, F>(jobs: usize, workers: usize, base_seed: u64, run: F) -> Vec<JobResult<T>>
where
    T: Send,
    F: Fn(usize, &mut Rng, &DistanceCounter) -> T + Sync,
{
    run_jobs_rec(jobs, workers, base_seed, &Recorder::off(), |j, rng, counter, _rec| {
        run(j, rng, counter)
    })
}

/// [`run_jobs`] with telemetry (DESIGN.md §2.11): each job runs under its
/// own [`Recorder::job_scope`] — a fresh summary aggregation (per-job
/// metric isolation, mirroring the private `DistanceCounter`) sharing the
/// parent's JSONL trace, every record name prefixed `job<j>.`. Per job:
/// a `job.run` span, a `job.queue_wait_s` gauge and a `job.distances`
/// counter, plus whatever the closure records through its scoped handle.
/// Strictly observational: results are bit-identical with `rec` on or
/// off, and worker-count independence is untouched.
pub fn run_jobs_rec<T, F>(
    jobs: usize,
    workers: usize,
    base_seed: u64,
    rec: &Recorder,
    run: F,
) -> Vec<JobResult<T>>
where
    T: Send,
    F: Fn(usize, &mut Rng, &DistanceCounter, &Recorder) -> T + Sync,
{
    assert!(jobs > 0, "run_jobs needs at least one job");
    let workers = workers.max(1).min(jobs);

    // Fork every job's stream up front, in job order: the seed a job sees
    // must not depend on which worker claims it or when.
    let mut root = Rng::new(base_seed);
    let seeds: Vec<Rng> = (0..jobs).map(|j| root.fork(j as u64 + 1)).collect();

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobResult<T>>>> =
        (0..jobs).map(|_| Mutex::new(None)).collect();
    let run = &run;
    let seeds = &seeds;
    let next = &next;
    let slots = &slots;
    let pool_watch = Stopwatch::start();

    // Each pool shard is one puller lane over the atomic job queue. The
    // lanes occupy the pool's single slot for the whole batch, so nested
    // sharded work inside a job degrades inline (§2.12 — see module docs)
    // instead of oversubscribing the machine. Inline fallback (busy pool,
    // zero workers) means lane 0 drains the whole queue serially:
    // bit-identical results either way, since job state depends only on
    // the job index.
    let lanes = FnTask(|_lane: usize| loop {
        let job = next.fetch_add(1, Ordering::Relaxed);
        if job >= jobs {
            break;
        }
        let queue_wait_s = pool_watch.elapsed_s();
        let mut rng = seeds[job].clone();
        let counter = DistanceCounter::new();
        let jrec = rec.job_scope(job);
        jrec.gauge("job.queue_wait_s", queue_wait_s);
        let watch = Stopwatch::start();
        let out = {
            let _job_span = jrec.span("job.run");
            run(job, &mut rng, &counter, &jrec)
        };
        let elapsed_s = watch.elapsed_s();
        jrec.counter("job.distances", counter.get());
        let result = JobResult {
            job,
            distances: counter.get(),
            notes: counter.notes(),
            elapsed_s,
            queue_wait_s,
            out,
        };
        *slots[job].lock().expect("job slot poisoned") = Some(result);
    });
    pool::global().run(workers, &lanes);
    pool::global().record_metrics(rec);

    slots
        .iter()
        .map(|s| {
            s.lock()
                .expect("job slot poisoned")
                .take()
                .expect("worker pool exited with an unfinished job")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_job(job: usize, rng: &mut Rng, counter: &DistanceCounter) -> (u64, u64) {
        // Draw a job-stream value and bill a job-dependent amount, so both
        // the RNG isolation and the counter isolation are observable.
        let draw = rng.next_u64();
        counter.add((job as u64 + 1) * 10);
        counter.note(format!("job {job}"));
        (draw, counter.get())
    }

    #[test]
    fn results_are_worker_count_independent() {
        let solo = run_jobs(7, 1, 99, toy_job);
        let pooled = run_jobs(7, 4, 99, toy_job);
        assert_eq!(solo.len(), 7);
        for (a, b) in solo.iter().zip(&pooled) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.out, b.out, "job {} diverged across pool sizes", a.job);
            assert_eq!(a.distances, b.distances);
            assert_eq!(a.notes, b.notes);
        }
    }

    #[test]
    fn per_job_counters_are_isolated() {
        let results = run_jobs(5, 3, 7, toy_job);
        for (j, r) in results.iter().enumerate() {
            assert_eq!(r.job, j);
            assert_eq!(r.distances, (j as u64 + 1) * 10, "cross-job bill bleed");
            assert_eq!(r.notes, vec![format!("job {j}")]);
        }
    }

    #[test]
    fn job_streams_are_distinct_and_deterministic() {
        let a = run_jobs(6, 2, 1234, toy_job);
        let b = run_jobs(6, 6, 1234, toy_job);
        let mut draws: Vec<u64> = a.iter().map(|r| r.out.0).collect();
        assert_eq!(draws, b.iter().map(|r| r.out.0).collect::<Vec<_>>());
        draws.sort_unstable();
        draws.dedup();
        assert_eq!(draws.len(), 6, "job RNG streams collided");
    }

    #[test]
    #[should_panic(expected = "at least one job")]
    fn zero_jobs_is_a_caller_bug() {
        let _ = run_jobs(0, 2, 1, toy_job);
    }

    #[test]
    fn job_timings_are_always_measured() {
        let results = run_jobs(3, 2, 11, toy_job);
        for r in &results {
            assert!(r.elapsed_s >= 0.0);
            assert!(r.queue_wait_s >= 0.0);
        }
    }

    #[test]
    fn scoped_recorder_isolates_jobs_and_matches_the_bills() {
        // Telemetry must neither perturb results nor mix jobs: the scoped
        // handle each closure receives aggregates only its own records,
        // and the bridged per-job bill equals the isolated counter's.
        let rec = Recorder::summary();
        let plain = run_jobs(4, 2, 55, toy_job);
        let scoped = run_jobs_rec(4, 2, 55, &rec, |j, rng, counter, jrec| {
            let out = toy_job(j, rng, counter);
            jrec.gauge_u64("mine", j as u64);
            assert_eq!(jrec.gauge_last("mine"), Some(j as f64), "job scope bled");
            out
        });
        for (a, b) in plain.iter().zip(&scoped) {
            assert_eq!(a.out, b.out, "recorder perturbed job {}", a.job);
            assert_eq!(a.distances, b.distances);
            assert_eq!(a.notes, b.notes);
        }
        // The root recorder sees the jobs only under their `job<j>.`
        // prefixes — an unscoped lookup finds nothing, so job metrics
        // can never be mistaken for run-level ones.
        assert_eq!(rec.counter_total("job.distances"), None);
        assert_eq!(rec.counter_total("job0.job.distances"), scoped[0].distances.into());
    }
}
