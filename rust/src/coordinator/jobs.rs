//! Concurrent job scheduler (DESIGN.md §5.2): multiplex independent
//! clustering jobs over a shared worker pool.
//!
//! Each job gets a **private** [`DistanceCounter`] and a deterministic RNG
//! stream forked from the base seed *in job order*, so every job's results
//! and bill are bit-identical no matter how many workers run or which
//! worker happens to pick the job up. Workers pull job indices from a
//! single atomic queue (work stealing degenerates to round-robin when jobs
//! are uniform) and publish into per-job slots; the caller always receives
//! results in job order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::metrics::DistanceCounter;
use crate::util::Rng;

/// One job's outcome, with its isolated accounting.
#[derive(Clone, Debug)]
pub struct JobResult<T> {
    /// Job index (also the result's position in the returned vector).
    pub job: usize,
    /// This job's own distance bill — no cross-job bleed.
    pub distances: u64,
    /// This job's counter notes (capped log, pinned summaries last).
    pub notes: Vec<String>,
    /// Whatever the job closure returned.
    pub out: T,
}

/// Run `jobs` independent jobs over at most `workers` OS threads.
///
/// `run(job, rng, counter)` executes job `job` with its private RNG stream
/// and counter. Determinism contract: the RNG handed to job `j` depends
/// only on `base_seed` and `j`, so `run_jobs(n, 1, s, f)` and
/// `run_jobs(n, 8, s, f)` return bit-identical results.
pub fn run_jobs<T, F>(jobs: usize, workers: usize, base_seed: u64, run: F) -> Vec<JobResult<T>>
where
    T: Send,
    F: Fn(usize, &mut Rng, &DistanceCounter) -> T + Sync,
{
    assert!(jobs > 0, "run_jobs needs at least one job");
    let workers = workers.max(1).min(jobs);

    // Fork every job's stream up front, in job order: the seed a job sees
    // must not depend on which worker claims it or when.
    let mut root = Rng::new(base_seed);
    let seeds: Vec<Rng> = (0..jobs).map(|j| root.fork(j as u64 + 1)).collect();

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobResult<T>>>> =
        (0..jobs).map(|_| Mutex::new(None)).collect();
    let run = &run;
    let seeds = &seeds;
    let next = &next;
    let slots = &slots;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                let job = next.fetch_add(1, Ordering::Relaxed);
                if job >= jobs {
                    break;
                }
                let mut rng = seeds[job].clone();
                let counter = DistanceCounter::new();
                let out = run(job, &mut rng, &counter);
                let result = JobResult {
                    job,
                    distances: counter.get(),
                    notes: counter.notes(),
                    out,
                };
                *slots[job].lock().expect("job slot poisoned") = Some(result);
            });
        }
    });

    slots
        .iter()
        .map(|s| {
            s.lock()
                .expect("job slot poisoned")
                .take()
                .expect("worker pool exited with an unfinished job")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_job(job: usize, rng: &mut Rng, counter: &DistanceCounter) -> (u64, u64) {
        // Draw a job-stream value and bill a job-dependent amount, so both
        // the RNG isolation and the counter isolation are observable.
        let draw = rng.next_u64();
        counter.add((job as u64 + 1) * 10);
        counter.note(format!("job {job}"));
        (draw, counter.get())
    }

    #[test]
    fn results_are_worker_count_independent() {
        let solo = run_jobs(7, 1, 99, toy_job);
        let pooled = run_jobs(7, 4, 99, toy_job);
        assert_eq!(solo.len(), 7);
        for (a, b) in solo.iter().zip(&pooled) {
            assert_eq!(a.job, b.job);
            assert_eq!(a.out, b.out, "job {} diverged across pool sizes", a.job);
            assert_eq!(a.distances, b.distances);
            assert_eq!(a.notes, b.notes);
        }
    }

    #[test]
    fn per_job_counters_are_isolated() {
        let results = run_jobs(5, 3, 7, toy_job);
        for (j, r) in results.iter().enumerate() {
            assert_eq!(r.job, j);
            assert_eq!(r.distances, (j as u64 + 1) * 10, "cross-job bill bleed");
            assert_eq!(r.notes, vec![format!("job {j}")]);
        }
    }

    #[test]
    fn job_streams_are_distinct_and_deterministic() {
        let a = run_jobs(6, 2, 1234, toy_job);
        let b = run_jobs(6, 6, 1234, toy_job);
        let mut draws: Vec<u64> = a.iter().map(|r| r.out.0).collect();
        assert_eq!(draws, b.iter().map(|r| r.out.0).collect::<Vec<_>>());
        draws.sort_unstable();
        draws.dedup();
        assert_eq!(draws.len(), 6, "job RNG streams collided");
    }

    #[test]
    #[should_panic(expected = "at least one job")]
    fn zero_jobs_is_a_caller_bug() {
        let _ = run_jobs(0, 2, 1, toy_job);
    }
}
