//! Leader/worker coordination — the "embarrassingly parallel" runtime the
//! paper's §4 calls for ("we could implement this approach in a more
//! appropriate platform ... as is the case of Apache Spark").
//!
//! The leader owns partition + centroid state; workers own contiguous row
//! shards. Two fan-out primitives cover every data-parallel phase of the
//! pipeline (assignment/error evaluation and the weighted-Lloyd step);
//! both are thin wrappers over the assignment engine's sharded backend
//! (`kmeans::assign::ShardedAssigner`, DESIGN.md §2.5), and [`streaming`]
//! handles sources that never fit in memory — up to the full out-of-core
//! BWKM loop ([`StreamingBwkm`], DESIGN.md §5.1), pinned bit-identical
//! to the in-memory path. Shards come from the one canonical
//! `shard_ranges` rule and reductions are performed in shard order, so
//! results are bit-identical to the serial path — asserted by the
//! equivalence tests. [`jobs`] adds the orthogonal axis (DESIGN.md §5.2):
//! whole independent jobs multiplexed over one worker pool, each with a
//! private counter and a deterministic per-job RNG stream.

pub mod jobs;
pub mod parallel;
pub mod streaming;

pub use jobs::{run_jobs, run_jobs_rec, JobResult};
pub use parallel::{sharded_assign_err, sharded_stepper_for, sharded_weighted_step, ShardedStepper};
pub use streaming::{
    stream_assign_err, stream_assign_err_with, stream_partition_stats,
    stream_partition_stats_with, ChunkCrew, StreamBwkmOutcome, StreamSeedOutcome, StreamSeeder,
    StreamSource, StreamStats, StreamingBwkm,
};
