//! [`PjrtStepper`]: the weighted-Lloyd [`Stepper`] backed by the AOT
//! artifacts, so `bwkm::run_with` executes its inner loop on the compiled
//! L2/L1 stack. Falls back to the native stepper — and through it to the
//! serial assignment engine (DESIGN.md §2) — for shapes no variant covers
//! (e.g. a partition that outgrew the largest mcap tier), counting the
//! same m·k distances either way: the accounting is algorithmic, not
//! backend-dependent (DESIGN.md §2.4). Future device backends plug in
//! exactly like this one: implement [`Stepper`] — or the engine's
//! `Assigner` trait for bare assignment — and honor the DESIGN.md §2
//! contract.

use crate::kmeans::{NativeStepper, StepOut, Stepper};
use crate::metrics::DistanceCounter;

use super::Runtime;

/// Stepper that executes iterations through PJRT.
pub struct PjrtStepper {
    runtime: Runtime,
    fallback: NativeStepper,
    /// Steps served by the device vs the native fallback (observability).
    pub device_steps: u64,
    pub fallback_steps: u64,
}

impl PjrtStepper {
    pub fn new(runtime: Runtime) -> PjrtStepper {
        PjrtStepper {
            runtime,
            fallback: NativeStepper::new(),
            device_steps: 0,
            fallback_steps: 0,
        }
    }

    pub fn into_runtime(self) -> Runtime {
        self.runtime
    }
}

impl Stepper for PjrtStepper {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        match self.runtime.wlloyd_step(reps, weights, d, centroids) {
            Ok(out) => {
                self.device_steps += 1;
                // Same algorithmic count as the native path: m·k.
                counter.add((weights.len() * (centroids.len() / d)) as u64);
                out
            }
            Err(_) => {
                self.fallback_steps += 1;
                self.fallback.step(reps, weights, d, centroids, counter)
            }
        }
    }
}
