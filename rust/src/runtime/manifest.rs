//! Artifact manifest: the TSV written by `python/compile/aot.py` mapping
//! (program, mcap, kcap, dcap) → HLO text file.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One AOT-compiled shape variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    pub program: String,
    pub mcap: usize,
    pub kcap: usize,
    pub dcap: usize,
    pub file: String,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Load `manifest.tsv` (format: `program\tmcap\tkcap\tdcap\tfile`,
    /// `#`-prefixed comment lines allowed).
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut variants = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = t.split('\t').collect();
            if cols.len() != 5 {
                bail!("manifest line {}: expected 5 columns, got {}", no + 1, cols.len());
            }
            variants.push(Variant {
                program: cols[0].to_string(),
                mcap: cols[1].parse().context("mcap")?,
                kcap: cols[2].parse().context("kcap")?,
                dcap: cols[3].parse().context("dcap")?,
                file: cols[4].to_string(),
            });
        }
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { variants })
    }

    /// Smallest variant of `program` with mcap ≥ m, kcap ≥ k, dcap ≥ d
    /// (ties broken toward smaller padded volume → least wasted compute).
    pub fn pick(&self, program: &str, m: usize, k: usize, d: usize) -> Option<&Variant> {
        self.variants
            .iter()
            .filter(|v| v.program == program && v.mcap >= m && v.kcap >= k && v.dcap >= d)
            .min_by_key(|v| v.mcap * v.kcap * v.dcap)
    }

    /// Largest row capacity available for `program` at (k, d) — the chunk
    /// size for streamed full-dataset programs.
    pub fn largest_mcap(&self, program: &str, k: usize, d: usize) -> Option<usize> {
        self.variants
            .iter()
            .filter(|v| v.program == program && v.kcap >= k && v.dcap >= d)
            .map(|v| v.mcap)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# program\tmcap\tkcap\tdcap\tfile\n\
        wlloyd_step\t2048\t4\t4\ta.hlo.txt\n\
        wlloyd_step\t2048\t32\t20\tb.hlo.txt\n\
        wlloyd_step\t16384\t32\t20\tc.hlo.txt\n\
        assign_err\t16384\t32\t20\td.hlo.txt\n";

    #[test]
    fn parses_and_picks_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 4);
        let v = m.pick("wlloyd_step", 100, 3, 4).unwrap();
        assert_eq!(v.file, "a.hlo.txt");
        let v = m.pick("wlloyd_step", 100, 9, 17).unwrap();
        assert_eq!(v.file, "b.hlo.txt");
        let v = m.pick("wlloyd_step", 5000, 3, 3).unwrap();
        assert_eq!(v.file, "c.hlo.txt");
        assert!(m.pick("wlloyd_step", 100_000, 3, 3).is_none());
        assert!(m.pick("wlloyd_step", 10, 64, 3).is_none());
        assert!(m.pick("nope", 1, 1, 1).is_none());
    }

    #[test]
    fn largest_mcap_for_chunking() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.largest_mcap("assign_err", 9, 19), Some(16384));
        assert_eq!(m.largest_mcap("assign_err", 64, 19), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("a\tb\n").is_err());
        assert!(Manifest::parse("p\tx\t1\t1\tf\n").is_err());
    }
}
