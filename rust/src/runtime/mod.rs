//! PJRT runtime — the L3 side of the three-layer AOT bridge.
//!
//! `make artifacts` lowers the L2 JAX programs (which embed the L1 Pallas
//! kernel) to **HLO text** once per padded-shape variant and writes
//! `artifacts/manifest.tsv`. This module loads that manifest, compiles the
//! requested variant on the PJRT CPU client (`xla` crate), and exposes:
//!
//! * [`Runtime::wlloyd_step`] — one weighted-Lloyd iteration on device;
//! * [`Runtime::assign_err`]  — chunked full-dataset assignment + SSE;
//! * [`PjrtStepper`] — a [`crate::kmeans::Stepper`] so BWKM's inner loop
//!   can run end-to-end on the compiled artifacts (`bwkm::run_with`).
//!
//! Padding conventions (weight-0 rows, zero dims, masked centroid slots)
//! are the ones pinned by `python/tests/test_model.py`; the Rust side is
//! validated against the native stepper in `tests/runtime_vs_native.rs`.

mod manifest;
mod stepper;

pub use manifest::{Manifest, Variant};
pub use stepper::PjrtStepper;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::kmeans::StepOut;

/// Large finite distance used by the artifacts to mask centroid slots.
pub const MASK_BIG: f32 = 1e30;

/// A compiled-executable cache over the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<(String, usize, usize, usize), xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Default artifact directory: `$BWKM_ARTIFACTS` or `<repo>/artifacts`.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("BWKM_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts")
    }

    /// Open the runtime over an artifact directory (reads the manifest and
    /// creates the PJRT CPU client; executables compile lazily per variant).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.tsv"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: HashMap::new() })
    }

    /// Open from the default directory.
    pub fn open_default() -> Result<Runtime> {
        Self::open(&Self::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the smallest variant of `program`
    /// fitting (m, k, d). Returns the variant descriptor.
    fn compile(&mut self, program: &str, m: usize, k: usize, d: usize) -> Result<Variant> {
        let var = self
            .manifest
            .pick(program, m, k, d)
            .ok_or_else(|| anyhow!("no {program} variant fits m={m} k={k} d={d}"))?
            .clone();
        let key = (program.to_string(), var.mcap, var.kcap, var.dcap);
        if !self.cache.contains_key(&key) {
            let path = self.dir.join(&var.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.cache.insert(key, exe);
        }
        Ok(var)
    }

    fn exe(&self, program: &str, var: &Variant) -> &xla::PjRtLoadedExecutable {
        self.cache
            .get(&(program.to_string(), var.mcap, var.kcap, var.dcap))
            .expect("compiled above")
    }

    /// Execute one weighted-Lloyd iteration on the PJRT device.
    ///
    /// Inputs are f64 host-side (the crate's native precision) and are
    /// converted to the artifacts' f32. Fails if no variant fits.
    pub fn wlloyd_step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
    ) -> Result<StepOut> {
        let m = weights.len();
        let k = centroids.len() / d;
        let var = self.compile("wlloyd_step", m, k, d)?;
        let (mcap, kcap, dcap) = (var.mcap, var.kcap, var.dcap);

        let reps_l = pad_matrix(reps, m, d, mcap, dcap);
        let weights_l = pad_vec(weights, mcap);
        let cents_l = pad_matrix(centroids, k, d, kcap, dcap);
        let mask_l = mask_vec(k, kcap);

        let lits = execute_tuple(
            self.exe("wlloyd_step", &var),
            &[
                literal_2d(&reps_l, mcap, dcap)?,
                literal_1d(&weights_l),
                literal_2d(&cents_l, kcap, dcap)?,
                literal_1d(&mask_l),
            ],
            5,
        )?;

        let new_c_f: Vec<f32> = lits[0].to_vec().map_err(xerr)?;
        let idx: Vec<i32> = lits[1].to_vec().map_err(xerr)?;
        let d1: Vec<f32> = lits[2].to_vec().map_err(xerr)?;
        let d2: Vec<f32> = lits[3].to_vec().map_err(xerr)?;
        let wss: f32 = lits[4].to_vec::<f32>().map_err(xerr)?[0];

        // Unpad.
        let mut centroids_out = Vec::with_capacity(k * d);
        for c in 0..k {
            for j in 0..d {
                centroids_out.push(new_c_f[c * dcap + j] as f64);
            }
        }
        Ok(StepOut {
            centroids: centroids_out,
            assign: idx[..m].iter().map(|&i| i as u32).collect(),
            d1: d1[..m].iter().map(|&x| x as f64).collect(),
            d2: d2[..m]
                .iter()
                .map(|&x| if x >= MASK_BIG * 0.5 { f64::INFINITY } else { x as f64 })
                .collect(),
            werr: wss as f64,
        })
    }

    /// Full-dataset assignment + SSE, chunked over the largest available
    /// `assign_err` variant. Returns (assignments, sse).
    pub fn assign_err(
        &mut self,
        data: &[f64],
        d: usize,
        centroids: &[f64],
    ) -> Result<(Vec<u32>, f64)> {
        let n = data.len() / d;
        let k = centroids.len() / d;
        let chunk = self
            .manifest
            .largest_mcap("assign_err", k, d)
            .ok_or_else(|| anyhow!("no assign_err variant for k={k} d={d}"))?;
        let mut assign = Vec::with_capacity(n);
        let mut sse = 0.0f64;
        let mut start = 0usize;
        while start < n {
            let rows = chunk.min(n - start);
            let slice = &data[start * d..(start + rows) * d];
            let var = self.compile("assign_err", rows, k, d)?;
            let (mcap, kcap, dcap) = (var.mcap, var.kcap, var.dcap);
            let pts = pad_matrix(slice, rows, d, mcap, dcap);
            let w = pad_vec(&vec![1.0; rows], mcap);
            let cents = pad_matrix(centroids, k, d, kcap, dcap);
            let mask = mask_vec(k, kcap);
            let lits = execute_tuple(
                self.exe("assign_err", &var),
                &[
                    literal_2d(&pts, mcap, dcap)?,
                    literal_1d(&w),
                    literal_2d(&cents, kcap, dcap)?,
                    literal_1d(&mask),
                ],
                2,
            )?;
            let idx: Vec<i32> = lits[0].to_vec().map_err(xerr)?;
            let part: f32 = lits[1].to_vec::<f32>().map_err(xerr)?[0];
            assign.extend(idx[..rows].iter().map(|&i| i as u32));
            sse += part as f64;
            start += rows;
        }
        Ok((assign, sse))
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Pad an r×c f64 matrix into an rcap×ccap f32 buffer (zeros elsewhere).
fn pad_matrix(src: &[f64], r: usize, c: usize, rcap: usize, ccap: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rcap * ccap];
    for i in 0..r {
        for j in 0..c {
            out[i * ccap + j] = src[i * c + j] as f32;
        }
    }
    out
}

fn pad_vec(src: &[f64], cap: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cap];
    for (i, &x) in src.iter().enumerate() {
        out[i] = x as f32;
    }
    out
}

fn mask_vec(k: usize, kcap: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; kcap];
    for slot in m.iter_mut().take(k) {
        *slot = 1.0;
    }
    m
}

fn literal_2d(buf: &[f32], r: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(buf).reshape(&[r as i64, c as i64]).map_err(xerr)
}

fn literal_1d(buf: &[f32]) -> xla::Literal {
    xla::Literal::vec1(buf)
}

/// Execute and unpack the artifacts' `return_tuple=True` output.
fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
    arity: usize,
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(args).map_err(xerr)?;
    let lit = result[0][0].to_literal_sync().map_err(xerr)?;
    let parts = lit.to_tuple().map_err(xerr)?;
    if parts.len() != arity {
        return Err(anyhow!("expected {arity}-tuple, got {}", parts.len()));
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_helpers() {
        let m = pad_matrix(&[1.0, 2.0, 3.0, 4.0], 2, 2, 3, 4);
        assert_eq!(m.len(), 12);
        assert_eq!(m[0], 1.0);
        assert_eq!(m[1], 2.0);
        assert_eq!(m[2], 0.0);
        assert_eq!(m[4], 3.0);
        assert_eq!(m[5], 4.0);
        assert_eq!(&m[8..], &[0.0; 4]);

        assert_eq!(mask_vec(2, 4), vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(pad_vec(&[5.0], 3), vec![5.0, 0.0, 0.0]);
    }
}
