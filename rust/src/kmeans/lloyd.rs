//! Lloyd's algorithm over a full dataset (paper §1.2) with the Eq. 2
//! stopping criterion — the engine behind the FKM / KM++ / KMC2 baselines.
//!
//! Implemented as weighted Lloyd with unit weights — and therefore on the
//! unified assignment engine (DESIGN.md §2) like every other method; the
//! error E^D(C) falls out of the assignment step, so the stopping
//! criterion costs no extra distance computations.

use crate::metrics::{Budget, DistanceCounter};

use super::weighted_lloyd::{weighted_lloyd_with, NativeStepper, WLloydCfg, WLloydOutcome};

/// Configuration for a Lloyd run.
#[derive(Clone, Copy, Debug)]
pub struct LloydCfg {
    pub max_iters: usize,
    /// Eq. 2 threshold ε on |E^D(C) − E^D(C')|.
    pub eps: f64,
    pub budget: Budget,
}

impl Default for LloydCfg {
    fn default() -> Self {
        LloydCfg { max_iters: 100, eps: 1e-6, budget: Budget::unlimited() }
    }
}

/// Outcome of a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    /// E^D of the final centroids.
    pub error: f64,
    pub iters: usize,
}

/// Run Lloyd's algorithm from `init` until Eq. 2 (or budget/max_iters).
pub fn lloyd(
    data: &[f64],
    d: usize,
    init: &[f64],
    cfg: &LloydCfg,
    counter: &DistanceCounter,
) -> LloydOutcome {
    let n = data.len() / d;
    let ones = vec![1.0; n];
    let wcfg = WLloydCfg { max_iters: cfg.max_iters, tol: cfg.eps, budget: cfg.budget };
    let out: WLloydOutcome =
        weighted_lloyd_with(&mut NativeStepper::new(), data, &ones, d, init, &wcfg, counter);
    LloydOutcome { centroids: out.centroids, assign: out.assign, error: out.werr, iters: out.iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmeans_error;
    use crate::util::prop;

    #[test]
    fn separates_two_obvious_clusters() {
        let mut data = Vec::new();
        for i in 0..50 {
            data.extend_from_slice(&[i as f64 * 0.01, 0.0]);
            data.extend_from_slice(&[100.0 + i as f64 * 0.01, 0.0]);
        }
        let init = [10.0, 0.0, 90.0, 0.0];
        let c = DistanceCounter::new();
        let out = lloyd(&data, 2, &init, &LloydCfg::default(), &c);
        let mut xs = [out.centroids[0], out.centroids[2]];
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((xs[0] - 0.245).abs() < 1e-9);
        assert!((xs[1] - 100.245).abs() < 1e-9);
    }

    #[test]
    fn prop_final_error_matches_kmeans_error() {
        prop::check("lloyd-error-consistency", 20, |g| {
            let n = g.int(10, 150);
            let d = g.int(1, 4);
            let k = g.int(1, 5).min(n);
            let data = g.blobs(n, d, 3, 0.8);
            let init: Vec<f64> = data[..k * d].to_vec();
            let c = DistanceCounter::new();
            let out = lloyd(&data, d, &init, &LloydCfg::default(), &c);
            // Lloyd reports E^D of the centroids *before* its last update;
            // after convergence (tol met) the reported error matches a
            // fresh evaluation up to the final (sub-tol) improvement.
            let c2 = DistanceCounter::new();
            let fresh = kmeans_error(&data, d, &out.centroids, &c2);
            assert!(
                fresh <= out.error * (1.0 + 1e-9) + 1e-9,
                "fresh {fresh} > reported {}",
                out.error
            );
        });
    }
}
