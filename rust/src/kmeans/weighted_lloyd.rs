//! Weighted Lloyd's algorithm — the engine under both BWKM and RPKM
//! (paper §1.2.2.1): Lloyd's iterations over the representatives of a
//! dataset partition, weighting each representative by its cardinality.
//!
//! The per-iteration *step* is abstracted behind [`Stepper`] so the same
//! outer loop can run on the native Rust hot path or on the AOT-compiled
//! HLO executable via PJRT (`runtime::PjrtStepper`); both produce the
//! 5-tuple (new centroids, assignment, d1², d2², weighted error). The two
//! nearest distances are retained because BWKM's misassignment function
//! (Eq. 3) needs δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖ for every representative —
//! they fall out of the assignment step for free.

use crate::metrics::{Budget, DistanceCounter};

/// Result of one weighted-Lloyd iteration.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Flat k×d updated centroids.
    pub centroids: Vec<f64>,
    /// Nearest-centroid index per representative.
    pub assign: Vec<u32>,
    /// Squared distance to the nearest centroid.
    pub d1: Vec<f64>,
    /// Squared distance to the second-nearest centroid (∞ if k = 1).
    pub d2: Vec<f64>,
    /// Weighted error E^P(C) of the *incoming* centroids.
    pub werr: f64,
}

/// One weighted-Lloyd iteration (assignment + update) over representatives.
pub trait Stepper {
    /// `reps`: m×d flat, `weights`: m, `centroids`: k×d flat.
    /// Implementations must count m·k distances on `counter`.
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut;
}

/// The native (pure Rust) stepper — the optimized hot path.
#[derive(Default)]
pub struct NativeStepper {
    // Scratch buffers reused across iterations (no per-iteration allocation
    // in the hot loop).
    sums: Vec<f64>,
    counts: Vec<f64>,
}

impl NativeStepper {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Stepper for NativeStepper {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        // Dispatch to a monomorphized body for the dimensions the Table-1
        // workloads actually use: constant trip counts let LLVM fully
        // unroll + vectorize the distance loop (§Perf iteration 1:
        // 1.3–2.1x on the d=19/d=5 sweeps).
        match d {
            2 => self.step_d::<2>(reps, weights, centroids, counter),
            3 => self.step_d::<3>(reps, weights, centroids, counter),
            4 => self.step_d::<4>(reps, weights, centroids, counter),
            5 => self.step_d::<5>(reps, weights, centroids, counter),
            17 => self.step_d::<17>(reps, weights, centroids, counter),
            19 => self.step_d::<19>(reps, weights, centroids, counter),
            20 => self.step_d::<20>(reps, weights, centroids, counter),
            _ => self.step_dyn(reps, weights, d, centroids, counter),
        }
    }
}

macro_rules! step_body {
    ($self:ident, $reps:ident, $weights:ident, $d:ident, $centroids:ident, $counter:ident) => {{
        let m = $weights.len();
        let k = $centroids.len() / $d;
        let mut assign = vec![0u32; m];
        let mut d1 = vec![0.0; m];
        let mut d2 = vec![0.0; m];
        $self.sums.clear();
        $self.sums.resize(k * $d, 0.0);
        $self.counts.clear();
        $self.counts.resize(k, 0.0);
        let mut werr = 0.0;

        for i in 0..m {
            let p = &$reps[i * $d..i * $d + $d];
            // Inlined top-2 scan (see metrics::nearest2); kept local so the
            // compiler fuses the assignment and accumulation loops.
            let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
            for c in 0..k {
                let q = &$centroids[c * $d..c * $d + $d];
                // 4-way split accumulators: FP adds can't be reassociated
                // by the compiler, so a single `acc` serializes the whole
                // distance on the FPU add latency (§Perf iteration 2).
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                let mut j = 0;
                while j + 4 <= $d {
                    let t0 = p[j] - q[j];
                    let t1 = p[j + 1] - q[j + 1];
                    let t2 = p[j + 2] - q[j + 2];
                    let t3 = p[j + 3] - q[j + 3];
                    a0 += t0 * t0;
                    a1 += t1 * t1;
                    a2 += t2 * t2;
                    a3 += t3 * t3;
                    j += 4;
                }
                while j < $d {
                    let t = p[j] - q[j];
                    a0 += t * t;
                    j += 1;
                }
                let acc = (a0 + a1) + (a2 + a3);
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c;
                } else if acc < b2 {
                    b2 = acc;
                }
            }
            assign[i] = i1 as u32;
            d1[i] = b1;
            d2[i] = b2;
            let w = $weights[i];
            werr += w * b1;
            let s = &mut $self.sums[i1 * $d..i1 * $d + $d];
            for j in 0..$d {
                s[j] += w * p[j];
            }
            $self.counts[i1] += w;
        }
        $counter.add((m * k) as u64);

        // Update step: centers of mass; empty clusters keep their centroid.
        let mut out = $centroids.to_vec();
        for c in 0..k {
            if $self.counts[c] > 0.0 {
                let inv = 1.0 / $self.counts[c];
                for j in 0..$d {
                    out[c * $d + j] = $self.sums[c * $d + j] * inv;
                }
            }
        }
        StepOut { centroids: out, assign, d1, d2, werr }
    }};
}

impl NativeStepper {
    /// Monomorphized step: `D` is a compile-time constant, and each point
    /// is hoisted into a fixed-size array so it lives in registers across
    /// the whole centroid scan (§Perf iteration 3).
    fn step_d<const D: usize>(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        let m = weights.len();
        let k = centroids.len() / D;
        let mut assign = vec![0u32; m];
        let mut d1 = vec![0.0; m];
        let mut d2 = vec![0.0; m];
        self.sums.clear();
        self.sums.resize(k * D, 0.0);
        self.counts.clear();
        self.counts.resize(k, 0.0);
        let mut werr = 0.0;

        for i in 0..m {
            let p: &[f64; D] = reps[i * D..i * D + D].try_into().unwrap();
            let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
            for c in 0..k {
                let q: &[f64; D] = centroids[c * D..c * D + D].try_into().unwrap();
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                let mut j = 0;
                while j + 4 <= D {
                    let t0 = p[j] - q[j];
                    let t1 = p[j + 1] - q[j + 1];
                    let t2 = p[j + 2] - q[j + 2];
                    let t3 = p[j + 3] - q[j + 3];
                    a0 += t0 * t0;
                    a1 += t1 * t1;
                    a2 += t2 * t2;
                    a3 += t3 * t3;
                    j += 4;
                }
                while j < D {
                    let t = p[j] - q[j];
                    a0 += t * t;
                    j += 1;
                }
                let acc = (a0 + a1) + (a2 + a3);
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c;
                } else if acc < b2 {
                    b2 = acc;
                }
            }
            assign[i] = i1 as u32;
            d1[i] = b1;
            d2[i] = b2;
            let w = weights[i];
            werr += w * b1;
            let s = &mut self.sums[i1 * D..i1 * D + D];
            for j in 0..D {
                s[j] += w * p[j];
            }
            self.counts[i1] += w;
        }
        counter.add((m * k) as u64);

        let mut out = centroids.to_vec();
        for c in 0..k {
            if self.counts[c] > 0.0 {
                let inv = 1.0 / self.counts[c];
                for j in 0..D {
                    out[c * D + j] = self.sums[c * D + j] * inv;
                }
            }
        }
        StepOut { centroids: out, assign, d1, d2, werr }
    }

    /// Fallback for uncommon dimensions.
    fn step_dyn(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        step_body!(self, reps, weights, d, centroids, counter)
    }
}

/// Configuration of the weighted-Lloyd outer loop.
#[derive(Clone, Copy, Debug)]
pub struct WLloydCfg {
    pub max_iters: usize,
    /// Stop when |E^P(C) − E^P(C')| ≤ tol (the Eq. 2 criterion applied to
    /// the weighted error).
    pub tol: f64,
    /// Optional hard cap on total distance computations.
    pub budget: Budget,
}

impl Default for WLloydCfg {
    fn default() -> Self {
        WLloydCfg { max_iters: 100, tol: 1e-9, budget: Budget::unlimited() }
    }
}

/// Outcome of a weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct WLloydOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    /// Squared top-2 distances of the *final* assignment (consumed by
    /// BWKM's misassignment computation — paper §2.3 "we store ... the two
    /// closest centroids to the representative").
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
    /// Weighted error of the final centroids.
    pub werr: f64,
    pub iters: usize,
    /// Max centroid displacement of the last iteration (‖C−C'‖∞, §2.4.2).
    pub last_shift: f64,
}

/// Run weighted Lloyd with the native stepper.
pub fn weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    weighted_lloyd_with(&mut NativeStepper::new(), reps, weights, d, init, cfg, counter)
}

/// Run weighted Lloyd over an arbitrary [`Stepper`] backend.
pub fn weighted_lloyd_with(
    stepper: &mut dyn Stepper,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    let k = init.len() / d;
    let mut centroids = init.to_vec();
    let mut prev_err = f64::INFINITY;
    let mut last = None;
    let mut iters = 0;
    let mut last_shift = f64::INFINITY;

    while iters < cfg.max_iters && !cfg.budget.exceeded(counter) {
        let step = stepper.step(reps, weights, d, &centroids, counter);
        iters += 1;
        last_shift = max_shift(&centroids, &step.centroids, d, k);
        let done = (prev_err - step.werr).abs() <= cfg.tol;
        prev_err = step.werr;
        centroids = step.centroids.clone();
        last = Some(step);
        if done {
            break;
        }
    }

    let last = last.unwrap_or_else(|| {
        // Zero iterations (exhausted budget): still produce a consistent
        // assignment so callers can proceed.
        stepper.step(reps, weights, d, &centroids, counter)
    });
    WLloydOutcome {
        centroids,
        assign: last.assign,
        d1: last.d1,
        d2: last.d2,
        werr: last.werr,
        iters,
        last_shift,
    }
}

/// ‖C−C'‖∞ = max_k ‖c_k − c'_k‖ (Thm A.4's displacement norm).
pub fn max_shift(a: &[f64], b: &[f64], d: usize, k: usize) -> f64 {
    let mut worst = 0.0f64;
    for c in 0..k {
        let s = crate::geometry::sq_dist(&a[c * d..(c + 1) * d], &b[c * d..(c + 1) * d]);
        worst = worst.max(s);
    }
    worst.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn counter() -> DistanceCounter {
        DistanceCounter::new()
    }

    #[test]
    fn converges_on_two_weighted_groups() {
        // Representatives at -1,1 (weight 2 each) and 9,11 (weight 3 each).
        let reps = [-1.0, 1.0, 9.0, 11.0];
        let weights = [2.0, 2.0, 3.0, 3.0];
        let init = [-0.5, 8.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        let mut c = out.centroids.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((c[0] - 0.0).abs() < 1e-9, "{c:?}");
        assert!((c[1] - 10.0).abs() < 1e-9, "{c:?}");
    }

    #[test]
    fn counts_mk_per_iteration() {
        let reps = [0.0, 1.0, 10.0, 11.0];
        let weights = [1.0; 4];
        let init = [0.0, 10.0];
        let c = counter();
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &c);
        assert_eq!(c.get(), (out.iters * 4 * 2) as u64);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let reps = [0.0, 1.0];
        let weights = [1.0, 1.0];
        let init = [0.5, 99.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        assert!((out.centroids[1] - 99.0).abs() < 1e-12);
    }

    #[test]
    fn budget_stops_loop() {
        let reps: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let weights = vec![1.0; 100];
        let init = [0.0, 50.0, 99.0];
        let c = counter();
        let cfg = WLloydCfg { budget: Budget::of(600), ..Default::default() };
        let out = weighted_lloyd(&reps, &weights, 1, &init, &cfg, &c);
        assert!(out.iters <= 2, "iters={}", out.iters);
    }

    #[test]
    fn prop_weighted_error_monotone_decreases() {
        // The classic Lloyd guarantee on the weighted error (the chain of
        // inequalities referenced by Thm A.2).
        prop::check("wlloyd-monotone", 30, |g| {
            let m = g.int(5, 120);
            let d = g.int(1, 5);
            let k = g.int(1, 6).min(m);
            let reps = g.blobs(m, d, 3, 1.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 20) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();
            let c = counter();
            let mut stepper = NativeStepper::new();
            let mut cent = init;
            let mut prev = f64::INFINITY;
            for _ in 0..12 {
                let s = stepper.step(&reps, &weights, d, &cent, &c);
                assert!(
                    s.werr <= prev * (1.0 + 1e-12) + 1e-9,
                    "weighted error increased: {prev} -> {}",
                    s.werr
                );
                prev = s.werr;
                cent = s.centroids;
            }
        });
    }

    #[test]
    fn prop_step_matches_reference_nearest2() {
        prop::check("step-vs-nearest2", 30, |g| {
            let m = g.int(1, 80);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let reps = g.cloud(m, d, 3.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
            let cent = g.cloud(k, d, 3.0);
            let c1 = counter();
            let out = NativeStepper::new().step(&reps, &weights, d, &cent, &c1);
            let c2 = counter();
            for i in 0..m {
                let (ii, dd1, dd2) =
                    crate::metrics::nearest2(&reps[i * d..(i + 1) * d], &cent, d, &c2);
                assert_eq!(out.assign[i], ii as u32);
                assert!((out.d1[i] - dd1).abs() < 1e-12);
                if dd2.is_finite() {
                    assert!((out.d2[i] - dd2).abs() < 1e-12);
                }
            }
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn max_shift_is_linf_of_row_norms() {
        let a = [0.0, 0.0, 1.0, 1.0];
        let b = [3.0, 4.0, 1.0, 1.0];
        assert!((max_shift(&a, &b, 2, 2) - 5.0).abs() < 1e-12);
    }
}
