//! Weighted Lloyd's algorithm — the outer loop under both BWKM and RPKM
//! (paper §1.2.2.1): Lloyd's iterations over the representatives of a
//! dataset partition, weighting each representative by its cardinality.
//!
//! The per-iteration *step* is abstracted behind [`Stepper`] so the same
//! outer loop can run on the native Rust hot path or on the AOT-compiled
//! HLO executable via PJRT (`runtime::PjrtStepper`); both produce the
//! 5-tuple (new centroids, assignment, d1², d2², weighted error). The two
//! nearest distances are retained because BWKM's misassignment function
//! (Eq. 3) needs δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖ for every representative —
//! they fall out of the assignment step for free.
//!
//! The distance hot path itself lives in [`super::assign`] (DESIGN.md §2):
//! [`EngineStepper<B>`](EngineStepper) binds the outer loop to any engine
//! backend — [`NativeStepper`] is its serial instantiation,
//! `coordinator::ShardedStepper` the sharded one, and
//! `EngineStepper<BoundedAssigner>` / `EngineStepper<AutoAssigner>` the
//! cross-iteration pruned ones (DESIGN.md §2.7). This module owns only
//! the iteration/stopping logic.

use crate::metrics::{Budget, DistanceCounter, QualityGap};
use crate::obs::Recorder;
use crate::util::Rng;

use super::assign::{
    sq_dist_kernel, weighted_step_into, weighted_step_with, AssignCfg, AssignMode, Assigner,
    ClosureAssigner, KernelKind, Precision, SerialAssigner, StepScratch, VectorAssigner,
};

/// Result of one weighted-Lloyd iteration. `Default` is the empty arena:
/// callers that iterate hold one `StepOut` and refill it through
/// [`Stepper::step_into`] so the warm loop reuses its buffers
/// (DESIGN.md §2.12).
#[derive(Clone, Debug, Default)]
pub struct StepOut {
    /// Flat k×d updated centroids.
    pub centroids: Vec<f64>,
    /// Nearest-centroid index per representative.
    pub assign: Vec<u32>,
    /// Squared distance to the nearest centroid.
    pub d1: Vec<f64>,
    /// Squared distance to the second-nearest centroid (∞ if k = 1).
    pub d2: Vec<f64>,
    /// Weighted error E^P(C) of the *incoming* centroids.
    pub werr: f64,
}

/// One weighted-Lloyd iteration (assignment + update) over representatives.
pub trait Stepper {
    /// `reps`: m×d flat, `weights`: m, `centroids`: k×d flat.
    /// Exact implementations must count m·k distances on `counter`;
    /// approximate ones (DESIGN.md §2.9) count exactly what they compute
    /// and self-report the difference through [`Stepper::quality_gap`].
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut;

    /// Arena form of [`Stepper::step`] (DESIGN.md §2.12): refill `out` in
    /// place so a caller looping with one `StepOut` re-uses its buffers.
    /// Must be observably identical to `step` — same outputs bit-for-bit,
    /// same counter activity; the only difference is where the result
    /// lands. The default simply overwrites `out` with a fresh `step`;
    /// steppers with allocation-free paths override it.
    fn step_into(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut StepOut,
    ) {
        *out = self.step(reps, weights, d, centroids, counter);
    }

    /// The approximate regime's self-report hook (DESIGN.md §2.9):
    /// measured E-vs-exact of this stepper's current approximation, as
    /// uncounted instrumentation (§2.4). Exact steppers — every stepper
    /// by default — return `None`.
    fn quality_gap(
        &mut self,
        _reps: &[f64],
        _weights: &[f64],
        _d: usize,
        _centroids: &[f64],
    ) -> Option<QualityGap> {
        None
    }

    /// Telemetry hook (DESIGN.md §2.11): publish this stepper's current
    /// diagnostic state — prune/hit rates, sampled-step accounts, auto
    /// choice tallies — as typed gauges on `rec`. Strictly observational
    /// (never touches the counter, the RNG, or assignment state), so
    /// results are bit-identical whether or not it is called. The default
    /// — every exact stepper — records nothing.
    fn record_metrics(&mut self, _rec: &Recorder) {}
}

/// A [`Stepper`] over any assignment-engine backend (DESIGN.md §2.2): one
/// weighted-Lloyd iteration per call, assignment through `B`, serial
/// row-order accumulation through [`weighted_step_with`]. The blocked,
/// cache-tiled top-2 kernel, the monomorphized fixed-`D` fast paths and
/// the distance accounting all live in [`super::assign`]; this adapter
/// only persists the engine (state matters for the cross-iteration
/// [`super::assign::BoundedAssigner`]) and the accumulation scratch
/// across iterations.
#[derive(Clone, Debug, Default)]
pub struct EngineStepper<B: Assigner> {
    engine: B,
    // Cluster-aggregate scratch reused across iterations (no per-iteration
    // allocation in the hot loop, as in the retired stepper).
    scratch: StepScratch,
}

/// The native (pure Rust) stepper — the weighted outer loop on the serial
/// assignment engine; the default behind [`weighted_lloyd`] and
/// `bwkm::run`.
pub type NativeStepper = EngineStepper<SerialAssigner>;

impl<B: Assigner + Default> EngineStepper<B> {
    pub fn new() -> Self {
        Self::default()
    }
}

impl<B: Assigner> EngineStepper<B> {
    /// Wrap a pre-configured engine (e.g. `Sharded::with_backend(..)`).
    pub fn with_engine(engine: B) -> Self {
        EngineStepper { engine, scratch: StepScratch::default() }
    }

    /// The wrapped engine (bench columns read backend stats from here).
    pub fn engine(&self) -> &B {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut B {
        &mut self.engine
    }
}

impl<B: Assigner> Stepper for EngineStepper<B> {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        weighted_step_with(
            &mut self.engine,
            &mut self.scratch,
            reps,
            weights,
            d,
            centroids,
            counter,
        )
    }

    /// The zero-allocation warm path (DESIGN.md §2.12): assignment writes
    /// straight into `out`'s retained buffers through
    /// [`weighted_step_into`], so a warm iteration allocates nothing.
    fn step_into(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut StepOut,
    ) {
        weighted_step_into(
            &mut self.engine,
            &mut self.scratch,
            reps,
            weights,
            d,
            centroids,
            counter,
            out,
        );
    }

    /// Forward to the engine: an approximate backend (the closure
    /// assigner, or auto in the approximate regime) reports through the
    /// stepper it is wrapped in.
    fn quality_gap(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
    ) -> Option<QualityGap> {
        self.engine.quality_gap(reps, Some(weights), d, centroids)
    }

    /// Forward to the engine: pruned/closure/auto backends publish their
    /// own diagnostics (DESIGN.md §2.11).
    fn record_metrics(&mut self, rec: &Recorder) {
        self.engine.record_metrics(rec);
    }
}

/// What the [`SampledStepper`] charged on its most recent call — the
/// backend's own exact account of its `DistanceCounter` activity, pinned
/// by the conformance suite with `counter delta == pairs` (sampling has
/// no bookkeeping distances: the index draw is distance-free).
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleStats {
    /// Point–centroid pairs evaluated through the engine (`rows·k`).
    pub pairs: u64,
    /// The full `m·k` an exact step would have paid.
    pub bill: u64,
    /// Rows assigned this call (`m` exact, `s` sampled).
    pub rows: u64,
    /// Whether this call ran the exact full-set path.
    pub exact: bool,
    /// Cumulative exact calls over the stepper's lifetime (cold primes
    /// and `sample_rows ≥ m` calls included).
    pub fallbacks: u64,
}

/// The Big-means-style **approximate** stepper (DESIGN.md §2.9, after
/// "How to Use K-means for Big Data Clustering?", PAPERS.md): each
/// weighted-Lloyd step runs on a deterministic seeded subsample of
/// `sample_rows` representatives, with the sampled weights rescaled by
/// `W_total / W_sample` so cluster masses stay calibrated.
///
/// The [`Stepper`] contract wants per-row `assign`/`d1`/`d2` for *all* m
/// rows (BWKM's ε machinery reads them), so the first call on a new
/// representative set is a full **exact** step that primes the per-row
/// state; warm sampled calls refresh the `s` drawn rows and retain the
/// previous values everywhere else. `sample_rows ≥ m` (or a sampled
/// weight mass of zero) also routes through the exact step — which is
/// what makes the `sample_rows = n == exact` conformance pin hold by
/// construction. The index stream is a **private** [`Rng`] seeded from
/// `AssignCfg::sample_seed`, so the caller's draw sequence is identical
/// across `assign=` modes.
#[derive(Clone, Debug)]
pub struct SampledStepper {
    sample_rows: usize,
    rng: Rng,
    engine: SerialAssigner,
    scratch: StepScratch,
    // Cached inputs + retained per-row state (the warmth check is by
    // value, like the bounded/closure backends).
    points: Vec<f64>,
    d: usize,
    k: usize,
    assign: Vec<u32>,
    d1: Vec<f64>,
    d2: Vec<f64>,
    // Sampled-accumulation scratch (StepScratch's fields are private to
    // the assign module, so the sampled path owns its own).
    sums: Vec<f64>,
    counts: Vec<f64>,
    stats: SampleStats,
}

impl SampledStepper {
    pub fn new(sample_rows: usize, seed: u64) -> Self {
        SampledStepper {
            sample_rows,
            rng: Rng::new(seed),
            engine: SerialAssigner,
            scratch: StepScratch::default(),
            points: Vec::new(),
            d: 0,
            k: 0,
            assign: Vec::new(),
            d1: Vec::new(),
            d2: Vec::new(),
            sums: Vec::new(),
            counts: Vec::new(),
            stats: SampleStats::default(),
        }
    }

    pub fn sample_rows(&self) -> usize {
        self.sample_rows
    }

    /// Exact account of the most recent call (DESIGN.md §2.4/§2.9).
    pub fn last_stats(&self) -> SampleStats {
        self.stats
    }

    /// Would a call with these inputs run the sampled path?
    pub fn is_warm_for(&self, reps: &[f64], d: usize, k: usize) -> bool {
        self.d == d && self.k == k && self.points == reps
    }

    /// The exact full-set step: bit-identical to [`NativeStepper`] (same
    /// engine, same serial accumulation), priming the retained per-row
    /// state and paying exactly `m·k`.
    fn exact_step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        let m = weights.len();
        let k = centroids.len() / d;
        let out = weighted_step_with(
            &mut self.engine,
            &mut self.scratch,
            reps,
            weights,
            d,
            centroids,
            counter,
        );
        self.points.clear();
        self.points.extend_from_slice(reps);
        self.d = d;
        self.k = k;
        self.assign.clone_from(&out.assign);
        self.d1.clone_from(&out.d1);
        self.d2.clone_from(&out.d2);
        self.stats = SampleStats {
            pairs: (m as u64) * (k as u64),
            bill: (m as u64) * (k as u64),
            rows: m as u64,
            exact: true,
            fallbacks: self.stats.fallbacks + 1,
        };
        out
    }
}

impl Stepper for SampledStepper {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        let m = weights.len();
        let k = centroids.len() / d;
        let s = self.sample_rows;
        if !self.is_warm_for(reps, d, k) || s == 0 || s >= m {
            return self.exact_step(reps, weights, d, centroids, counter);
        }
        // Deterministic distinct sample, sorted ascending so the sampled
        // accumulation visits rows in global row order.
        let mut idx = self.rng.sample_indices(m, s);
        idx.sort_unstable();
        let w_total: f64 = weights.iter().sum();
        let w_sample: f64 = idx.iter().map(|&i| weights[i]).sum();
        if !(w_sample > 0.0) {
            // Degenerate draw (all-zero weights): nothing to rescale by.
            return self.exact_step(reps, weights, d, centroids, counter);
        }
        let scale = w_total / w_sample;

        let mut srows = Vec::with_capacity(s * d);
        for &i in &idx {
            srows.extend_from_slice(&reps[i * d..(i + 1) * d]);
        }
        // Engine assignment over the sample: counts exactly s·k.
        let top2 = self.engine.assign_top2(&srows, d, centroids, counter);

        self.sums.clear();
        self.sums.resize(k * d, 0.0);
        self.counts.clear();
        self.counts.resize(k, 0.0);
        let mut werr = 0.0f64;
        for (j, &i) in idx.iter().enumerate() {
            let w = weights[i] * scale;
            werr += w * top2.d1[j];
            let c = top2.assign[j] as usize;
            let p = &srows[j * d..(j + 1) * d];
            let sum = &mut self.sums[c * d..(c + 1) * d];
            for t in 0..d {
                sum[t] += w * p[t];
            }
            self.counts[c] += w;
            // Refresh the retained per-row state at the sampled rows; the
            // unsampled rows keep their last known values.
            self.assign[i] = top2.assign[j];
            self.d1[i] = top2.d1[j];
            self.d2[i] = top2.d2[j];
        }
        let mut cents = centroids.to_vec();
        for c in 0..k {
            if self.counts[c] > 0.0 {
                let inv = 1.0 / self.counts[c];
                for t in 0..d {
                    cents[c * d + t] = self.sums[c * d + t] * inv;
                }
            }
        }
        self.stats = SampleStats {
            pairs: (s as u64) * (k as u64),
            bill: (m as u64) * (k as u64),
            rows: s as u64,
            exact: false,
            fallbacks: self.stats.fallbacks,
        };
        StepOut {
            centroids: cents,
            assign: self.assign.clone(),
            d1: self.d1.clone(),
            d2: self.d2.clone(),
            werr,
        }
    }

    /// Measured E-vs-exact of the retained (possibly stale) per-row
    /// assignment against the given centroids, on private counters
    /// (uncounted instrumentation). Scoring a fixed assignment can only
    /// overestimate: `approx_err ≥ exact_err` holds exactly (same kernel
    /// values, row-order monotone summation). `hit_rate` reports the
    /// fraction of rows the last call refreshed.
    fn quality_gap(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
    ) -> Option<QualityGap> {
        let m = weights.len();
        let k = centroids.len() / d;
        let probe = DistanceCounter::new();
        let exact = SerialAssigner.assign_top2(reps, d, centroids, &probe);
        let mut exact_err = 0.0f64;
        for i in 0..m {
            exact_err += weights[i] * exact.d1[i];
        }
        let approx_err = if self.is_warm_for(reps, d, k) {
            let mut e = 0.0f64;
            for i in 0..m {
                let c = self.assign[i] as usize;
                e += weights[i]
                    * sq_dist_kernel(&reps[i * d..(i + 1) * d], &centroids[c * d..(c + 1) * d]);
            }
            e
        } else {
            // The next call would run the exact step.
            exact_err
        };
        let coverage = if m == 0 { 1.0 } else { (self.stats.rows as f64 / m as f64).min(1.0) };
        Some(QualityGap {
            backend: "sampled",
            approx_err,
            exact_err,
            hit_rate: coverage,
            fallbacks: self.stats.fallbacks,
        })
    }

    /// The [`SampleStats`] account as typed gauges (DESIGN.md §2.11):
    /// cumulative fields are re-gauged each step, so last-value == total.
    fn record_metrics(&mut self, rec: &Recorder) {
        if !rec.is_on() {
            return;
        }
        let s = self.stats;
        rec.gauge_u64("sampled.pairs", s.pairs);
        rec.gauge_u64("sampled.bill", s.bill);
        rec.gauge_u64("sampled.rows", s.rows);
        rec.gauge_u64("sampled.exact", u64::from(s.exact));
        rec.gauge_u64("sampled.fallbacks", s.fallbacks);
    }
}

/// Build the weighted-Lloyd stepper an [`AssignCfg`] asks for
/// (DESIGN.md §2.9/§2.10): the shared dispatch behind `bwkm::run`, the
/// grid RPKM baseline, the out-of-core coordinator and the CLI's
/// `assign=` key. Exact mode with the default scalar/f64 selection
/// returns the plain [`NativeStepper`]; a non-default `kernel=` /
/// `precision=` selection mounts the [`VectorAssigner`] (f64: pinned
/// bit-identical, so this fork is unobservable in output; f32: the
/// documented relaxed contract). The approximate modes wrap their
/// backend with a serial inner engine and always run the canonical
/// scalar kernel — the config layer rejects contradictory key
/// combinations instead of ignoring them.
pub fn stepper_for(assign: &AssignCfg) -> Box<dyn Stepper> {
    match assign.mode {
        AssignMode::Exact => {
            if assign.kernel == KernelKind::Scalar && assign.precision == Precision::F64 {
                Box::new(NativeStepper::new())
            } else {
                Box::new(EngineStepper::with_engine(VectorAssigner::from_cfg(assign)))
            }
        }
        AssignMode::Closure => {
            Box::new(EngineStepper::with_engine(ClosureAssigner::new(assign.closure_expand)))
        }
        AssignMode::Sampled => Box::new(SampledStepper::new(assign.sample_rows, assign.sample_seed)),
    }
}

/// Configuration of the weighted-Lloyd outer loop.
#[derive(Clone, Copy, Debug)]
pub struct WLloydCfg {
    pub max_iters: usize,
    /// Stop when |E^P(C) − E^P(C')| ≤ tol (the Eq. 2 criterion applied to
    /// the weighted error).
    pub tol: f64,
    /// Optional hard cap on total distance computations.
    pub budget: Budget,
}

impl Default for WLloydCfg {
    fn default() -> Self {
        WLloydCfg { max_iters: 100, tol: 1e-9, budget: Budget::unlimited() }
    }
}

/// Outcome of a weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct WLloydOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    /// Squared top-2 distances of the *final* assignment (consumed by
    /// BWKM's misassignment computation — paper §2.3 "we store ... the two
    /// closest centroids to the representative").
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
    /// Weighted error of the final centroids.
    pub werr: f64,
    pub iters: usize,
    /// Max centroid displacement of the last iteration (‖C−C'‖∞, §2.4.2).
    pub last_shift: f64,
}

/// Run weighted Lloyd with the native stepper.
pub fn weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    weighted_lloyd_with(&mut NativeStepper::new(), reps, weights, d, init, cfg, counter)
}

/// Run weighted Lloyd over an arbitrary [`Stepper`] backend.
pub fn weighted_lloyd_with(
    stepper: &mut dyn Stepper,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    let k = init.len() / d;
    let mut centroids = init.to_vec();
    let mut prev_err = f64::INFINITY;
    let mut iters = 0;
    let mut last_shift = f64::INFINITY;
    // One arena for the whole run: `step_into` refills these buffers in
    // place each iteration, so the warm loop allocates nothing
    // (DESIGN.md §2.12).
    let mut step = StepOut::default();
    let mut ran = false;

    while iters < cfg.max_iters && !cfg.budget.exceeded(counter) {
        stepper.step_into(reps, weights, d, &centroids, counter, &mut step);
        ran = true;
        iters += 1;
        last_shift = max_shift(&centroids, &step.centroids, d, k);
        let done = (prev_err - step.werr).abs() <= cfg.tol;
        prev_err = step.werr;
        centroids.copy_from_slice(&step.centroids);
        if done {
            break;
        }
    }

    if !ran {
        // Zero iterations (exhausted budget): still produce a consistent
        // assignment so callers can proceed.
        stepper.step_into(reps, weights, d, &centroids, counter, &mut step);
    }
    WLloydOutcome {
        centroids,
        assign: step.assign,
        d1: step.d1,
        d2: step.d2,
        werr: step.werr,
        iters,
        last_shift,
    }
}

/// ‖C−C'‖∞ = max_k ‖c_k − c'_k‖ (Thm A.4's displacement norm).
pub fn max_shift(a: &[f64], b: &[f64], d: usize, k: usize) -> f64 {
    let mut worst = 0.0f64;
    for c in 0..k {
        let s = crate::geometry::sq_dist(&a[c * d..(c + 1) * d], &b[c * d..(c + 1) * d]);
        worst = worst.max(s);
    }
    worst.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn counter() -> DistanceCounter {
        DistanceCounter::new()
    }

    #[test]
    fn converges_on_two_weighted_groups() {
        // Representatives at -1,1 (weight 2 each) and 9,11 (weight 3 each).
        let reps = [-1.0, 1.0, 9.0, 11.0];
        let weights = [2.0, 2.0, 3.0, 3.0];
        let init = [-0.5, 8.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        let mut c = out.centroids.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((c[0] - 0.0).abs() < 1e-9, "{c:?}");
        assert!((c[1] - 10.0).abs() < 1e-9, "{c:?}");
    }

    #[test]
    fn counts_mk_per_iteration() {
        let reps = [0.0, 1.0, 10.0, 11.0];
        let weights = [1.0; 4];
        let init = [0.0, 10.0];
        let c = counter();
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &c);
        assert_eq!(c.get(), (out.iters * 4 * 2) as u64);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let reps = [0.0, 1.0];
        let weights = [1.0, 1.0];
        let init = [0.5, 99.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        assert!((out.centroids[1] - 99.0).abs() < 1e-12);
    }

    #[test]
    fn budget_stops_loop() {
        let reps: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let weights = vec![1.0; 100];
        let init = [0.0, 50.0, 99.0];
        let c = counter();
        let cfg = WLloydCfg { budget: Budget::of(600), ..Default::default() };
        let out = weighted_lloyd(&reps, &weights, 1, &init, &cfg, &c);
        assert!(out.iters <= 2, "iters={}", out.iters);
    }

    #[test]
    fn prop_weighted_error_monotone_decreases() {
        // The classic Lloyd guarantee on the weighted error (the chain of
        // inequalities referenced by Thm A.2).
        prop::check("wlloyd-monotone", 30, |g| {
            let m = g.int(5, 120);
            let d = g.int(1, 5);
            let k = g.int(1, 6).min(m);
            let reps = g.blobs(m, d, 3, 1.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 20) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();
            let c = counter();
            let mut stepper = NativeStepper::new();
            let mut cent = init;
            let mut prev = f64::INFINITY;
            for _ in 0..12 {
                let s = stepper.step(&reps, &weights, d, &cent, &c);
                assert!(
                    s.werr <= prev * (1.0 + 1e-12) + 1e-9,
                    "weighted error increased: {prev} -> {}",
                    s.werr
                );
                prev = s.werr;
                cent = s.centroids;
            }
        });
    }

    #[test]
    fn prop_step_matches_reference_nearest2() {
        prop::check("step-vs-nearest2", 30, |g| {
            let m = g.int(1, 80);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let reps = g.cloud(m, d, 3.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
            let cent = g.cloud(k, d, 3.0);
            let c1 = counter();
            let out = NativeStepper::new().step(&reps, &weights, d, &cent, &c1);
            let c2 = counter();
            for i in 0..m {
                let (ii, dd1, dd2) =
                    crate::metrics::nearest2(&reps[i * d..(i + 1) * d], &cent, d, &c2);
                assert_eq!(out.assign[i], ii as u32);
                assert!((out.d1[i] - dd1).abs() < 1e-12);
                if dd2.is_finite() {
                    assert!((out.d2[i] - dd2).abs() < 1e-12);
                }
            }
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn max_shift_is_linf_of_row_norms() {
        let a = [0.0, 0.0, 1.0, 1.0];
        let b = [3.0, 4.0, 1.0, 1.0];
        assert!((max_shift(&a, &b, 2, 2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sampled_full_sample_is_exactly_the_exact_step() {
        // sample_rows ≥ m routes through the exact path: bit-identical
        // to NativeStepper at the identical m·k count, every call.
        let mut g = prop::Gen { rng: crate::util::Rng::new(41), case: 0 };
        let (m, d, k) = (80, 3, 4);
        let reps = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
        let mut cents = g.cloud(k, d, 2.0);
        let mut native = NativeStepper::new();
        let mut sampled = SampledStepper::new(m, 0xB16D);
        for step in 0..4 {
            let c1 = counter();
            let a = native.step(&reps, &weights, d, &cents, &c1);
            let c2 = counter();
            let b = sampled.step(&reps, &weights, d, &cents, &c2);
            assert_eq!(a.assign, b.assign, "step {step}");
            assert_eq!(a.d1, b.d1);
            assert_eq!(a.d2, b.d2);
            assert_eq!(a.centroids, b.centroids);
            assert_eq!(a.werr.to_bits(), b.werr.to_bits());
            assert_eq!(c1.get(), c2.get());
            assert!(sampled.last_stats().exact);
            cents = a.centroids;
        }
    }

    #[test]
    fn sampled_warm_step_pays_exactly_its_own_account() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(42), case: 0 };
        let (m, d, k, s) = (120, 3, 4, 30);
        let reps = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
        let cents = g.cloud(k, d, 2.0);
        let mut sampled = SampledStepper::new(s, 0xB16D);
        let c = counter();
        let _ = sampled.step(&reps, &weights, d, &cents, &c);
        // Cold prime: the exact step at m·k.
        assert!(sampled.last_stats().exact);
        assert_eq!(c.get(), (m * k) as u64);
        let before = c.get();
        let out = sampled.step(&reps, &weights, d, &cents, &c);
        let stats = sampled.last_stats();
        assert!(!stats.exact);
        assert_eq!(stats.pairs, (s * k) as u64);
        assert_eq!(stats.bill, (m * k) as u64);
        assert_eq!(c.get() - before, stats.pairs, "counter delta == own account");
        assert_eq!(out.assign.len(), m, "full per-row state retained");
        assert_eq!(out.d1.len(), m);
        // Gap self-report: present, ordered, uncounted.
        let after = c.get();
        let gap = Stepper::quality_gap(&mut sampled, &reps, &weights, d, &cents)
            .expect("sampled stepper always reports");
        assert_eq!(gap.backend, "sampled");
        assert!(gap.approx_err >= gap.exact_err);
        assert!((gap.hit_rate - s as f64 / m as f64).abs() < 1e-15);
        assert_eq!(c.get(), after);
    }

    #[test]
    fn sampled_reruns_are_deterministic() {
        // Same seed ⇒ identical draw sequence ⇒ identical outputs, bills
        // and fallback tallies across reruns.
        let mut g = prop::Gen { rng: crate::util::Rng::new(43), case: 0 };
        let (m, d, k, s) = (100, 2, 3, 25);
        let reps = g.cloud(m, d, 2.0);
        let weights = vec![1.0; m];
        let cents = g.cloud(k, d, 2.0);
        let run = |seed: u64| {
            let mut st = SampledStepper::new(s, seed);
            let c = counter();
            let mut cur = cents.clone();
            let mut outs = Vec::new();
            for _ in 0..4 {
                let o = st.step(&reps, &weights, d, &cur, &c);
                cur = o.centroids.clone();
                outs.push(o);
            }
            (outs, c.get(), st.last_stats().fallbacks)
        };
        let (a, ca, fa) = run(7);
        let (b, cb, fb) = run(7);
        assert_eq!(ca, cb);
        assert_eq!(fa, fb);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.assign, y.assign);
            assert_eq!(x.centroids, y.centroids);
            assert_eq!(x.werr.to_bits(), y.werr.to_bits());
        }
        let (c3, _, _) = run(8);
        assert!(
            a.iter().zip(&c3).any(|(x, y)| x.centroids != y.centroids),
            "a different seed should draw a different sample"
        );
    }

    #[test]
    fn step_into_reuses_buffers_and_matches_step_bitwise() {
        // The arena form is observably identical to `step` (DESIGN.md
        // §2.12): same outputs by `==`, same counter activity — only the
        // destination differs.
        let mut g = prop::Gen { rng: crate::util::Rng::new(44), case: 0 };
        let (m, d, k) = (60, 3, 4);
        let reps = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
        let mut cents = g.cloud(k, d, 2.0);
        let mut fresh = NativeStepper::new();
        let mut arena = NativeStepper::new();
        let mut out = StepOut::default();
        for step in 0..4 {
            let c1 = counter();
            let a = fresh.step(&reps, &weights, d, &cents, &c1);
            let c2 = counter();
            arena.step_into(&reps, &weights, d, &cents, &c2, &mut out);
            assert_eq!(a.assign, out.assign, "step {step}");
            assert_eq!(a.d1, out.d1);
            assert_eq!(a.d2, out.d2);
            assert_eq!(a.centroids, out.centroids);
            assert_eq!(a.werr.to_bits(), out.werr.to_bits());
            assert_eq!(c1.get(), c2.get());
            cents = a.centroids;
        }
    }

    #[test]
    fn stepper_for_dispatches_on_mode() {
        let mut cfg = AssignCfg::default();
        assert!(stepper_for(&cfg).quality_gap(&[0.0], &[1.0], 1, &[0.0]).is_none());
        cfg.mode = AssignMode::Closure;
        assert!(stepper_for(&cfg).quality_gap(&[0.0], &[1.0], 1, &[0.0]).is_some());
        cfg.mode = AssignMode::Sampled;
        cfg.sample_rows = 1;
        assert!(stepper_for(&cfg).quality_gap(&[0.0], &[1.0], 1, &[0.0]).is_some());
    }
}
