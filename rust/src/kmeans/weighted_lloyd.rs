//! Weighted Lloyd's algorithm — the outer loop under both BWKM and RPKM
//! (paper §1.2.2.1): Lloyd's iterations over the representatives of a
//! dataset partition, weighting each representative by its cardinality.
//!
//! The per-iteration *step* is abstracted behind [`Stepper`] so the same
//! outer loop can run on the native Rust hot path or on the AOT-compiled
//! HLO executable via PJRT (`runtime::PjrtStepper`); both produce the
//! 5-tuple (new centroids, assignment, d1², d2², weighted error). The two
//! nearest distances are retained because BWKM's misassignment function
//! (Eq. 3) needs δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖ for every representative —
//! they fall out of the assignment step for free.
//!
//! The distance hot path itself lives in [`super::assign`] (DESIGN.md §2):
//! [`EngineStepper<B>`](EngineStepper) binds the outer loop to any engine
//! backend — [`NativeStepper`] is its serial instantiation,
//! `coordinator::ShardedStepper` the sharded one, and
//! `EngineStepper<BoundedAssigner>` / `EngineStepper<AutoAssigner>` the
//! cross-iteration pruned ones (DESIGN.md §2.7). This module owns only
//! the iteration/stopping logic.

use crate::metrics::{Budget, DistanceCounter};

use super::assign::{weighted_step_with, Assigner, SerialAssigner, StepScratch};

/// Result of one weighted-Lloyd iteration.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Flat k×d updated centroids.
    pub centroids: Vec<f64>,
    /// Nearest-centroid index per representative.
    pub assign: Vec<u32>,
    /// Squared distance to the nearest centroid.
    pub d1: Vec<f64>,
    /// Squared distance to the second-nearest centroid (∞ if k = 1).
    pub d2: Vec<f64>,
    /// Weighted error E^P(C) of the *incoming* centroids.
    pub werr: f64,
}

/// One weighted-Lloyd iteration (assignment + update) over representatives.
pub trait Stepper {
    /// `reps`: m×d flat, `weights`: m, `centroids`: k×d flat.
    /// Implementations must count m·k distances on `counter`.
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut;
}

/// A [`Stepper`] over any assignment-engine backend (DESIGN.md §2.2): one
/// weighted-Lloyd iteration per call, assignment through `B`, serial
/// row-order accumulation through [`weighted_step_with`]. The blocked,
/// cache-tiled top-2 kernel, the monomorphized fixed-`D` fast paths and
/// the distance accounting all live in [`super::assign`]; this adapter
/// only persists the engine (state matters for the cross-iteration
/// [`super::assign::BoundedAssigner`]) and the accumulation scratch
/// across iterations.
#[derive(Clone, Debug, Default)]
pub struct EngineStepper<B: Assigner> {
    engine: B,
    // Cluster-aggregate scratch reused across iterations (no per-iteration
    // allocation in the hot loop, as in the retired stepper).
    scratch: StepScratch,
}

/// The native (pure Rust) stepper — the weighted outer loop on the serial
/// assignment engine; the default behind [`weighted_lloyd`] and
/// `bwkm::run`.
pub type NativeStepper = EngineStepper<SerialAssigner>;

impl<B: Assigner + Default> EngineStepper<B> {
    pub fn new() -> Self {
        Self::default()
    }
}

impl<B: Assigner> EngineStepper<B> {
    /// Wrap a pre-configured engine (e.g. `Sharded::with_backend(..)`).
    pub fn with_engine(engine: B) -> Self {
        EngineStepper { engine, scratch: StepScratch::default() }
    }

    /// The wrapped engine (bench columns read backend stats from here).
    pub fn engine(&self) -> &B {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut B {
        &mut self.engine
    }
}

impl<B: Assigner> Stepper for EngineStepper<B> {
    fn step(
        &mut self,
        reps: &[f64],
        weights: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> StepOut {
        weighted_step_with(
            &mut self.engine,
            &mut self.scratch,
            reps,
            weights,
            d,
            centroids,
            counter,
        )
    }
}

/// Configuration of the weighted-Lloyd outer loop.
#[derive(Clone, Copy, Debug)]
pub struct WLloydCfg {
    pub max_iters: usize,
    /// Stop when |E^P(C) − E^P(C')| ≤ tol (the Eq. 2 criterion applied to
    /// the weighted error).
    pub tol: f64,
    /// Optional hard cap on total distance computations.
    pub budget: Budget,
}

impl Default for WLloydCfg {
    fn default() -> Self {
        WLloydCfg { max_iters: 100, tol: 1e-9, budget: Budget::unlimited() }
    }
}

/// Outcome of a weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct WLloydOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    /// Squared top-2 distances of the *final* assignment (consumed by
    /// BWKM's misassignment computation — paper §2.3 "we store ... the two
    /// closest centroids to the representative").
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
    /// Weighted error of the final centroids.
    pub werr: f64,
    pub iters: usize,
    /// Max centroid displacement of the last iteration (‖C−C'‖∞, §2.4.2).
    pub last_shift: f64,
}

/// Run weighted Lloyd with the native stepper.
pub fn weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    weighted_lloyd_with(&mut NativeStepper::new(), reps, weights, d, init, cfg, counter)
}

/// Run weighted Lloyd over an arbitrary [`Stepper`] backend.
pub fn weighted_lloyd_with(
    stepper: &mut dyn Stepper,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    cfg: &WLloydCfg,
    counter: &DistanceCounter,
) -> WLloydOutcome {
    let k = init.len() / d;
    let mut centroids = init.to_vec();
    let mut prev_err = f64::INFINITY;
    let mut last = None;
    let mut iters = 0;
    let mut last_shift = f64::INFINITY;

    while iters < cfg.max_iters && !cfg.budget.exceeded(counter) {
        let step = stepper.step(reps, weights, d, &centroids, counter);
        iters += 1;
        last_shift = max_shift(&centroids, &step.centroids, d, k);
        let done = (prev_err - step.werr).abs() <= cfg.tol;
        prev_err = step.werr;
        centroids = step.centroids.clone();
        last = Some(step);
        if done {
            break;
        }
    }

    let last = last.unwrap_or_else(|| {
        // Zero iterations (exhausted budget): still produce a consistent
        // assignment so callers can proceed.
        stepper.step(reps, weights, d, &centroids, counter)
    });
    WLloydOutcome {
        centroids,
        assign: last.assign,
        d1: last.d1,
        d2: last.d2,
        werr: last.werr,
        iters,
        last_shift,
    }
}

/// ‖C−C'‖∞ = max_k ‖c_k − c'_k‖ (Thm A.4's displacement norm).
pub fn max_shift(a: &[f64], b: &[f64], d: usize, k: usize) -> f64 {
    let mut worst = 0.0f64;
    for c in 0..k {
        let s = crate::geometry::sq_dist(&a[c * d..(c + 1) * d], &b[c * d..(c + 1) * d]);
        worst = worst.max(s);
    }
    worst.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn counter() -> DistanceCounter {
        DistanceCounter::new()
    }

    #[test]
    fn converges_on_two_weighted_groups() {
        // Representatives at -1,1 (weight 2 each) and 9,11 (weight 3 each).
        let reps = [-1.0, 1.0, 9.0, 11.0];
        let weights = [2.0, 2.0, 3.0, 3.0];
        let init = [-0.5, 8.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        let mut c = out.centroids.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((c[0] - 0.0).abs() < 1e-9, "{c:?}");
        assert!((c[1] - 10.0).abs() < 1e-9, "{c:?}");
    }

    #[test]
    fn counts_mk_per_iteration() {
        let reps = [0.0, 1.0, 10.0, 11.0];
        let weights = [1.0; 4];
        let init = [0.0, 10.0];
        let c = counter();
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &c);
        assert_eq!(c.get(), (out.iters * 4 * 2) as u64);
    }

    #[test]
    fn empty_cluster_keeps_centroid() {
        let reps = [0.0, 1.0];
        let weights = [1.0, 1.0];
        let init = [0.5, 99.0];
        let out = weighted_lloyd(&reps, &weights, 1, &init, &WLloydCfg::default(), &counter());
        assert!((out.centroids[1] - 99.0).abs() < 1e-12);
    }

    #[test]
    fn budget_stops_loop() {
        let reps: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let weights = vec![1.0; 100];
        let init = [0.0, 50.0, 99.0];
        let c = counter();
        let cfg = WLloydCfg { budget: Budget::of(600), ..Default::default() };
        let out = weighted_lloyd(&reps, &weights, 1, &init, &cfg, &c);
        assert!(out.iters <= 2, "iters={}", out.iters);
    }

    #[test]
    fn prop_weighted_error_monotone_decreases() {
        // The classic Lloyd guarantee on the weighted error (the chain of
        // inequalities referenced by Thm A.2).
        prop::check("wlloyd-monotone", 30, |g| {
            let m = g.int(5, 120);
            let d = g.int(1, 5);
            let k = g.int(1, 6).min(m);
            let reps = g.blobs(m, d, 3, 1.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 20) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();
            let c = counter();
            let mut stepper = NativeStepper::new();
            let mut cent = init;
            let mut prev = f64::INFINITY;
            for _ in 0..12 {
                let s = stepper.step(&reps, &weights, d, &cent, &c);
                assert!(
                    s.werr <= prev * (1.0 + 1e-12) + 1e-9,
                    "weighted error increased: {prev} -> {}",
                    s.werr
                );
                prev = s.werr;
                cent = s.centroids;
            }
        });
    }

    #[test]
    fn prop_step_matches_reference_nearest2() {
        prop::check("step-vs-nearest2", 30, |g| {
            let m = g.int(1, 80);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let reps = g.cloud(m, d, 3.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
            let cent = g.cloud(k, d, 3.0);
            let c1 = counter();
            let out = NativeStepper::new().step(&reps, &weights, d, &cent, &c1);
            let c2 = counter();
            for i in 0..m {
                let (ii, dd1, dd2) =
                    crate::metrics::nearest2(&reps[i * d..(i + 1) * d], &cent, d, &c2);
                assert_eq!(out.assign[i], ii as u32);
                assert!((out.d1[i] - dd1).abs() < 1e-12);
                if dd2.is_finite() {
                    assert!((out.d2[i] - dd2).abs() < 1e-12);
                }
            }
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn max_shift_is_linf_of_row_norms() {
        let a = [0.0, 0.0, 1.0, 1.0];
        let b = [3.0, 4.0, 1.0, 1.0];
        assert!((max_shift(&a, &b, 2, 2) - 5.0).abs() < 1e-12);
    }
}
