//! Mini-batch K-means (Sculley [31]) — the paper's MB baseline with
//! batch sizes b ∈ {100, 500, 1000}.
//!
//! Per iteration: sample b points uniformly, assign the gathered batch
//! through the shared assignment engine (DESIGN.md §2; b·k distances,
//! identical accounting to the retired per-point `nearest` loop), then
//! move each selected centroid toward the batch points with per-center
//! learning rate 1/v[c], where v[c] counts all samples ever assigned to c.

use crate::metrics::{Budget, DistanceCounter};
use crate::util::Rng;

use super::assign::{Assigner, SerialAssigner};
use super::init::forgy;
use super::KmResult;

/// Mini-batch configuration.
#[derive(Clone, Copy, Debug)]
pub struct MiniBatchCfg {
    pub batch: usize,
    pub max_iters: usize,
    /// Stop when the max centroid shift of an iteration falls below this.
    pub tol: f64,
    pub budget: Budget,
}

impl Default for MiniBatchCfg {
    fn default() -> Self {
        MiniBatchCfg { batch: 100, max_iters: 1000, tol: 1e-4, budget: Budget::unlimited() }
    }
}

/// Run Mini-batch K-means with Forgy initialization (as in the paper §3).
pub fn minibatch_kmeans(
    data: &[f64],
    d: usize,
    k: usize,
    cfg: &MiniBatchCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> KmResult {
    let n = data.len() / d;
    let mut centroids = forgy(data, d, k, rng);
    let mut v = vec![0u64; k]; // per-center sample counts
    let mut iters = 0;

    let mut engine = SerialAssigner;
    let mut batch_idx = vec![0usize; cfg.batch];
    // Gather scratch: the sampled rows, contiguous for the blocked kernel.
    let mut batch_points = vec![0.0f64; cfg.batch * d];

    for _ in 0..cfg.max_iters {
        if cfg.budget.exceeded(counter) {
            break;
        }
        iters += 1;
        // Sample, then assign the whole batch in one engine pass (Sculley
        // caches assignments per batch; same rng draw order as before).
        for b in 0..cfg.batch {
            let i = rng.usize(n);
            batch_idx[b] = i;
            batch_points[b * d..(b + 1) * d].copy_from_slice(&data[i * d..(i + 1) * d]);
        }
        // The engine's top-2 byproduct (d1/d2) goes unused here; that (and
        // the per-batch AssignOut allocation) is the accepted price of
        // running every method on the one canonical kernel (DESIGN.md §2)
        // — it is O(b) against the O(b·k·d) distance work.
        let top2 = engine.assign_top2(&batch_points, d, &centroids, counter);
        let batch_assign = &top2.assign;
        // Gradient step with per-center rates.
        let mut max_shift2 = 0.0f64;
        for b in 0..cfg.batch {
            let c = batch_assign[b] as usize;
            v[c] += 1;
            let eta = 1.0 / v[c] as f64;
            let x = &data[batch_idx[b] * d..(batch_idx[b] + 1) * d];
            let cent = &mut centroids[c * d..(c + 1) * d];
            let mut shift2 = 0.0;
            for j in 0..d {
                let delta = eta * (x[j] - cent[j]);
                cent[j] += delta;
                shift2 += delta * delta;
            }
            max_shift2 = max_shift2.max(shift2);
        }
        if max_shift2.sqrt() < cfg.tol {
            break;
        }
    }
    KmResult { centroids, k, d, iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmeans_error;
    use crate::util::prop;

    #[test]
    fn counts_bk_per_iteration() {
        let data: Vec<f64> = (0..1000).map(|x| x as f64).collect();
        let c = DistanceCounter::new();
        let cfg = MiniBatchCfg { batch: 50, max_iters: 7, tol: 0.0, ..Default::default() };
        let out = minibatch_kmeans(&data, 1, 3, &cfg, &mut Rng::new(1), &c);
        assert_eq!(out.iters, 7);
        assert_eq!(c.get(), 7 * 50 * 3);
    }

    #[test]
    fn improves_over_forgy_on_blobs() {
        prop::check("mb-improves", 5, |g| {
            let data = g.blobs(2000, 2, 4, 0.4);
            let mut rng = g.rng.fork(3);
            let c = DistanceCounter::new();
            let init = forgy(&data, 2, 4, &mut rng.clone());
            let e_init = kmeans_error(&data, 2, &init, &c);
            let cfg = MiniBatchCfg { batch: 100, max_iters: 300, ..Default::default() };
            let out = minibatch_kmeans(&data, 2, 4, &cfg, &mut rng, &c);
            let e_mb = kmeans_error(&data, 2, &out.centroids, &c);
            assert!(e_mb < e_init * 1.05, "mb {e_mb} vs forgy-init {e_init}");
        });
    }

    #[test]
    fn budget_respected() {
        let data: Vec<f64> = (0..4000).map(|x| x as f64).collect();
        let c = DistanceCounter::new();
        let cfg = MiniBatchCfg {
            batch: 100,
            max_iters: 100_000,
            tol: 0.0,
            budget: Budget::of(10_000),
        };
        let _ = minibatch_kmeans(&data, 1, 5, &cfg, &mut Rng::new(2), &c);
        // One batch overshoot at most.
        assert!(c.get() <= 10_000 + 100 * 5);
    }
}
