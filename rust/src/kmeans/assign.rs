//! The **assignment engine** — the one distance hot path every method in
//! this crate shares (DESIGN.md §2).
//!
//! The assignment step — "for each point, find the nearest (and second
//! nearest) centroid" — is the cost center of every K-means-family
//! algorithm the paper evaluates (§1.2, §3): plain Lloyd, weighted Lloyd
//! under BWKM/RPKM, Mini-batch, and the exact accelerated variants. BWKM
//! additionally consumes the distance to the *second* nearest centroid,
//! because the misassignment function (paper Eq. 3)
//!
//! ```text
//! ε_{C,D}(B) = max(0, 2·l_B − (‖P̄−c₂‖ − ‖P̄−c₁‖))
//! ```
//!
//! needs δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖ for every representative. This module
//! therefore computes nearest/top-2 once, behind one [`Assigner`] trait,
//! and every consumer (`lloyd`, `weighted_lloyd::NativeStepper`,
//! `minibatch`, `elkan`'s exact fallback pass,
//! `coordinator::parallel::sharded_assign_err`, and `bwkm`'s ε machinery)
//! rides on it instead of keeping a private distance loop.
//!
//! Contract highlights (normative text in DESIGN.md §2):
//!
//! * **Canonical kernel.** One squared-distance summation order —
//!   [`sq_dist_kernel`], the 4-way split-accumulator form — is used by
//!   every backend, so all backends produce **bit-identical**
//!   `(assign, d1, d2)` for the same inputs. (`geometry::sq_dist` is the
//!   plain left-to-right *reference* form; the two agree to ~1 ulp per
//!   term and the property tests pin the engine against it at 1e-12.)
//! * **Tie-breaking.** Strict `<` against the incumbent: the
//!   lowest-indexed centroid wins equal distances, and `d2` is the second
//!   *value* in scan order (`d2 = ∞` when k = 1).
//! * **Counting.** Exact backends tick the shared [`DistanceCounter`]
//!   with one unit per point-centroid pair — n·k per call, accounted
//!   per cache block. Pruned backends ([`NormPrunedAssigner`], the
//!   cross-iteration [`BoundedAssigner`], and whatever [`AutoAssigner`]
//!   selects per step) count only what they compute (plus their
//!   documented bookkeeping), and may therefore count *less* while
//!   returning bit-identical output.
//! * **Approximate regime (DESIGN.md §2.9, opt-in).** [`ClosureAssigner`]
//!   (and `weighted_lloyd::SampledStepper`) trade the bit-identity
//!   guarantee for a smaller bill. Their *accounting* stays exact —
//!   `counter delta == pairs + bookkeeping`, self-reported stats — and
//!   the measured quality gap is available on demand through
//!   [`Assigner::quality_gap`].
//! * **Shard determinism.** [`Sharded<B>`](Sharded) splits rows with
//!   [`shard_ranges`] (the same contiguous base/extra split as
//!   `Dataset::shard_ranges`), runs any inner backend per shard, and
//!   reduces in shard order, so its output equals the serial backend's
//!   bit for bit, for every inner backend and thread count.
//!
//! The kernel itself is blocked and cache-tiled: points are processed in
//! [`POINT_BLOCK`]-row blocks and centroids in [`CENT_TILE`]-row tiles, so
//! a tile of centroids is reused from L1 across the whole point block
//! while the top-2 state lives in registers / stack arrays. Dimensions the
//! Table-1 workloads use (§Perf iteration 1: 1.3–2.1x) get monomorphized
//! fast paths with a compile-time `D`.

use crate::metrics::{DistanceCounter, QualityGap};
use crate::obs::Recorder;
use crate::util::pool::{self, PoolTask, SendPtr};

use super::weighted_lloyd::StepOut;

/// Rows per cache block of the tiled kernel (top-2 state for a block lives
/// in stack arrays; 64 rows × 3 lanes × 8 B ≈ 1.5 KiB).
pub const POINT_BLOCK: usize = 64;

/// Centroids per tile of the tiled kernel (a tile of k ≤ 8, d ≤ 20
/// centroids is ≤ 1.25 KiB — resident in L1 across the point block).
pub const CENT_TILE: usize = 8;

/// Result of a top-2 assignment pass: for every input row, the index of
/// the nearest centroid and the two smallest squared distances
/// (`d2[i] = ∞` when only one centroid exists).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AssignOut {
    pub assign: Vec<u32>,
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

impl AssignOut {
    fn with_capacity(m: usize) -> AssignOut {
        AssignOut {
            assign: Vec::with_capacity(m),
            d1: Vec::with_capacity(m),
            d2: Vec::with_capacity(m),
        }
    }

    /// Size the buffers for `m` rows in place, keeping their capacity
    /// (DESIGN.md §2.12): once a buffer has seen its steady-state `m`, a
    /// reset allocates nothing. Every row is overwritten by the scan that
    /// follows, so the zero fill is shape bookkeeping, not data.
    pub fn reset(&mut self, m: usize) {
        self.assign.clear();
        self.assign.resize(m, 0);
        self.d1.clear();
        self.d1.resize(m, 0.0);
        self.d2.clear();
        self.d2.resize(m, 0.0);
    }
}

/// A nearest/top-2 assignment backend (DESIGN.md §2.2). Implementations
/// must obey the canonical-kernel, tie-breaking, counting and determinism
/// rules spelled out there, so callers may swap backends freely.
pub trait Assigner {
    /// Assign every row of `points` (m×d flat) to its nearest centroid,
    /// returning the top-2 squared distances alongside.
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut;

    /// In-place form of [`assign_top2`](Self::assign_top2) (DESIGN.md
    /// §2.12): write the pass into a caller-owned reusable buffer. The
    /// default delegates to `assign_top2` and moves the result — the
    /// pre-arena per-call path, kept callable so the conformance suite
    /// can compare the two. Backends on the zero-allocation steady-state
    /// path override it to fill `out` directly; values are pinned `==`
    /// either way.
    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        *out = self.assign_top2(points, d, centroids, counter);
    }

    /// Slice-window form of [`assign_top2`](Self::assign_top2) (DESIGN.md
    /// §2.12): write the pass for these rows into caller-provided windows
    /// (all of length `points.len() / d`). This is the shard primitive —
    /// [`Sharded`] hands each worker its disjoint `split_at_mut`-style
    /// window of the full output, so the shard-order fan-in is a layout
    /// fact instead of a copy. The default routes through `assign_top2`
    /// and copies once; zero-allocation backends override.
    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let out = self.assign_top2(points, d, centroids, counter);
        assign.copy_from_slice(&out.assign);
        d1.copy_from_slice(&out.d1);
        d2.copy_from_slice(&out.d2);
    }

    /// The approximate regime's self-report hook (DESIGN.md §2.9): the
    /// measured cost of this backend's current approximation on these
    /// inputs, as **uncounted instrumentation** (§2.4 — private
    /// counters, nothing charged to any caller-visible account). Exact
    /// backends — every backend by default — have no gap and return
    /// `None`.
    fn quality_gap(
        &mut self,
        _points: &[f64],
        _weights: Option<&[f64]>,
        _d: usize,
        _centroids: &[f64],
    ) -> Option<QualityGap> {
        None
    }

    /// Telemetry hook (DESIGN.md §2.11): publish this backend's current
    /// diagnostic state — the stringly-typed note content, promoted to
    /// typed gauges — on `rec`. Strictly observational: implementations
    /// must not touch the [`DistanceCounter`], any RNG, or assignment
    /// state, so output stays bit-identical whether or not the hook runs.
    /// The default — every stateless exact backend — records nothing.
    fn record_metrics(&mut self, _rec: &Recorder) {}
}

/// The canonical squared-distance kernel (DESIGN.md §2.1): 4-way split
/// accumulators so the FPU add latency chain is broken (the compiler may
/// not reassociate FP adds itself — §Perf iteration 2), combined as
/// `(a0 + a1) + (a2 + a3)`. Every engine backend computes *exactly* this
/// value for every pair it evaluates.
#[inline]
pub fn sq_dist_kernel(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let d = p.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut j = 0;
    while j + 4 <= d {
        let t0 = p[j] - q[j];
        let t1 = p[j + 1] - q[j + 1];
        let t2 = p[j + 2] - q[j + 2];
        let t3 = p[j + 3] - q[j + 3];
        a0 += t0 * t0;
        a1 += t1 * t1;
        a2 += t2 * t2;
        a3 += t3 * t3;
        j += 4;
    }
    while j < d {
        let t = p[j] - q[j];
        a0 += t * t;
        j += 1;
    }
    (a0 + a1) + (a2 + a3)
}

/// Canonical *metric* distance: `sqrt` of [`sq_dist_kernel`]. `sqrt` is
/// exact and monotone, so argmins and tie-breaks match the squared form.
/// Consumers that work in metric space (Elkan's bounds) must use this for
/// every point↔centroid distance, so their cached bounds stay consistent
/// with the distances they are later compared against (DESIGN.md §2.6).
#[inline]
pub fn dist_kernel(p: &[f64], q: &[f64]) -> f64 {
    sq_dist_kernel(p, q).sqrt()
}

/// Split `0..n` into at most `shards` contiguous ranges of near-equal
/// length (the first `n % shards` ranges get one extra row). This is the
/// *only* shard-range rule in the crate — `Dataset::shard_ranges` and both
/// sharded coordinator paths route through it (DESIGN.md §2.5), so a
/// leader and its workers can never disagree about row ownership.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    let shards = shards.max(1).min(n.max(1));
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// How many ranges [`shard_ranges`] returns for the same inputs.
pub fn shard_count(n: usize, shards: usize) -> usize {
    shards.max(1).min(n.max(1))
}

/// The closed form of one [`shard_ranges`] entry:
/// `shard_range(n, shards, s) == shard_ranges(n, shards)[s]` for every
/// `s < shard_count(n, shards)` (pinned by a unit test below), with no
/// allocation — the warm sharded path's per-call form (DESIGN.md §2.12).
pub fn shard_range(n: usize, shards: usize, s: usize) -> std::ops::Range<usize> {
    let shards = shard_count(n, shards);
    debug_assert!(s < shards);
    let base = n / shards;
    let extra = n % shards;
    let start = s * base + s.min(extra);
    start..start + base + usize::from(s < extra)
}

// ---------------------------------------------------------------------------
// The blocked, cache-tiled kernel.
// ---------------------------------------------------------------------------

/// Monomorphized blocked top-2 scan: `D` is a compile-time constant so the
/// inner loop fully unrolls, and each row is hoisted into a fixed-size
/// array that lives in registers across a centroid tile (§Perf
/// iteration 3). Centroids are visited in increasing index order across
/// tiles, so the result is bit-identical to a straight scan.
fn top2_blocked<const D: usize>(
    points: &[f64],
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / D;
    debug_assert_eq!(points.len(), m * D);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p: &[f64; D] = points[i * D..i * D + D].try_into().unwrap();
                for c in tile..tile + tlen {
                    let q: &[f64; D] = centroids[c * D..c * D + D].try_into().unwrap();
                    // Inlined canonical kernel (see `sq_dist_kernel`) on
                    // register-resident rows.
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                    let mut j = 0;
                    while j + 4 <= D {
                        let t0 = p[j] - q[j];
                        let t1 = p[j + 1] - q[j + 1];
                        let t2 = p[j + 2] - q[j + 2];
                        let t3 = p[j + 3] - q[j + 3];
                        a0 += t0 * t0;
                        a1 += t1 * t1;
                        a2 += t2 * t2;
                        a3 += t3 * t3;
                        j += 4;
                    }
                    while j < D {
                        let t = p[j] - q[j];
                        a0 += t * t;
                        j += 1;
                    }
                    let acc = (a0 + a1) + (a2 + a3);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        // Per-block accounting: one unit per point-centroid pair.
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Dynamic-dimension fallback of [`top2_blocked`] (identical structure and
/// summation order; rows stay slices).
fn top2_blocked_dyn(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / d;
    debug_assert_eq!(points.len(), m * d);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p = &points[i * d..i * d + d];
                for c in tile..tile + tlen {
                    let acc = sq_dist_kernel(p, &centroids[c * d..c * d + d]);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Dispatch to a monomorphized body for the dimensions the Table-1
/// workloads actually use (constant trip counts let LLVM fully unroll and
/// vectorize the inner loop — §Perf iteration 1: 1.3–2.1x on the d=19/d=5
/// sweeps).
fn top2_dispatch(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    match d {
        2 => top2_blocked::<2>(points, centroids, assign, d1, d2, counter),
        3 => top2_blocked::<3>(points, centroids, assign, d1, d2, counter),
        4 => top2_blocked::<4>(points, centroids, assign, d1, d2, counter),
        5 => top2_blocked::<5>(points, centroids, assign, d1, d2, counter),
        17 => top2_blocked::<17>(points, centroids, assign, d1, d2, counter),
        19 => top2_blocked::<19>(points, centroids, assign, d1, d2, counter),
        20 => top2_blocked::<20>(points, centroids, assign, d1, d2, counter),
        _ => top2_blocked_dyn(points, d, centroids, assign, d1, d2, counter),
    }
}

// ---------------------------------------------------------------------------
// Vectorized kernels & mixed precision (DESIGN.md §2.10).
// ---------------------------------------------------------------------------

/// Lanes of the explicit-lane f64 kernel (f64x4: one AVX2 register, two
/// NEON registers). This is also the split width of the *scalar* canonical
/// kernel, which is why the two are bit-identical (DESIGN.md §2.10).
pub const F64_LANES: usize = 4;

/// Lanes of the explicit-lane f32 kernel (f32x8). The mixed-precision
/// scalar reference [`sq_dist_kernel_f32`] uses the same 8-way split so
/// scalar-f32 and simd-f32 are bit-identical to each other.
pub const F32_LANES: usize = 8;

/// Storage/arithmetic precision of the assignment kernel (DESIGN.md
/// §2.10). `F64` is the canonical engine; `F32` is the opt-in
/// mixed-precision mode — **f32 storage and subtraction, f64
/// accumulation** — built for ~2× memory bandwidth on the streaming
/// paths. `F32` is *relaxed*: its outputs are tolerance-bounded against
/// the f64 engine, never bit-identical (§2.10's error model).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F64,
    F32,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// Parse a `precision=` config/CLI value. `None` for anything but
    /// `f64`/`f32` (the config layer turns that into an actionable error).
    pub fn parse(v: &str) -> Option<Precision> {
        match v.to_ascii_lowercase().as_str() {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }
}

impl Default for Precision {
    fn default() -> Self {
        Precision::F64
    }
}

/// Which kernel implementation the engine runs (DESIGN.md §2.10).
/// `Scalar` is the canonical split-accumulator loop; `Simd` the
/// explicit-lane variant (portable lane arrays — no `unsafe`, no ISA
/// gate); `Auto` resolves deterministically per call via [`resolve`].
/// Within a precision the choice is **unobservable in output**: both
/// kernels perform the identical FP operations in the identical order, so
/// they are bit-identical (pinned by `engine_conformance.rs`) and the
/// distance bill is the same exact n·k either way.
///
/// [`resolve`]: KernelKind::resolve
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Scalar,
    Simd,
    Auto,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
            KernelKind::Auto => "auto",
        }
    }

    /// Parse a `kernel=` config/CLI value. `None` for anything but
    /// `scalar`/`simd`/`auto`.
    pub fn parse(v: &str) -> Option<KernelKind> {
        match v.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "simd" => Some(KernelKind::Simd),
            "auto" => Some(KernelKind::Auto),
            _ => None,
        }
    }

    /// Deterministic `Auto` resolution: lanes pay off once a full lane
    /// group fits in the row, so `Auto` is `Simd` for d ≥ [`F64_LANES`]
    /// and `Scalar` below (where the lane main loop would never run).
    /// Depends on nothing but `d` — no runtime feature detection — so a
    /// run's kernel choice is reproducible from its config alone. (When
    /// the crate is built without the `simd` feature, `Simd` additionally
    /// falls back to the scalar *implementation* at the dispatch site;
    /// that too is unobservable, by the bit-identity above.)
    pub fn resolve(self, d: usize) -> KernelKind {
        match self {
            KernelKind::Auto => {
                if d >= F64_LANES {
                    KernelKind::Simd
                } else {
                    KernelKind::Scalar
                }
            }
            k => k,
        }
    }
}

impl Default for KernelKind {
    fn default() -> Self {
        KernelKind::Scalar
    }
}

/// The canonical **mixed-precision** squared-distance kernel (DESIGN.md
/// §2.10): subtraction in f32 on f32-stored rows, then each difference is
/// widened to f64 and squared there — the 24-bit×24-bit product is exact
/// in f64 — and accumulated over an **8-way split** ([`F32_LANES`])
/// matching the f32x8 lane order, tail into lane 0, folded
/// `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`. Every f32-mode code path
/// (scalar and lane variants alike) computes exactly this value, so
/// within f32 the kernels are bit-identical; f32 vs f64 is
/// tolerance-bounded only (the storage/subtraction rounding model of
/// §2.10).
#[inline]
pub fn sq_dist_kernel_f32(p: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let d = p.len();
    let mut acc = [0.0f64; F32_LANES];
    let mut j = 0;
    while j + F32_LANES <= d {
        let t0 = p[j] - q[j];
        let t1 = p[j + 1] - q[j + 1];
        let t2 = p[j + 2] - q[j + 2];
        let t3 = p[j + 3] - q[j + 3];
        let t4 = p[j + 4] - q[j + 4];
        let t5 = p[j + 5] - q[j + 5];
        let t6 = p[j + 6] - q[j + 6];
        let t7 = p[j + 7] - q[j + 7];
        acc[0] += (t0 as f64) * (t0 as f64);
        acc[1] += (t1 as f64) * (t1 as f64);
        acc[2] += (t2 as f64) * (t2 as f64);
        acc[3] += (t3 as f64) * (t3 as f64);
        acc[4] += (t4 as f64) * (t4 as f64);
        acc[5] += (t5 as f64) * (t5 as f64);
        acc[6] += (t6 as f64) * (t6 as f64);
        acc[7] += (t7 as f64) * (t7 as f64);
        j += F32_LANES;
    }
    while j < d {
        let t = p[j] - q[j];
        acc[0] += (t as f64) * (t as f64);
        j += 1;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Explicit-lane f64 pair kernel (f64x4 as portable lane arrays): the
/// main loop subtracts and multiply-accumulates a whole lane group per
/// trip — the shape LLVM maps straight onto vector sub/FMA — while
/// performing the **identical FP operations in the identical order** as
/// [`sq_dist_kernel`] (lane l accumulates dims j ≡ l mod 4, tail into
/// lane 0, fold `(a0+a1)+(a2+a3)`). Bit-identity with the scalar kernel
/// is therefore *pinned*, not approximate.
#[cfg(feature = "simd")]
#[inline(always)]
fn sq_dist_lanes_f64(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let d = p.len();
    let mut acc = [0.0f64; F64_LANES];
    let mut j = 0;
    while j + F64_LANES <= d {
        let mut t = [0.0f64; F64_LANES];
        for l in 0..F64_LANES {
            t[l] = p[j + l] - q[j + l];
        }
        for l in 0..F64_LANES {
            acc[l] += t[l] * t[l];
        }
        j += F64_LANES;
    }
    while j < d {
        let t = p[j] - q[j];
        acc[0] += t * t;
        j += 1;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// Explicit-lane f32 pair kernel (f32x8 lane arrays, f64 lane
/// accumulators). Same operation order as [`sq_dist_kernel_f32`], so
/// scalar-f32 and lane-f32 are pinned bit-identical to each other.
#[cfg(feature = "simd")]
#[inline(always)]
fn sq_dist_lanes_f32(p: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let d = p.len();
    let mut acc = [0.0f64; F32_LANES];
    let mut j = 0;
    while j + F32_LANES <= d {
        let mut t = [0.0f32; F32_LANES];
        for l in 0..F32_LANES {
            t[l] = p[j + l] - q[j + l];
        }
        for l in 0..F32_LANES {
            acc[l] += (t[l] as f64) * (t[l] as f64);
        }
        j += F32_LANES;
    }
    while j < d {
        let t = p[j] - q[j];
        acc[0] += (t as f64) * (t as f64);
        j += 1;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Lane-kernel monomorphized blocked top-2 scan: same
/// [`POINT_BLOCK`]×[`CENT_TILE`] tiling, same strict-`<` register-blocked
/// top-2 reduction, same per-block accounting as [`top2_blocked`] — only
/// the pair kernel is the explicit-lane form.
#[cfg(feature = "simd")]
fn top2_blocked_simd<const D: usize>(
    points: &[f64],
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / D;
    debug_assert_eq!(points.len(), m * D);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p: &[f64; D] = points[i * D..i * D + D].try_into().unwrap();
                for c in tile..tile + tlen {
                    let q: &[f64; D] = centroids[c * D..c * D + D].try_into().unwrap();
                    let acc = sq_dist_lanes_f64(p, q);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Dynamic-dimension lane-kernel scan (mirrors [`top2_blocked_dyn`]).
#[cfg(feature = "simd")]
fn top2_blocked_dyn_simd(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / d;
    debug_assert_eq!(points.len(), m * d);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p = &points[i * d..i * d + d];
                for c in tile..tile + tlen {
                    let acc = sq_dist_lanes_f64(p, &centroids[c * d..c * d + d]);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Lane-kernel dispatch over the same monomorphized dimension set as
/// [`top2_dispatch`].
#[cfg(feature = "simd")]
fn top2_simd_dispatch(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    match d {
        2 => top2_blocked_simd::<2>(points, centroids, assign, d1, d2, counter),
        3 => top2_blocked_simd::<3>(points, centroids, assign, d1, d2, counter),
        4 => top2_blocked_simd::<4>(points, centroids, assign, d1, d2, counter),
        5 => top2_blocked_simd::<5>(points, centroids, assign, d1, d2, counter),
        17 => top2_blocked_simd::<17>(points, centroids, assign, d1, d2, counter),
        19 => top2_blocked_simd::<19>(points, centroids, assign, d1, d2, counter),
        20 => top2_blocked_simd::<20>(points, centroids, assign, d1, d2, counter),
        _ => top2_blocked_dyn_simd(points, d, centroids, assign, d1, d2, counter),
    }
}

/// Blocked top-2 scan over **f32 mirrors** through a chosen pair kernel
/// (scalar [`sq_dist_kernel_f32`] or the lane form — bit-identical by
/// construction). Tiling, tie-breaking and per-block accounting are the
/// §2.1 contract unchanged: the bill is precision-independent, exactly
/// n·k.
macro_rules! top2_blocked_f32_body {
    ($pair:path, $points:expr, $d:expr, $centroids:expr,
     $assign:expr, $d1:expr, $d2:expr, $counter:expr) => {{
        let (points, d, centroids) = ($points, $d, $centroids);
        let (assign, d1, d2, counter) = ($assign, $d1, $d2, $counter);
        let m = assign.len();
        let k = centroids.len() / d;
        debug_assert_eq!(points.len(), m * d);
        let mut base = 0usize;
        while base < m {
            let len = (m - base).min(POINT_BLOCK);
            let mut bi = [0u32; POINT_BLOCK];
            let mut b1 = [f64::INFINITY; POINT_BLOCK];
            let mut b2 = [f64::INFINITY; POINT_BLOCK];
            let mut tile = 0usize;
            while tile < k {
                let tlen = (k - tile).min(CENT_TILE);
                for r in 0..len {
                    let i = base + r;
                    let p = &points[i * d..i * d + d];
                    for c in tile..tile + tlen {
                        let acc = $pair(p, &centroids[c * d..c * d + d]);
                        if acc < b1[r] {
                            b2[r] = b1[r];
                            b1[r] = acc;
                            bi[r] = c as u32;
                        } else if acc < b2[r] {
                            b2[r] = acc;
                        }
                    }
                }
                tile += tlen;
            }
            for r in 0..len {
                assign[base + r] = bi[r];
                d1[base + r] = b1[r];
                d2[base + r] = b2[r];
            }
            counter.add((len * k) as u64);
            base += len;
        }
    }};
}

/// Scalar-kernel f32 blocked scan.
fn top2_blocked_f32(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    top2_blocked_f32_body!(sq_dist_kernel_f32, points, d, centroids, assign, d1, d2, counter)
}

/// Lane-kernel f32 blocked scan (bit-identical to [`top2_blocked_f32`]).
#[cfg(feature = "simd")]
fn top2_blocked_f32_simd(
    points: &[f32],
    d: usize,
    centroids: &[f32],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    top2_blocked_f32_body!(sq_dist_lanes_f32, points, d, centroids, assign, d1, d2, counter)
}

/// f64 kernel-kind dispatch: resolve `Auto`, run the lane variant when
/// selected *and* compiled in, otherwise the canonical scalar path.
/// Either way the output and the count are identical (§2.10).
fn top2_f64_dispatch(
    kernel: KernelKind,
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    #[cfg(feature = "simd")]
    if kernel.resolve(d) == KernelKind::Simd {
        return top2_simd_dispatch(points, d, centroids, assign, d1, d2, counter);
    }
    #[cfg(not(feature = "simd"))]
    let _ = kernel;
    top2_dispatch(points, d, centroids, assign, d1, d2, counter)
}

/// f32 kernel-kind dispatch (mirror of [`top2_f64_dispatch`]).
fn top2_f32_dispatch(
    kernel: KernelKind,
    points: &[f32],
    d: usize,
    centroids: &[f32],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    #[cfg(feature = "simd")]
    if kernel.resolve(d) == KernelKind::Simd {
        return top2_blocked_f32_simd(points, d, centroids, assign, d1, d2, counter);
    }
    #[cfg(not(feature = "simd"))]
    let _ = kernel;
    top2_blocked_f32(points, d, centroids, assign, d1, d2, counter)
}

/// The vectorized / mixed-precision backend (DESIGN.md §2.10): the same
/// blocked cache-tiled top-2 scan as [`SerialAssigner`], through the
/// explicit-lane kernels and/or f32 storage mirrors, selected by
/// [`KernelKind`]/[`Precision`]. Contract per mode:
///
/// * `precision = f64` (any kernel): **pinned bit-identical** to
///   [`SerialAssigner`] — the lane kernel performs the identical FP
///   operations in the identical order.
/// * `precision = f32`: *relaxed* — tolerance-bounded against the f64
///   engine per the §2.10 error model; scalar-f32 and simd-f32 remain
///   bit-identical to *each other*.
/// * Counting: exactly n·k per call in either precision (the f32 mirror
///   conversion is storage traffic, not distance work, and charges
///   nothing).
///
/// The f32 mirrors are owned buffers: the point mirror is refilled per
/// call (clear + extend — capacity is kept, so the warm path allocates
/// nothing), and the centroid mirror is **generation-cached** (DESIGN.md
/// §2.12): a [`GenCache`] compares the f64 centroids by value and the
/// O(k·d) mirror conversion runs only when they actually changed — e.g.
/// repeated evaluations at a converged centroid set. Rounding is
/// per-value and input-deterministic, so caching cannot change a single
/// bit of any output; `Sharded<VectorAssigner>` works unchanged (each
/// worker owns its mirrors and cache).
#[derive(Clone, Debug, Default)]
pub struct VectorAssigner {
    kernel: KernelKind,
    precision: Precision,
    pf32: Vec<f32>,
    cf32: Vec<f32>,
    cgen: GenCache,
}

impl VectorAssigner {
    pub fn new(kernel: KernelKind, precision: Precision) -> VectorAssigner {
        VectorAssigner { kernel, precision, ..VectorAssigner::default() }
    }

    /// The backend an [`AssignCfg`]'s `kernel`/`precision` pair selects.
    pub fn from_cfg(cfg: &AssignCfg) -> VectorAssigner {
        VectorAssigner::new(cfg.kernel, cfg.precision)
    }

    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }
}

impl Assigner for VectorAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        self.assign_top2_slices(
            points,
            d,
            centroids,
            counter,
            &mut out.assign,
            &mut out.d1,
            &mut out.d2,
        );
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        match self.precision {
            Precision::F64 => {
                top2_f64_dispatch(self.kernel, points, d, centroids, assign, d1, d2, counter)
            }
            Precision::F32 => {
                self.pf32.clear();
                self.pf32.extend(points.iter().map(|&v| v as f32));
                if self.cgen.refresh(centroids, d) {
                    self.cf32.clear();
                    self.cf32.extend(centroids.iter().map(|&v| v as f32));
                }
                top2_f32_dispatch(self.kernel, &self.pf32, d, &self.cf32, assign, d1, d2, counter);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Backends.
// ---------------------------------------------------------------------------

/// Generation-keyed snapshot of a derived-state input (DESIGN.md §2.12):
/// [`refresh`](Self::refresh) compares the new input by value (plus its
/// row width, so a reshape of identical flat values can never alias)
/// against the cached copy, bumps the generation and re-snapshots on
/// change, and tells the caller whether its derived state must be
/// rebuilt. The comparison is O(len) — centroid-sized, negligible next to
/// the O(m·k·d) scan it guards — and the snapshot buffer is reused, so a
/// warm refresh allocates nothing. Invalidation is *only* by this value
/// comparison: there is no time-to-live and no external dirty bit, so a
/// stale derived state is impossible by construction.
#[derive(Clone, Debug, Default)]
pub struct GenCache {
    gen: u64,
    width: usize,
    data: Vec<f64>,
}

impl GenCache {
    /// `true` when `input` (at row width `width`) differs from the cached
    /// snapshot or the cache is cold: the caller must rebuild whatever it
    /// derives from `input`, then rely on the cache until the next miss.
    pub fn refresh(&mut self, input: &[f64], width: usize) -> bool {
        if self.gen > 0 && self.width == width && self.data == input {
            return false;
        }
        self.gen += 1;
        self.width = width;
        self.data.clear();
        self.data.extend_from_slice(input);
        true
    }

    /// Generation counter: bumped on every rebuild, 0 while cold.
    pub fn gen(&self) -> u64 {
        self.gen
    }
}

/// The serial backend: the blocked, cache-tiled canonical kernel on the
/// calling thread. This is the default engine behind
/// [`super::NativeStepper`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialAssigner;

impl Assigner for SerialAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        top2_dispatch(points, d, centroids, &mut out.assign, &mut out.d1, &mut out.d2, counter);
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        top2_dispatch(points, d, centroids, assign, d1, d2, counter);
    }
}

/// The sharding **combinator** (DESIGN.md §2.5): rows fanned out over
/// `threads` logical shards via the canonical shard split, each shard
/// running its own persistent copy of an arbitrary inner backend `B` on
/// its contiguous row range, reduced in shard order. Because every
/// backend is bit-identical to [`SerialAssigner`] on any row slice,
/// `Sharded<B>` is bit-identical to [`SerialAssigner`] for every inner
/// backend and every thread count — `Sharded<NormPrunedAssigner>` and
/// `Sharded<BoundedAssigner>` exist for free and count whatever their
/// inner backend counts, summed over shards.
///
/// Execution is on the process-wide persistent pool (DESIGN.md §2.12) —
/// no per-call thread spawns — and each shard writes its rows directly
/// into its disjoint window of the caller's pre-sized output via
/// [`Assigner::assign_top2_slices`], so there is no partials-then-extend
/// double copy and a warm [`assign_top2_into`](Assigner::assign_top2_into)
/// call allocates nothing. `threads` stays a pure determinism key: the
/// shard split depends only on it, while physical concurrency is whatever
/// the pool provides (inline serial when the pool is busy — same shards,
/// same order, same bits).
///
/// Worker state persists across calls: shard `s` always owns the rows of
/// `shard_ranges(m, threads)[s]`, so a stateful inner backend (the
/// cross-iteration [`BoundedAssigner`]) sees a stable row slice between
/// weighted-Lloyd iterations and keeps its bounds warm; when `m` changes
/// the slices change and the inner backends re-prime themselves.
#[derive(Clone, Debug)]
pub struct Sharded<B: Assigner> {
    threads: usize,
    workers: Vec<B>,
}

/// The serial-kernel sharding of the original engine — the monolith is now
/// just the combinator applied to [`SerialAssigner`].
pub type ShardedAssigner = Sharded<SerialAssigner>;

impl<B: Assigner + Clone> Sharded<B> {
    /// `threads` workers, each a clone of `worker`.
    pub fn with_backend(threads: usize, worker: B) -> Self {
        let threads = threads.max(1);
        Sharded { threads, workers: vec![worker; threads] }
    }

    /// `threads` workers of a defaultable backend.
    pub fn new(threads: usize) -> Self
    where
        B: Default,
    {
        Self::with_backend(threads, B::default())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// One sharded top-2 pass as a pool task (DESIGN.md §2.12): shard `s`
/// runs worker `s`'s inner backend on its canonical row range,
/// [`shard_range`]`(m, shards, s)`, writing the rows in place through its
/// disjoint output window. The pool claims each shard index exactly once,
/// so the raw-pointer windows never overlap, worker `s` is exclusively
/// shard `s`'s, and the shard-order reduction is implicit in the output
/// layout (shard order == row order — no fan-in copy at all).
struct ShardScanTask<'a, B> {
    points: &'a [f64],
    d: usize,
    centroids: &'a [f64],
    counter: &'a DistanceCounter,
    m: usize,
    shards: usize,
    workers: SendPtr<B>,
    assign: SendPtr<u32>,
    d1: SendPtr<f64>,
    d2: SendPtr<f64>,
}

impl<B: Assigner + Send> PoolTask for ShardScanTask<'_, B> {
    fn run(&self, s: usize) {
        let r = shard_range(self.m, self.shards, s);
        let d = self.d;
        // Safety: each shard index is claimed exactly once (pool
        // contract); shard ranges are disjoint and in-bounds for the m
        // output rows, and worker `s` is touched by shard `s` alone.
        let worker = unsafe { &mut *self.workers.0.add(s) };
        let (assign, d1, d2) = unsafe {
            (
                std::slice::from_raw_parts_mut(self.assign.0.add(r.start), r.len()),
                std::slice::from_raw_parts_mut(self.d1.0.add(r.start), r.len()),
                std::slice::from_raw_parts_mut(self.d2.0.add(r.start), r.len()),
            )
        };
        worker.assign_top2_slices(
            &self.points[r.start * d..r.end * d],
            d,
            self.centroids,
            self.counter,
            assign,
            d1,
            d2,
        );
    }
}

impl<B: Assigner + Send> Assigner for Sharded<B> {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        self.assign_top2_slices(
            points,
            d,
            centroids,
            counter,
            &mut out.assign,
            &mut out.d1,
            &mut out.d2,
        );
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let m = points.len() / d;
        let shards = shard_count(m, self.threads);
        if shards <= 1 {
            // One shard: the inner backend straight into the caller's
            // windows, no pool round-trip.
            return self.workers[0].assign_top2_slices(points, d, centroids, counter, assign, d1, d2);
        }
        let task = ShardScanTask {
            points,
            d,
            centroids,
            counter,
            m,
            shards,
            workers: SendPtr(self.workers.as_mut_ptr()),
            assign: SendPtr(assign.as_mut_ptr()),
            d1: SendPtr(d1.as_mut_ptr()),
            d2: SendPtr(d2.as_mut_ptr()),
        };
        pool::global().run(shards, &task);
    }
}

/// The norm-pruned backend: precomputes every centroid norm ‖c‖ and skips
/// candidates that provably cannot enter the top-2, via the reverse
/// triangle inequality ‖x−c‖ ≥ |‖x‖−‖c‖|. The skip test carries a
/// scale-aware safety margin covering the rounding of the norm
/// subtraction, so outputs stay **bit-identical** to [`SerialAssigner`];
/// only the distance *count* shrinks (DESIGN.md §2.4: pruned backends
/// count k centroid norms + 1 point norm per row + one unit per pair
/// actually evaluated).
///
/// The centroid norms are **generation-cached** (DESIGN.md §2.12): a
/// [`GenCache`] keeps the norm buffer valid while the centroid values are
/// unchanged, so repeated calls at the same centroid set rebuild — and
/// charge — the `k` norm computations only once, on the generation that
/// built them (§2.4: the account bills work actually performed). Any
/// centroid change rebuilds and re-charges. Norm values are input-
/// deterministic, so caching cannot change a single output bit.
#[derive(Clone, Debug, Default)]
pub struct NormPrunedAssigner {
    /// Cached ‖c‖ per centroid, valid for the cached generation.
    norms: Vec<f64>,
    cgen: GenCache,
}

impl NormPrunedAssigner {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Assigner for NormPrunedAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        self.assign_top2_slices(
            points,
            d,
            centroids,
            counter,
            &mut out.assign,
            &mut out.d1,
            &mut out.d2,
        );
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let m = points.len() / d;
        let k = centroids.len() / d;
        // Centroid norms, counted as k distance computations on the
        // generation that computes them; cache hits charge nothing.
        if self.cgen.refresh(centroids, d) {
            self.norms.clear();
            self.norms.resize(k, 0.0);
            for c in 0..k {
                self.norms[c] = norm_kernel(&centroids[c * d..(c + 1) * d]);
            }
            counter.add(k as u64);
        }
        let cn = &self.norms;

        let mut evaluated = 0u64;
        for i in 0..m {
            let p = &points[i * d..(i + 1) * d];
            let pn = norm_kernel(p);
            evaluated += 1; // the point norm
            let (mut i1, mut b1, mut b2) = (0u32, f64::INFINITY, f64::INFINITY);
            // sqrt of the running second-best, maintained lazily so the
            // skip test runs in metric space.
            let mut b2_rt = f64::INFINITY;
            for c in 0..k {
                let lb = (pn - cn[c]).abs();
                // Sound skip: true ‖x−c‖ ≥ lb up to rounding of the two
                // norms. The rounding of a d-term norm is ≤ ~(d/4+2)·ε
                // relative, so the margin scales with d and stays ≥ ~100×
                // the worst case at every dimension — a skipped candidate
                // can never have entered the top-2 (asserted bit-for-bit
                // by the property tests).
                let margin = (4.0 + d as f64) * 1e-14 * (pn + cn[c]);
                if lb > b2_rt + margin {
                    continue;
                }
                let acc = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
                evaluated += 1;
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c as u32;
                    b2_rt = b2.sqrt();
                } else if acc < b2 {
                    b2 = acc;
                    b2_rt = b2.sqrt();
                }
            }
            assign[i] = i1;
            d1[i] = b1;
            d2[i] = b2;
        }
        counter.add(evaluated);
    }
}

/// Euclidean norm through the canonical summation order (identical to
/// `sq_dist_kernel(p, 0)` — subtracting zero is exact — so norms round the
/// same way distances do).
fn norm_kernel(p: &[f64]) -> f64 {
    let d = p.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut j = 0;
    while j + 4 <= d {
        a0 += p[j] * p[j];
        a1 += p[j + 1] * p[j + 1];
        a2 += p[j + 2] * p[j + 2];
        a3 += p[j + 3] * p[j + 3];
        j += 4;
    }
    while j < d {
        a0 += p[j] * p[j];
        j += 1;
    }
    ((a0 + a1) + (a2 + a3)).sqrt()
}

// ---------------------------------------------------------------------------
// Cross-iteration bounded pruning (DESIGN.md §2.7).
// ---------------------------------------------------------------------------

/// Relative deflation applied to a stored lower bound every drift round.
/// It must dominate the floating-point error chain relating a cached
/// metric distance to a later recomputation of the same pair — kernel
/// summation (≲ (d/4+2)·ε rel), `sqrt` (½ ulp), the drift distance's own
/// kernel error, and the subtraction ulps — which totals well under
/// `(8+d)·1e-15`; the factor-10 margin keeps the skip test sound
/// (DESIGN.md §2.7) with ~100× headroom while costing nothing measurable
/// in prune rate.
#[inline]
fn bound_defl(d: usize) -> f64 {
    (8.0 + d as f64) * 1e-14
}

/// What the [`BoundedAssigner`] charged on its most recent call — the
/// backend's own exact account of its `DistanceCounter` activity, pinned
/// by the conformance suite (`counter delta == pairs + bookkeeping`).
#[derive(Clone, Copy, Debug, Default)]
pub struct BoundedStats {
    /// Point–centroid pairs actually evaluated through the canonical
    /// kernel (cold call: exactly `m·k`).
    pub pairs: u64,
    /// Bookkeeping distances: the `k` centroid-drift distances of a warm
    /// call (0 on a cold call).
    pub bookkeeping: u64,
    /// The unpruned bill `m·k` of the same call.
    pub bill: u64,
    /// Whether the call reused bounds (warm) or re-primed them (cold).
    pub warm: bool,
}

impl BoundedStats {
    /// Fraction of the `m·k` pair bill this call *skipped* (0 when cold).
    pub fn prune_rate(&self) -> f64 {
        if self.bill == 0 {
            return 0.0;
        }
        1.0 - self.pairs as f64 / self.bill as f64
    }
}

/// The cross-iteration bounded backend (DESIGN.md §2.7): Hamerly/Elkan-
/// style bounds generalized to weighted representatives and to the
/// engine's **bit-identical top-2** contract.
///
/// State per point: the previous winner and runner-up indices, plus one
/// metric lower bound per centroid (`m·k`, Elkan's memory shape), kept
/// valid across [`weighted_step`] calls on the same representative set by
/// per-centroid drift updates `lb ← lb − ‖c − c'‖` (deflated by
/// `bound_defl` so accumulated rounding can never make a bound
/// overshoot a later recomputation).
///
/// A warm call evaluates, per point, the exact distances to the previous
/// winner and runner-up — two distinct centroids, so the larger of the
/// two caps the true second-nearest distance *exactly*, no drift
/// inflation — then scans the remaining centroids in index order,
/// skipping every candidate whose lower bound exceeds the running cap.
/// Every skipped candidate is provably strictly farther than the final
/// second-nearest value, so the returned `(assign, d1, d2)` equals
/// [`SerialAssigner`]'s bit for bit (§2.1 tie-breaking included), while
/// the counter is charged only `k` drift distances plus the pairs
/// actually evaluated.
///
/// Input change detection is by value: a call whose `points` (or shapes)
/// differ from the cached ones re-primes the bounds with a full `m·k`
/// pass. Centroids may change arbitrarily between calls — drifts are
/// measured from the *last seen* centroids, so skipping steps (as
/// [`AutoAssigner`] does) keeps the bounds valid.
#[derive(Clone, Debug, Default)]
pub struct BoundedAssigner {
    points: Vec<f64>,
    centroids: Vec<f64>,
    d: usize,
    k: usize,
    assign: Vec<u32>,
    runner: Vec<u32>,
    /// m×k metric lower bounds.
    lower: Vec<f64>,
    drift: Vec<f64>,
    /// Reusable k-length distance row of the cold prime (§2.12).
    row: Vec<f64>,
    stats: BoundedStats,
}

impl BoundedAssigner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Exact account of the most recent call (DESIGN.md §2.4/§2.7).
    pub fn last_stats(&self) -> BoundedStats {
        self.stats
    }

    /// Would a call with these inputs reuse the cached bounds?
    pub fn is_warm_for(&self, points: &[f64], d: usize, k: usize) -> bool {
        self.d == d && self.k == k && self.points == points
    }

    /// Cold pass: full distance rows through the canonical kernel (the
    /// §2.6 engine shape, `k` counted per row — `m·k` total, exactly the
    /// serial bill), priming tight per-centroid bounds and the
    /// winner/runner-up pair. Top-2 selection scans the row in index
    /// order with strict `<`, so the output equals the blocked kernel's
    /// bit for bit.
    fn prime(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        out.reset(points.len() / d);
        self.prime_slices(points, d, centroids, counter, &mut out.assign, &mut out.d1, &mut out.d2);
        out
    }

    /// [`prime`](Self::prime) into caller-provided windows (§2.12): all
    /// scratch lives in reused fields, so a steady-state re-prime
    /// allocates nothing.
    fn prime_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let m = points.len() / d;
        let k = centroids.len() / d;
        self.points.clear();
        self.points.extend_from_slice(points);
        self.centroids.clear();
        self.centroids.extend_from_slice(centroids);
        self.d = d;
        self.k = k;
        self.assign.clear();
        self.assign.resize(m, 0);
        self.runner.clear();
        self.runner.resize(m, 0);
        self.lower.clear();
        self.lower.resize(m * k, 0.0);
        self.drift.clear();
        self.drift.resize(k, 0.0);
        self.row.clear();
        self.row.resize(k, 0.0);

        for i in 0..m {
            let p = &points[i * d..(i + 1) * d];
            let (_, _) = sq_dist_row(p, centroids, d, &mut self.row, counter);
            let (mut i1, mut i2, mut b1, mut b2) = (0u32, 0u32, f64::INFINITY, f64::INFINITY);
            for (c, &v) in self.row.iter().enumerate() {
                self.lower[i * k + c] = v.sqrt();
                if v < b1 {
                    b2 = b1;
                    i2 = i1;
                    b1 = v;
                    i1 = c as u32;
                } else if v < b2 {
                    b2 = v;
                    i2 = c as u32;
                }
            }
            self.assign[i] = i1;
            self.runner[i] = i2;
            assign[i] = i1;
            d1[i] = b1;
            d2[i] = b2;
        }
        self.stats = BoundedStats {
            pairs: (m as u64) * (k as u64),
            bookkeeping: 0,
            bill: (m as u64) * (k as u64),
            warm: false,
        };
    }

    /// Warm pass: drift-update the bounds, then the capped pruned scan.
    fn step(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        out.reset(points.len() / d);
        self.step_slices(points, d, centroids, counter, &mut out.assign, &mut out.d1, &mut out.d2);
        out
    }

    /// [`step`](Self::step) into caller-provided windows (§2.12): the
    /// warm path of the zero-allocation steady state — bounds, drifts and
    /// the output all live in reused buffers.
    fn step_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let m = points.len() / d;
        let k = self.k;
        let defl = bound_defl(d);

        // Per-centroid drift from the last-seen centroids (k bookkeeping
        // distances — DESIGN.md §2.4), then the deflated bound update.
        for c in 0..k {
            self.drift[c] =
                dist_kernel(&self.centroids[c * d..(c + 1) * d], &centroids[c * d..(c + 1) * d]);
        }
        counter.add(k as u64);
        for i in 0..m {
            let row = &mut self.lower[i * k..(i + 1) * k];
            for (c, lb) in row.iter_mut().enumerate() {
                let dr = self.drift[c];
                *lb = ((*lb - dr) - defl * (*lb + dr)).max(0.0);
            }
        }
        self.centroids.clear();
        self.centroids.extend_from_slice(centroids);

        let mut pairs = 0u64;
        for i in 0..m {
            let p = &points[i * d..(i + 1) * d];
            let cur = self.assign[i] as usize;
            let d_cur = sq_dist_kernel(p, &centroids[cur * d..(cur + 1) * d]);
            pairs += 1;
            if k == 1 {
                self.lower[i] = d_cur.sqrt();
                assign[i] = 0;
                d1[i] = d_cur;
                d2[i] = f64::INFINITY;
                continue;
            }
            let run = self.runner[i] as usize;
            let d_run = sq_dist_kernel(p, &centroids[run * d..(run + 1) * d]);
            pairs += 1;
            // Two exact distances to two *distinct* centroids: the larger
            // caps the final second-nearest value exactly.
            let cap0 = d_cur.max(d_run).sqrt();

            let (mut i1, mut i2, mut b1, mut b2) = (0u32, 0u32, f64::INFINITY, f64::INFINITY);
            let mut b2_rt = f64::INFINITY;
            for c in 0..k {
                let acc = if c == cur {
                    d_cur
                } else if c == run {
                    d_run
                } else {
                    // Sound skip (§2.7): the deflated lower bound still
                    // under-estimates the distance this pair would compute,
                    // so a candidate above the cap is strictly farther
                    // than the final second-nearest — it could enter
                    // neither top-2 slot of the serial scan.
                    if self.lower[i * k + c] > b2_rt.min(cap0) {
                        continue;
                    }
                    let v = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
                    pairs += 1;
                    self.lower[i * k + c] = v.sqrt();
                    v
                };
                if acc < b1 {
                    b2 = b1;
                    i2 = i1;
                    b1 = acc;
                    i1 = c as u32;
                    b2_rt = b2.sqrt();
                } else if acc < b2 {
                    b2 = acc;
                    i2 = c as u32;
                    b2_rt = b2.sqrt();
                }
            }
            self.lower[i * k + cur] = d_cur.sqrt();
            self.lower[i * k + run] = d_run.sqrt();
            self.assign[i] = i1;
            self.runner[i] = i2;
            assign[i] = i1;
            d1[i] = b1;
            d2[i] = b2;
        }
        counter.add(pairs);
        self.stats = BoundedStats {
            pairs,
            bookkeeping: k as u64,
            bill: (m as u64) * (k as u64),
            warm: true,
        };
    }
}

impl Assigner for BoundedAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        self.assign_top2_slices(
            points,
            d,
            centroids,
            counter,
            &mut out.assign,
            &mut out.d1,
            &mut out.d2,
        );
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let k = centroids.len() / d;
        if self.is_warm_for(points, d, k) {
            self.step_slices(points, d, centroids, counter, assign, d1, d2)
        } else {
            self.prime_slices(points, d, centroids, counter, assign, d1, d2)
        }
    }

    /// [`BoundedStats`] of the most recent call as typed gauges
    /// (DESIGN.md §2.11): prune rate plus its ingredients.
    fn record_metrics(&mut self, rec: &Recorder) {
        if !rec.is_on() {
            return;
        }
        let s = self.stats;
        rec.gauge("bounded.prune_rate", s.prune_rate());
        rec.gauge_u64("bounded.pairs", s.pairs);
        rec.gauge_u64("bounded.bookkeeping", s.bookkeeping);
        rec.gauge_u64("bounded.bill", s.bill);
        rec.gauge_u64("bounded.warm", u64::from(s.warm));
    }
}

// ---------------------------------------------------------------------------
// Approximate regime: cluster-closure candidate lists (DESIGN.md §2.9).
// ---------------------------------------------------------------------------

/// Which assignment regime a run uses (DESIGN.md §2.9): the exact engine
/// (the default — bit-identical backends, §2.1), the cluster-closure
/// candidate backend, or the Big-means-style sampled stepper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignMode {
    Exact,
    Closure,
    Sampled,
}

impl AssignMode {
    pub fn name(self) -> &'static str {
        match self {
            AssignMode::Exact => "exact",
            AssignMode::Closure => "closure",
            AssignMode::Sampled => "sampled",
        }
    }
}

/// Assignment-regime configuration carried by `BwkmCfg`/`RpkmCfg` and the
/// CLI's `assign=exact|closure|sampled`, `closure_expand=`, `sample_rows=`
/// and `sample_seed=` keys (DESIGN.md §2.9), plus the exact engine's
/// `kernel=scalar|simd|auto` / `precision=f64|f32` selection (§2.10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AssignCfg {
    pub mode: AssignMode,
    /// Closure radius: every point's candidate list is the closure of its
    /// previous winner — that centroid plus its `closure_expand` nearest
    /// others (clamped to ≥ 1; a closure that would be *total* routes
    /// through the exact fallback instead).
    pub closure_expand: usize,
    /// Rows per sampled weighted-Lloyd step (`≥ m` runs the exact step).
    pub sample_rows: usize,
    /// Seed of the sampled stepper's **private** index stream. Kept out
    /// of the run's main `Rng` so switching `assign=` modes leaves every
    /// other random draw of the run identical.
    pub sample_seed: u64,
    /// Exact-engine kernel selection (§2.10). Non-default values apply to
    /// `mode = Exact` only — the approximate regime always runs the
    /// canonical scalar kernel, and the config layer rejects the
    /// combination rather than ignore it.
    pub kernel: KernelKind,
    /// Exact-engine precision (§2.10); same `Exact`-only rule as `kernel`.
    pub precision: Precision,
}

impl Default for AssignCfg {
    fn default() -> Self {
        AssignCfg {
            mode: AssignMode::Exact,
            closure_expand: 2,
            sample_rows: 0,
            sample_seed: 0xB16D_A7A5,
            kernel: KernelKind::Scalar,
            precision: Precision::F64,
        }
    }
}

/// What the [`ClosureAssigner`] charged on its most recent call — the
/// backend's own exact account of its `DistanceCounter` activity, pinned
/// by the conformance suite with `counter delta == pairs + bookkeeping`
/// (the [`BoundedStats`] pattern).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClosureStats {
    /// Point–candidate pairs evaluated through the canonical kernel
    /// (exact fallback: exactly `m·k`).
    pub pairs: u64,
    /// Bookkeeping distances: the `k·(k−1)/2` inter-centroid distances a
    /// warm call spends building the closures (0 on a fallback).
    pub bookkeeping: u64,
    /// The unpruned bill `m·k` of the same call.
    pub bill: u64,
    /// Whether the call ran the approximate closure scan (`false`: it
    /// fell back to the exact engine).
    pub warm: bool,
    /// Candidates per point of a warm call (0 on a fallback).
    pub candidates: usize,
    /// Points whose winner landed strictly inside its closure, i.e. not
    /// on the rim (a fallback counts every point: exact always "hits").
    pub hits: u64,
    /// Points assigned by the call.
    pub points: u64,
    /// Cumulative exact fallbacks over the backend's lifetime (cold
    /// primes included).
    pub fallbacks: u64,
}

impl ClosureStats {
    /// Fraction of points whose winner did not land on its closure's rim
    /// — the observed probability that the candidate list was wide
    /// enough. 1.0 before any call and after exact fallbacks.
    pub fn hit_rate(&self) -> f64 {
        if self.points == 0 {
            1.0
        } else {
            self.hits as f64 / self.points as f64
        }
    }
}

/// The cluster-closure **approximate** backend (DESIGN.md §2.9, after
/// "Fast Approximate K-means via Cluster Closures", PAPERS.md): a warm
/// call evaluates each point only against the *closure* of its previous
/// winner — that centroid plus its `expand` nearest others — instead of
/// all k centroids. The same boundary intuition as BWKM's cutting
/// criterion: a point's next winner is almost always in the immediate
/// neighborhood of its current one.
///
/// Unlike every other backend in this module the output is **not**
/// bit-identical to [`SerialAssigner`] on warm calls — the returned
/// `(assign, d1, d2)` is exact *restricted to the candidate set* (same
/// kernel, same strict-`<` index-order tie-breaking), so `d1 ≥` serial's
/// and `d2` is the candidate-set runner-up. What *is* pinned exactly is
/// the accounting: every call charges `pairs + bookkeeping` with
/// self-reported [`ClosureStats`], and the measured quality gap is
/// available on demand via [`Assigner::quality_gap`].
///
/// Exact fallbacks (cold anchors, shape change, total closure, or a
/// closure build that would not amortize) run [`SerialAssigner`]
/// verbatim — bit-identical output at the full `m·k` bill — and re-prime
/// the anchors; `fallbacks` tallies them.
///
/// The closure table is **generation-cached** (DESIGN.md §2.12): it is a
/// pure function of (centroids, d, candidate width), so a [`GenCache`]
/// keeps it valid while the centroids are unchanged and a warm call at
/// the same centroid set charges `bookkeeping = 0` — the `k·(k−1)/2`
/// build was billed on the generation that performed it, and the
/// self-account `counter delta == pairs + bookkeeping` stays exact per
/// call (§2.4). All build scratch lives in reused fields, so a
/// steady-state rebuild allocates nothing.
#[derive(Clone, Debug)]
pub struct ClosureAssigner {
    expand: usize,
    points: Vec<f64>,
    d: usize,
    k: usize,
    /// Previous winner per point — the closure anchor of the next call.
    assign: Vec<u32>,
    /// Generation-cached closure table (k×`cached_c` row-major) and rims.
    closures: Vec<u32>,
    rims: Vec<u32>,
    cached_c: usize,
    cgen: GenCache,
    /// Reused closure-build scratch: k×k inter-centroid distances and the
    /// per-anchor sort order.
    dist: Vec<f64>,
    order: Vec<u32>,
    stats: ClosureStats,
    fallbacks: u64,
}

impl Default for ClosureAssigner {
    fn default() -> Self {
        Self::new(AssignCfg::default().closure_expand)
    }
}

impl ClosureAssigner {
    /// Candidate lists of `1 + expand` centroids. `expand` is clamped to
    /// ≥ 1 so every warm-evaluated point keeps a genuine runner-up for
    /// `d2` (BWKM's ε machinery would read `d2 = ∞` as a zero
    /// misassignment bound otherwise).
    pub fn new(expand: usize) -> Self {
        ClosureAssigner {
            expand: expand.max(1),
            points: Vec::new(),
            d: 0,
            k: 0,
            assign: Vec::new(),
            closures: Vec::new(),
            rims: Vec::new(),
            cached_c: 0,
            cgen: GenCache::default(),
            dist: Vec::new(),
            order: Vec::new(),
            stats: ClosureStats::default(),
            fallbacks: 0,
        }
    }

    pub fn expand(&self) -> usize {
        self.expand
    }

    /// Exact account of the most recent call (DESIGN.md §2.4/§2.9).
    pub fn last_stats(&self) -> ClosureStats {
        self.stats
    }

    /// Would a call with these inputs reuse the cached anchors?
    pub fn is_warm_for(&self, points: &[f64], d: usize, k: usize) -> bool {
        self.d == d && self.k == k && self.points == points
    }

    /// Candidates per point a warm call would scan.
    fn candidates(&self, k: usize) -> usize {
        (self.expand + 1).min(k)
    }

    /// Is the closure scan a strict win over the exact `m·k` bill? False
    /// when the closure would be total (`c == k`: nothing left to prune
    /// — the degenerate "empty closure complement") or when the
    /// `k·(k−1)/2` closure build would not amortize over `m` points, so
    /// an approximate bill can never exceed the exact one.
    pub fn approx_viable(&self, m: usize, k: usize) -> bool {
        let c = self.candidates(k);
        c < k && (k * (k - 1)) / 2 + m * c < m * k
    }
}

/// The closure table of one centroid set: for every anchor centroid, the
/// candidate list of itself plus its `c − 1` nearest other centroids
/// (nearest-first selection, index tie-breaking, then re-sorted to
/// ascending index so the strict-`<` candidate scan inherits the serial
/// tie-breaking on the subset), plus the anchor's **rim** — the farthest
/// member of its own closure. Returns `(closures, rims, bookkeeping)`
/// where `closures` is k×c row-major and `bookkeeping = k·(k−1)/2`
/// kernel evaluations.
fn build_closures(centroids: &[f64], d: usize, k: usize, c: usize) -> (Vec<u32>, Vec<u32>, u64) {
    let (mut dist, mut order) = (Vec::new(), Vec::new());
    let (mut closures, mut rims) = (Vec::new(), Vec::new());
    let bookkeeping =
        build_closures_into(centroids, d, k, c, &mut dist, &mut order, &mut closures, &mut rims);
    (closures, rims, bookkeeping)
}

/// [`build_closures`] into caller-reused buffers (DESIGN.md §2.12): all
/// four vectors are cleared and refilled in place, so a steady-state
/// rebuild allocates nothing once they have seen their (k, c) shape.
#[allow(clippy::too_many_arguments)]
fn build_closures_into(
    centroids: &[f64],
    d: usize,
    k: usize,
    c: usize,
    dist: &mut Vec<f64>,
    order: &mut Vec<u32>,
    closures: &mut Vec<u32>,
    rims: &mut Vec<u32>,
) -> u64 {
    dist.clear();
    dist.resize(k * k, 0.0);
    for a in 0..k {
        for b in (a + 1)..k {
            let v =
                sq_dist_kernel(&centroids[a * d..(a + 1) * d], &centroids[b * d..(b + 1) * d]);
            dist[a * k + b] = v;
            dist[b * k + a] = v;
        }
    }
    let bookkeeping = (k * (k - 1) / 2) as u64;
    closures.clear();
    closures.resize(k * c, 0);
    rims.clear();
    rims.resize(k, 0);
    for a in 0..k {
        order.clear();
        order.extend(0..k as u32);
        order.sort_by(|&x, &y| {
            let (dx, dy) = (dist[a * k + x as usize], dist[a * k + y as usize]);
            dx.partial_cmp(&dy).expect("finite centroid distances").then(x.cmp(&y))
        });
        let sel = &mut closures[a * c..(a + 1) * c];
        sel.copy_from_slice(&order[..c]);
        rims[a] = sel[c - 1];
        sel.sort_unstable();
    }
    bookkeeping
}

/// One approximate pass: each point scanned against the closure of its
/// anchor (previous winner), exact kernel over the candidate subset.
/// Returns the pass plus `(pairs, hits)`.
fn closure_scan(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    anchors: &[u32],
    closures: &[u32],
    c: usize,
    rims: &[u32],
) -> (AssignOut, u64, u64) {
    let mut out = AssignOut::default();
    out.reset(points.len() / d);
    let (pairs, hits) = closure_scan_slices(
        points,
        d,
        centroids,
        anchors,
        closures,
        c,
        rims,
        &mut out.assign,
        &mut out.d1,
        &mut out.d2,
    );
    (out, pairs, hits)
}

/// [`closure_scan`] into caller-provided windows (DESIGN.md §2.12).
#[allow(clippy::too_many_arguments)]
fn closure_scan_slices(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    anchors: &[u32],
    closures: &[u32],
    c: usize,
    rims: &[u32],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
) -> (u64, u64) {
    let m = points.len() / d;
    let mut hits = 0u64;
    for i in 0..m {
        let p = &points[i * d..(i + 1) * d];
        let a = anchors[i] as usize;
        let cand = &closures[a * c..(a + 1) * c];
        let (mut i1, mut b1, mut b2) = (cand[0], f64::INFINITY, f64::INFINITY);
        for &cc in cand {
            let v = sq_dist_kernel(p, &centroids[cc as usize * d..(cc as usize + 1) * d]);
            if v < b1 {
                b2 = b1;
                b1 = v;
                i1 = cc;
            } else if v < b2 {
                b2 = v;
            }
        }
        if i1 != rims[a] {
            hits += 1;
        }
        assign[i] = i1;
        d1[i] = b1;
        d2[i] = b2;
    }
    ((m * c) as u64, hits)
}

impl Assigner for ClosureAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let mut out = AssignOut::default();
        self.assign_top2_into(points, d, centroids, counter, &mut out);
        out
    }

    fn assign_top2_into(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        out: &mut AssignOut,
    ) {
        out.reset(points.len() / d);
        self.assign_top2_slices(
            points,
            d,
            centroids,
            counter,
            &mut out.assign,
            &mut out.d1,
            &mut out.d2,
        );
    }

    fn assign_top2_slices(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        assign: &mut [u32],
        d1: &mut [f64],
        d2: &mut [f64],
    ) {
        let m = points.len() / d;
        let k = centroids.len() / d;
        if !self.is_warm_for(points, d, k) || !self.approx_viable(m, k) {
            // Exact fallback (cold anchors, shape change, or a closure
            // that would be total / would not amortize): the serial
            // engine at its full `m·k` bill, which also re-primes the
            // anchors. The closure-table cache is untouched — it depends
            // only on (centroids, d, c), which a fallback does not change.
            SerialAssigner.assign_top2_slices(points, d, centroids, counter, assign, d1, d2);
            self.points.clear();
            self.points.extend_from_slice(points);
            self.d = d;
            self.k = k;
            self.assign.clear();
            self.assign.extend_from_slice(assign);
            self.fallbacks += 1;
            self.stats = ClosureStats {
                pairs: (m as u64) * (k as u64),
                bookkeeping: 0,
                bill: (m as u64) * (k as u64),
                warm: false,
                candidates: 0,
                hits: m as u64,
                points: m as u64,
                fallbacks: self.fallbacks,
            };
            return;
        }
        let c = self.candidates(k);
        // Rebuild — and charge — the closure table only when the
        // centroid generation (or the candidate width) actually changed
        // (§2.12); a cache hit reports `bookkeeping = 0`, keeping the
        // per-call self-account exact (§2.4).
        let bookkeeping = if self.cgen.refresh(centroids, d) || self.cached_c != c {
            self.cached_c = c;
            build_closures_into(
                centroids,
                d,
                k,
                c,
                &mut self.dist,
                &mut self.order,
                &mut self.closures,
                &mut self.rims,
            )
        } else {
            0
        };
        let (pairs, hits) = closure_scan_slices(
            points,
            d,
            centroids,
            &self.assign,
            &self.closures,
            c,
            &self.rims,
            assign,
            d1,
            d2,
        );
        counter.add(pairs + bookkeeping);
        self.assign.copy_from_slice(assign);
        self.stats = ClosureStats {
            pairs,
            bookkeeping,
            bill: (m as u64) * (k as u64),
            warm: true,
            candidates: c,
            hits,
            points: m as u64,
            fallbacks: self.fallbacks,
        };
    }

    /// Measured E-vs-exact of the state this backend is in *right now*:
    /// replays the scan the next warm call would run (read-only — the
    /// anchors are untouched) against a serial pass, both on private
    /// counters (uncounted instrumentation, DESIGN.md §2.4). The weighted
    /// errors are accumulated in row order on both sides, so
    /// `approx_err ≥ exact_err` holds exactly (each term is a min over a
    /// subset of the same kernel values; rounded summation is monotone).
    fn quality_gap(
        &mut self,
        points: &[f64],
        weights: Option<&[f64]>,
        d: usize,
        centroids: &[f64],
    ) -> Option<QualityGap> {
        let m = points.len() / d;
        let k = centroids.len() / d;
        let probe = DistanceCounter::new();
        let exact = SerialAssigner.assign_top2(points, d, centroids, &probe);
        let wsum = |out: &AssignOut| {
            let mut e = 0.0f64;
            for i in 0..m {
                e += weights.map_or(1.0, |w| w[i]) * out.d1[i];
            }
            e
        };
        let exact_err = wsum(&exact);
        let approx_err = if self.is_warm_for(points, d, k) && self.approx_viable(m, k) {
            let c = self.candidates(k);
            let (closures, rims, _) = build_closures(centroids, d, k, c);
            let (out, _, _) =
                closure_scan(points, d, centroids, &self.assign, &closures, c, &rims);
            wsum(&out)
        } else {
            // The next call would fall back to the exact engine.
            exact_err
        };
        Some(QualityGap {
            backend: "closure",
            approx_err,
            exact_err,
            hit_rate: self.stats.hit_rate(),
            fallbacks: self.fallbacks,
        })
    }

    /// [`ClosureStats`] of the most recent call as typed gauges
    /// (DESIGN.md §2.11). `closure.fallbacks` is cumulative, so its last
    /// gauged value is the lifetime total.
    fn record_metrics(&mut self, rec: &Recorder) {
        if !rec.is_on() {
            return;
        }
        let s = self.stats;
        rec.gauge("closure.hit_rate", s.hit_rate());
        rec.gauge_u64("closure.pairs", s.pairs);
        rec.gauge_u64("closure.bookkeeping", s.bookkeeping);
        rec.gauge_u64("closure.bill", s.bill);
        rec.gauge_u64("closure.candidates", s.candidates as u64);
        rec.gauge_u64("closure.fallbacks", s.fallbacks);
    }
}

// ---------------------------------------------------------------------------
// Per-step backend auto-selection (DESIGN.md §2.7).
// ---------------------------------------------------------------------------

/// Below this k the bounded machinery cannot beat the plain kernel (a warm
/// step pays ≥ 2 of k pairs per point anyway).
const AUTO_MIN_K: usize = 4;
/// Below this m per call, backend overheads dwarf any pruning win.
const AUTO_MIN_M: usize = 64;
/// Keep using bounds while they skip at least this fraction of the bill.
const AUTO_MIN_RATE: f64 = 0.2;
/// While demoted to norm pruning, re-probe the bounds every this many
/// warm steps (drifts shrink as Lloyd converges, so bounds recover).
const AUTO_PROBE_EVERY: u64 = 8;
/// Approximate regime only: keep the closure backend while its observed
/// hit rate holds at least this fraction (the §2.9 analogue of
/// [`AUTO_MIN_RATE`]).
const AUTO_MIN_HIT: f64 = 0.5;

/// A backend [`AutoAssigner`] can select. One enum drives dispatch, the
/// choice tally *and* the note log, so the three can never disagree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoChoice {
    Serial = 0,
    NormPruned = 1,
    Bounded = 2,
    /// The approximate closure backend — selectable only after
    /// [`AutoAssigner::with_closure`] opted the engine into the
    /// approximate regime (DESIGN.md §2.9); the default engine never
    /// picks it.
    Closure = 3,
}

impl AutoChoice {
    /// Every selectable backend, in discriminant order.
    pub const ALL: [AutoChoice; 4] =
        [AutoChoice::Serial, AutoChoice::NormPruned, AutoChoice::Bounded, AutoChoice::Closure];

    pub fn name(self) -> &'static str {
        match self {
            AutoChoice::Serial => "serial",
            AutoChoice::NormPruned => "normpruned",
            AutoChoice::Bounded => "bounded",
            AutoChoice::Closure => "closure",
        }
    }
}

/// Per-[`AutoChoice`] selection tallies — the structured form of the
/// per-step note log, keyed by choice rather than by tuple position so a
/// new backend can never silently alias an existing counter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChoiceCounts {
    counts: [u64; 4],
}

impl ChoiceCounts {
    /// How often `choice` was selected.
    pub fn get(&self, choice: AutoChoice) -> u64 {
        self.counts[choice as usize]
    }

    /// Total calls tallied.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// `(choice, count)` pairs in discriminant order.
    pub fn iter(&self) -> impl Iterator<Item = (AutoChoice, u64)> + '_ {
        AutoChoice::ALL.iter().map(move |&c| (c, self.get(c)))
    }

    /// `"serial:a normpruned:b bounded:c closure:d"` — the bench-report
    /// column form.
    pub fn summary(&self) -> String {
        AutoChoice::ALL
            .iter()
            .map(|&c| format!("{}:{}", c.name(), self.get(c)))
            .collect::<Vec<_>>()
            .join(" ")
    }

    fn bump(&mut self, choice: AutoChoice) {
        self.counts[choice as usize] += 1;
    }
}

/// Per-step backend auto-selection (DESIGN.md §2.7): picks
/// [`SerialAssigner`], [`NormPrunedAssigner`] or [`BoundedAssigner`] per
/// call from (m, k, d, warmth, last-step prune rate) and logs the choice
/// as a [`DistanceCounter`] note, so the accounting report shows which
/// engine produced each count. All candidate backends are bit-identical
/// (§2.1), so the selection is unobservable in the output — only in time
/// and count.
///
/// Policy (deterministic): a cold call — new representative set — runs
/// serial when the problem is too small to amortize bound state
/// (`k < 4 || m < 64`) and otherwise invests the same `m·k` bill in the
/// bounded backend to prime its bounds; a warm call keeps the bounded
/// backend while its last prune rate holds above 20%, demoting to the
/// stateless norm-pruned backend otherwise, with a bounded re-probe every
/// 8th warm step.
///
/// **Approximate regime (opt-in):** [`with_closure`](Self::with_closure)
/// adds the [`ClosureAssigner`] as a fourth selectable choice, preferred
/// while its observed hit rate holds ≥ 50% (DESIGN.md §2.9). The default
/// (`new`) engine never selects it, so exact auto runs stay bit-identical
/// to serial.
#[derive(Clone, Debug)]
pub struct AutoAssigner {
    bounded: BoundedAssigner,
    /// Persistent norm-pruned worker, so its generation-cached centroid
    /// norms (§2.12) survive across demoted steps.
    pruned: NormPrunedAssigner,
    /// The approximate fourth choice; `None` on the default exact engine.
    closure: Option<ClosureAssigner>,
    step: u64,
    warm_steps: u64,
    last_rate: f64,
    /// Observed closure hit rate (approximate regime only; 1.0 before
    /// any closure call).
    last_hit: f64,
    last_choice: Option<AutoChoice>,
    /// Per-choice selection tallies — the structured form of the
    /// per-step note log, for reports that aggregate choices rather than
    /// replay them.
    choices: ChoiceCounts,
    /// Metrics-only: the choice most recently published through
    /// [`Assigner::record_metrics`], so engine-choice *switches* surface
    /// as events (DESIGN.md §2.11). Never read by the selection policy.
    reported_choice: Option<AutoChoice>,
}

impl Default for AutoAssigner {
    fn default() -> Self {
        AutoAssigner {
            bounded: BoundedAssigner::new(),
            pruned: NormPrunedAssigner::new(),
            closure: None,
            step: 0,
            warm_steps: 0,
            last_rate: 1.0,
            last_hit: 1.0,
            last_choice: None,
            choices: ChoiceCounts::default(),
            reported_choice: None,
        }
    }
}

impl AutoAssigner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Opt the auto policy into the approximate regime (DESIGN.md §2.9):
    /// the [`ClosureAssigner`] with the given `expand` becomes a fourth
    /// selectable backend, learned from its observed hit rate.
    pub fn with_closure(expand: usize) -> Self {
        AutoAssigner { closure: Some(ClosureAssigner::new(expand)), ..Self::default() }
    }

    /// The backend the most recent call ran on (`"none"` before any
    /// call).
    pub fn last_choice(&self) -> &'static str {
        self.last_choice.map(AutoChoice::name).unwrap_or("none")
    }

    /// How often each backend was selected, keyed by [`AutoChoice`].
    pub fn choice_counts(&self) -> ChoiceCounts {
        self.choices
    }

    /// The bounded backend's most recent stats (for bench columns).
    pub fn last_bounded_stats(&self) -> BoundedStats {
        self.bounded.last_stats()
    }

    /// The approximate-regime policy (DESIGN.md §2.9): run the closure
    /// backend — whose cold calls are its own exact re-priming fallback —
    /// while its observed hit rate holds ≥ [`AUTO_MIN_HIT`], demoting to
    /// the stateless exact norm-pruned backend otherwise, with a closure
    /// re-probe every [`AUTO_PROBE_EVERY`]-th warm step (anchors stay
    /// valid while the points do, so the closure can recover).
    fn assign_closure(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
        m: usize,
        k: usize,
    ) -> AssignOut {
        let warm = self.closure.as_ref().map_or(false, |cl| cl.is_warm_for(points, d, k));
        self.warm_steps = if warm { self.warm_steps + 1 } else { 0 };
        let choice = if self.last_hit >= AUTO_MIN_HIT || self.warm_steps % AUTO_PROBE_EVERY == 0 {
            AutoChoice::Closure
        } else {
            AutoChoice::NormPruned
        };
        let out = match choice {
            AutoChoice::Closure => {
                let cl = self.closure.as_mut().expect("closure policy without a backend");
                let out = cl.assign_top2(points, d, centroids, counter);
                self.last_hit = cl.last_stats().hit_rate();
                out
            }
            _ => self.pruned.assign_top2(points, d, centroids, counter),
        };
        self.step += 1;
        self.last_choice = Some(choice);
        self.choices.bump(choice);
        counter.note(format!(
            "auto[{}]: {} (m={m} k={k} d={d} warm={warm} hit={:.0}%)",
            self.step,
            choice.name(),
            self.last_hit * 100.0
        ));
        out
    }
}

impl Assigner for AutoAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = points.len() / d;
        let k = centroids.len() / d;
        if self.closure.is_some() {
            return self.assign_closure(points, d, centroids, counter, m, k);
        }
        let warm = self.bounded.is_warm_for(points, d, k);
        self.warm_steps = if warm { self.warm_steps + 1 } else { 0 };
        let choice = if !warm {
            if k >= AUTO_MIN_K && m >= AUTO_MIN_M {
                AutoChoice::Bounded
            } else {
                AutoChoice::Serial
            }
        } else if self.last_rate >= AUTO_MIN_RATE || self.warm_steps % AUTO_PROBE_EVERY == 0 {
            AutoChoice::Bounded
        } else {
            AutoChoice::NormPruned
        };
        let out = match choice {
            AutoChoice::Bounded => {
                // Dispatch on the warmth already computed above rather
                // than through `assign_top2`, which would repeat the
                // O(m·d) by-value input comparison.
                let out = if warm {
                    self.bounded.step(points, d, centroids, counter)
                } else {
                    self.bounded.prime(points, d, centroids, counter)
                };
                let stats = self.bounded.last_stats();
                // A cold prime pays the full bill by construction; judge
                // pruning from warm steps only.
                self.last_rate = if stats.warm { stats.prune_rate() } else { 1.0 };
                out
            }
            AutoChoice::Serial => SerialAssigner.assign_top2(points, d, centroids, counter),
            AutoChoice::NormPruned | AutoChoice::Closure => {
                self.pruned.assign_top2(points, d, centroids, counter)
            }
        };
        self.step += 1;
        self.last_choice = Some(choice);
        self.choices.bump(choice);
        counter.note(format!(
            "auto[{}]: {} (m={m} k={k} d={d} warm={warm} prune={:.0}%)",
            self.step,
            choice.name(),
            self.last_rate * 100.0
        ));
        out
    }

    /// Exact-mode auto has no gap to report; the approximate regime
    /// delegates to its closure backend (DESIGN.md §2.9).
    fn quality_gap(
        &mut self,
        points: &[f64],
        weights: Option<&[f64]>,
        d: usize,
        centroids: &[f64],
    ) -> Option<QualityGap> {
        self.closure.as_mut()?.quality_gap(points, weights, d, centroids)
    }

    /// The auto policy's per-step note content as typed metrics
    /// (DESIGN.md §2.11): one gauge per [`AutoChoice`] tally (cumulative,
    /// so last value == total — cross-checked `==` against
    /// [`AutoAssigner::choice_counts`] and the `auto[…]` note log by the
    /// conformance suite), the last observed prune/hit rates, and an
    /// `auto.switch` event whenever the selected backend changed since
    /// the previous publication.
    fn record_metrics(&mut self, rec: &Recorder) {
        if !rec.is_on() {
            return;
        }
        for (choice, count) in self.choices.iter() {
            rec.gauge_u64(&format!("auto.choice.{}", choice.name()), count);
        }
        rec.gauge_u64("auto.steps", self.step);
        rec.gauge("auto.prune_rate", self.last_rate);
        rec.gauge("auto.hit_rate", self.last_hit);
        if self.last_choice != self.reported_choice {
            rec.event("auto.switch", self.last_choice());
            self.reported_choice = self.last_choice;
        }
    }
}

// ---------------------------------------------------------------------------
// Shared consumers: the three shapes every retired loop reduces to.
// ---------------------------------------------------------------------------

/// Reusable accumulation scratch for [`weighted_step_with`], so steppers
/// that iterate (the weighted-Lloyd outer loops) keep the retired
/// `NativeStepper`'s "no per-iteration allocation in the hot loop"
/// property for the cluster aggregates.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    sums: Vec<f64>,
    counts: Vec<f64>,
}

/// One weighted-Lloyd iteration on any [`Assigner`] backend (paper Alg. 1
/// steps 2/4): engine assignment, then a serial weighted accumulation in
/// row order and the center-of-mass update (empty clusters keep their
/// centroid). Because the accumulation is always serial and in row order,
/// `werr`, `sums` and the updated centroids are bit-identical across
/// backends (DESIGN.md §2.5). One-shot convenience over
/// [`weighted_step_with`]; iterating callers hold a [`StepScratch`].
pub fn weighted_step(
    engine: &mut dyn Assigner,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> StepOut {
    weighted_step_with(engine, &mut StepScratch::default(), reps, weights, d, centroids, counter)
}

/// [`weighted_step`] with caller-owned accumulation scratch. One-shot
/// form of [`weighted_step_into`] on a fresh [`StepOut`].
pub fn weighted_step_with(
    engine: &mut dyn Assigner,
    scratch: &mut StepScratch,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> StepOut {
    let mut out = StepOut::default();
    weighted_step_into(engine, scratch, reps, weights, d, centroids, counter, &mut out);
    out
}

/// One weighted-Lloyd iteration into a caller-owned reusable [`StepOut`]
/// (DESIGN.md §2.12): the assignment pass lands in `out`'s assign/d1/d2
/// buffers through [`Assigner::assign_top2_into`] and the centroid update
/// is written in place, so a warm caller — pre-sized buffers, exact
/// backend — performs **zero heap allocations per step** (pinned by
/// `tests/pool_conformance.rs`). Accumulation stays serial in row order,
/// so every value is bit-identical to [`weighted_step`]'s.
#[allow(clippy::too_many_arguments)]
pub fn weighted_step_into(
    engine: &mut dyn Assigner,
    scratch: &mut StepScratch,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
    out: &mut StepOut,
) {
    let m = weights.len();
    let k = centroids.len() / d;
    // Reuse out's buffers as the assignment arena (moved out and back, so
    // the engine sees one coherent AssignOut).
    let mut top2 = AssignOut {
        assign: std::mem::take(&mut out.assign),
        d1: std::mem::take(&mut out.d1),
        d2: std::mem::take(&mut out.d2),
    };
    engine.assign_top2_into(reps, d, centroids, counter, &mut top2);

    scratch.sums.clear();
    scratch.sums.resize(k * d, 0.0);
    scratch.counts.clear();
    scratch.counts.resize(k, 0.0);
    let mut werr = 0.0f64;
    for i in 0..m {
        let w = weights[i];
        werr += w * top2.d1[i];
        let c = top2.assign[i] as usize;
        let p = &reps[i * d..(i + 1) * d];
        let s = &mut scratch.sums[c * d..(c + 1) * d];
        for j in 0..d {
            s[j] += w * p[j];
        }
        scratch.counts[c] += w;
    }

    out.centroids.clear();
    out.centroids.extend_from_slice(centroids);
    for c in 0..k {
        if scratch.counts[c] > 0.0 {
            let inv = 1.0 / scratch.counts[c];
            for j in 0..d {
                out.centroids[c * d + j] = scratch.sums[c * d + j] * inv;
            }
        }
    }
    out.assign = top2.assign;
    out.d1 = top2.d1;
    out.d2 = top2.d2;
    out.werr = werr;
}

/// Assignment + SSE on any [`Assigner`] backend — the E^D / E^P evaluator
/// shape (`coordinator::sharded_assign_err` is a thin wrapper). The SSE is
/// accumulated serially in row order, so it is backend-independent.
pub fn assign_err(
    engine: &mut dyn Assigner,
    points: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> (Vec<u32>, f64) {
    let top2 = engine.assign_top2(points, d, centroids, counter);
    let sse = top2.d1.iter().sum();
    (top2.assign, sse)
}

/// Nearest centroid of a single row through the canonical kernel — the
/// per-row *pure* shape streamed fan-outs hand to their chunk workers
/// (the K-means|| refresh of DESIGN.md §2.8: workers compute this,
/// the leader folds). Straight scan in index order with strict `<`, so
/// `(d1, argmin)` equals the blocked kernel's output bit for bit (§2.1;
/// tiling only reorders memory traffic). Returns `(∞, 0)` when
/// `centroids` is empty. Counts nothing itself — callers account rows·k
/// per pass, exactly as the engine's per-block batching does.
#[inline]
pub fn nearest_in(p: &[f64], centroids: &[f64], d: usize) -> (f64, u32) {
    let k = centroids.len() / d;
    let (mut b1, mut i1) = (f64::INFINITY, 0u32);
    for c in 0..k {
        let v = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
        if v < b1 {
            b1 = v;
            i1 = c as u32;
        }
    }
    (b1, i1)
}

/// Exact full-row fallback (DESIGN.md §2.6): all k squared distances of
/// one point through the canonical kernel, written into `row`; returns
/// (argmin, min). Counts k. This is the engine shape behind Elkan's
/// bound-initialization pass, which needs *every* distance, not just the
/// top 2.
pub fn sq_dist_row(
    p: &[f64],
    centroids: &[f64],
    d: usize,
    row: &mut [f64],
    counter: &DistanceCounter,
) -> (usize, f64) {
    let k = centroids.len() / d;
    debug_assert_eq!(row.len(), k);
    let (mut i1, mut b1) = (0usize, f64::INFINITY);
    for c in 0..k {
        let dd = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
        row[c] = dd;
        if dd < b1 {
            b1 = dd;
            i1 = c;
        }
    }
    counter.add(k as u64);
    (i1, b1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Verbatim copy of the retired `NativeStepper` assignment loop (the
    /// pre-engine hot path of `weighted_lloyd.rs`): straight row scan,
    /// 4-way split accumulators, strict-`<` top-2. The engine must match
    /// it bit for bit — same floats, same indices, same counts.
    fn retired_reference(
        reps: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = reps.len() / d;
        let k = centroids.len() / d;
        let mut out = AssignOut {
            assign: vec![0u32; m],
            d1: vec![0.0; m],
            d2: vec![0.0; m],
        };
        for i in 0..m {
            let p = &reps[i * d..i * d + d];
            let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
            for c in 0..k {
                let q = &centroids[c * d..c * d + d];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                let mut j = 0;
                while j + 4 <= d {
                    let t0 = p[j] - q[j];
                    let t1 = p[j + 1] - q[j + 1];
                    let t2 = p[j + 2] - q[j + 2];
                    let t3 = p[j + 3] - q[j + 3];
                    a0 += t0 * t0;
                    a1 += t1 * t1;
                    a2 += t2 * t2;
                    a3 += t3 * t3;
                    j += 4;
                }
                while j < d {
                    let t = p[j] - q[j];
                    a0 += t * t;
                    j += 1;
                }
                let acc = (a0 + a1) + (a2 + a3);
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c;
                } else if acc < b2 {
                    b2 = acc;
                }
            }
            out.assign[i] = i1 as u32;
            out.d1[i] = b1;
            out.d2[i] = b2;
        }
        counter.add((m * k) as u64);
        out
    }

    fn counter() -> DistanceCounter {
        DistanceCounter::new()
    }

    #[test]
    fn prop_engine_matches_retired_loop_bit_for_bit() {
        // The acceptance property of the port: on random weighted corpora
        // the engine's top-2 output and distance counts equal the retired
        // per-algorithm loop exactly (no tolerance).
        prop::check("engine-vs-retired", 40, |g| {
            let m = g.int(1, 300);
            let d = g.int(1, 24); // exercises every monomorphized path + dyn
            let k = g.int(1, 20);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);

            let c_ref = counter();
            let reference = retired_reference(&reps, d, &cents, &c_ref);
            let c_eng = counter();
            let engine = SerialAssigner.assign_top2(&reps, d, &cents, &c_eng);

            assert_eq!(engine.assign, reference.assign);
            assert_eq!(engine.d1, reference.d1);
            assert_eq!(engine.d2, reference.d2);
            assert_eq!(c_eng.get(), c_ref.get());
            assert_eq!(c_eng.get(), (m * k) as u64);
        });
    }

    #[test]
    fn prop_vector_f64_pinned_bit_identical_to_serial() {
        // §2.10 pinned contract: in f64, every kernel kind — scalar, the
        // explicit-lane variant, and auto — is bit-identical to the
        // canonical engine and bills exactly m·k, for every dimension
        // class (sub-lane, lane-multiple, tail).
        prop::check("vector-f64-pinned", 30, |g| {
            let m = g.int(1, 250);
            let d = g.int(1, 24);
            let k = g.int(1, 16);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);

            let c0 = counter();
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c0);
            for kernel in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Auto] {
                let c = counter();
                let out = VectorAssigner::new(kernel, Precision::F64)
                    .assign_top2(&reps, d, &cents, &c);
                assert_eq!(out, serial, "kernel={} diverged", kernel.name());
                assert_eq!(c.get(), (m * k) as u64, "kernel={} bill", kernel.name());
            }
        });
    }

    #[test]
    fn prop_vector_f32_kernels_bit_identical_within_precision() {
        // §2.10: scalar-f32 and lane-f32 share one operation order, so
        // within the f32 precision the kernel choice is unobservable —
        // and the bill stays exactly m·k.
        prop::check("vector-f32-within", 30, |g| {
            let m = g.int(1, 250);
            let d = g.int(1, 24);
            let k = g.int(1, 16);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);

            let c_s = counter();
            let scalar = VectorAssigner::new(KernelKind::Scalar, Precision::F32)
                .assign_top2(&reps, d, &cents, &c_s);
            for kernel in [KernelKind::Simd, KernelKind::Auto] {
                let c = counter();
                let out = VectorAssigner::new(kernel, Precision::F32)
                    .assign_top2(&reps, d, &cents, &c);
                assert_eq!(out, scalar, "f32 kernel={} diverged", kernel.name());
                assert_eq!(c.get(), (m * k) as u64);
            }
            assert_eq!(c_s.get(), (m * k) as u64);
        });
    }

    #[test]
    fn f32_kernel_widening_products_are_exact() {
        // The mixed-precision design hinges on 24-bit×24-bit products
        // being exact in f64: on values already representable in f32 the
        // f32 kernel must equal the f64 kernel *exactly* whenever every
        // difference is also f32-exact (here: small integers).
        let p64 = [3.0, -7.0, 11.0, 0.5, -2.25, 9.0, 1.0, -4.0, 6.0];
        let q64 = [1.0, 2.0, -3.0, 0.25, 0.75, -8.0, 2.0, 0.0, -5.0];
        let p32: Vec<f32> = p64.iter().map(|&v| v as f32).collect();
        let q32: Vec<f32> = q64.iter().map(|&v| v as f32).collect();
        for d in 1..=p64.len() {
            assert_eq!(
                sq_dist_kernel_f32(&p32[..d], &q32[..d]),
                sq_dist_kernel(&p64[..d], &q64[..d]),
                "d={d}"
            );
        }
    }

    #[test]
    fn kernel_kind_auto_resolution_is_deterministic() {
        assert_eq!(KernelKind::Auto.resolve(F64_LANES - 1), KernelKind::Scalar);
        assert_eq!(KernelKind::Auto.resolve(F64_LANES), KernelKind::Simd);
        assert_eq!(KernelKind::Auto.resolve(64), KernelKind::Simd);
        // Explicit kinds resolve to themselves regardless of d.
        for d in [1, 4, 64] {
            assert_eq!(KernelKind::Scalar.resolve(d), KernelKind::Scalar);
            assert_eq!(KernelKind::Simd.resolve(d), KernelKind::Simd);
        }
    }

    #[test]
    fn precision_and_kernel_parse_round_trip() {
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        for k in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Auto] {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(Precision::parse("F32"), Some(Precision::F32), "case-insensitive");
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(KernelKind::parse("avx"), None);
    }

    #[test]
    fn sharded_vector_assigner_matches_serial_vector() {
        // The §2.5 combinator holds for the vectorized backend too: shard
        // order == row order, per-worker f32 mirrors notwithstanding.
        let mut rng = crate::util::Rng::new(11);
        let (m, d, k) = (157, 7, 9);
        let reps: Vec<f64> = (0..m * d).map(|_| rng.normal() * 2.0).collect();
        let cents: Vec<f64> = (0..k * d).map(|_| rng.normal() * 2.0).collect();
        for precision in [Precision::F64, Precision::F32] {
            let c1 = counter();
            let serial = VectorAssigner::new(KernelKind::Auto, precision)
                .assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            let sharded = Sharded::with_backend(4, VectorAssigner::new(KernelKind::Auto, precision))
                .assign_top2(&reps, d, &cents, &c2);
            assert_eq!(sharded, serial, "precision={}", precision.name());
            assert_eq!(c1.get(), (m * k) as u64);
            assert_eq!(c2.get(), (m * k) as u64);
        }
    }

    #[test]
    fn prop_all_backends_bit_identical() {
        prop::check("backend-equivalence", 30, |g| {
            let m = g.int(1, 250);
            let d = g.int(1, 8);
            let k = g.int(1, 12);
            let threads = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let cents = g.cloud(k, d, 2.0);

            let c1 = counter();
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            let sharded = ShardedAssigner::new(threads).assign_top2(&reps, d, &cents, &c2);
            let c3 = counter();
            let pruned = NormPrunedAssigner::new().assign_top2(&reps, d, &cents, &c3);

            // Sharded: identical output AND identical count.
            assert_eq!(serial, sharded);
            assert_eq!(c1.get(), c2.get());
            // Pruned: identical output, count never exceeds the exact
            // backends' n·k plus its documented norm overhead.
            assert_eq!(serial, pruned);
            assert!(c3.get() <= c1.get() + (k + m) as u64, "{} vs {}", c3.get(), c1.get());
        });
    }

    #[test]
    fn prop_weighted_step_backend_independent() {
        prop::check("step-backend-equivalence", 20, |g| {
            let m = g.int(1, 150);
            let d = g.int(1, 5);
            let k = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
            let cents = g.cloud(k, d, 2.0);
            let threads = g.int(1, 5);

            let c1 = counter();
            let a = weighted_step(&mut SerialAssigner, &reps, &weights, d, &cents, &c1);
            let c2 = counter();
            let b = weighted_step(
                &mut ShardedAssigner::new(threads),
                &reps,
                &weights,
                d,
                &cents,
                &c2,
            );
            // Serial accumulation makes even werr and the updated
            // centroids bit-identical, not merely close.
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.d1, b.d1);
            assert_eq!(a.d2, b.d2);
            assert_eq!(a.werr.to_bits(), b.werr.to_bits());
            assert_eq!(a.centroids, b.centroids);
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn prop_matches_reference_nearest2_tolerance() {
        // Against the plain-summation *reference* kernel the contract is
        // exact indices/counts and 1e-12 on values (DESIGN.md §2.1).
        prop::check("engine-vs-nearest2", 25, |g| {
            let m = g.int(1, 120);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);
            let c1 = counter();
            let out = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            for i in 0..m {
                let (ii, dd1, dd2) =
                    crate::metrics::nearest2(&reps[i * d..(i + 1) * d], &cents, d, &c2);
                assert_eq!(out.assign[i], ii as u32);
                assert!((out.d1[i] - dd1).abs() < 1e-12);
                if dd2.is_finite() {
                    assert!((out.d2[i] - dd2).abs() < 1e-12);
                }
            }
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn tie_break_lowest_index_wins() {
        // Two coincident centroids: strict `<` keeps the first.
        let cents = [1.0, 0.0, 1.0, 0.0, 5.0, 0.0];
        let out = SerialAssigner.assign_top2(&[0.0, 0.0], 2, &cents, &counter());
        assert_eq!(out.assign, vec![0]);
        assert_eq!(out.d1, vec![1.0]);
        assert_eq!(out.d2, vec![1.0]); // the duplicate is the runner-up
    }

    #[test]
    fn single_centroid_d2_infinite() {
        let out = SerialAssigner.assign_top2(&[3.0], 1, &[1.0], &counter());
        assert_eq!(out.assign, vec![0]);
        assert_eq!(out.d1, vec![4.0]);
        assert!(out.d2[0].is_infinite());
    }

    #[test]
    fn empty_input_counts_nothing() {
        let c = counter();
        let out = SerialAssigner.assign_top2(&[], 3, &[0.0, 0.0, 0.0], &c);
        assert!(out.assign.is_empty());
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn block_boundaries_are_seamless() {
        // m straddling POINT_BLOCK and k straddling CENT_TILE: the tiled
        // state handoff must not disturb results at the seams.
        let mut g = prop::Gen { rng: crate::util::Rng::new(7), case: 0 };
        for &(m, k) in &[
            (POINT_BLOCK - 1, CENT_TILE),
            (POINT_BLOCK, CENT_TILE + 1),
            (POINT_BLOCK + 1, 2 * CENT_TILE + 3),
            (3 * POINT_BLOCK + 5, 1),
        ] {
            let d = 3;
            let reps = g.cloud(m, d, 2.0);
            let cents = g.cloud(k, d, 2.0);
            let c1 = counter();
            let eng = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            let reference = retired_reference(&reps, d, &cents, &c2);
            assert_eq!(eng, reference, "m={m} k={k}");
            assert_eq!(c1.get(), (m * k) as u64);
        }
    }

    #[test]
    fn shard_ranges_cover_and_order() {
        for n in [0usize, 1, 7, 10, 64, 65] {
            for shards in 1..=12 {
                let ranges = shard_ranges(n, shards);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut prev = 0;
                for r in &ranges {
                    assert_eq!(r.start, prev);
                    prev = r.end;
                }
                // Near-equal: lengths differ by at most one.
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(hi - lo <= 1);
                // The closed form is the same split, entry for entry.
                assert_eq!(ranges.len(), shard_count(n, shards));
                for (s, r) in ranges.iter().enumerate() {
                    assert_eq!(shard_range(n, shards, s), *r, "n={n} shards={shards} s={s}");
                }
            }
        }
    }

    #[test]
    fn sq_dist_row_fills_all_k() {
        let c = counter();
        let cents = [0.0, 0.0, 3.0, 0.0, 0.0, 4.0];
        let mut row = vec![0.0; 3];
        let (i1, b1) = sq_dist_row(&[0.0, 0.0], &cents, 2, &mut row, &c);
        assert_eq!(i1, 0);
        assert_eq!(b1, 0.0);
        assert_eq!(row, vec![0.0, 9.0, 16.0]);
        assert_eq!(c.get(), 3);
    }

    #[test]
    fn norm_pruned_actually_prunes_separated_clusters() {
        // Radially spread centroids: the norm bound removes most
        // candidates once the top-2 tightens.
        let mut g = prop::Gen { rng: crate::util::Rng::new(21), case: 0 };
        let d = 3;
        let k = 32;
        let m = 2000;
        // Centroids at widely different radii.
        let mut cents = Vec::with_capacity(k * d);
        for c in 0..k {
            let r = 1.0 + 10.0 * c as f64;
            cents.extend_from_slice(&[r, 0.0, 0.0]);
        }
        let reps: Vec<f64> = (0..m)
            .flat_map(|_| {
                let c = g.rng.usize(k);
                let r = 1.0 + 10.0 * c as f64;
                vec![r + g.rng.normal() * 0.1, g.rng.normal() * 0.1, g.rng.normal() * 0.1]
            })
            .collect();
        let c_exact = counter();
        let exact = SerialAssigner.assign_top2(&reps, d, &cents, &c_exact);
        let c_pruned = counter();
        let pruned = NormPrunedAssigner::new().assign_top2(&reps, d, &cents, &c_pruned);
        assert_eq!(exact, pruned);
        assert!(
            c_pruned.get() < c_exact.get() / 2,
            "pruned {} vs exact {}",
            c_pruned.get(),
            c_exact.get()
        );
    }

    #[test]
    fn prop_bounded_bit_identical_across_drifting_steps() {
        // The tentpole property in miniature: one BoundedAssigner driven
        // through a sequence of centroid updates on fixed points matches
        // the serial backend bit for bit at every step, at a shrinking
        // count. (The full fuzz lives in tests/engine_conformance.rs.)
        prop::check("bounded-warm", 15, |g| {
            let m = g.int(1, 200);
            let d = g.int(1, 8);
            let k = g.int(1, 10);
            let reps = g.cloud(m, d, 2.0);
            let mut cents = g.cloud(k, d, 2.0);
            let mut bounded = BoundedAssigner::new();
            for step in 0..6 {
                let c1 = counter();
                let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
                let c2 = counter();
                let out = bounded.assign_top2(&reps, d, &cents, &c2);
                assert_eq!(serial, out, "step {step}");
                let stats = bounded.last_stats();
                assert_eq!(
                    c2.get(),
                    stats.pairs + stats.bookkeeping,
                    "counter must equal the backend's own account"
                );
                assert_eq!(stats.warm, step > 0);
                assert!(stats.pairs <= (m * k) as u64);
                // Drift the centroids a little, as a Lloyd update would.
                for v in cents.iter_mut() {
                    *v += g.rng.normal() * 0.05;
                }
            }
        });
    }

    #[test]
    fn bounded_reprimes_when_points_change() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(9), case: 0 };
        let d = 3;
        let reps_a = g.cloud(40, d, 2.0);
        let reps_b = g.cloud(40, d, 2.0);
        let cents = g.cloud(5, d, 2.0);
        let mut bounded = BoundedAssigner::new();
        let c = counter();
        let _ = bounded.assign_top2(&reps_a, d, &cents, &c);
        assert!(!bounded.last_stats().warm);
        let _ = bounded.assign_top2(&reps_a, d, &cents, &c);
        assert!(bounded.last_stats().warm);
        let out = bounded.assign_top2(&reps_b, d, &cents, &c);
        assert!(!bounded.last_stats().warm, "new points must re-prime the bounds");
        assert_eq!(out, SerialAssigner.assign_top2(&reps_b, d, &cents, &counter()));
    }

    #[test]
    fn prop_sharded_combinator_generic_over_backends() {
        // Sharded<NormPruned> and Sharded<Bounded> exist for free and stay
        // bit-identical to serial, warm steps included.
        prop::check("sharded-combinator", 10, |g| {
            let m = g.int(1, 150);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let threads = g.int(1, 5);
            let reps = g.cloud(m, d, 2.0);
            let mut cents = g.cloud(k, d, 2.0);
            let mut sp: Sharded<NormPrunedAssigner> = Sharded::new(threads);
            let mut sb: Sharded<BoundedAssigner> = Sharded::new(threads);
            for _ in 0..3 {
                let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
                assert_eq!(serial, sp.assign_top2(&reps, d, &cents, &counter()));
                assert_eq!(serial, sb.assign_top2(&reps, d, &cents, &counter()));
                for v in cents.iter_mut() {
                    *v += g.rng.normal() * 0.1;
                }
            }
        });
    }

    #[test]
    fn auto_is_bit_identical_and_logs_choices() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(13), case: 0 };
        let d = 3;
        let m = 300;
        let k = 6;
        let reps = g.cloud(m, d, 2.0);
        let mut cents = g.cloud(k, d, 2.0);
        let mut auto = AutoAssigner::new();
        let c = counter();
        for _ in 0..5 {
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
            assert_eq!(serial, auto.assign_top2(&reps, d, &cents, &c));
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.02;
            }
        }
        let notes = c.notes();
        assert_eq!(notes.len(), 5, "one choice note per call: {notes:?}");
        assert!(notes[0].contains("bounded"), "large k/m cold call invests in bounds");
        // Tiny problem: auto must not pay bound overheads.
        let tiny = g.cloud(8, d, 1.0);
        let c2 = counter();
        let _ = auto.assign_top2(&tiny, d, &cents, &c2);
        assert!(c2.notes()[0].contains("serial"), "{:?}", c2.notes());
    }

    #[test]
    fn closure_cold_and_total_calls_are_exact_fallbacks() {
        // Cold anchors and total closures (expand ≥ k−1) both route
        // through the serial engine: bit-identical output, the exact
        // `m·k` bill, and a tallied fallback (DESIGN.md §2.9).
        let mut g = prop::Gen { rng: crate::util::Rng::new(31), case: 0 };
        let (m, d, k) = (120, 3, 4);
        let reps = g.cloud(m, d, 2.0);
        let cents = g.cloud(k, d, 2.0);
        let mut cl = ClosureAssigner::new(9); // 1+9 ≥ k ⇒ total closure
        let c = counter();
        for step in 0..3u64 {
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
            let before = c.get();
            let out = cl.assign_top2(&reps, d, &cents, &c);
            assert_eq!(serial, out, "step {step}");
            let stats = cl.last_stats();
            assert!(!stats.warm);
            assert_eq!(c.get() - before, stats.pairs + stats.bookkeeping);
            assert_eq!(stats.pairs, (m * k) as u64);
            assert_eq!(stats.fallbacks, step + 1);
            assert_eq!(stats.hit_rate(), 1.0);
        }
    }

    #[test]
    fn closure_warm_call_pays_exactly_its_own_account() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(32), case: 0 };
        let (m, d, k) = (200, 3, 8);
        let reps = g.cloud(m, d, 2.0);
        let mut cents = g.cloud(k, d, 2.0);
        let mut cl = ClosureAssigner::new(2);
        let c = counter();
        let _ = cl.assign_top2(&reps, d, &cents, &c); // cold prime
        assert!(!cl.last_stats().warm);
        for v in cents.iter_mut() {
            *v += g.rng.normal() * 0.05;
        }
        let before = c.get();
        let out = cl.assign_top2(&reps, d, &cents, &c);
        let stats = cl.last_stats();
        assert!(stats.warm);
        // The §2.9 bill pin: counter delta == pairs + bookkeeping, with
        // pairs = m·(1+expand) and bookkeeping = k·(k−1)/2, strictly
        // under the exact m·k bill.
        assert_eq!(c.get() - before, stats.pairs + stats.bookkeeping);
        assert_eq!(stats.pairs, (m * 3) as u64);
        assert_eq!(stats.bookkeeping, (k * (k - 1) / 2) as u64);
        assert_eq!(stats.bill, (m * k) as u64);
        assert!(stats.pairs + stats.bookkeeping < stats.bill);
        // expand ≥ 1 guarantees a genuine runner-up on warm calls.
        assert!(out.d2.iter().all(|v| v.is_finite()));
        // The gap self-report is available, ordered, and uncounted.
        let after = c.get();
        let gap = cl
            .quality_gap(&reps, None, d, &cents)
            .expect("approximate backends always report a gap");
        assert_eq!(gap.backend, "closure");
        assert!(gap.approx_err >= gap.exact_err);
        assert!(gap.rel_gap() >= 0.0);
        assert_eq!(c.get(), after, "gap measurement is uncounted instrumentation");
    }

    #[test]
    fn closure_expand_is_clamped_to_one() {
        assert_eq!(ClosureAssigner::new(0).expand(), 1);
    }

    #[test]
    fn auto_with_closure_selects_logs_and_reports() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(33), case: 0 };
        let (m, d, k) = (300, 3, 6);
        let reps = g.cloud(m, d, 2.0);
        let mut cents = g.cloud(k, d, 2.0);
        let mut auto = AutoAssigner::with_closure(2);
        let c = counter();
        for _ in 0..4 {
            let _ = auto.assign_top2(&reps, d, &cents, &c);
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.02;
            }
        }
        let counts = auto.choice_counts();
        assert_eq!(counts.total(), 4);
        assert!(counts.get(AutoChoice::Closure) >= 1, "{}", counts.summary());
        let notes = c.notes();
        assert!(notes[0].starts_with("auto[1]: closure ("), "{:?}", notes);
        assert!(notes[0].contains("hit="), "{:?}", notes);
        assert!(
            auto.quality_gap(&reps, None, d, &cents).is_some(),
            "approximate auto must self-report a gap"
        );
        // The exact engine never selects (or reports) the closure.
        let mut exact = AutoAssigner::new();
        let c2 = counter();
        let _ = exact.assign_top2(&reps, d, &cents, &c2);
        assert_eq!(exact.choice_counts().get(AutoChoice::Closure), 0);
        assert!(exact.quality_gap(&reps, None, d, &cents).is_none());
    }
}
