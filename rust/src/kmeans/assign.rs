//! The **assignment engine** — the one distance hot path every method in
//! this crate shares (DESIGN.md §2).
//!
//! The assignment step — "for each point, find the nearest (and second
//! nearest) centroid" — is the cost center of every K-means-family
//! algorithm the paper evaluates (§1.2, §3): plain Lloyd, weighted Lloyd
//! under BWKM/RPKM, Mini-batch, and the exact accelerated variants. BWKM
//! additionally consumes the distance to the *second* nearest centroid,
//! because the misassignment function (paper Eq. 3)
//!
//! ```text
//! ε_{C,D}(B) = max(0, 2·l_B − (‖P̄−c₂‖ − ‖P̄−c₁‖))
//! ```
//!
//! needs δ_P(C) = ‖P̄−c₂‖ − ‖P̄−c₁‖ for every representative. This module
//! therefore computes nearest/top-2 once, behind one [`Assigner`] trait,
//! and every consumer (`lloyd`, `weighted_lloyd::NativeStepper`,
//! `minibatch`, `elkan`'s exact fallback pass,
//! `coordinator::parallel::sharded_assign_err`, and `bwkm`'s ε machinery)
//! rides on it instead of keeping a private distance loop.
//!
//! Contract highlights (normative text in DESIGN.md §2):
//!
//! * **Canonical kernel.** One squared-distance summation order —
//!   [`sq_dist_kernel`], the 4-way split-accumulator form — is used by
//!   every backend, so all backends produce **bit-identical**
//!   `(assign, d1, d2)` for the same inputs. (`geometry::sq_dist` is the
//!   plain left-to-right *reference* form; the two agree to ~1 ulp per
//!   term and the property tests pin the engine against it at 1e-12.)
//! * **Tie-breaking.** Strict `<` against the incumbent: the
//!   lowest-indexed centroid wins equal distances, and `d2` is the second
//!   *value* in scan order (`d2 = ∞` when k = 1).
//! * **Counting.** Exact backends tick the shared [`DistanceCounter`]
//!   with one unit per point-centroid pair — n·k per call, accounted
//!   per cache block. Pruned backends count only what they compute
//!   (plus the norm precomputations), and may therefore count *less*
//!   while returning bit-identical output.
//! * **Shard determinism.** [`ShardedAssigner`] splits rows with
//!   [`shard_ranges`] (the same contiguous base/extra split as
//!   `Dataset::shard_ranges`) and reduces in shard order, so its output
//!   equals the serial backend's bit for bit, for every thread count.
//!
//! The kernel itself is blocked and cache-tiled: points are processed in
//! [`POINT_BLOCK`]-row blocks and centroids in [`CENT_TILE`]-row tiles, so
//! a tile of centroids is reused from L1 across the whole point block
//! while the top-2 state lives in registers / stack arrays. Dimensions the
//! Table-1 workloads use (§Perf iteration 1: 1.3–2.1x) get monomorphized
//! fast paths with a compile-time `D`.

use crate::metrics::DistanceCounter;

use super::weighted_lloyd::StepOut;

/// Rows per cache block of the tiled kernel (top-2 state for a block lives
/// in stack arrays; 64 rows × 3 lanes × 8 B ≈ 1.5 KiB).
pub const POINT_BLOCK: usize = 64;

/// Centroids per tile of the tiled kernel (a tile of k ≤ 8, d ≤ 20
/// centroids is ≤ 1.25 KiB — resident in L1 across the point block).
pub const CENT_TILE: usize = 8;

/// Result of a top-2 assignment pass: for every input row, the index of
/// the nearest centroid and the two smallest squared distances
/// (`d2[i] = ∞` when only one centroid exists).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AssignOut {
    pub assign: Vec<u32>,
    pub d1: Vec<f64>,
    pub d2: Vec<f64>,
}

impl AssignOut {
    fn with_capacity(m: usize) -> AssignOut {
        AssignOut {
            assign: Vec::with_capacity(m),
            d1: Vec::with_capacity(m),
            d2: Vec::with_capacity(m),
        }
    }
}

/// A nearest/top-2 assignment backend (DESIGN.md §2.2). Implementations
/// must obey the canonical-kernel, tie-breaking, counting and determinism
/// rules spelled out there, so callers may swap backends freely.
pub trait Assigner {
    /// Assign every row of `points` (m×d flat) to its nearest centroid,
    /// returning the top-2 squared distances alongside.
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut;
}

/// The canonical squared-distance kernel (DESIGN.md §2.1): 4-way split
/// accumulators so the FPU add latency chain is broken (the compiler may
/// not reassociate FP adds itself — §Perf iteration 2), combined as
/// `(a0 + a1) + (a2 + a3)`. Every engine backend computes *exactly* this
/// value for every pair it evaluates.
#[inline]
pub fn sq_dist_kernel(p: &[f64], q: &[f64]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    let d = p.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut j = 0;
    while j + 4 <= d {
        let t0 = p[j] - q[j];
        let t1 = p[j + 1] - q[j + 1];
        let t2 = p[j + 2] - q[j + 2];
        let t3 = p[j + 3] - q[j + 3];
        a0 += t0 * t0;
        a1 += t1 * t1;
        a2 += t2 * t2;
        a3 += t3 * t3;
        j += 4;
    }
    while j < d {
        let t = p[j] - q[j];
        a0 += t * t;
        j += 1;
    }
    (a0 + a1) + (a2 + a3)
}

/// Canonical *metric* distance: `sqrt` of [`sq_dist_kernel`]. `sqrt` is
/// exact and monotone, so argmins and tie-breaks match the squared form.
/// Consumers that work in metric space (Elkan's bounds) must use this for
/// every point↔centroid distance, so their cached bounds stay consistent
/// with the distances they are later compared against (DESIGN.md §2.6).
#[inline]
pub fn dist_kernel(p: &[f64], q: &[f64]) -> f64 {
    sq_dist_kernel(p, q).sqrt()
}

/// Split `0..n` into at most `shards` contiguous ranges of near-equal
/// length (the first `n % shards` ranges get one extra row). This is the
/// *only* shard-range rule in the crate — `Dataset::shard_ranges` and both
/// sharded coordinator paths route through it (DESIGN.md §2.5), so a
/// leader and its workers can never disagree about row ownership.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    let shards = shards.max(1).min(n.max(1));
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ---------------------------------------------------------------------------
// The blocked, cache-tiled kernel.
// ---------------------------------------------------------------------------

/// Monomorphized blocked top-2 scan: `D` is a compile-time constant so the
/// inner loop fully unrolls, and each row is hoisted into a fixed-size
/// array that lives in registers across a centroid tile (§Perf
/// iteration 3). Centroids are visited in increasing index order across
/// tiles, so the result is bit-identical to a straight scan.
fn top2_blocked<const D: usize>(
    points: &[f64],
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / D;
    debug_assert_eq!(points.len(), m * D);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p: &[f64; D] = points[i * D..i * D + D].try_into().unwrap();
                for c in tile..tile + tlen {
                    let q: &[f64; D] = centroids[c * D..c * D + D].try_into().unwrap();
                    // Inlined canonical kernel (see `sq_dist_kernel`) on
                    // register-resident rows.
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                    let mut j = 0;
                    while j + 4 <= D {
                        let t0 = p[j] - q[j];
                        let t1 = p[j + 1] - q[j + 1];
                        let t2 = p[j + 2] - q[j + 2];
                        let t3 = p[j + 3] - q[j + 3];
                        a0 += t0 * t0;
                        a1 += t1 * t1;
                        a2 += t2 * t2;
                        a3 += t3 * t3;
                        j += 4;
                    }
                    while j < D {
                        let t = p[j] - q[j];
                        a0 += t * t;
                        j += 1;
                    }
                    let acc = (a0 + a1) + (a2 + a3);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        // Per-block accounting: one unit per point-centroid pair.
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Dynamic-dimension fallback of [`top2_blocked`] (identical structure and
/// summation order; rows stay slices).
fn top2_blocked_dyn(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    let m = assign.len();
    let k = centroids.len() / d;
    debug_assert_eq!(points.len(), m * d);
    let mut base = 0usize;
    while base < m {
        let len = (m - base).min(POINT_BLOCK);
        let mut bi = [0u32; POINT_BLOCK];
        let mut b1 = [f64::INFINITY; POINT_BLOCK];
        let mut b2 = [f64::INFINITY; POINT_BLOCK];
        let mut tile = 0usize;
        while tile < k {
            let tlen = (k - tile).min(CENT_TILE);
            for r in 0..len {
                let i = base + r;
                let p = &points[i * d..i * d + d];
                for c in tile..tile + tlen {
                    let acc = sq_dist_kernel(p, &centroids[c * d..c * d + d]);
                    if acc < b1[r] {
                        b2[r] = b1[r];
                        b1[r] = acc;
                        bi[r] = c as u32;
                    } else if acc < b2[r] {
                        b2[r] = acc;
                    }
                }
            }
            tile += tlen;
        }
        for r in 0..len {
            assign[base + r] = bi[r];
            d1[base + r] = b1[r];
            d2[base + r] = b2[r];
        }
        counter.add((len * k) as u64);
        base += len;
    }
}

/// Dispatch to a monomorphized body for the dimensions the Table-1
/// workloads actually use (constant trip counts let LLVM fully unroll and
/// vectorize the inner loop — §Perf iteration 1: 1.3–2.1x on the d=19/d=5
/// sweeps).
fn top2_dispatch(
    points: &[f64],
    d: usize,
    centroids: &[f64],
    assign: &mut [u32],
    d1: &mut [f64],
    d2: &mut [f64],
    counter: &DistanceCounter,
) {
    match d {
        2 => top2_blocked::<2>(points, centroids, assign, d1, d2, counter),
        3 => top2_blocked::<3>(points, centroids, assign, d1, d2, counter),
        4 => top2_blocked::<4>(points, centroids, assign, d1, d2, counter),
        5 => top2_blocked::<5>(points, centroids, assign, d1, d2, counter),
        17 => top2_blocked::<17>(points, centroids, assign, d1, d2, counter),
        19 => top2_blocked::<19>(points, centroids, assign, d1, d2, counter),
        20 => top2_blocked::<20>(points, centroids, assign, d1, d2, counter),
        _ => top2_blocked_dyn(points, d, centroids, assign, d1, d2, counter),
    }
}

// ---------------------------------------------------------------------------
// Backends.
// ---------------------------------------------------------------------------

/// The serial backend: the blocked, cache-tiled canonical kernel on the
/// calling thread. This is the default engine behind
/// [`super::NativeStepper`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialAssigner;

impl Assigner for SerialAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = points.len() / d;
        let mut out = AssignOut {
            assign: vec![0u32; m],
            d1: vec![0.0; m],
            d2: vec![0.0; m],
        };
        top2_dispatch(points, d, centroids, &mut out.assign, &mut out.d1, &mut out.d2, counter);
        out
    }
}

/// The sharded backend: rows fanned out over `threads` scoped workers via
/// [`shard_ranges`], each running the serial kernel on its contiguous
/// shard, reduced in shard order. Bit-identical to [`SerialAssigner`] for
/// every thread count (DESIGN.md §2.5).
#[derive(Clone, Copy, Debug)]
pub struct ShardedAssigner {
    pub threads: usize,
}

impl Assigner for ShardedAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = points.len() / d;
        let ranges = shard_ranges(m, self.threads);
        let mut partials: Vec<AssignOut> = Vec::with_capacity(ranges.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|r| {
                    let r = r.clone();
                    scope.spawn(move || {
                        let len = r.len();
                        let mut part = AssignOut {
                            assign: vec![0u32; len],
                            d1: vec![0.0; len],
                            d2: vec![0.0; len],
                        };
                        top2_dispatch(
                            &points[r.start * d..r.end * d],
                            d,
                            centroids,
                            &mut part.assign,
                            &mut part.d1,
                            &mut part.d2,
                            counter,
                        );
                        part
                    })
                })
                .collect();
            for h in handles {
                partials.push(h.join().expect("assignment worker panicked"));
            }
        });
        // Ordered reduction: shard order == row order.
        let mut out = AssignOut::with_capacity(m);
        for p in partials {
            out.assign.extend(p.assign);
            out.d1.extend(p.d1);
            out.d2.extend(p.d2);
        }
        out
    }
}

/// The norm-pruned backend: precomputes every centroid norm ‖c‖ and skips
/// candidates that provably cannot enter the top-2, via the reverse
/// triangle inequality ‖x−c‖ ≥ |‖x‖−‖c‖|. The skip test carries a
/// scale-aware safety margin covering the rounding of the norm
/// subtraction, so outputs stay **bit-identical** to [`SerialAssigner`];
/// only the distance *count* shrinks (DESIGN.md §2.4: pruned backends
/// count k centroid norms + 1 point norm per row + one unit per pair
/// actually evaluated).
#[derive(Clone, Copy, Debug, Default)]
pub struct NormPrunedAssigner;

impl Assigner for NormPrunedAssigner {
    fn assign_top2(
        &mut self,
        points: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = points.len() / d;
        let k = centroids.len() / d;
        let mut out = AssignOut {
            assign: vec![0u32; m],
            d1: vec![0.0; m],
            d2: vec![0.0; m],
        };
        // Centroid norms, counted as k distance computations.
        let mut cn = vec![0.0f64; k];
        for c in 0..k {
            cn[c] = norm_kernel(&centroids[c * d..(c + 1) * d]);
        }
        counter.add(k as u64);

        let mut evaluated = 0u64;
        for i in 0..m {
            let p = &points[i * d..(i + 1) * d];
            let pn = norm_kernel(p);
            evaluated += 1; // the point norm
            let (mut i1, mut b1, mut b2) = (0u32, f64::INFINITY, f64::INFINITY);
            // sqrt of the running second-best, maintained lazily so the
            // skip test runs in metric space.
            let mut b2_rt = f64::INFINITY;
            for c in 0..k {
                let lb = (pn - cn[c]).abs();
                // Sound skip: true ‖x−c‖ ≥ lb up to rounding of the two
                // norms. The rounding of a d-term norm is ≤ ~(d/4+2)·ε
                // relative, so the margin scales with d and stays ≥ ~100×
                // the worst case at every dimension — a skipped candidate
                // can never have entered the top-2 (asserted bit-for-bit
                // by the property tests).
                let margin = (4.0 + d as f64) * 1e-14 * (pn + cn[c]);
                if lb > b2_rt + margin {
                    continue;
                }
                let acc = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
                evaluated += 1;
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c as u32;
                    b2_rt = b2.sqrt();
                } else if acc < b2 {
                    b2 = acc;
                    b2_rt = b2.sqrt();
                }
            }
            out.assign[i] = i1;
            out.d1[i] = b1;
            out.d2[i] = b2;
        }
        counter.add(evaluated);
        out
    }
}

/// Euclidean norm through the canonical summation order (identical to
/// `sq_dist_kernel(p, 0)` — subtracting zero is exact — so norms round the
/// same way distances do).
fn norm_kernel(p: &[f64]) -> f64 {
    let d = p.len();
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let mut j = 0;
    while j + 4 <= d {
        a0 += p[j] * p[j];
        a1 += p[j + 1] * p[j + 1];
        a2 += p[j + 2] * p[j + 2];
        a3 += p[j + 3] * p[j + 3];
        j += 4;
    }
    while j < d {
        a0 += p[j] * p[j];
        j += 1;
    }
    ((a0 + a1) + (a2 + a3)).sqrt()
}

// ---------------------------------------------------------------------------
// Shared consumers: the three shapes every retired loop reduces to.
// ---------------------------------------------------------------------------

/// Reusable accumulation scratch for [`weighted_step_with`], so steppers
/// that iterate (the weighted-Lloyd outer loops) keep the retired
/// `NativeStepper`'s "no per-iteration allocation in the hot loop"
/// property for the cluster aggregates.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    sums: Vec<f64>,
    counts: Vec<f64>,
}

/// One weighted-Lloyd iteration on any [`Assigner`] backend (paper Alg. 1
/// steps 2/4): engine assignment, then a serial weighted accumulation in
/// row order and the center-of-mass update (empty clusters keep their
/// centroid). Because the accumulation is always serial and in row order,
/// `werr`, `sums` and the updated centroids are bit-identical across
/// backends (DESIGN.md §2.5). One-shot convenience over
/// [`weighted_step_with`]; iterating callers hold a [`StepScratch`].
pub fn weighted_step(
    engine: &mut dyn Assigner,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> StepOut {
    weighted_step_with(engine, &mut StepScratch::default(), reps, weights, d, centroids, counter)
}

/// [`weighted_step`] with caller-owned accumulation scratch (the returned
/// assign/d1/d2 buffers are part of [`StepOut`] and necessarily fresh).
pub fn weighted_step_with(
    engine: &mut dyn Assigner,
    scratch: &mut StepScratch,
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> StepOut {
    let m = weights.len();
    let k = centroids.len() / d;
    let top2 = engine.assign_top2(reps, d, centroids, counter);

    scratch.sums.clear();
    scratch.sums.resize(k * d, 0.0);
    scratch.counts.clear();
    scratch.counts.resize(k, 0.0);
    let mut werr = 0.0f64;
    for i in 0..m {
        let w = weights[i];
        werr += w * top2.d1[i];
        let c = top2.assign[i] as usize;
        let p = &reps[i * d..(i + 1) * d];
        let s = &mut scratch.sums[c * d..(c + 1) * d];
        for j in 0..d {
            s[j] += w * p[j];
        }
        scratch.counts[c] += w;
    }

    let mut out = centroids.to_vec();
    for c in 0..k {
        if scratch.counts[c] > 0.0 {
            let inv = 1.0 / scratch.counts[c];
            for j in 0..d {
                out[c * d + j] = scratch.sums[c * d + j] * inv;
            }
        }
    }
    StepOut { centroids: out, assign: top2.assign, d1: top2.d1, d2: top2.d2, werr }
}

/// Assignment + SSE on any [`Assigner`] backend — the E^D / E^P evaluator
/// shape (`coordinator::sharded_assign_err` is a thin wrapper). The SSE is
/// accumulated serially in row order, so it is backend-independent.
pub fn assign_err(
    engine: &mut dyn Assigner,
    points: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> (Vec<u32>, f64) {
    let top2 = engine.assign_top2(points, d, centroids, counter);
    let sse = top2.d1.iter().sum();
    (top2.assign, sse)
}

/// Exact full-row fallback (DESIGN.md §2.6): all k squared distances of
/// one point through the canonical kernel, written into `row`; returns
/// (argmin, min). Counts k. This is the engine shape behind Elkan's
/// bound-initialization pass, which needs *every* distance, not just the
/// top 2.
pub fn sq_dist_row(
    p: &[f64],
    centroids: &[f64],
    d: usize,
    row: &mut [f64],
    counter: &DistanceCounter,
) -> (usize, f64) {
    let k = centroids.len() / d;
    debug_assert_eq!(row.len(), k);
    let (mut i1, mut b1) = (0usize, f64::INFINITY);
    for c in 0..k {
        let dd = sq_dist_kernel(p, &centroids[c * d..(c + 1) * d]);
        row[c] = dd;
        if dd < b1 {
            b1 = dd;
            i1 = c;
        }
    }
    counter.add(k as u64);
    (i1, b1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Verbatim copy of the retired `NativeStepper` assignment loop (the
    /// pre-engine hot path of `weighted_lloyd.rs`): straight row scan,
    /// 4-way split accumulators, strict-`<` top-2. The engine must match
    /// it bit for bit — same floats, same indices, same counts.
    fn retired_reference(
        reps: &[f64],
        d: usize,
        centroids: &[f64],
        counter: &DistanceCounter,
    ) -> AssignOut {
        let m = reps.len() / d;
        let k = centroids.len() / d;
        let mut out = AssignOut {
            assign: vec![0u32; m],
            d1: vec![0.0; m],
            d2: vec![0.0; m],
        };
        for i in 0..m {
            let p = &reps[i * d..i * d + d];
            let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
            for c in 0..k {
                let q = &centroids[c * d..c * d + d];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
                let mut j = 0;
                while j + 4 <= d {
                    let t0 = p[j] - q[j];
                    let t1 = p[j + 1] - q[j + 1];
                    let t2 = p[j + 2] - q[j + 2];
                    let t3 = p[j + 3] - q[j + 3];
                    a0 += t0 * t0;
                    a1 += t1 * t1;
                    a2 += t2 * t2;
                    a3 += t3 * t3;
                    j += 4;
                }
                while j < d {
                    let t = p[j] - q[j];
                    a0 += t * t;
                    j += 1;
                }
                let acc = (a0 + a1) + (a2 + a3);
                if acc < b1 {
                    b2 = b1;
                    b1 = acc;
                    i1 = c;
                } else if acc < b2 {
                    b2 = acc;
                }
            }
            out.assign[i] = i1 as u32;
            out.d1[i] = b1;
            out.d2[i] = b2;
        }
        counter.add((m * k) as u64);
        out
    }

    fn counter() -> DistanceCounter {
        DistanceCounter::new()
    }

    #[test]
    fn prop_engine_matches_retired_loop_bit_for_bit() {
        // The acceptance property of the port: on random weighted corpora
        // the engine's top-2 output and distance counts equal the retired
        // per-algorithm loop exactly (no tolerance).
        prop::check("engine-vs-retired", 40, |g| {
            let m = g.int(1, 300);
            let d = g.int(1, 24); // exercises every monomorphized path + dyn
            let k = g.int(1, 20);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);

            let c_ref = counter();
            let reference = retired_reference(&reps, d, &cents, &c_ref);
            let c_eng = counter();
            let engine = SerialAssigner.assign_top2(&reps, d, &cents, &c_eng);

            assert_eq!(engine.assign, reference.assign);
            assert_eq!(engine.d1, reference.d1);
            assert_eq!(engine.d2, reference.d2);
            assert_eq!(c_eng.get(), c_ref.get());
            assert_eq!(c_eng.get(), (m * k) as u64);
        });
    }

    #[test]
    fn prop_all_backends_bit_identical() {
        prop::check("backend-equivalence", 30, |g| {
            let m = g.int(1, 250);
            let d = g.int(1, 8);
            let k = g.int(1, 12);
            let threads = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let cents = g.cloud(k, d, 2.0);

            let c1 = counter();
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            let sharded = ShardedAssigner { threads }.assign_top2(&reps, d, &cents, &c2);
            let c3 = counter();
            let pruned = NormPrunedAssigner.assign_top2(&reps, d, &cents, &c3);

            // Sharded: identical output AND identical count.
            assert_eq!(serial, sharded);
            assert_eq!(c1.get(), c2.get());
            // Pruned: identical output, count never exceeds the exact
            // backends' n·k plus its documented norm overhead.
            assert_eq!(serial, pruned);
            assert!(c3.get() <= c1.get() + (k + m) as u64, "{} vs {}", c3.get(), c1.get());
        });
    }

    #[test]
    fn prop_weighted_step_backend_independent() {
        prop::check("step-backend-equivalence", 20, |g| {
            let m = g.int(1, 150);
            let d = g.int(1, 5);
            let k = g.int(1, 6);
            let reps = g.cloud(m, d, 2.0);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
            let cents = g.cloud(k, d, 2.0);
            let threads = g.int(1, 5);

            let c1 = counter();
            let a = weighted_step(&mut SerialAssigner, &reps, &weights, d, &cents, &c1);
            let c2 = counter();
            let b = weighted_step(
                &mut ShardedAssigner { threads },
                &reps,
                &weights,
                d,
                &cents,
                &c2,
            );
            // Serial accumulation makes even werr and the updated
            // centroids bit-identical, not merely close.
            assert_eq!(a.assign, b.assign);
            assert_eq!(a.d1, b.d1);
            assert_eq!(a.d2, b.d2);
            assert_eq!(a.werr.to_bits(), b.werr.to_bits());
            assert_eq!(a.centroids, b.centroids);
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn prop_matches_reference_nearest2_tolerance() {
        // Against the plain-summation *reference* kernel the contract is
        // exact indices/counts and 1e-12 on values (DESIGN.md §2.1).
        prop::check("engine-vs-nearest2", 25, |g| {
            let m = g.int(1, 120);
            let d = g.int(1, 6);
            let k = g.int(1, 8);
            let reps = g.cloud(m, d, 3.0);
            let cents = g.cloud(k, d, 3.0);
            let c1 = counter();
            let out = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            for i in 0..m {
                let (ii, dd1, dd2) =
                    crate::metrics::nearest2(&reps[i * d..(i + 1) * d], &cents, d, &c2);
                assert_eq!(out.assign[i], ii as u32);
                assert!((out.d1[i] - dd1).abs() < 1e-12);
                if dd2.is_finite() {
                    assert!((out.d2[i] - dd2).abs() < 1e-12);
                }
            }
            assert_eq!(c1.get(), c2.get());
        });
    }

    #[test]
    fn tie_break_lowest_index_wins() {
        // Two coincident centroids: strict `<` keeps the first.
        let cents = [1.0, 0.0, 1.0, 0.0, 5.0, 0.0];
        let out = SerialAssigner.assign_top2(&[0.0, 0.0], 2, &cents, &counter());
        assert_eq!(out.assign, vec![0]);
        assert_eq!(out.d1, vec![1.0]);
        assert_eq!(out.d2, vec![1.0]); // the duplicate is the runner-up
    }

    #[test]
    fn single_centroid_d2_infinite() {
        let out = SerialAssigner.assign_top2(&[3.0], 1, &[1.0], &counter());
        assert_eq!(out.assign, vec![0]);
        assert_eq!(out.d1, vec![4.0]);
        assert!(out.d2[0].is_infinite());
    }

    #[test]
    fn empty_input_counts_nothing() {
        let c = counter();
        let out = SerialAssigner.assign_top2(&[], 3, &[0.0, 0.0, 0.0], &c);
        assert!(out.assign.is_empty());
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn block_boundaries_are_seamless() {
        // m straddling POINT_BLOCK and k straddling CENT_TILE: the tiled
        // state handoff must not disturb results at the seams.
        let mut g = prop::Gen { rng: crate::util::Rng::new(7), case: 0 };
        for &(m, k) in &[
            (POINT_BLOCK - 1, CENT_TILE),
            (POINT_BLOCK, CENT_TILE + 1),
            (POINT_BLOCK + 1, 2 * CENT_TILE + 3),
            (3 * POINT_BLOCK + 5, 1),
        ] {
            let d = 3;
            let reps = g.cloud(m, d, 2.0);
            let cents = g.cloud(k, d, 2.0);
            let c1 = counter();
            let eng = SerialAssigner.assign_top2(&reps, d, &cents, &c1);
            let c2 = counter();
            let reference = retired_reference(&reps, d, &cents, &c2);
            assert_eq!(eng, reference, "m={m} k={k}");
            assert_eq!(c1.get(), (m * k) as u64);
        }
    }

    #[test]
    fn shard_ranges_cover_and_order() {
        for n in [0usize, 1, 7, 10, 64, 65] {
            for shards in 1..=12 {
                let ranges = shard_ranges(n, shards);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut prev = 0;
                for r in &ranges {
                    assert_eq!(r.start, prev);
                    prev = r.end;
                }
                // Near-equal: lengths differ by at most one.
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(hi - lo <= 1);
            }
        }
    }

    #[test]
    fn sq_dist_row_fills_all_k() {
        let c = counter();
        let cents = [0.0, 0.0, 3.0, 0.0, 0.0, 4.0];
        let mut row = vec![0.0; 3];
        let (i1, b1) = sq_dist_row(&[0.0, 0.0], &cents, 2, &mut row, &c);
        assert_eq!(i1, 0);
        assert_eq!(b1, 0.0);
        assert_eq!(row, vec![0.0, 9.0, 16.0]);
        assert_eq!(c.get(), 3);
    }

    #[test]
    fn norm_pruned_actually_prunes_separated_clusters() {
        // Radially spread centroids: the norm bound removes most
        // candidates once the top-2 tightens.
        let mut g = prop::Gen { rng: crate::util::Rng::new(21), case: 0 };
        let d = 3;
        let k = 32;
        let m = 2000;
        // Centroids at widely different radii.
        let mut cents = Vec::with_capacity(k * d);
        for c in 0..k {
            let r = 1.0 + 10.0 * c as f64;
            cents.extend_from_slice(&[r, 0.0, 0.0]);
        }
        let reps: Vec<f64> = (0..m)
            .flat_map(|_| {
                let c = g.rng.usize(k);
                let r = 1.0 + 10.0 * c as f64;
                vec![r + g.rng.normal() * 0.1, g.rng.normal() * 0.1, g.rng.normal() * 0.1]
            })
            .collect();
        let c_exact = counter();
        let exact = SerialAssigner.assign_top2(&reps, d, &cents, &c_exact);
        let c_pruned = counter();
        let pruned = NormPrunedAssigner.assign_top2(&reps, d, &cents, &c_pruned);
        assert_eq!(exact, pruned);
        assert!(
            c_pruned.get() < c_exact.get() / 2,
            "pruned {} vs exact {}",
            c_pruned.get(),
            c_exact.get()
        );
    }
}
