//! The seeding subsystem (paper §1.2.1, DESIGN.md §2.8): one [`Seeder`]
//! trait — k centroids from weighted rows, exact accounting, seeded RNG —
//! with four backends:
//!
//! * **Forgy** [14]: K instances uniformly at random ([`ForgySeeder`]);
//! * **K-means++** [2], plain and weighted — the weighted form seeds
//!   BWKM's runs over representatives, Alg. 4 / Alg. 5 Step 1
//!   ([`KmppSeeder`]);
//! * **AFK-MC²** [3] (the paper's "KMC2" baseline), the MCMC
//!   approximation of K-means++ ([`Kmc2Seeder`]);
//! * **K-means||** (Bahmani et al.): r rounds of l-oversampled D²
//!   sampling with the per-round refresh on the unified assignment
//!   engine, then a weighted-K-means++ recluster of the candidate set
//!   ([`KmeansParSeeder`]; streamed twin in
//!   `coordinator::streaming::StreamSeeder`).
//!
//! The historical free functions ([`forgy`], [`kmeanspp`],
//! [`weighted_kmeanspp`], [`kmc2`]) are kept as the legacy surface; the
//! trait backends are bit-identical to them and are what the rest of the
//! crate (BWKM, RPKM, CLI `init=` policy) now routes through.

pub mod forgy;
pub mod kmc2;
pub mod kmeans_par;
pub mod kmeanspp;
pub mod seeder;

pub use forgy::forgy;
pub use kmc2::{kmc2, Kmc2Cfg};
pub use kmeans_par::{KmeansParSeeder, ParCfg, ParStats};
pub use kmeanspp::{kmeanspp, weighted_kmeanspp};
pub use seeder::{ForgySeeder, Kmc2Seeder, KmppSeeder, SeedMethod, SeedPolicy, Seeder};
