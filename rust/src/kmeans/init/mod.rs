//! Seeding strategies (paper §1.2.1): Forgy, K-means++ (plain and
//! weighted — the weighted form seeds BWKM's runs over representatives,
//! Alg. 4 / Alg. 5 Step 1), and AFK-MC² (the MCMC approximation of
//! K-means++, the paper's "KMC2" baseline).

pub mod forgy;
pub mod kmc2;
pub mod kmeanspp;

pub use forgy::forgy;
pub use kmc2::{kmc2, Kmc2Cfg};
pub use kmeanspp::{kmeanspp, weighted_kmeanspp};
