//! Scalable K-means++ — **K-means||** (Bahmani et al., "Scalable
//! K-Means++") as a [`Seeder`] backend (DESIGN.md §2.8).
//!
//! K-means++'s D² sampling is inherently serial: k sequential passes,
//! each conditioned on the previous draw. K-means|| collapses that to
//! r ∈ O(log n) rounds by *oversampling*: each round samples every row
//! independently with probability min(1, l·w·D²(x,C)/ψ) (l ≈ 2k rows in
//! expectation), so one pass yields a whole batch of candidates; after r
//! rounds the ~r·l candidates are weighted by the mass of the rows they
//! are nearest to and reclustered with weighted K-means++ down to k.
//!
//! **Round structure** (normative; DESIGN.md §2.8). One *prime* pass
//! against the first centroid, then r fused *round* passes, then one
//! *final* pass:
//!
//! * prime: c₀ by weight-proportional draw (the same first draw as
//!   weighted K-means++); one pass sets `mind2[i] = D²(xᵢ, c₀)`,
//!   `assign[i] = 0`, and folds ψ = Σ w·mind2 in global row order.
//! * round t (t = 1..r): **one pass** that (a) refreshes `mind2`/`assign`
//!   against the batch sampled in round t−1 (empty for t = 1), (b)
//!   re-folds ψ in global row order, and (c) draws one uniform per row —
//!   in row order — admitting row i into batch Bₜ iff
//!   `u·ψ_prev < l·w·mind2[i]`, where ψ_prev is the ψ of the *previous*
//!   pass. The numerator is therefore fully fresh and the normalizer one
//!   batch stale; ψ is non-increasing, so inclusion probabilities are a
//!   conservative lower bound on Bahmani's exact form (and exact for
//!   t = 1). The lag is what lets a round be a *single* pass out of core.
//! * final: one pass refreshing against B_r (skipped when empty), then
//!   candidate masses `cw[j] = Σ_{assign[i]=j} wᵢ` folded in row order,
//!   then `weighted_kmeanspp(C, cw, k)`.
//!
//! **Refresh = the unified engine.** The per-round min-distance refresh
//! is one [`Assigner::assign_top2`] call against the new batch only —
//! `Sharded<B>` parallelizes it for free — and the incremental update
//! `mind2 ← min(mind2, d1)` with strict `<` equals a full index-order
//! scan over all candidates bit for bit (new candidates have higher
//! indices, and ties keep the incumbent — the §2.1 tie-break).
//!
//! **Counting** (pinned by `rust/tests/init_conformance.rs`): every
//! batch is scanned against all m rows exactly once, so the total bill
//! is **m·|C| + |C|·(k−1)** with |C| = 1 + Σₜ|Bₜ| (the recluster is a
//! weighted K-means++ over the |C| candidates).
//!
//! The same driver runs in memory ([`MemParSource`]) and over a chunked
//! stream (`coordinator::streaming::StreamSeeder`): the [`ParSource`]
//! seam delivers per-row `(D², argmin)` values in global row order, and
//! every floating-point fold (ψ, candidate masses) plus every RNG draw
//! happens in the shared driver — so the two paths are bit-identical by
//! construction (same centroids, same counter totals, same notes), the
//! §5.1 merge-determinism rule applied to seeding.

use anyhow::Result;

use crate::metrics::DistanceCounter;
use crate::util::Rng;

use super::super::assign::{Assigner, SerialAssigner};
use super::kmeanspp::weighted_kmeanspp;
use super::seeder::Seeder;

/// K-means|| configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParCfg {
    /// Sampling rounds r (Bahmani et al. report r ≈ 5 suffices; each
    /// round is one pass over the data).
    pub rounds: usize,
    /// Oversampling factor l — the expected batch size per round.
    /// 0 selects the standard l = 2·k.
    pub oversample: f64,
}

impl Default for ParCfg {
    fn default() -> Self {
        ParCfg { rounds: 5, oversample: 0.0 }
    }
}

impl ParCfg {
    /// The effective l for a given k (resolves the 0 = auto default).
    pub fn effective_l(&self, k: usize) -> f64 {
        if self.oversample > 0.0 {
            self.oversample
        } else {
            (2 * k) as f64
        }
    }
}

/// What a K-means|| run did — enough to reproduce its exact distance
/// bill (DESIGN.md §2.8).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParStats {
    /// Total candidates |C| (c₀ plus every round batch).
    pub candidates: usize,
    /// Per-round batch sizes |Bₜ| (may be shorter than `rounds` when
    /// seeding converged early on ψ = 0).
    pub batches: Vec<usize>,
}

impl ParStats {
    /// The closed-form distance bill of the run that produced these
    /// stats: m·|C| (every batch scanned once against all rows, the
    /// prime pass included) + |C|·(k−1) (the weighted K-means++
    /// recluster).
    pub fn bill(&self, m: usize, k: usize) -> u64 {
        (m * self.candidates + self.candidates * (k - 1)) as u64
    }
}

/// Data access for the K-means|| driver — the seeding twin of
/// `bwkm::source::RefineSource` (DESIGN.md §2.8): one trait, two
/// implementations (in-memory below, streamed in
/// `coordinator::streaming`), one shared driver holding every fold and
/// every RNG draw.
pub(crate) trait ParSource {
    /// Number of rows m.
    fn rows(&self) -> usize;

    /// Dimension d.
    fn dim(&self) -> usize;

    /// The row at dataset index `idx` (flat d) — fetches c₀'s
    /// coordinates (one streamed pass out of core, a copy in memory).
    fn fetch(&mut self, idx: usize) -> Result<Vec<f64>>;

    /// One pass: for **every** row in **global row order**, call `visit`
    /// with `(i, row, dnew, jnew)` where `(dnew, jnew)` is the smallest
    /// squared distance / argmin of the row against `batch` (flat b×d;
    /// `(∞, 0)` when b = 0), computed through the canonical kernel in
    /// batch index order with strict `<`
    /// ([`crate::kmeans::assign::nearest_in`]). Implementations charge
    /// exactly rows·b to `counter` and perform **no** floating-point
    /// accumulation of their own — every fold lives in `visit`, on the
    /// driver (the §5.1 merge-determinism rule).
    fn pass(
        &mut self,
        batch: &[f64],
        counter: &DistanceCounter,
        visit: &mut dyn FnMut(usize, &[f64], f64, u32),
    ) -> Result<()>;
}

/// The in-memory [`ParSource`]: borrowed flat rows, refresh through any
/// unified-engine backend (`Sharded<B>` for free parallelism).
pub(crate) struct MemParSource<'a, B: Assigner> {
    pub data: &'a [f64],
    pub d: usize,
    pub engine: &'a mut B,
}

impl<B: Assigner> ParSource for MemParSource<'_, B> {
    fn rows(&self) -> usize {
        self.data.len() / self.d
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn fetch(&mut self, idx: usize) -> Result<Vec<f64>> {
        Ok(self.data[idx * self.d..(idx + 1) * self.d].to_vec())
    }

    fn pass(
        &mut self,
        batch: &[f64],
        counter: &DistanceCounter,
        visit: &mut dyn FnMut(usize, &[f64], f64, u32),
    ) -> Result<()> {
        let d = self.d;
        if batch.is_empty() {
            for (i, row) in self.data.chunks_exact(d).enumerate() {
                visit(i, row, f64::INFINITY, 0);
            }
            return Ok(());
        }
        // One engine call per round: the blocked/tiled kernel (or any
        // §2.2 backend) computes every row's nearest new candidate and
        // charges rows·b — bit-identical to the straight `nearest_in`
        // scan the streamed workers run (§2.1).
        let out = self.engine.assign_top2(self.data, d, batch, counter);
        for (i, row) in self.data.chunks_exact(d).enumerate() {
            visit(i, row, out.d1[i], out.assign[i]);
        }
        Ok(())
    }
}

/// The K-means|| driver over any [`ParSource`] — all folds in global row
/// order, all randomness from `rng`, notes on `counter` (one per round),
/// so every source produces bit-identical results (DESIGN.md §2.8).
pub(crate) fn kmeans_par_source<S: ParSource>(
    src: &mut S,
    weights: &[f64],
    k: usize,
    cfg: &ParCfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Result<(Vec<f64>, ParStats)> {
    let m = src.rows();
    let d = src.dim();
    assert!(k >= 1 && m >= 1, "kmeans||: need k>=1, n>=1");
    assert_eq!(weights.len(), m, "kmeans||: one weight per row");
    let l = cfg.effective_l(k);

    // c₀: weight-proportional draw — the same first draw as weighted
    // K-means++.
    let c0 = rng.weighted_index(weights).unwrap_or(0);
    let mut cands = src.fetch(c0)?;
    let mut mind2 = vec![f64::INFINITY; m];
    let mut assign = vec![0u32; m];

    // Prime pass: D² to c₀ (m pairs), ψ folded in global row order.
    let mut psi = {
        let mut psi_acc = 0.0f64;
        src.pass(&cands, counter, &mut |i, _row, dnew, jnew| {
            if dnew < mind2[i] {
                mind2[i] = dnew;
                assign[i] = jnew;
            }
            psi_acc += weights[i] * mind2[i];
        })?;
        psi_acc
    };
    counter.note(format!("kmpar[prime]: cands=1 psi={psi:e}"));

    let mut stats = ParStats::default();
    // The candidate range the next pass must refresh against (B_{t−1};
    // empty before round 1 — the prime pass already covered c₀).
    let mut pend = 0usize..0usize;
    for t in 1..=cfg.rounds {
        if psi <= 0.0 {
            // Every row coincides with a candidate: no further round can
            // sample anything (and refreshing B_{t−1} cannot lower a
            // zero min-distance), so seeding has converged.
            counter.note(format!("kmpar[{t}]: psi=0, converged"));
            break;
        }
        let psi_prev = psi;
        let base = pend.start as u32;
        let mut next: Vec<f64> = Vec::new();
        let mut psi_acc = 0.0f64;
        src.pass(&cands[pend.start * d..pend.end * d], counter, &mut |i, row, dnew, jnew| {
            if dnew < mind2[i] {
                mind2[i] = dnew;
                assign[i] = base + jnew;
            }
            psi_acc += weights[i] * mind2[i];
            let u = rng.f64();
            if u * psi_prev < l * weights[i] * mind2[i] {
                next.extend_from_slice(row);
            }
        })?;
        psi = psi_acc;
        let b = next.len() / d;
        let start = cands.len() / d;
        cands.extend_from_slice(&next);
        pend = start..start + b;
        stats.batches.push(b);
        counter.note(format!("kmpar[{t}]: batch={b} cands={} psi={psi:e}", start + b));
    }
    // Final refresh against the last round's batch (skipped when empty:
    // a no-batch pass could neither move an assignment nor a distance).
    if !pend.is_empty() {
        let base = pend.start as u32;
        src.pass(&cands[pend.start * d..pend.end * d], counter, &mut |i, _row, dnew, jnew| {
            if dnew < mind2[i] {
                mind2[i] = dnew;
                assign[i] = base + jnew;
            }
        })?;
    }

    // Candidate masses: each row's weight accrues to its nearest
    // candidate, folded in global row order.
    let c = cands.len() / d;
    let mut cw = vec![0.0f64; c];
    for i in 0..m {
        cw[assign[i] as usize] += weights[i];
    }
    // Recluster the weighted candidate set down to k (|C|·(k−1) pairs).
    let centroids = weighted_kmeanspp(&cands, &cw, d, k, rng, counter);
    stats.candidates = c;
    counter.note(format!("kmpar[final]: cands={c} k={k}"));
    Ok((centroids, stats))
}

/// K-means|| as a [`Seeder`], refreshing through any unified-engine
/// backend `B` (default serial; `Sharded<B>` parallelizes every round's
/// refresh with bit-identical output — DESIGN.md §2.5).
#[derive(Clone, Debug, Default)]
pub struct KmeansParSeeder<B: Assigner = SerialAssigner> {
    cfg: ParCfg,
    engine: B,
    stats: ParStats,
}

impl KmeansParSeeder<SerialAssigner> {
    pub fn new(cfg: ParCfg) -> Self {
        KmeansParSeeder { cfg, engine: SerialAssigner, stats: ParStats::default() }
    }
}

impl<B: Assigner> KmeansParSeeder<B> {
    /// Seed through a pre-configured engine backend.
    pub fn with_engine(cfg: ParCfg, engine: B) -> Self {
        KmeansParSeeder { cfg, engine, stats: ParStats::default() }
    }

    /// What the most recent [`Seeder::seed`] call did — the conformance
    /// suite asserts `counter delta == stats.bill(m, k)`.
    pub fn last_stats(&self) -> &ParStats {
        &self.stats
    }
}

impl<B: Assigner> Seeder for KmeansParSeeder<B> {
    fn name(&self) -> &'static str {
        "par"
    }

    fn seed(
        &mut self,
        data: &[f64],
        weights: &[f64],
        d: usize,
        k: usize,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Vec<f64> {
        let cfg = self.cfg;
        let mut src = MemParSource { data, d, engine: &mut self.engine };
        let (centroids, stats) = kmeans_par_source(&mut src, weights, k, &cfg, rng, counter)
            .expect("the in-memory source is infallible");
        self.stats = stats;
        centroids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::assign::Sharded;
    use crate::metrics::kmeans_error;
    use crate::util::prop;

    fn unit(m: usize) -> Vec<f64> {
        vec![1.0; m]
    }

    #[test]
    fn counter_matches_closed_form() {
        let mut g = prop::Gen { rng: Rng::new(51), case: 0 };
        let data = g.blobs(400, 2, 4, 0.5);
        let c = DistanceCounter::new();
        let mut s = KmeansParSeeder::new(ParCfg::default());
        let cents = s.seed(&data, &unit(400), 2, 4, &mut Rng::new(9), &c);
        assert_eq!(cents.len(), 4 * 2);
        let stats = s.last_stats();
        assert!(stats.candidates >= 1);
        assert_eq!(c.get(), stats.bill(400, 4), "bill must be m·|C| + |C|·(k−1)");
    }

    #[test]
    fn prop_sharded_engine_bit_identical() {
        // Sharded<Serial> refresh == serial refresh: same centroids, same
        // counts, same notes, for every thread count (DESIGN.md §2.5).
        prop::check("kmpar-sharded", 8, |g| {
            let m = g.int(10, 300);
            let d = g.int(1, 5);
            let k = g.int(1, 6);
            let data = g.cloud(m, d, 3.0);
            let w: Vec<f64> = (0..m).map(|_| g.int(1, 7) as f64).collect();
            let cfg = ParCfg { rounds: g.int(1, 4), oversample: 0.0 };
            let c1 = DistanceCounter::new();
            let a = KmeansParSeeder::new(cfg).seed(&data, &w, d, k, &mut Rng::new(77), &c1);
            for threads in [2usize, 5] {
                let c2 = DistanceCounter::new();
                let mut s = KmeansParSeeder::with_engine(
                    cfg,
                    Sharded::<SerialAssigner>::new(threads),
                );
                let b = s.seed(&data, &w, d, k, &mut Rng::new(77), &c2);
                assert_eq!(a, b);
                assert_eq!(c1.get(), c2.get());
                assert_eq!(c1.notes(), c2.notes());
            }
        });
    }

    #[test]
    fn seeds_are_dataset_rows() {
        let mut g = prop::Gen { rng: Rng::new(52), case: 0 };
        let data = g.cloud(120, 3, 2.0);
        let c = DistanceCounter::new();
        let cents = KmeansParSeeder::new(ParCfg::default())
            .seed(&data, &unit(120), 3, 5, &mut Rng::new(4), &c);
        for cent in cents.chunks(3) {
            assert!(data.chunks(3).any(|r| r == cent), "{cent:?}");
        }
    }

    #[test]
    fn degenerate_identical_points() {
        let data = vec![2.5; 12]; // 12 identical rows, d=1
        let c = DistanceCounter::new();
        let mut s = KmeansParSeeder::new(ParCfg::default());
        let cents = s.seed(&data, &unit(12), 1, 3, &mut Rng::new(6), &c);
        assert_eq!(cents, vec![2.5; 3]);
        // ψ = 0 after the prime pass: rounds sample nothing, so |C| = 1
        // and the bill collapses to m + (k−1).
        assert_eq!(s.last_stats().candidates, 1);
        assert_eq!(c.get(), (12 + 2) as u64);
    }

    #[test]
    fn k1_skips_the_recluster_bill() {
        let mut g = prop::Gen { rng: Rng::new(53), case: 0 };
        let data = g.cloud(80, 2, 2.0);
        let c = DistanceCounter::new();
        let mut s = KmeansParSeeder::new(ParCfg::default());
        let cents = s.seed(&data, &unit(80), 2, 1, &mut Rng::new(8), &c);
        assert_eq!(cents.len(), 2);
        assert_eq!(c.get(), s.last_stats().bill(80, 1));
    }

    #[test]
    fn quality_close_to_kmeanspp_on_blobs() {
        // Seeding-error sanity on separated blobs, averaged over seeds.
        let mut g = prop::Gen { rng: Rng::new(54), case: 0 };
        let data = g.blobs(600, 2, 4, 0.3);
        let (mut e_par, mut e_pp) = (0.0, 0.0);
        for seed in 0..10 {
            let c = DistanceCounter::new();
            let cp = KmeansParSeeder::new(ParCfg::default())
                .seed(&data, &unit(600), 2, 4, &mut Rng::new(seed), &c);
            e_par += kmeans_error(&data, 2, &cp, &c);
            let ck = super::super::kmeanspp::kmeanspp(&data, 2, 4, &mut Rng::new(seed), &c);
            e_pp += kmeans_error(&data, 2, &ck, &c);
        }
        assert!(e_par < e_pp * 2.0, "km|| err {e_par} vs km++ {e_pp}");
    }
}
