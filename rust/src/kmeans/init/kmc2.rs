//! AFK-MC² seeding (Bachem et al. [3], "Fast and Provably Good Seedings
//! for k-Means") — the paper's **KMC2** baseline: a Markov-chain Monte
//! Carlo approximation of the K-means++ D² distribution with sublinear
//! per-centroid cost.
//!
//! One preprocessing pass builds the assumption-free proposal
//! q(x) ∝ ½·d(x, c₁)²/Σd² + ½·1/n (n distances); afterwards each of the
//! k−1 centroids runs a Metropolis–Hastings chain of length `m`, each chain
//! step computing |C| distances (the distance from the candidate to the
//! current centroid set).

use crate::geometry::sq_dist;
use crate::metrics::DistanceCounter;
use crate::util::{Cdf, Rng};

/// AFK-MC² configuration.
#[derive(Clone, Copy, Debug)]
pub struct Kmc2Cfg {
    /// Chain length (Bachem et al. use m = 100..200).
    pub chain_length: usize,
}

impl Default for Kmc2Cfg {
    fn default() -> Self {
        Kmc2Cfg { chain_length: 200 }
    }
}

/// Run AFK-MC² over `data`; returns flat k×d centroids.
///
/// Legacy surface, deprecated in favor of the
/// [`Seeder`](super::Seeder) trait: [`super::Kmc2Seeder`] is
/// bit-identical for the same [`Kmc2Cfg`] (DESIGN.md §2.8).
pub fn kmc2(
    data: &[f64],
    d: usize,
    k: usize,
    cfg: &Kmc2Cfg,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Vec<f64> {
    let n = data.len() / d;
    assert!(k >= 1 && n >= 1);
    let mut centroids = Vec::with_capacity(k * d);

    // c1 uniform.
    let first = rng.usize(n);
    centroids.extend_from_slice(&data[first * d..(first + 1) * d]);
    if k == 1 {
        return centroids;
    }

    // Assumption-free proposal from one full pass against c1.
    let c1 = &data[first * d..(first + 1) * d].to_vec();
    let mut d2_c1 = vec![0.0; n];
    let mut total = 0.0;
    for i in 0..n {
        let dd = sq_dist(&data[i * d..(i + 1) * d], c1);
        d2_c1[i] = dd;
        total += dd;
    }
    counter.add(n as u64);
    let q: Vec<f64> = if total > 0.0 {
        d2_c1.iter().map(|&dd| 0.5 * dd / total + 0.5 / n as f64).collect()
    } else {
        vec![1.0 / n as f64; n] // all points identical
    };
    let q_cdf = Cdf::new(&q).expect("proposal mass");

    // dist²(x, C) of the current chain state, recomputed lazily.
    let dist_to_set = |x: usize, cents: &[f64], counter: &DistanceCounter| -> f64 {
        let kc = cents.len() / d;
        let mut best = f64::INFINITY;
        let row = &data[x * d..(x + 1) * d];
        for c in 0..kc {
            best = best.min(sq_dist(row, &cents[c * d..(c + 1) * d]));
        }
        counter.add(kc as u64);
        best
    };

    for _ in 1..k {
        // Initialize the chain at a proposal draw.
        let mut x = q_cdf.sample(rng);
        let mut dx = dist_to_set(x, &centroids, counter);
        for _ in 1..cfg.chain_length {
            let y = q_cdf.sample(rng);
            let dy = dist_to_set(y, &centroids, counter);
            // Metropolis–Hastings acceptance for target ∝ d²(·,C):
            // accept with min(1, (dy·q(x)) / (dx·q(y))).
            let num = dy * q[x];
            let den = dx * q[y];
            if den <= 0.0 || rng.f64() * den < num {
                x = y;
                dx = dy;
            }
        }
        centroids.extend_from_slice(&data[x * d..(x + 1) * d]);
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmeans_error;

    #[test]
    fn distance_count_is_n_plus_chains() {
        let data: Vec<f64> = (0..500).map(|x| x as f64).collect();
        let c = DistanceCounter::new();
        let cfg = Kmc2Cfg { chain_length: 50 };
        let _ = kmc2(&data, 1, 4, &cfg, &mut Rng::new(1), &c);
        // n (proposal) + per added centroid j=1..3: chain of 50 states with
        // |C| = j distances each (initial draw + 49 steps).
        let expect = 500 + 50 * (1 + 2 + 3);
        assert_eq!(c.get(), expect as u64);
    }

    #[test]
    fn sublinear_vs_kmeanspp_for_large_n() {
        let n = 20_000usize;
        let data: Vec<f64> = (0..n).map(|x| (x % 97) as f64).collect();
        let c_mc = DistanceCounter::new();
        let _ = kmc2(&data, 1, 10, &Kmc2Cfg::default(), &mut Rng::new(2), &c_mc);
        let c_pp = DistanceCounter::new();
        let _ = super::super::kmeanspp::kmeanspp(&data, 1, 10, &mut Rng::new(2), &c_pp);
        assert!(
            c_mc.get() < c_pp.get() / 2,
            "kmc2 {} not ≪ km++ {}",
            c_mc.get(),
            c_pp.get()
        );
    }

    #[test]
    fn quality_close_to_kmeanspp_on_blobs() {
        // Average seeding error within 2x of KM++ on separated blobs.
        let mut rng = Rng::new(3);
        let mut data = Vec::new();
        for &(cx, cy) in &[(0.0, 0.0), (40.0, 0.0), (0.0, 40.0), (40.0, 40.0)] {
            for _ in 0..200 {
                data.push(cx + rng.normal());
                data.push(cy + rng.normal());
            }
        }
        let (mut e_mc, mut e_pp) = (0.0, 0.0);
        for seed in 0..15 {
            let c = DistanceCounter::new();
            let cm = kmc2(&data, 2, 4, &Kmc2Cfg::default(), &mut Rng::new(seed), &c);
            e_mc += kmeans_error(&data, 2, &cm, &c);
            let cp =
                super::super::kmeanspp::kmeanspp(&data, 2, 4, &mut Rng::new(seed), &c);
            e_pp += kmeans_error(&data, 2, &cp, &c);
        }
        assert!(e_mc < e_pp * 2.0, "kmc2 err {e_mc} vs km++ {e_pp}");
    }

    #[test]
    fn degenerate_identical_points() {
        let data = vec![3.3; 10];
        let c = DistanceCounter::new();
        let cents = kmc2(&data, 1, 3, &Kmc2Cfg::default(), &mut Rng::new(5), &c);
        assert_eq!(cents, vec![3.3; 3]);
    }
}
