//! K-means++ seeding (Arthur & Vassilvitskii [2]): first centroid uniform,
//! each subsequent centroid sampled with probability proportional to the
//! squared distance to the already-selected set (D² sampling).
//!
//! The weighted variant (probability ∝ w(x)·D²(x)) seeds runs over
//! partition representatives — BWKM uses it in Alg. 4 and Alg. 5 Step 1.
//!
//! Cost: each added centroid refreshes the min-distance array with one new
//! distance per point → exactly n·(k−1) + 0 distances for the plain run
//! (the first centroid is free), matching the paper's O(n·K·d) accounting.

use crate::geometry::sq_dist;
use crate::metrics::DistanceCounter;
use crate::util::Rng;

/// Plain K-means++ over `data`. Returns flat k×d centroids.
///
/// Legacy surface, deprecated in favor of the
/// [`Seeder`](super::Seeder) trait: [`super::KmppSeeder`] with unit
/// weights is bit-identical (DESIGN.md §2.8).
pub fn kmeanspp(
    data: &[f64],
    d: usize,
    k: usize,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Vec<f64> {
    let n = data.len() / d;
    weighted_kmeanspp(data, &vec![1.0; n], d, k, rng, counter)
}

/// Weighted K-means++: D² sampling with probabilities ∝ w(x)·D²(x).
/// (The canonical implementation behind [`super::KmppSeeder`] and the
/// K-means|| recluster step — DESIGN.md §2.8.)
pub fn weighted_kmeanspp(
    data: &[f64],
    weights: &[f64],
    d: usize,
    k: usize,
    rng: &mut Rng,
    counter: &DistanceCounter,
) -> Vec<f64> {
    let n = weights.len();
    assert!(k >= 1 && n >= 1, "kmeans++: need k>=1, n>=1");
    let mut centroids = Vec::with_capacity(k * d);

    // First centroid: weight-proportional uniform draw (uniform over the
    // underlying instances each representative stands for).
    let first = rng.weighted_index(weights).unwrap_or(0);
    centroids.extend_from_slice(&data[first * d..(first + 1) * d]);

    // min squared distance to the selected set, maintained incrementally.
    let mut mind2 = vec![f64::INFINITY; n];
    let mut probs = vec![0.0; n];
    for c in 1..k {
        let newest = &centroids[(c - 1) * d..c * d];
        for i in 0..n {
            let dd = sq_dist(&data[i * d..(i + 1) * d], newest);
            if dd < mind2[i] {
                mind2[i] = dd;
            }
            probs[i] = weights[i] * mind2[i];
        }
        counter.add(n as u64);
        match rng.weighted_index(&probs) {
            Some(next) => centroids.extend_from_slice(&data[next * d..(next + 1) * d]),
            None => {
                // All mass at distance 0 (fewer distinct points than k):
                // fall back to a weight-proportional draw.
                let f = rng.weighted_index(weights).unwrap_or(0);
                centroids.extend_from_slice(&data[f * d..(f + 1) * d]);
            }
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmeans_error;
    use crate::util::prop;

    #[test]
    fn counts_exactly_n_per_added_centroid() {
        let data: Vec<f64> = (0..100).map(|x| x as f64).collect(); // n=100, d=1
        let c = DistanceCounter::new();
        let _ = kmeanspp(&data, 1, 5, &mut Rng::new(1), &c);
        assert_eq!(c.get(), 100 * 4);
    }

    #[test]
    fn seeds_are_dataset_rows() {
        let data: Vec<f64> = (0..60).map(|x| (x as f64).sin() * 10.0).collect();
        let c = DistanceCounter::new();
        let cents = kmeanspp(&data, 2, 6, &mut Rng::new(2), &c);
        for cent in cents.chunks(2) {
            assert!(data.chunks(2).any(|r| r == cent));
        }
    }

    #[test]
    fn spreads_over_separated_clusters() {
        // Three far-apart blobs: KM++ should seed one centroid in each
        // almost always (probability of failure is astronomically small).
        let mut data = Vec::new();
        let mut rng = Rng::new(3);
        for &cx in &[0.0, 1000.0, 2000.0] {
            for _ in 0..50 {
                data.push(cx + rng.normal());
            }
        }
        let c = DistanceCounter::new();
        let mut hits = 0;
        for seed in 0..20 {
            let cents = kmeanspp(&data, 1, 3, &mut Rng::new(seed), &c);
            let mut got = [false; 3];
            for &x in &cents {
                if x < 500.0 {
                    got[0] = true;
                } else if x < 1500.0 {
                    got[1] = true;
                } else {
                    got[2] = true;
                }
            }
            hits += got.iter().all(|&g| g) as usize;
        }
        assert!(hits >= 19, "only {hits}/20 runs covered all clusters");
    }

    #[test]
    fn weighted_prefers_heavy_points() {
        // Two points; one carries weight 10^6. It should be selected first
        // nearly always.
        let data = [0.0, 1.0];
        let weights = [1e6, 1.0];
        let mut firsts = 0;
        for seed in 0..50 {
            let c = DistanceCounter::new();
            let cents =
                weighted_kmeanspp(&data, &weights, 1, 1, &mut Rng::new(seed), &c);
            firsts += (cents[0] == 0.0) as usize;
        }
        assert!(firsts >= 48);
    }

    #[test]
    fn degenerate_fewer_distinct_points_than_k() {
        let data = [5.0, 5.0, 5.0, 5.0]; // 4 identical rows, d=1
        let c = DistanceCounter::new();
        let cents = kmeanspp(&data, 1, 3, &mut Rng::new(4), &c);
        assert_eq!(cents, vec![5.0, 5.0, 5.0]);
    }

    #[test]
    fn prop_kmpp_no_worse_than_random_on_average() {
        // Sanity of the O(log K) guarantee's *direction*: KM++ beats Forgy
        // in expectation on clustered data. Compare averages over seeds.
        prop::check("kmpp-vs-forgy", 5, |g| {
            let n = 200;
            let d = 2;
            let k = 4;
            let data = g.blobs(n, d, k, 0.3);
            let (mut e_pp, mut e_fg) = (0.0, 0.0);
            for seed in 0..12 {
                let c = DistanceCounter::new();
                let mut rng = Rng::new(1000 + seed);
                let cents = kmeanspp(&data, d, k, &mut rng, &c);
                e_pp += kmeans_error(&data, d, &cents, &c);
                let cents = super::super::forgy::forgy(&data, d, k, &mut rng);
                e_fg += kmeans_error(&data, d, &cents, &c);
            }
            assert!(
                e_pp <= e_fg * 1.25,
                "km++ {e_pp} much worse than forgy {e_fg}"
            );
        });
    }
}
