//! The `Seeder` trait — the seeding subsystem's one entry shape
//! (DESIGN.md §2.8).
//!
//! Every initialization method in the crate seeds k centroids from a
//! *weighted* row set: the raw dataset (unit weights), a partition's
//! representatives (weights = block cardinalities — BWKM's Alg. 4 /
//! Alg. 5 Step 1 shape), or a grid level's occupied cells (RPKM).
//! Historically the three methods were free functions with ad-hoc
//! signatures; the trait names the common contract so BWKM, RPKM, the
//! CLI's seeding policy and the out-of-core coordinator can swap methods
//! without knowing them:
//!
//! * **Inputs.** Flat m×d `data`, per-row `weights` (length m, positive),
//!   a seeded [`Rng`] (the *only* randomness source — identical seeds
//!   give identical centroids), and the caller's [`DistanceCounter`].
//! * **Accounting.** Exact and closed-form per backend (DESIGN.md §2.4 /
//!   §2.8): Forgy 0, K-means++ m·(k−1), AFK-MC²
//!   m + chain·k·(k−1)/2 for k ≥ 2 (0 for k = 1 — the proposal pass is
//!   skipped), K-means|| m·|C| + |C|·(k−1).
//!   `rust/tests/init_conformance.rs` pins every formula with `==`.
//! * **Output.** Flat k×d centroids; every centroid is (a copy of) an
//!   input row.
//!
//! Weight-blind baselines (Forgy, AFK-MC²) are defined by their papers on
//! unweighted instances; their backends ignore `weights` — documented per
//! backend — so that on unit weights every backend is **bit-identical**
//! to the legacy free function it wraps.

use anyhow::{bail, Result};

use crate::metrics::DistanceCounter;
use crate::util::Rng;

use super::forgy::forgy;
use super::kmc2::{kmc2, Kmc2Cfg};
use super::kmeans_par::{KmeansParSeeder, ParCfg};
use super::kmeanspp::weighted_kmeanspp;

/// A seeding backend: k centroids from weighted rows, exact distance
/// accounting, all randomness from the caller's [`Rng`] (DESIGN.md §2.8).
pub trait Seeder {
    /// The method's CLI/report name (`forgy`, `pp`, `kmc2`, `par`).
    fn name(&self) -> &'static str;

    /// Seed `k` centroids (flat k×d) from the m×d `data` rows carrying
    /// `weights`. Must draw randomness only from `rng` and tick `counter`
    /// by the backend's documented closed-form bill.
    fn seed(
        &mut self,
        data: &[f64],
        weights: &[f64],
        d: usize,
        k: usize,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Vec<f64>;
}

/// Forgy [14] as a [`Seeder`]: k distinct rows uniformly at random.
/// Weight-blind (the paper's baseline is defined on instances, not
/// masses) and distance-free — bit-identical to [`forgy`] whenever
/// k ≤ m. The k > m degenerate (unreachable through the free function,
/// which panics) takes every row once and fills the remainder with
/// weight-proportional draws with replacement — the same fallback rule
/// weighted K-means++ uses when it runs out of distinct mass.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForgySeeder;

impl Seeder for ForgySeeder {
    fn name(&self) -> &'static str {
        "forgy"
    }

    fn seed(
        &mut self,
        data: &[f64],
        weights: &[f64],
        d: usize,
        k: usize,
        rng: &mut Rng,
        _counter: &DistanceCounter,
    ) -> Vec<f64> {
        let m = weights.len();
        if k <= m {
            return forgy(data, d, k, rng);
        }
        let mut out = forgy(data, d, m, rng);
        for _ in m..k {
            let i = rng.weighted_index(weights).unwrap_or(0);
            out.extend_from_slice(&data[i * d..(i + 1) * d]);
        }
        out
    }
}

/// Weighted K-means++ [2] as a [`Seeder`] — the D² sampler BWKM's Alg. 4
/// is pinned to. Bit-identical to [`weighted_kmeanspp`] (and to
/// [`super::kmeanspp`] on unit weights). Counts exactly m·(k−1).
#[derive(Clone, Copy, Debug, Default)]
pub struct KmppSeeder;

impl Seeder for KmppSeeder {
    fn name(&self) -> &'static str {
        "pp"
    }

    fn seed(
        &mut self,
        data: &[f64],
        weights: &[f64],
        d: usize,
        k: usize,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Vec<f64> {
        weighted_kmeanspp(data, weights, d, k, rng, counter)
    }
}

/// AFK-MC² [3] as a [`Seeder`]. Weight-blind (the MCMC proposal is
/// defined on instances); bit-identical to [`kmc2`] with the same
/// [`Kmc2Cfg`]. Counts exactly m + chain·k·(k−1)/2 for k ≥ 2, and 0 for
/// k = 1 (the single centroid is a uniform draw — [`kmc2`] returns
/// before the proposal pass).
#[derive(Clone, Copy, Debug, Default)]
pub struct Kmc2Seeder {
    pub cfg: Kmc2Cfg,
}

impl Seeder for Kmc2Seeder {
    fn name(&self) -> &'static str {
        "kmc2"
    }

    fn seed(
        &mut self,
        data: &[f64],
        _weights: &[f64],
        d: usize,
        k: usize,
        rng: &mut Rng,
        counter: &DistanceCounter,
    ) -> Vec<f64> {
        kmc2(data, d, k, &self.cfg, rng, counter)
    }
}

/// Which [`Seeder`] backend a run uses (the CLI's `init=` key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedMethod {
    Forgy,
    /// (Weighted) K-means++ — BWKM's Alg. 4 default.
    Kmpp,
    /// AFK-MC² (the paper's KMC2 baseline).
    Kmc2,
    /// Scalable K-means++ (K-means||, Bahmani et al.) — DESIGN.md §2.8.
    Par,
}

impl SeedMethod {
    /// Parse a CLI/config `init=` value.
    pub fn parse(s: &str) -> Result<SeedMethod> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "forgy" => SeedMethod::Forgy,
            "pp" | "kmpp" | "km++" | "kmeans++" => SeedMethod::Kmpp,
            "kmc2" | "afkmc2" => SeedMethod::Kmc2,
            "par" | "kmeans_par" | "km||" | "kmeanspar" => SeedMethod::Par,
            other => bail!("unknown init method `{other}` (expected forgy|pp|kmc2|par)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SeedMethod::Forgy => "forgy",
            SeedMethod::Kmpp => "pp",
            SeedMethod::Kmc2 => "kmc2",
            SeedMethod::Par => "par",
        }
    }
}

/// A run's seeding policy (DESIGN.md §2.8): the backend plus its knobs,
/// carried by `BwkmCfg`/`RpkmCfg` and populated from the `init`,
/// `oversample_l` and `init_rounds` config keys. The default —
/// weighted K-means++ — reproduces the pre-policy pipeline bit for bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeedPolicy {
    pub method: SeedMethod,
    /// K-means|| oversampling factor l (0 = auto: 2·k).
    pub oversample_l: f64,
    /// K-means|| sampling rounds r.
    pub init_rounds: usize,
    /// AFK-MC² chain length.
    pub chain_length: usize,
}

impl Default for SeedPolicy {
    fn default() -> Self {
        SeedPolicy {
            method: SeedMethod::Kmpp,
            oversample_l: 0.0,
            init_rounds: ParCfg::default().rounds,
            chain_length: Kmc2Cfg::default().chain_length,
        }
    }
}

impl SeedPolicy {
    /// A policy running `method` with default knobs.
    pub fn of(method: SeedMethod) -> SeedPolicy {
        SeedPolicy { method, ..SeedPolicy::default() }
    }

    /// The K-means|| configuration this policy encodes.
    pub fn par_cfg(&self) -> ParCfg {
        ParCfg { rounds: self.init_rounds, oversample: self.oversample_l }
    }

    /// Instantiate the backend (serial engine; parallel seeding goes
    /// through [`KmeansParSeeder::with_engine`] and a `Sharded` backend).
    pub fn seeder(&self) -> Box<dyn Seeder> {
        match self.method {
            SeedMethod::Forgy => Box::new(ForgySeeder),
            SeedMethod::Kmpp => Box::new(KmppSeeder),
            SeedMethod::Kmc2 => {
                Box::new(Kmc2Seeder { cfg: Kmc2Cfg { chain_length: self.chain_length } })
            }
            SeedMethod::Par => Box::new(KmeansParSeeder::new(self.par_cfg())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_rejects() {
        for m in [SeedMethod::Forgy, SeedMethod::Kmpp, SeedMethod::Kmc2, SeedMethod::Par] {
            assert_eq!(SeedMethod::parse(m.name()).unwrap(), m);
        }
        assert_eq!(SeedMethod::parse("KM++").unwrap(), SeedMethod::Kmpp);
        assert_eq!(SeedMethod::parse("km||").unwrap(), SeedMethod::Par);
        assert!(SeedMethod::parse("quantum").is_err());
    }

    #[test]
    fn default_policy_is_kmpp() {
        // The pre-policy pipeline seeded with weighted K-means++; the
        // default must keep that bit-compatible.
        assert_eq!(SeedPolicy::default().method, SeedMethod::Kmpp);
    }

    #[test]
    fn forgy_seeder_pads_past_row_count() {
        let data = [0.0, 10.0, 20.0]; // 3 rows, d=1
        let w = [1.0, 1.0, 1.0];
        let c = DistanceCounter::new();
        let out = ForgySeeder.seed(&data, &w, 1, 5, &mut Rng::new(3), &c);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|v| data.contains(v)));
        // The first 3 are distinct rows.
        let mut head = out[..3].to_vec();
        head.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(head, data.to_vec());
        assert_eq!(c.get(), 0, "forgy computes no distances");
    }
}
