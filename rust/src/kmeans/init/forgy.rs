//! Forgy initialization [14]: K instances chosen uniformly at random.

use crate::util::Rng;

/// Select `k` distinct rows of `data` uniformly at random as centroids.
/// Panics if `k` exceeds the number of rows. Computes no distances.
///
/// Legacy surface, deprecated in favor of the
/// [`Seeder`](super::Seeder) trait: [`super::ForgySeeder`] is
/// bit-identical (and handles k > n) — new call sites should go through
/// the trait / the `init=` policy (DESIGN.md §2.8).
pub fn forgy(data: &[f64], d: usize, k: usize, rng: &mut Rng) -> Vec<f64> {
    let n = data.len() / d;
    assert!(k <= n, "forgy: k={k} > n={n}");
    let idx = rng.sample_indices(n, k);
    let mut out = Vec::with_capacity(k * d);
    for i in idx {
        out.extend_from_slice(&data[i * d..(i + 1) * d]);
    }
    out
}

/// The §1.2.1 "standard initialization procedure": several Forgy
/// re-initializations, keeping the set with the smallest error. Each
/// candidate's evaluation costs n·k distances (counted) — exactly the
/// drawback the paper cites for this baseline.
pub fn forgy_restarts(
    data: &[f64],
    d: usize,
    k: usize,
    restarts: usize,
    rng: &mut crate::util::Rng,
    counter: &crate::metrics::DistanceCounter,
) -> Vec<f64> {
    assert!(restarts >= 1);
    let mut best: Option<(f64, Vec<f64>)> = None;
    for _ in 0..restarts {
        let cand = forgy(data, d, k, rng);
        let err = crate::metrics::kmeans_error(data, d, &cand, counter);
        if best.as_ref().map_or(true, |(e, _)| err < *e) {
            best = Some((err, cand));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn restarts_never_worse_than_single_draw_in_expectation() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(66), case: 0 };
        let data = g.blobs(600, 2, 4, 0.4);
        let c = crate::metrics::DistanceCounter::new();
        let (mut e_multi, mut e_single) = (0.0, 0.0);
        for seed in 0..10 {
            let mut rng = crate::util::Rng::new(seed);
            let multi = forgy_restarts(&data, 2, 4, 8, &mut rng, &c);
            e_multi += crate::metrics::kmeans_error(&data, 2, &multi, &c);
            let single = forgy(&data, 2, 4, &mut rng);
            e_single += crate::metrics::kmeans_error(&data, 2, &single, &c);
        }
        assert!(e_multi <= e_single, "{e_multi} > {e_single}");
    }

    #[test]
    fn restarts_count_nk_per_candidate() {
        let data: Vec<f64> = (0..200).map(|x| x as f64).collect();
        let c = crate::metrics::DistanceCounter::new();
        let _ = forgy_restarts(&data, 1, 4, 3, &mut crate::util::Rng::new(1), &c);
        assert_eq!(c.get(), 3 * 200 * 4);
    }

    #[test]
    fn picks_distinct_rows() {
        let data: Vec<f64> = (0..40).map(|x| x as f64).collect(); // 20 rows, d=2
        let mut rng = Rng::new(5);
        let c = forgy(&data, 2, 5, &mut rng);
        assert_eq!(c.len(), 10);
        // Each centroid is one of the rows.
        for chunk in c.chunks(2) {
            let found = data.chunks(2).any(|r| r == chunk);
            assert!(found);
        }
    }

    #[test]
    fn prop_forgy_centroids_are_dataset_rows() {
        prop::check("forgy-rows", 20, |g| {
            let n = g.int(3, 100);
            let d = g.int(1, 5);
            let k = g.int(1, n.min(8));
            let data = g.cloud(n, d, 2.0);
            let mut rng = g.rng.fork(2);
            let cents = forgy(&data, d, k, &mut rng);
            let mut seen = std::collections::HashSet::new();
            for c in cents.chunks(d) {
                let i = (0..n).find(|&i| &data[i * d..(i + 1) * d] == c).expect("row");
                assert!(seen.insert(i), "duplicate row {i}");
            }
        });
    }
}
