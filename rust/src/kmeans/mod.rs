//! K-means engines: the weighted Lloyd core (paper Alg. 1 steps 2/4, used
//! by BWKM and RPKM), plain Lloyd over a dataset, the seeding algorithms
//! (Forgy, K-means++, AFK-MC²) and Mini-batch K-means — every baseline of
//! the paper's §3 — all with exact distance accounting.

pub mod elkan;
pub mod init;
pub mod lloyd;
pub mod minibatch;
pub mod pruning;
pub mod weighted_lloyd;

pub use elkan::{elkan_weighted_lloyd, ElkanOutcome};
pub use lloyd::{lloyd, LloydCfg, LloydOutcome};
pub use minibatch::{minibatch_kmeans, MiniBatchCfg};
pub use weighted_lloyd::{
    weighted_lloyd, weighted_lloyd_with, NativeStepper, StepOut, Stepper, WLloydCfg,
    WLloydOutcome,
};

/// Output of any end-to-end clustering method, as the bench harness
/// consumes it.
#[derive(Clone, Debug)]
pub struct KmResult {
    /// Flat k×d centroid matrix.
    pub centroids: Vec<f64>,
    pub k: usize,
    pub d: usize,
    /// Iterations of the method's own outer loop.
    pub iters: usize,
}
