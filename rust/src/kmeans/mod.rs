//! K-means engines: the unified assignment engine ([`assign`], DESIGN.md
//! §2 — the one nearest/top-2 distance hot path every method shares), the
//! weighted Lloyd outer loop (paper Alg. 1 steps 2/4, used by BWKM and
//! RPKM), plain Lloyd over a dataset, the seeding algorithms (Forgy,
//! K-means++, AFK-MC²) and Mini-batch K-means — every baseline of the
//! paper's §3 — all with exact distance accounting.
//!
//! Layering (DESIGN.md §1/§2): [`assign`] owns the distance kernel and its
//! counting/tie-breaking/determinism contract; [`weighted_lloyd`] owns the
//! iteration and stopping logic over any [`Stepper`]; [`elkan`] and
//! [`pruning`] are the exact accelerated variants (they count only what
//! they compute); [`lloyd`] and [`minibatch`] are the full-dataset
//! baselines of the paper's evaluation.

pub mod assign;
pub mod elkan;
pub mod init;
pub mod lloyd;
pub mod minibatch;
pub mod pruning;
pub mod weighted_lloyd;

pub use assign::{
    AssignCfg, AssignMode, Assigner, AssignOut, AutoAssigner, AutoChoice, BoundedAssigner,
    BoundedStats, ChoiceCounts, ClosureAssigner, ClosureStats, GenCache, KernelKind,
    NormPrunedAssigner, Precision, SerialAssigner, Sharded, ShardedAssigner, VectorAssigner,
};
pub use init::{KmeansParSeeder, ParCfg, SeedMethod, SeedPolicy, Seeder};
pub use elkan::{elkan_weighted_lloyd, ElkanOutcome};
pub use lloyd::{lloyd, LloydCfg, LloydOutcome};
pub use minibatch::{minibatch_kmeans, MiniBatchCfg};
pub use weighted_lloyd::{
    stepper_for, weighted_lloyd, weighted_lloyd_with, EngineStepper, NativeStepper, SampleStats,
    SampledStepper, StepOut, Stepper, WLloydCfg, WLloydOutcome,
};

/// Output of any end-to-end clustering method, as the bench harness
/// consumes it.
#[derive(Clone, Debug)]
pub struct KmResult {
    /// Flat k×d centroid matrix.
    pub centroids: Vec<f64>,
    pub k: usize,
    pub d: usize,
    /// Iterations of the method's own outer loop.
    pub iters: usize,
}
