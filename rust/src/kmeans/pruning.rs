//! Hamerly-bound distance pruning inside weighted Lloyd ([15], and the
//! integration the paper's §4 proposes as future work: "BWKM is also
//! compatible with the distance pruning techniques ... within the weighted
//! Lloyd framework").
//!
//! Exact algorithm (identical fixed point to the plain stepper): per
//! representative we keep an upper bound `u` on the distance to its
//! assigned centroid and a lower bound `l` on the distance to the rest;
//! a representative is scanned against all centroids only when
//! `u > max(l, s[a])`, where `s[c]` is half the distance from `c` to its
//! nearest other centroid. Only *actually computed* distances are counted,
//! which is the whole point of the ablation (`benches/ablation_pruning`).

use crate::geometry::{dist, sq_dist};
use crate::metrics::DistanceCounter;

/// Outcome of a pruned weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct PrunedOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    pub iters: usize,
    /// Distances a plain (unpruned) run of the same iterations would have
    /// computed — for the ablation report.
    pub unpruned_equiv: u64,
}

/// Run weighted Lloyd with Hamerly pruning until the assignment is stable
/// (fixed point) or `max_iters`.
pub fn pruned_weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    max_iters: usize,
    counter: &DistanceCounter,
) -> PrunedOutcome {
    let m = weights.len();
    let k = init.len() / d;
    let mut centroids = init.to_vec();

    let mut assign = vec![u32::MAX; m];
    let mut upper = vec![f64::INFINITY; m];
    let mut lower = vec![0.0f64; m];

    // Weighted cluster aggregates, maintained incrementally on reassignment.
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];

    let mut s_half = vec![0.0f64; k];
    let mut drift = vec![0.0f64; k];
    let mut iters = 0usize;

    for _ in 0..max_iters {
        iters += 1;

        // s[c] = ½ min_{c'≠c} ‖c−c'‖ : k(k−1)/2 distances.
        for c in 0..k {
            s_half[c] = f64::INFINITY;
        }
        for a in 0..k {
            for b in a + 1..k {
                let dd = dist(&centroids[a * d..(a + 1) * d], &centroids[b * d..(b + 1) * d]);
                if dd < s_half[a] {
                    s_half[a] = dd;
                }
                if dd < s_half[b] {
                    s_half[b] = dd;
                }
            }
        }
        counter.add((k * (k - 1) / 2) as u64);
        for c in 0..k {
            s_half[c] *= 0.5;
        }

        let mut changed = 0usize;
        for i in 0..m {
            let p = &reps[i * d..(i + 1) * d];
            let a = assign[i];
            if a != u32::MAX {
                let z = lower[i].max(s_half[a as usize]);
                if upper[i] <= z {
                    continue; // pruned: assignment provably unchanged
                }
                // Tighten the upper bound with one distance.
                upper[i] = dist(p, &centroids[a as usize * d..(a as usize + 1) * d]);
                counter.add(1);
                if upper[i] <= z {
                    continue;
                }
            }
            // Full scan: top-2 over all centroids.
            let (mut i1, mut b1, mut b2) = (0usize, f64::INFINITY, f64::INFINITY);
            for c in 0..k {
                let dd = sq_dist(p, &centroids[c * d..(c + 1) * d]);
                if dd < b1 {
                    b2 = b1;
                    b1 = dd;
                    i1 = c;
                } else if dd < b2 {
                    b2 = dd;
                }
            }
            counter.add(k as u64);
            upper[i] = b1.sqrt();
            lower[i] = b2.sqrt();
            if assign[i] != i1 as u32 {
                let w = weights[i];
                if assign[i] != u32::MAX {
                    let old = assign[i] as usize;
                    counts[old] -= w;
                    for j in 0..d {
                        sums[old * d + j] -= w * p[j];
                    }
                }
                counts[i1] += w;
                for j in 0..d {
                    sums[i1 * d + j] += w * p[j];
                }
                assign[i] = i1 as u32;
                changed += 1;
            }
        }

        if changed == 0 && iters > 1 {
            break;
        }

        // Update step + per-centroid drift (k "distances" for the drifts).
        let mut max_drift = 0.0f64;
        for c in 0..k {
            let old = centroids[c * d..(c + 1) * d].to_vec();
            if counts[c] > 0.0 {
                let inv = 1.0 / counts[c];
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] * inv;
                }
            }
            drift[c] = dist(&old, &centroids[c * d..(c + 1) * d]);
            max_drift = max_drift.max(drift[c]);
        }
        counter.add(k as u64);
        if max_drift == 0.0 {
            break;
        }
        for i in 0..m {
            upper[i] += drift[assign[i] as usize];
            lower[i] = (lower[i] - max_drift).max(0.0);
        }
    }

    PrunedOutcome {
        centroids,
        assign,
        iters,
        unpruned_equiv: (iters as u64) * (m as u64) * (k as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::weighted_lloyd::{weighted_lloyd, WLloydCfg};
    use crate::util::prop;

    #[test]
    fn prop_matches_plain_weighted_lloyd() {
        prop::check("pruned-equals-plain", 25, |g| {
            let m = g.int(5, 150);
            let d = g.int(1, 5);
            let k = g.int(2, 6).min(m);
            let reps = g.blobs(m, d, k, 0.8);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();

            let c1 = DistanceCounter::new();
            let plain = weighted_lloyd(
                &reps,
                &weights,
                d,
                &init,
                &WLloydCfg { max_iters: 200, tol: 0.0, ..Default::default() },
                &c1,
            );
            let c2 = DistanceCounter::new();
            let pruned = pruned_weighted_lloyd(&reps, &weights, d, &init, 200, &c2);

            // Same fixed point (allowing fp noise of different accumulation
            // orders).
            for (a, b) in plain.centroids.iter().zip(&pruned.centroids) {
                assert!((a - b).abs() < 1e-6, "centroid mismatch {a} vs {b}");
            }
        });
    }

    #[test]
    fn prunes_on_separated_clusters() {
        // Well-separated blobs: pruning should save a large fraction of
        // distances relative to the unpruned equivalent.
        let mut g = crate::util::prop::Gen { rng: crate::util::Rng::new(77), case: 0 };
        let reps = g.blobs(3000, 3, 8, 0.2);
        let weights = vec![1.0; 3000];
        let init: Vec<f64> = reps[..8 * 3].to_vec();
        let c = DistanceCounter::new();
        let out = pruned_weighted_lloyd(&reps, &weights, 3, &init, 100, &c);
        assert!(
            c.get() < out.unpruned_equiv / 2,
            "computed {} vs unpruned {}",
            c.get(),
            out.unpruned_equiv
        );
    }

    #[test]
    fn single_cluster_degenerate() {
        let reps = [0.0, 1.0, 2.0];
        let weights = [1.0; 3];
        let init = [5.0];
        let c = DistanceCounter::new();
        let out = pruned_weighted_lloyd(&reps, &weights, 1, &init, 50, &c);
        assert!((out.centroids[0] - 1.0).abs() < 1e-12);
    }
}
