//! Elkan-style exact accelerated weighted Lloyd ([13], the second pruning
//! technique the paper's §4 names) — since the engine port, a thin outer
//! loop over the shared [`BoundedAssigner`] backend (DESIGN.md §2.7).
//!
//! The private bound bookkeeping this module used to carry — per-point
//! upper bounds, an m×k lower-bound matrix, drift maintenance, the
//! triangle-inequality filters — now lives in the assignment engine,
//! where *every* algorithm inherits it. What remains here is only the
//! fixed-point iteration: step until the assignment stabilizes. Each step
//! is **bit-identical** to the plain stepper's (a stronger guarantee than
//! the retired implementation's "same fixed point"), and the counter is
//! charged exactly what the bounds fail to prune (DESIGN.md §2.4): m·k on
//! the priming pass, then k drift distances plus the evaluated pairs per
//! warm iteration.

use crate::metrics::DistanceCounter;

use super::assign::{weighted_step_with, BoundedAssigner, StepScratch};

/// Outcome of an Elkan-accelerated weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct ElkanOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    pub iters: usize,
    /// m·k·iters — what the unpruned run would have computed.
    pub unpruned_equiv: u64,
}

/// Weighted Lloyd with cross-iteration bounds until assignment stability.
///
/// Runs [`weighted_step_with`] on a [`BoundedAssigner`] until two
/// consecutive iterations produce the same assignment (at which point the
/// centroids are a fixed point of weighted Lloyd: the update recomputes
/// the same means) or `max_iters` is reached.
pub fn elkan_weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    max_iters: usize,
    counter: &DistanceCounter,
) -> ElkanOutcome {
    let m = weights.len();
    let k = init.len() / d;
    let mut engine = BoundedAssigner::new();
    let mut scratch = StepScratch::default();
    let mut centroids = init.to_vec();
    let mut assign: Vec<u32> = Vec::new();
    let mut iters = 0usize;
    // Distinguishes "no previous assignment yet" from a genuinely empty
    // representative set, so m = 0 still stabilizes after two passes.
    let mut primed = false;

    while iters < max_iters {
        let step =
            weighted_step_with(&mut engine, &mut scratch, reps, weights, d, &centroids, counter);
        iters += 1;
        let stable = primed && assign == step.assign;
        primed = true;
        assign = step.assign;
        centroids = step.centroids;
        if stable {
            break;
        }
    }

    ElkanOutcome {
        centroids,
        assign,
        iters,
        unpruned_equiv: (iters as u64) * (m as u64) * (k as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::pruning::pruned_weighted_lloyd;
    use crate::kmeans::weighted_lloyd::{weighted_lloyd, WLloydCfg};
    use crate::util::prop;

    #[test]
    fn prop_elkan_matches_plain() {
        prop::check("elkan-equals-plain", 25, |g| {
            let m = g.int(5, 140);
            let d = g.int(1, 5);
            let k = g.int(2, 6).min(m);
            let reps = g.blobs(m, d, k, 0.8);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();

            let c1 = DistanceCounter::new();
            let plain = weighted_lloyd(
                &reps,
                &weights,
                d,
                &init,
                &WLloydCfg { max_iters: 200, tol: 0.0, ..Default::default() },
                &c1,
            );
            let c2 = DistanceCounter::new();
            let elkan = elkan_weighted_lloyd(&reps, &weights, d, &init, 200, &c2);
            for (a, b) in plain.centroids.iter().zip(&elkan.centroids) {
                assert!((a - b).abs() < 1e-6, "fixed points differ: {a} vs {b}");
            }
            // Bounded steps are bit-identical to plain steps; beyond the
            // unpruned pair bill the run may only charge its documented
            // bookkeeping — k drift distances per warm iteration (at k=2 a
            // warm step evaluates both candidates, so pruning can be
            // exactly zero and the bookkeeping is the whole overhead).
            let bookkeeping = (elkan.iters as u64) * (k as u64);
            assert!(
                c2.get() <= elkan.unpruned_equiv + bookkeeping,
                "{} > {} + {bookkeeping}",
                c2.get(),
                elkan.unpruned_equiv
            );
        });
    }

    #[test]
    fn elkan_warm_iterations_prune_hard_on_many_clusters() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(88), case: 0 };
        let m = 4000usize;
        let k = 16usize;
        let reps = g.blobs(m, 3, k, 0.15);
        let weights = vec![1.0; m];
        let init: Vec<f64> = reps[..k * 3].to_vec();
        let ce = DistanceCounter::new();
        let e = elkan_weighted_lloyd(&reps, &weights, 3, &init, 100, &ce);
        let ch = DistanceCounter::new();
        let h = pruned_weighted_lloyd(&reps, &weights, 3, &init, 100, &ch);
        // The priming pass pays the full m·k; across the warm iterations
        // the bounds must prune at least half the bill on well-separated
        // clusters (early iterations still carry large drifts; late ones
        // collapse to ~2 pairs per point).
        let bill = (m * k) as u64;
        assert!(e.iters >= 1);
        let warm = ce.get().saturating_sub(bill);
        assert!(
            warm <= (e.iters as u64 - 1) * bill / 2,
            "warm iterations computed {warm} of {} possible",
            (e.iters as u64 - 1) * bill
        );
        // And both accelerated runs beat their unpruned equivalents.
        assert!(ce.get() < e.unpruned_equiv || e.iters == 1);
        assert!(ch.get() < h.unpruned_equiv, "hamerly did not prune at all");
    }

    #[test]
    fn single_centroid_degenerate() {
        let reps = [0.0, 2.0, 4.0];
        let weights = [1.0, 1.0, 2.0];
        let c = DistanceCounter::new();
        let out = elkan_weighted_lloyd(&reps, &weights, 1, &[9.0], 50, &c);
        assert!((out.centroids[0] - 2.5).abs() < 1e-12);
    }
}
