//! Elkan's exact accelerated Lloyd ([13], the second pruning technique the
//! paper's §4 names): k per-point lower bounds (one per centroid) plus an
//! upper bound, and the triangle-inequality filter
//! d(c, c') ≥ 2·d(x, c) ⇒ d(x, c') ≥ d(x, c).
//!
//! Stronger pruning than Hamerly at O(m·k) bound memory (Hamerly keeps 2
//! bounds — see [`super::pruning`]); both reach the same fixed point as the
//! plain stepper and count only the distances they actually compute
//! (DESIGN.md §2.4). The exact first pass — the *fallback path* that
//! initializes every bound with a full distance row — runs through the
//! shared assignment engine's `sq_dist_row` (see DESIGN.md §2.6), since
//! it is the one place Elkan needs all k distances rather than the top 2.
//! Every point↔centroid distance — the first pass *and* the in-loop
//! tighten/reassign computations — goes through the engine's canonical
//! kernel, so the cached bounds are always consistent with the distances
//! they are later compared against; `geometry::dist` remains only for the
//! centroid↔centroid bookkeeping (drifts, s(c)).

use crate::geometry::dist;
use crate::metrics::DistanceCounter;

use super::assign::{dist_kernel, sq_dist_row};

/// Outcome of an Elkan-accelerated weighted-Lloyd run.
#[derive(Clone, Debug)]
pub struct ElkanOutcome {
    pub centroids: Vec<f64>,
    pub assign: Vec<u32>,
    pub iters: usize,
    /// m·k·iters — what the unpruned run would have computed.
    pub unpruned_equiv: u64,
}

/// Weighted Lloyd with Elkan's bounds until assignment stability.
pub fn elkan_weighted_lloyd(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    init: &[f64],
    max_iters: usize,
    counter: &DistanceCounter,
) -> ElkanOutcome {
    let m = weights.len();
    let k = init.len() / d;
    let mut centroids = init.to_vec();

    let mut assign = vec![0u32; m];
    let mut upper = vec![f64::INFINITY; m];
    let mut lower = vec![0.0f64; m * k];
    let mut upper_stale = vec![true; m];

    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];

    // First pass (the exact fallback): full distance rows through the
    // engine, then bounds from their square roots. argmin over squared
    // distances equals argmin over metric distances (sqrt is monotone),
    // and the engine counts the same k per representative.
    let mut row = vec![0.0f64; k];
    for i in 0..m {
        let p = &reps[i * d..(i + 1) * d];
        let (i1, b1_sq) = sq_dist_row(p, centroids.as_slice(), d, &mut row, counter);
        for c in 0..k {
            lower[i * k + c] = row[c].sqrt();
        }
        assign[i] = i1 as u32;
        upper[i] = b1_sq.sqrt();
        upper_stale[i] = false;
        let w = weights[i];
        counts[i1] += w;
        for j in 0..d {
            sums[i1 * d + j] += w * p[j];
        }
    }

    let mut cc = vec![0.0f64; k * k]; // inter-centroid distances
    let mut s_half = vec![0.0f64; k];
    let mut drift = vec![0.0f64; k];
    let mut iters = 1usize;

    loop {
        // Update step + drifts.
        let mut max_drift = 0.0f64;
        for c in 0..k {
            let old = centroids[c * d..(c + 1) * d].to_vec();
            if counts[c] > 0.0 {
                let inv = 1.0 / counts[c];
                for j in 0..d {
                    centroids[c * d + j] = sums[c * d + j] * inv;
                }
            }
            drift[c] = dist(&old, &centroids[c * d..(c + 1) * d]);
            max_drift = max_drift.max(drift[c]);
        }
        counter.add(k as u64);
        // Bound maintenance.
        for i in 0..m {
            upper[i] += drift[assign[i] as usize];
            upper_stale[i] = true;
            for c in 0..k {
                lower[i * k + c] = (lower[i * k + c] - drift[c]).max(0.0);
            }
        }
        if max_drift == 0.0 || iters >= max_iters {
            break;
        }
        iters += 1;

        // Inter-centroid distances and s(c) = ½ min_{c'≠c} d(c, c').
        for c in 0..k {
            s_half[c] = f64::INFINITY;
        }
        for a in 0..k {
            for b in a + 1..k {
                let dd = dist(&centroids[a * d..(a + 1) * d], &centroids[b * d..(b + 1) * d]);
                cc[a * k + b] = dd;
                cc[b * k + a] = dd;
                if dd < s_half[a] {
                    s_half[a] = dd;
                }
                if dd < s_half[b] {
                    s_half[b] = dd;
                }
            }
        }
        counter.add((k * (k - 1) / 2) as u64);
        for c in 0..k {
            s_half[c] *= 0.5;
        }

        let mut changed = 0usize;
        for i in 0..m {
            let mut cur = assign[i] as usize; // current assignment (updated in-loop)
            if upper[i] <= s_half[cur] {
                continue; // Elkan step 2: nothing can be closer.
            }
            let p = &reps[i * d..(i + 1) * d];
            for c in 0..k {
                if c == cur {
                    continue;
                }
                // Elkan step 3 filters (against the *current* center).
                let z = lower[i * k + c].max(0.5 * cc[cur * k + c]);
                if upper[i] <= z {
                    continue;
                }
                // Tighten the upper bound once per point per iteration.
                if upper_stale[i] {
                    let du = dist_kernel(p, &centroids[cur * d..(cur + 1) * d]);
                    counter.add(1);
                    upper[i] = du;
                    lower[i * k + cur] = du;
                    upper_stale[i] = false;
                    if upper[i] <= z {
                        continue;
                    }
                }
                let dc = dist_kernel(p, &centroids[c * d..(c + 1) * d]);
                counter.add(1);
                lower[i * k + c] = dc;
                if dc < upper[i] {
                    // Reassign i: cur -> c.
                    let w = weights[i];
                    counts[cur] -= w;
                    counts[c] += w;
                    for j in 0..d {
                        sums[cur * d + j] -= w * p[j];
                        sums[c * d + j] += w * p[j];
                    }
                    assign[i] = c as u32;
                    cur = c;
                    upper[i] = dc;
                    upper_stale[i] = false;
                    changed += 1;
                }
            }
        }
        if changed == 0 {
            break;
        }
    }

    ElkanOutcome {
        centroids,
        assign,
        iters,
        unpruned_equiv: (iters as u64) * (m as u64) * (k as u64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::pruning::pruned_weighted_lloyd;
    use crate::kmeans::weighted_lloyd::{weighted_lloyd, WLloydCfg};
    use crate::util::prop;

    #[test]
    fn prop_elkan_matches_plain() {
        prop::check("elkan-equals-plain", 25, |g| {
            let m = g.int(5, 140);
            let d = g.int(1, 5);
            let k = g.int(2, 6).min(m);
            let reps = g.blobs(m, d, k, 0.8);
            let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
            let init: Vec<f64> = reps[..k * d].to_vec();

            let c1 = DistanceCounter::new();
            let plain = weighted_lloyd(
                &reps,
                &weights,
                d,
                &init,
                &WLloydCfg { max_iters: 200, tol: 0.0, ..Default::default() },
                &c1,
            );
            let c2 = DistanceCounter::new();
            let elkan = elkan_weighted_lloyd(&reps, &weights, d, &init, 200, &c2);
            for (a, b) in plain.centroids.iter().zip(&elkan.centroids) {
                assert!((a - b).abs() < 1e-6, "fixed points differ: {a} vs {b}");
            }
        });
    }

    #[test]
    fn elkan_prunes_at_least_as_hard_as_hamerly_on_many_clusters() {
        let mut g = prop::Gen { rng: crate::util::Rng::new(88), case: 0 };
        let reps = g.blobs(4000, 3, 16, 0.15);
        let weights = vec![1.0; 4000];
        let init: Vec<f64> = reps[..16 * 3].to_vec();
        let ce = DistanceCounter::new();
        let e = elkan_weighted_lloyd(&reps, &weights, 3, &init, 100, &ce);
        let ch = DistanceCounter::new();
        let _h = pruned_weighted_lloyd(&reps, &weights, 3, &init, 100, &ch);
        // Elkan's per-centroid bounds usually dominate on many clusters;
        // at minimum both must beat the unpruned count substantially.
        assert!(ce.get() < e.unpruned_equiv / 2, "elkan {} vs {}", ce.get(), e.unpruned_equiv);
        assert!(ch.get() < e.unpruned_equiv, "hamerly did not prune at all");
    }

    #[test]
    fn single_centroid_degenerate() {
        let reps = [0.0, 2.0, 4.0];
        let weights = [1.0, 1.0, 2.0];
        let c = DistanceCounter::new();
        let out = elkan_weighted_lloyd(&reps, &weights, 1, &[9.0], 50, &c);
        assert!((out.centroids[0] - 2.5).abs() < 1e-12);
    }
}
