//! The `bwkm` launcher CLI (hand-rolled arg parsing; DESIGN.md §4).
//!
//! ```text
//! bwkm info
//! bwkm run [--config FILE] [key=value ...]
//! bwkm figure <CIF|3RN|GS|SUSY|WUY> [key=value ...]
//! bwkm quickstart
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bench::figures::{emit, run_figure, FigureCfg};
use crate::config::{Method, RunConfig};
use crate::data::{simulate, Dataset, TABLE1};
use crate::kmeans::init::{forgy, kmc2, kmeanspp, Kmc2Cfg};
use crate::kmeans::{lloyd, minibatch_kmeans, LloydCfg, MiniBatchCfg};
use crate::metrics::{kmeans_error, DistanceCounter};
use crate::rpkm::{grid_rpkm, RpkmCfg};
use crate::util::{fmt_count, Rng};

const USAGE: &str = "\
bwkm — Boundary Weighted K-means (Capó, Pérez, Lozano 2018) reproduction

USAGE:
  bwkm info                         dataset table, artifact manifest
  bwkm quickstart                   tiny end-to-end demo
  bwkm run [--config F] [k=v ...]   one clustering run (see config::RunConfig)
  bwkm figure <NAME> [k=v ...]      regenerate a paper figure (CIF 3RN GS SUSY WUY)

RUN KEYS: dataset scale seed k method budget threads use_pjrt eval_full_error
          chunk_rows m m_prime s r max_outer
          init oversample_l init_rounds chain_length
          assign closure_expand sample_rows sample_seed
          kernel precision
          (method: bwkm fkm kmpp kmpp_init kmc2 mbN rpkm)
          (assign: exact closure sampled — the §2.9 assignment regime for
           bwkm/rpkm; closure scans closure_expand+1 candidate centroids
           per point, sampled runs each step on sample_rows rows seeded
           by sample_seed; approximate runs print their measured gap[..]
           note and still pay an exactly-accounted bill)
          (kernel: scalar simd auto / precision: f64 f32 — the §2.10 exact
           engine selection for bwkm/rpkm, assign=exact only; f64 output is
           bit-identical for every kernel, f32 is the opt-in mixed-precision
           mode — f32 storage, f64 accumulate — with a documented tolerance
           contract; the distance bill is identical either way)
          (init: forgy pp kmc2 par — the BWKM/RPKM seeding policy over
           partition representatives, DESIGN.md §2.8; par is K-means||
           with init_rounds rounds and oversampling l = oversample_l,
           0 = auto 2k)
          (dataset: a Table-1 name, path:FILE to load into memory, or
           stream:FILE.bin to cluster out of core — method=bwkm only,
           bit-identical to the in-memory run on the same data/seed;
           the per-iteration E^D trace costs one pass per iteration out
           of core, so it is opt-in there: eval_full_error=on)
";

/// Entry point used by `src/main.rs`.
pub fn main(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("quickstart") => quickstart(),
        Some("run") => run(&args[1..]),
        Some("figure") => figure(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn info() -> Result<()> {
    println!("Table 1 datasets (simulated; see DESIGN.md §4):");
    println!("{:<6} {:>12} {:>4}", "name", "paper n", "d");
    for s in TABLE1 {
        println!("{:<6} {:>12} {:>4}", s.name, fmt_count(s.paper_n as u64), s.d);
    }
    let dir = crate::runtime::Runtime::default_dir();
    match crate::runtime::Manifest::load(&dir.join("manifest.tsv")) {
        Ok(m) => {
            println!("\nAOT artifacts at {} ({} variants):", dir.display(), m.variants.len());
            for v in &m.variants {
                println!(
                    "  {:<12} mcap={:<6} kcap={:<3} dcap={:<3} {}",
                    v.program, v.mcap, v.kcap, v.dcap, v.file
                );
            }
        }
        Err(e) => println!("\nno artifacts found at {} ({e}); run `make artifacts`", dir.display()),
    }
    Ok(())
}

fn quickstart() -> Result<()> {
    let ds = simulate("WUY", 0.0005, 42).context("simulate")?;
    let counter = DistanceCounter::new();
    let mut cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 9);
    cfg.eval_full_error = true;
    let out = crate::bwkm::run(&ds, 9, &cfg, &mut Rng::new(7), &counter);
    let last = out.trace.last().unwrap();
    println!(
        "BWKM on simulated WUY (n={}, d={}): E^D={:.4e} after {} distances ({:?})",
        ds.n,
        ds.d,
        last.full_error.unwrap(),
        fmt_count(counter.get()),
        out.stop
    );
    Ok(())
}

fn parse_overrides(cfg: &mut RunConfig, args: &[String]) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).context("--config needs a path")?;
            *cfg = RunConfig::from_file(Path::new(path))?;
            i += 2;
            continue;
        }
        let (k, v) = args[i]
            .split_once('=')
            .with_context(|| format!("expected key=value, got `{}`", args[i]))?;
        cfg.set(k, v)?;
        i += 1;
    }
    Ok(())
}

fn load_dataset(cfg: &RunConfig) -> Result<Dataset> {
    if let Some(path) = cfg.dataset.strip_prefix("path:") {
        let p = Path::new(path);
        if path.ends_with(".bin") {
            crate::data::loader::load_bin(p)
        } else {
            crate::data::loader::load_csv(p, None)
        }
    } else {
        simulate(&cfg.dataset, cfg.scale, cfg.seed)
            .with_context(|| format!("unknown dataset `{}`", cfg.dataset))
    }
}

/// One line per outer BWKM iteration — shared by the in-memory and
/// streaming runs so the two can never drift apart in layout.
fn print_trace(trace: &[crate::bwkm::TracePoint]) {
    for t in trace {
        println!(
            "  outer={:<3} dists={:>14} |B|={:<6} boundary={:<6} E^P={:.5e}{}",
            t.outer_iter,
            fmt_count(t.distances),
            t.blocks,
            t.boundary,
            t.weighted_error,
            t.full_error.map(|e| format!(" E^D={e:.5e}")).unwrap_or_default()
        );
    }
}

/// Out-of-core run: the full BWKM loop against a `stream:` binary file,
/// never materializing the dataset (DESIGN.md §5.1). Bit-identical to
/// `run` on the same data and seed.
fn run_streaming(cfg: &RunConfig, path: &str) -> Result<()> {
    use crate::coordinator::{stream_assign_err, StreamingBwkm};
    use crate::data::loader::BinChunks;

    if cfg.method != Method::Bwkm {
        bail!("stream: datasets support method=bwkm only (got {})", cfg.method.name());
    }
    if cfg.use_pjrt {
        bail!("stream: datasets do not support use_pjrt yet");
    }
    let p = Path::new(path);
    let probe = BinChunks::open(p, cfg.chunk_rows)?; // header + truncation check
    let (n, d) = (probe.n, probe.d);
    drop(probe);
    println!(
        "run: dataset=stream:{path} n={n} d={d} k={} method=BWKM chunk_rows={} threads={}",
        cfg.k, cfg.chunk_rows, cfg.threads
    );
    let mut bcfg = cfg.bwkm_cfg(n, d)?;
    if !cfg.eval_full_error_explicit {
        // Out of core every trace evaluation is one full pass over the
        // source; keep the E^D trace opt-in here (eval_full_error=on).
        bcfg.eval_full_error = false;
    }
    let counter = DistanceCounter::new();
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut coordinator =
        StreamingBwkm::new(BinChunks::opener(p, cfg.chunk_rows), d).with_threads(cfg.threads);
    let out = coordinator.run(cfg.k, &bcfg, &mut rng, &counter)?;
    print_trace(&out.trace);
    // Final E^D by one more streamed scoring pass (its own counter).
    let eval = DistanceCounter::new();
    let (rows, sse) =
        stream_assign_err(d, &out.centroids, BinChunks::open(p, cfg.chunk_rows)?, &eval)?;
    if rows != n {
        bail!("source changed during the run: scoring pass saw {rows} rows, expected {n}");
    }
    // Approximate runs self-report their measured quality gap (§2.9).
    for note in counter.notes().iter().filter(|note| note.starts_with("gap[")) {
        println!("  {note}");
    }
    println!(
        "result: E^D={sse:.6e} distances={} passes={} wall={:.2?} (stop={:?} init={})",
        fmt_count(counter.get()),
        out.passes,
        t0.elapsed(),
        out.stop,
        bcfg.seed.method.name()
    );
    Ok(())
}

fn run(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    parse_overrides(&mut cfg, args)?;
    if let Some(path) = cfg.dataset.strip_prefix("stream:") {
        let path = path.to_string();
        return run_streaming(&cfg, &path);
    }
    let ds = load_dataset(&cfg)?;
    if !ds.is_finite() {
        bail!("dataset contains non-finite values");
    }
    println!(
        "run: dataset={} n={} d={} k={} method={} threads={}",
        cfg.dataset,
        ds.n,
        ds.d,
        cfg.k,
        cfg.method.name(),
        cfg.threads
    );
    let counter = DistanceCounter::new();
    let eval = DistanceCounter::new();
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let (centroids, note) = match &cfg.method {
        Method::Bwkm => {
            let bcfg = cfg.bwkm_cfg(ds.n, ds.d)?;
            let approx = bcfg.assign.mode != crate::kmeans::AssignMode::Exact;
            if cfg.use_pjrt && approx {
                bail!("use_pjrt supports assign=exact only (the device step is exact)");
            }
            if cfg.use_pjrt
                && (bcfg.assign.kernel != crate::kmeans::KernelKind::Scalar
                    || bcfg.assign.precision != crate::kmeans::Precision::F64)
            {
                // Never silently ignore a §2.10 selection: the device step
                // has its own kernel (DESIGN.md §8), not the native one.
                bail!("use_pjrt supports the default kernel/precision only (drop the keys)");
            }
            let out = if approx {
                // Approximate regimes run their own (serial) stepper —
                // closures / sampled steps carry state across steps.
                let mut stepper = crate::kmeans::stepper_for(&bcfg.assign);
                crate::bwkm::run_with(stepper.as_mut(), &ds, cfg.k, &bcfg, &mut rng, &counter)
            } else if cfg.use_pjrt {
                let rt = crate::runtime::Runtime::open_default()?;
                let mut stepper = crate::runtime::PjrtStepper::new(rt);
                let o = crate::bwkm::run_with(&mut stepper, &ds, cfg.k, &bcfg, &mut rng, &counter);
                println!(
                    "pjrt: {} device steps, {} native-fallback steps",
                    stepper.device_steps, stepper.fallback_steps
                );
                o
            } else if cfg.threads > 1 {
                // Honors the §2.10 kernel/precision selection per worker.
                let mut stepper =
                    crate::coordinator::sharded_stepper_for(&bcfg.assign, cfg.threads);
                crate::bwkm::run_with(stepper.as_mut(), &ds, cfg.k, &bcfg, &mut rng, &counter)
            } else {
                crate::bwkm::run(&ds, cfg.k, &bcfg, &mut rng, &counter)
            };
            print_trace(&out.trace);
            let stop = out.stop;
            (out.centroids, format!("stop={stop:?} init={}", bcfg.seed.method.name()))
        }
        Method::Fkm => {
            let init = forgy(&ds.data, ds.d, cfg.k, &mut rng);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::Kmpp => {
            let init = kmeanspp(&ds.data, ds.d, cfg.k, &mut rng, &counter);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::KmppInit => {
            let init = kmeanspp(&ds.data, ds.d, cfg.k, &mut rng, &counter);
            (init, "init only".into())
        }
        Method::Kmc2 => {
            let init = kmc2(&ds.data, ds.d, cfg.k, &Kmc2Cfg::default(), &mut rng, &counter);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::MiniBatch(b) => {
            let mcfg = MiniBatchCfg { batch: *b, budget: cfg.budget(), ..Default::default() };
            let r = minibatch_kmeans(&ds.data, ds.d, cfg.k, &mcfg, &mut rng, &counter);
            (r.centroids, format!("iters={}", r.iters))
        }
        Method::Rpkm => {
            let rcfg = RpkmCfg {
                budget: cfg.budget(),
                seed: cfg.seed_policy(crate::kmeans::init::SeedMethod::Forgy)?,
                assign: cfg.assign_cfg()?,
                ..Default::default()
            };
            let out = grid_rpkm(&ds, cfg.k, &rcfg, &mut rng, &counter);
            (out.centroids, format!("levels={}", out.trace.len()))
        }
    };
    let err = if cfg.threads > 1 {
        crate::coordinator::sharded_assign_err(&ds, &centroids, cfg.threads, &eval).1
    } else {
        kmeans_error(&ds.data, ds.d, &centroids, &eval)
    };
    // Approximate runs self-report their measured quality gap (§2.9).
    for n in counter.notes().iter().filter(|n| n.starts_with("gap[")) {
        println!("  {n}");
    }
    println!(
        "result: E^D={err:.6e} distances={} wall={:.2?} ({note})",
        fmt_count(counter.get()),
        t0.elapsed()
    );
    Ok(())
}

fn figure(args: &[String]) -> Result<()> {
    let name = args.first().context("figure needs a dataset name")?.to_uppercase();
    let base = match name.as_str() {
        "CIF" => 0.3,
        "3RN" => 0.05,
        "GS" => 0.005,
        "SUSY" => 0.004,
        "WUY" => 0.0005,
        _ => bail!("unknown figure dataset `{name}`"),
    };
    let mut cfg = FigureCfg::for_dataset(&name, base);
    for arg in &args[1..] {
        let (k, v) = arg.split_once('=').context("expected key=value")?;
        match k {
            "scale" => cfg.scale = v.parse()?,
            "reps" => cfg.reps = v.parse()?,
            "ks" => cfg.ks = v.split(';').map(|x| x.parse()).collect::<Result<_, _>>()?,
            "seed" => cfg.seed = v.parse()?,
            _ => bail!("unknown figure key `{k}`"),
        }
    }
    let res = run_figure(&cfg);
    emit(&res, &format!("fig_{}", name.to_lowercase()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_paths() {
        assert!(main(&[]).is_ok());
        assert!(main(&["help".into()]).is_ok());
        assert!(main(&["definitely-not-a-command".into()]).is_err());
    }

    #[test]
    fn quickstart_runs() {
        quickstart().unwrap();
    }

    #[test]
    fn run_with_overrides() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.003".into(),
            "k=3".into(),
            "method=mb100".into(),
            "seed=1".into(),
        ])
        .unwrap();
    }

    #[test]
    fn run_bwkm_with_par_init_policy() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "init=par".into(),
            "init_rounds=2".into(),
            "oversample_l=6".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // RPKM honors the policy keys too.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "init=pp".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // A bad init value is a clean error.
        assert!(run(&["dataset=3RN".into(), "scale=0.002".into(), "init=quantum".into()]).is_err());
    }

    #[test]
    fn run_approximate_assign_modes() {
        // BWKM with closure candidates.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "closure_expand=2".into(),
            "max_outer=3".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // RPKM with sampled steps.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "assign=sampled".into(),
            "sample_rows=64".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // Validation surfaces as clean errors.
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=sampled".into(), // sample_rows missing
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "use_pjrt=on".into(), // exact-only path
        ])
        .is_err());
    }

    #[test]
    fn run_kernel_precision_keys() {
        // BWKM through the explicit-lane f64 kernel (pinned bit-identical
        // to the scalar default — §2.10), single- and multi-threaded.
        for threads in ["1", "2"] {
            run(&[
                "dataset=3RN".into(),
                "scale=0.002".into(),
                "k=3".into(),
                "method=bwkm".into(),
                "kernel=simd".into(),
                format!("threads={threads}"),
                "max_outer=3".into(),
                "seed=1".into(),
                "eval_full_error=off".into(),
            ])
            .unwrap();
        }
        // RPKM in the mixed-precision mode.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "kernel=auto".into(),
            "precision=f32".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // Bad values and contradictory combinations are clean errors.
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "kernel=avx512".into(),
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "precision=f32".into(), // exact-engine key under the approximate regime
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "use_pjrt=on".into(),
            "kernel=simd".into(), // the device step has its own kernel
        ])
        .is_err());
    }

    #[test]
    fn run_streaming_dataset_end_to_end() {
        let ds = crate::data::simulate("3RN", 0.002, 7).unwrap();
        let p = std::env::temp_dir()
            .join(format!("bwkm_cli_stream_{}.bin", std::process::id()));
        crate::data::loader::save_bin(&ds, &p).unwrap();
        run(&[
            format!("dataset=stream:{}", p.display()),
            "k=3".into(),
            "chunk_rows=256".into(),
            "threads=2".into(),
            "seed=1".into(),
            "max_outer=3".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // Non-BWKM methods must refuse the streaming path.
        let err = run(&[
            format!("dataset=stream:{}", p.display()),
            "method=fkm".into(),
        ]);
        assert!(err.is_err());
        std::fs::remove_file(&p).ok();
    }
}
