//! The `bwkm` launcher CLI (hand-rolled arg parsing; DESIGN.md §4).
//!
//! ```text
//! bwkm info
//! bwkm run [--config FILE] [key=value ...]
//! bwkm figure <CIF|3RN|GS|SUSY|WUY> [key=value ...]
//! bwkm quickstart
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bench::figures::{emit, run_figure, FigureCfg};
use crate::bench::{write_bench_json, write_bench_json_to};
use crate::config::{Method, RunConfig};
use crate::data::{simulate, Dataset, TABLE1};
use crate::kmeans::init::{forgy, kmc2, kmeanspp, Kmc2Cfg};
use crate::kmeans::{lloyd, minibatch_kmeans, LloydCfg, MiniBatchCfg};
use crate::metrics::{kmeans_error, DistanceCounter};
use crate::obs::Recorder;
use crate::rpkm::{grid_rpkm_rec, RpkmCfg};
use crate::util::{fmt_count, Rng};

const USAGE: &str = "\
bwkm — Boundary Weighted K-means (Capó, Pérez, Lozano 2018) reproduction

USAGE:
  bwkm info                         dataset table, artifact manifest
  bwkm quickstart                   tiny end-to-end demo
  bwkm run [--config F] [k=v ...]   one clustering run (see config::RunConfig)
  bwkm figure <NAME> [k=v ...]      regenerate a paper figure (CIF 3RN GS SUSY WUY)

RUN KEYS: dataset scale seed k method budget threads use_pjrt eval_full_error
          chunk_rows m m_prime s r max_outer
          init oversample_l init_rounds chain_length
          assign closure_expand sample_rows sample_seed
          kernel precision
          save resume ingest jobs
          metrics metrics_path
          (method: bwkm fkm kmpp kmpp_init kmc2 mbN rpkm)
          (assign: exact closure sampled — the §2.9 assignment regime for
           bwkm/rpkm; closure scans closure_expand+1 candidate centroids
           per point, sampled runs each step on sample_rows rows seeded
           by sample_seed; approximate runs print their measured gap[..]
           note and still pay an exactly-accounted bill)
          (kernel: scalar simd auto / precision: f64 f32 — the §2.10 exact
           engine selection for bwkm/rpkm, assign=exact only; f64 output is
           bit-identical for every kernel, f32 is the opt-in mixed-precision
           mode — f32 storage, f64 accumulate — with a documented tolerance
           contract; the distance bill is identical either way)
          (init: forgy pp kmc2 par — the BWKM/RPKM seeding policy over
           partition representatives, DESIGN.md §2.8; par is K-means||
           with init_rounds rounds and oversampling l = oversample_l,
           0 = auto 2k)
          (dataset: a Table-1 name, path:FILE to load into memory, or
           stream:FILE.bin to cluster out of core — method=bwkm only,
           bit-identical to the in-memory run on the same data/seed;
           the per-iteration E^D trace costs one pass per iteration out
           of core, so it is opt-in there: eval_full_error=on)
          (save=FILE / resume=FILE — the DESIGN.md §5.2 model store,
           method=bwkm only: save persists the fitted model — centroids,
           partition cells, RNG stream, cumulative distance bill; resume
           continues an iteration-capped run over its original dataset,
           bit-identical to the uninterrupted run. Resume under the
           saving run's settings: only max_outer and budget may change —
           size-derived defaults like m must be passed explicitly if the
           dataset scale differs)
          (ingest=FILE resume=MODEL — warm-start ingestion: fold a
           mini-batch (.bin or CSV) into a saved model *without* the
           original dataset; re-refinement runs only when a cell's
           misassignment bound moved, the bill is exact, and the updated
           model is written to save= — or back over resume= if absent)
          (jobs=N — multiplex N independent bwkm jobs over threads=
           lanes of the shared persistent worker pool (DESIGN.md §2.12);
           each job gets a private distance counter and a deterministic
           RNG stream forked from seed, so results are worker-count
           independent; per-job queue wait prints as wait=)
          (metrics=off|summary|jsonl — run telemetry, DESIGN.md §2.11.
           summary prints an aggregated run report (phase spans, typed
           counters/gauges, events) and writes it as BENCH_run_metrics.json;
           jsonl additionally appends every record to metrics_path=FILE
           (default bwkm_trace.jsonl) as one JSON object per line, with the
           summary JSON landing at FILE.summary.json. Telemetry is strictly
           observational: centroids, bills and notes are bit-identical with
           metrics on or off)
";

/// Entry point used by `src/main.rs`.
pub fn main(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("quickstart") => quickstart(),
        Some("run") => run(&args[1..]),
        Some("figure") => figure(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn info() -> Result<()> {
    println!("Table 1 datasets (simulated; see DESIGN.md §4):");
    println!("{:<6} {:>12} {:>4}", "name", "paper n", "d");
    for s in TABLE1 {
        println!("{:<6} {:>12} {:>4}", s.name, fmt_count(s.paper_n as u64), s.d);
    }
    let dir = crate::runtime::Runtime::default_dir();
    match crate::runtime::Manifest::load(&dir.join("manifest.tsv")) {
        Ok(m) => {
            println!("\nAOT artifacts at {} ({} variants):", dir.display(), m.variants.len());
            for v in &m.variants {
                println!(
                    "  {:<12} mcap={:<6} kcap={:<3} dcap={:<3} {}",
                    v.program, v.mcap, v.kcap, v.dcap, v.file
                );
            }
        }
        Err(e) => println!("\nno artifacts found at {} ({e}); run `make artifacts`", dir.display()),
    }
    Ok(())
}

fn quickstart() -> Result<()> {
    let ds = simulate("WUY", 0.0005, 42).context("simulate")?;
    let counter = DistanceCounter::new();
    let mut cfg = crate::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 9);
    cfg.eval_full_error = true;
    let out = crate::bwkm::run(&ds, 9, &cfg, &mut Rng::new(7), &counter);
    let last = out.trace.last().unwrap();
    println!(
        "BWKM on simulated WUY (n={}, d={}): E^D={:.4e} after {} distances ({:?})",
        ds.n,
        ds.d,
        last.full_error.unwrap(),
        fmt_count(counter.get()),
        out.stop
    );
    Ok(())
}

fn parse_overrides(cfg: &mut RunConfig, args: &[String]) -> Result<()> {
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).context("--config needs a path")?;
            *cfg = RunConfig::from_file(Path::new(path))?;
            i += 2;
            continue;
        }
        let (k, v) = args[i]
            .split_once('=')
            .with_context(|| format!("expected key=value, got `{}`", args[i]))?;
        cfg.set(k, v)?;
        i += 1;
    }
    Ok(())
}

fn load_dataset(cfg: &RunConfig) -> Result<Dataset> {
    if let Some(path) = cfg.dataset.strip_prefix("path:") {
        let p = Path::new(path);
        if path.ends_with(".bin") {
            crate::data::loader::load_bin(p)
        } else {
            crate::data::loader::load_csv(p, None)
        }
    } else {
        simulate(&cfg.dataset, cfg.scale, cfg.seed)
            .with_context(|| format!("unknown dataset `{}`", cfg.dataset))
    }
}

/// One line per outer BWKM iteration — shared by the in-memory and
/// streaming runs so the two can never drift apart in layout.
fn print_trace(trace: &[crate::bwkm::TracePoint]) {
    for t in trace {
        println!(
            "  outer={:<3} dists={:>14} |B|={:<6} boundary={:<6} E^P={:.5e}{}",
            t.outer_iter,
            fmt_count(t.distances),
            t.blocks,
            t.boundary,
            t.weighted_error,
            t.full_error.map(|e| format!(" E^D={e:.5e}")).unwrap_or_default()
        );
    }
}

/// Print the telemetry run report and persist the typed summary JSON
/// (DESIGN.md §2.11). No-op with `metrics=off`. In `jsonl` mode the
/// summary lands beside the trace (`<trace>.summary.json`); in `summary`
/// mode it is the repo-root `BENCH_run_metrics.json` (the bench-harness
/// cell/row convention either way).
fn emit_metrics(rec: &Recorder) -> Result<()> {
    if !rec.is_on() {
        return Ok(());
    }
    rec.flush();
    let report = rec.report();
    if !report.is_empty() {
        println!("metrics:");
        for line in &report {
            println!("  {line}");
        }
    }
    let rows = rec.summary_rows();
    match rec.trace_path() {
        Some(trace) => {
            let summary = std::path::PathBuf::from(format!("{}.summary.json", trace.display()));
            write_bench_json_to(&summary, &rows);
            println!("metrics: trace={} summary={}", trace.display(), summary.display());
        }
        None => {
            write_bench_json("run_metrics", &rows);
            println!("metrics: summary=BENCH_run_metrics.json");
        }
    }
    Ok(())
}

/// Out-of-core run: the full BWKM loop against a `stream:` binary file,
/// never materializing the dataset (DESIGN.md §5.1). Bit-identical to
/// `run` on the same data and seed.
fn run_streaming(cfg: &RunConfig, path: &str, rec: &Recorder) -> Result<()> {
    use crate::coordinator::{stream_assign_err, StreamingBwkm};
    use crate::data::loader::BinChunks;

    if cfg.method != Method::Bwkm {
        bail!("stream: datasets support method=bwkm only (got {})", cfg.method.name());
    }
    if cfg.use_pjrt {
        bail!("stream: datasets do not support use_pjrt yet");
    }
    let p = Path::new(path);
    let probe = BinChunks::open(p, cfg.chunk_rows)?; // header + truncation check
    let (n, d) = (probe.n, probe.d);
    drop(probe);
    println!(
        "run: dataset=stream:{path} n={n} d={d} k={} method=BWKM chunk_rows={} threads={}",
        cfg.k, cfg.chunk_rows, cfg.threads
    );
    let mut bcfg = cfg.bwkm_cfg(n, d)?;
    if !cfg.eval_full_error_explicit {
        // Out of core every trace evaluation is one full pass over the
        // source; keep the E^D trace opt-in here (eval_full_error=on).
        bcfg.eval_full_error = false;
    }
    let counter = DistanceCounter::new();
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut coordinator =
        StreamingBwkm::new(BinChunks::opener(p, cfg.chunk_rows), d).with_threads(cfg.threads);
    let out = coordinator.run_rec(cfg.k, &bcfg, &mut rng, &counter, rec)?;
    print_trace(&out.trace);
    // Final E^D by one more streamed scoring pass (its own counter).
    let eval = DistanceCounter::new();
    let (rows, sse) =
        stream_assign_err(d, &out.centroids, BinChunks::open(p, cfg.chunk_rows)?, &eval)?;
    if rows != n {
        bail!("source changed during the run: scoring pass saw {rows} rows, expected {n}");
    }
    // Approximate runs self-report their measured quality gap (§2.9).
    for note in counter.notes().iter().filter(|note| note.starts_with("gap[")) {
        println!("  {note}");
    }
    println!(
        "result: E^D={sse:.6e} distances={} passes={} wall={:.2?} (stop={:?} init={})",
        fmt_count(counter.get()),
        out.passes,
        t0.elapsed(),
        out.stop,
        bcfg.seed.method.name()
    );
    emit_metrics(rec)
}

/// Warm-start ingestion (DESIGN.md §5.2): fold a mini-batch into a saved
/// model without its original dataset. `resume=` names the store,
/// `ingest=` the batch file; the updated model goes to `save=` (or back
/// over the input store when absent).
fn run_ingest(cfg: &RunConfig, batch_path: &str, rec: &Recorder) -> Result<()> {
    let model_path = cfg
        .resume
        .as_deref()
        .context("ingest= needs resume=FILE naming the model store to ingest into")?;
    if cfg.jobs > 1 {
        bail!("ingest= is a single job (drop jobs=)");
    }
    if cfg.method != Method::Bwkm {
        bail!("ingest= operates on BWKM model stores (method=bwkm only)");
    }
    let p = Path::new(batch_path);
    let batch = if batch_path.ends_with(".bin") {
        crate::data::loader::load_bin(p)?
    } else {
        crate::data::loader::load_csv(p, None)?
    };
    let mut model = crate::store::load(model_path)?;
    if rec.is_on() {
        rec.event(
            "store.load",
            &format!("path={model_path} k={} rows={}", model.k, model.rows),
        );
    }
    // Rebuild the saving run's configuration. model.rows equals the
    // original n until the first ingest grows it; after that, pass the
    // size-derived keys (m, m_prime, s) explicitly — the digest check
    // rejects a drifted configuration rather than guessing.
    let bcfg = cfg.bwkm_cfg(model.rows as usize, model.d)?;
    let counter = DistanceCounter::new();
    let t0 = std::time::Instant::now();
    let report = crate::store::ingest_rec(&mut model, &batch, &bcfg, &counter, rec)?;
    let out_path = cfg.save.as_deref().unwrap_or(model_path);
    crate::store::save(&model, out_path)?;
    if rec.is_on() {
        rec.event(
            "store.save",
            &format!("path={out_path} cells={} rows={}", model.cells.len(), model.rows),
        );
    }
    println!(
        "ingest: rows={} touched={} moved={} refine_iters={} batch_err={:.6e}",
        report.rows, report.touched, report.moved, report.refine_iters, report.batch_err
    );
    println!(
        "result: model={} rows={} distances=+{} wall={:.2?}",
        out_path,
        model.rows,
        fmt_count(report.bill),
        t0.elapsed()
    );
    emit_metrics(rec)
}

/// Multiplex `jobs=N` independent BWKM runs over the shared worker pool
/// (DESIGN.md §5.2): one dataset, N seed streams, isolated bills.
fn run_multi(cfg: &RunConfig, rec: &Recorder) -> Result<()> {
    if cfg.method != Method::Bwkm {
        bail!("jobs= supports method=bwkm only (got {})", cfg.method.name());
    }
    if cfg.save.is_some() || cfg.resume.is_some() {
        bail!("jobs= cannot be combined with save=/resume= (a store file holds one model; run jobs separately)");
    }
    if cfg.use_pjrt {
        bail!("jobs= does not support use_pjrt (the device runtime is single-tenant)");
    }
    if cfg.dataset.starts_with("stream:") {
        bail!("jobs= needs an in-memory dataset (stream: sources are single-job)");
    }
    let ds = load_dataset(cfg)?;
    if !ds.is_finite() {
        bail!("dataset contains non-finite values");
    }
    let bcfg = cfg.bwkm_cfg(ds.n, ds.d)?;
    println!(
        "run: dataset={} n={} d={} k={} method=BWKM jobs={} workers={}",
        cfg.dataset,
        ds.n,
        ds.d,
        cfg.k,
        cfg.jobs,
        cfg.threads.max(1).min(cfg.jobs)
    );
    let t0 = std::time::Instant::now();
    let results = crate::coordinator::run_jobs_rec(
        cfg.jobs,
        cfg.threads,
        cfg.seed,
        rec,
        |_job, rng, counter, jrec| crate::bwkm::run_rec(&ds, cfg.k, &bcfg, rng, counter, jrec),
    );
    for r in &results {
        let eval = DistanceCounter::new();
        let err = kmeans_error(&ds.data, ds.d, &r.out.centroids, &eval);
        println!(
            "  job={:<3} E^D={err:.6e} distances={:>14} wall={:.2}s wait={:.2}s (stop={:?})",
            r.job,
            fmt_count(r.distances),
            r.elapsed_s,
            r.queue_wait_s,
            r.out.stop
        );
        for n in r.notes.iter().filter(|n| n.starts_with("gap[")) {
            println!("    {n}");
        }
    }
    println!("result: {} jobs wall={:.2?} (init={})", results.len(), t0.elapsed(), bcfg.seed.method.name());
    emit_metrics(rec)
}

fn run(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    parse_overrides(&mut cfg, args)?;
    let rec = cfg.recorder()?;
    if let Some(batch) = cfg.ingest.clone() {
        return run_ingest(&cfg, &batch, &rec);
    }
    if cfg.jobs > 1 {
        return run_multi(&cfg, &rec);
    }
    if let Some(path) = cfg.dataset.strip_prefix("stream:") {
        if cfg.save.is_some() || cfg.resume.is_some() {
            bail!("save=/resume= need the in-memory path (the streaming outcome holds no store state yet)");
        }
        let path = path.to_string();
        return run_streaming(&cfg, &path, &rec);
    }
    if (cfg.save.is_some() || cfg.resume.is_some()) && cfg.method != Method::Bwkm {
        bail!("save=/resume= operate on BWKM model stores (method=bwkm only)");
    }
    let ds = load_dataset(&cfg)?;
    if !ds.is_finite() {
        bail!("dataset contains non-finite values");
    }
    println!(
        "run: dataset={} n={} d={} k={} method={} threads={}",
        cfg.dataset,
        ds.n,
        ds.d,
        cfg.k,
        cfg.method.name(),
        cfg.threads
    );
    let counter = DistanceCounter::new();
    let eval = DistanceCounter::new();
    let mut rng = Rng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let (centroids, note) = match &cfg.method {
        Method::Bwkm => {
            let bcfg = cfg.bwkm_cfg(ds.n, ds.d)?;
            let approx = bcfg.assign.mode != crate::kmeans::AssignMode::Exact;
            if cfg.use_pjrt && approx {
                bail!("use_pjrt supports assign=exact only (the device step is exact)");
            }
            if cfg.use_pjrt
                && (bcfg.assign.kernel != crate::kmeans::KernelKind::Scalar
                    || bcfg.assign.precision != crate::kmeans::Precision::F64)
            {
                // Never silently ignore a §2.10 selection: the device step
                // has its own kernel (DESIGN.md §8), not the native one.
                bail!("use_pjrt supports the default kernel/precision only (drop the keys)");
            }
            let out = if let Some(mp) = &cfg.resume {
                if cfg.use_pjrt {
                    bail!("resume= does not support use_pjrt (the device stepper holds no store state)");
                }
                let model = crate::store::load(mp)?;
                if rec.is_on() {
                    rec.event(
                        "store.load",
                        &format!("path={mp} k={} rows={}", model.k, model.rows),
                    );
                }
                if cfg.threads > 1 && !approx {
                    let mut stepper =
                        crate::coordinator::sharded_stepper_for(&bcfg.assign, cfg.threads);
                    crate::store::resume_with_rec(
                        stepper.as_mut(),
                        &model,
                        &ds,
                        &bcfg,
                        &mut rng,
                        &counter,
                        &rec,
                    )?
                } else {
                    crate::store::resume_rec(&model, &ds, &bcfg, &mut rng, &counter, &rec)?
                }
            } else if approx {
                // Approximate regimes run their own (serial) stepper —
                // closures / sampled steps carry state across steps.
                let mut stepper = crate::kmeans::stepper_for(&bcfg.assign);
                crate::bwkm::run_with_rec(
                    stepper.as_mut(),
                    &ds,
                    cfg.k,
                    &bcfg,
                    &mut rng,
                    &counter,
                    &rec,
                )
            } else if cfg.use_pjrt {
                let rt = crate::runtime::Runtime::open_default()?;
                let mut stepper = crate::runtime::PjrtStepper::new(rt);
                let o = crate::bwkm::run_with_rec(
                    &mut stepper,
                    &ds,
                    cfg.k,
                    &bcfg,
                    &mut rng,
                    &counter,
                    &rec,
                );
                println!(
                    "pjrt: {} device steps, {} native-fallback steps",
                    stepper.device_steps, stepper.fallback_steps
                );
                o
            } else if cfg.threads > 1 {
                // Honors the §2.10 kernel/precision selection per worker.
                let mut stepper =
                    crate::coordinator::sharded_stepper_for(&bcfg.assign, cfg.threads);
                crate::bwkm::run_with_rec(
                    stepper.as_mut(),
                    &ds,
                    cfg.k,
                    &bcfg,
                    &mut rng,
                    &counter,
                    &rec,
                )
            } else {
                crate::bwkm::run_rec(&ds, cfg.k, &bcfg, &mut rng, &counter, &rec)
            };
            print_trace(&out.trace);
            if let Some(sp) = &cfg.save {
                // The advanced rng/counter go into the snapshot so a
                // later resume continues the exact same trajectory.
                let model = crate::store::Model::from_run(&out, &bcfg, &rng, &counter);
                crate::store::save(&model, sp)?;
                if rec.is_on() {
                    rec.event(
                        "store.save",
                        &format!("path={sp} cells={} rows={}", model.cells.len(), model.rows),
                    );
                }
                println!(
                    "saved: {sp} ({} cells, {} rows, {} trace points)",
                    model.cells.len(),
                    model.rows,
                    model.trace.len()
                );
            }
            let stop = out.stop;
            (out.centroids, format!("stop={stop:?} init={}", bcfg.seed.method.name()))
        }
        Method::Fkm => {
            let init = forgy(&ds.data, ds.d, cfg.k, &mut rng);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::Kmpp => {
            let init = kmeanspp(&ds.data, ds.d, cfg.k, &mut rng, &counter);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::KmppInit => {
            let init = kmeanspp(&ds.data, ds.d, cfg.k, &mut rng, &counter);
            (init, "init only".into())
        }
        Method::Kmc2 => {
            let init = kmc2(&ds.data, ds.d, cfg.k, &Kmc2Cfg::default(), &mut rng, &counter);
            let l = lloyd(&ds.data, ds.d, &init, &LloydCfg::default(), &counter);
            (l.centroids, format!("iters={}", l.iters))
        }
        Method::MiniBatch(b) => {
            let mcfg = MiniBatchCfg { batch: *b, budget: cfg.budget(), ..Default::default() };
            let r = minibatch_kmeans(&ds.data, ds.d, cfg.k, &mcfg, &mut rng, &counter);
            (r.centroids, format!("iters={}", r.iters))
        }
        Method::Rpkm => {
            let rcfg = RpkmCfg {
                budget: cfg.budget(),
                seed: cfg.seed_policy(crate::kmeans::init::SeedMethod::Forgy)?,
                assign: cfg.assign_cfg()?,
                ..Default::default()
            };
            let out = grid_rpkm_rec(&ds, cfg.k, &rcfg, &mut rng, &counter, &rec);
            (out.centroids, format!("levels={}", out.trace.len()))
        }
    };
    let err = if cfg.threads > 1 {
        crate::coordinator::sharded_assign_err(&ds, &centroids, cfg.threads, &eval).1
    } else {
        kmeans_error(&ds.data, ds.d, &centroids, &eval)
    };
    // Approximate runs self-report their measured quality gap (§2.9).
    for n in counter.notes().iter().filter(|n| n.starts_with("gap[")) {
        println!("  {n}");
    }
    println!(
        "result: E^D={err:.6e} distances={} wall={:.2?} ({note})",
        fmt_count(counter.get()),
        t0.elapsed()
    );
    emit_metrics(&rec)
}

fn figure(args: &[String]) -> Result<()> {
    let name = args.first().context("figure needs a dataset name")?.to_uppercase();
    let base = match name.as_str() {
        "CIF" => 0.3,
        "3RN" => 0.05,
        "GS" => 0.005,
        "SUSY" => 0.004,
        "WUY" => 0.0005,
        _ => bail!("unknown figure dataset `{name}`"),
    };
    let mut cfg = FigureCfg::for_dataset(&name, base);
    for arg in &args[1..] {
        let (k, v) = arg.split_once('=').context("expected key=value")?;
        match k {
            "scale" => cfg.scale = v.parse()?,
            "reps" => cfg.reps = v.parse()?,
            "ks" => cfg.ks = v.split(';').map(|x| x.parse()).collect::<Result<_, _>>()?,
            "seed" => cfg.seed = v.parse()?,
            _ => bail!("unknown figure key `{k}`"),
        }
    }
    let res = run_figure(&cfg);
    emit(&res, &format!("fig_{}", name.to_lowercase()));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_paths() {
        assert!(main(&[]).is_ok());
        assert!(main(&["help".into()]).is_ok());
        assert!(main(&["definitely-not-a-command".into()]).is_err());
    }

    #[test]
    fn quickstart_runs() {
        quickstart().unwrap();
    }

    #[test]
    fn run_with_overrides() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.003".into(),
            "k=3".into(),
            "method=mb100".into(),
            "seed=1".into(),
        ])
        .unwrap();
    }

    #[test]
    fn run_bwkm_with_par_init_policy() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "init=par".into(),
            "init_rounds=2".into(),
            "oversample_l=6".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // RPKM honors the policy keys too.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "init=pp".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // A bad init value is a clean error.
        assert!(run(&["dataset=3RN".into(), "scale=0.002".into(), "init=quantum".into()]).is_err());
    }

    #[test]
    fn run_approximate_assign_modes() {
        // BWKM with closure candidates.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "closure_expand=2".into(),
            "max_outer=3".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // RPKM with sampled steps.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "assign=sampled".into(),
            "sample_rows=64".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // Validation surfaces as clean errors.
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=sampled".into(), // sample_rows missing
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "use_pjrt=on".into(), // exact-only path
        ])
        .is_err());
    }

    #[test]
    fn run_kernel_precision_keys() {
        // BWKM through the explicit-lane f64 kernel (pinned bit-identical
        // to the scalar default — §2.10), single- and multi-threaded.
        for threads in ["1", "2"] {
            run(&[
                "dataset=3RN".into(),
                "scale=0.002".into(),
                "k=3".into(),
                "method=bwkm".into(),
                "kernel=simd".into(),
                format!("threads={threads}"),
                "max_outer=3".into(),
                "seed=1".into(),
                "eval_full_error=off".into(),
            ])
            .unwrap();
        }
        // RPKM in the mixed-precision mode.
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=rpkm".into(),
            "kernel=auto".into(),
            "precision=f32".into(),
            "seed=1".into(),
        ])
        .unwrap();
        // Bad values and contradictory combinations are clean errors.
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "kernel=avx512".into(),
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "assign=closure".into(),
            "precision=f32".into(), // exact-engine key under the approximate regime
        ])
        .is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=bwkm".into(),
            "use_pjrt=on".into(),
            "kernel=simd".into(), // the device step has its own kernel
        ])
        .is_err());
    }

    #[test]
    fn run_service_verbs_end_to_end() {
        let ds = crate::data::simulate("3RN", 0.002, 5).unwrap();
        let data = std::env::temp_dir().join(format!("bwkm_cli_svc_{}.bin", std::process::id()));
        crate::data::loader::save_bin(&ds, &data).unwrap();
        let model = std::env::temp_dir().join(format!("bwkm_cli_svc_{}.mdl", std::process::id()));
        let common = [
            format!("dataset=path:{}", data.display()),
            "k=3".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ];
        // Fit an iteration-capped model and save it.
        let mut args: Vec<String> = common.to_vec();
        args.push("max_outer=2".into());
        args.push(format!("save={}", model.display()));
        run(&args).unwrap();
        // Resume it over the same dataset with a raised cap.
        let mut args: Vec<String> = common.to_vec();
        args.push("max_outer=4".into());
        args.push(format!("resume={}", model.display()));
        args.push(format!("save={}", model.display()));
        run(&args).unwrap();
        // Ingest a mini-batch without the original dataset in play.
        let batch = Dataset::new(ds.data[..ds.d * 16].to_vec(), ds.d);
        let bpath = std::env::temp_dir().join(format!("bwkm_cli_svc_{}.batch.bin", std::process::id()));
        crate::data::loader::save_bin(&batch, &bpath).unwrap();
        // The resumed model covers n rows; size-derived defaults still
        // match because the batch does not change the cfg inputs here.
        let mut args: Vec<String> = common.to_vec();
        args.push("max_outer=4".into());
        args.push(format!("ingest={}", bpath.display()));
        args.push(format!("resume={}", model.display()));
        run(&args).unwrap();
        let grown = crate::store::load(model.to_str().unwrap()).unwrap();
        assert_eq!(grown.rows, ds.n as u64 + 16);
        for p in [&data, &model, &bpath] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn run_jobs_multiplexing_and_bad_combos() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "jobs=3".into(),
            "threads=2".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // jobs= is bwkm-only and excludes the store verbs.
        assert!(run(&["dataset=3RN".into(), "scale=0.002".into(), "method=fkm".into(), "jobs=2".into()]).is_err());
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "jobs=2".into(),
            "save=x.mdl".into(),
        ])
        .is_err());
        // ingest= without a model store to anchor on is a clean error.
        assert!(run(&["dataset=3RN".into(), "scale=0.002".into(), "ingest=b.bin".into()]).is_err());
        // save= is meaningless for methods without a model store.
        assert!(run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "method=fkm".into(),
            "save=x.mdl".into(),
        ])
        .is_err());
    }

    /// Every line of a JSONL trace is one record with the pinned field
    /// order, and the typed summary JSON landed beside it (§2.11).
    fn assert_trace_and_summary(trace: &Path) {
        let body = std::fs::read_to_string(trace).unwrap();
        assert!(!body.is_empty(), "trace {} is empty", trace.display());
        for line in body.lines() {
            assert!(line.starts_with("{\"ts\": "), "bad trace line: {line}");
            assert!(line.ends_with('}'), "bad trace line: {line}");
            assert!(line.contains("\"kind\": \""), "bad trace line: {line}");
            assert!(line.contains("\"name\": \""), "bad trace line: {line}");
            assert!(line.contains("\"value\": "), "bad trace line: {line}");
        }
        let summary = std::path::PathBuf::from(format!("{}.summary.json", trace.display()));
        assert!(summary.is_file(), "missing {}", summary.display());
        std::fs::remove_file(&summary).ok();
    }

    #[test]
    fn run_metrics_summary_mode_writes_bench_json() {
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
            "metrics=summary".into(),
        ])
        .unwrap();
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_run_metrics.json");
        assert!(p.is_file(), "missing {}", p.display());
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("bwkm.iter"), "summary JSON lacks the bwkm.iter span: {body}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn run_metrics_jsonl_across_surfaces() {
        let tmp = std::env::temp_dir();
        let pid = std::process::id();

        // 1. Plain in-memory BWKM run.
        let trace = tmp.join(format!("bwkm_cli_obs_run_{pid}.jsonl"));
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
            "metrics=jsonl".into(),
            format!("metrics_path={}", trace.display()),
        ])
        .unwrap();
        assert_trace_and_summary(&trace);
        std::fs::remove_file(&trace).ok();

        // 2. Out-of-core stream: run.
        let ds = crate::data::simulate("3RN", 0.002, 7).unwrap();
        let bin = tmp.join(format!("bwkm_cli_obs_stream_{pid}.bin"));
        crate::data::loader::save_bin(&ds, &bin).unwrap();
        let trace = tmp.join(format!("bwkm_cli_obs_stream_{pid}.jsonl"));
        run(&[
            format!("dataset=stream:{}", bin.display()),
            "k=3".into(),
            "chunk_rows=256".into(),
            "threads=2".into(),
            "seed=1".into(),
            "max_outer=2".into(),
            "eval_full_error=off".into(),
            "metrics=jsonl".into(),
            format!("metrics_path={}", trace.display()),
        ])
        .unwrap();
        assert_trace_and_summary(&trace);
        let body = std::fs::read_to_string(&trace).unwrap();
        assert!(body.contains("stream.read"), "stream trace lacks read timing");
        assert!(body.contains("stream.compute"), "stream trace lacks compute timing");
        std::fs::remove_file(&trace).ok();
        std::fs::remove_file(&bin).ok();

        // 3. jobs= multiplexing: per-job scoped names in one shared trace.
        let trace = tmp.join(format!("bwkm_cli_obs_jobs_{pid}.jsonl"));
        run(&[
            "dataset=3RN".into(),
            "scale=0.002".into(),
            "k=3".into(),
            "method=bwkm".into(),
            "jobs=2".into(),
            "threads=2".into(),
            "max_outer=2".into(),
            "seed=1".into(),
            "eval_full_error=off".into(),
            "metrics=jsonl".into(),
            format!("metrics_path={}", trace.display()),
        ])
        .unwrap();
        assert_trace_and_summary(&trace);
        let body = std::fs::read_to_string(&trace).unwrap();
        assert!(body.contains("job0."), "jobs trace lacks job0.-scoped records");
        assert!(body.contains("job1."), "jobs trace lacks job1.-scoped records");
        std::fs::remove_file(&trace).ok();
    }

    #[test]
    fn run_streaming_dataset_end_to_end() {
        let ds = crate::data::simulate("3RN", 0.002, 7).unwrap();
        let p = std::env::temp_dir()
            .join(format!("bwkm_cli_stream_{}.bin", std::process::id()));
        crate::data::loader::save_bin(&ds, &p).unwrap();
        run(&[
            format!("dataset=stream:{}", p.display()),
            "k=3".into(),
            "chunk_rows=256".into(),
            "threads=2".into(),
            "seed=1".into(),
            "max_outer=3".into(),
            "eval_full_error=off".into(),
        ])
        .unwrap();
        // Non-BWKM methods must refuse the streaming path.
        let err = run(&[
            format!("dataset=stream:{}", p.display()),
            "method=fkm".into(),
        ]);
        assert!(err.is_err());
        std::fs::remove_file(&p).ok();
    }
}
