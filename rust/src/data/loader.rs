//! File loaders/writers: delimited text (CSV/TSV/whitespace) and raw
//! little-endian f64 binary, plus a chunked binary reader used by the
//! streaming coordinator.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

/// Load a delimited numeric text file. Delimiters: ',', ';', tab or runs of
/// spaces. Lines starting with '#' (or an optional single header line that
/// fails to parse) are skipped. `take_cols` optionally restricts to the
/// first N columns (e.g. the paper's datasets carry id columns).
pub fn load_csv(path: &Path, take_cols: Option<usize>) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut header_skipped = false;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = t
            .split(|c: char| c == ',' || c == ';' || c == '\t' || c == ' ')
            .filter(|s| !s.is_empty())
            .collect();
        let parsed: Result<Vec<f64>, _> = fields.iter().map(|s| s.parse::<f64>()).collect();
        let mut row = match parsed {
            Ok(r) => r,
            Err(_) if !header_skipped => {
                header_skipped = true;
                continue; // tolerate one header line
            }
            Err(e) => bail!("{}:{}: parse error: {e}", path.display(), lineno + 1),
        };
        if let Some(c) = take_cols {
            if row.len() < c {
                bail!("{}:{}: {} columns, need {c}", path.display(), lineno + 1, row.len());
            }
            row.truncate(c);
        }
        if d == 0 {
            d = row.len();
        } else if row.len() != d {
            bail!("{}:{}: ragged row ({} vs {d})", path.display(), lineno + 1, row.len());
        }
        data.extend_from_slice(&row);
    }
    if d == 0 {
        bail!("{}: no data rows", path.display());
    }
    Ok(Dataset::new(data, d))
}

/// Write a dataset as raw little-endian f64 with an 16-byte header
/// (`n: u64 le`, `d: u64 le`).
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(&(ds.n as u64).to_le_bytes())?;
    w.write_all(&(ds.d as u64).to_le_bytes())?;
    for &x in &ds.data {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Payload bytes a header-declared (n, d) implies, rejecting headers
/// whose sizes overflow or declare d = 0 (corruption — `save_bin` can
/// never write either) and files too short to hold them (truncation /
/// short read — caught at open, before any chunk is read).
fn payload_bytes(n: usize, d: usize, file_len: u64, path: &Path) -> Result<u64> {
    if d == 0 {
        bail!("{}: corrupt header (d=0)", path.display());
    }
    let bytes = (n as u64)
        .checked_mul(d as u64)
        .and_then(|x| x.checked_mul(8))
        .ok_or_else(|| {
            anyhow::anyhow!("{}: corrupt header (n={n}, d={d} overflows)", path.display())
        })?;
    let expected = 16 + bytes;
    if file_len < expected {
        bail!(
            "{}: truncated binary dataset: {file_len} bytes, header (n={n}, d={d}) needs {expected}",
            path.display()
        );
    }
    Ok(bytes)
}

/// Load a raw binary dataset written by [`save_bin`].
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut hdr = [0u8; 16];
    r.read_exact(&mut hdr)?;
    let n = u64::from_le_bytes(hdr[0..8].try_into().unwrap()) as usize;
    let d = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
    let bytes = payload_bytes(n, d, file_len, path)?;
    let mut buf = vec![0u8; bytes as usize];
    r.read_exact(&mut buf)?;
    let data: Vec<f64> = buf
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Dataset::new(data, d))
}

/// Chunked reader over a binary dataset file — the streaming-ingestion
/// source for the coordinator (`coordinator::streaming`). Yields row-major
/// chunks of at most `chunk_rows` rows without materializing the dataset.
pub struct BinChunks {
    reader: BufReader<File>,
    pub n: usize,
    pub d: usize,
    pub chunk_rows: usize,
    read_rows: usize,
}

impl BinChunks {
    pub fn open(path: &Path, chunk_rows: usize) -> Result<BinChunks> {
        let f = File::open(path)?;
        let file_len = f.metadata()?.len();
        let mut reader = BufReader::new(f);
        let mut hdr = [0u8; 16];
        reader.read_exact(&mut hdr)?;
        let n = u64::from_le_bytes(hdr[0..8].try_into().unwrap()) as usize;
        let d = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        // Truncation and d=0 corruption are detected here, not
        // mid-stream: a reader pinned to the header's (n, d) never hands
        // a short chunk to the streaming coordinator (DESIGN.md §5.1
        // failure contract).
        payload_bytes(n, d, file_len, path)?;
        Ok(BinChunks { reader, n, d, chunk_rows: chunk_rows.max(1), read_rows: 0 })
    }

    /// A restartable opener for this file — the shape
    /// `coordinator::streaming::StreamingBwkm` consumes: every call
    /// re-opens the file and yields the same rows in the same order.
    pub fn opener(
        path: &Path,
        chunk_rows: usize,
    ) -> impl FnMut() -> Result<BinChunks> {
        let path = path.to_path_buf();
        move || BinChunks::open(&path, chunk_rows)
    }
}

impl Iterator for BinChunks {
    type Item = Result<Vec<f64>>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.read_rows >= self.n {
            return None;
        }
        let rows = self.chunk_rows.min(self.n - self.read_rows);
        let mut buf = vec![0u8; rows * self.d * 8];
        if let Err(e) = self.reader.read_exact(&mut buf) {
            return Some(Err(e.into()));
        }
        self.read_rows += rows;
        let chunk: Vec<f64> = buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Some(Ok(chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("bwkm_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn csv_roundtrip_with_header_and_comments() {
        let p = tmp("a.csv");
        std::fs::write(&p, "x,y\n# comment\n1.0,2.0\n3.5,-4\n").unwrap();
        let ds = load_csv(&p, None).unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.row(1), &[3.5, -4.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_take_cols() {
        let p = tmp("b.csv");
        std::fs::write(&p, "1 2 3\n4 5 6\n").unwrap();
        let ds = load_csv(&p, Some(2)).unwrap();
        assert_eq!(ds.d, 2);
        assert_eq!(ds.row(1), &[4.0, 5.0]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("c.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(load_csv(&p, None).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_open_rejects_truncation_and_corrupt_headers() {
        let p = tmp("trunc.bin");
        let ds = Dataset::new((0..30).map(|x| x as f64).collect(), 3);
        save_bin(&ds, &p).unwrap();
        // Chop the last row off the payload: both readers must refuse at
        // open, before any chunk is handed out.
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(16 + 9 * 3 * 8 + 4).unwrap();
        drop(f);
        assert!(BinChunks::open(&p, 4).is_err(), "truncated file must fail at open");
        assert!(load_bin(&p).is_err());
        // Corrupt header: n·d·8 overflows u64.
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&u64::MAX.to_le_bytes());
        hdr.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &hdr).unwrap();
        assert!(BinChunks::open(&p, 4).is_err(), "overflowing header must fail");
        assert!(load_bin(&p).is_err());
        // Corrupt header: d=0 (save_bin can never write one) must be a
        // clean Err from both readers, not an assert panic downstream.
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&7u64.to_le_bytes());
        hdr.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &hdr).unwrap();
        assert!(BinChunks::open(&p, 4).is_err(), "d=0 header must fail");
        assert!(load_bin(&p).is_err(), "d=0 header must fail in load_bin too");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_opener_is_restartable() {
        let p = tmp("opener.bin");
        let ds = Dataset::new((0..24).map(|x| x as f64).collect(), 2);
        save_bin(&ds, &p).unwrap();
        let mut open = BinChunks::opener(&p, 5);
        for _ in 0..2 {
            let flat: Vec<f64> =
                open().unwrap().map(|c| c.unwrap()).flatten().collect();
            assert_eq!(flat, ds.data, "every pass must yield the same rows");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_roundtrip_and_chunks() {
        let p = tmp("d.bin");
        let ds = Dataset::new((0..24).map(|x| x as f64).collect(), 3);
        save_bin(&ds, &p).unwrap();
        let back = load_bin(&p).unwrap();
        assert_eq!(back.data, ds.data);
        assert_eq!(back.d, 3);

        let chunks: Vec<Vec<f64>> =
            BinChunks::open(&p, 3).unwrap().map(|c| c.unwrap()).collect();
        assert_eq!(chunks.len(), 3); // 8 rows in chunks of 3: 3+3+2
        assert_eq!(chunks[2].len(), 2 * 3);
        let flat: Vec<f64> = chunks.concat();
        assert_eq!(flat, ds.data);
        std::fs::remove_file(&p).ok();
    }
}
