//! Table-1 dataset simulators.
//!
//! The paper evaluates on five public datasets (Table 1). This environment
//! has no network access, so each dataset is simulated by a generator with
//! the *same dimensionality*, a scalable n, and a cluster-boundary geometry
//! chosen to reproduce the regime the paper attributes to it (see §3 of the
//! paper and DESIGN.md §4):
//!
//! | name | paper n    | d  | regime reproduced                           |
//! |------|-----------:|---:|---------------------------------------------|
//! | CIF  |     68,037 | 17 | small n, high d: many overlapping blobs      |
//! | 3RN  |    434,874 |  3 | low d manifold: noisy road polylines         |
//! | GS   |  4,208,259 | 19 | large n, high d, drifting heavy-tailed blobs |
//! | SUSY |  5,000,000 | 19 | large n, high d, two heavily-overlapping     |
//! |      |            |    | physics-like populations + subclusters       |
//! | WUY  | 45,811,883 |  5 | huge n, low d, heavily skewed cluster sizes  |
//!
//! Real files (when available) load through `data::loader` instead.

use crate::util::Rng;

use super::synthetic;
use super::Dataset;

/// Metadata of a Table-1 dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Full size used in the paper.
    pub paper_n: usize,
    pub d: usize,
}

/// The paper's Table 1.
pub const TABLE1: [DatasetSpec; 5] = [
    DatasetSpec { name: "CIF", paper_n: 68_037, d: 17 },
    DatasetSpec { name: "3RN", paper_n: 434_874, d: 3 },
    DatasetSpec { name: "GS", paper_n: 4_208_259, d: 19 },
    DatasetSpec { name: "SUSY", paper_n: 5_000_000, d: 19 },
    DatasetSpec { name: "WUY", paper_n: 45_811_883, d: 5 },
];

/// Look up a spec by (case-insensitive) name.
pub fn spec(name: &str) -> Option<DatasetSpec> {
    TABLE1.iter().copied().find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Simulate dataset `name` at `scale` ∈ (0, 1] of the paper's n
/// (min 1,000 rows so tiny scales stay meaningful).
pub fn simulate(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    let s = spec(name)?;
    let n = ((s.paper_n as f64 * scale) as usize).max(1_000);
    let mut rng = Rng::new(seed ^ 0xD5_0000);
    Some(match s.name {
        "CIF" => cif(&mut rng, n),
        "3RN" => rn3(&mut rng, n),
        "GS" => gs(&mut rng, n),
        "SUSY" => susy(&mut rng, n),
        "WUY" => wuy(&mut rng, n),
        _ => unreachable!(),
    })
}

/// CIF (Corel Image Features): d=17 color-histogram-like features.
/// Many moderately-overlapping blobs in a bounded positive region — the
/// "small dataset, large dimension" worst case for BWKM (paper §3).
fn cif(rng: &mut Rng, n: usize) -> Dataset {
    let d = 17;
    let k = 24;
    let comps: Vec<synthetic::Component> = (0..k)
        .map(|i| synthetic::Component {
            // Histogram-ish: sparse positive centers.
            center: (0..d)
                .map(|_| if rng.f64() < 0.4 { rng.range(0.1, 1.0) } else { rng.range(0.0, 0.08) })
                .collect(),
            std: (0..d).map(|_| rng.range(0.04, 0.18)).collect(),
            weight: 1.0 / (1.0 + i as f64).powf(0.5),
        })
        .collect();
    synthetic::gmm(rng, n, &comps)
}

/// 3RN (3D Road Network): d=3, road polylines with small altitude noise —
/// low-dimensional curvilinear density, BWKM's favourable low-d regime.
fn rn3(rng: &mut Rng, n: usize) -> Dataset {
    // Several disconnected road systems of differing density.
    let systems = 6;
    let mut data = Vec::with_capacity(n * 3);
    let mut remaining = n;
    for s in 0..systems {
        let take = if s == systems - 1 { remaining } else { remaining / (systems - s) };
        remaining -= take;
        let mut roads = synthetic::polyline(rng, take, 3, 24, 0.03);
        // Offset each system to its own region; squash the z axis (altitude).
        let off = [rng.range(-40.0, 40.0), rng.range(-40.0, 40.0), rng.range(-1.0, 1.0)];
        for i in 0..roads.n {
            roads.data[i * 3] += off[0];
            roads.data[i * 3 + 1] += off[1];
            roads.data[i * 3 + 2] = roads.data[i * 3 + 2] * 0.1 + off[2];
        }
        data.extend_from_slice(&roads.data);
    }
    Dataset::new(data, 3)
}

/// GS (Gas Sensor): d=19, large n, sensor drift → elongated heavy-tailed
/// clusters with substantial overlap.
fn gs(rng: &mut Rng, n: usize) -> Dataset {
    let d = 19;
    let k = 12;
    let mut ds = synthetic::heavy_tailed_blobs(rng, n, d, k, 1.2, 0.08);
    // Sensor drift: add a shared slow linear drift along a random direction,
    // stretching clusters into overlapping cigars.
    let dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let norm = dir.iter().map(|x| x * x).sum::<f64>().sqrt();
    for i in 0..ds.n {
        let t = (i as f64 / ds.n as f64 - 0.5) * 6.0;
        for j in 0..d {
            ds.data[i * d + j] += t * dir[j] / norm;
        }
    }
    ds
}

/// SUSY: d=19, two heavily-overlapping populations (signal/background),
/// each with internal substructure — the hardest overlap regime.
fn susy(rng: &mut Rng, n: usize) -> Dataset {
    let d = 19;
    let mut comps = Vec::new();
    for pop in 0..2 {
        let base: Vec<f64> = (0..d).map(|_| rng.normal() * (0.8 + pop as f64 * 0.4)).collect();
        for sub in 0..5 {
            comps.push(synthetic::Component {
                center: base.iter().map(|&b| b + rng.normal() * 1.0).collect(),
                std: (0..d).map(|_| rng.range(0.8, 1.6)).collect(),
                weight: if sub == 0 { 2.0 } else { 1.0 },
            });
        }
    }
    synthetic::gmm(rng, n, &comps)
}

/// WUY (Web Users Yahoo!): d=5, huge n, heavily skewed cluster sizes and
/// compact well-separated behaviour clusters — BWKM's best regime.
fn wuy(rng: &mut Rng, n: usize) -> Dataset {
    synthetic::random_blobs(rng, n, 5, 20, 0.35, 2.2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        assert_eq!(TABLE1.len(), 5);
        assert_eq!(spec("susy").unwrap().paper_n, 5_000_000);
        assert_eq!(spec("WUY").unwrap().d, 5);
        assert!(spec("nope").is_none());
    }

    #[test]
    fn simulators_have_table1_dims() {
        for s in TABLE1 {
            let ds = simulate(s.name, 0.001, 7).unwrap();
            assert_eq!(ds.d, s.d, "{}", s.name);
            assert!(ds.n >= 1000);
            assert!(ds.is_finite(), "{}", s.name);
        }
    }

    #[test]
    fn simulate_is_deterministic_per_seed() {
        let a = simulate("3RN", 0.002, 3).unwrap();
        let b = simulate("3RN", 0.002, 3).unwrap();
        let c = simulate("3RN", 0.002, 4).unwrap();
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn scale_controls_n() {
        let small = simulate("GS", 0.0005, 1).unwrap();
        let large = simulate("GS", 0.002, 1).unwrap();
        assert!(large.n > small.n);
        assert_eq!(large.n, (4_208_259.0 * 0.002) as usize);
    }
}
