//! Generic synthetic workload generators: Gaussian mixtures with
//! controllable overlap and skew, uniform noise, and noisy-polyline
//! manifolds (road networks). The Table-1 simulators compose these.

use crate::util::Rng;

use super::Dataset;

/// Specification of one mixture component.
#[derive(Clone, Debug)]
pub struct Component {
    pub center: Vec<f64>,
    /// Per-axis standard deviation.
    pub std: Vec<f64>,
    /// Relative weight (need not be normalized).
    pub weight: f64,
}

/// Sample `n` points from a Gaussian mixture.
pub fn gmm(rng: &mut Rng, n: usize, components: &[Component]) -> Dataset {
    assert!(!components.is_empty());
    let d = components[0].center.len();
    let weights: Vec<f64> = components.iter().map(|c| c.weight).collect();
    let cdf = crate::util::Cdf::new(&weights).expect("positive weights");
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        let c = &components[cdf.sample(rng)];
        for j in 0..d {
            data.push(c.center[j] + c.std[j] * rng.normal());
        }
    }
    Dataset::new(data, d)
}

/// `k` random isotropic blobs in `[-10, 10]^d` with std `spread` and
/// mixing weights drawn from a power law with exponent `skew`
/// (skew = 0 → balanced; larger → heavier imbalance, the WUY regime).
pub fn random_blobs(rng: &mut Rng, n: usize, d: usize, k: usize, spread: f64, skew: f64) -> Dataset {
    let comps: Vec<Component> = (0..k)
        .map(|i| Component {
            center: (0..d).map(|_| rng.range(-10.0, 10.0)).collect(),
            std: vec![spread; d],
            weight: 1.0 / (1.0 + i as f64).powf(skew),
        })
        .collect();
    gmm(rng, n, &comps)
}

/// Uniform noise in `[lo, hi]^d` — the outlier/background component.
pub fn uniform(rng: &mut Rng, n: usize, d: usize, lo: f64, hi: f64) -> Dataset {
    let data = (0..n * d).map(|_| rng.range(lo, hi)).collect();
    Dataset::new(data, d)
}

/// Noisy polyline manifold: points scattered around a random-walk polyline
/// of `segments` segments — mimics road-network data (3RN): low intrinsic
/// dimension, curvilinear high-density ridges, cluster boundaries occupying
/// a small fraction of the volume.
pub fn polyline(rng: &mut Rng, n: usize, d: usize, segments: usize, noise: f64) -> Dataset {
    assert!(d >= 2);
    // Random-walk vertices.
    let mut verts = vec![vec![0.0; d]];
    for _ in 0..segments {
        let prev = verts.last().unwrap().clone();
        let step: Vec<f64> = (0..d).map(|_| rng.normal() * 4.0).collect();
        verts.push((0..d).map(|j| prev[j] + step[j]).collect());
    }
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        let s = rng.usize(segments);
        let t = rng.f64();
        for j in 0..d {
            let v = verts[s][j] * (1.0 - t) + verts[s + 1][j] * t;
            data.push(v + rng.normal() * noise);
        }
    }
    Dataset::new(data, d)
}

/// Heavy-tailed mixture: Gaussian blobs plus a `tail_frac` fraction of
/// points with Student-t-like tails (normal / sqrt(chi2/k) approximated by
/// ratio of normals) — the GS/SUSY sensor-physics regime where clusters
/// overlap heavily.
pub fn heavy_tailed_blobs(
    rng: &mut Rng,
    n: usize,
    d: usize,
    k: usize,
    spread: f64,
    tail_frac: f64,
) -> Dataset {
    let base = random_blobs(rng, n, d, k, spread, 0.3);
    let mut data = base.data;
    let n_tail = (n as f64 * tail_frac) as usize;
    for _ in 0..n_tail {
        let i = rng.usize(n);
        for j in 0..d {
            // Fatten the tail: multiply the offset by an inverse-uniform.
            let fat = 1.0 / (rng.f64().max(0.05));
            data[i * d + j] += rng.normal() * spread * fat;
        }
    }
    Dataset::new(data, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn gmm_shapes_and_determinism() {
        let comps = vec![
            Component { center: vec![0.0, 0.0], std: vec![1.0, 1.0], weight: 1.0 },
            Component { center: vec![50.0, 50.0], std: vec![1.0, 1.0], weight: 1.0 },
        ];
        let a = gmm(&mut Rng::new(9), 500, &comps);
        let b = gmm(&mut Rng::new(9), 500, &comps);
        assert_eq!(a.data, b.data);
        assert_eq!(a.n, 500);
        // Points concentrate near the two centers.
        let near = a
            .data
            .chunks(2)
            .filter(|p| {
                let d0 = p[0].hypot(p[1]);
                let d1 = (p[0] - 50.0).hypot(p[1] - 50.0);
                d0 < 6.0 || d1 < 6.0
            })
            .count();
        assert!(near > 480, "near={near}");
    }

    #[test]
    fn blobs_skew_imbalances_clusters() {
        let mut rng = Rng::new(10);
        let ds = random_blobs(&mut rng, 2000, 2, 4, 0.5, 3.0);
        assert_eq!(ds.n, 2000);
        assert!(ds.is_finite());
    }

    #[test]
    fn polyline_lives_near_segments() {
        let mut rng = Rng::new(11);
        let ds = polyline(&mut rng, 300, 3, 8, 0.05);
        assert_eq!(ds.d, 3);
        assert!(ds.is_finite());
    }

    #[test]
    fn prop_generators_finite_and_sized() {
        prop::check("gen-finite", 20, |g| {
            let n = g.int(10, 400);
            let d = g.int(2, 8);
            let k = g.int(1, 6);
            let mut rng = g.rng.fork(1);
            for ds in [
                random_blobs(&mut rng, n, d, k, 0.7, 1.0),
                uniform(&mut rng, n, d, -3.0, 3.0),
                polyline(&mut rng, n, d.max(2), 5, 0.1),
                heavy_tailed_blobs(&mut rng, n, d, k, 0.7, 0.1),
            ] {
                assert_eq!(ds.n, n);
                assert!(ds.is_finite());
            }
        });
    }
}
