//! Dataset substrate: the flat row-major [`Dataset`] container, file
//! loaders (CSV / raw f64), generic synthetic generators, and the
//! simulators that stand in for the paper's Table 1 datasets (see
//! DESIGN.md §4 — no network access, so the UCI/Yahoo originals are
//! replaced by generators that reproduce each dataset's (n, d,
//! boundary-geometry) regime; the CSV loader accepts the originals when
//! available).

pub mod loader;
pub mod simulators;
pub mod synthetic;

pub use simulators::{simulate, DatasetSpec, TABLE1};

/// A dense dataset: `n` rows of dimension `d`, row-major `f64`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub data: Vec<f64>,
    pub n: usize,
    pub d: usize,
}

impl Dataset {
    pub fn new(data: Vec<f64>, d: usize) -> Dataset {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(data.len() % d, 0, "data length {} not a multiple of d={d}", data.len());
        let n = data.len() / d;
        Dataset { data, n, d }
    }

    /// Row accessor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Rows selected by indices, copied into a new flat buffer.
    pub fn gather(&self, idx: &[usize]) -> Dataset {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Dataset::new(data, self.d)
    }

    /// Split row indices into `shards` contiguous ranges (coordinator).
    /// Delegates to the one canonical split rule,
    /// [`crate::kmeans::assign::shard_ranges`] (DESIGN.md §2.5), so the
    /// leader and the engine's sharded backend always agree on ownership.
    pub fn shard_ranges(&self, shards: usize) -> Vec<std::ops::Range<usize>> {
        crate::kmeans::assign::shard_ranges(self.n, shards)
    }

    /// Check for non-finite values (failure-injection guard).
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_gather() {
        let ds = Dataset::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2);
        assert_eq!(ds.n, 3);
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        let g = ds.gather(&[2, 0]);
        assert_eq!(g.data, vec![5.0, 6.0, 1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn rejects_ragged() {
        Dataset::new(vec![1.0, 2.0, 3.0], 2);
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        let ds = Dataset::new(vec![0.0; 10], 1);
        for shards in 1..=12 {
            let ranges = ds.shard_ranges(shards);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, 10);
            let mut prev = 0;
            for r in &ranges {
                assert_eq!(r.start, prev);
                prev = r.end;
            }
        }
    }

    #[test]
    fn finite_guard() {
        let mut ds = Dataset::new(vec![0.0, 1.0], 1);
        assert!(ds.is_finite());
        ds.data[0] = f64::NAN;
        assert!(!ds.is_finite());
    }
}
