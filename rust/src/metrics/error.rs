//! K-means error functions: E^D (paper Eq. 1), the weighted error E^P
//! (§1.2.2.1), and the relative-error score used by the evaluation (Eq. 6).

use super::counter::DistanceCounter;
use crate::geometry::sq_dist;

/// Nearest centroid of `p` among `centroids` (k rows of length d).
/// Returns (index, squared distance). Counts k distances.
#[inline]
pub fn nearest(p: &[f64], centroids: &[f64], d: usize, counter: &DistanceCounter) -> (usize, f64) {
    let k = centroids.len() / d;
    let mut best = (0usize, f64::INFINITY);
    for c in 0..k {
        let dd = sq_dist(p, &centroids[c * d..(c + 1) * d]);
        if dd < best.1 {
            best = (c, dd);
        }
    }
    counter.add(k as u64);
    best
}

/// Two nearest centroids: returns (index of nearest, d1_sq, d2_sq).
/// `d2_sq` is `INFINITY` when only one centroid exists. Counts k distances.
#[inline]
pub fn nearest2(
    p: &[f64],
    centroids: &[f64],
    d: usize,
    counter: &DistanceCounter,
) -> (usize, f64, f64) {
    let k = centroids.len() / d;
    let (mut i1, mut d1, mut d2) = (0usize, f64::INFINITY, f64::INFINITY);
    for c in 0..k {
        let dd = sq_dist(p, &centroids[c * d..(c + 1) * d]);
        if dd < d1 {
            d2 = d1;
            d1 = dd;
            i1 = c;
        } else if dd < d2 {
            d2 = dd;
        }
    }
    counter.add(k as u64);
    (i1, d1, d2)
}

/// Full-dataset K-means error E^D(C) (Eq. 1). Counts n·k distances.
pub fn kmeans_error(data: &[f64], d: usize, centroids: &[f64], counter: &DistanceCounter) -> f64 {
    let n = data.len() / d;
    let mut err = 0.0;
    for i in 0..n {
        let (_, d1) = nearest(&data[i * d..(i + 1) * d], centroids, d, counter);
        err += d1;
    }
    err
}

/// Weighted error E^P(C) over representatives (§1.2.2.1). Counts m·k.
pub fn weighted_error(
    reps: &[f64],
    weights: &[f64],
    d: usize,
    centroids: &[f64],
    counter: &DistanceCounter,
) -> f64 {
    let m = weights.len();
    let mut err = 0.0;
    for i in 0..m {
        let (_, d1) = nearest(&reps[i * d..(i + 1) * d], centroids, d, counter);
        err += weights[i] * d1;
    }
    err
}

/// Relative error of Eq. 6: (E_M - E_best) / E_best.
pub fn relative_error(e: f64, best: f64) -> f64 {
    (e - best) / best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn nearest_and_counts() {
        let c = DistanceCounter::new();
        let centroids = [0.0, 0.0, 10.0, 0.0, 0.0, 10.0]; // k=3, d=2
        let (i, dd) = nearest(&[9.0, 1.0], &centroids, 2, &c);
        assert_eq!(i, 1);
        assert_eq!(dd, 2.0);
        assert_eq!(c.get(), 3);
    }

    #[test]
    fn nearest2_orders() {
        let c = DistanceCounter::new();
        let centroids = [0.0, 0.0, 3.0, 0.0, 100.0, 0.0];
        let (i, d1, d2) = nearest2(&[1.0, 0.0], &centroids, 2, &c);
        assert_eq!(i, 0);
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 4.0);
    }

    #[test]
    fn nearest2_single_centroid() {
        let c = DistanceCounter::new();
        let (i, d1, d2) = nearest2(&[1.0], &[0.0], 1, &c);
        assert_eq!(i, 0);
        assert_eq!(d1, 1.0);
        assert!(d2.is_infinite());
    }

    #[test]
    fn error_counts_exactly_nk() {
        let c = DistanceCounter::new();
        let data: Vec<f64> = (0..20).map(|x| x as f64).collect(); // n=10, d=2
        let centroids = [0.0, 0.0, 5.0, 5.0];
        let _ = kmeans_error(&data, 2, &centroids, &c);
        assert_eq!(c.get(), 10 * 2);
    }

    #[test]
    fn prop_weighted_error_of_unit_weights_matches_full() {
        prop::check("weq", 30, |g| {
            let n = g.int(1, 60);
            let d = g.int(1, 4);
            let k = g.int(1, 5);
            let data = g.cloud(n, d, 2.0);
            let cent = g.cloud(k, d, 2.0);
            let c1 = DistanceCounter::new();
            let c2 = DistanceCounter::new();
            let e1 = kmeans_error(&data, d, &cent, &c1);
            let w = vec![1.0; n];
            let e2 = weighted_error(&data, &w, d, &cent, &c2);
            assert!((e1 - e2).abs() <= 1e-9 * e1.abs().max(1.0));
            assert_eq!(c1.get(), c2.get());
        });
    }
}
