//! Measurement substrate: exact distance-computation accounting (the
//! paper's cost metric), the error functions of Eq. 1 / Eq. 6, and the
//! approximate regime's measured quality record (DESIGN.md §2.9).

pub mod counter;
pub mod error;
pub mod quality;

pub use counter::{Budget, DistanceCounter};
pub use error::{kmeans_error, nearest, nearest2, relative_error, weighted_error};
pub use quality::QualityGap;
