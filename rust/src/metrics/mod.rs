//! Measurement substrate: exact distance-computation accounting (the
//! paper's cost metric) and the error functions of Eq. 1 / Eq. 6.

pub mod counter;
pub mod error;

pub use counter::{Budget, DistanceCounter};
pub use error::{kmeans_error, nearest, nearest2, relative_error, weighted_error};
