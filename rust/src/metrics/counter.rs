//! Exact distance-computation accounting — the x-axis of every figure in
//! the paper's evaluation (§3).
//!
//! The counter is an `AtomicU64` so the sharded coordinator's workers can
//! tick it concurrently; single-threaded hot loops batch their increments
//! (`add(nk)` once per assignment pass) so the accounting costs nothing.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone counter of Euclidean-distance computations.
#[derive(Debug, Default)]
pub struct DistanceCounter {
    count: AtomicU64,
}

impl DistanceCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` distance computations.
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    /// Total distances recorded so far.
    #[inline]
    pub fn get(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Reset to zero (between repetitions).
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }
}

/// A distance budget: the "practical computational criterion" stopping rule
/// of §2.4.2 ("set a maximum number of distances and stop when exceeded")
/// and the per-method cap used by the benchmark harness ("we limit the
/// maximum number of distance computations to the minimum required by the
/// benchmark algorithms").
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub max_distances: u64,
}

impl Budget {
    pub fn unlimited() -> Budget {
        Budget { max_distances: u64::MAX }
    }

    pub fn of(max_distances: u64) -> Budget {
        Budget { max_distances }
    }

    #[inline]
    pub fn exceeded(&self, counter: &DistanceCounter) -> bool {
        counter.get() >= self.max_distances
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_resets() {
        let c = DistanceCounter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn budget_trips() {
        let c = DistanceCounter::new();
        let b = Budget::of(10);
        assert!(!b.exceeded(&c));
        c.add(10);
        assert!(b.exceeded(&c));
        assert!(!Budget::unlimited().exceeded(&c));
    }

    #[test]
    fn concurrent_ticks() {
        let c = std::sync::Arc::new(DistanceCounter::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.add(1);
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
