//! Exact distance-computation accounting — the x-axis of every figure in
//! the paper's evaluation (§3).
//!
//! The counter is an `AtomicU64` so the sharded coordinator's workers can
//! tick it concurrently; single-threaded hot loops batch their increments
//! (`add(nk)` once per assignment pass) so the accounting costs nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Maximum retained accounting notes per counter (see
/// [`DistanceCounter::note`]).
pub const NOTE_CAP: usize = 8192;

/// Monotone counter of Euclidean-distance computations, plus a free-form
/// note log for accounting *annotations* (DESIGN.md §2.4): adaptive
/// backends — `kmeans::assign::AutoAssigner` — record which engine served
/// each step here, so a bench report can print the per-step choice next to
/// the count it produced. Notes never affect the count.
#[derive(Debug, Default)]
pub struct DistanceCounter {
    count: AtomicU64,
    notes: Mutex<Vec<String>>,
    pinned: Mutex<Vec<String>>,
}

impl DistanceCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` distance computations.
    #[inline]
    pub fn add(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }

    /// Total distances recorded so far.
    #[inline]
    pub fn get(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Attach an accounting annotation (e.g. `AutoAssigner`'s per-step
    /// backend choice) to this counter's report. The log is capped at
    /// [`NOTE_CAP`] entries (far above any single run's step count) so a
    /// long-lived counter that is never `reset()` cannot grow without
    /// bound; once full, one truncation marker is appended and further
    /// notes are dropped — the structured tallies (e.g.
    /// `AutoAssigner::choice_counts`) remain exact regardless.
    pub fn note(&self, note: String) {
        let mut notes = self.notes.lock().expect("counter note lock poisoned");
        match notes.len().cmp(&NOTE_CAP) {
            std::cmp::Ordering::Less => notes.push(note),
            std::cmp::Ordering::Equal => {
                notes.push(format!("…note log capped at {NOTE_CAP} entries (reset() clears)"));
            }
            std::cmp::Ordering::Greater => {}
        }
    }

    /// Attach a **pinned** annotation: once-per-run summaries (the
    /// end-of-run `gap[backend]` quality report) that conformance suites
    /// assert appear exactly once. Pinned notes live in a reserved slot
    /// outside the [`NOTE_CAP`] budget, so a run whose per-step log
    /// overflows the cap cannot drop them.
    pub fn note_pinned(&self, note: String) {
        self.pinned.lock().expect("counter note lock poisoned").push(note);
    }

    /// Pinned annotations only (reserved-slot summaries).
    pub fn pinned_notes(&self) -> Vec<String> {
        self.pinned.lock().expect("counter note lock poisoned").clone()
    }

    /// All annotations recorded so far: the capped per-step log in order,
    /// then pinned summaries (which are emitted at end-of-run, so this
    /// preserves the report's chronological reading).
    pub fn notes(&self) -> Vec<String> {
        let mut out = self.notes.lock().expect("counter note lock poisoned").clone();
        out.extend(self.pinned.lock().expect("counter note lock poisoned").iter().cloned());
        out
    }

    /// Reset count *and* notes (capped and pinned) to empty (between
    /// repetitions).
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.notes.lock().expect("counter note lock poisoned").clear();
        self.pinned.lock().expect("counter note lock poisoned").clear();
    }
}

/// A distance budget: the "practical computational criterion" stopping rule
/// of §2.4.2 ("set a maximum number of distances and stop when exceeded")
/// and the per-method cap used by the benchmark harness ("we limit the
/// maximum number of distance computations to the minimum required by the
/// benchmark algorithms").
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub max_distances: u64,
}

impl Budget {
    pub fn unlimited() -> Budget {
        Budget { max_distances: u64::MAX }
    }

    pub fn of(max_distances: u64) -> Budget {
        Budget { max_distances }
    }

    #[inline]
    pub fn exceeded(&self, counter: &DistanceCounter) -> bool {
        counter.get() >= self.max_distances
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_resets() {
        let c = DistanceCounter::new();
        c.add(5);
        c.add(7);
        assert_eq!(c.get(), 12);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn notes_record_and_reset() {
        let c = DistanceCounter::new();
        assert!(c.notes().is_empty());
        c.note("auto[1]: bounded".into());
        c.note("auto[2]: serial".into());
        assert_eq!(c.notes(), vec!["auto[1]: bounded", "auto[2]: serial"]);
        c.add(3);
        c.reset();
        assert!(c.notes().is_empty());
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn note_log_caps_with_marker_and_reset_reopens() {
        let c = DistanceCounter::new();
        for i in 0..(NOTE_CAP + 50) {
            c.note(format!("n{i}"));
        }
        let notes = c.notes();
        assert_eq!(notes.len(), NOTE_CAP + 1, "cap plus one truncation marker");
        assert!(notes.last().unwrap().contains("capped"));
        c.reset();
        c.note("fresh".into());
        assert_eq!(c.notes(), vec!["fresh"]);
    }

    #[test]
    fn pinned_notes_survive_cap_flood() {
        // Regression: the end-of-run `gap[...]` summary used to go through
        // the capped log, so a run with > NOTE_CAP per-step notes dropped
        // exactly the note the conformance suites pin as once-per-run.
        let c = DistanceCounter::new();
        for i in 0..(NOTE_CAP + 100) {
            c.note(format!("auto[{i}]: serial"));
        }
        c.note_pinned("gap[closure]: rel_gap=1.25e-3".into());
        let notes = c.notes();
        assert_eq!(notes.len(), NOTE_CAP + 2, "cap + marker + pinned");
        assert_eq!(notes.last().unwrap(), "gap[closure]: rel_gap=1.25e-3");
        assert_eq!(c.pinned_notes(), vec!["gap[closure]: rel_gap=1.25e-3"]);
        assert_eq!(
            notes.iter().filter(|n| n.starts_with("gap[")).count(),
            1,
            "pinned summary appears exactly once"
        );
        c.reset();
        assert!(c.notes().is_empty());
        assert!(c.pinned_notes().is_empty());
    }

    #[test]
    fn pinned_notes_append_after_capped_log() {
        let c = DistanceCounter::new();
        c.note("auto[1]: bounded".into());
        c.note_pinned("gap[sampled]: rel_gap=0e0".into());
        c.note("auto[2]: serial".into());
        // Pinned entries read last regardless of interleaving: they are
        // end-of-run summaries.
        assert_eq!(
            c.notes(),
            vec!["auto[1]: bounded", "auto[2]: serial", "gap[sampled]: rel_gap=0e0"]
        );
    }

    #[test]
    fn budget_trips() {
        let c = DistanceCounter::new();
        let b = Budget::of(10);
        assert!(!b.exceeded(&c));
        c.add(10);
        assert!(b.exceeded(&c));
        assert!(!Budget::unlimited().exceeded(&c));
    }

    #[test]
    fn concurrent_ticks() {
        let c = std::sync::Arc::new(DistanceCounter::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.add(1);
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
