//! The approximate regime's quality record (DESIGN.md §2.9).
//!
//! A [`QualityGap`] is what an approximate backend (the closure assigner
//! or the sampled stepper — `kmeans::assign` / `kmeans::weighted_lloyd`)
//! returns from its `quality_gap` hook: the measured weighted error of
//! its current approximation next to an exact pass over the same inputs,
//! plus the backend's own health signals. It is a pure data record —
//! measurement lives with the backends (they own the state being
//! measured), and the measurement itself is *uncounted* instrumentation
//! (§2.4: private counters, nothing charged to the run's account).
//!
//! Every approximate run surfaces its final gap as a counter note (the
//! `"gap[...]"` prefix, pinned by the conformance suite), so the
//! accounting report shows not just what was paid but what the discount
//! cost in solution quality.

/// Measured E-vs-exact of one approximate backend on one input set.
#[derive(Clone, Copy, Debug)]
pub struct QualityGap {
    /// Which approximation produced this record: `"closure"` or
    /// `"sampled"`.
    pub backend: &'static str,
    /// Weighted error of the approximate assignment. Both errors are
    /// accumulated in row order through the canonical kernel, so
    /// `approx_err ≥ exact_err` holds exactly, not just approximately.
    pub approx_err: f64,
    /// Weighted error of the exact assignment on the same inputs.
    pub exact_err: f64,
    /// Backend health: the closure backend's candidate-hit rate, or the
    /// sampled stepper's row coverage of its last call. In [0, 1].
    pub hit_rate: f64,
    /// Cumulative exact fallbacks the backend took (cold primes
    /// included).
    pub fallbacks: u64,
}

impl QualityGap {
    /// Relative gap `(approx − exact) / exact`, clamped to ≥ 0 and
    /// defined as 0 when the exact error is not positive (a perfect fit
    /// has nothing to degrade).
    pub fn rel_gap(&self) -> f64 {
        if self.exact_err > 0.0 {
            ((self.approx_err - self.exact_err) / self.exact_err).max(0.0)
        } else {
            0.0
        }
    }

    /// The counter-note form. The `"gap["` prefix is part of the §2.9
    /// contract (tests and the CLI's report filter key on it).
    pub fn note(&self) -> String {
        format!(
            "gap[{}]: E_approx={:.6e} E_exact={:.6e} rel={:.3e} hit={:.1}% fallbacks={}",
            self.backend,
            self.approx_err,
            self.exact_err,
            self.rel_gap(),
            self.hit_rate * 100.0,
            self.fallbacks
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_gap_clamps_and_handles_zero_exact() {
        let g = QualityGap {
            backend: "closure",
            approx_err: 12.0,
            exact_err: 10.0,
            hit_rate: 0.9,
            fallbacks: 1,
        };
        assert!((g.rel_gap() - 0.2).abs() < 1e-15);
        let zero = QualityGap { exact_err: 0.0, approx_err: 0.0, ..g };
        assert_eq!(zero.rel_gap(), 0.0);
        let below = QualityGap { approx_err: 9.0, ..g };
        assert_eq!(below.rel_gap(), 0.0, "clamped: gaps never report negative");
    }

    #[test]
    fn note_carries_the_pinned_prefix_and_fields() {
        let g = QualityGap {
            backend: "sampled",
            approx_err: 2.0,
            exact_err: 1.0,
            hit_rate: 0.25,
            fallbacks: 3,
        };
        let n = g.note();
        assert!(n.starts_with("gap[sampled]: "), "{n}");
        assert!(n.contains("rel=1.000e0"), "{n}");
        assert!(n.contains("hit=25.0%"), "{n}");
        assert!(n.contains("fallbacks=3"), "{n}");
    }
}
