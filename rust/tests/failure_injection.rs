//! Failure injection: degenerate datasets, hostile parameters and broken
//! inputs must fail loudly (documented panics / Result errors) or degrade
//! gracefully — never loop forever or return garbage silently.

use anyhow::Result;
use bwkm::bwkm::{BwkmCfg, RefineSource};
use bwkm::coordinator::{StreamSource, StreamingBwkm};
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::{simulate, Dataset};
use bwkm::kmeans::init::{forgy, kmeanspp};
use bwkm::kmeans::{lloyd, LloydCfg};
use bwkm::metrics::{Budget, DistanceCounter};
use bwkm::util::Rng;

#[test]
fn identical_points_everywhere() {
    // n identical points, k > distinct values: everything must terminate
    // with the degenerate (correct) answer.
    let ds = Dataset::new(vec![2.5; 200], 1);
    let c = DistanceCounter::new();
    let cents = kmeanspp(&ds.data, 1, 4, &mut Rng::new(1), &c);
    assert_eq!(cents, vec![2.5; 4]);
    let l = lloyd(&ds.data, 1, &cents, &LloydCfg::default(), &c);
    assert!(l.error < 1e-20);

    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 5;
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(2), &c);
    assert!(out.centroids.iter().all(|&x| (x - 2.5).abs() < 1e-12));
}

#[test]
#[should_panic(expected = "k=")]
fn forgy_rejects_k_above_n() {
    let data = vec![0.0, 1.0, 2.0];
    forgy(&data, 1, 5, &mut Rng::new(1));
}

#[test]
#[should_panic(expected = "n must be ≥ k")]
fn bwkm_rejects_k_above_n() {
    let ds = Dataset::new(vec![0.0, 1.0], 1);
    let cfg = BwkmCfg::for_dataset(2, 1, 5);
    bwkm::bwkm::run(&ds, 5, &cfg, &mut Rng::new(1), &DistanceCounter::new());
}

#[test]
fn zero_budget_still_terminates_with_valid_output() {
    let ds = simulate("3RN", 0.003, 1).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.budget = Budget::of(1); // trips immediately after the first pass
    cfg.max_outer = 100;
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(1), &c);
    assert_eq!(out.centroids.len(), 3 * ds.d);
    assert!(out.centroids.iter().all(|x| x.is_finite()));
    assert!(out.trace.len() <= 2);
}

#[test]
fn nan_dataset_detected_by_guard() {
    let mut ds = simulate("WUY", 0.0005, 1).unwrap();
    ds.data[7] = f64::NAN;
    assert!(!ds.is_finite());
    // The CLI refuses such data.
    let p = std::env::temp_dir().join(format!("bwkm_nan_{}.csv", std::process::id()));
    std::fs::write(&p, "1.0,2.0\nnan,1.0\n").unwrap();
    // loader parses "nan" as f64::NAN; the run command must bail.
    let err = bwkm::cli::main(&[
        "run".into(),
        format!("dataset=path:{}", p.display()),
        "k=1".into(),
        "method=fkm".into(),
    ]);
    assert!(err.is_err(), "NaN dataset must be rejected");
    std::fs::remove_file(&p).ok();
}

#[test]
fn outlier_heavy_data_stays_finite() {
    // A single absurd outlier must not break partitions or centroids.
    let mut g = Rng::new(3);
    let mut data: Vec<f64> = (0..1000).map(|_| g.normal()).collect();
    data[500] = 1e12;
    let ds = Dataset::new(data, 2);
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 8;
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(4), &c);
    assert!(out.centroids.iter().all(|x| x.is_finite()));
}

#[test]
fn single_point_dataset() {
    let ds = Dataset::new(vec![3.0, 4.0], 2);
    let cfg = BwkmCfg::for_dataset(1, 2, 1);
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 1, &cfg, &mut Rng::new(5), &c);
    assert_eq!(out.centroids, vec![3.0, 4.0]);
}

#[test]
fn config_rejects_malformed_values() {
    let mut cfg = bwkm::config::RunConfig::default();
    assert!(cfg.set("scale", "huge").is_err());
    assert!(cfg.set("use_pjrt", "perhaps").is_err());
    assert!(cfg.set("method", "definitely-not").is_err());
    // Unknown keys are collected, not fatal (forward compatibility).
    cfg.set("future_knob", "1").unwrap();
}

#[test]
fn manifest_corruption_is_loud() {
    use bwkm::runtime::Manifest;
    assert!(Manifest::parse("wlloyd_step\tnot_a_number\t4\t4\tf\n").is_err());
    assert!(Manifest::parse("").is_err());
}

// ---------------------------------------------------------------------------
// Streaming failure injection (DESIGN.md §5.1 failure contract): broken
// chunked sources must surface as clean `Err`s — no panic, no partial
// statistics committed.
// ---------------------------------------------------------------------------

fn stream_cfg(n: usize, d: usize, k: usize) -> BwkmCfg {
    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 3;
    cfg
}

#[test]
fn streaming_truncated_file_is_clean_err() {
    let ds = Dataset::new((0..300).map(|x| x as f64).collect(), 3);
    let p = std::env::temp_dir()
        .join(format!("bwkm_fail_trunc_{}.bin", std::process::id()));
    save_bin(&ds, &p).unwrap();
    // Chop half the payload off: the header promises 100 rows.
    let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
    f.set_len(16 + 50 * 3 * 8).unwrap();
    drop(f);
    let mut sb = StreamingBwkm::new(BinChunks::opener(&p, 16), 3);
    let c = DistanceCounter::new();
    let out = sb.run(3, &stream_cfg(100, 3, 3), &mut Rng::new(1), &c);
    assert!(out.is_err(), "truncated source must be a clean Err");
    std::fs::remove_file(&p).ok();
}

#[test]
fn streaming_mid_stream_read_error_is_clean_err() {
    // A chunk-level IO error inside the stream (not at open).
    let open = || -> Result<Vec<Result<Vec<f64>>>> {
        Ok(vec![
            Ok(vec![0.0, 0.0, 1.0, 1.0]),
            Err(anyhow::anyhow!("disk vanished")),
            Ok(vec![2.0, 2.0]),
        ])
    };
    let mut sb = StreamingBwkm::new(open, 2);
    let c = DistanceCounter::new();
    let out = sb.run(2, &stream_cfg(3, 2, 2), &mut Rng::new(1), &c);
    assert!(out.is_err(), "mid-stream read error must be a clean Err");
}

#[test]
fn streaming_ragged_chunk_is_clean_err() {
    // 5 values with d=2: a short read that is not a whole number of rows
    // must never be silently dropped.
    let open = || -> Result<Vec<Result<Vec<f64>>>> {
        Ok(vec![Ok(vec![0.0, 0.0, 1.0, 1.0]), Ok(vec![2.0, 2.0, 3.0])])
    };
    let mut sb = StreamingBwkm::new(open, 2);
    let c = DistanceCounter::new();
    let out = sb.run(2, &stream_cfg(3, 2, 2), &mut Rng::new(1), &c);
    assert!(out.is_err(), "ragged chunk must be a clean Err");
}

#[test]
fn streaming_shrinking_source_is_clean_err() {
    // The source yields fewer rows from the second pass on: every later
    // pass validates the row count against the first, so the run must
    // fail cleanly instead of computing statistics over a different
    // dataset.
    let data: Vec<f64> = (0..240).map(|x| (x as f64).sin()).collect();
    let mut opens = 0usize;
    let open = move || -> Result<Vec<Result<Vec<f64>>>> {
        opens += 1;
        let upto = if opens == 1 { data.len() } else { data.len() - 2 };
        Ok(data[..upto].chunks(24).map(|c| Ok(c.to_vec())).collect())
    };
    let mut sb = StreamingBwkm::new(open, 2);
    let c = DistanceCounter::new();
    let out = sb.run(3, &stream_cfg(120, 2, 3), &mut Rng::new(2), &c);
    assert!(out.is_err(), "a source that shrinks between passes must be a clean Err");
}

#[test]
fn streaming_failed_refresh_commits_nothing() {
    // Commit-on-success at the RefineSource level: a refresh pass that
    // fails (here: the source shrinks) leaves the previously committed
    // statistics — and therefore reps/weights — untouched.
    let data: Vec<f64> = (0..80).map(|x| x as f64).collect();
    let mut opens = 0usize;
    let open = move || -> Result<Vec<Result<Vec<f64>>>> {
        opens += 1;
        let upto = if opens == 1 { data.len() } else { data.len() - 4 };
        Ok(data[..upto].chunks(10).map(|c| Ok(c.to_vec())).collect())
    };
    let mut src = StreamSource::new(open, 2, 2).unwrap();
    let stats_before = src.stats().clone();
    let (_, weights_before, _) = src.reps_weights();
    src.split(0);
    assert!(src.refresh().is_err(), "the shrunken refresh pass must fail");
    // The committed view is still the pre-split one, not a half-updated
    // mixture: no statistics were attributed to the new spatial children.
    assert_eq!(src.stats().counts, stats_before.counts, "no partial stats committed");
    assert_eq!(src.stats().rows, stats_before.rows);
    assert_eq!(
        src.stats().reps_weights(2).1,
        weights_before,
        "weights unchanged after failed refresh"
    );
}

#[test]
fn streaming_non_finite_value_is_clean_err() {
    // The in-memory CLI path refuses NaN datasets; the streaming path
    // must too (a NaN would silently poison bbox folds and tree
    // descents) — caught on the very first (extent) pass.
    let open = || -> Result<Vec<Result<Vec<f64>>>> {
        Ok(vec![Ok(vec![0.0, 0.0, f64::NAN, 1.0]), Ok(vec![2.0, 2.0])])
    };
    let mut sb = StreamingBwkm::new(open, 2);
    let c = DistanceCounter::new();
    let out = sb.run(2, &stream_cfg(3, 2, 2), &mut Rng::new(1), &c);
    assert!(out.is_err(), "non-finite stream values must be a clean Err");
}

#[test]
fn streaming_empty_stream_is_clean_err() {
    let mut sb = StreamingBwkm::new(|| Ok(Vec::<Result<Vec<f64>>>::new()), 4);
    let c = DistanceCounter::new();
    let out = sb.run(1, &stream_cfg(1, 4, 1), &mut Rng::new(3), &c);
    assert!(out.is_err());
}

// ---------------------------------------------------------------------------
// Model-store failure injection (DESIGN.md §5.2 failure contract): broken
// store files and mismatched resume/ingest inputs must be clean `Err`s with
// the offending field named — never a panic, never a silently wrong model.
// ---------------------------------------------------------------------------

/// A small fitted model plus the dataset and configuration it came from.
fn store_fixture() -> (Dataset, BwkmCfg, bwkm::store::Model) {
    let ds = simulate("3RN", 0.002, 11).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 2;
    cfg.eval_full_error = false;
    let c = DistanceCounter::new();
    let mut rng = Rng::new(9);
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
    let model = bwkm::store::Model::from_run(&out, &cfg, &rng, &c);
    (ds, cfg, model)
}

/// Recompute the trailing checksum after deliberately tampering with the
/// payload, so the test exercises the *field* validation, not the checksum.
fn reseal(mut bytes: Vec<u8>) -> Vec<u8> {
    let n = bytes.len();
    let sum = bwkm::store::format::fnv1a(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
    bytes
}

#[test]
fn store_truncated_file_is_clean_err() {
    let (_, _, model) = store_fixture();
    let bytes = model.to_bytes();
    for cut in [0, 3, bytes.len() / 2, bytes.len() - 1] {
        let err = bwkm::store::Model::from_bytes(&bytes[..cut]);
        assert!(err.is_err(), "truncation at {cut} bytes must be a clean Err");
    }
}

#[test]
fn store_bit_corruption_is_a_checksum_err() {
    let (_, _, model) = store_fixture();
    let mut bytes = model.to_bytes();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let err = bwkm::store::Model::from_bytes(&bytes).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");
}

#[test]
fn store_bad_magic_is_clean_err() {
    let (_, _, model) = store_fixture();
    let mut bytes = model.to_bytes();
    bytes[..8].copy_from_slice(b"NOTBWKM\0");
    let err = bwkm::store::Model::from_bytes(&reseal(bytes)).unwrap_err().to_string();
    assert!(err.contains("not a BWKM model store"), "{err}");
}

#[test]
fn store_newer_format_version_is_rejected() {
    let (_, _, model) = store_fixture();
    let mut bytes = model.to_bytes();
    let next = bwkm::store::format::VERSION + 1;
    bytes[8..12].copy_from_slice(&next.to_le_bytes());
    let err = bwkm::store::Model::from_bytes(&reseal(bytes)).unwrap_err().to_string();
    assert!(err.contains("newer release"), "forward-compat refusal missing: {err}");
}

#[test]
fn store_resume_rejects_config_drift() {
    let (ds, cfg, model) = store_fixture();
    let mut drifted = cfg.clone();
    drifted.wl.max_iters += 1; // any digest-covered knob
    let err = bwkm::store::resume(&model, &ds, &drifted, &mut Rng::new(1), &DistanceCounter::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("digest"), "{err}");
    // Raising only the caps is the sanctioned change and passes the gate.
    let mut raised = cfg.clone();
    raised.max_outer += 2;
    raised.budget = Budget::of(u64::MAX);
    assert!(bwkm::store::resume(&model, &ds, &raised, &mut Rng::new(1), &DistanceCounter::new())
        .is_ok());
}

#[test]
fn store_resume_rejects_a_mismatched_dataset() {
    let (ds, cfg, model) = store_fixture();
    // Wrong dimension: refused before any work.
    let err = bwkm::store::resume(
        &model,
        &Dataset::new(vec![0.0; (ds.d + 1) * 4], ds.d + 1),
        &cfg,
        &mut Rng::new(1),
        &DistanceCounter::new(),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("dimension"), "{err}");
    // Wrong row count: refused.
    let short = Dataset::new(ds.data[..ds.d * (ds.n - 1)].to_vec(), ds.d);
    let err = bwkm::store::resume(&model, &short, &cfg, &mut Rng::new(1), &DistanceCounter::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("rows"), "{err}");
    // Same shape, different data: the per-cell occupancy check trips.
    let other = simulate("3RN", 0.002, 12).unwrap();
    assert_eq!((other.n, other.d), (ds.n, ds.d));
    let err = bwkm::store::resume(&model, &other, &cfg, &mut Rng::new(1), &DistanceCounter::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("does not match the stored model"), "{err}");
}

#[test]
fn store_ingest_rejects_mismatched_inputs() {
    let (_, cfg, model) = store_fixture();
    // Wrong batch dimension.
    let mut m = model.clone();
    let err = bwkm::store::ingest(
        &mut m,
        &Dataset::new(vec![0.0; (m.d + 1) * 2], m.d + 1),
        &cfg,
        &DistanceCounter::new(),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("dimension"), "{err}");
    // Non-finite batch rows.
    let mut m = model.clone();
    let mut row = vec![0.0; m.d];
    row[0] = f64::NAN;
    let err = bwkm::store::ingest(&mut m, &Dataset::new(row, m.d), &cfg, &DistanceCounter::new())
        .unwrap_err()
        .to_string();
    assert!(err.contains("non-finite"), "{err}");
    // Config drift is refused just like on resume.
    let mut drifted = cfg.clone();
    drifted.wl.max_iters += 1;
    let mut m = model.clone();
    let err = bwkm::store::ingest(
        &mut m,
        &Dataset::new(vec![0.0; m.d], m.d),
        &drifted,
        &DistanceCounter::new(),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("digest"), "{err}");
}
