//! Failure injection: degenerate datasets, hostile parameters and broken
//! inputs must fail loudly (documented panics / Result errors) or degrade
//! gracefully — never loop forever or return garbage silently.

use bwkm::bwkm::BwkmCfg;
use bwkm::data::{Dataset, simulate};
use bwkm::kmeans::init::{forgy, kmeanspp};
use bwkm::kmeans::{lloyd, LloydCfg};
use bwkm::metrics::{Budget, DistanceCounter};
use bwkm::util::Rng;

#[test]
fn identical_points_everywhere() {
    // n identical points, k > distinct values: everything must terminate
    // with the degenerate (correct) answer.
    let ds = Dataset::new(vec![2.5; 200], 1);
    let c = DistanceCounter::new();
    let cents = kmeanspp(&ds.data, 1, 4, &mut Rng::new(1), &c);
    assert_eq!(cents, vec![2.5; 4]);
    let l = lloyd(&ds.data, 1, &cents, &LloydCfg::default(), &c);
    assert!(l.error < 1e-20);

    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 5;
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(2), &c);
    assert!(out.centroids.iter().all(|&x| (x - 2.5).abs() < 1e-12));
}

#[test]
#[should_panic(expected = "k=")]
fn forgy_rejects_k_above_n() {
    let data = vec![0.0, 1.0, 2.0];
    forgy(&data, 1, 5, &mut Rng::new(1));
}

#[test]
#[should_panic(expected = "n must be ≥ k")]
fn bwkm_rejects_k_above_n() {
    let ds = Dataset::new(vec![0.0, 1.0], 1);
    let cfg = BwkmCfg::for_dataset(2, 1, 5);
    bwkm::bwkm::run(&ds, 5, &cfg, &mut Rng::new(1), &DistanceCounter::new());
}

#[test]
fn zero_budget_still_terminates_with_valid_output() {
    let ds = simulate("3RN", 0.003, 1).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.budget = Budget::of(1); // trips immediately after the first pass
    cfg.max_outer = 100;
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(1), &c);
    assert_eq!(out.centroids.len(), 3 * ds.d);
    assert!(out.centroids.iter().all(|x| x.is_finite()));
    assert!(out.trace.len() <= 2);
}

#[test]
fn nan_dataset_detected_by_guard() {
    let mut ds = simulate("WUY", 0.0005, 1).unwrap();
    ds.data[7] = f64::NAN;
    assert!(!ds.is_finite());
    // The CLI refuses such data.
    let p = std::env::temp_dir().join(format!("bwkm_nan_{}.csv", std::process::id()));
    std::fs::write(&p, "1.0,2.0\nnan,1.0\n").unwrap();
    // loader parses "nan" as f64::NAN; the run command must bail.
    let err = bwkm::cli::main(&[
        "run".into(),
        format!("dataset=path:{}", p.display()),
        "k=1".into(),
        "method=fkm".into(),
    ]);
    assert!(err.is_err(), "NaN dataset must be rejected");
    std::fs::remove_file(&p).ok();
}

#[test]
fn outlier_heavy_data_stays_finite() {
    // A single absurd outlier must not break partitions or centroids.
    let mut g = Rng::new(3);
    let mut data: Vec<f64> = (0..1000).map(|_| g.normal()).collect();
    data[500] = 1e12;
    let ds = Dataset::new(data, 2);
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 8;
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(4), &c);
    assert!(out.centroids.iter().all(|x| x.is_finite()));
}

#[test]
fn single_point_dataset() {
    let ds = Dataset::new(vec![3.0, 4.0], 2);
    let cfg = BwkmCfg::for_dataset(1, 2, 1);
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 1, &cfg, &mut Rng::new(5), &c);
    assert_eq!(out.centroids, vec![3.0, 4.0]);
}

#[test]
fn config_rejects_malformed_values() {
    let mut cfg = bwkm::config::RunConfig::default();
    assert!(cfg.set("scale", "huge").is_err());
    assert!(cfg.set("use_pjrt", "perhaps").is_err());
    assert!(cfg.set("method", "definitely-not").is_err());
    // Unknown keys are collected, not fatal (forward compatibility).
    cfg.set("future_knob", "1").unwrap();
}

#[test]
fn manifest_corruption_is_loud() {
    use bwkm::runtime::Manifest;
    assert!(Manifest::parse("wlloyd_step\tnot_a_number\t4\t4\tf\n").is_err());
    assert!(Manifest::parse("").is_err());
}
