//! Integration: the PJRT-executed artifacts (L2 weighted-Lloyd step over
//! the L1 Pallas kernel) must match the native Rust hot path.
//!
//! Requires `make artifacts` plus a real `xla` binding (the offline build
//! vendors a stub — DESIGN.md §4); when the runtime cannot open, each
//! test skips with a note instead of failing, per the degrade-gracefully
//! policy.

use bwkm::data::simulate;
use bwkm::kmeans::{NativeStepper, Stepper};
use bwkm::metrics::DistanceCounter;
use bwkm::runtime::{PjrtStepper, Runtime};
use bwkm::util::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            // In an artifacts-equipped CI job, set BWKM_REQUIRE_PJRT=1 so a
            // runtime regression fails loudly instead of skipping the suite.
            if std::env::var("BWKM_REQUIRE_PJRT").is_ok() {
                panic!("BWKM_REQUIRE_PJRT set but the PJRT runtime failed to open: {e}");
            }
            eprintln!("skipping PJRT test: {e} (run `make artifacts` with the real xla crate)");
            None
        }
    }
}

#[test]
fn step_matches_native_small() {
    let mut rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let mut rng = Rng::new(1);
    for &(m, k, d) in &[(50usize, 3usize, 2usize), (300, 9, 17), (1500, 27, 19), (3000, 4, 4)] {
        let reps: Vec<f64> = (0..m * d).map(|_| rng.normal() * 3.0).collect();
        let weights: Vec<f64> = (0..m).map(|_| 1.0 + rng.usize(30) as f64).collect();
        let cents: Vec<f64> = (0..k * d).map(|_| rng.normal() * 3.0).collect();

        let device = rt.wlloyd_step(&reps, &weights, d, &cents).expect("device step");
        let c = DistanceCounter::new();
        let native = NativeStepper::new().step(&reps, &weights, d, &cents, &c);

        // f32 artifacts vs f64 host: compare within f32 tolerance.
        let mut mismatched_assign = 0usize;
        for i in 0..m {
            if device.assign[i] != native.assign[i] {
                // Tolerate ties that f32 resolves differently.
                let gap = (native.d2[i].sqrt() - native.d1[i].sqrt()).abs();
                assert!(gap < 1e-3, "assign mismatch at {i} with clear gap {gap}");
                mismatched_assign += 1;
            }
            assert!(
                (device.d1[i] - native.d1[i]).abs() < 1e-2 * (1.0 + native.d1[i]),
                "d1 mismatch at {i}: {} vs {}",
                device.d1[i],
                native.d1[i]
            );
        }
        assert!(mismatched_assign * 50 <= m + 50, "too many tie mismatches");
        assert!(
            (device.werr - native.werr).abs() < 1e-3 * native.werr.max(1.0),
            "werr {} vs {}",
            device.werr,
            native.werr
        );
        for (a, b) in device.centroids.iter().zip(&native.centroids) {
            assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "centroid {a} vs {b}");
        }
    }
}

#[test]
fn assign_err_matches_host_eval_chunked() {
    let mut rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    // > 16384 rows forces multi-chunk execution.
    let ds = simulate("WUY", 0.0005, 3).unwrap();
    assert!(ds.n > 16384, "need a multi-chunk dataset, got {}", ds.n);
    let mut rng = Rng::new(2);
    let k = 9;
    let cents: Vec<f64> = (0..k * ds.d).map(|_| rng.normal() * 3.0).collect();

    let (assign, sse) = rt.assign_err(&ds.data, ds.d, &cents).expect("device assign_err");
    assert_eq!(assign.len(), ds.n);
    let c = DistanceCounter::new();
    let host = bwkm::metrics::kmeans_error(&ds.data, ds.d, &cents, &c);
    let rel = (sse - host).abs() / host;
    assert!(rel < 1e-3, "device {sse} vs host {host} (rel {rel})");
}

#[test]
fn masked_centroids_never_selected_on_device() {
    let mut rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    // k=3 runs in the kcap=4 variant: the padded 4th slot must never win.
    let mut rng = Rng::new(4);
    let (m, k, d) = (200usize, 3usize, 4usize);
    let reps: Vec<f64> = (0..m * d).map(|_| rng.normal()).collect();
    let weights = vec![1.0; m];
    let cents: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
    let out = rt.wlloyd_step(&reps, &weights, d, &cents).unwrap();
    assert!(out.assign.iter().all(|&a| (a as usize) < k));
    // d2 is a real distance (not the mask sentinel) since k >= 2.
    assert!(out.d2.iter().all(|&x| x.is_finite()));
}

#[test]
fn bwkm_runs_end_to_end_on_pjrt() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let ds = simulate("3RN", 0.003, 7).unwrap();
    let mut cfg = bwkm::bwkm::BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 5;
    cfg.eval_full_error = true;
    let counter = DistanceCounter::new();
    let mut stepper = PjrtStepper::new(rt);
    let out = bwkm::bwkm::run_with(&mut stepper, &ds, 3, &cfg, &mut Rng::new(5), &counter);
    assert!(stepper.device_steps > 0, "device path unused");
    assert_eq!(out.centroids.len(), 3 * ds.d);
    // Error decreases across the trace.
    let first = out.trace.first().unwrap().full_error.unwrap();
    let last = out.trace.last().unwrap().full_error.unwrap();
    assert!(last <= first * (1.0 + 1e-6), "{first} -> {last}");
}

#[test]
fn fixed_point_is_stable_on_device() {
    let mut rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    // Converged config: reps at ±1 around two centroids.
    let reps = vec![-1.0, 0.0, 1.0, 0.0, 9.0, 0.0, 11.0, 0.0];
    let weights = vec![2.0, 2.0, 3.0, 3.0];
    let cents = vec![0.0, 0.0, 10.0, 0.0];
    let out = rt.wlloyd_step(&reps, &weights, 2, &cents).unwrap();
    for (a, b) in out.centroids.iter().zip(&cents) {
        assert!((a - b).abs() < 1e-5, "fixed point moved: {a} vs {b}");
    }
}
