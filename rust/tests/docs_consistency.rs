//! Docs-consistency gate (DESIGN.md §6): every `DESIGN.md §…` citation in
//! the Rust and Python sources must resolve to a real section header of
//! the repository-root `DESIGN.md`, so the architecture contract the code
//! refers to can never silently drift away from the document.
//!
//! Citation grammar: the literal `DESIGN.md §` followed by either a
//! dotted section number (`4`, `2.5`) or a word anchor
//! (`Hardware-Adaptation`). Numeric citations resolve against the `§N`
//! markers in DESIGN.md headings; word citations resolve if any heading
//! contains the token.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("the rust crate lives one level under the repo root")
        .to_path_buf()
}

/// Recursively collect .rs / .py files, skipping build output and hidden
/// directories.
fn source_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if path.is_dir() {
            if name == "target" || name == "bench_out" || name.starts_with('.') {
                continue;
            }
            source_files(&path, out);
        } else if matches!(path.extension().and_then(|x| x.to_str()), Some("rs") | Some("py")) {
            out.push(path);
        }
    }
}

/// Join wrapped comment/prose lines into one whitespace-normalized string
/// so a citation split across a line break (`DESIGN.md` at the end of one
/// doc-comment line, `§2.6` at the start of the next) is still seen by the
/// scanner. Comment markers (`//!`, `///`, `//`, `#`) are stripped after
/// the join.
fn normalize(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    for line in text.lines() {
        let t = line.trim_start();
        let t = t
            .strip_prefix("//!")
            .or_else(|| t.strip_prefix("///"))
            .or_else(|| t.strip_prefix("//"))
            .or_else(|| t.strip_prefix("#"))
            .unwrap_or(t);
        out.push_str(t.trim());
        out.push(' ');
    }
    out
}

/// Every citation token following the literal `DESIGN.md §` in `text`.
fn citations(text: &str) -> Vec<String> {
    const NEEDLE: &str = "DESIGN.md \u{a7}"; // "DESIGN.md §"
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(pos) = rest.find(NEEDLE) {
        let after = &rest[pos + NEEDLE.len()..];
        let token: String = after
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '-' | '_'))
            .collect();
        let token = token.trim_end_matches(&['.', '-', '_'][..]).to_string();
        if !token.is_empty() {
            out.push(token);
        }
        rest = after;
    }
    out
}

/// (numeric §-anchors, full heading lines) of DESIGN.md.
fn anchors(design: &str) -> (BTreeSet<String>, Vec<String>) {
    let mut numeric = BTreeSet::new();
    let mut headings = Vec::new();
    for line in design.lines() {
        if !line.starts_with('#') {
            continue;
        }
        headings.push(line.to_string());
        let mut rest = line;
        while let Some(pos) = rest.find('\u{a7}') {
            let after = &rest[pos + '\u{a7}'.len_utf8()..];
            let tok: String =
                after.chars().take_while(|c| c.is_ascii_digit() || *c == '.').collect();
            let tok = tok.trim_end_matches('.').to_string();
            if !tok.is_empty() {
                numeric.insert(tok);
            }
            rest = after;
        }
    }
    (numeric, headings)
}

#[test]
fn design_md_exists_with_contract_sections() {
    let design = fs::read_to_string(repo_root().join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repository root");
    let (numeric, _) = anchors(&design);
    // The minimum contract: architecture, assignment engine, pipeline map,
    // offline constraints.
    for required in ["1", "2", "3", "4"] {
        assert!(
            numeric.contains(required),
            "DESIGN.md is missing a §{required} section header; found anchors {numeric:?}"
        );
    }
}

#[test]
fn obs_module_cites_the_observability_contract() {
    // The telemetry subsystem (rust/src/obs/) was specified as DESIGN.md
    // §2.11; both sides of that link must exist — the section header in
    // the document and at least one citation in the module — so the
    // observability contract can't silently detach from its code.
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repository root");
    let (numeric, _) = anchors(&design);
    assert!(
        numeric.contains("2.11"),
        "DESIGN.md is missing the §2.11 observability-contract header; found {numeric:?}"
    );

    let mut files = Vec::new();
    source_files(&root.join("rust").join("src").join("obs"), &mut files);
    assert!(!files.is_empty(), "rust/src/obs/ has no source files to scan");
    let cites_contract = files.iter().any(|f| {
        fs::read_to_string(f)
            .map(|text| citations(&normalize(&text)).iter().any(|t| t == "2.11"))
            .unwrap_or(false)
    });
    assert!(cites_contract, "rust/src/obs/ never cites DESIGN.md §2.11");
}

#[test]
fn pool_module_cites_the_steady_state_contract() {
    // The zero-allocation steady state (shared worker pool, arenas,
    // generation caches) was specified as DESIGN.md §2.12; both sides of
    // that link must exist — the section header in the document and at
    // least one citation in the pool module — so the pool/arena contract
    // can't silently detach from its code.
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repository root");
    let (numeric, _) = anchors(&design);
    assert!(
        numeric.contains("2.12"),
        "DESIGN.md is missing the §2.12 pool/arena/cache-generation header; found {numeric:?}"
    );

    let pool = root.join("rust").join("src").join("util").join("pool.rs");
    let cites = fs::read_to_string(&pool)
        .map(|text| citations(&normalize(&text)).iter().any(|t| t == "2.12"))
        .unwrap_or(false);
    assert!(cites, "rust/src/util/pool.rs never cites DESIGN.md §2.12");
}

#[test]
fn every_design_citation_resolves() {
    let root = repo_root();
    let design = fs::read_to_string(root.join("DESIGN.md"))
        .expect("DESIGN.md must exist at the repository root");
    let (numeric, headings) = anchors(&design);

    let mut files = Vec::new();
    source_files(&root.join("rust"), &mut files);
    source_files(&root.join("python"), &mut files);
    source_files(&root.join("examples"), &mut files);
    assert!(!files.is_empty(), "source scan found no files under {}", root.display());

    let mut seen = 0usize;
    let mut unresolved: Vec<String> = Vec::new();
    for file in &files {
        let text = match fs::read_to_string(file) {
            Ok(text) => text,
            Err(_) => continue,
        };
        for token in citations(&normalize(&text)) {
            seen += 1;
            let is_numeric = token.chars().all(|c| c.is_ascii_digit() || c == '.');
            let ok = if is_numeric {
                numeric.contains(&token)
            } else {
                headings.iter().any(|h| h.contains(&token))
            };
            if !ok {
                unresolved.push(format!("{} cites DESIGN.md §{token}", file.display()));
            }
        }
    }

    // Guard against a vacuous pass: the tree is known to cite DESIGN.md
    // from rust/src, rust/benches and python (≥ 10 citations at the time
    // this gate landed).
    assert!(seen >= 10, "citation scanner found only {seen} citations — scanner regression?");
    assert!(
        unresolved.is_empty(),
        "unresolved DESIGN.md citations:\n  {}",
        unresolved.join("\n  ")
    );
}
