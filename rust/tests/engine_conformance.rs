//! Cross-backend conformance suite for the assignment engine
//! (DESIGN.md §2): every backend — serial, the `Sharded<B>` combinator
//! over every inner backend, norm-pruned, the cross-iteration bounded
//! backend, and the auto-selector — must produce **bit-identical**
//! `AssignOut` (`==`, no tolerances) on the same inputs, under the §2.1
//! tie-breaking rules, while charging the `DistanceCounter` exactly what
//! §2.4 prescribes. The fuzz deliberately covers the Table-1 dimensions
//! (2, 3, 4, 5, 17, 19, 20), k = 1, duplicate points and exact-tie
//! centroids, plus multi-iteration drift sequences that only a stateful
//! backend can get wrong. The §2.10 section at the bottom pins the
//! vectorized/mixed-precision backend: scalar-vs-SIMD bit-identity where
//! the contract pins it (within a precision), the bounded-tolerance
//! harness where it is relaxed (f32 vs f64), and kernel/precision-
//! independent distance bills.

use bwkm::bwkm::{boundary, epsilons, initial_partition, theorem2_bound, InitCfg};
use bwkm::data::{simulate, Dataset};
use bwkm::kmeans::assign::{
    sq_dist_kernel, weighted_step, weighted_step_with, Assigner, AssignOut, AutoAssigner,
    AutoChoice, BoundedAssigner, KernelKind, NormPrunedAssigner, Precision, SerialAssigner,
    Sharded, StepScratch, VectorAssigner,
};
use bwkm::kmeans::init::weighted_kmeanspp;
use bwkm::metrics::DistanceCounter;
use bwkm::util::prop;
use bwkm::util::Rng;

/// The dimensions the paper's Table-1 workloads use (DESIGN.md §2.1 gives
/// them monomorphized kernels — exactly the paths that could diverge),
/// plus odd/dyn-path extras.
const DIMS: [usize; 10] = [2, 3, 4, 5, 17, 19, 20, 1, 7, 23];

fn counter() -> DistanceCounter {
    DistanceCounter::new()
}

/// Fuzzed corpus with the adversarial features the §2.1 contract names:
/// duplicate points (copied rows) and exact-tie centroids (duplicated and
/// reflected rows — reflection preserves squared distance bit for bit for
/// points at the origin, duplication for all points).
fn adversarial_corpus(g: &mut prop::Gen, m: usize, d: usize, k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut reps = g.cloud(m, d, 2.0);
    // Duplicate a batch of rows.
    for _ in 0..g.int(0, (m / 2).max(1)) {
        let (src, dst) = (g.int(0, m - 1), g.int(0, m - 1));
        let row: Vec<f64> = reps[src * d..(src + 1) * d].to_vec();
        reps[dst * d..(dst + 1) * d].copy_from_slice(&row);
    }
    // A few exact-zero rows (tie fodder for reflected centroids).
    for _ in 0..g.int(0, 3) {
        let dst = g.int(0, m - 1);
        reps[dst * d..(dst + 1) * d].fill(0.0);
    }
    let mut cents = g.cloud(k, d, 2.0);
    if k >= 2 {
        // Exact-tie centroids: duplicate one row and reflect another.
        let (src, dst) = (g.int(0, k - 1), g.int(0, k - 1));
        let row: Vec<f64> = cents[src * d..(src + 1) * d].to_vec();
        cents[dst * d..(dst + 1) * d].copy_from_slice(&row);
        let (src, dst) = (g.int(0, k - 1), g.int(0, k - 1));
        let row: Vec<f64> = cents[src * d..(src + 1) * d].iter().map(|x| -x).collect();
        cents[dst * d..(dst + 1) * d].copy_from_slice(&row);
    }
    (reps, cents)
}

#[test]
fn prop_every_backend_bit_identical_to_serial() {
    prop::check("conformance-bit-identical", 40, |g| {
        let d = DIMS[g.int(0, DIMS.len() - 1)];
        let m = g.int(1, 220);
        let k = g.int(1, 14); // includes k = 1 (d2 = ∞ per §2.1)
        let threads = g.int(1, 5);
        let (reps, mut cents) = adversarial_corpus(g, m, d, k);

        let mut sharded_serial: Sharded<SerialAssigner> = Sharded::new(threads);
        let mut sharded_pruned: Sharded<NormPrunedAssigner> = Sharded::new(threads);
        let mut sharded_bounded: Sharded<BoundedAssigner> = Sharded::new(threads);
        let mut bounded = BoundedAssigner::new();
        let mut auto = AutoAssigner::new();

        // A short drift sequence: step 0 is the cold path, steps 1..3 the
        // warm (cross-iteration) paths of the stateful backends.
        for step in 0..3 {
            let c_serial = counter();
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c_serial);
            assert_eq!(c_serial.get(), (m * k) as u64);

            let checks: [(&str, AssignOut, u64); 6] = [
                {
                    let c = counter();
                    let out = sharded_serial.assign_top2(&reps, d, &cents, &c);
                    ("sharded-serial", out, c.get())
                },
                {
                    let c = counter();
                    let out = NormPrunedAssigner::new().assign_top2(&reps, d, &cents, &c);
                    ("normpruned", out, c.get())
                },
                {
                    let c = counter();
                    let out = sharded_pruned.assign_top2(&reps, d, &cents, &c);
                    ("sharded-normpruned", out, c.get())
                },
                {
                    let c = counter();
                    let out = bounded.assign_top2(&reps, d, &cents, &c);
                    ("bounded", out, c.get())
                },
                {
                    let c = counter();
                    let out = sharded_bounded.assign_top2(&reps, d, &cents, &c);
                    ("sharded-bounded", out, c.get())
                },
                {
                    let c = counter();
                    let out = auto.assign_top2(&reps, d, &cents, &c);
                    ("auto", out, c.get())
                },
            ];
            for (name, out, count) in &checks {
                assert_eq!(&serial, out, "{name} diverged at step {step} (m={m} k={k} d={d})");
                match *name {
                    // Exact backends: exactly n·k, sharded or not (§2.4).
                    "sharded-serial" => assert_eq!(*count, (m * k) as u64, "{name}"),
                    // Pruned backends: never above the bill plus their
                    // documented bookkeeping (norms / drift distances).
                    "normpruned" => {
                        assert!(*count <= ((m * k) + m + k) as u64, "{name}: {count}")
                    }
                    "sharded-normpruned" => {
                        assert!(*count <= ((m * k) + m + k * threads) as u64, "{name}: {count}")
                    }
                    "bounded" | "sharded-bounded" => {
                        assert!(*count <= ((m * k) + k * threads) as u64, "{name}: {count}")
                    }
                    _ => {}
                }
            }
            // d2 = ∞ at k = 1 (§2.1).
            if k == 1 {
                assert!(serial.d2.iter().all(|x| x.is_infinite()));
            }
            // Drift the centroids, Lloyd-ishly.
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.08;
            }
        }
    });
}

#[test]
fn exact_tie_centroids_lowest_index_wins_on_every_backend() {
    // Three coincident centroids at index 1/2/3, a farther one at 0: the
    // winner must be index 1 and d2 must equal d1 on every backend.
    let d = 2;
    let cents = [9.0, 9.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
    let reps = [0.0, 0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 0.0]; // duplicate rows too
    let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
    assert_eq!(serial.assign, vec![1, 1, 1, 1]);
    assert_eq!(serial.d1, serial.d2, "coincident runner-up: d2 == d1");
    let mut bounded = BoundedAssigner::new();
    let mut auto = AutoAssigner::new();
    let mut shp: Sharded<NormPrunedAssigner> = Sharded::new(3);
    let mut shb: Sharded<BoundedAssigner> = Sharded::new(3);
    for _ in 0..2 {
        assert_eq!(serial, NormPrunedAssigner::new().assign_top2(&reps, d, &cents, &counter()));
        assert_eq!(serial, bounded.assign_top2(&reps, d, &cents, &counter()));
        assert_eq!(serial, auto.assign_top2(&reps, d, &cents, &counter()));
        assert_eq!(serial, shp.assign_top2(&reps, d, &cents, &counter()));
        assert_eq!(serial, shb.assign_top2(&reps, d, &cents, &counter()));
    }
}

#[test]
fn prop_bounded_counter_is_exactly_its_own_account() {
    // §2.4 exactness for the bounded backend: the counter delta of every
    // call equals the backend's self-reported pairs + bookkeeping, the
    // cold bill is exactly m·k, and warm pairs stay within [min(2,k)·m,
    // m·k].
    prop::check("conformance-bounded-count", 25, |g| {
        let d = DIMS[g.int(0, DIMS.len() - 1)];
        let m = g.int(1, 150);
        let k = g.int(1, 10);
        let (reps, mut cents) = adversarial_corpus(g, m, d, k);
        let mut bounded = BoundedAssigner::new();
        let c = counter();
        let mut last = 0u64;
        for step in 0..4 {
            let _ = bounded.assign_top2(&reps, d, &cents, &c);
            let delta = c.get() - last;
            last = c.get();
            let stats = bounded.last_stats();
            assert_eq!(delta, stats.pairs + stats.bookkeeping, "step {step}");
            assert_eq!(stats.bill, (m * k) as u64);
            if step == 0 {
                assert!(!stats.warm);
                assert_eq!(stats.pairs, (m * k) as u64, "cold pass pays the serial bill");
                assert_eq!(stats.bookkeeping, 0);
            } else {
                assert!(stats.warm);
                assert_eq!(stats.bookkeeping, k as u64, "k drift distances per warm step");
                assert!(stats.pairs >= (m * k.min(2)) as u64);
                assert!(stats.pairs <= (m * k) as u64);
            }
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.05;
            }
        }
    });
}

#[test]
fn prop_multi_iteration_bound_validity_vs_serial_recompute() {
    // The stale-bound regression net (a single-pass test cannot catch a
    // bound that only goes invalid after accumulated drift): run a real
    // weighted-Lloyd trajectory on the bounded engine and, after *every*
    // step, recompute the assignment with the serial backend on the same
    // inputs — outputs must stay `==` for the whole run, including after
    // an abrupt centroid teleport and a representative-set change.
    prop::check("conformance-bound-validity", 15, |g| {
        let d = g.int(1, 6);
        let m = g.int(4, 160);
        let k = g.int(2, 8).min(m);
        let reps = g.blobs(m, d, k, 0.7);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
        let mut cents: Vec<f64> = reps[..k * d].to_vec();

        let mut bounded = BoundedAssigner::new();
        let mut scratch = StepScratch::default();
        let c = counter();
        for step in 0..10 {
            let out = weighted_step_with(&mut bounded, &mut scratch, &reps, &weights, d, &cents, &c);
            let serial = weighted_step(&mut SerialAssigner, &reps, &weights, d, &cents, &counter());
            assert_eq!(out.assign, serial.assign, "step {step}");
            assert_eq!(out.d1, serial.d1, "step {step}");
            assert_eq!(out.d2, serial.d2, "step {step}");
            assert_eq!(out.centroids, serial.centroids, "step {step}");
            assert_eq!(out.werr.to_bits(), serial.werr.to_bits(), "step {step}");
            cents = out.centroids;
            if step == 4 {
                // Adversarial teleport: maximal drift, maximally stale
                // bounds.
                for v in cents.iter_mut() {
                    *v = g.rng.normal() * 8.0;
                }
            }
        }
        // Representative-set change (BWKM splits a block): the backend
        // must re-prime, not reuse bounds keyed to the old rows.
        let mut reps2 = reps.clone();
        reps2.extend(g.cloud(2, d, 2.0));
        let mut weights2 = weights.clone();
        weights2.extend([1.0, 1.0]);
        let out = weighted_step_with(&mut bounded, &mut scratch, &reps2, &weights2, d, &cents, &c);
        let serial = weighted_step(&mut SerialAssigner, &reps2, &weights2, d, &cents, &counter());
        assert_eq!(out.assign, serial.assign);
        assert_eq!(out.d1, serial.d1);
        assert_eq!(out.d2, serial.d2);
        assert!(!bounded.last_stats().warm, "changed reps must re-prime");
    });
}

#[test]
fn epsilon_machinery_charges_zero_over_multi_iteration_bwkm_run() {
    // §2.3: ε, boundary and the Theorem 2 bound are computed from the
    // top-2 distances the step already produced and never touch the
    // counter — verified across a real multi-iteration BWKM-style loop
    // (partition refinement included) on both the serial and the bounded
    // engine.
    let mut g = prop::Gen { rng: Rng::new(77), case: 0 };
    let ds = Dataset::new(g.blobs(900, 3, 4, 0.5), 3);
    let k = 4;
    let cfg = InitCfg { m_prime: k + 1, m: 40, s: 30, r: 3 };
    for engine_kind in 0..2 {
        let c = counter();
        let mut rng = Rng::new(5);
        let mut partition = initial_partition(&ds, k, &cfg, &mut rng, &c);
        let (mut reps, mut weights, mut ids) = partition.reps_weights();
        let mut cents = weighted_kmeanspp(&reps, &weights, ds.d, k, &mut rng, &c);
        let mut serial = SerialAssigner;
        let mut bounded = BoundedAssigner::new();
        for _outer in 0..4 {
            let engine: &mut dyn Assigner =
                if engine_kind == 0 { &mut serial } else { &mut bounded };
            let step = weighted_step(engine, &reps, &weights, ds.d, &cents, &c);
            cents = step.centroids.clone();

            let before = c.get();
            let eps = epsilons(&partition, &ids, &step.d1, &step.d2);
            let f = boundary(&eps);
            let bound = theorem2_bound(&partition, &ids, &weights, &step.d1, &eps);
            assert!(bound.is_finite());
            assert_eq!(
                c.get(),
                before,
                "ε/boundary/Theorem-2 must not charge the counter (DESIGN.md §2.3)"
            );

            // Refine: split the first boundary blocks, as Alg. 5 would.
            for &row in f.iter().take(3) {
                if partition.blocks[ids[row]].weight() > 1 {
                    partition.split(ids[row], &ds);
                }
            }
            let rw = partition.reps_weights();
            reps = rw.0;
            weights = rw.1;
            ids = rw.2;
        }
    }
}

#[test]
fn auto_choice_counts_and_note_formats_are_pinned() {
    // The auto-selector's observables are part of the §2.7/§2.9 contract:
    // the per-step note string (exact format, pinned verbatim on the
    // deterministic cold step), and the per-`AutoChoice` tally map with
    // its bench-column `summary()` form.
    let mut g = prop::Gen { rng: Rng::new(0xC0DE), case: 0 };
    let (m, d, k) = (300usize, 3usize, 6usize);
    let reps = g.cloud(m, d, 2.0);
    let mut cents = g.cloud(k, d, 2.0);

    // Exact auto: a cold call on an amortizable problem (k ≥ 4, m ≥ 64)
    // primes the bounded backend; the warm follow-up keeps it (the cold
    // prime reports rate 1.0).
    let mut auto = AutoAssigner::new();
    let c = counter();
    let _ = auto.assign_top2(&reps, d, &cents, &c);
    assert_eq!(
        c.notes(),
        vec![format!("auto[1]: bounded (m={m} k={k} d={d} warm=false prune=100%)")],
        "pinned note format"
    );
    for v in cents.iter_mut() {
        *v += g.rng.normal() * 0.05;
    }
    let _ = auto.assign_top2(&reps, d, &cents, &c);
    assert!(c.notes()[1].starts_with("auto[2]: bounded ("), "{:?}", c.notes()[1]);
    let counts = auto.choice_counts();
    assert_eq!(counts.total(), 2);
    assert_eq!(counts.get(AutoChoice::Bounded), 2);
    assert_eq!(counts.get(AutoChoice::Closure), 0, "exact auto never picks closure");
    assert_eq!(counts.summary(), "serial:0 normpruned:0 bounded:2 closure:0");
    assert_eq!(counts.iter().map(|(_, n)| n).sum::<u64>(), counts.total());

    // Approximate regime (§2.9, opt-in): the cold call routes through the
    // closure backend's own exact fallback — bit-identical to serial —
    // and the note carries the hit-rate field instead of the prune rate.
    let mut auto = AutoAssigner::with_closure(2);
    let c = counter();
    let cold = auto.assign_top2(&reps, d, &cents, &c);
    let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
    assert_eq!(cold, serial, "closure cold call is the exact fallback");
    assert_eq!(
        c.notes(),
        vec![format!("auto[1]: closure (m={m} k={k} d={d} warm=false hit=100%)")],
        "pinned note format (approximate regime)"
    );
    let _ = auto.assign_top2(&reps, d, &cents, &c);
    assert!(c.notes()[1].starts_with("auto[2]: closure ("), "{:?}", c.notes()[1]);
    assert_eq!(auto.choice_counts().get(AutoChoice::Closure), 2);
}

// ---------------------------------------------------------------------------
// §2.10 — vectorization & precision conformance.
// ---------------------------------------------------------------------------

/// The §2.10 dimension sweep: sub-lane (1..3), exact f64-lane multiples
/// (4, 8), f32-lane boundary (7..9), a Table-1 monomorphized dim (17) and
/// a wide dyn-path dim (64).
const SIMD_DIMS: [usize; 11] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 17, 64];

/// The documented f32-storage error bound (DESIGN.md §2.10): with every
/// coordinate bounded by R, each dimension's squared-difference term
/// carries at most ~16·R²·2⁻²⁴ of f32 storage/subtraction error (the
/// widening f32→f64 products are exact), so a squared distance over d
/// dims is within `C·d·R²·2⁻²⁴` of the f64 kernel's value, with C = 32
/// a 2× safety factor.
fn f32_tol(d: usize, scale: f64) -> f64 {
    32.0 * d as f64 * scale * scale * (2f64).powi(-24)
}

fn max_abs(vals: &[f64]) -> f64 {
    vals.iter().fold(0.0f64, |a, &v| a.max(v.abs()))
}

#[test]
fn prop_vector_kernels_conform_over_simd_dims() {
    // The §2.10 contract over the full dim sweep on adversarial corpora
    // (duplicate rows, exact-tie centroids, k = 1):
    //  * f64: every kernel kind is pinned bit-identical to the serial
    //    engine (`==`, no tolerances);
    //  * f32: every kernel kind is bit-identical to every other f32
    //    kernel, and tolerance-bounded against f64 per the documented
    //    error model (winner bound-plausible, d1 within tol of the f64
    //    distance to the f32 winner);
    //  * the bill is precision- and kernel-independent: exactly m·k.
    prop::check("conformance-vector", 30, |g| {
        let d = SIMD_DIMS[g.int(0, SIMD_DIMS.len() - 1)];
        let m = g.int(1, 180);
        let k = g.int(1, 12); // includes k = 1 (d2 = ∞ per §2.1)
        let (reps, cents) = adversarial_corpus(g, m, d, k);

        let c0 = counter();
        let serial = SerialAssigner.assign_top2(&reps, d, &cents, &c0);
        assert_eq!(c0.get(), (m * k) as u64);

        // f64: pinned.
        for kernel in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Auto] {
            let c = counter();
            let out = VectorAssigner::new(kernel, Precision::F64).assign_top2(&reps, d, &cents, &c);
            assert_eq!(out, serial, "f64 kernel={} diverged (m={m} k={k} d={d})", kernel.name());
            assert_eq!(c.get(), (m * k) as u64, "f64 kernel={} bill", kernel.name());
        }

        // f32: bit-identical within the precision...
        let c_f32 = counter();
        let f32_scalar = VectorAssigner::new(KernelKind::Scalar, Precision::F32)
            .assign_top2(&reps, d, &cents, &c_f32);
        assert_eq!(c_f32.get(), (m * k) as u64, "the bill is precision-independent");
        for kernel in [KernelKind::Simd, KernelKind::Auto] {
            let c = counter();
            let out = VectorAssigner::new(kernel, Precision::F32).assign_top2(&reps, d, &cents, &c);
            assert_eq!(out, f32_scalar, "f32 kernel={} diverged", kernel.name());
            assert_eq!(c.get(), (m * k) as u64);
        }

        // ...and tolerance-bounded against f64 (the relaxed contract):
        // the f32 winner need not index-match under near-ties, but its
        // *f64* distance must be within 2·tol of the true minimum, and
        // the reported d1 within tol of that f64 distance.
        let scale = max_abs(&reps).max(max_abs(&cents));
        let tol = f32_tol(d, scale);
        for i in 0..m {
            let row = &reps[i * d..(i + 1) * d];
            let w32 = f32_scalar.assign[i] as usize;
            let d64_of_w32 = sq_dist_kernel(row, &cents[w32 * d..(w32 + 1) * d]);
            assert!(
                (f32_scalar.d1[i] - d64_of_w32).abs() <= tol,
                "row {i}: f32 d1 {} vs f64 distance {} exceeds tol {tol} (d={d})",
                f32_scalar.d1[i],
                d64_of_w32
            );
            assert!(
                d64_of_w32 <= serial.d1[i] + 2.0 * tol,
                "row {i}: f32 winner {w32} is not bound-plausible: {} > {} + 2·{tol}",
                d64_of_w32,
                serial.d1[i]
            );
        }
        if k == 1 {
            assert!(f32_scalar.d2.iter().all(|x| x.is_infinite()), "d2 = ∞ at k = 1 in f32 too");
        }
    });
}

#[test]
fn vector_backends_respect_tie_and_degenerate_rules() {
    // The §2.1 degenerates on the vectorized backends, with f32-exact
    // inputs (small integers) so even the relaxed mode must reproduce
    // the serial output exactly: coincident centroids (lowest index
    // wins, d2 == d1), duplicate rows, and k = 1.
    let d = 2;
    let cents = [9.0, 9.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
    let reps = [0.0, 0.0, 1.0, 0.0, 2.0, 0.0, 0.0, 0.0]; // duplicate rows too
    let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
    assert_eq!(serial.assign, vec![1, 1, 1, 1]);
    for precision in [Precision::F64, Precision::F32] {
        for kernel in [KernelKind::Scalar, KernelKind::Simd, KernelKind::Auto] {
            let c = counter();
            let out =
                VectorAssigner::new(kernel, precision).assign_top2(&reps, d, &cents, &c);
            assert_eq!(
                out,
                serial,
                "kernel={} precision={} on f32-exact tie corpus",
                kernel.name(),
                precision.name()
            );
            assert_eq!(c.get(), (reps.len() / d * (cents.len() / d)) as u64);
        }
    }
    // k = 1: d2 = ∞ in every kernel × precision combination.
    let one = [3.0, 4.0];
    for precision in [Precision::F64, Precision::F32] {
        let out = VectorAssigner::new(KernelKind::Auto, precision)
            .assign_top2(&reps, d, &one, &counter());
        assert!(out.d2.iter().all(|x| x.is_infinite()), "precision={}", precision.name());
        assert_eq!(out.assign, vec![0, 0, 0, 0]);
    }
}

#[test]
fn prop_vector_counter_totals_equal_across_kernels_in_full_lloyd_steps() {
    // Counter-total equality end to end: a short weighted-Lloyd drift
    // sequence through every kernel × precision charges *exactly* the
    // same total — steps × m·k — because exact accounting is algorithmic,
    // not backend- or precision-dependent (§2.4/§2.10).
    prop::check("conformance-vector-bills", 10, |g| {
        let d = SIMD_DIMS[g.int(0, SIMD_DIMS.len() - 1)];
        let m = g.int(2, 120);
        let k = g.int(1, 8);
        let (reps, cents) = adversarial_corpus(g, m, d, k);
        let weights: Vec<f64> = (0..m).map(|_| 1.0 + g.int(0, 5) as f64).collect();
        let steps = 3usize;
        let mut bills = Vec::new();
        for (kernel, precision) in [
            (KernelKind::Scalar, Precision::F64),
            (KernelKind::Simd, Precision::F64),
            (KernelKind::Scalar, Precision::F32),
            (KernelKind::Simd, Precision::F32),
        ] {
            let mut engine = VectorAssigner::new(kernel, precision);
            let c = counter();
            let mut cur = cents.clone();
            for _ in 0..steps {
                cur = weighted_step(&mut engine, &reps, &weights, d, &cur, &c).centroids;
            }
            bills.push(c.get());
        }
        assert!(
            bills.iter().all(|&b| b == (steps * m * k) as u64),
            "bills diverged across kernel×precision: {bills:?} (expected {})",
            steps * m * k
        );
    });
}

#[test]
fn bounded_beats_normpruned_after_first_iteration_on_clustered_data() {
    // The acceptance criterion, on GS-style clustered data (the paper's
    // d = 19 simulator) over a BWKM-like representative set: from
    // iteration 1 on (warm bounds), the bounded backend must evaluate
    // strictly fewer pairs — and charge strictly less in total — than the
    // stateless norm-pruned backend on the identical inputs, at identical
    // output.
    let ds = simulate("GS", 0.001, 7).expect("GS simulator");
    let k = 27;
    let mut rng = Rng::new(11);
    let c0 = counter();
    let m_cfg = (10.0 * ((k * ds.d) as f64).sqrt()).ceil() as usize;
    let cfg = InitCfg {
        m_prime: (m_cfg / 4).max(k + 1),
        m: m_cfg,
        s: (ds.n as f64).sqrt() as usize,
        r: 5,
    };
    let p = initial_partition(&ds, k, &cfg, &mut rng, &c0);
    let (reps, weights, _) = p.reps_weights();
    let m = weights.len();
    let mut cents = weighted_kmeanspp(&reps, &weights, ds.d, k, &mut rng, &c0);

    let mut bounded = BoundedAssigner::new();
    // Iteration 0: cold prime (pays exactly the serial bill) + update.
    let step = weighted_step(&mut bounded, &reps, &weights, ds.d, &cents, &counter());
    assert_eq!(bounded.last_stats().pairs, (m * k) as u64);
    cents = step.centroids;

    for iter in 1..4 {
        let cb = counter();
        let b_out = bounded.assign_top2(&reps, ds.d, &cents, &cb);
        let stats = bounded.last_stats();
        assert!(stats.warm);

        let cn = counter();
        let n_out = NormPrunedAssigner::new().assign_top2(&reps, ds.d, &cents, &cn);
        assert_eq!(b_out, n_out, "backends diverged at iteration {iter}");

        // NormPruned charges k + m norms + its evaluated pairs.
        let norm_pairs = cn.get() - (m + k) as u64;
        assert!(
            stats.pairs < norm_pairs,
            "iteration {iter}: bounded evaluated {} pairs, norm-pruned {} (bill {})",
            stats.pairs,
            norm_pairs,
            m * k
        );
        assert!(
            cb.get() < cn.get(),
            "iteration {iter}: bounded charged {} total, norm-pruned {}",
            cb.get(),
            cn.get()
        );

        // Advance the trajectory one weighted-Lloyd update (serial engine
        // so the bounded backend's own warm stats above stay per-pass).
        let step = weighted_step(&mut SerialAssigner, &reps, &weights, ds.d, &cents, &counter());
        cents = step.centroids;
    }
}
