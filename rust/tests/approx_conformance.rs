//! Approximate-regime conformance suite (DESIGN.md §2.9): the closure
//! assigner and the sampled stepper trade bit-identity for a smaller
//! bill, but three things stay pinned with `==`, no tolerances:
//!
//! 1. **Degenerate-to-exact**: a *total* closure (`expand ≥ k−1`, k = 1,
//!    or a build that would not amortize) and a *full* sample
//!    (`sample_rows ≥ m`, or an all-zero sampled weight mass) must route
//!    through the exact engine — bit-identical to [`SerialAssigner`] /
//!    `NativeStepper` at the identical `m·k` count.
//! 2. **Accounting**: every call's counter delta equals the backend's own
//!    self-reported account (`pairs + bookkeeping`), and an approximate
//!    bill is *never* larger than the exact `m·k` bill.
//! 3. **Self-report**: every approximate end-to-end run (BWKM, grid RPKM,
//!    the out-of-core coordinator) leaves exactly one `"gap["` note on
//!    its counter; exact runs leave none. The measured gap obeys
//!    `approx_err ≥ exact_err` *bit-exactly* (each approximate term is a
//!    min over a subset of the same kernel values; row-order rounded
//!    summation is monotone) — and stays within the declared bound on
//!    clustered data.
//!
//! Like `engine_conformance`, the fuzz covers the Table-1 dimensions,
//! k = 1, duplicate points, exact-tie centroids and multi-step drift
//! sequences that only a stateful backend can get wrong.

use anyhow::Result;
use bwkm::bwkm::BwkmCfg;
use bwkm::coordinator::StreamingBwkm;
use bwkm::data::Dataset;
use bwkm::kmeans::assign::{Assigner, ClosureAssigner, SerialAssigner};
use bwkm::kmeans::{
    weighted_lloyd_with, AssignCfg, AssignMode, NativeStepper, SampledStepper, Stepper, WLloydCfg,
};
use bwkm::metrics::DistanceCounter;
use bwkm::rpkm::{grid_rpkm, RpkmCfg};
use bwkm::util::{prop, Rng};

/// The engine-conformance dimension grid (monomorphized kernels + odd
/// dyn-path extras).
const DIMS: [usize; 10] = [2, 3, 4, 5, 17, 19, 20, 1, 7, 23];

fn counter() -> DistanceCounter {
    DistanceCounter::new()
}

fn gap_notes(c: &DistanceCounter) -> usize {
    c.notes().iter().filter(|n| n.starts_with("gap[")).count()
}

/// Adversarial features per the §2.1 contract: duplicate points, exact
/// zero rows, duplicated and reflected (tie) centroids.
fn corpus(g: &mut prop::Gen, m: usize, d: usize, k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut reps = g.cloud(m, d, 2.0);
    for _ in 0..g.int(0, (m / 2).max(1)) {
        let (src, dst) = (g.int(0, m - 1), g.int(0, m - 1));
        let row: Vec<f64> = reps[src * d..(src + 1) * d].to_vec();
        reps[dst * d..(dst + 1) * d].copy_from_slice(&row);
    }
    for _ in 0..g.int(0, 3) {
        let dst = g.int(0, m - 1);
        reps[dst * d..(dst + 1) * d].fill(0.0);
    }
    let mut cents = g.cloud(k, d, 2.0);
    if k >= 2 {
        let (src, dst) = (g.int(0, k - 1), g.int(0, k - 1));
        let row: Vec<f64> = cents[src * d..(src + 1) * d].to_vec();
        cents[dst * d..(dst + 1) * d].copy_from_slice(&row);
        let (src, dst) = (g.int(0, k - 1), g.int(0, k - 1));
        let row: Vec<f64> = cents[src * d..(src + 1) * d].iter().map(|x| -x).collect();
        cents[dst * d..(dst + 1) * d].copy_from_slice(&row);
    }
    (reps, cents)
}

fn vec_opener(
    data: Vec<f64>,
    d: usize,
    chunk_rows: usize,
) -> impl FnMut() -> Result<Vec<Result<Vec<f64>>>> {
    let chunk_rows = chunk_rows.max(1);
    move || Ok(data.chunks(chunk_rows * d).map(|c| Ok(c.to_vec())).collect())
}

#[test]
fn prop_total_closure_is_bit_identical_to_serial() {
    // `expand ≥ k−1` makes every closure total — the degenerate "empty
    // closure complement". Every call (cold *and* would-be warm) must be
    // the serial fallback: `==` output, exactly m·k on the counter, and a
    // deterministic fallback tally.
    prop::check("approx-total-closure", 25, |g| {
        let d = DIMS[g.int(0, DIMS.len() - 1)];
        let m = g.int(1, 150);
        let k = g.int(1, 8);
        let (reps, mut cents) = corpus(g, m, d, k);
        let mut cl = ClosureAssigner::new(k); // candidates = min(k+1, k) = k
        let c = counter();
        let mut last = 0u64;
        for step in 0..3u64 {
            let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
            let out = cl.assign_top2(&reps, d, &cents, &c);
            assert_eq!(serial, out, "step {step} (m={m} k={k} d={d})");
            let delta = c.get() - last;
            last = c.get();
            assert_eq!(delta, (m * k) as u64, "fallback pays the serial bill");
            let stats = cl.last_stats();
            assert!(!stats.warm);
            assert_eq!(stats.pairs, (m * k) as u64);
            assert_eq!(stats.bookkeeping, 0);
            assert_eq!(delta, stats.pairs + stats.bookkeeping, "self-account");
            assert_eq!(stats.fallbacks, step + 1);
            assert_eq!(stats.hit_rate(), 1.0, "exact always hits");
            if k == 1 {
                assert!(out.d2.iter().all(|x| x.is_infinite()), "d2 = ∞ at k = 1 (§2.1)");
            }
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.08;
            }
        }
    });
}

#[test]
fn prop_warm_closure_bill_pinned_and_never_above_exact() {
    // The §2.9 accounting pin on genuinely approximate (warm, viable)
    // calls: counter delta == pairs + bookkeeping == m·(expand+1) +
    // k·(k−1)/2, always ≤ the exact bill m·k; per-row d1 dominates the
    // serial d1 (a min over a candidate subset of the same kernel
    // values), and the measured gap is ordered and uncounted.
    prop::check("approx-closure-bill", 20, |g| {
        let d = g.int(1, 6);
        let m = g.int(150, 300);
        let k = g.int(4, 10);
        let expand = g.int(1, 2); // candidates ≤ 3 < k: viable at this m
        let reps = g.cloud(m, d, 2.0);
        let mut cents = g.cloud(k, d, 2.0);
        let mut cl = ClosureAssigner::new(expand);
        let c = counter();
        let mut last = 0u64;
        for step in 0..4 {
            let out = cl.assign_top2(&reps, d, &cents, &c);
            let delta = c.get() - last;
            last = c.get();
            let stats = cl.last_stats();
            assert_eq!(delta, stats.pairs + stats.bookkeeping, "step {step}: self-account");
            assert_eq!(stats.bill, (m * k) as u64);
            assert!(delta <= (m * k) as u64, "approximate bill must never exceed exact");
            if step == 0 {
                assert!(!stats.warm, "cold call is the exact prime");
                assert_eq!(stats.pairs, (m * k) as u64);
            } else {
                assert!(stats.warm, "step {step} (m={m} k={k} expand={expand})");
                assert_eq!(stats.candidates, expand + 1);
                assert_eq!(stats.pairs, (m * (expand + 1)) as u64);
                assert_eq!(stats.bookkeeping, (k * (k - 1) / 2) as u64);
                assert!(stats.hit_rate() >= 0.0 && stats.hit_rate() <= 1.0);
                let serial = SerialAssigner.assign_top2(&reps, d, &cents, &counter());
                for i in 0..m {
                    assert!(
                        out.d1[i] >= serial.d1[i],
                        "row {i}: candidate-subset min below the exact min"
                    );
                }
                // Gap self-report: ordered bit-exactly, uncounted.
                let before = c.get();
                let gap = cl
                    .quality_gap(&reps, None, d, &cents)
                    .expect("closure backend always reports");
                assert_eq!(gap.backend, "closure");
                assert!(gap.approx_err >= gap.exact_err, "monotone rounding ordering");
                assert_eq!(c.get(), before, "measurement is uncounted (§2.4)");
            }
            for v in cents.iter_mut() {
                *v += g.rng.normal() * 0.05;
            }
        }
    });
}

#[test]
fn prop_sampled_full_sample_equals_exact_lloyd_outcome() {
    // `sample_rows ≥ m` routes every step through the exact path: the
    // whole weighted-Lloyd outcome — centroids, assignment, top-2
    // distances, werr bits, iteration count, counter total — is `==` the
    // native stepper's, for any seed.
    prop::check("approx-sampled-full", 20, |g| {
        let d = DIMS[g.int(0, DIMS.len() - 1)];
        let m = g.int(2, 120);
        let k = g.int(1, 6).min(m);
        let reps = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
        let init: Vec<f64> = reps[..k * d].to_vec();
        let cfg = WLloydCfg { max_iters: 6, ..Default::default() };
        let c1 = counter();
        let exact =
            weighted_lloyd_with(&mut NativeStepper::new(), &reps, &weights, d, &init, &cfg, &c1);
        let c2 = counter();
        let mut st = SampledStepper::new(m + g.int(0, 5), g.int(0, 10_000) as u64);
        let full = weighted_lloyd_with(&mut st, &reps, &weights, d, &init, &cfg, &c2);
        assert_eq!(exact.centroids, full.centroids);
        assert_eq!(exact.assign, full.assign);
        assert_eq!(exact.d1, full.d1);
        assert_eq!(exact.d2, full.d2);
        assert_eq!(exact.werr.to_bits(), full.werr.to_bits());
        assert_eq!(exact.iters, full.iters);
        assert_eq!(c1.get(), c2.get(), "identical m·k bill per step");
    });
}

#[test]
fn prop_sampled_bill_pinned_and_reruns_deterministic() {
    // Warm sampled steps: counter delta == s·k (the self-reported pairs),
    // strictly below the m·k bill; and the whole trajectory — outputs,
    // bills, fallback tally — replays identically under the same private
    // seed (satellite: fallback-to-exact determinism).
    prop::check("approx-sampled-bill", 20, |g| {
        let d = g.int(1, 5);
        let m = g.int(40, 160);
        let k = g.int(2, 6);
        let s = g.int(1, m - 1);
        let seed = g.int(0, 10_000) as u64;
        let reps = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 5) as f64).collect();
        let cents0 = g.cloud(k, d, 2.0);
        let run = |seed: u64| {
            let mut st = SampledStepper::new(s, seed);
            let c = counter();
            let mut cents = cents0.clone();
            let mut deltas = Vec::new();
            let mut last = 0u64;
            let mut werrs = Vec::new();
            for _ in 0..3 {
                let o = st.step(&reps, &weights, d, &cents, &c);
                deltas.push(c.get() - last);
                last = c.get();
                werrs.push(o.werr.to_bits());
                cents = o.centroids;
            }
            (deltas, werrs, cents, st.last_stats().fallbacks)
        };
        let (deltas, werrs, cents, fallbacks) = run(seed);
        assert_eq!(deltas[0], (m * k) as u64, "cold prime pays the exact bill");
        for (step, &delta) in deltas.iter().enumerate().skip(1) {
            assert_eq!(delta, (s * k) as u64, "step {step}: delta == own account");
            assert!(delta < (m * k) as u64, "sampled bill strictly below exact");
        }
        let (d2, w2, c2, f2) = run(seed);
        assert_eq!(deltas, d2, "same seed: same bills");
        assert_eq!(werrs, w2, "same seed: same trajectory, bit for bit");
        assert_eq!(cents, c2);
        assert_eq!(fallbacks, f2, "same seed: same fallback tally");
    });
}

#[test]
fn closure_quality_gap_within_declared_bound_on_clustered_data() {
    // GS-style workload: well-separated Gaussian blobs with centroids
    // drifting near the blob means — the regime the closure heuristic is
    // built for. Declared bound for this suite: relative gap ≤ 25%.
    let mut g = prop::Gen { rng: Rng::new(0xA991), case: 0 };
    let (m, d, k) = (600, 5, 6);
    let reps = g.blobs(m, d, k, 0.4);
    let weights = vec![1.0; m];
    let mut cl = ClosureAssigner::new(2);
    let c = counter();
    let mut cents: Vec<f64> = reps[..k * d].to_vec();
    let _ = cl.assign_top2(&reps, d, &cents, &c); // prime anchors
    for step in 0..4 {
        for v in cents.iter_mut() {
            *v += g.rng.normal() * 0.02;
        }
        let _ = cl.assign_top2(&reps, d, &cents, &c);
        assert!(cl.last_stats().warm, "step {step}");
        let gap = cl
            .quality_gap(&reps, Some(&weights), d, &cents)
            .expect("closure backend always reports");
        assert!(gap.approx_err >= gap.exact_err, "step {step}: bit-exact ordering");
        assert!(
            gap.rel_gap() <= 0.25,
            "step {step}: rel gap {} above the declared bound",
            gap.rel_gap()
        );
        assert!((0.0..=1.0).contains(&gap.hit_rate));
        assert!(gap.note().starts_with("gap[closure]: "), "pinned note prefix");
    }
}

#[test]
fn degenerate_cases_fall_back_to_exact() {
    // k = 1: the closure would be total; every call is the serial
    // fallback (full bill, d2 = ∞, tallied).
    let reps = [0.0, 1.0, 2.0, 3.0];
    let mut cl = ClosureAssigner::new(3);
    for step in 0..2u64 {
        let c = counter();
        let out = cl.assign_top2(&reps, 1, &[1.5], &c);
        assert_eq!(c.get(), 4);
        assert!(out.d2.iter().all(|x| x.is_infinite()));
        assert!(!cl.last_stats().warm);
        assert_eq!(cl.last_stats().fallbacks, step + 1);
    }

    // Duplicate points + exact-tie centroids inside a *warm* closure: the
    // candidate scan inherits the serial lowest-index tie-breaking on the
    // subset, and a coincident runner-up gives d2 == d1.
    let reps: Vec<f64> = vec![10.0; 8];
    let cents = [0.0, 10.0, 10.0, 50.0];
    let mut cl = ClosureAssigner::new(1);
    let c = counter();
    let cold = cl.assign_top2(&reps, 1, &cents, &c);
    assert_eq!(cold.assign, vec![1; 8], "serial tie-breaking on the cold prime");
    let warm = cl.assign_top2(&reps, 1, &cents, &c);
    assert!(cl.last_stats().warm);
    assert_eq!(warm.assign, vec![1; 8], "lowest index wins among coincident candidates");
    for i in 0..8 {
        assert_eq!(warm.d1[i], 0.0);
        assert_eq!(warm.d2[i], 0.0, "coincident runner-up inside the closure: d2 == d1");
    }

    // All-zero weights: the sampled stepper has nothing to rescale by and
    // must route through the exact step, every call.
    let mut st = SampledStepper::new(2, 9);
    let reps = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
    let weights = [0.0; 6];
    let cents = [0.5, 4.5];
    let c = counter();
    let _ = st.step(&reps, &weights, 1, &cents, &c);
    let _ = st.step(&reps, &weights, 1, &cents, &c);
    assert!(st.last_stats().exact);
    assert_eq!(st.last_stats().fallbacks, 2);
    assert_eq!(c.get(), 2 * 6 * 2, "both calls pay the exact bill");
}

#[test]
fn end_to_end_runs_self_report_exactly_one_gap_note() {
    let mut g = prop::Gen { rng: Rng::new(0xE2E0), case: 0 };
    let (n, d, k) = (400, 3, 4);
    let ds = Dataset::new(g.blobs(n, d, k, 0.6), d);

    // BWKM, all three modes.
    let run_mode = |assign: AssignCfg| {
        let mut cfg = BwkmCfg::for_dataset(n, d, k);
        cfg.max_outer = 4;
        cfg.assign = assign;
        let c = DistanceCounter::new();
        let out = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(3), &c);
        let gaps = gap_notes(&c);
        (out, c.get(), gaps)
    };
    let exact = run_mode(AssignCfg::default());
    assert_eq!(exact.2, 0, "exact runs report no gap");
    let closure = run_mode(AssignCfg { mode: AssignMode::Closure, ..Default::default() });
    assert_eq!(closure.2, 1, "one gap note per approximate run");
    assert!(!closure.0.trace.is_empty());
    // A full sample makes every sampled step the exact step: the whole
    // run is bit-identical to the exact run — only the self-report
    // (uncounted) differs.
    let sampled = run_mode(AssignCfg {
        mode: AssignMode::Sampled,
        sample_rows: usize::MAX,
        ..Default::default()
    });
    assert_eq!(sampled.2, 1);
    assert_eq!(sampled.0.centroids, exact.0.centroids, "full sample == exact, bit for bit");
    assert_eq!(sampled.0.stop, exact.0.stop);
    assert_eq!(sampled.1, exact.1, "identical distance totals");

    // Grid RPKM.
    let rcfg = RpkmCfg {
        max_levels: 4,
        assign: AssignCfg { mode: AssignMode::Sampled, sample_rows: 32, ..Default::default() },
        ..Default::default()
    };
    let c = DistanceCounter::new();
    let out = grid_rpkm(&ds, k, &rcfg, &mut Rng::new(5), &c);
    assert!(!out.centroids.is_empty());
    assert_eq!(gap_notes(&c), 1);
    let c2 = DistanceCounter::new();
    let _ = grid_rpkm(&ds, k, &RpkmCfg { max_levels: 3, ..Default::default() }, &mut Rng::new(5), &c2);
    assert_eq!(gap_notes(&c2), 0, "exact RPKM reports no gap");

    // Out-of-core coordinator (run_source emits the note for both paths).
    let mut cfg = BwkmCfg::for_dataset(n, d, k);
    cfg.max_outer = 3;
    cfg.assign = AssignCfg { mode: AssignMode::Closure, ..Default::default() };
    let c3 = DistanceCounter::new();
    let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), d, 97), d);
    let out = sb.run(k, &cfg, &mut Rng::new(3), &c3).expect("streaming run");
    assert!(!out.centroids.is_empty());
    assert_eq!(gap_notes(&c3), 1, "streamed approximate run self-reports once");
}
