//! Resident-service conformance (DESIGN.md §5.2): the model store's
//! round-trip pin — save → load → resume is **bit-identical** (`==`, no
//! tolerances) to the uninterrupted run, in centroids, trace, stop
//! reason, top-2 distances, RNG stream and distance bill — plus the
//! warm-start ingestion billing and determinism contracts and the job
//! scheduler's worker-count independence.

use bwkm::bwkm::{BwkmCfg, StopReason, TracePoint};
use bwkm::coordinator::run_jobs;
use bwkm::data::{simulate, Dataset};
use bwkm::metrics::DistanceCounter;
use bwkm::store::{self, ingest, IngestReport, Model};
use bwkm::util::Rng;

fn cfg_for(ds: &Dataset, k: usize, max_outer: usize) -> BwkmCfg {
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    // The early-stop tolerances default to None (disabled), so a low cap
    // makes the cut run genuinely iteration-capped (stop = MaxIters) and
    // leaves the resume real work.
    cfg.max_outer = max_outer;
    cfg.eval_full_error = false;
    cfg
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_trace_eq(a: &[TracePoint], b: &[TracePoint]) {
    assert_eq!(a.len(), b.len(), "trace lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.outer_iter, y.outer_iter);
        assert_eq!(x.distances, y.distances, "bill drift at outer {}", x.outer_iter);
        assert_eq!(x.blocks, y.blocks);
        assert_eq!(x.occupied, y.occupied);
        assert_eq!(x.boundary, y.boundary);
        assert_eq!(x.weighted_error.to_bits(), y.weighted_error.to_bits());
        assert_eq!(x.bound.to_bits(), y.bound.to_bits());
        assert_eq!(
            x.full_error.map(f64::to_bits),
            y.full_error.map(f64::to_bits)
        );
        assert_eq!(x.lloyd_iters, y.lloyd_iters);
    }
}

fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("bwkm_svc_{tag}_{}.mdl", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn save_load_resume_is_bit_identical_to_uninterrupted() {
    let ds = simulate("3RN", 0.003, 7).unwrap();
    let k = 3;

    // Uninterrupted reference: 5 outer iterations in one sitting.
    let full_cfg = cfg_for(&ds, k, 5);
    let ca = DistanceCounter::new();
    let mut ra = Rng::new(11);
    let a = bwkm::bwkm::run(&ds, k, &full_cfg, &mut ra, &ca);

    // The same run cut at 2, persisted through the file layer, resumed.
    let cut_cfg = cfg_for(&ds, k, 2);
    let cb = DistanceCounter::new();
    let mut rb = Rng::new(11);
    let b = bwkm::bwkm::run(&ds, k, &cut_cfg, &mut rb, &cb);
    assert_eq!(b.stop, StopReason::MaxIters, "cut run must be iteration-capped");
    let path = tmp("roundtrip");
    store::save(&Model::from_run(&b, &cut_cfg, &rb, &cb), &path).unwrap();

    let model = store::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let cr = DistanceCounter::new();
    let mut rr = Rng::new(999_999); // must be overwritten by the snapshot
    let r = store::resume(&model, &ds, &full_cfg, &mut rr, &cr).unwrap();

    // The pin: `==` everywhere, no tolerances.
    assert_eq!(bits(&a.centroids), bits(&r.centroids), "centroids diverged");
    assert_eq!(a.stop, r.stop);
    assert_trace_eq(&a.trace, &r.trace);
    assert_eq!(ca.get(), cr.get(), "distance bills must match to the unit");
    assert_eq!(bits(&a.d1), bits(&r.d1));
    assert_eq!(bits(&a.d2), bits(&r.d2));
    // The RNG stream advanced identically: a follow-up save would match.
    assert_eq!(ra.state(), rr.state(), "RNG streams diverged");
}

#[test]
fn resume_of_a_terminal_snapshot_is_a_noop() {
    let ds = simulate("3RN", 0.002, 9).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 4;
    cfg.eval_full_error = false;
    let c = DistanceCounter::new();
    let mut rng = Rng::new(5);
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
    let model = Model::from_run(&out, &cfg, &rng, &c);

    // Same config back in: whether the run ended on a terminal criterion
    // or at the cap, there is nothing left to do — and nothing billed.
    let cr = DistanceCounter::new();
    let mut rr = Rng::new(1);
    let r = store::resume(&model, &ds, &cfg, &mut rr, &cr).unwrap();
    assert_eq!(bits(&out.centroids), bits(&r.centroids));
    assert_eq!(out.stop, r.stop);
    assert_eq!(out.trace.len(), r.trace.len());
    assert_eq!(cr.get(), model.distances, "a no-op resume bills nothing new");
}

#[test]
fn save_load_through_disk_is_byte_exact() {
    let ds = simulate("3RN", 0.002, 13).unwrap();
    let cfg = cfg_for(&ds, 3, 2);
    let c = DistanceCounter::new();
    let mut rng = Rng::new(3);
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
    let model = Model::from_run(&out, &cfg, &rng, &c);
    let path = tmp("bytes");
    store::save(&model, &path).unwrap();
    let back = store::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(model.to_bytes(), back.to_bytes(), "disk round-trip changed bytes");
}

#[test]
fn empty_batch_ingest_is_a_zero_bill_noop() {
    let ds = simulate("3RN", 0.002, 17).unwrap();
    let cfg = cfg_for(&ds, 3, 2);
    let c = DistanceCounter::new();
    let mut rng = Rng::new(4);
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
    let mut model = Model::from_run(&out, &cfg, &rng, &c);

    let before = model.to_bytes();
    let bill = DistanceCounter::new();
    let report = ingest(&mut model, &Dataset::new(vec![], ds.d), &cfg, &bill).unwrap();
    assert_eq!(report, IngestReport::default(), "empty batch must report all zeros");
    assert_eq!(bill.get(), 0, "empty batch must bill zero distances");
    assert_eq!(model.to_bytes(), before, "empty batch must not perturb the model");
}

#[test]
fn ingest_bill_is_exact_and_ingest_is_deterministic() {
    let ds = simulate("3RN", 0.003, 21).unwrap();
    let k = 3;
    let cfg = cfg_for(&ds, k, 2);
    let c = DistanceCounter::new();
    let mut rng = Rng::new(6);
    let out = bwkm::bwkm::run(&ds, k, &cfg, &mut rng, &c);
    let mut model = Model::from_run(&out, &cfg, &rng, &c);
    let snapshot = model.to_bytes();

    // A batch drawn from a different part of the distribution, same d.
    let other = simulate("3RN", 0.003, 22).unwrap();
    let batch = Dataset::new(other.data[..other.d * 24].to_vec(), other.d);

    let c1 = DistanceCounter::new();
    let r1 = ingest(&mut model, &batch, &cfg, &c1).unwrap();
    assert_eq!(r1.rows, 24);
    assert!(r1.touched >= 1);
    let occupied = model.cells.iter().filter(|c| c.count > 0).count();
    let expect = ((batch.n + r1.touched) * k + r1.refine_iters * occupied * k) as u64;
    assert_eq!(r1.bill, expect, "the §5.2 ingest billing identity");
    assert_eq!(c1.get(), r1.bill, "counter delta must equal the reported bill");
    assert_eq!(model.rows, ds.n as u64 + 24);

    // Byte-for-byte determinism from the same snapshot.
    let mut m2 = Model::from_bytes(&snapshot).unwrap();
    let c2 = DistanceCounter::new();
    let r2 = ingest(&mut m2, &batch, &cfg, &c2).unwrap();
    assert_eq!(r1, r2, "ingest reports diverged");
    assert_eq!(model.to_bytes(), m2.to_bytes(), "ingested models diverged");
}

#[test]
fn ingested_model_still_resumes_over_the_grown_dataset() {
    // Ingest, then hand resume the original rows + the batch rows: the
    // stored cell counts must reconcile with a locate() re-assignment.
    let ds = simulate("3RN", 0.003, 31).unwrap();
    let cfg = cfg_for(&ds, 3, 2);
    let c = DistanceCounter::new();
    let mut rng = Rng::new(8);
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut rng, &c);
    let mut model = Model::from_run(&out, &cfg, &rng, &c);

    let other = simulate("3RN", 0.003, 32).unwrap();
    let batch = Dataset::new(other.data[..other.d * 10].to_vec(), other.d);
    ingest(&mut model, &batch, &cfg, &DistanceCounter::new()).unwrap();

    let mut grown = ds.data.clone();
    grown.extend_from_slice(&batch.data);
    let grown = Dataset::new(grown, ds.d);
    let mut full_cfg = cfg.clone();
    full_cfg.max_outer = 4;
    let cr = DistanceCounter::new();
    let mut rr = Rng::new(2);
    let r = store::resume(&model, &grown, &full_cfg, &mut rr, &cr).unwrap();
    assert_eq!(r.centroids.len(), 3 * ds.d);
    assert!(r.centroids.iter().all(|x| x.is_finite()));
    assert!(r.trace.len() >= model.trace.len(), "resume lost trace history");
}

#[test]
fn job_scheduler_is_worker_count_independent_on_real_runs() {
    let ds = simulate("3RN", 0.002, 41).unwrap();
    let cfg = cfg_for(&ds, 3, 2);
    let run_one = |_job: usize, rng: &mut Rng, counter: &DistanceCounter| {
        let out = bwkm::bwkm::run(&ds, 3, &cfg, rng, counter);
        (bits(&out.centroids), out.stop)
    };
    let solo = run_jobs(4, 1, 77, run_one);
    let pooled = run_jobs(4, 3, 77, run_one);
    for (a, b) in solo.iter().zip(&pooled) {
        assert_eq!(a.out, b.out, "job {} diverged across pool sizes", a.job);
        assert_eq!(a.distances, b.distances, "job {} bill diverged", a.job);
    }
    // Distinct seed streams: the jobs are independent replicates, not
    // four copies of the same run.
    assert!(
        solo.windows(2).any(|w| w[0].out.0 != w[1].out.0),
        "all jobs produced identical centroids — streams not forked?"
    );
}
