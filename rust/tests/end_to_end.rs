//! Cross-module integration: every method of the paper's comparison runs
//! end to end on every Table-1 simulator (tiny scales), the full-figure
//! protocol holds together, and the paper's headline *shape* (BWKM reaches
//! competitive error with orders-of-magnitude fewer distances) shows up.

use bwkm::bwkm::{BwkmCfg, StopReason};
use bwkm::data::{simulate, TABLE1};
use bwkm::kmeans::init::{forgy, kmc2, kmeanspp, Kmc2Cfg};
use bwkm::kmeans::{lloyd, minibatch_kmeans, LloydCfg, MiniBatchCfg};
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::rpkm::{grid_rpkm, RpkmCfg};
use bwkm::util::Rng;

#[test]
fn all_methods_on_all_simulators() {
    for spec in TABLE1 {
        let ds = simulate(spec.name, 0.0006, 1).unwrap();
        let k = 3;
        let mut rng = Rng::new(2);
        let eval = DistanceCounter::new();

        // Lloyd-based.
        let c = DistanceCounter::new();
        let init = forgy(&ds.data, ds.d, k, &mut rng);
        let f = lloyd(&ds.data, ds.d, &init, &LloydCfg { max_iters: 8, ..Default::default() }, &c);
        assert!(f.error.is_finite());

        let init = kmeanspp(&ds.data, ds.d, k, &mut rng, &c);
        let p = lloyd(&ds.data, ds.d, &init, &LloydCfg { max_iters: 8, ..Default::default() }, &c);
        assert!(p.error.is_finite());

        let init = kmc2(&ds.data, ds.d, k, &Kmc2Cfg { chain_length: 30 }, &mut rng, &c);
        let q = lloyd(&ds.data, ds.d, &init, &LloydCfg { max_iters: 8, ..Default::default() }, &c);
        assert!(q.error.is_finite());

        // Mini-batch.
        let mb = minibatch_kmeans(
            &ds.data,
            ds.d,
            k,
            &MiniBatchCfg { batch: 64, max_iters: 30, ..Default::default() },
            &mut rng,
            &c,
        );
        assert!(kmeans_error(&ds.data, ds.d, &mb.centroids, &eval).is_finite());

        // RPKM.
        let r = grid_rpkm(
            &ds,
            k,
            &RpkmCfg { max_levels: 3, ..Default::default() },
            &mut rng,
            &c,
        );
        assert!(kmeans_error(&ds.data, ds.d, &r.centroids, &eval).is_finite());

        // BWKM.
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
        cfg.max_outer = 6;
        let b = bwkm::bwkm::run(&ds, k, &cfg, &mut rng, &c);
        let e = kmeans_error(&ds.data, ds.d, &b.centroids, &eval);
        assert!(e.is_finite(), "{}: BWKM produced non-finite error", spec.name);
    }
}

/// The paper's headline: BWKM reaches within a few percent of Lloyd-based
/// methods' error using far fewer distance computations (here: ≥ 5x less
/// on the favourable WUY regime; the paper reports 2–6 orders at scale).
#[test]
fn headline_tradeoff_on_wuy() {
    let ds = simulate("WUY", 0.001, 3).unwrap();
    let k = 9;
    let reps = 3;
    let mut ratios = Vec::new();
    let mut rel_errs = Vec::new();
    for rep in 0..reps {
        let mut rng = Rng::new(100 + rep);
        let c_ref = DistanceCounter::new();
        let init = kmeanspp(&ds.data, ds.d, k, &mut rng, &c_ref);
        let l = lloyd(&ds.data, ds.d, &init, &LloydCfg { max_iters: 30, ..Default::default() }, &c_ref);

        let c_b = DistanceCounter::new();
        let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
        cfg.max_outer = 25;
        let out = bwkm::bwkm::run(&ds, k, &cfg, &mut rng, &c_b);
        let eval = DistanceCounter::new();
        let e_b = kmeans_error(&ds.data, ds.d, &out.centroids, &eval);

        ratios.push(c_ref.get() as f64 / c_b.get() as f64);
        rel_errs.push((e_b - l.error) / l.error);
    }
    let mean_ratio = ratios.iter().sum::<f64>() / reps as f64;
    let mean_rel = rel_errs.iter().sum::<f64>() / reps as f64;
    assert!(
        mean_ratio > 5.0,
        "expected ≥5x distance reduction on WUY, got {mean_ratio:.2}x ({ratios:?})"
    );
    assert!(
        mean_rel < 0.10,
        "BWKM error should be within 10% of KM+++Lloyd, got {:.2}% ({rel_errs:?})",
        100.0 * mean_rel
    );
}

/// Empty-boundary termination really means a Lloyd fixed point (Thm 3) —
/// checked on a well-separated instance where BWKM converges fast.
#[test]
fn empty_boundary_fixed_point_on_separated_blobs() {
    let mut rng = Rng::new(8);
    let mut data = Vec::new();
    for &(cx, cy) in &[(0.0, 0.0), (100.0, 0.0), (0.0, 100.0)] {
        for _ in 0..400 {
            data.push(cx + rng.normal());
            data.push(cy + rng.normal());
        }
    }
    let ds = bwkm::data::Dataset::new(data, 2);
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 300;
    let c = DistanceCounter::new();
    let out = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(9), &c);
    assert_eq!(out.stop, StopReason::EmptyBoundary, "trace: {:?}", out.trace.len());
    let one = lloyd(
        &ds.data,
        ds.d,
        &out.centroids,
        &LloydCfg { max_iters: 1, eps: 0.0, ..Default::default() },
        &DistanceCounter::new(),
    );
    let shift = bwkm::kmeans::weighted_lloyd::max_shift(&out.centroids, &one.centroids, 2, 3);
    assert!(shift < 1e-9, "Thm 3 violated: {shift}");
}

/// The config → CLI path: a full `run` through the public surface.
#[test]
fn cli_run_bwkm_and_rpkm() {
    bwkm::cli::main(&[
        "run".into(),
        "dataset=GS".into(),
        "scale=0.0004".into(),
        "k=3".into(),
        "method=bwkm".into(),
        "max_outer=4".into(),
        "seed=3".into(),
    ])
    .unwrap();
    bwkm::cli::main(&[
        "run".into(),
        "dataset=CIF".into(),
        "scale=0.02".into(),
        "k=3".into(),
        "method=rpkm".into(),
    ])
    .unwrap();
    bwkm::cli::main(&["run".into(), "method=kmpp_init".into(), "scale=0.0005".into()]).unwrap();
}

/// Sharded coordination produces byte-identical traces to serial BWKM.
#[test]
fn sharded_bwkm_equals_serial() {
    let ds = simulate("3RN", 0.004, 5).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 6;
    let c1 = DistanceCounter::new();
    let serial = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(11), &c1);
    let c2 = DistanceCounter::new();
    let mut stepper = bwkm::coordinator::ShardedStepper::new(3);
    let sharded = bwkm::bwkm::run_with(&mut stepper, &ds, 3, &cfg, &mut Rng::new(11), &c2);
    assert_eq!(c1.get(), c2.get());
    for (a, b) in serial.centroids.iter().zip(&sharded.centroids) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}
