//! Conformance suite for the seeding subsystem (DESIGN.md §2.8):
//!
//! * every `Seeder` trait backend is **bit-identical** (`==`, no
//!   tolerances) to the legacy free function it wraps, at identical
//!   counter totals;
//! * every seeder's distance count is pinned by its exact closed-form
//!   bill — Forgy 0, K-means++ m·(k−1), AFK-MC² m + chain·k·(k−1)/2,
//!   K-means|| m·|C| + |C|·(k−1);
//! * K-means|| is bit-identical across engines (serial vs `Sharded<B>`
//!   refresh) and across the in-memory / out-of-core divide: the
//!   streamed `StreamSeeder` equals the in-memory `KmeansParSeeder` —
//!   centroids, counter totals, counter notes — over the chunk-size ×
//!   worker-count grid;
//! * degenerates hold: k = 1, k > distinct points, identical points,
//!   k > n (the ForgySeeder pad);
//! * the seeding policy flows through BWKM identically in memory and out
//!   of core.

use bwkm::bwkm::BwkmCfg;
use bwkm::coordinator::{StreamSeeder, StreamingBwkm};
use bwkm::data::Dataset;
use bwkm::kmeans::init::{
    forgy, kmc2, kmeanspp, weighted_kmeanspp, ForgySeeder, Kmc2Cfg, Kmc2Seeder, KmeansParSeeder,
    KmppSeeder, ParCfg, SeedMethod, SeedPolicy, Seeder,
};
use bwkm::kmeans::{SerialAssigner, Sharded};
use bwkm::metrics::DistanceCounter;
use bwkm::util::prop;
use bwkm::util::Rng;

fn counter() -> DistanceCounter {
    DistanceCounter::new()
}

fn unit(m: usize) -> Vec<f64> {
    vec![1.0; m]
}

fn chunked(data: &[f64], d: usize, rows_per_chunk: usize) -> Vec<anyhow::Result<Vec<f64>>> {
    data.chunks(rows_per_chunk * d).map(|c| Ok(c.to_vec())).collect()
}

fn vec_opener(
    data: Vec<f64>,
    d: usize,
    rows_per_chunk: usize,
) -> impl FnMut() -> anyhow::Result<Vec<anyhow::Result<Vec<f64>>>> {
    move || Ok(chunked(&data, d, rows_per_chunk))
}

// ---------------------------------------------------------------------------
// Trait backends == legacy free functions, bit for bit.
// ---------------------------------------------------------------------------

#[test]
fn prop_trait_backends_match_free_functions() {
    prop::check("seeder-vs-free", 25, |g| {
        let m = g.int(2, 200);
        let d = g.int(1, 6);
        let k = g.int(1, m.min(8));
        let data = g.cloud(m, d, 2.0);
        let weights: Vec<f64> = (0..m).map(|_| g.int(1, 9) as f64).collect();
        let seed = g.rng.next_u64();

        // Forgy (weight-blind, distance-free).
        let c1 = counter();
        let a = ForgySeeder.seed(&data, &weights, d, k, &mut Rng::new(seed), &c1);
        let b = forgy(&data, d, k, &mut Rng::new(seed));
        assert_eq!(a, b);
        assert_eq!(c1.get(), 0);

        // Weighted K-means++.
        let c1 = counter();
        let a = KmppSeeder.seed(&data, &weights, d, k, &mut Rng::new(seed), &c1);
        let c2 = counter();
        let b = weighted_kmeanspp(&data, &weights, d, k, &mut Rng::new(seed), &c2);
        assert_eq!(a, b);
        assert_eq!(c1.get(), c2.get());

        // Plain K-means++ == the trait backend on unit weights.
        let c1 = counter();
        let a = KmppSeeder.seed(&data, &unit(m), d, k, &mut Rng::new(seed), &c1);
        let c2 = counter();
        let b = kmeanspp(&data, d, k, &mut Rng::new(seed), &c2);
        assert_eq!(a, b);
        assert_eq!(c1.get(), c2.get());

        // AFK-MC² (weight-blind).
        let cfg = Kmc2Cfg { chain_length: g.int(2, 40) };
        let c1 = counter();
        let a = Kmc2Seeder { cfg }.seed(&data, &weights, d, k, &mut Rng::new(seed), &c1);
        let c2 = counter();
        let b = kmc2(&data, d, k, &cfg, &mut Rng::new(seed), &c2);
        assert_eq!(a, b);
        assert_eq!(c1.get(), c2.get());
    });
}

// ---------------------------------------------------------------------------
// Exact counter pins (DESIGN.md §2.8's closed forms).
// ---------------------------------------------------------------------------

#[test]
fn prop_counter_closed_forms() {
    prop::check("seeder-bills", 20, |g| {
        let m = g.int(2, 150);
        let d = g.int(1, 5);
        let k = g.int(1, m.min(7));
        let data = g.cloud(m, d, 2.0);
        let w = unit(m);
        let seed = g.rng.next_u64();

        // Forgy: 0 — selection is sampling, never distance work.
        let c = counter();
        let _ = ForgySeeder.seed(&data, &w, d, k, &mut Rng::new(seed), &c);
        assert_eq!(c.get(), 0);

        // K-means++: each added centroid refreshes the min-distance
        // array with one new distance per row → m·(k−1).
        let c = counter();
        let _ = KmppSeeder.seed(&data, &w, d, k, &mut Rng::new(seed), &c);
        assert_eq!(c.get(), (m * (k - 1)) as u64);

        // AFK-MC²: one proposal pass (m) plus, per added centroid
        // j = 1..k−1, a chain of `chain` states costing |C| = j each →
        // m + chain·k·(k−1)/2 for k ≥ 2; for k = 1 the documented bill
        // is 0 (the single centroid is a uniform draw — the proposal
        // pass is skipped).
        let chain = g.int(2, 30);
        let c = counter();
        let _ = Kmc2Seeder { cfg: Kmc2Cfg { chain_length: chain } }
            .seed(&data, &w, d, k, &mut Rng::new(seed), &c);
        if k == 1 {
            assert_eq!(c.get(), 0);
        } else {
            assert_eq!(c.get(), (m + chain * (k * (k - 1)) / 2) as u64);
        }

        // K-means||: every candidate batch (the c₀ prime included) is
        // scanned against all m rows exactly once, and the recluster is
        // a weighted K-means++ over the |C| candidates →
        // m·|C| + |C|·(k−1).
        let cfg = ParCfg { rounds: g.int(1, 5), oversample: g.f64(0.5, 8.0) };
        let c = counter();
        let mut s = KmeansParSeeder::new(cfg);
        let cents = s.seed(&data, &w, d, k, &mut Rng::new(seed), &c);
        assert_eq!(cents.len(), k * d);
        let stats = s.last_stats().clone();
        assert_eq!(stats.candidates, 1 + stats.batches.iter().sum::<usize>());
        assert_eq!(c.get(), stats.bill(m, k), "kmeans|| bill must be m·|C| + |C|·(k−1)");
    });
}

// ---------------------------------------------------------------------------
// K-means||: sharded and streamed == serial, bit for bit.
// ---------------------------------------------------------------------------

#[test]
fn prop_kmeans_par_sharded_and_streamed_bit_identical() {
    prop::check("kmpar-grid", 8, |g| {
        let m = g.int(20, 300);
        let d = [2usize, 3, 5, 17][g.int(0, 3)];
        let k = g.int(1, 6);
        let data = g.cloud(m, d, 3.0);
        let cfg = ParCfg { rounds: g.int(1, 4), oversample: 0.0 };
        let seed = g.rng.next_u64();

        // Reference: serial in-memory seeder on unit weights.
        let c_ref = counter();
        let mut s_ref = KmeansParSeeder::new(cfg);
        let reference = s_ref.seed(&data, &unit(m), d, k, &mut Rng::new(seed), &c_ref);

        // Sharded engine refresh.
        for threads in [2usize, 8] {
            let c = counter();
            let mut s = KmeansParSeeder::with_engine(cfg, Sharded::<SerialAssigner>::new(threads));
            let out = s.seed(&data, &unit(m), d, k, &mut Rng::new(seed), &c);
            assert_eq!(out, reference, "threads={threads}");
            assert_eq!(c.get(), c_ref.get());
            assert_eq!(c.notes(), c_ref.notes());
        }

        // Streamed: chunk sizes {1, 7, n} × workers {1, 2, 8}.
        for chunk in [1usize, 7, m] {
            for threads in [1usize, 2, 8] {
                let c = counter();
                let mut sb =
                    StreamSeeder::new(vec_opener(data.clone(), d, chunk), d).with_threads(threads);
                let out = sb.kmeans_par(k, &cfg, &mut Rng::new(seed), &c).unwrap();
                assert_eq!(out.centroids, reference, "chunk={chunk} threads={threads}");
                assert_eq!(out.rows, m);
                assert_eq!(out.candidates, s_ref.last_stats().candidates);
                assert_eq!(c.get(), c_ref.get(), "counter totals must match");
                assert_eq!(c.notes(), c_ref.notes(), "round notes must match");
            }
        }
    });
}

#[test]
fn streamed_seeder_rejects_bad_streams() {
    let c = counter();
    let mut empty = StreamSeeder::new(|| Ok(Vec::<anyhow::Result<Vec<f64>>>::new()), 2);
    assert!(empty.kmeans_par(2, &ParCfg::default(), &mut Rng::new(1), &c).is_err());
    // Ragged chunk (5 values, d=2) is a clean error, never a silent drop.
    let mut ragged = StreamSeeder::new(|| Ok(vec![Ok(vec![0.0; 5])]), 2);
    assert!(ragged.kmeans_par(1, &ParCfg::default(), &mut Rng::new(1), &c).is_err());
    // A source that shrinks between passes is detected.
    let data: Vec<f64> = (0..40).map(|x| x as f64).collect();
    let mut opens = 0usize;
    let base = data.clone();
    let mut shrinking = StreamSeeder::new(
        move || -> anyhow::Result<Vec<anyhow::Result<Vec<f64>>>> {
            opens += 1;
            let take = if opens == 1 { 40 } else { 38 };
            Ok(base[..take].chunks(10).map(|c| Ok(c.to_vec())).collect())
        },
        2,
    );
    assert!(shrinking.kmeans_par(2, &ParCfg::default(), &mut Rng::new(1), &c).is_err());
    // A source that *grows* between passes must be a clean Err too (the
    // driver's fold state is sized to the count pass), never a panic.
    // Growth starts after the count and c₀-fetch passes, so it is the
    // prime pass's own fold guard that has to catch it.
    let mut opens = 0usize;
    let base = data.clone();
    let mut growing = StreamSeeder::new(
        move || -> anyhow::Result<Vec<anyhow::Result<Vec<f64>>>> {
            opens += 1;
            let mut rows = base.clone();
            if opens > 2 {
                rows.extend_from_slice(&[99.0, 99.0, 98.0, 98.0]);
            }
            Ok(rows.chunks(10).map(|c| Ok(c.to_vec())).collect())
        },
        2,
    );
    assert!(growing.kmeans_par(2, &ParCfg::default(), &mut Rng::new(1), &c).is_err());
    // Non-finite values are a loud error at the count pass (a NaN would
    // otherwise silently collapse every round's sampling).
    let mut nan = data.clone();
    nan[13] = f64::NAN;
    let mut poisoned = StreamSeeder::new(vec_opener(nan, 2, 10), 2);
    assert!(poisoned.kmeans_par(2, &ParCfg::default(), &mut Rng::new(1), &c).is_err());
}

// ---------------------------------------------------------------------------
// Degenerates.
// ---------------------------------------------------------------------------

#[test]
fn degenerate_cases_hold_for_every_backend() {
    let policies = [SeedMethod::Forgy, SeedMethod::Kmpp, SeedMethod::Kmc2, SeedMethod::Par];

    // k = 1: every backend returns one row of the data.
    let mut g = prop::Gen { rng: Rng::new(61), case: 0 };
    let data = g.cloud(30, 2, 2.0);
    for method in policies {
        let c = counter();
        let cents =
            SeedPolicy::of(method).seeder().seed(&data, &unit(30), 2, 1, &mut Rng::new(5), &c);
        assert_eq!(cents.len(), 2, "{method:?}");
        assert!(data.chunks(2).any(|r| r == &cents[..]), "{method:?}");
    }

    // Identical points, k > distinct points: k copies of the point.
    let flat = vec![7.5; 20];
    for method in policies {
        let c = counter();
        let cents =
            SeedPolicy::of(method).seeder().seed(&flat, &unit(20), 1, 4, &mut Rng::new(6), &c);
        assert_eq!(cents, vec![7.5; 4], "{method:?}");
    }

    // K-means|| on identical points: ψ = 0 after the prime pass, so the
    // rounds sample nothing and the bill collapses to m + (k−1).
    let c = counter();
    let mut s = KmeansParSeeder::new(ParCfg::default());
    let _ = s.seed(&flat, &unit(20), 1, 4, &mut Rng::new(7), &c);
    assert_eq!(s.last_stats().candidates, 1);
    assert_eq!(c.get(), (20 + 3) as u64);

    // Streamed twin of the identical-point degenerate.
    let c2 = counter();
    let mut sb = StreamSeeder::new(vec_opener(flat.clone(), 1, 3), 1);
    let out = sb.kmeans_par(4, &ParCfg::default(), &mut Rng::new(7), &c2).unwrap();
    assert_eq!(out.centroids, vec![7.5; 4]);
    assert_eq!(c2.get(), c.get());

    // k > n: the ForgySeeder pad (unreachable through the free function).
    let tiny = [0.0, 5.0];
    let c = counter();
    let cents = ForgySeeder.seed(&tiny, &unit(2), 1, 4, &mut Rng::new(8), &c);
    assert_eq!(cents.len(), 4);
    assert!(cents.iter().all(|v| tiny.contains(v)));
    // Both rows appear (the first n draws are distinct).
    assert!(cents[..2].contains(&0.0) && cents[..2].contains(&5.0));
}

// ---------------------------------------------------------------------------
// The policy flows through BWKM identically in memory and out of core.
// ---------------------------------------------------------------------------

#[test]
fn bwkm_par_policy_streamed_equals_in_memory() {
    let mut g = prop::Gen { rng: Rng::new(62), case: 0 };
    let ds = Dataset::new(g.blobs(600, 3, 4, 0.4), 3);
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 4);
    cfg.seed = SeedPolicy::of(SeedMethod::Par);
    cfg.max_outer = 4;

    let c_mem = counter();
    let mem = bwkm::bwkm::run(&ds, 4, &cfg, &mut Rng::new(3), &c_mem);

    let c_str = counter();
    let mut sb = StreamingBwkm::new(vec_opener(ds.data.clone(), 3, 83), 3).with_threads(2);
    let out = sb.run(4, &cfg, &mut Rng::new(3), &c_str).unwrap();

    assert_eq!(out.centroids, mem.centroids);
    assert_eq!(out.stop, mem.stop);
    assert_eq!(c_str.get(), c_mem.get());
    assert_eq!(c_str.notes(), c_mem.notes(), "kmpar round notes must match");
}

// ---------------------------------------------------------------------------
// Direction sanity: K-means|| seeds competitively with K-means++.
// ---------------------------------------------------------------------------

#[test]
fn kmeans_par_quality_tracks_kmeanspp() {
    let mut g = prop::Gen { rng: Rng::new(63), case: 0 };
    let data = g.blobs(800, 2, 5, 0.3);
    let (mut e_par, mut e_pp) = (0.0, 0.0);
    for seed in 0..8 {
        let c = counter();
        let cp = KmeansParSeeder::new(ParCfg::default())
            .seed(&data, &unit(800), 2, 5, &mut Rng::new(seed), &c);
        e_par += bwkm::metrics::kmeans_error(&data, 2, &cp, &c);
        let ck = kmeanspp(&data, 2, 5, &mut Rng::new(seed), &c);
        e_pp += bwkm::metrics::kmeans_error(&data, 2, &ck, &c);
    }
    assert!(e_par < e_pp * 2.0, "km|| seeding error {e_par} vs km++ {e_pp}");
}
