//! Observability conformance (DESIGN.md §2.11): telemetry is strictly
//! **observational**. Identical seeds with `metrics=off` vs a live
//! `jsonl` recorder must produce **bit-identical** results — `==`, no
//! tolerances — in centroids, traces, distance-counter totals, and note
//! logs, across every instrumented surface: the in-memory BWKM loop, the
//! grid-RPKM baseline, the out-of-core coordinator (over a chunk ×
//! worker grid), and the model store's resume path. On top of the
//! non-perturbation pin: the JSONL line schema is stable and parseable,
//! the typed gap/auto metrics rebuild their legacy note strings `==`,
//! and a NOTE_CAP flood that truncates the note log leaves the typed
//! metrics complete.
//!
//! `scripts/ci.sh --obs` runs this suite; `--quick` runs the
//! `non_perturb` subset.

use std::path::PathBuf;

use bwkm::bwkm::{BwkmCfg, TracePoint};
use bwkm::coordinator::StreamingBwkm;
use bwkm::data::loader::{save_bin, BinChunks};
use bwkm::data::simulate;
use bwkm::kmeans::{stepper_for, AssignCfg, AssignMode, AutoChoice};
use bwkm::metrics::counter::NOTE_CAP;
use bwkm::metrics::DistanceCounter;
use bwkm::obs::{Recorder, EVENT_TAIL_CAP};
use bwkm::rpkm::{grid_rpkm, grid_rpkm_rec, RpkmCfg};
use bwkm::store::{self, Model};
use bwkm::util::Rng;

/// Named fixed seeds — quoted in every assertion context so a failure
/// names its reproduction.
const BWKM_SEED: u64 = 0x0B5_0001;
const RPKM_SEED: u64 = 0x0B5_0002;
const STREAM_SEED: u64 = 0x0B5_0003;
const RESUME_SEED: u64 = 0x0B5_0004;
const GAP_SEED: u64 = 0x0B5_0005;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_trace_eq(ctx: &str, a: &[TracePoint], b: &[TracePoint]) {
    assert_eq!(a.len(), b.len(), "{ctx}: trace lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.outer_iter, y.outer_iter, "{ctx}");
        assert_eq!(x.distances, y.distances, "{ctx}: bill at outer {}", x.outer_iter);
        assert_eq!(x.blocks, y.blocks, "{ctx}");
        assert_eq!(x.occupied, y.occupied, "{ctx}");
        assert_eq!(x.boundary, y.boundary, "{ctx}");
        assert_eq!(x.weighted_error.to_bits(), y.weighted_error.to_bits(), "{ctx}");
        assert_eq!(x.bound.to_bits(), y.bound.to_bits(), "{ctx}");
        assert_eq!(x.full_error.map(f64::to_bits), y.full_error.map(f64::to_bits), "{ctx}");
        assert_eq!(x.lloyd_iters, y.lloyd_iters, "{ctx}");
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("bwkm_obs_{tag}_{}", std::process::id()))
}

/// Every trace line is one flat JSON object with the pinned field order
/// `ts, kind, name, value` and a known `kind`.
fn assert_jsonl_schema(path: &PathBuf) {
    let text = std::fs::read_to_string(path).expect("read trace");
    assert!(!text.is_empty(), "trace {} is empty", path.display());
    for line in text.lines() {
        assert!(line.starts_with("{\"ts\": "), "bad ts prefix: {line}");
        assert!(line.ends_with('}'), "unterminated line: {line}");
        let kind_at = line.find("\"kind\": \"").expect("kind field");
        let rest = &line[kind_at + 9..];
        let kind = &rest[..rest.find('"').expect("kind close")];
        assert!(
            matches!(kind, "span" | "counter" | "gauge" | "event"),
            "unknown kind `{kind}` in: {line}"
        );
        assert!(line.contains("\"name\": \""), "missing name: {line}");
        assert!(line.contains("\"value\": "), "missing value: {line}");
        // Pinned field order: ts < kind < name < value.
        let name_at = line.find("\"name\": \"").unwrap();
        let value_at = line.find("\"value\": ").expect("value field");
        assert!(kind_at < name_at && name_at < value_at, "field order drifted: {line}");
    }
}

#[test]
fn non_perturb_bwkm_off_vs_jsonl() {
    let ds = simulate("3RN", 0.003, 7).unwrap();
    let k = 3;
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cfg.max_outer = 4;
    cfg.eval_full_error = true;

    let c_off = DistanceCounter::new();
    let off = bwkm::bwkm::run(&ds, k, &cfg, &mut Rng::new(BWKM_SEED), &c_off);

    let trace = tmp("bwkm.jsonl");
    let c_on = DistanceCounter::new();
    let rec = Recorder::jsonl(&trace).unwrap();
    let on = bwkm::bwkm::run_rec(&ds, k, &cfg, &mut Rng::new(BWKM_SEED), &c_on, &rec);
    rec.flush();

    assert_eq!(bits(&off.centroids), bits(&on.centroids), "bwkm: centroids");
    assert_eq!(off.stop, on.stop, "bwkm: stop reason");
    assert_trace_eq("bwkm", &off.trace, &on.trace);
    assert_eq!(c_off.get(), c_on.get(), "bwkm: counter totals");
    assert_eq!(c_off.notes(), c_on.notes(), "bwkm: note logs");
    assert_eq!(bits(&off.d1), bits(&on.d1), "bwkm: top-1 distances");
    assert_eq!(bits(&off.d2), bits(&on.d2), "bwkm: top-2 distances");

    // The same trace doubles as the schema fixture.
    assert_jsonl_schema(&trace);
    // The typed bill bridge saw exactly what the counter billed.
    assert_eq!(rec.counter_total("bwkm.distances"), Some(c_on.get()), "bridged bill");
    std::fs::remove_file(&trace).ok();
}

#[test]
fn non_perturb_rpkm_off_vs_jsonl() {
    let ds = simulate("3RN", 0.003, 9).unwrap();
    let k = 3;
    let cfg = RpkmCfg::default();

    let c_off = DistanceCounter::new();
    let off = grid_rpkm(&ds, k, &cfg, &mut Rng::new(RPKM_SEED), &c_off);

    let trace = tmp("rpkm.jsonl");
    let c_on = DistanceCounter::new();
    let rec = Recorder::jsonl(&trace).unwrap();
    let on = grid_rpkm_rec(&ds, k, &cfg, &mut Rng::new(RPKM_SEED), &c_on, &rec);
    rec.flush();

    assert_eq!(bits(&off.centroids), bits(&on.centroids), "rpkm: centroids");
    assert_eq!(off.trace.len(), on.trace.len(), "rpkm: trace length");
    for (a, b) in off.trace.iter().zip(&on.trace) {
        assert_eq!(a.level, b.level, "rpkm: level");
        assert_eq!(a.distances, b.distances, "rpkm: per-level bill");
        assert_eq!(a.weighted_error.to_bits(), b.weighted_error.to_bits(), "rpkm: E^P");
    }
    assert_eq!(c_off.get(), c_on.get(), "rpkm: counter totals");
    assert_eq!(c_off.notes(), c_on.notes(), "rpkm: note logs");
    assert_jsonl_schema(&trace);
    std::fs::remove_file(&trace).ok();
}

#[test]
fn non_perturb_streaming_chunk_worker_grid() {
    let ds = simulate("3RN", 0.003, 11).unwrap();
    let (d, k) = (ds.d, 3);
    let mut cfg = BwkmCfg::for_dataset(ds.n, d, k);
    cfg.max_outer = 3;
    cfg.eval_full_error = false;
    let bin = tmp("grid.bin");
    save_bin(&ds, &bin).unwrap();

    for &chunk_rows in &[64usize, 311] {
        for &threads in &[1usize, 2, 4] {
            let ctx = format!("stream chunk={chunk_rows} threads={threads} seed={STREAM_SEED:#x}");
            let c_off = DistanceCounter::new();
            let mut sb =
                StreamingBwkm::new(BinChunks::opener(&bin, chunk_rows), d).with_threads(threads);
            let off = sb.run(k, &cfg, &mut Rng::new(STREAM_SEED), &c_off).unwrap();

            let trace = tmp(&format!("grid_{chunk_rows}_{threads}.jsonl"));
            let rec = Recorder::jsonl(&trace).unwrap();
            let c_on = DistanceCounter::new();
            let mut sb =
                StreamingBwkm::new(BinChunks::opener(&bin, chunk_rows), d).with_threads(threads);
            let on = sb.run_rec(k, &cfg, &mut Rng::new(STREAM_SEED), &c_on, &rec).unwrap();
            rec.flush();

            assert_eq!(bits(&off.centroids), bits(&on.centroids), "{ctx}: centroids");
            assert_eq!(off.stop, on.stop, "{ctx}: stop reason");
            assert_eq!(off.passes, on.passes, "{ctx}: pass count");
            assert_trace_eq(&ctx, &off.trace, &on.trace);
            assert_eq!(c_off.get(), c_on.get(), "{ctx}: counter totals");
            assert_eq!(c_off.notes(), c_on.notes(), "{ctx}: note logs");
            assert_jsonl_schema(&trace);
            std::fs::remove_file(&trace).ok();
        }
    }
    std::fs::remove_file(&bin).ok();
}

#[test]
fn non_perturb_service_resume_off_vs_jsonl() {
    let ds = simulate("3RN", 0.003, 13).unwrap();
    let k = 3;
    let mut cut_cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cut_cfg.max_outer = 2;
    cut_cfg.eval_full_error = false;
    let mut full_cfg = cut_cfg.clone();
    full_cfg.max_outer = 5;

    // One iteration-capped snapshot both resumes start from.
    let cb = DistanceCounter::new();
    let mut rb = Rng::new(RESUME_SEED);
    let b = bwkm::bwkm::run(&ds, k, &cut_cfg, &mut rb, &cb);
    let model = Model::from_run(&b, &cut_cfg, &rb, &cb);

    let c_off = DistanceCounter::new();
    let mut r_off = Rng::new(1);
    let off = store::resume(&model, &ds, &full_cfg, &mut r_off, &c_off).unwrap();

    let trace = tmp("resume.jsonl");
    let rec = Recorder::jsonl(&trace).unwrap();
    let c_on = DistanceCounter::new();
    let mut r_on = Rng::new(1);
    let on = store::resume_rec(&model, &ds, &full_cfg, &mut r_on, &c_on, &rec).unwrap();
    rec.flush();

    assert_eq!(bits(&off.centroids), bits(&on.centroids), "resume: centroids");
    assert_eq!(off.stop, on.stop, "resume: stop reason");
    assert_trace_eq("resume", &off.trace, &on.trace);
    assert_eq!(c_off.get(), c_on.get(), "resume: counter totals");
    assert_eq!(c_off.notes(), c_on.notes(), "resume: note logs");
    assert_eq!(r_off.state(), r_on.state(), "resume: RNG streams");

    // The resume event made it to the trace with the snapshot's facts.
    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("\"name\": \"store.resume\""), "missing store.resume event");
    assert_jsonl_schema(&trace);
    std::fs::remove_file(&trace).ok();
}

#[test]
fn typed_gap_metrics_rebuild_the_pinned_note() {
    // An approximate (closure) run publishes its §2.9 quality gap twice:
    // the pinned `gap[…]` note (compatibility surface) and §2.11 typed
    // gauges. The gauges must rebuild the note string `==` — same
    // values, same formatting — so neither surface can drift.
    let ds = simulate("3RN", 0.003, 17).unwrap();
    let k = 3;
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, k);
    cfg.max_outer = 3;
    cfg.eval_full_error = false;
    cfg.assign = AssignCfg { mode: AssignMode::Closure, ..AssignCfg::default() };

    let rec = Recorder::summary();
    let counter = DistanceCounter::new();
    let mut stepper = stepper_for(&cfg.assign);
    let _out = bwkm::bwkm::run_with_rec(
        stepper.as_mut(),
        &ds,
        k,
        &cfg,
        &mut Rng::new(GAP_SEED),
        &counter,
        &rec,
    );

    let note = counter
        .notes()
        .into_iter()
        .find(|n| n.starts_with("gap["))
        .expect("closure run must publish a gap note");

    let backend = rec.event_stats("gap.backend").expect("gap.backend event").1.pop().unwrap();
    let approx_err = rec.gauge_last("gap.approx_err").expect("gap.approx_err");
    let exact_err = rec.gauge_last("gap.exact_err").expect("gap.exact_err");
    let rel = rec.gauge_last("gap.rel").expect("gap.rel");
    let hit_rate = rec.gauge_last("gap.hit_rate").expect("gap.hit_rate");
    let fallbacks = rec.gauge_last("gap.fallbacks").expect("gap.fallbacks") as u64;
    let rebuilt = format!(
        "gap[{backend}]: E_approx={approx_err:.6e} E_exact={exact_err:.6e} rel={rel:.3e} \
         hit={:.1}% fallbacks={fallbacks}",
        hit_rate * 100.0
    );
    assert_eq!(rebuilt, note, "typed gap metrics drifted from the pinned note");

    // The auto engine's typed tallies agree with its note log: the
    // cumulative per-choice gauges sum to the step count, which equals
    // the number of `auto[…]` notes (one per engine step, uncapped here).
    let steps = rec.gauge_last("auto.steps").expect("auto.steps") as u64;
    let tallied: u64 = AutoChoice::ALL
        .iter()
        .filter_map(|c| rec.gauge_last(&format!("auto.choice.{}", c.name())))
        .map(|v| v as u64)
        .sum();
    assert_eq!(tallied, steps, "per-choice tallies must sum to the step count");
    let auto_notes = counter.notes().iter().filter(|n| n.starts_with("auto[")).count() as u64;
    assert_eq!(steps, auto_notes, "typed step count drifted from the auto[…] note log");
}

#[test]
fn note_cap_flood_keeps_typed_metrics_complete() {
    // The legacy note log truncates at NOTE_CAP; the typed stream must
    // not. Flood both: every typed record is still counted (events keep
    // an exact count with a bounded tail; counters keep exact sums).
    let flood = NOTE_CAP + 100;
    let counter = DistanceCounter::new();
    let rec = Recorder::summary();
    for i in 0..flood {
        counter.note(format!("auto[{i}]: serial"));
        rec.event("auto.switch", "serial");
        rec.counter("flood.records", 1);
    }
    let notes = counter.notes();
    assert_eq!(notes.len(), NOTE_CAP + 1, "note log caps at NOTE_CAP plus the marker");

    let (count, tail) = rec.event_stats("auto.switch").expect("flooded event");
    assert_eq!(count as usize, flood, "event count must stay exact under flood");
    assert_eq!(tail.len(), EVENT_TAIL_CAP, "tail is bounded, count is not");
    assert_eq!(rec.counter_total("flood.records"), Some(flood as u64), "counter sums stay exact");
}
