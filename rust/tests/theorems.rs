//! The paper's remaining formal results as executable properties.
//! (Theorems 1, 2, 3, A.1, A.2 and Lemma A.1 live next to their modules;
//! this suite covers Theorem A.4 and the §1.3 structural claims.)

use bwkm::data::Dataset;
use bwkm::kmeans::weighted_lloyd::max_shift;
use bwkm::metrics::{kmeans_error, DistanceCounter};
use bwkm::util::prop;
use bwkm::util::Rng;

/// Theorem A.4: if ‖C − C'‖∞ ≤ ε_w then |E^D(C) − E^D(C')| ≤ ε — the
/// displacement-based stopping criterion is sound for the Eq. 2 criterion.
///
/// NOTE — paper erratum (documented in EXPERIMENTS.md): the paper states
/// ε_w = sqrt(l² + ε²/n²) − l, but its own proof chain ends at
/// n·ε_w² + 2·n·l·ε_w, which equals ε only for ε_w = sqrt(l² + ε/n) − l
/// (with the paper's ε_w the bound evaluates to ε²/n instead, and a direct
/// counterexample to the stated form exists — this test found one). We
/// test the corrected ε_w.
#[test]
fn theorem_a4_displacement_criterion_is_sound() {
    prop::check("thm-a4", 40, |g| {
        let n = g.int(10, 200);
        let d = g.int(1, 4);
        let k = g.int(1, 5);
        let data = g.blobs(n, d, 3, 1.0);
        let ds = Dataset::new(data, d);
        let bbox = bwkm::geometry::BBox::of(&ds.data, d, None).unwrap();
        let l = bbox.diagonal();
        if l == 0.0 {
            return;
        }

        // Centroids inside the bounding box (the theorem's d(x, C) ≤ l
        // regime), perturbed by at most ε_w.
        let mut c1 = Vec::with_capacity(k * d);
        for _ in 0..k {
            let i = g.rng.usize(n);
            c1.extend_from_slice(ds.row(i));
        }
        let eps = g.f64(1e-3, 10.0) * n as f64; // target error tolerance
        // Corrected ε_w (see erratum note above).
        let eps_w = (l * l + eps / n as f64).sqrt() - l;

        // Random displacement with ‖·‖∞ ≤ ε_w (each centroid moved by a
        // vector of norm ≤ ε_w, clamped back into the box).
        let mut c2 = c1.clone();
        for c in 0..k {
            let dir: Vec<f64> = (0..d).map(|_| g.rng.normal()).collect();
            let norm = dir.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
            let step = g.f64(0.0, 1.0) * eps_w;
            for j in 0..d {
                let v = c2[c * d + j] + dir[j] / norm * step;
                c2[c * d + j] = v.clamp(bbox.lo[j], bbox.hi[j]);
            }
        }
        assert!(max_shift(&c1, &c2, d, k) <= eps_w * (1.0 + 1e-9));

        let counter = DistanceCounter::new();
        let e1 = kmeans_error(&ds.data, d, &c1, &counter);
        let e2 = kmeans_error(&ds.data, d, &c2, &counter);
        assert!(
            (e1 - e2).abs() <= eps * (1.0 + 1e-9),
            "Theorem A.4 violated: |{e1} - {e2}| = {} > eps {eps} (eps_w {eps_w})",
            (e1 - e2).abs()
        );
    });
}

/// §1.3: "if all the instances in P are correctly assigned for C and C',
/// the difference between the error of both sets equals the difference of
/// their weighted error" — already property-tested at module level; here
/// the *consequence* used by BWKM's bench traces: with singleton blocks the
/// weighted error IS the full error.
#[test]
fn singleton_partition_weighted_error_equals_full_error() {
    prop::check("singleton-werr", 25, |g| {
        let n = g.int(2, 150);
        let d = g.int(1, 4);
        let k = g.int(1, 4);
        let data = g.cloud(n, d, 2.0);
        let ds = Dataset::new(data, d);
        let cents = g.cloud(k, d, 2.0);
        let counter = DistanceCounter::new();
        let full = kmeans_error(&ds.data, d, &cents, &counter);
        let weights = vec![1.0; n];
        let wtd = bwkm::metrics::weighted_error(&ds.data, &weights, d, &cents, &counter);
        assert!((full - wtd).abs() <= 1e-9 * full.max(1.0));
    });
}

/// §2.3's storage claim: the misassignment function for the *whole*
/// partition is computable from the last weighted-Lloyd iteration with no
/// extra distance computations. We pin that exactness: computing ε for all
/// blocks adds zero to the counter.
#[test]
fn epsilon_computation_is_distance_free() {
    let mut g = prop::Gen { rng: Rng::new(77), case: 0 };
    let ds = Dataset::new(g.blobs(500, 3, 4, 0.7), 3);
    let mut partition = bwkm::partition::Partition::root(&ds);
    let mut rng = Rng::new(3);
    for _ in 0..40 {
        let b = rng.usize(partition.len());
        if partition.blocks[b].weight() > 1 {
            partition.split(b, &ds);
        }
    }
    let (reps, weights, ids) = partition.reps_weights();
    let cents = g.cloud(4, 3, 3.0);
    let counter = DistanceCounter::new();
    let step = {
        use bwkm::kmeans::{NativeStepper, Stepper};
        NativeStepper::new().step(&reps, &weights, 3, &cents, &counter)
    };
    let before = counter.get();
    let eps = bwkm::bwkm::epsilons(&partition, &ids, &step.d1, &step.d2);
    let bound = bwkm::bwkm::theorem2_bound(&partition, &ids, &weights, &step.d1, &eps);
    assert_eq!(counter.get(), before, "ε/bound computation must be distance-free");
    assert!(bound.is_finite());
}

/// Monotone link between boundary emptiness and Theorem 2: if the boundary
/// is empty, the ε-part of the Theorem 2 bound vanishes, leaving only the
/// diagonal quantization term.
#[test]
fn empty_boundary_bound_reduces_to_quantization_term() {
    prop::check("bound-structure", 20, |g| {
        let n = g.int(5, 120);
        let d = g.int(1, 3);
        let ds = Dataset::new(g.blobs(n, d, 2, 0.4), d);
        let mut partition = bwkm::partition::Partition::root(&ds);
        let mut rng = g.rng.fork(2);
        for _ in 0..60 {
            let b = rng.usize(partition.len());
            if partition.blocks[b].weight() > 1 {
                partition.split(b, &ds);
            }
        }
        let (reps, weights, ids) = partition.reps_weights();
        let k = 2.min(weights.len());
        let cents: Vec<f64> = reps[..k * d].to_vec();
        let counter = DistanceCounter::new();
        let step = {
            use bwkm::kmeans::{NativeStepper, Stepper};
            NativeStepper::new().step(&reps, &weights, d, &cents, &counter)
        };
        let eps = bwkm::bwkm::epsilons(&partition, &ids, &step.d1, &step.d2);
        if !bwkm::bwkm::boundary(&eps).is_empty() {
            return;
        }
        let bound = bwkm::bwkm::theorem2_bound(&partition, &ids, &weights, &step.d1, &eps);
        let quant: f64 = ids
            .iter()
            .enumerate()
            .map(|(row, &b)| {
                let l = partition.blocks[b].diagonal();
                (weights[row] - 1.0) * 0.5 * l * l
            })
            .sum();
        assert!((bound - quant).abs() <= 1e-9 * quant.max(1.0));
    });
}
