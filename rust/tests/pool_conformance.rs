//! Pool / arena / generation-cache conformance (DESIGN.md §2.12).
//!
//! The zero-allocation steady state must be **unobservable** in every
//! output: the shared persistent worker pool, the reusable `AssignOut` /
//! `StepOut` arenas and the generation-keyed caches (centroid norms, f32
//! centroid mirrors, closure tables) may change *where* bytes land and
//! *when* derived state is rebuilt, but never a single output bit, a
//! counter total, or a note. This suite pins:
//!
//! * bit-identity (`==`, no tolerances) of the arena entry points
//!   (`assign_top2_into`, `step_into`) against the per-call entry points
//!   (`assign_top2`, `step`) across backends {serial, normpruned,
//!   bounded, closure, vector} × thread counts {1, 2, 8};
//! * BWKM end-to-end: centroids, the full iteration trace, counter
//!   totals and counter notes identical across thread counts;
//! * the §2.12 allocation guarantee, via a counting global allocator:
//!   a warm exact `weighted_step` performs **zero** heap allocations on
//!   the leader thread, for the serial and the pooled sharded path; and
//!   the `Sharded` fan-in regression — a cold `assign_top2` allocates
//!   exactly its three output buffers (one allocation each), not the
//!   retired partials-then-extend double copy.
//!
//! Allocation counts are kept **per thread** (`thread_local!`), so the
//! pins measure the leader path deterministically even while the pool's
//! background workers (or the test harness's other threads) run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use bwkm::bwkm::BwkmCfg;
use bwkm::coordinator::ShardedStepper;
use bwkm::data::simulate;
use bwkm::kmeans::assign::{
    weighted_step_into, Assigner, AssignOut, AutoAssigner, BoundedAssigner, ClosureAssigner,
    KernelKind, NormPrunedAssigner, Precision, SerialAssigner, Sharded, ShardedAssigner,
    StepScratch, VectorAssigner,
};
use bwkm::kmeans::{weighted_lloyd_with, NativeStepper, StepOut, WLloydCfg};
use bwkm::metrics::DistanceCounter;
use bwkm::util::Rng;

// ---------------------------------------------------------------------------
// Counting allocator (the §2.12 allocation-accounting harness)
// ---------------------------------------------------------------------------

/// Global allocator that tallies allocations per thread. `try_with`
/// guards against TLS teardown; counting is best-effort there, exact on
/// live test threads — which is where every pin below measures.
struct CountingAlloc;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Heap allocations `f` performed **on this thread**.
fn thread_allocs(f: impl FnOnce()) -> u64 {
    let before = TL_ALLOCS.with(|c| c.get());
    f();
    TL_ALLOCS.with(|c| c.get()) - before
}

fn counter() -> DistanceCounter {
    DistanceCounter::new()
}

fn corpus(m: usize, d: usize, k: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let reps: Vec<f64> = (0..m * d).map(|_| rng.normal() * 2.0).collect();
    let weights: Vec<f64> = (0..m).map(|_| 1.0 + rng.usize(9) as f64).collect();
    let cents: Vec<f64> = (0..k * d).map(|_| rng.normal() * 2.0).collect();
    (reps, weights, cents)
}

#[test]
fn counting_allocator_sees_allocations() {
    let n = thread_allocs(|| {
        std::hint::black_box(Vec::<u64>::with_capacity(32));
    });
    assert!(n >= 1, "allocator harness is blind");
    let z = thread_allocs(|| {
        std::hint::black_box(3u64 + 4);
    });
    assert_eq!(z, 0, "allocator harness over-counts");
}

// ---------------------------------------------------------------------------
// Bit-identity: arena entry points == per-call entry points
// ---------------------------------------------------------------------------

/// Drive two instances of the same backend down the same 4-step centroid
/// drift — one through the per-call `assign_top2`, one through the arena
/// `assign_top2_into` with a reused buffer — and pin outputs and counter
/// deltas `==` at every step. `expect_serial` additionally pins both
/// against the exact serial ground truth.
fn check_backend<B: Assigner>(
    mut percall: B,
    mut arena: B,
    m: usize,
    d: usize,
    k: usize,
    expect_serial: bool,
    name: &str,
) {
    let (reps, _w, mut cents) = corpus(m, d, k, 0xC0DE + m as u64 + k as u64);
    let mut drift = Rng::new(7);
    let mut out = AssignOut::default();
    for step in 0..4 {
        let c1 = counter();
        let a = percall.assign_top2(&reps, d, &cents, &c1);
        let c2 = counter();
        arena.assign_top2_into(&reps, d, &cents, &c2, &mut out);
        assert_eq!(a, out, "{name}: arena diverged at step {step} (m={m} d={d} k={k})");
        assert_eq!(c1.get(), c2.get(), "{name}: counter diverged at step {step}");
        assert_eq!(c1.notes(), c2.notes(), "{name}: notes diverged at step {step}");
        if expect_serial {
            let cs = counter();
            let s = SerialAssigner.assign_top2(&reps, d, &cents, &cs);
            assert_eq!(s, out, "{name}: diverged from serial at step {step}");
        }
        for v in cents.iter_mut() {
            *v += drift.normal() * 0.05;
        }
    }
}

#[test]
fn arena_paths_bit_identical_across_backends_and_threads() {
    for &(m, d, k) in &[(57, 3, 4), (220, 2, 7), (130, 17, 3), (9, 5, 6), (1, 2, 1)] {
        check_backend(SerialAssigner, SerialAssigner, m, d, k, true, "serial");
        check_backend(
            NormPrunedAssigner::new(),
            NormPrunedAssigner::new(),
            m,
            d,
            k,
            true,
            "normpruned",
        );
        check_backend(BoundedAssigner::new(), BoundedAssigner::new(), m, d, k, true, "bounded");
        check_backend(ClosureAssigner::new(2), ClosureAssigner::new(2), m, d, k, false, "closure");
        check_backend(
            VectorAssigner::new(KernelKind::Auto, Precision::F64),
            VectorAssigner::new(KernelKind::Auto, Precision::F64),
            m,
            d,
            k,
            true,
            "vector-f64",
        );
        check_backend(
            VectorAssigner::new(KernelKind::Auto, Precision::F32),
            VectorAssigner::new(KernelKind::Auto, Precision::F32),
            m,
            d,
            k,
            false,
            "vector-f32",
        );
        check_backend(AutoAssigner::new(), AutoAssigner::new(), m, d, k, false, "auto");
        for threads in [1usize, 2, 8] {
            check_backend(
                ShardedAssigner::new(threads),
                ShardedAssigner::new(threads),
                m,
                d,
                k,
                true,
                &format!("sharded-serial({threads})"),
            );
            check_backend(
                Sharded::<BoundedAssigner>::new(threads),
                Sharded::<BoundedAssigner>::new(threads),
                m,
                d,
                k,
                true,
                &format!("sharded-bounded({threads})"),
            );
            check_backend(
                Sharded::<NormPrunedAssigner>::new(threads),
                Sharded::<NormPrunedAssigner>::new(threads),
                m,
                d,
                k,
                true,
                &format!("sharded-normpruned({threads})"),
            );
        }
    }
}

#[test]
fn weighted_lloyd_on_pooled_steppers_matches_serial_across_thread_counts() {
    let (reps, weights, cents) = corpus(180, 4, 5, 0x51ED);
    let cfg = WLloydCfg { max_iters: 12, ..WLloydCfg::default() };
    let c0 = counter();
    let base = weighted_lloyd_with(&mut NativeStepper::new(), &reps, &weights, 4, &cents, &cfg, &c0);
    for threads in [1usize, 2, 8] {
        let c = counter();
        let mut stepper = ShardedStepper::new(threads);
        let got = weighted_lloyd_with(&mut stepper, &reps, &weights, 4, &cents, &cfg, &c);
        assert_eq!(base.centroids, got.centroids, "threads={threads}");
        assert_eq!(base.assign, got.assign, "threads={threads}");
        assert_eq!(base.d1, got.d1, "threads={threads}");
        assert_eq!(base.d2, got.d2, "threads={threads}");
        assert_eq!(base.werr.to_bits(), got.werr.to_bits(), "threads={threads}");
        assert_eq!(base.iters, got.iters, "threads={threads}");
        assert_eq!(c0.get(), c.get(), "threads={threads}: bill diverged");
    }
}

#[test]
fn bwkm_trace_bill_and_notes_pinned_across_thread_counts() {
    let ds = simulate("3RN", 0.004, 5).unwrap();
    let mut cfg = BwkmCfg::for_dataset(ds.n, ds.d, 3);
    cfg.max_outer = 5;
    let c1 = counter();
    let serial = bwkm::bwkm::run(&ds, 3, &cfg, &mut Rng::new(11), &c1);
    for threads in [1usize, 2, 8] {
        let c2 = counter();
        let mut stepper = ShardedStepper::new(threads);
        let pooled = bwkm::bwkm::run_with(&mut stepper, &ds, 3, &cfg, &mut Rng::new(11), &c2);
        assert_eq!(serial.centroids, pooled.centroids, "threads={threads}");
        assert_eq!(serial.d1, pooled.d1, "threads={threads}");
        assert_eq!(serial.d2, pooled.d2, "threads={threads}");
        assert_eq!(serial.stop, pooled.stop, "threads={threads}");
        // TracePoint carries no PartialEq; Debug is exact for our purpose
        // (bit-equal floats render identically).
        assert_eq!(
            format!("{:?}", serial.trace),
            format!("{:?}", pooled.trace),
            "threads={threads}: trace diverged"
        );
        assert_eq!(c1.get(), c2.get(), "threads={threads}: bill diverged");
        assert_eq!(c1.notes(), c2.notes(), "threads={threads}: notes diverged");
    }
}

// ---------------------------------------------------------------------------
// Generation-cache accounting (DESIGN.md §2.12 invalidation-by-generation)
// ---------------------------------------------------------------------------

#[test]
fn norm_cache_rebuilds_and_charges_only_when_centroids_change() {
    let (reps, _w, mut cents) = corpus(80, 4, 6, 0x9012);
    let k = 6u64;
    let mut np = NormPrunedAssigner::new();
    let c = counter();
    let a1 = np.assign_top2(&reps, 4, &cents, &c);
    let bill_cold = c.get();
    let a2 = np.assign_top2(&reps, 4, &cents, &c);
    let bill_warm = c.get() - bill_cold;
    assert_eq!(a1, a2, "cached norms changed an output");
    assert_eq!(
        bill_warm,
        bill_cold - k,
        "a repeat at unchanged centroids must shave exactly the k norm charges"
    );
    // A fresh instance replays the pre-cache per-call bill exactly.
    let cf = counter();
    let af = NormPrunedAssigner::new().assign_top2(&reps, 4, &cents, &cf);
    assert_eq!(af, a2);
    assert_eq!(cf.get(), bill_cold);
    // Any centroid change invalidates the generation: full bill again.
    cents[0] += 0.25;
    let before = c.get();
    let a3 = np.assign_top2(&reps, 4, &cents, &c);
    let cf3 = counter();
    let af3 = NormPrunedAssigner::new().assign_top2(&reps, 4, &cents, &cf3);
    assert_eq!(a3, af3);
    assert_eq!(c.get() - before, cf3.get(), "stale-generation rebuild must re-charge k");
}

#[test]
fn closure_table_cache_hit_reports_zero_bookkeeping() {
    let (reps, _w, cents) = corpus(150, 3, 5, 0xC105);
    let k = 5usize;
    let mut cl = ClosureAssigner::new(2);
    let c = counter();
    let _ = cl.assign_top2(&reps, 3, &cents, &c); // cold: exact fallback + prime
    let before = c.get();
    let w1 = cl.assign_top2(&reps, 3, &cents, &c); // warm: builds the table
    let d1 = c.get() - before;
    let s1 = cl.last_stats();
    assert_eq!(s1.bookkeeping, (k * (k - 1) / 2) as u64, "first warm call builds the table");
    assert_eq!(d1, s1.pairs + s1.bookkeeping, "§2.4: delta == own account");
    let before = c.get();
    let w2 = cl.assign_top2(&reps, 3, &cents, &c); // warm repeat: cache hit
    let d2 = c.get() - before;
    let s2 = cl.last_stats();
    assert_eq!(w1, w2, "cached closure table changed an output");
    assert_eq!(s2.bookkeeping, 0, "unchanged centroids must not re-bill the table");
    assert_eq!(d2, s2.pairs, "§2.4 stays exact on the cache hit");
}

// ---------------------------------------------------------------------------
// Allocation pins (the §2.12 steady-state guarantee)
// ---------------------------------------------------------------------------

#[test]
fn warm_weighted_step_is_allocation_free_serial_and_sharded() {
    let d = 5;
    let (reps, weights, cents) = corpus(120, d, 6, 0xA110);
    // Serial exact path: the whole step runs on this thread, so zero here
    // is the full steady-state guarantee.
    {
        let mut engine = SerialAssigner;
        let mut scratch = StepScratch::default();
        let mut out = StepOut::default();
        let c = counter();
        weighted_step_into(&mut engine, &mut scratch, &reps, &weights, d, &cents, &c, &mut out);
        let mut cur = cents.clone();
        for step in 0..3 {
            cur.copy_from_slice(&out.centroids);
            let n = thread_allocs(|| {
                weighted_step_into(
                    &mut engine, &mut scratch, &reps, &weights, d, &cur, &c, &mut out,
                );
            });
            assert_eq!(n, 0, "serial warm step {step} allocated {n} times");
        }
    }
    // Pooled sharded exact path: publish/claim/join and the shard windows
    // are allocation-free on the leader (§2.12 "no allocation on the
    // leader path"); the shard bodies run the same slice code pinned
    // above. threads=1 exercises that code fully on this thread.
    for threads in [1usize, 2, 8] {
        let mut engine = ShardedAssigner::new(threads);
        let mut scratch = StepScratch::default();
        let mut out = StepOut::default();
        let c = counter();
        weighted_step_into(&mut engine, &mut scratch, &reps, &weights, d, &cents, &c, &mut out);
        let mut cur = cents.clone();
        for step in 0..3 {
            cur.copy_from_slice(&out.centroids);
            let n = thread_allocs(|| {
                weighted_step_into(
                    &mut engine, &mut scratch, &reps, &weights, d, &cur, &c, &mut out,
                );
            });
            assert_eq!(n, 0, "sharded({threads}) warm step {step} allocated {n} times on the leader");
        }
    }
}

#[test]
fn sharded_cold_call_allocates_exactly_its_three_output_buffers() {
    // Regression for the retired partials-then-extend fan-in: shards now
    // write through disjoint windows of the pre-sized output, so a cold
    // `assign_top2` allocates the three output buffers once each — not a
    // partials vector plus a second full-size copy — and a warm
    // `assign_top2_into` allocates nothing at all (leader thread).
    let (reps, _w, cents) = corpus(160, 3, 4, 0x3A11);
    for threads in [1usize, 2, 8] {
        let mut sh = ShardedAssigner::new(threads);
        // Warm the pool (first use spawns its workers) outside the count.
        let _ = sh.assign_top2(&reps, 3, &cents, &counter());
        let c = counter();
        let mut out = AssignOut::default();
        let cold = thread_allocs(|| {
            out = sh.assign_top2(&reps, 3, &cents, &c);
        });
        assert_eq!(
            cold, 3,
            "threads={threads}: cold call must allocate assign/d1/d2 once each, got {cold}"
        );
        let warm = thread_allocs(|| {
            sh.assign_top2_into(&reps, 3, &cents, &c, &mut out);
        });
        assert_eq!(warm, 0, "threads={threads}: warm arena call allocated {warm} times");
    }
}
